"""Sync-vs-async: loss / bytes / simulated wall-clock (DESIGN.md Sec. 6).

Runs the same (stream, learner, kernel) workload through the lockstep
serial simulator and the asynchronous event-driven runtime, then sweeps
latency distributions and straggler fractions.  Claims checked:

- with an ideal network (zero latency, alpha=1, constant staleness) the
  async dynamic protocol's cumulative bytes match the serial ledger
  within 1% (they are byte-identical in practice);
- under a straggler fraction >= 0.25 the async runtime's simulated
  wall-clock beats the synchronized-barrier baseline priced on the very
  same compute-time draws.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import simulation
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.core.rkhs import KernelSpec
from repro.data import susy_stream
from repro.runtime import (AsyncProtocolConfig, SystemConfig,
                           run_async_simulation)

from .common import Row

T, M, D = 600, 4, 8
DELTA = 2.0

NETWORKS = {
    "ideal": dict(),
    "lan": dict(base_latency=0.05, latency_jitter=0.3, bandwidth=1e6),
    "wan": dict(base_latency=0.5, latency_jitter=0.5, bandwidth=1e5),
}
STRAGGLER_FRACS = [0.0, 0.25, 0.5]


def _learner():
    return LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.5, lam=0.01,
                         budget=64, kernel=KernelSpec("gaussian", gamma=0.3),
                         dim=D)


def run(quick: bool = False):
    t = 200 if quick else T
    X, Y = susy_stream(T=t, m=M, d=D, seed=0)
    lcfg = _learner()
    rows = []

    # ---- serial reference -------------------------------------------------
    t0 = time.perf_counter()
    res_s = simulation.run_kernel_simulation(
        lcfg, ProtocolConfig(kind="dynamic", delta=DELTA), X, Y)
    wall_s = (time.perf_counter() - t0) * 1e6 / t
    rows.append(Row(
        "async/serial_dynamic", wall_s,
        f"loss={res_s.total_loss:.1f};bytes={res_s.total_bytes};"
        f"syncs={res_s.num_syncs}"))

    # ---- async on the ideal network: byte-exactness claim -----------------
    acfg0 = AsyncProtocolConfig(kind="dynamic", delta=DELTA, alpha=1.0,
                                staleness="constant")
    t0 = time.perf_counter()
    res_0 = run_async_simulation(lcfg, acfg0, X, Y, sys_cfg=SystemConfig(),
                                 record_divergence=False)
    wall_0 = (time.perf_counter() - t0) * 1e6 / t
    byte_err = abs(res_0.total_bytes - res_s.total_bytes) \
        / max(res_s.total_bytes, 1)
    rows.append(Row(
        "async/ideal_dynamic", wall_0,
        f"loss={res_0.total_loss:.1f};bytes={res_0.total_bytes};"
        f"syncs={res_0.num_syncs};byte_err_vs_serial={byte_err:.4f};"
        f"sim_wall={res_0.wall_clock:.1f}"))

    # ---- latency x straggler sweep ----------------------------------------
    straggler_claims = []
    for net_name, net in NETWORKS.items():
        for frac in STRAGGLER_FRACS:
            sc = SystemConfig(seed=0, compute_jitter=0.3,
                              straggler_frac=frac, straggler_mult=4.0,
                              straggler_prob=0.3, **net)
            acfg = AsyncProtocolConfig(kind="dynamic", delta=DELTA,
                                       alpha=0.6, staleness="poly",
                                       agg_window=2 * net.get("base_latency", 0.0))
            t0 = time.perf_counter()
            res = run_async_simulation(lcfg, acfg, X, Y, sys_cfg=sc,
                                       record_divergence=False,
                                       barrier_num_syncs=res_s.num_syncs)
            wall = (time.perf_counter() - t0) * 1e6 / t
            rows.append(Row(
                f"async/{net_name}_straggler{frac}", wall,
                f"loss={res.total_loss:.1f};bytes={res.total_bytes};"
                f"syncs={res.num_syncs};sim_wall={res.wall_clock:.1f};"
                f"barrier_wall={res.barrier_wall_clock:.1f};"
                f"speedup={res.speedup_vs_barrier:.2f};"
                f"stale_max={res.max_staleness}"))
            if frac >= 0.25:
                straggler_claims.append(
                    res.wall_clock < res.barrier_wall_clock)

    claims = {
        "bytes_match_serial_1pct": byte_err < 0.01,
        "async_beats_barrier_when_straggling": all(straggler_claims),
        "loss_comparable_ideal": (res_0.total_loss
                                  < 1.05 * res_s.total_loss + 1.0),
    }
    rows.append(Row("async/claims", 0.0,
                    ";".join(f"{k}={v}" for k, v in claims.items())))
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run())
