"""Online serving suite (DESIGN.md Sec. 10, EXPERIMENTS.md §Serving).

The serving engine's two-sided contract, measured:

- **protocol side** — the same labeled stream pushed through
  ``serving.serve_stream`` (with query traffic riding along) must
  reproduce ``engine.run`` bit-for-bit on losses and integer-exactly
  on Sec. 3 bytes;
- **serving side** — micro-batching must pay: answering a bucket of B
  requests with ONE padded ``predict_batch`` call must beat B
  one-at-a-time calls by a clear multiple (per-call dispatch is the
  serving engine's whole reason to bucket).

Registered claims (asserted here, grepped by CI):

- ``serving_losses_identical`` / ``serving_bytes_identical`` — the
  parity contract over {SV, RFF} x dynamic on the bench stream;
- ``batched_predict_faster_2x`` — the measured batched-vs-solo
  speedup at bucket 32 is at least 2x (in practice far higher; the
  gate is deliberately loose because shared CI runners are noisy —
  the honest multiple is in the ``speedup`` column).

Max sustainable QPS at a p99 latency SLO (DESIGN.md Sec. 13,
EXPERIMENTS.md §Serving): a doubling + bisection search over the
Poisson arrival rate finds the largest rate at which a policy serves
with p99 latency <= SLO and zero requests shed — run once for the
static tick grid and once for continuous batching, same seeds, same
stream, same slot pool.  All quantities in the search live on the
simulated clock, so the resulting QPS numbers are deterministic under
seed and CAN be gated:

- ``continuous_beats_static_p99`` — continuous batching sustains at
  least the static tick grid's QPS at the same SLO;
- ``protocol_view_identical_under_load`` — every probe of both
  searches (including overloaded, shedding probes) reproduced
  ``engine.run`` bitwise on losses and integer-exactly on bytes;
- ``shed_only_when_over_capacity`` — with a bounded queue, a probe
  at a fraction of nominal capacity (max_bucket / predict_cost) sheds
  nothing, and a probe far above it sheds.

Latency percentiles / queue depths remain reported-never-gated
derived columns; the QPS-at-SLO numbers are gated because they are
event-clock quantities, not host timings.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.core.rff import RFFSpec
from repro.core.rkhs import KernelSpec
from repro.core.substrate import RFFSubstrate, substrate_of
from repro.data import susy_stream
from repro.runtime import SystemConfig
from repro.serving import PoissonArrivals, serve_stream

from .common import Row, timeit

T, M, D_IN = 600, 4, 8

# --- QPS-at-SLO search fixture (simulated units) ---------------------------
QPS_SLO = 0.3              # p99 latency target
QPS_PREDICT_COST = 0.04    # simulated seconds per predict launch
QPS_TICK = 0.25            # static policy's grid interval
QPS_BUCKETS = (1, 2, 4, 8, 16)
QPS_QUEUE = 128            # bounded queue for the search probes
QPS_CAPACITY = QPS_BUCKETS[-1] / QPS_PREDICT_COST   # 400 req/s nominal


def _kernel_cfg():
    return LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.5, lam=0.01,
                         budget=64, kernel=KernelSpec("gaussian", gamma=0.3),
                         dim=D_IN)


def _serve_row(name, learner, pcfg, X, Y):
    t = X.shape[0]
    res_ref = engine.run(learner, pcfg, X, Y)
    wall0 = time.perf_counter()
    res = serve_stream(
        learner, pcfg, X, Y, queries_per_round=4.0,
        sys_cfg=SystemConfig(seed=0, compute_jitter=0.3, base_latency=0.05,
                             bandwidth=1e7))
    wall = time.perf_counter() - wall0
    loss_ok = bool(np.array_equal(res_ref.cumulative_loss,
                                  res.sim.cumulative_loss))
    bytes_ok = bool(np.array_equal(res_ref.cumulative_bytes,
                                   res.sim.cumulative_bytes))
    pct = res.latency_percentiles()
    row = Row(
        f"serve/{name}", wall * 1e6 / t,
        f"requests={res.num_requests};rounds={res.rounds};"
        f"syncs={res.num_syncs};bytes={res.total_bytes};"
        f"p50={pct['p50']:.3f};p90={pct['p90']:.3f};p99={pct['p99']:.3f};"
        f"mean_queue_depth={float(res.queue_depth.mean()):.1f};"
        f"losses_identical={loss_ok};bytes_identical={bytes_ok}")
    return row, loss_ok, bytes_ok


def _batched_predict_speedup(X, Y, bucket=32, reps=20):
    """Warm batched bucket-B predict vs B warm one-at-a-time calls.
    The stream labels Y train the models through the protocol step so
    predict runs against non-trivial expansions."""
    sub = substrate_of(_kernel_cfg())
    step = jax.jit(engine.make_protocol_step(sub, "dynamic"))
    params = engine.params_of(ProtocolConfig(kind="dynamic", delta=2.0))
    carry = engine.init_protocol_carry(sub, X.shape[1])
    for t in range(min(X.shape[0], 100)):
        carry, _ = step(params, carry,
                        (jnp.asarray(X[t]), jnp.asarray(Y[t]),
                         jnp.asarray(t, jnp.int32)))
    models = sub.models_of(carry[0])

    rng = np.random.default_rng(0)
    lids = jnp.asarray(rng.integers(0, X.shape[1], bucket).astype(np.int32))
    Xb = jnp.asarray(X[:bucket, 0].astype(np.float32))
    predict = jax.jit(sub.predict_batch)
    batched = timeit(predict, models, lids, Xb, iters=reps) / 1e6

    def solo_pass():
        # blocking INSIDE the loop is the point here: each request
        # waits for its own answer, as a real one-at-a-time server would
        for i in range(bucket):
            jax.block_until_ready(predict(models, lids[i:i + 1],
                                          Xb[i:i + 1]))

    solo = timeit(solo_pass, iters=reps) / 1e6
    return batched, solo, solo / batched


def _linear_cfg():
    return LearnerConfig(algo="linear_sgd", loss="hinge", eta=0.1, lam=0.001,
                         dim=D_IN)


def _qps_probe(policy, rate, X, Y, pcfg, ref, *, seed=0,
               max_queue=QPS_QUEUE):
    """One serving run at Poisson rate ``rate``; returns
    (sustainable, p99, shed, parity_ok)."""
    res = serve_stream(
        _linear_cfg(), pcfg, X, Y,
        arrivals=PoissonArrivals(rate=rate, seed=seed), query_seed=seed,
        policy=policy, slots=1, buckets=QPS_BUCKETS,
        predict_cost=QPS_PREDICT_COST, tick_interval=QPS_TICK,
        slo=QPS_SLO, max_queue=max_queue, overload="shed",
        sys_cfg=SystemConfig(seed=0, base_compute=0.1))
    parity = bool(
        np.array_equal(ref.cumulative_loss, res.sim.cumulative_loss)
        and np.array_equal(ref.cumulative_bytes, res.sim.cumulative_bytes)
        and np.array_equal(ref.sync_rounds, res.sim.sync_rounds))
    p99 = res.latency_percentiles()["p99"]
    sustainable = bool(p99 <= QPS_SLO and res.num_shed == 0
                       and res.num_requests > 0)
    return sustainable, p99, res.num_shed, parity


def _max_qps(policy, X, Y, pcfg, ref, *, bisect_iters=6):
    """Largest Poisson rate sustaining p99 <= SLO with zero sheds:
    double from 16 until a probe fails (cap 2048), then bisect.
    Deterministic — every probe runs on the seeded event clock."""
    probes = 0
    all_parity = True
    lo, lo_p99 = 0.0, 0.0

    rate = 16.0
    while rate <= 2048.0:
        ok, p99, _, parity = _qps_probe(policy, rate, X, Y, pcfg, ref)
        probes += 1
        all_parity &= parity
        if not ok:
            break
        lo, lo_p99 = rate, p99
        rate *= 2.0
    else:
        return lo, lo_p99, probes, all_parity
    if lo == 0.0:       # never sustainable, even at the smallest probe
        return 0.0, p99, probes, all_parity

    hi = rate
    for _ in range(bisect_iters):
        mid = 0.5 * (lo + hi)
        ok, p99, _, parity = _qps_probe(policy, mid, X, Y, pcfg, ref)
        probes += 1
        all_parity &= parity
        if ok:
            lo, lo_p99 = mid, p99
        else:
            hi = mid
    return lo, lo_p99, probes, all_parity


def run(quick: bool = False):
    t = 150 if quick else T
    X, Y = susy_stream(T=t, m=M, d=D_IN, seed=0)
    pcfg = ProtocolConfig(kind="dynamic", delta=2.0)
    rows = []

    ok_loss = ok_bytes = True
    for name, learner in (
            ("sv_dynamic", _kernel_cfg()),
            ("rff_dynamic", RFFSubstrate(
                spec=RFFSpec(dim=D_IN, num_features=128, gamma=0.3, seed=0)))):
        row, lo, by = _serve_row(name, learner, pcfg, X, Y)
        rows.append(row)
        ok_loss &= lo
        ok_bytes &= by

    bucket = 32
    batched_s, solo_s, speedup = _batched_predict_speedup(X, Y, bucket=bucket)
    faster = bool(speedup >= 2.0)
    assert faster, (
        f"bucket-{bucket} batched predict only {speedup:.2f}x faster than "
        f"{bucket} one-at-a-time calls ({batched_s*1e6:.0f}us vs "
        f"{solo_s*1e6:.0f}us)")
    rows.append(Row(
        "serve/batched_predict", batched_s * 1e6,
        f"bucket={bucket};solo_us={solo_s*1e6:.0f};speedup={speedup:.1f}x"))

    assert ok_loss and ok_bytes, "serving parity violated"
    rows.append(Row(
        "serve/claims", 0.0,
        f"serving_losses_identical={ok_loss};"
        f"serving_bytes_identical={ok_bytes};"
        f"batched_predict_faster_2x={faster}"))

    # --- max sustainable QPS at the p99 SLO, static vs continuous ----------
    tq = 30 if quick else 60
    Xq, Yq = susy_stream(T=tq, m=M, d=D_IN, seed=0)
    pcfg_q = ProtocolConfig(kind="dynamic", delta=2.0)
    ref_q = engine.run(_linear_cfg(), pcfg_q, Xq, Yq)
    iters = 4 if quick else 6

    qps = {}
    parity_all = True
    for policy in ("tick", "continuous"):
        wall0 = time.perf_counter()
        max_rate, p99, probes, parity = _max_qps(
            policy, Xq, Yq, pcfg_q, ref_q, bisect_iters=iters)
        wall = time.perf_counter() - wall0
        qps[policy] = max_rate
        parity_all &= parity
        rows.append(Row(
            f"serve/qps_{policy}", wall * 1e6 / max(probes, 1),
            f"max_qps={max_rate:.0f};p99_at_max={p99:.3f};slo={QPS_SLO};"
            f"probes={probes};parity={parity}"))

    # admission sanity on the same fixture, tiny queue: well under
    # nominal capacity nothing sheds; far over it, admission must shed.
    _, _, shed_under, par_u = _qps_probe(
        "continuous", 0.25 * QPS_CAPACITY, Xq, Yq, pcfg_q, ref_q,
        max_queue=16)
    _, _, shed_over, par_o = _qps_probe(
        "continuous", 3.0 * QPS_CAPACITY, Xq, Yq, pcfg_q, ref_q,
        max_queue=16)
    parity_all &= par_u and par_o
    shed_sane = bool(shed_under == 0 and shed_over > 0)
    rows.append(Row(
        "serve/admission", 0.0,
        f"shed_under_capacity={shed_under};shed_over_capacity={shed_over};"
        f"capacity={QPS_CAPACITY:.0f}"))

    cont_wins = bool(qps["continuous"] >= qps["tick"] and
                     qps["continuous"] > 0)
    assert cont_wins, (
        f"continuous batching sustains {qps['continuous']:.0f} QPS < "
        f"static {qps['tick']:.0f} QPS at p99 <= {QPS_SLO}")
    assert parity_all, "protocol view diverged under load"
    assert shed_sane, (
        f"admission shed {shed_under} under capacity / {shed_over} over")
    rows.append(Row(
        "serve/slo_claims", 0.0,
        f"continuous_beats_static_p99={cont_wins};"
        f"protocol_view_identical_under_load={parity_all};"
        f"shed_only_when_over_capacity={shed_sane}"))
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run(quick=True))
