"""Online serving suite (DESIGN.md Sec. 10, EXPERIMENTS.md §Serving).

The serving engine's two-sided contract, measured:

- **protocol side** — the same labeled stream pushed through
  ``serving.serve_stream`` (with query traffic riding along) must
  reproduce ``engine.run`` bit-for-bit on losses and integer-exactly
  on Sec. 3 bytes;
- **serving side** — micro-batching must pay: answering a bucket of B
  requests with ONE padded ``predict_batch`` call must beat B
  one-at-a-time calls by a clear multiple (per-call dispatch is the
  serving engine's whole reason to bucket).

Registered claims (asserted here, grepped by CI):

- ``serving_losses_identical`` / ``serving_bytes_identical`` — the
  parity contract over {SV, RFF} x dynamic on the bench stream;
- ``batched_predict_faster_2x`` — the measured batched-vs-solo
  speedup at bucket 32 is at least 2x (in practice far higher; the
  gate is deliberately loose because shared CI runners are noisy —
  the honest multiple is in the ``speedup`` column).

Latency percentiles / queue depths are reported as derived columns,
never gated — they are simulated-timeline quantities, deterministic
under seed, but their *interest* is the trade-off shape, not a
threshold.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.core.rff import RFFSpec
from repro.core.rkhs import KernelSpec
from repro.core.substrate import RFFSubstrate, substrate_of
from repro.data import susy_stream
from repro.runtime import SystemConfig
from repro.serving import serve_stream

from .common import Row, timeit

T, M, D_IN = 600, 4, 8


def _kernel_cfg():
    return LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.5, lam=0.01,
                         budget=64, kernel=KernelSpec("gaussian", gamma=0.3),
                         dim=D_IN)


def _serve_row(name, learner, pcfg, X, Y):
    t = X.shape[0]
    res_ref = engine.run(learner, pcfg, X, Y)
    wall0 = time.perf_counter()
    res = serve_stream(
        learner, pcfg, X, Y, queries_per_round=4.0,
        sys_cfg=SystemConfig(seed=0, compute_jitter=0.3, base_latency=0.05,
                             bandwidth=1e7))
    wall = time.perf_counter() - wall0
    loss_ok = bool(np.array_equal(res_ref.cumulative_loss,
                                  res.sim.cumulative_loss))
    bytes_ok = bool(np.array_equal(res_ref.cumulative_bytes,
                                   res.sim.cumulative_bytes))
    pct = res.latency_percentiles()
    row = Row(
        f"serve/{name}", wall * 1e6 / t,
        f"requests={res.num_requests};rounds={res.rounds};"
        f"syncs={res.num_syncs};bytes={res.total_bytes};"
        f"p50={pct['p50']:.3f};p90={pct['p90']:.3f};p99={pct['p99']:.3f};"
        f"mean_queue_depth={float(res.queue_depth.mean()):.1f};"
        f"losses_identical={loss_ok};bytes_identical={bytes_ok}")
    return row, loss_ok, bytes_ok


def _batched_predict_speedup(X, Y, bucket=32, reps=20):
    """Warm batched bucket-B predict vs B warm one-at-a-time calls.
    The stream labels Y train the models through the protocol step so
    predict runs against non-trivial expansions."""
    sub = substrate_of(_kernel_cfg())
    step = jax.jit(engine.make_protocol_step(sub, "dynamic"))
    params = engine.params_of(ProtocolConfig(kind="dynamic", delta=2.0))
    carry = engine.init_protocol_carry(sub, X.shape[1])
    for t in range(min(X.shape[0], 100)):
        carry, _ = step(params, carry,
                        (jnp.asarray(X[t]), jnp.asarray(Y[t]),
                         jnp.asarray(t, jnp.int32)))
    models = sub.models_of(carry[0])

    rng = np.random.default_rng(0)
    lids = jnp.asarray(rng.integers(0, X.shape[1], bucket).astype(np.int32))
    Xb = jnp.asarray(X[:bucket, 0].astype(np.float32))
    predict = jax.jit(sub.predict_batch)
    batched = timeit(predict, models, lids, Xb, iters=reps) / 1e6

    def solo_pass():
        # blocking INSIDE the loop is the point here: each request
        # waits for its own answer, as a real one-at-a-time server would
        for i in range(bucket):
            jax.block_until_ready(predict(models, lids[i:i + 1],
                                          Xb[i:i + 1]))

    solo = timeit(solo_pass, iters=reps) / 1e6
    return batched, solo, solo / batched


def run(quick: bool = False):
    t = 150 if quick else T
    X, Y = susy_stream(T=t, m=M, d=D_IN, seed=0)
    pcfg = ProtocolConfig(kind="dynamic", delta=2.0)
    rows = []

    ok_loss = ok_bytes = True
    for name, learner in (
            ("sv_dynamic", _kernel_cfg()),
            ("rff_dynamic", RFFSubstrate(
                spec=RFFSpec(dim=D_IN, num_features=128, gamma=0.3, seed=0)))):
        row, lo, by = _serve_row(name, learner, pcfg, X, Y)
        rows.append(row)
        ok_loss &= lo
        ok_bytes &= by

    bucket = 32
    batched_s, solo_s, speedup = _batched_predict_speedup(X, Y, bucket=bucket)
    faster = bool(speedup >= 2.0)
    assert faster, (
        f"bucket-{bucket} batched predict only {speedup:.2f}x faster than "
        f"{bucket} one-at-a-time calls ({batched_s*1e6:.0f}us vs "
        f"{solo_s*1e6:.0f}us)")
    rows.append(Row(
        "serve/batched_predict", batched_s * 1e6,
        f"bucket={bucket};solo_us={solo_s*1e6:.0f};speedup={speedup:.1f}x"))

    assert ok_loss and ok_bytes, "serving parity violated"
    rows.append(Row(
        "serve/claims", 0.0,
        f"serving_losses_identical={ok_loss};"
        f"serving_bytes_identical={ok_bytes};"
        f"batched_predict_faster_2x={faster}"))
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run(quick=True))
