"""Population-scale runs: 10^5-10^6 simulated learners with churn and
partial participation (DESIGN.md Sec. 15, EXPERIMENTS.md §Population).

The population layer's whole value is measured here: loss vs Sec. 3
bytes as the coordinator's sampling rate sweeps the cohort, at learner
counts far beyond the per-process worlds of the other suites.  Primal
substrates only (the SV device ledger's int32 envelope refuses these
scales by design — ``accounting.device_sync_bytes_kernel``); the
paper's Sec. 4 fixed-size-model proposal is exactly what makes the
byte column integer-exact at 10^5 learners.

Registered claims (asserted here, grepped by CI):

- ``full_participation_identical`` — the masked scan core under an
  all-True mask reproduces ``engine.run`` BIT-FOR-BIT (losses, errors,
  bytes, sync rounds).  The oracle contract the whole layer rides on.
- ``bytes_scale_with_cohort`` — per sampling rate, the run's byte
  column equals the closed-form Sec. 3 oracle priced from (mask, sync
  decisions) alone — ``2 c_t |theta| B`` per sync plus ``|theta| B``
  per rejoiner — and total bytes increase strictly with the rate under
  a fixed periodic schedule.
- ``criterion_integer_exact`` — the Def. 1 monitor adopts the cohort
  ledger's byte series integer-exactly at every sampling rate
  (``monitor_population`` prices the bound at the largest cohort).

With >= 2 visible devices (the CI population step forces 8 host
devices) the rate-0.5 run also executes mesh-sharded and must match
the single-device run bitwise (``mesh_population_identical``).

The us_per_call column is per-round wall time of the warmed masked
engine at the row's population size.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import engine
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.core.substrate import substrate_of
from repro.data import separable_stream
from repro.population import (ALWAYS_ON, PopulationSpec, rejoin_counts,
                              run_population)
from repro.telemetry.monitor import monitor_population

from .common import Row

D = 4
RATES = (0.1, 0.5, 1.0)


def _lcfg():
    return LearnerConfig(algo="linear_sgd", loss="hinge", eta=0.1,
                         lam=0.001, dim=D)


def _oracle_cumulative_bytes(res, mask, num_params):
    """Closed-form Sec. 3 byte column from (mask, sync decisions):
    every rejoiner downloads |theta| B, every sync moves
    2 c_t |theta| B over the coordinator links."""
    T = mask.shape[0]
    sync_set = {int(t) for t in np.asarray(res.sync_rounds)}
    r = rejoin_counts(mask)
    c = mask.sum(axis=1).astype(np.int64)
    per = np.zeros(T, np.int64)
    for t in range(T):
        per[t] = int(r[t]) * num_params * 4
        if t in sync_set:
            per[t] += 2 * int(c[t]) * num_params * 4
    return np.cumsum(per)


def _full_participation_claim(rows):
    """Small-population bitwise parity: all-True mask == engine.run."""
    T, m = 40, 8
    X, Y = separable_stream(T=T, m=m, d=D, seed=1, margin=0.5)
    pcfg = ProtocolConfig(kind="dynamic", delta=0.5)
    oracle = engine.run(_lcfg(), pcfg, X, Y)
    pres = run_population(
        PopulationSpec(m_total=m, classes=((ALWAYS_ON, 1.0),)),
        _lcfg(), pcfg, X, Y)
    identical = bool(
        np.asarray(oracle.cumulative_loss).tobytes()
        == np.asarray(pres.sim.cumulative_loss).tobytes()
        and np.asarray(oracle.cumulative_errors).tobytes()
        == np.asarray(pres.sim.cumulative_errors).tobytes()
        and np.array_equal(oracle.cumulative_bytes,
                           pres.sim.cumulative_bytes)
        and np.array_equal(oracle.sync_rounds, pres.sim.sync_rounds))
    assert identical, "masked scan core diverged from engine.run"
    assert oracle.num_syncs > 0
    rows.append(Row(
        "population/claims", 0.0,
        f"syncs={oracle.num_syncs};"
        f"full_participation_identical={identical}"))


def _mesh_or_none(m_total):
    import jax

    if len(jax.devices()) < 2 or m_total % len(jax.devices()):
        return None
    from repro.launch.mesh import make_learner_mesh
    return make_learner_mesh()


def run(quick: bool = False):
    rows = []
    _full_participation_claim(rows)

    m_total = 100_000
    T = 12 if quick else 40
    num_params = substrate_of(_lcfg()).num_params
    X, Y = separable_stream(T=T, m=m_total, d=D, seed=0, margin=0.5)
    pcfg = ProtocolConfig(kind="periodic", period=3)

    totals = {}
    scale_ok = True
    exact_ok = True
    for rate in RATES:
        spec = PopulationSpec(m_total=m_total,
                              classes=((ALWAYS_ON, 1.0),),
                              sample_rate=rate, seed=7)
        pres = run_population(spec, _lcfg(), pcfg, X, Y)
        t0 = time.perf_counter()
        pres = run_population(spec, _lcfg(), pcfg, X, Y)   # warm
        us = (time.perf_counter() - t0) * 1e6 / T
        want = _oracle_cumulative_bytes(pres.sim, pres.participation,
                                        num_params)
        exact = bool(np.array_equal(
            np.asarray(pres.sim.cumulative_bytes, np.int64), want))
        exact_ok = exact_ok and exact
        totals[rate] = pres.sim.total_bytes
        mon = monitor_population(pres, _lcfg())
        mon_exact = bool(np.array_equal(
            mon.series().cumulative_bytes,
            np.asarray(pres.sim.cumulative_bytes, np.int64)))
        exact_ok = exact_ok and mon_exact
        rows.append(Row(
            f"population/rate{rate}", us,
            f"m={m_total};cohort={int(pres.cohort_sizes.max())};"
            f"errors={int(pres.sim.cumulative_errors[-1])};"
            f"bytes={pres.sim.total_bytes};syncs={pres.sim.num_syncs};"
            f"criterion_integer_exact={mon_exact};"
            f"monitor_ok={'true' if mon.ok else 'false'}"))
    scale_ok = bool(totals[0.1] < totals[0.5] < totals[1.0])
    assert exact_ok, "cohort byte column diverged from the Sec. 3 oracle"
    assert scale_ok, f"bytes not monotone in sampling rate: {totals}"
    rows.append(Row(
        "population/scaling", 0.0,
        ";".join(f"bytes@{r}={totals[r]}" for r in RATES)
        + f";bytes_scale_with_cohort={scale_ok and exact_ok}"))

    # churny mix: phones drop and recover; rejoin downloads are charged
    spec = PopulationSpec(m_total=m_total, sample_rate=0.8, seed=3)
    pres = run_population(spec, _lcfg(),
                          ProtocolConfig(kind="dynamic", delta=200.0), X, Y)
    want = _oracle_cumulative_bytes(pres.sim, pres.participation, num_params)
    churn_exact = bool(np.array_equal(
        np.asarray(pres.sim.cumulative_bytes, np.int64), want))
    assert churn_exact and pres.total_rejoins > 0
    rows.append(Row(
        "population/churn_dynamic", 0.0,
        f"m={m_total};mean_cohort={pres.mean_cohort:.0f};"
        f"rejoins={pres.total_rejoins};bytes={pres.sim.total_bytes};"
        f"syncs={pres.sim.num_syncs};"
        f"rejoin_bytes_exact={churn_exact}"))

    # mesh-sharded half (CI forces 8 host devices for this suite)
    mesh = _mesh_or_none(m_total)
    if mesh is not None:
        spec = PopulationSpec(m_total=m_total,
                              classes=((ALWAYS_ON, 1.0),),
                              sample_rate=0.5, seed=7)
        p1 = run_population(spec, _lcfg(), pcfg, X, Y)
        p8 = run_population(spec, _lcfg(), pcfg, X, Y, mesh=mesh)
        same = bool(
            np.asarray(p1.sim.cumulative_loss).tobytes()
            == np.asarray(p8.sim.cumulative_loss).tobytes()
            and np.array_equal(p1.sim.cumulative_bytes,
                               p8.sim.cumulative_bytes)
            and np.array_equal(p1.sim.sync_rounds, p8.sim.sync_rounds))
        assert same, "mesh-sharded population diverged"
        rows.append(Row(
            "population/mesh/claims", 0.0,
            f"devices={len(mesh.devices.flat)};"
            f"mesh_population_identical={same}"))

    if not quick:
        # one 10^6-learner round trip: the memory-bound upper end
        m_big = 1_000_000
        Tb = 6
        Xb, Yb = separable_stream(T=Tb, m=m_big, d=D, seed=0, margin=0.5)
        spec = PopulationSpec(m_total=m_big, classes=((ALWAYS_ON, 1.0),),
                              sample_rate=0.2, seed=7)
        pres = run_population(spec, _lcfg(),
                              ProtocolConfig(kind="periodic", period=2),
                              Xb, Yb)
        t0 = time.perf_counter()
        pres = run_population(spec, _lcfg(),
                              ProtocolConfig(kind="periodic", period=2),
                              Xb, Yb)
        us = (time.perf_counter() - t0) * 1e6 / Tb
        want = _oracle_cumulative_bytes(pres.sim, pres.participation,
                                        num_params)
        exact = bool(np.array_equal(
            np.asarray(pres.sim.cumulative_bytes, np.int64), want))
        assert exact
        rows.append(Row(
            "population/m1e6", us,
            f"m={m_big};cohort={int(pres.cohort_sizes.max())};"
            f"bytes={pres.sim.total_bytes};syncs={pres.sim.num_syncs};"
            f"bytes_exact={exact}"))
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run())
