"""Microbenchmarks of the Pallas compute kernels vs their jnp oracles
(CPU interpret mode here; the derived column reports the TPU-relevant
HBM-traffic saving of the fused quadform path)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from .common import Row, timeit


def run(quick: bool = False):
    rng = np.random.default_rng(0)
    M = 256 if quick else 512
    d = 64
    X = jnp.asarray(rng.normal(size=(M, d)), jnp.float32)
    Y = jnp.asarray(rng.normal(size=(M, d)), jnp.float32)
    a = jnp.asarray(rng.normal(size=(M,)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(M,)), jnp.float32)

    rows = []
    g_ref = jax.jit(lambda X, Y: ref.gram_ref(X, Y, gamma=0.5))
    us = timeit(g_ref, X, Y)
    rows.append(Row("kernels/gram_jnp_oracle", us, f"M={M};d={d}"))
    us = timeit(lambda: ops.gram(X, Y, gamma=0.5, force_pallas=True))
    rows.append(Row("kernels/gram_pallas_interpret", us,
                    "validated=allclose;mode=interpret(CPU)"))

    q_ref = jax.jit(lambda X, Y, a, b: ref.quadform_ref(X, Y, a, b, gamma=0.5))
    us = timeit(q_ref, X, Y, a, b)
    hbm_naive = M * M * 4
    hbm_fused = 2 * M * d * 4
    rows.append(Row("kernels/quadform_jnp_oracle", us,
                    f"hbm_gram_bytes={hbm_naive}"))
    us = timeit(lambda: ops.quadform(X, Y, a, b, gamma=0.5,
                                    force_pallas=True))
    rows.append(Row("kernels/quadform_pallas_interpret", us,
                    f"hbm_stream_bytes={hbm_fused};"
                    f"traffic_saving={hbm_naive / hbm_fused:.0f}x"))

    W = jnp.asarray(rng.normal(size=(M, d)), jnp.float32)
    bias = jnp.asarray(rng.uniform(size=(M,)) * 6.28, jnp.float32)
    r_ref = jax.jit(lambda X: ref.rff_ref(X, W, bias))
    us = timeit(r_ref, X)
    rows.append(Row("kernels/rff_jnp_oracle", us, f"D={M}"))
    us = timeit(lambda: ops.rff_features(X, W, bias, force_pallas=True))
    rows.append(Row("kernels/rff_pallas_interpret", us,
                    "fused=proj+bias+cos"))
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run())
