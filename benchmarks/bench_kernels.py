"""Microbenchmarks of the Pallas compute kernels vs their jnp oracles
(CPU interpret mode here; the derived columns report the TPU-relevant
HBM-traffic savings of the fused paths).

Two gated claims ride in this suite (checked by tools/bench_compare.py
against benchmarks/baselines/BENCH_kernels.json in CI):

- ``kernels/fused_round_sv/fused_step_faster`` — the fused scan round
  (one shared predict feeding ``kernel_update_from_yhat``) beats the
  legacy composed predict+update on the SAME backend.  Measured on the
  reference (jnp) path so the number is a real CPU latency, not an
  interpret-mode artifact; the structural saving (half the Gram work
  per round) is backend-independent.
- ``kernels/serve_bucket/bucket_predict_hits_pallas`` — replaying a
  query-bearing stream through the serving engine with an ENGAGED
  pallas SV substrate routes bucketized predicts through the fused
  ``ops.sv_predict`` kernel, observed via ``ops.LAUNCH_COUNTS``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as core_engine
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.core.rkhs import KernelSpec
from repro.core.substrate import SVSubstrate
from repro.kernels import ops, ref
from repro.serving.engine import serve_stream

from .common import Row, timeit


def _sv_sub(budget: int, d: int, backend: str) -> SVSubstrate:
    return SVSubstrate(
        lcfg=LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.5,
                           lam=0.01, budget=budget, dim=d,
                           kernel=KernelSpec("gaussian", gamma=0.3)),
        backend=backend)


def _fused_round_rows(quick: bool):
    """fused round_stacked vs composed predict+update, reference path."""
    # same shape in quick mode: the claim needs the Gram-dominated
    # regime, where the structural 2-grams -> 1-gram saving shows up
    # above timer noise
    m, budget, d = (8, 1024, 64)
    sub = _sv_sub(budget, d, "reference")
    rng = np.random.default_rng(1)
    state = sub.init(m)
    x = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
    y = jnp.asarray(rng.choice([-1.0, 1.0], size=(m,)), jnp.float32)
    # warm the buffers so both timings see full SV sets
    warm = jax.jit(lambda st, x, y: sub.round_stacked(st, (x, y))[0])
    for t in range(budget // m + 2):
        state = warm(state, x + 0.01 * t, y)

    # composed = the pre-refactor shape of a round: predict and update
    # as SEPARATE jitted dispatches (XLA cannot share the Gram across
    # them, and each pays its own dispatch).  Fused = one
    # round_stacked call where update consumes predict's value.
    predict_j = jax.jit(lambda st, x: sub.predict(sub.models_of(st), x))
    update_j = jax.jit(lambda st, x, y: sub.update(st, (x, y)))

    def composed(st, x, y):
        return predict_j(st, x), update_j(st, x, y)

    fused = jax.jit(lambda st, x, y: sub.round_stacked(st, (x, y)))
    # min-of-3 means: scheduler spikes on shared CI runners must not
    # flip the gated claim
    us_composed = min(timeit(composed, state, x, y) for _ in range(3))
    us_fused = min(timeit(fused, state, x, y) for _ in range(3))
    faster = bool(us_fused < us_composed)
    return [
        Row("kernels/composed_round_sv", us_composed,
            f"m={m};budget={budget};d={d};grams_per_round=2"),
        Row("kernels/fused_round_sv", us_fused,
            f"grams_per_round=1;speedup={us_composed / us_fused:.2f}x;"
            f"fused_step_faster={faster}"),
    ]


def _serve_bucket_rows(quick: bool):
    """engaged pallas SV serving: the bucket predict is ONE fused
    sv_predict launch, proven by the launch counter."""
    T, m, d = (30, 3, 8) if quick else (60, 3, 8)
    budget = 130                                  # >= _MIN_PALLAS: engaged
    rng = np.random.default_rng(2)
    X = np.asarray(rng.normal(size=(T, m, d)), np.float32)
    Y = np.asarray(rng.choice([-1.0, 1.0], size=(T, m)), np.float32)
    sub = _sv_sub(budget, d, "pallas")
    pcfg = ProtocolConfig(kind="periodic", period=10)
    before = ops.LAUNCH_COUNTS["sv_predict"]
    t0 = time.perf_counter()
    res = serve_stream(sub, pcfg, X, Y, queries_per_round=1.0)
    wall_us = (time.perf_counter() - t0) * 1e6
    hits = ops.LAUNCH_COUNTS["sv_predict"] - before
    # ledger parity with the scan engine is part of the claim: routing
    # predicts through the fused kernel must not perturb the protocol
    ref_res = core_engine.run(sub, pcfg, X, Y)
    ok = bool(hits > 0
              and res.num_syncs == ref_res.num_syncs
              and res.total_bytes == ref_res.total_bytes)
    return [Row("kernels/serve_bucket", wall_us,
                f"budget={budget};queries={res.num_requests};"
                f"sv_predict_launches={hits};"
                f"bucket_predict_hits_pallas={ok}")]


def run(quick: bool = False):
    rng = np.random.default_rng(0)
    M = 256 if quick else 512
    d = 64
    X = jnp.asarray(rng.normal(size=(M, d)), jnp.float32)
    Y = jnp.asarray(rng.normal(size=(M, d)), jnp.float32)
    a = jnp.asarray(rng.normal(size=(M,)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(M,)), jnp.float32)

    rows = []
    g_ref = jax.jit(lambda X, Y: ref.gram_ref(X, Y, gamma=0.5))
    us = timeit(g_ref, X, Y)
    rows.append(Row("kernels/gram_jnp_oracle", us, f"M={M};d={d}"))
    us = timeit(lambda: ops.gram(X, Y, gamma=0.5, force_pallas=True))
    rows.append(Row("kernels/gram_pallas_interpret", us,
                    "validated=allclose;mode=interpret(CPU)"))

    q_ref = jax.jit(lambda X, Y, a, b: ref.quadform_ref(X, Y, a, b, gamma=0.5))
    us = timeit(q_ref, X, Y, a, b)
    hbm_naive = M * M * 4
    hbm_fused = 2 * M * d * 4
    rows.append(Row("kernels/quadform_jnp_oracle", us,
                    f"hbm_gram_bytes={hbm_naive}"))
    us = timeit(lambda: ops.quadform(X, Y, a, b, gamma=0.5,
                                    force_pallas=True))
    rows.append(Row("kernels/quadform_pallas_interpret", us,
                    f"hbm_stream_bytes={hbm_fused};"
                    f"traffic_saving={hbm_naive / hbm_fused:.0f}x"))

    W = jnp.asarray(rng.normal(size=(M, d)), jnp.float32)
    bias = jnp.asarray(rng.uniform(size=(M,)) * 6.28, jnp.float32)
    r_ref = jax.jit(lambda X: ref.rff_ref(X, W, bias))
    us = timeit(r_ref, X)
    rows.append(Row("kernels/rff_jnp_oracle", us, f"D={M}"))
    us = timeit(lambda: ops.rff_features(X, W, bias, force_pallas=True))
    rows.append(Row("kernels/rff_pallas_interpret", us,
                    "fused=proj+bias+cos"))

    # fused sv_predict: one launch covers a (B, N, d) stacked predict
    B, N = (4, 192) if quick else (8, 384)
    Xs = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)
    SVs = jnp.asarray(rng.normal(size=(B, N, d)), jnp.float32)
    As = jnp.asarray(rng.normal(size=(B, N)), jnp.float32)
    sv_ref = jax.jit(lambda X, S, A: ref.sv_predict_ref(X, S, A, gamma=0.5))
    us = timeit(sv_ref, Xs, SVs, As)
    rows.append(Row("kernels/sv_predict_jnp_oracle", us, f"B={B};N={N}"))
    us = timeit(lambda: ops.sv_predict(Xs, SVs, As, gamma=0.5,
                                       force_pallas=True))
    rows.append(Row("kernels/sv_predict_pallas_interpret", us,
                    "fused=gram+mask+reduce;row_bits=batch_invariant"))

    # fused primal step: featurize + predict + loss/grad + update in one
    D = 128 if quick else 256
    Xp = jnp.asarray(rng.normal(size=(M, d)), jnp.float32)
    Yp = jnp.asarray(rng.choice([-1.0, 1.0], size=(M,)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(M, D)), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(M,)), jnp.float32)
    Wp = jnp.asarray(rng.normal(size=(D, d)), jnp.float32)
    bp = jnp.asarray(rng.uniform(size=(D,)) * 6.28, jnp.float32)
    scale = float(np.sqrt(2.0 / D))
    p_ref = jax.jit(lambda *t: ref.primal_step_ref(
        *t, W=Wp, bias=bp, scale=scale, loss="hinge", eta=0.5, lam=0.01))
    us = timeit(p_ref, Xp, Yp, w, bb)
    rows.append(Row("kernels/rff_step_jnp_oracle", us, f"B={M};D={D}"))
    us = timeit(lambda: ops.fused_primal_step(
        Xp, Yp, w, bb, W=Wp, bias=bp, scale=scale, loss="hinge",
        eta=0.5, lam=0.01, force_pallas=True))
    rows.append(Row("kernels/rff_step_pallas_interpret", us,
                    "fused=featurize+dot+lossgrad+update"))

    rows.extend(_fused_round_rows(quick))
    rows.extend(_serve_bucket_rows(quick))
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run())
