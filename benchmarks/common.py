"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, List


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def timeit(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters * 1e6


def print_rows(rows: List[Row]) -> None:
    print("name,us_per_call,derived")
    for r in rows:
        print(r.csv())
