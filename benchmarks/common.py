"""Shared helpers for the benchmark harness (DESIGN.md Sec. 11).

Two jobs:

* ``timeit`` — wall-clock timing that is honest under JAX's async
  dispatch: the result of every call is ``jax.block_until_ready``-ed
  inside BOTH the warmup and the timed loop, so a benchmark measures
  the computation, not the enqueue.  Benchmarks pass plain callables;
  no caller-side blocking needed.

* ``BenchReport`` — the machine-readable form of a suite's rows.  The
  human-facing CSV on stdout stays, but ``run.py --json-dir`` also
  serializes one ``BENCH_<suite>.json`` per suite: an environment /
  device fingerprint, the raw rows, and the suite's *claims* — every
  ``key=True|False`` pair found in a row's ``derived`` string, keyed
  ``<row_name>/<key>``.  ``tools/bench_compare.py`` diffs two report
  directories against per-metric thresholds so CI can gate on
  performance and claim regressions.
"""
from __future__ import annotations

import dataclasses
import json
import os
import platform
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List

import jax

SCHEMA_VERSION = 1

# required keys (and value types) of a serialized report / row — kept
# as data so validate_report needs no third-party schema library
_REPORT_FIELDS = {
    "schema_version": int,
    "suite": str,
    "wall_seconds": (int, float),
    "env": dict,
    "rows": list,
    "claims": dict,
}
_ROW_FIELDS = {
    "name": str,
    "us_per_call": (int, float),
    "derived": str,
}
_ENV_FIELDS = ("python", "jax", "backend", "device_kind", "device_count",
               "platform")


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"

    def derived_fields(self) -> Dict[str, str]:
        """The ``k=v`` pairs of ``derived`` (``;``-separated)."""
        out: Dict[str, str] = {}
        for part in self.derived.split(";"):
            if "=" in part:
                k, _, v = part.partition("=")
                out[k.strip()] = v.strip()
        return out

    def claims(self) -> Dict[str, bool]:
        """Boolean-valued derived fields — the row's gated claims."""
        return {k: v == "True" for k, v in self.derived_fields().items()
                if v in ("True", "False")}


def timeit(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Mean microseconds per call, blocking on each call's result.

    Blocking inside the timed loop (not just at the end) is what makes
    the number a latency rather than a dispatch rate; blocking in
    warmup keeps compilation out of the timed region.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def env_fingerprint() -> Dict[str, Any]:
    """Where these numbers came from — attached to every report."""
    devices = jax.devices()
    return {
        "python": platform.python_version(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": devices[0].device_kind if devices else "none",
        "device_count": jax.device_count(),
        "platform": platform.platform(),
    }


@dataclass
class BenchReport:
    """One suite's run, ready for serialization and later comparison."""

    suite: str
    rows: List[Row]
    wall_seconds: float = 0.0
    env: Dict[str, Any] = field(default_factory=env_fingerprint)
    schema_version: int = SCHEMA_VERSION

    def claims(self) -> Dict[str, bool]:
        out: Dict[str, bool] = {}
        for r in self.rows:
            for k, v in r.claims().items():
                out[f"{r.name}/{k}"] = v
        return out

    def to_dict(self) -> Dict[str, Any]:
        return {
            "schema_version": self.schema_version,
            "suite": self.suite,
            "wall_seconds": self.wall_seconds,
            "env": self.env,
            "rows": [dataclasses.asdict(r) for r in self.rows],
            "claims": self.claims(),
        }

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True, indent=1)

    def save(self, out_dir: str) -> str:
        """Write ``BENCH_<suite>.json`` under out_dir; returns the path."""
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f"BENCH_{self.suite}.json")
        with open(path, "w") as fh:
            fh.write(self.to_json() + "\n")
        return path


def validate_report(doc: Any) -> List[str]:
    """Schema problems of a deserialized report; empty means valid."""
    problems: List[str] = []
    if not isinstance(doc, dict):
        return [f"report is {type(doc).__name__}, expected object"]
    for key, typ in _REPORT_FIELDS.items():
        if key not in doc:
            problems.append(f"missing key {key!r}")
        elif not isinstance(doc[key], typ) or isinstance(doc[key], bool):
            problems.append(f"{key!r} has type {type(doc[key]).__name__}")
    if problems:
        return problems
    if doc["schema_version"] != SCHEMA_VERSION:
        problems.append(f"schema_version {doc['schema_version']} != "
                        f"{SCHEMA_VERSION}")
    for key in _ENV_FIELDS:
        if key not in doc["env"]:
            problems.append(f"env missing {key!r}")
    for i, row in enumerate(doc["rows"]):
        if not isinstance(row, dict):
            problems.append(f"rows[{i}] is not an object")
            continue
        for key, typ in _ROW_FIELDS.items():
            if key not in row:
                problems.append(f"rows[{i}] missing {key!r}")
            elif not isinstance(row[key], typ) or isinstance(row[key], bool):
                problems.append(f"rows[{i}].{key} has type "
                                f"{type(row[key]).__name__}")
    for k, v in doc["claims"].items():
        if not isinstance(v, bool):
            problems.append(f"claim {k!r} is not a bool")
    return problems


def load_report(path: str) -> Dict[str, Any]:
    """Load and validate one BENCH_*.json; raises ValueError if invalid."""
    with open(path) as fh:
        doc = json.load(fh)
    problems = validate_report(doc)
    if problems:
        raise ValueError(f"{path}: " + "; ".join(problems))
    return doc


def print_rows(rows: List[Row]) -> None:
    print("name,us_per_call,derived")
    for r in rows:
        print(r.csv())
