"""Beyond-paper (Sec. 4 open problem): adaptive divergence threshold.

The paper notes that choosing Delta 'is in practice a neither intuitive
nor trivial task' and calls for an adaptive threshold that lets the
user select the trade-off directly.  Our controller steers the sync
RATE to a target via multiplicative feedback on a Delta multiplier.

This benchmark runs linear learners on a drifting stream (so loss, and
hence drift, never vanishes): fixed thresholds give wildly different
sync rates depending on Delta; the adaptive schedule hits the requested
rate from any starting Delta.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import protocol
from repro.core.protocol import ProtocolConfig
from repro.data import drifting_stream

from .common import Row

T, M, D = 600, 4, 8


def _run(pcfg, X, Y):
    def local_update(model, ex):
        x, y = ex
        pred = model["w"] @ x
        ell = jnp.maximum(0.0, 1.0 - y * pred)
        g = jnp.where(ell > 0, -y, 0.0)
        return {"w": model["w"] - 0.2 * g * x}, ell

    step = jax.jit(protocol.make_protocol_step(pcfg, local_update))
    st = {"w": jnp.zeros((M, D))}
    state = protocol.init_state({"w": jnp.zeros((D,))}, M)
    total = 0.0
    Tn = X.shape[0]
    syncs_half = 0
    for t in range(Tn):
        st, state, loss = step(st, state, (jnp.asarray(X[t]), jnp.asarray(Y[t])))
        total += float(loss)
        if t == Tn // 2:
            syncs_half = int(state.syncs)
    # steady-state sync rate: second half only (controller burn-in)
    rate2 = (int(state.syncs) - syncs_half) / (Tn - Tn // 2)
    return total, int(state.syncs), int(state.bytes_sent), rate2


def run(quick: bool = False):
    t = 200 if quick else T
    X, Y = drifting_stream(t, M, d=D, seed=0, drift_every=t // 4)
    rows = []
    for name, pcfg in [
        ("fixed_delta_1e-3", ProtocolConfig(kind="dynamic", delta=1e-3)),
        ("fixed_delta_1e1", ProtocolConfig(kind="dynamic", delta=1e1)),
        ("adaptive_rate10%_from_1e-3",
         ProtocolConfig(kind="dynamic", delta=1e-3, delta_schedule="adaptive",
                        target_sync_rate=0.10, adapt_up=2.0)),
        ("adaptive_rate10%_from_1e1",
         ProtocolConfig(kind="dynamic", delta=1e1, delta_schedule="adaptive",
                        target_sync_rate=0.10, adapt_up=2.0)),
        ("sqrt_schedule", ProtocolConfig(kind="dynamic", delta=5.0,
                                         delta_schedule="sqrt")),
    ]:
        t0 = time.perf_counter()
        loss, syncs, bts, rate2 = _run(pcfg, X, Y)
        wall = (time.perf_counter() - t0) * 1e6 / t
        rows.append(Row(f"adaptive/{name}", wall,
                        f"loss={loss:.1f};syncs={syncs};rate={rate2:.3f};"
                        f"bytes={int(bts)}"))
    a, b = rows[2], rows[3]
    ra = float(a.derived.split("rate=")[1].split(";")[0])
    rb = float(b.derived.split("rate=")[1].split(";")[0])
    rows.append(Row("adaptive/claims", 0.0,
                    f"rate_converges_regardless_of_delta0={abs(ra-rb) < 0.08};"
                    f"both_near_target={abs(ra-0.1) < 0.08 and abs(rb-0.1) < 0.08}"))
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run())
