"""Roofline summary rows from the dry-run artifacts (§Roofline).

Requires experiments/dryrun/*.json (produced by repro.launch.dryrun).
Degrades gracefully to a notice row when the dry-run has not been run
in this checkout.
"""
from __future__ import annotations

import os

from .common import Row


def run(quick: bool = False):
    try:
        from repro.launch.roofline import analyze_record, load_records
    except Exception as e:                      # pragma: no cover
        return [Row("roofline/unavailable", 0.0, repr(e))]
    recs = load_records("experiments/dryrun")
    if not recs:
        return [Row("roofline/no_dryrun_artifacts", 0.0,
                    "run: python -m repro.launch.dryrun --all")]
    rows = []
    for r in recs:
        a = analyze_record(r)
        rows.append(Row(
            f"roofline/{a['arch']}/{a['shape']}/{a['mesh']}",
            (a["compile_s"] or 0) * 1e6,
            f"compute_s={a['compute_s']:.3e};memory_s={a['memory_s']:.3e};"
            f"collective_s={a['collective_s']:.3e};dominant={a['dominant']};"
            f"useful={a['useful_ratio']:.2f}"))
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run())
