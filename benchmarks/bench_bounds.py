"""Theorem-slack benchmark: Thm. 4 / Prop. 5 / Prop. 6 / Thm. 7.

For each bound we report measured / bound (<= 1 required) so the table
doubles as a tightness study.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import criterion, simulation
from repro.core.accounting import ByteModel
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.core.rkhs import KernelSpec
from repro.data import susy_stream

from .common import Row

T, M, D = 600, 4, 8


def run(quick: bool = False):
    t = 150 if quick else T
    X, Y = susy_stream(T=t, m=M, d=D, seed=0)
    delta = 2.0
    lcfg = LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.5, lam=0.01,
                         budget=64, kernel=KernelSpec("gaussian", gamma=0.3),
                         dim=D)

    t0 = time.perf_counter()
    res_d = simulation.run_kernel_simulation(
        lcfg, ProtocolConfig(kind="dynamic", delta=delta), X, Y)
    res_c = simulation.run_kernel_simulation(
        lcfg, ProtocolConfig(kind="continuous"), X, Y)
    wall = (time.perf_counter() - t0) * 1e6 / (2 * t)

    gamma = lcfg.eta
    eps = float(res_d.eps_history.max()) if len(res_d.eps_history) else 0.0
    bm = ByteModel(dim=D)
    union = t * M

    thm4_bound = res_c.total_loss + t * (delta + 2 * eps ** 2) / gamma ** 2
    prop6_bound = (lcfg.eta / np.sqrt(delta)) * res_d.total_loss
    prop5_bound = 2 * t * M * union * bm.B_alpha + M * union * bm.B_x
    thm7_bound = (prop6_bound * 2 * M * union * bm.B_alpha
                  + M * union * bm.B_x)

    rows = [
        Row("bounds/thm4_loss", wall,
            f"measured={res_d.total_loss:.1f};bound={thm4_bound:.1f};"
            f"ratio={res_d.total_loss / thm4_bound:.3f};ok={res_d.total_loss <= thm4_bound}"),
        Row("bounds/prop6_syncs", 0.0,
            f"measured={res_d.num_syncs};bound={prop6_bound:.1f};"
            f"ratio={res_d.num_syncs / prop6_bound:.3f};ok={res_d.num_syncs <= prop6_bound}"),
        Row("bounds/prop5_comm_continuous", 0.0,
            f"measured={res_c.total_bytes};bound={int(prop5_bound)};"
            f"ratio={res_c.total_bytes / prop5_bound:.4f};ok={res_c.total_bytes <= prop5_bound}"),
        Row("bounds/thm7_comm_dynamic", 0.0,
            f"measured={res_d.total_bytes};bound={int(thm7_bound)};"
            f"ratio={res_d.total_bytes / thm7_bound:.5f};ok={res_d.total_bytes <= thm7_bound}"),
    ]
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run())
