"""The paper's technique at LM scale, MEASURED (not just compiled).

Four learners train the same (smoke-scale) transformer on a learnable
synthetic copy-structure token stream under each protocol.  Claims:

  (1) isolated learners (none) end with the worst loss;
  (2) the dynamic protocol tracks the continuous protocol's loss;
  (3) while synchronizing in far fewer rounds (=> proportionally fewer
      parameter all-reduces at production scale).

This is the framework-scale counterpart of Fig. 1: the hypothesis class
changed from RKHS expansions to a transformer, the coordinator to an
all-reduce — the protocol and its trade-off are unchanged.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.core.protocol import ProtocolConfig
from repro.launch.train import init_train_state, make_train_step
from repro.optim import OptimizerConfig

from .common import Row

STEPS, M, B, S = 150, 4, 4, 32


def _stream(cfg, steps, seed=0):
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        toks = rng.integers(0, cfg.vocab, (M, B, S + 1))
        half = S // 2
        toks[..., half + 1: 2 * half + 1] = toks[..., 1: half + 1]
        yield {"tokens": jnp.asarray(toks[..., :-1], jnp.int32),
               "labels": jnp.asarray(toks[..., 1:], jnp.int32)}


def run(quick: bool = False):
    steps = 40 if quick else STEPS
    cfg = get("qwen2_5_3b").smoke()
    opt_cfg = OptimizerConfig(kind="adamw", lr=3e-3)

    rows, results = [], {}
    for name, pcfg in [
        ("none", ProtocolConfig(kind="none")),
        ("continuous", ProtocolConfig(kind="continuous")),
        ("periodic_b10", ProtocolConfig(kind="periodic", period=10)),
        ("dynamic", ProtocolConfig(kind="dynamic", delta=4.0)),
        ("dynamic_adaptive", ProtocolConfig(
            kind="dynamic", delta=1.0, delta_schedule="adaptive",
            target_sync_rate=0.15, adapt_up=2.0)),
    ]:
        state = init_train_state(jax.random.PRNGKey(0), cfg, M, opt_cfg)
        step_fn = jax.jit(make_train_step(cfg, pcfg, opt_cfg))
        t0 = time.perf_counter()
        last = []
        for batch in _stream(cfg, steps):
            state, loss = step_fn(state, batch)
            last.append(float(loss))
        wall = (time.perf_counter() - t0) * 1e6 / steps
        final = float(np.mean(last[-10:]))
        results[name] = (final, int(state.pstate.syncs))
        rows.append(Row(
            f"lm_protocol/{name}", wall,
            f"final_loss={final:.4f};syncs={int(state.pstate.syncs)};"
            f"sync_rate={int(state.pstate.syncs)/steps:.2f}"))

    none_l = results["none"][0]
    cont_l = results["continuous"][0]
    dyn_l, dyn_s = results["dynamic"]
    claims = {
        "isolated_worst": none_l >= max(cont_l, dyn_l) - 1e-3,
        "dynamic_tracks_continuous": dyn_l <= cont_l * 1.10 + 0.05,
        "dynamic_fewer_syncs": dyn_s < steps,
    }
    rows.append(Row("lm_protocol/claims", 0.0,
                    ";".join(f"{k}={v}" for k, v in claims.items())))
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run())
