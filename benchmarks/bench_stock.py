"""Fig. 2: stock-price prediction with 32 learners.

The paper reports (Sec. 4): kernel models reduce error vs linear by
~an order of magnitude; the dynamic protocol reduces communication vs
the periodic (static) kernel protocol by orders of magnitude, ending
below even the linear-model communication; quiescence within ~2000
rounds.  We reproduce the qualitative ordering on a synthetic stock
stream (the original dataset is not redistributable).
"""
from __future__ import annotations

import time

from repro.core import simulation
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.core.rkhs import KernelSpec
from repro.data import stock_stream

from .common import Row

T, M, D = 1200, 32, 10


def run(quick: bool = False):
    t = 150 if quick else T
    m = 8 if quick else M
    X, Y = stock_stream(T=t, m=m, d=D, seed=0)

    lin = LearnerConfig(algo="linear_sgd", loss="squared", eta=0.05,
                        lam=1e-4, dim=D)
    ker = LearnerConfig(algo="kernel_sgd", loss="squared", eta=0.5, lam=1e-3,
                        budget=100, kernel=KernelSpec("gaussian", gamma=0.2),
                        dim=D)

    systems = {
        "linear_periodic_b10": (lin, ProtocolConfig(kind="periodic", period=10), "linear"),
        "kernel_periodic_b10": (ker, ProtocolConfig(kind="periodic", period=10), "kernel"),
        "kernel_dynamic": (ker, ProtocolConfig(kind="dynamic", delta=2.0), "kernel"),
    }
    rows, res = [], {}
    for name, (lcfg, pcfg, fam) in systems.items():
        t0 = time.perf_counter()
        if fam == "linear":
            r = simulation.run_linear_simulation(lcfg, pcfg, X, Y)
        else:
            r = simulation.run_kernel_simulation(lcfg, pcfg, X, Y)
        wall = (time.perf_counter() - t0) * 1e6 / t
        res[name] = r
        rows.append(Row(
            f"stock/{name}", wall,
            f"sq_err={r.cumulative_errors[-1]:.1f};bytes={r.total_bytes};"
            f"syncs={r.num_syncs}"))

    err_reduction = (res["linear_periodic_b10"].cumulative_errors[-1]
                     / max(res["kernel_dynamic"].cumulative_errors[-1], 1e-9))
    comm_reduction = (res["kernel_periodic_b10"].total_bytes
                      / max(res["kernel_dynamic"].total_bytes, 1))
    claims = {
        "kernel_cuts_error_vs_linear": f"{err_reduction:.1f}x",
        "dynamic_cuts_comm_vs_periodic_kernel": f"{comm_reduction:.1f}x",
        "kernel_dyn_less_comm_than_periodic":
            res["kernel_dynamic"].total_bytes
            < res["kernel_periodic_b10"].total_bytes,
    }
    rows.append(Row("stock/claims", 0.0,
                    ";".join(f"{k}={v}" for k, v in claims.items())))
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run())
