"""Scan-engine throughput: loop driver vs scan vs vmapped sweep.

Three drivers produce the same SimResult (EXPERIMENTS.md §Engine):

  loop   — core/simulation.py: Python loop, one host round-trip per
           round, numpy set-algebra per sync (the oracle).
  scan   — core/engine.py: the whole T-round experiment as one
           compiled lax.scan (DESIGN.md Sec. 7).
  sweep  — engine.sweep: the scan vmapped across a protocol grid, one
           compilation for the entire grid.

Measured on the Fig. 1(a) tradeoff systems (same learner/protocol
configs as bench_tradeoff): per-system rounds/sec for loop and scan
(scan timed warm; first-call compile reported separately), then a
>=8-config dynamic-protocol grid run once per-config through the scan
and once through one vmapped sweep.

Distributed mode (runs when >=2 devices are visible, e.g. under
XLA_FLAGS=--xla_force_host_platform_device_count=8 as CI does): the
same systems through ``engine.run(..., mesh=...)`` with the learner
axis sharded (DESIGN.md Sec. 9), checking the parity contract — losses
bit-identical, ledger integer-exact — plus the
``topology="allreduce"`` pricing, and a learner-weak-scaling row
(4x the learners on the same mesh).

Claims (recorded in the claims rows):
  (1) the scan engine beats the loop driver by >=10x rounds/sec in
      geometric mean over the tradeoff systems, with byte-identical
      ledgers;
  (2) the vmapped sweep amortizes further: sweeping the grid in one
      compile is faster than running the same configs through the
      scan engine one at a time;
  (3) distributed (gated in CI's mesh step): mesh_losses_identical,
      mesh_bytes_identical, mesh_allreduce_consistent — the sharded
      engine is indistinguishable from the single-device engine
      except for where the learners live and what a sync is priced at.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import engine, simulation
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.core.rkhs import KernelSpec
from repro.data import susy_stream

from .common import Row

T, M, D = 1000, 4, 8


def _kernel_cfg(budget):
    return LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.5, lam=0.01,
                         budget=budget,
                         kernel=KernelSpec("gaussian", gamma=0.3), dim=D)


def run(quick: bool = False):
    t = 200 if quick else T
    X, Y = susy_stream(T=t, m=M, d=D, seed=0)
    lin = LearnerConfig(algo="linear_sgd", loss="hinge", eta=0.1, lam=0.001,
                        dim=D)

    systems = {
        "linear_continuous": (lin, ProtocolConfig(kind="continuous")),
        "linear_dynamic": (lin, ProtocolConfig(kind="dynamic", delta=0.1)),
        "kernel_continuous": (_kernel_cfg(256), ProtocolConfig(kind="continuous")),
        "kernel_dynamic": (_kernel_cfg(256), ProtocolConfig(kind="dynamic", delta=2.0)),
        "kernel_dyn_compress": (_kernel_cfg(48), ProtocolConfig(kind="dynamic", delta=2.0)),
    }

    rows, speedups = [], {}
    for name, (lcfg, pcfg) in systems.items():
        run_loop = (simulation.run_kernel_simulation if lcfg.is_kernel
                    else simulation.run_linear_simulation)
        t0 = time.perf_counter()
        res_loop = run_loop(lcfg, pcfg, X, Y)
        wall_loop = time.perf_counter() - t0

        t0 = time.perf_counter()
        res_scan = engine.run(lcfg, pcfg, X, Y)    # first call compiles
        wall_compile = time.perf_counter() - t0
        t0 = time.perf_counter()
        res_scan = engine.run(lcfg, pcfg, X, Y)
        wall_scan = time.perf_counter() - t0

        bytes_eq = bool(np.array_equal(res_loop.cumulative_bytes,
                                       res_scan.cumulative_bytes))
        speedups[name] = wall_loop / wall_scan
        rows.append(Row(
            f"engine/loop/{name}", wall_loop * 1e6 / t,
            f"rounds_per_sec={t / wall_loop:.1f}"))
        rows.append(Row(
            f"engine/scan/{name}", wall_scan * 1e6 / t,
            f"rounds_per_sec={t / wall_scan:.1f};"
            f"speedup={speedups[name]:.1f}x;bytes_identical={bytes_eq};"
            f"compile_s={wall_compile - wall_scan:.2f}"))

    # --- vmapped sweep over >=8-config dynamic-protocol grids -------------
    # Two regimes (DESIGN.md Sec. 7): under vmap, lax.cond lowers to
    # select, so every lane pays the sync branch every round.  Where the
    # per-round math is small (linear models) the per-iteration scan
    # overhead dominates and the sweep amortizes it across the grid;
    # where a sync is expensive (kernel compression) the sweep's win is
    # against the loop driver, not against back-to-back warm scans.
    grid = [ProtocolConfig(kind="dynamic", delta=d, mini_batch=mb)
            for d in (0.05, 0.1, 0.2, 0.4) for mb in (1, 5)]

    def time_grid(lcfg, grid):
        for p in grid:                              # warm scan + sweep caches
            engine.run(lcfg, p, X, Y)
        engine.sweep(lcfg, grid, X, Y)
        t0 = time.perf_counter()
        solo = [engine.run(lcfg, p, X, Y) for p in grid]
        wall_solo = time.perf_counter() - t0
        t0 = time.perf_counter()
        sw = engine.sweep(lcfg, grid, X, Y)
        wall_sweep = time.perf_counter() - t0
        matches = all(
            np.array_equal(solo[i].cumulative_bytes, sw[i].cumulative_bytes)
            for i in range(len(grid)))
        return solo, wall_solo, wall_sweep, matches

    _, lin_solo_s, lin_sweep_s, lin_eq = time_grid(lin, grid)
    rows.append(Row(
        "engine/sweep/linear_grid8", lin_sweep_s * 1e6 / (t * len(grid)),
        f"configs={len(grid)};rounds_per_sec_per_config={t * len(grid) / lin_sweep_s:.1f};"
        f"solo_scan_s={lin_solo_s:.2f};sweep_s={lin_sweep_s:.2f};"
        f"bytes_identical={lin_eq}"))

    kc = _kernel_cfg(48)
    kgrid = [ProtocolConfig(kind="dynamic", delta=d, mini_batch=mb)
             for d in (0.5, 1.0, 2.0, 4.0) for mb in (1, 5)]
    _, k_solo_s, k_sweep_s, k_eq = time_grid(kc, kgrid)
    t0 = time.perf_counter()
    for p in kgrid:
        simulation.run_kernel_simulation(kc, p, X, Y)
    k_loop_s = time.perf_counter() - t0
    rows.append(Row(
        "engine/sweep/kernel_grid8", k_sweep_s * 1e6 / (t * len(kgrid)),
        f"configs={len(kgrid)};rounds_per_sec_per_config={t * len(kgrid) / k_sweep_s:.1f};"
        f"loop_s={k_loop_s:.2f};solo_scan_s={k_solo_s:.2f};sweep_s={k_sweep_s:.2f};"
        f"bytes_identical={k_eq}"))

    geomean = float(np.exp(np.mean(np.log(list(speedups.values())))))
    claims = {
        "scan_geomean_speedup_10x": geomean >= 10.0,
        "sweep_amortizes_vs_scan": lin_sweep_s < lin_solo_s,
        "sweep_beats_loop_10x": k_sweep_s * 10.0 < k_loop_s,
    }
    rows.append(Row(
        "engine/claims", 0.0,
        f"geomean_speedup={geomean:.1f}x;"
        + ";".join(f"{k}={v}" for k, v in claims.items())))
    rows.extend(_distributed_rows(t))
    return rows


def _distributed_rows(t: int):
    """Mesh-sharded engine parity + scaling rows (DESIGN.md Sec. 9).

    Correctness claims only — wall-clock on forced host devices shares
    one CPU, so timings are reported, never gated (the CI philosophy
    of the engine suite).
    """
    import jax
    from jax.sharding import NamedSharding

    from repro.core.substrate import substrate_of
    from repro.launch import sharding as shd
    from repro.launch.mesh import make_learner_mesh

    n_dev = len(jax.devices())
    if n_dev < 2:
        return [Row("engine/mesh/skipped", 0.0,
                    f"devices={n_dev};need>=2 (set XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8)")]

    mesh = make_learner_mesh()
    rows = []
    ok_loss = ok_bytes = ok_ring = True
    stream_sh = NamedSharding(mesh, shd.stream_pspec(("learners",)))

    systems = {
        "kernel_dynamic": (_kernel_cfg(48),
                           ProtocolConfig(kind="dynamic", delta=2.0)),
        "linear_dynamic": (LearnerConfig(algo="linear_sgd", loss="hinge",
                                         eta=0.1, lam=0.001, dim=D),
                           ProtocolConfig(kind="dynamic", delta=0.1)),
    }
    for name, (lcfg, pcfg) in systems.items():
        for m_mult, tag in ((1, name), (4, f"{name}_4x_learners")):
            m = n_dev * m_mult
            X, Y = susy_stream(T=t, m=m, d=D, seed=0)
            Xd = jax.device_put(np.asarray(X), stream_sh)
            Yd = jax.device_put(np.asarray(Y), stream_sh)

            res_1 = engine.run(lcfg, pcfg, X, Y)
            engine.run(lcfg, pcfg, Xd, Yd, mesh=mesh)    # compile
            t0 = time.perf_counter()
            res_m = engine.run(lcfg, pcfg, Xd, Yd, mesh=mesh)
            wall = time.perf_counter() - t0

            ok_loss &= bool(np.array_equal(res_1.cumulative_loss,
                                           res_m.cumulative_loss))
            ok_bytes &= bool(np.array_equal(res_1.cumulative_bytes,
                                            res_m.cumulative_bytes))

            res_ring = engine.run(lcfg, pcfg, Xd, Yd, mesh=mesh,
                                  topology="allreduce")
            per_sync = substrate_of(lcfg).allreduce_sync_bytes(m)
            ok_ring &= (res_ring.num_syncs == res_m.num_syncs
                        and res_ring.total_bytes
                        == res_ring.num_syncs * per_sync)
            rows.append(Row(
                f"engine/mesh/{tag}", wall * 1e6 / t,
                f"devices={n_dev};learners={m};"
                f"learners_per_device={m_mult};"
                f"rounds_per_sec={t / wall:.1f};syncs={res_m.num_syncs};"
                f"coordinator_bytes={res_m.total_bytes};"
                f"allreduce_bytes={res_ring.total_bytes}"))

    rows.append(Row(
        "engine/mesh/claims", 0.0,
        f"mesh_losses_identical={ok_loss};"
        f"mesh_bytes_identical={ok_bytes};"
        f"mesh_allreduce_consistent={ok_ring}"))
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run(quick=True))
