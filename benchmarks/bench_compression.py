"""Compression ablation (Sec. 3/4): budget tau sweep, truncation vs
projection — loss/communication/epsilon trade-off."""
from __future__ import annotations

import time

import numpy as np

from repro.core import engine
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.core.rkhs import KernelSpec
from repro.data import susy_stream

from .common import Row

T, M, D = 500, 4, 8


def run(quick: bool = False):
    t = 120 if quick else T
    X, Y = susy_stream(T=t, m=M, d=D, seed=0)
    rows = []
    for method in ("truncate", "project"):
        for tau in (16, 48, 128):
            lcfg = LearnerConfig(
                algo="kernel_sgd", loss="hinge", eta=0.5, lam=0.01,
                budget=tau, kernel=KernelSpec("gaussian", gamma=0.3), dim=D)
            pcfg = ProtocolConfig(kind="dynamic", delta=2.0)
            engine.run(lcfg, pcfg, X, Y, compress_method=method)   # warm
            t0 = time.perf_counter()
            res = engine.run(lcfg, pcfg, X, Y, compress_method=method)
            wall = (time.perf_counter() - t0) * 1e6 / t
            eps = float(res.eps_history.mean()) if len(res.eps_history) else 0.0
            rows.append(Row(
                f"compression/{method}/tau{tau}", wall,
                f"errors={int(res.cumulative_errors[-1])};"
                f"bytes={res.total_bytes};mean_eps={eps:.4f};"
                f"syncs={res.num_syncs}"))
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run())
