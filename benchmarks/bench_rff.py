"""Beyond-paper (Sec. 4 'future work'): Random Fourier Features make the
kernel learner's model fixed-size, so the dynamic protocol communicates
like the *linear* case while keeping near-kernel accuracy.

Since the substrate layer (DESIGN.md Sec. 8) this suite runs entirely
through the unified scan engine: the SV baseline and every RFF
configuration share ONE generic ``engine.run`` / ``engine.sweep`` code
path (no private Python driver loop), and the asynchronous harness row
shows the identical substrate running event-driven.

Registered claims (asserted here, grepped by CI):

- ``bytes_per_sync_const`` — every RFF synchronization costs exactly
  2 m (D+1) B bytes, independent of the rounds seen (Cor. 8 strict
  adaptivity; the SV ledger has no such guarantee).
- ``rff_cheaper_than_sv`` — at D=128 the RFF dynamic run moves fewer
  total bytes than the budget-128 SV dynamic run on the same stream.

The us_per_call column is per-round wall time of the warmed engine
(rounds/sec); engine-vs-legacy-loop timing methodology lives in
benchmarks/bench_engine.py (EXPERIMENTS.md §Engine).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import engine
from repro.core.accounting import sync_bytes_linear
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.core.rff import RFFSpec
from repro.core.rkhs import KernelSpec
from repro.core.substrate import RFFSubstrate
from repro.data import susy_stream
from repro.runtime import AsyncProtocolConfig, SystemConfig, run_async_simulation

from .common import Row

T, M, D_IN = 600, 4, 8


def _time_run(sub_or_cfg, pcfg, X, Y, reps=3):
    engine.run(sub_or_cfg, pcfg, X, Y)           # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        res = engine.run(sub_or_cfg, pcfg, X, Y)
    wall = (time.perf_counter() - t0) / reps
    return res, wall * 1e6 / X.shape[0]          # us per round


def run(quick: bool = False):
    t = 150 if quick else T
    X, Y = susy_stream(T=t, m=M, d=D_IN, seed=0)
    pcfg = ProtocolConfig(kind="dynamic", delta=2.0)
    rows = []

    # SV-expansion kernel learner (dynamic) through the same engine
    lcfg = LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.5, lam=0.01,
                         budget=128, kernel=KernelSpec("gaussian", gamma=0.3),
                         dim=D_IN)
    res_sv, us_sv = _time_run(lcfg, pcfg, X, Y)
    rows.append(Row("rff/sv_expansion_dynamic", us_sv,
                    f"errors={int(res_sv.cumulative_errors[-1])};"
                    f"bytes={res_sv.total_bytes}"))

    # RFF learner (dynamic): fixed-size model, same engine code path
    res_by_D = {}
    for D in (128, 512):
        sub = RFFSubstrate(spec=RFFSpec(dim=D_IN, num_features=D, gamma=0.3,
                                        seed=0))
        res, us = _time_run(sub, pcfg, X, Y)
        res_by_D[D] = res
        per_sync = sync_bytes_linear(D + 1, M)
        round_bytes = np.diff(np.concatenate([[0], res.cumulative_bytes]))
        nz = round_bytes[round_bytes > 0]
        bytes_const = bool(len(nz) == 0 or (nz == per_sync).all())
        assert bytes_const, f"RFF per-sync bytes not constant: {set(nz)}"
        assert res.total_bytes == res.num_syncs * per_sync
        rows.append(Row(
            f"rff/rff{D}_dynamic", us,
            f"errors={int(res.cumulative_errors[-1])};"
            f"bytes={res.total_bytes};syncs={res.num_syncs};"
            f"bytes_per_sync_const={bytes_const}"))

    cheaper = bool(res_by_D[128].total_bytes < res_sv.total_bytes)
    assert cheaper, (
        f"RFF-128 moved {res_by_D[128].total_bytes} bytes vs SV "
        f"{res_sv.total_bytes}")
    rows.append(Row("rff/bytes_vs_sv", 0.0,
                    f"rff128_bytes={res_by_D[128].total_bytes};"
                    f"sv_bytes={res_sv.total_bytes};"
                    f"rff_cheaper_than_sv={cheaper}"))

    # delta sweep, one compilation (engine.sweep over the RFF substrate)
    sub = RFFSubstrate(spec=RFFSpec(dim=D_IN, num_features=128, gamma=0.3,
                                    seed=0))
    grid = [ProtocolConfig(kind="dynamic", delta=dl)
            for dl in (0.5, 1.0, 2.0, 4.0)]
    engine.sweep(sub, grid, X, Y)                # compile
    t0 = time.perf_counter()
    sw = engine.sweep(sub, grid, X, Y)
    us_sweep = (time.perf_counter() - t0) * 1e6 / (t * len(grid))
    rows.append(Row("rff/delta_sweep4", us_sweep,
                    "syncs=" + "/".join(str(r.num_syncs)
                                        for r in sw.results)))

    # the identical substrate through the async event-driven harness
    res_a = run_async_simulation(
        sub, AsyncProtocolConfig(kind="dynamic", delta=2.0), X, Y,
        sys_cfg=SystemConfig(), record_divergence=False)
    per_sync = sync_bytes_linear(sub.num_params, M)
    async_const = bool(res_a.total_bytes == res_a.num_syncs * per_sync)
    assert async_const
    rows.append(Row("rff/rff128_async_dynamic", 0.0,
                    f"errors={int(res_a.cumulative_errors[-1])};"
                    f"bytes={res_a.total_bytes};syncs={res_a.num_syncs};"
                    f"bytes_per_sync_const={async_const}"))
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run())
