"""Beyond-paper (Sec. 4 'future work'): Random Fourier Features make the
kernel learner's model fixed-size, so the dynamic protocol communicates
like the *linear* case while keeping near-kernel accuracy."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import protocol, rff, simulation
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.core.rkhs import KernelSpec
from repro.data import susy_stream

from .common import Row

T, M, D_IN = 600, 4, 8


def _run_rff(spec, X, Y, pcfg, eta=0.5, lam=0.01):
    W, b = rff.rff_params(spec)
    update = rff.make_update(spec, W, b, eta=eta, lam=lam, loss="hinge")
    m = X.shape[1]
    states = [rff.init_state(spec) for _ in range(m)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
    step = jax.jit(protocol.make_protocol_step(pcfg, update))
    pstate = protocol.init_state(rff.init_state(spec), m)
    total_err = 0.0
    vpred = jax.jit(jax.vmap(
        lambda s, x: s.w @ rff.featurize(spec, W, b, x[None])[0] + s.b))
    for t in range(X.shape[0]):
        xb, yb = jnp.asarray(X[t]), jnp.asarray(Y[t])
        yhat = vpred(stacked, xb)
        total_err += float(jnp.sum(jnp.sign(yhat) != yb))
        stacked, pstate, _ = step(stacked, pstate, (xb, yb))
    return total_err, float(pstate.bytes_sent), int(pstate.syncs)


def run(quick: bool = False):
    t = 150 if quick else T
    X, Y = susy_stream(T=t, m=M, d=D_IN, seed=0)
    rows = []

    # SV-expansion kernel learner (dynamic)
    lcfg = LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.5, lam=0.01,
                         budget=128, kernel=KernelSpec("gaussian", gamma=0.3),
                         dim=D_IN)
    t0 = time.perf_counter()
    res_sv = simulation.run_kernel_simulation(
        lcfg, ProtocolConfig(kind="dynamic", delta=2.0), X, Y)
    w_sv = (time.perf_counter() - t0) * 1e6 / t
    rows.append(Row("rff/sv_expansion_dynamic", w_sv,
                    f"errors={int(res_sv.cumulative_errors[-1])};"
                    f"bytes={res_sv.total_bytes}"))

    # RFF learner (dynamic): fixed-size model
    for D in (128, 512):
        spec = rff.RFFSpec(dim=D_IN, num_features=D, gamma=0.3, seed=0)
        t0 = time.perf_counter()
        err, bts, syncs = _run_rff(spec, X, Y,
                                   ProtocolConfig(kind="dynamic", delta=2.0))
        wall = (time.perf_counter() - t0) * 1e6 / t
        rows.append(Row(f"rff/rff{D}_dynamic", wall,
                        f"errors={int(err)};bytes={int(bts)};syncs={syncs}"))
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run())
