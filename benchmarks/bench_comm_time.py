"""Fig. 1(b): cumulative communication over time + quiescence.

On a stream the hypothesis class can fit (separable for linear,
RKHS-representable for kernel), the dynamic protocol's cumulative
communication must flatten (quiescence), while periodic/continuous
grow linearly forever.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import engine
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.data import separable_stream

from .common import Row

T, M, D = 1000, 4, 8


def run(quick: bool = False):
    t = 300 if quick else T
    X, Y = separable_stream(T=t, m=M, d=D, seed=0, margin=1.0)
    lin = LearnerConfig(algo="linear_pa", loss="hinge", C=1.0, dim=D)

    rows = []
    curves = {}
    for name, pcfg in [
        ("continuous", ProtocolConfig(kind="continuous")),
        ("periodic_b10", ProtocolConfig(kind="periodic", period=10)),
        ("dynamic", ProtocolConfig(kind="dynamic", delta=1.0)),
    ]:
        engine.run(lin, pcfg, X, Y)         # warm: exclude XLA compile
        t0 = time.perf_counter()
        res = engine.run(lin, pcfg, X, Y)   # scan engine; loop driver is the oracle
        wall = (time.perf_counter() - t0) * 1e6 / t
        curves[name] = res
        # communication in the last quarter of the run
        last_q = res.cumulative_bytes[-1] - res.cumulative_bytes[3 * t // 4]
        rows.append(Row(
            f"comm_time/{name}", wall,
            f"total_bytes={res.total_bytes};last_quarter_bytes={int(last_q)};"
            f"quiescence_round={res.quiescence_round}"))

    dyn = curves["dynamic"]
    per = curves["periodic_b10"]
    claims = {
        "dynamic_quiescent": (dyn.cumulative_bytes[-1]
                              == dyn.cumulative_bytes[3 * t // 4]),
        "periodic_never_stops": (per.cumulative_bytes[-1]
                                 > per.cumulative_bytes[3 * t // 4]),
        "dynamic_least_comm": dyn.total_bytes
            == min(c.total_bytes for c in curves.values()),
    }
    rows.append(Row("comm_time/claims", 0.0,
                    ";".join(f"{k}={v}" for k, v in claims.items())))
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run())
