"""Fig. 1(a): cumulative error vs cumulative communication trade-off.

Reproduces the paper's SUSY experiment layout: 4 learners x 1000
instances each; learning systems compared:
  - linear models, continuous / dynamic sync
  - kernel (SV expansion), continuous / dynamic sync
  - kernel + model compression (truncation to a small budget), dynamic

Claims validated (paper Sec. 1, Fig. 1):
  (1) kernel models reach lower error than linear ones on the
      non-linear task;
  (2) continuous kernel sync has by far the highest communication;
  (3) the dynamic protocol cuts kernel communication without losing
      prediction quality;
  (4) compression cuts communication further, approaching the linear
      budget, at some cost in error.
"""
from __future__ import annotations

import time

from repro.core import engine
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.core.rkhs import KernelSpec
from repro.data import susy_stream

from .common import Row

T, M, D = 1000, 4, 8


def _kernel_cfg(budget):
    return LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.5, lam=0.01,
                         budget=budget,
                         kernel=KernelSpec("gaussian", gamma=0.3), dim=D)


def run(quick: bool = False):
    global T
    t = 200 if quick else T
    X, Y = susy_stream(T=t, m=M, d=D, seed=0)
    lin = LearnerConfig(algo="linear_sgd", loss="hinge", eta=0.1, lam=0.001,
                        dim=D)

    systems = {
        "linear_continuous": (lin, ProtocolConfig(kind="continuous")),
        "linear_dynamic": (lin, ProtocolConfig(kind="dynamic", delta=0.1)),
        "kernel_continuous": (_kernel_cfg(256), ProtocolConfig(kind="continuous")),
        "kernel_dynamic": (_kernel_cfg(256), ProtocolConfig(kind="dynamic", delta=2.0)),
        "kernel_dyn_compress": (_kernel_cfg(48), ProtocolConfig(kind="dynamic", delta=2.0)),
    }

    # scan engine (core/engine.py); the Python-loop driver in
    # core/simulation.py stays the byte-for-byte oracle (tests/test_engine.py)
    # and bench_engine reports the loop-vs-scan rounds/sec comparison.
    rows, results = [], {}
    for name, (lcfg, pcfg) in systems.items():
        engine.run(lcfg, pcfg, X, Y)        # warm: exclude XLA compile
        t0 = time.perf_counter()
        res = engine.run(lcfg, pcfg, X, Y)
        wall = (time.perf_counter() - t0) * 1e6 / t
        results[name] = res
        rows.append(Row(
            f"tradeoff/{name}", wall,
            f"errors={int(res.cumulative_errors[-1])};"
            f"bytes={res.total_bytes};syncs={res.num_syncs}"))

    # paper-claim assertions (soft: recorded in derived column)
    r = results
    claims = {
        "kernel_beats_linear":
            r["kernel_continuous"].cumulative_errors[-1]
            < r["linear_continuous"].cumulative_errors[-1],
        "continuous_kernel_most_comm":
            r["kernel_continuous"].total_bytes
            == max(x.total_bytes for x in r.values()),
        "dynamic_cuts_kernel_comm":
            r["kernel_dynamic"].total_bytes
            < 0.8 * r["kernel_continuous"].total_bytes,
        "dynamic_keeps_quality":
            r["kernel_dynamic"].cumulative_errors[-1]
            < 1.3 * r["kernel_continuous"].cumulative_errors[-1],
        "compression_cuts_comm_further":
            r["kernel_dyn_compress"].total_bytes
            < r["kernel_dynamic"].total_bytes,
    }
    rows.append(Row("tradeoff/claims", 0.0,
                    ";".join(f"{k}={v}" for k, v in claims.items())))
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run())
