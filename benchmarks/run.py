"""Benchmark harness: one module per paper table/figure.

  python -m benchmarks.run [--quick] [--only NAME] [--json-dir DIR]

Output: ``name,us_per_call,derived`` CSV rows (plus a summary).  With
``--json-dir`` each suite additionally writes a machine-readable
``BENCH_<suite>.json`` report (env fingerprint, rows, gated claims —
see ``common.BenchReport``) that ``tools/bench_compare.py`` can diff
against a baseline directory.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from . import (bench_adaptive, bench_async, bench_bounds, bench_comm_time,
               bench_compression, bench_engine, bench_kernels,
               bench_lm_protocol, bench_population, bench_rff,
               bench_roofline, bench_serve, bench_stock, bench_tradeoff)
from .common import BenchReport, print_rows

SUITES = {
    "tradeoff": bench_tradeoff,        # Fig. 1(a)
    "comm_time": bench_comm_time,      # Fig. 1(b)
    "engine": bench_engine,            # loop vs scan vs sweep (DESIGN.md 7)
    "async": bench_async,              # sync-vs-async runtime (DESIGN.md 6)
    "stock": bench_stock,              # Fig. 2
    "bounds": bench_bounds,            # Thm.4 / Prop.5 / Prop.6 / Thm.7
    "compression": bench_compression,  # Sec. 3/4 ablation
    "rff": bench_rff,                  # Sec. 4 future-work
    "serve": bench_serve,              # online serving (DESIGN.md 10)
    "adaptive": bench_adaptive,        # Sec. 4 open problem (beyond paper)
    "lm_protocol": bench_lm_protocol,  # the technique at LM scale (measured)
    "kernels": bench_kernels,          # Pallas hot-spots
    "roofline": bench_roofline,        # §Roofline summary
    "population": bench_population,    # 10^5-10^6 learners (DESIGN.md 15)
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes for CI")
    ap.add_argument("--only", default=None, choices=list(SUITES))
    ap.add_argument("--json-dir", default=None, metavar="DIR",
                    help="also write BENCH_<suite>.json reports here")
    args = ap.parse_args()

    names = [args.only] if args.only else list(SUITES)
    all_rows, failures = [], []
    for name in names:
        t0 = time.time()
        try:
            rows = SUITES[name].run(quick=args.quick)
            all_rows.extend(rows)
            wall = time.time() - t0
            print(f"# {name}: {len(rows)} rows in {wall:.1f}s",
                  file=sys.stderr)
            if args.json_dir:
                path = BenchReport(name, rows, wall_seconds=wall).save(
                    args.json_dir)
                print(f"# {name}: wrote {path}", file=sys.stderr)
        except Exception:
            traceback.print_exc()
            failures.append(name)
    print_rows(all_rows)
    if failures:
        print(f"# FAILED suites: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
