"""Message-passing nodes of the asynchronous runtime (DESIGN.md Sec. 6).

A :class:`LearnerNode` runs any ``core.substrate`` learner on its own
stream at its own (straggler-perturbed) pace; a
:class:`CoordinatorNode` owns the reference model and aggregates
arriving models with staleness weights.  Nodes interact ONLY through
``transport.Network`` messages — there is no shared state and no
global barrier, so the same node code would run unchanged over real
sockets.

Everything representation-specific — local update, prediction,
local-condition distance, upload/download payload sizing (Sec. 3 delta
encoding for SV, fixed-size vectors for RFF / linear), and the
staleness-weighted aggregation — goes through the
``core.substrate.Substrate`` node face (DESIGN.md Sec. 8), so every
substrate runs through the identical protocol machinery the scan
engine uses.

Message kinds (all payloads are plain dicts):

  report   learner -> coord   local-condition violation (control)
  pull     coord  -> learner  request for the current model (control)
  upload   learner -> coord   delta-encoded model
  download coord  -> learner  delta-encoded aggregated reference

The dynamic flow is: a learner that observes ``||f_i - r||^2 > Delta``
sends ``report``; the coordinator opens an *episode* (ignoring further
reports while one is open) and pulls every learner; each pull is
answered at most once per episode.  Arriving uploads are collected in
an aggregation window; at window close the coordinator aggregates
whatever arrived — late stragglers simply open the next window and are
discounted by their staleness weight.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Set

import jax.numpy as jnp
import numpy as np

from ..core.accounting import ByteModel
from ..core.substrate import Substrate, node_ops
from ..telemetry.trace import PID_RUNTIME
from .async_protocol import AsyncProtocolConfig, staleness_weight
from .clock import Clock
from .transport import Message, Network

COORD = "coord"


class LearnerNode:
    """One online learner on its own stream.

    Processes round t at its own pace (``compute_times[t]`` apart),
    checks the local condition against the last reference it received,
    and speaks the async protocol of the module docstring.  Never
    blocks: syncs in flight do not stop the stream.
    """

    def __init__(
        self,
        idx: int,
        sub: Substrate,
        acfg: AsyncProtocolConfig,
        bm: ByteModel,
        clock: Clock,
        network: Network,
        X: np.ndarray,              # (T, d) this learner's stream
        Y: np.ndarray,              # (T,)
        compute_times: np.ndarray,  # (T,)
        loss_out: np.ndarray,       # (T, m) harness-owned
        err_out: np.ndarray,
        snapshot: Optional[Callable[[int, int, Any], None]] = None,
    ):
        self.idx = idx
        self.name = f"learner{idx}"
        self.sub, self.acfg, self.bm = sub, acfg, bm
        self.clock, self.network = clock, network
        self.X, self.Y, self.compute_times = X, Y, compute_times
        self.ops = node_ops(sub)    # jitted, shared across nodes
        self.loss_out, self.err_out = loss_out, err_out
        self.snapshot = snapshot

        self.state = sub.init_node(idx)
        self.reference = None        # set by harness before start()
        self.known_union: Set[int] = set()
        self.ref_version = 0
        self.t = 0                   # rounds completed
        self.last_upload_episode = -1
        self.finish_time = 0.0
        network.register(self.name, self.handle)

    # -- stream processing --------------------------------------------------

    def start(self) -> None:
        self.clock.schedule(float(self.compute_times[0]), self._round)

    def _round(self) -> None:
        t = self.t
        x = jnp.asarray(self.X[t])
        y = jnp.asarray(self.Y[t])
        # one round = predict (service quality, pre-update, as in the
        # serial driver) + update; fused where the substrate shares
        # work between the two (e.g. the RFF feature map)
        self.state, loss, yhat = self.ops.round(self.state, (x, y))
        if self.sub.loss == "hinge":
            # zero margin predicts +1, identically in every driver
            # (engine._err_terms / the serial oracle)
            pred = 1.0 if float(yhat) >= 0.0 else -1.0
            self.err_out[t, self.idx] = float(pred != float(y))
        else:
            self.err_out[t, self.idx] = float((yhat - y) ** 2)
        self.loss_out[t, self.idx] = float(loss)
        self.t = t + 1
        if self.snapshot is not None:
            self.snapshot(t, self.idx, self._model())

        tracer = self.network.tracer
        if tracer is not None:
            # the round slice ends NOW (this event fired at completion)
            # and lasted this round's drawn compute time
            ct = float(self.compute_times[t])
            tracer.complete(
                "round", self.clock.now - ct, ct, pid=PID_RUNTIME,
                tid=tracer.tid(PID_RUNTIME, self.name),
                args={"t": t, "loss": self.loss_out[t, self.idx]})

        self._maybe_communicate(t)

        if self.t < len(self.X):
            self.clock.schedule(float(self.compute_times[self.t]), self._round)
        else:
            self.finish_time = self.clock.now

    def _model(self):
        return self.sub.node_model(self.state)

    def _maybe_communicate(self, t: int) -> None:
        if self.acfg.kind == "periodic":
            if (t + 1) % self.acfg.period == 0:
                self._upload(round_idx=t)
        else:  # dynamic: report a violation the moment we observe one
            if (t + 1) % self.acfg.mini_batch == 0 and self._violated():
                self.network.send(self.name, COORD, "report",
                                  {"round": t, "learner": self.idx},
                                  self.acfg.control_bytes, round=t)

    def _violated(self) -> bool:
        d = float(self.ops.dist(self._model(), self.reference))
        return d > self.acfg.delta

    # -- protocol messages --------------------------------------------------

    def handle(self, msg: Message) -> None:
        if msg.kind == "pull":
            episode = msg.payload["episode"]
            if episode > self.last_upload_episode:
                self.last_upload_episode = episode
                self._upload(round_idx=self.t - 1, episode=episode)
        elif msg.kind == "download":
            self._adopt(msg.payload)
        else:
            raise ValueError(f"learner got unexpected {msg.kind!r}")

    def _upload(self, round_idx: int, episode: Optional[int] = None) -> None:
        model, ids, nbytes = self.sub.upload_payload(
            self.bm, self.state, self.known_union)
        self.network.send(
            self.name, COORD, "upload",
            {"learner": self.idx, "model": model, "ids": ids,
             "version": self.ref_version, "round": round_idx,
             "episode": episode},
            nbytes, round=round_idx)

    def _adopt(self, payload: Dict[str, Any]) -> None:
        """Adopt the aggregated reference (the serial ``set_all``)."""
        fsync = payload["model"]
        self.state = self.sub.adopt_node(self.state, fsync)
        self.reference = fsync
        self.known_union = payload["union"]
        self.ref_version = payload["version"]
        if self.snapshot is not None and self.t > 0:
            self.snapshot(self.t - 1, self.idx, self._model())


class CoordinatorNode:
    """Reference-model owner; staleness-weighted aggregation, no barrier."""

    def __init__(
        self,
        sub: Substrate,
        acfg: AsyncProtocolConfig,
        bm: ByteModel,
        clock: Clock,
        network: Network,
        m: int,
        reference0,
        episode_timeout: Optional[float] = None,
    ):
        self.sub, self.acfg, self.bm = sub, acfg, bm
        self.clock, self.network, self.m = clock, network, m
        self.reference = reference0
        self.version = 0
        self.episode_ctr = 0
        self.episode_open = False
        self.window_open = False
        self.window: Dict[int, Dict[str, Any]] = {}   # learner -> upload
        self.eps_history: List[float] = []
        self.sync_log: List[Dict[str, Any]] = []
        self.staleness_seen: List[int] = []
        self._episode_start = 0.0    # trace: episode-open time
        self._window_start = 0.0     # trace: aggregation-window open time
        # generous default: a lost pull/upload must not wedge the
        # protocol; after the timeout new reports may re-trigger pulls.
        if episode_timeout is None:
            sys_cfg = network.model.cfg
            episode_timeout = acfg.agg_window + 1.0 + 8.0 * sys_cfg.base_latency
        self.episode_timeout = episode_timeout
        network.register(COORD, self.handle)

    def handle(self, msg: Message) -> None:
        if msg.kind == "report":
            self._on_report(msg)
        elif msg.kind == "upload":
            self._on_upload(msg)
        else:
            raise ValueError(f"coordinator got unexpected {msg.kind!r}")

    def _on_report(self, msg: Message) -> None:
        if self.episode_open:
            return                      # a sync is already in flight
        self.episode_open = True
        self.episode_ctr += 1
        self._episode_start = self.clock.now
        episode = self.episode_ctr
        for i in range(self.m):
            self.network.send(COORD, f"learner{i}", "pull",
                              {"episode": episode},
                              self.acfg.control_bytes, round=msg.round)
        self.clock.schedule(self.episode_timeout,
                            lambda: self._episode_timeout(episode))

    def _episode_timeout(self, episode: int) -> None:
        # pulls or every upload of this episode were lost: clear the
        # in-flight flag so a later report can re-trigger a sync.  A
        # window holding this episode's uploads clears it itself.
        if self.episode_open and self.episode_ctr == episode and not any(
                e.get("episode") == episode for e in self.window.values()):
            self.episode_open = False

    def _on_upload(self, msg: Message) -> None:
        self.window[msg.payload["learner"]] = msg.payload
        if not self.window_open:
            self.window_open = True
            self._window_start = self.clock.now
            self.clock.schedule(self.acfg.agg_window, self._close_window)

    def _close_window(self) -> None:
        entries = list(self.window.values())
        self.window = {}
        self.window_open = False
        # Only the window that merged the CURRENT episode's uploads
        # resolves it — a straggler window replaying an old episode
        # must not clear the flag of a sync still in flight.
        resolved_episode = any(
            e.get("episode") == self.episode_ctr for e in entries)
        if resolved_episode:
            self.episode_open = False
        if not entries:
            return

        lags = [self.version - e["version"] for e in entries]
        weights = [self.acfg.alpha * staleness_weight(self.acfg, lag)
                   for lag in lags]
        self.staleness_seen.extend(lags)
        models = [e["model"] for e in entries]

        fsync, eps, union = self.sub.aggregate(self.reference, models, weights)
        if eps is not None:
            self.eps_history.append(eps)
        self.version += 1
        self.reference = fsync

        trigger_round = max(e["round"] for e in entries)
        payload = {"model": fsync, "union": union, "version": self.version}
        for e in entries:
            nbytes = self.sub.download_payload_bytes(self.bm, union, e["ids"])
            self.network.send(COORD, f"learner{e['learner']}", "download",
                              payload, nbytes, round=trigger_round)
        self.sync_log.append({
            "round": trigger_round,
            "time": self.clock.now,
            "n_models": len(entries),
            "version": self.version,
            "max_lag": max(lags),
        })

        tracer = self.network.tracer
        if tracer is not None:
            tid = tracer.tid(PID_RUNTIME, COORD)
            args = {"round": trigger_round, "n_models": len(entries),
                    "version": self.version, "max_lag": max(lags)}
            # the aggregation window that just closed ...
            tracer.complete("sync/window", self._window_start,
                            self.clock.now - self._window_start,
                            pid=PID_RUNTIME, tid=tid, args=args)
            # ... and, when it resolved a dynamic episode, the whole
            # report -> pulls -> uploads -> aggregate span
            if resolved_episode:
                tracer.complete("sync/episode", self._episode_start,
                                self.clock.now - self._episode_start,
                                pid=PID_RUNTIME, tid=tid,
                                args=dict(args, episode=self.episode_ctr))
