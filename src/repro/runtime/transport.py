"""Message transport with delta-encoded model payloads (DESIGN.md Sec. 6).

Payload sizing follows the Sec. 3 accounting of ``core.accounting``
exactly: a support-vector expansion shipped over a link costs

    |S| * B_alpha  +  |S \\ known| * B_x

where ``known`` is the set of sv_ids the *receiver* already holds —
support vectors known to the other side are never re-sent, only their
(always-changing) coefficients are.  Summed over one full m-learner
synchronization this reproduces ``accounting.sync_bytes_kernel`` to the
byte (tests/test_runtime.py::test_delta_encoding_matches_accounting).

The :class:`Network` routes messages between registered nodes through
the discrete-event clock, applying the system model's latency,
bandwidth and drop behaviour, and meters bytes / message counts /
cumulative latency per directed link.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

# Payload sizing (the Sec. 3 delta encoding per link) lives with the
# rest of the byte accounting in core.accounting; the substrate layer
# (core/substrate.py, DESIGN.md Sec. 8) chooses which sizing applies to
# each upload/download.  Re-exported here for the transport's users.
from ..core.accounting import (ByteModel, idset, kernel_payload_bytes,
                               linear_payload_bytes)
from ..telemetry.trace import PID_NETWORK, Tracer
from .clock import Clock, SystemModel


# ---------------------------------------------------------------------------
# Messages and links
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Message:
    src: str
    dst: str
    kind: str                 # "report" | "pull" | "upload" | "download"
    payload: Any
    nbytes: int
    send_time: float
    deliver_time: float = 0.0
    round: int = -1           # learner round the content corresponds to


@dataclasses.dataclass
class LinkStats:
    messages: int = 0
    bytes: int = 0
    dropped: int = 0
    total_latency: float = 0.0

    @property
    def delivered(self) -> int:
        return self.messages - self.dropped

    @property
    def mean_latency(self) -> float:
        return self.total_latency / self.delivered if self.delivered else 0.0


class Network:
    """Event-driven message fabric between named nodes."""

    def __init__(self, clock: Clock, model: SystemModel,
                 tracer: Optional[Tracer] = None):
        self.clock = clock
        self.model = model
        # default to the clock's tracer so one handle threads the run
        self.tracer = tracer if tracer is not None else clock.tracer
        self._nodes: Dict[str, Callable[[Message], None]] = {}
        self.links: Dict[Tuple[str, str], LinkStats] = {}
        self.total_bytes = 0
        self.dropped = 0
        # metadata-only trace: payloads are model references and would
        # pin every historical model for the run's lifetime.
        self.sent: list = []    # (round, nbytes, kind) at send time

    def register(self, name: str, handler: Callable[[Message], None]) -> None:
        if name in self._nodes:
            raise ValueError(f"duplicate node name {name!r}")
        self._nodes[name] = handler

    def send(self, src: str, dst: str, kind: str, payload: Any,
             nbytes: int, round: int = -1) -> Message:
        """Meter and enqueue a message; delivery is a clock event."""
        if dst not in self._nodes:
            raise KeyError(f"unknown destination {dst!r}")
        stats = self.links.setdefault((src, dst), LinkStats())
        msg = Message(src=src, dst=dst, kind=kind, payload=payload,
                      nbytes=nbytes, send_time=self.clock.now, round=round)
        # bytes leave the sender even if the network then loses them
        stats.messages += 1
        stats.bytes += nbytes
        self.total_bytes += nbytes
        self.sent.append((round, nbytes, kind))
        if self.model.drop():
            stats.dropped += 1
            self.dropped += 1
            if self.tracer is not None:
                self.tracer.instant(
                    f"drop/{kind}", self.clock.now, pid=PID_NETWORK,
                    tid=self.tracer.tid(PID_NETWORK, f"{src}->{dst}"),
                    args={"src": src, "dst": dst, "nbytes": nbytes,
                          "round": round})
            return msg
        latency = self.model.draw_latency(nbytes)
        stats.total_latency += latency
        msg.deliver_time = self.clock.now + latency
        if self.tracer is not None:
            # one span per message, send -> deliver, carrying the
            # Sec. 3 byte annotation (DESIGN.md Sec. 11): the nbytes
            # args summed over msg/* spans plus drop/* instants ARE
            # the run's total_bytes (bytes leave the sender either way)
            self.tracer.complete(
                f"msg/{kind}", msg.send_time, latency, pid=PID_NETWORK,
                tid=self.tracer.tid(PID_NETWORK, f"{src}->{dst}"),
                args={"src": src, "dst": dst, "nbytes": nbytes,
                      "round": round})
        self.clock.schedule(latency, lambda: self._deliver(msg))
        return msg

    def _deliver(self, msg: Message) -> None:
        self._nodes[msg.dst](msg)

    def link_bytes(self) -> Dict[str, int]:
        return {f"{s}->{d}": st.bytes for (s, d), st in self.links.items()}
