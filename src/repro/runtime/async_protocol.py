"""Asynchronous synchronization policy (DESIGN.md Sec. 6).

Asynchronous counterparts of ``core.protocol``'s sigma_periodic /
sigma_dynamic.  The structural difference to the lockstep operators is
*who decides when*:

- **async periodic**: every learner pushes its model after each b of
  its OWN rounds — no global round counter exists.
- **async dynamic**: a learner reports a local-condition violation
  ``||f_i - r||^2 > Delta`` the moment *it* observes one; the
  coordinator then pulls every learner once and aggregates whatever
  models have arrived when its aggregation window closes — stragglers
  join a later window instead of blocking this one.  Quiescence needs
  no global barrier: when no learner violates, no message is ever sent.

Aggregation is staleness-weighted in the FedAsync style: a model based
on coordinator version ``tau`` merged at version ``t`` gets mixing
weight

    alpha_t = alpha * s(t - tau),   s in {constant, hinge, poly},

each arrived model k forms the candidate
``(1 - alpha_t^k) r + alpha_t^k f_k`` and the new reference is the
plain average of the candidates.  With ``alpha = 1`` and the constant
schedule every candidate collapses to its model and the update
degenerates to the paper's Prop. 2 average over the arrived subset —
which is why the zero-latency async run reproduces the serial
simulator byte-for-byte (bench_async).

The aggregation itself is representation-specific and lives on the
substrate (``core.substrate.Substrate.aggregate`` — SV expansions
concatenate coefficient-scaled slots and compress back to the sync
budget; primal substrates mix in weight space).  This module owns only
the *policy*: the protocol configuration and the staleness schedules.
"""
from __future__ import annotations

import dataclasses


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AsyncProtocolConfig:
    """Configuration of the asynchronous protocol.

    Attributes:
      kind: ``periodic`` (push every ``period`` local rounds) or
        ``dynamic`` (violation-triggered, threshold ``delta``).
      period: local-round push period (periodic only).
      delta: divergence threshold Delta (dynamic only).
      mini_batch: local conditions are checked every ``mini_batch``
        local rounds (same role as in the serial protocol).
      alpha: base mixing weight of an arriving model.  ``1.0`` +
        constant schedule = plain averaging of the arrived subset.
      staleness: ``constant | hinge | poly`` — the s(.) schedule.
      stale_a / stale_b: schedule shape parameters (FedAsync: hinge is
        1 for lag <= b then 1/(a (lag - b)); poly is (lag+1)^-a).
      agg_window: how long (sim time) the coordinator collects arrived
        models after the first one before aggregating.  0 still batches
        all same-instant arrivals (event order is deterministic).
      control_bytes: metered size of control messages (violation
        reports / pull requests).  The paper's Sec. 3 accounting counts
        model payloads only, so this defaults to 0.
    """

    kind: str = "dynamic"
    period: int = 10
    delta: float = 0.1
    mini_batch: int = 1
    alpha: float = 1.0
    staleness: str = "constant"
    stale_a: float = 0.5
    stale_b: int = 4
    agg_window: float = 0.0
    control_bytes: int = 0

    def __post_init__(self) -> None:
        if self.kind not in ("periodic", "dynamic"):
            raise ValueError(f"unknown async protocol kind: {self.kind!r}")
        if self.staleness not in ("constant", "hinge", "poly"):
            raise ValueError(f"unknown staleness schedule: {self.staleness!r}")
        if not (0.0 < self.alpha <= 1.0):
            raise ValueError("alpha in (0, 1]")
        if self.period < 1 or self.mini_batch < 1:
            raise ValueError("period and mini_batch must be >= 1")
        if self.staleness != "constant" and self.stale_a <= 0:
            raise ValueError("stale_a must be > 0 for hinge/poly schedules")
        if self.agg_window < 0:
            raise ValueError("agg_window must be >= 0")


def staleness_weight(cfg: AsyncProtocolConfig, lag: int) -> float:
    """s(t - tau), clipped to (0, 1]."""
    lag = max(int(lag), 0)
    if cfg.staleness == "constant":
        s = 1.0
    elif cfg.staleness == "hinge":
        s = 1.0 if lag <= cfg.stale_b else 1.0 / (cfg.stale_a * (lag - cfg.stale_b))
    else:  # poly
        s = float((lag + 1) ** (-cfg.stale_a))
    return min(max(s, 1e-12), 1.0)
