"""Discrete-event clock + deterministic seeded system models.

The asynchronous runtime (DESIGN.md Sec. 6) is a discrete-event
simulation: every node action — a learner finishing a round, a message
arriving, an aggregation window closing — is an :class:`Event` on one
global priority queue ordered by ``(time, seq)``.  The monotonically
increasing ``seq`` makes simultaneous events pop in scheduling order,
so a run is a pure function of its seeds: identical configuration =>
identical event trace => identical results (tested in
tests/test_runtime.py::test_determinism_under_seed).

:class:`SystemModel` owns all randomness of the simulated system:

- per-(round, learner) compute times with lognormal jitter and a
  deterministic straggler subset slowed by a multiplier;
- per-message latency = base * jitter + nbytes / bandwidth;
- i.i.d. message drops (link failures).

Compute times are drawn up front as a (T, m) table so the exact same
draws can price the synchronized-barrier baseline (sum_t max_i c[t,i])
against the asynchronous runtime (max_i sum_t c[t,i] + sync overhead).
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(order=True)
class Event:
    time: float
    seq: int
    fn: Callable[[], None] = dataclasses.field(compare=False)
    cancelled: bool = dataclasses.field(default=False, compare=False)


class Clock:
    """Global event queue.  ``schedule`` is the only way time advances.

    ``tracer`` (a ``telemetry.trace.Tracer``, optional) samples the
    queue as events process: a ``clock/queue`` counter track of
    pending events on the simulated timeline (DESIGN.md Sec. 11).
    Everything else traced in a run — message spans, round slices,
    sync episodes — is recorded by the component that owns it
    (transport / nodes / serving), all against this clock's ``now``,
    which is what makes the export deterministic under seed.
    """

    def __init__(self, tracer=None) -> None:
        self.now: float = 0.0
        self._seq: int = 0
        self._heap: List[Event] = []
        self.events_processed: int = 0
        self.tracer = tracer

    def schedule(self, delay: float, fn: Callable[[], None]) -> Event:
        if delay < 0:
            raise ValueError(f"negative delay {delay}")
        ev = Event(self.now + delay, self._seq, fn)
        heapq.heappush(self._heap, ev)
        self._seq += 1
        return ev

    def schedule_at(self, time: float, fn: Callable[[], None]) -> Event:
        """Schedule at an *absolute* simulated time, preserved exactly.

        ``schedule(t - now, fn)`` round-trips the target through a
        subtraction and a re-addition, so a value that IS representable
        (a tick-grid point ``k * interval``, say) can come back a ulp
        off after ``now + (t - now)``.  Grid-sensitive callers (the
        serving tick scheduler) use this instead: the event fires at
        exactly the float passed in.  Times in the past are clamped to
        ``now`` (fire as soon as the queue reaches them).
        """
        ev = Event(max(self.now, float(time)), self._seq, fn)
        heapq.heappush(self._heap, ev)
        self._seq += 1
        return ev

    def cancel(self, ev: Event) -> None:
        """Mark a scheduled event dead: it is skipped when popped (the
        heap is not rebuilt), advances nothing, and is not counted in
        ``events_processed``.  Cancelling twice, or cancelling an event
        that already fired, is a no-op."""
        ev.cancelled = True

    def run(self, max_events: Optional[int] = None) -> None:
        """Process events until the queue drains (or max_events)."""
        n = 0
        while self._heap:
            ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            assert ev.time >= self.now, "event queue went backwards"
            self.now = ev.time
            ev.fn()
            self.events_processed += 1
            n += 1
            if self.tracer is not None:
                self.tracer.counter("clock/queue", self.now,
                                    {"pending": len(self._heap)})
            if max_events is not None and n >= max_events:
                return

    @property
    def pending(self) -> int:
        return len(self._heap)


# ---------------------------------------------------------------------------
# System models (latency / stragglers / failures)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SystemConfig:
    """Deterministic-under-seed model of the simulated cluster.

    All times are in abstract simulation units; ``base_compute = 1.0``
    means one learner round takes one unit on an unperturbed node.

    Attributes:
      seed: master seed for every draw the system makes.
      base_compute: mean per-round compute time of a healthy learner.
      compute_jitter: lognormal sigma of per-round compute noise
        (0 disables; mean is kept at base_compute by the -sigma^2/2
        correction).
      straggler_frac: fraction of learners designated stragglers.
      straggler_mult: compute-time multiplier applied to stragglers.
      straggler_prob: per-round probability that a designated straggler
        actually stalls by straggler_mult (1.0 = constantly slow;
        < 1 models intermittent stalls — GC pauses, preemption — where
        a lockstep barrier pays for every stall of every node while an
        async learner only pays for its own).
      base_latency: mean one-way message latency (0 = ideal network).
      latency_jitter: lognormal sigma of per-message latency noise.
      bandwidth: link bandwidth in bytes per time unit
        (``inf`` = size-independent latency).
      drop_prob: probability a message is silently lost in transit.
    """

    seed: int = 0
    base_compute: float = 1.0
    compute_jitter: float = 0.0
    straggler_frac: float = 0.0
    straggler_mult: float = 4.0
    straggler_prob: float = 1.0
    base_latency: float = 0.0
    latency_jitter: float = 0.0
    bandwidth: float = math.inf
    drop_prob: float = 0.0

    def __post_init__(self) -> None:
        if not (0.0 <= self.straggler_frac <= 1.0):
            raise ValueError("straggler_frac in [0, 1]")
        if not (0.0 < self.straggler_prob <= 1.0):
            raise ValueError("straggler_prob in (0, 1]")
        if self.bandwidth <= 0:
            raise ValueError("bandwidth must be > 0 (inf = unmetered)")
        if not (0.0 <= self.drop_prob < 1.0):
            raise ValueError("drop_prob in [0, 1)")
        if self.base_compute <= 0:
            raise ValueError("base_compute must be > 0")


class SystemModel:
    """Seeded sampler for compute times, latencies and drops.

    Two independent generators: compute draws are tabulated up front
    (shared with the barrier baseline), network draws happen on demand
    in event order (deterministic because event order is).
    """

    def __init__(self, cfg: SystemConfig, m: int):
        self.cfg = cfg
        self.m = m
        self._net_rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, 0xA51C]))
        rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, 0xC0DE]))
        k = int(round(cfg.straggler_frac * m))
        self.stragglers = np.sort(rng.choice(m, size=k, replace=False)) \
            if k else np.zeros((0,), np.int64)
        self._compute_rng = rng

    def draw_compute(self, T: int) -> np.ndarray:
        """(T, m) per-round compute times; stragglers stall on a
        straggler_prob fraction of their rounds."""
        cfg = self.cfg
        mult = np.ones((T, self.m))
        if len(self.stragglers):
            stall = (self._compute_rng.random((T, len(self.stragglers)))
                     < cfg.straggler_prob)
            mult[:, self.stragglers] = np.where(stall, cfg.straggler_mult, 1.0)
        if cfg.compute_jitter > 0:
            z = self._compute_rng.normal(size=(T, self.m))
            jit = np.exp(cfg.compute_jitter * z - 0.5 * cfg.compute_jitter ** 2)
        else:
            jit = np.ones((T, self.m))
        return cfg.base_compute * mult * jit

    def draw_latency(self, nbytes: int) -> float:
        """One-way latency for a message of ``nbytes``."""
        cfg = self.cfg
        lat = cfg.base_latency
        if cfg.latency_jitter > 0 and lat > 0:
            z = self._net_rng.normal()
            lat *= math.exp(cfg.latency_jitter * z
                            - 0.5 * cfg.latency_jitter ** 2)
        if math.isfinite(cfg.bandwidth):
            # reprolint: allow[ACC01] bandwidth term: bytes->seconds in the time model, not ledger math
            lat += nbytes / cfg.bandwidth
        return lat

    def drop(self) -> bool:
        if self.cfg.drop_prob <= 0:
            return False
        return bool(self._net_rng.random() < self.cfg.drop_prob)

    def expected_round_trip(self) -> float:
        """Mean request+response latency, used by the barrier baseline
        to price one synchronization's network cost."""
        return 2.0 * self.cfg.base_latency


def barrier_wall_clock(compute_times: np.ndarray, num_syncs: int,
                       model: SystemModel, sync_bytes: int = 0) -> float:
    """Simulated wall-clock of the lockstep serial driver on the same
    cluster: every round ends with a global barrier (sum of per-round
    maxima), every synchronization adds a round trip to the
    coordinator, and ``sync_bytes`` of synchronization traffic pay the
    same bandwidth term the async runtime is charged per message."""
    per_round_max = compute_times.max(axis=1)
    total = float(per_round_max.sum()) + num_syncs * model.expected_round_trip()
    if math.isfinite(model.cfg.bandwidth):
        # reprolint: allow[ACC01] bandwidth term: bytes->seconds in the time model, not ledger math
        total += sync_bytes / model.cfg.bandwidth
    return total
