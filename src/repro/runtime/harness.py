"""Asynchronous experiment driver (DESIGN.md Sec. 6).

Runs the same (stream, learner, kernel) workloads as
``core.simulation`` and ``core.engine`` through the event-driven
runtime and reports the same ``SimResult`` fields — existing figure
benchmarks compare the lockstep and asynchronous systems directly —
plus async-only metrics (simulated wall-clock, per-link bytes,
staleness statistics).

The learner may be anything ``core.substrate.substrate_of`` resolves —
a ``LearnerConfig`` (SV or linear), an ``RFFSpec``, or a ``Substrate``
instance — so every protocol kind x substrate x network model
combination runs in both the serial engine and this runtime
(DESIGN.md Sec. 8).

Round-indexed series keep the serial driver's semantics: learners may
reach round t at very different simulated times, but
``cumulative_loss[t]`` always sums every learner's first t+1 rounds,
and a synchronization's bytes are attributed to the learner round that
triggered it.  With an ideal network (zero latency, no stragglers,
``alpha = 1``, constant staleness) the async dynamic protocol's event
trace collapses to the serial simulator's round structure and the byte
ledgers agree exactly (tests/test_runtime.py, bench_async).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from ..core import accounting
from ..core.simulation import SimResult
from ..core.substrate import substrate_of
from .async_protocol import AsyncProtocolConfig
from .clock import Clock, SystemConfig, SystemModel, barrier_wall_clock
from .nodes import CoordinatorNode, LearnerNode
from .transport import Network


@dataclasses.dataclass
class AsyncSimResult(SimResult):
    """SimResult plus the quantities only an async system has."""

    wall_clock: float = 0.0            # simulated time to finish all streams
    barrier_wall_clock: float = 0.0    # lockstep baseline on the same draws
    link_bytes: Dict[str, int] = dataclasses.field(default_factory=dict)
    mean_staleness: float = 0.0        # mean version lag of merged models
    max_staleness: int = 0
    num_dropped: int = 0
    events_processed: int = 0

    @property
    def speedup_vs_barrier(self) -> float:
        return self.barrier_wall_clock / max(self.wall_clock, 1e-12)


def run_async_simulation(
    learner,
    acfg: AsyncProtocolConfig,
    X: np.ndarray,              # (T, m, d)
    Y: np.ndarray,              # (T, m)
    sys_cfg: Optional[SystemConfig] = None,
    sync_budget: Optional[int] = None,
    compress_method: Optional[str] = None,   # None -> substrate's own
    record_divergence: bool = True,
    barrier_num_syncs: Optional[int] = None,
    backend: Optional[str] = None,           # None -> substrate's own
    tracer=None,                             # telemetry.Tracer, optional
) -> AsyncSimResult:
    """Run T rounds of m learners under the asynchronous protocol.

    ``compress_method=None`` / ``backend=None`` keep the substrate's
    own configuration (``compression.DEFAULT_METHOD`` — "truncate" —
    and "reference" for a LearnerConfig); see
    ``substrate.substrate_of`` for the full sentinel semantics.

    record_divergence keeps per-round model snapshots — O(T m |model|)
    memory — because an async run has no global round boundary at
    which divergence could be computed streaming.  Matches the serial
    driver's always-on divergence series; pass False for large T.

    barrier_num_syncs prices the lockstep baseline's per-sync round
    trips.  Async windowing can fragment aggregations, so for a fair
    baseline pass the SERIAL simulator's sync count on the same
    workload (bench_async does); defaults to this run's own count.

    tracer: a ``repro.telemetry.Tracer`` records the run's full event
    trace on the simulated clock — learner round slices, message spans
    with their Sec. 3 byte annotations, aggregation windows and
    dynamic sync episodes — Perfetto-loadable via ``tracer.save`` and
    byte-identical under seed (DESIGN.md Sec. 11).
    """
    sub = substrate_of(learner, sync_budget=sync_budget,
                       compress_method=compress_method, backend=backend)
    T, m, d = X.shape
    sub.validate(T, m, d)
    sys_cfg = sys_cfg or SystemConfig()
    model = SystemModel(sys_cfg, m)
    compute_times = model.draw_compute(T)

    clock = Clock(tracer=tracer)
    network = Network(clock, model)
    bm = accounting.ByteModel(dim=d)

    loss_out = np.zeros((T, m))
    err_out = np.zeros((T, m))

    if record_divergence:
        bufs = sub.snapshot_buffers(T, m)

        def snapshot(t, i, f):
            sub.write_snapshot(bufs, t, i, f)
    else:
        snapshot = None

    reference0 = sub.init_reference()
    coord = CoordinatorNode(sub, acfg, bm, clock, network, m, reference0)
    nodes = []
    for i in range(m):
        node = LearnerNode(
            i, sub, acfg, bm, clock, network,
            X[:, i], Y[:, i], compute_times[:, i],
            loss_out, err_out, snapshot=snapshot)
        node.reference = reference0
        nodes.append(node)
    for node in nodes:
        node.start()
    clock.run()

    # ---- round-indexed series ---------------------------------------------
    cum_loss = np.cumsum(loss_out.sum(axis=1))
    cum_err = np.cumsum(err_out.sum(axis=1))
    bytes_by_round = np.zeros((T,), np.int64)
    for rnd, nbytes, _kind in network.sent:
        bytes_by_round[min(max(rnd, 0), T - 1)] += nbytes
    cum_bytes = np.cumsum(bytes_by_round)

    sync_rounds = np.sort(np.asarray(
        [s["round"] for s in coord.sync_log], dtype=np.int64))

    divs = sub.divergence_series(bufs) if record_divergence \
        else np.zeros((T,))

    lags = coord.staleness_seen
    return AsyncSimResult(
        cumulative_loss=cum_loss,
        cumulative_bytes=cum_bytes,
        cumulative_errors=cum_err,
        sync_rounds=sync_rounds,
        divergences=divs,
        eps_history=np.asarray(coord.eps_history),
        num_syncs=len(coord.sync_log),
        total_bytes=int(network.total_bytes),
        total_loss=float(cum_loss[-1]) if T else 0.0,
        wall_clock=max((n.finish_time for n in nodes), default=0.0),
        barrier_wall_clock=barrier_wall_clock(
            compute_times,
            len(coord.sync_log) if barrier_num_syncs is None
            else barrier_num_syncs,
            model, sync_bytes=int(network.total_bytes)),
        link_bytes=network.link_bytes(),
        mean_staleness=float(np.mean(lags)) if lags else 0.0,
        max_staleness=int(np.max(lags)) if lags else 0,
        num_dropped=network.dropped,
        events_processed=clock.events_processed,
    )


# Convenience wrappers mirroring core.simulation's entry points.


def run_async_kernel_simulation(lcfg, acfg, X, Y, **kw) -> AsyncSimResult:
    assert lcfg.is_kernel
    return run_async_simulation(lcfg, acfg, X, Y, **kw)


def run_async_linear_simulation(lcfg, acfg, X, Y, **kw) -> AsyncSimResult:
    assert not lcfg.is_kernel
    return run_async_simulation(lcfg, acfg, X, Y, **kw)
