"""Asynchronous event-driven protocol runtime (DESIGN.md Sec. 6).

- clock:          discrete-event queue + seeded latency/straggler/failure
                  models; deterministic under seed.
- transport:      delta-encoded messages metered with the Sec. 3
                  ByteModel; per-link byte/latency stats.
- nodes:          LearnerNode (any core.substrate learner on its own
                  stream) and CoordinatorNode (staleness-weighted
                  aggregation, no global barrier).
- async_protocol: async sigma_periodic / sigma_dynamic policy + the
                  FedAsync staleness schedules alpha_t = alpha * s(t-tau)
                  (the aggregation itself lives on the substrate).
- harness:        driver producing SimResult-compatible AsyncSimResult
                  so sync and async systems plot on the same axes; runs
                  any substrate (SV / RFF / linear, DESIGN.md Sec. 8).
"""
from . import async_protocol, clock, harness, nodes, transport
from .async_protocol import AsyncProtocolConfig, staleness_weight
from .clock import Clock, SystemConfig, SystemModel, barrier_wall_clock
from .harness import (AsyncSimResult, run_async_kernel_simulation,
                      run_async_linear_simulation, run_async_simulation)
from .nodes import CoordinatorNode, LearnerNode
from .transport import Message, Network

__all__ = [
    "async_protocol", "clock", "harness", "nodes", "transport",
    "AsyncProtocolConfig", "staleness_weight",
    "Clock", "SystemConfig", "SystemModel", "barrier_wall_clock",
    "AsyncSimResult", "run_async_kernel_simulation",
    "run_async_linear_simulation", "run_async_simulation",
    "CoordinatorNode", "LearnerNode", "Message", "Network",
]
