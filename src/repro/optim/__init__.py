from .optimizers import Optimizer, OptimizerConfig, make

__all__ = ["Optimizer", "OptimizerConfig", "make"]
