"""Optimizers (functional, pytree-based; no optax dependency).

``sgd`` (plain / momentum) is the theory-relevant optimizer: its update
is loss-proportional in the paper's sense (Cor. 8), so the dynamic
protocol's guarantees apply.  ``adamw`` is provided for practical LM
training; its update is only approximately loss-proportional (the
epsilon machinery of Lemma 3 covers bounded deviations), which we note
in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    kind: str = "sgd"          # sgd | adamw
    lr: float = 1e-2
    momentum: float = 0.0
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 0.0     # 0 = off


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree, jnp.ndarray], Tuple[PyTree, PyTree]]
    # update(grads, opt_state, params, step) -> (new_params, new_state)


def _clip(grads: PyTree, max_norm: float) -> PyTree:
    if max_norm <= 0:
        return grads
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)


def make(cfg: OptimizerConfig) -> Optimizer:
    if cfg.kind == "sgd":
        def init(params):
            if cfg.momentum == 0.0:
                return ()
            return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

        def update(grads, state, params, step):
            grads = _clip(grads, cfg.grad_clip)
            if cfg.momentum == 0.0:
                new_params = jax.tree.map(
                    lambda p, g: (p.astype(jnp.float32)
                                  - cfg.lr * g.astype(jnp.float32)).astype(p.dtype),
                    params, grads)
                return new_params, state
            new_state = jax.tree.map(
                lambda m, g: cfg.momentum * m + g.astype(jnp.float32), state, grads)
            new_params = jax.tree.map(
                lambda p, m: (p.astype(jnp.float32) - cfg.lr * m).astype(p.dtype),
                params, new_state)
            return new_params, new_state

        return Optimizer(init=init, update=update)

    if cfg.kind == "adamw":
        def init(params):
            z = lambda p: jnp.zeros_like(p, jnp.float32)
            return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

        def update(grads, state, params, step):
            grads = _clip(grads, cfg.grad_clip)
            t = step.astype(jnp.float32) + 1.0
            b1, b2 = cfg.beta1, cfg.beta2
            m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                             state["m"], grads)
            v = jax.tree.map(lambda v, g: b2 * v + (1 - b2)
                             * jnp.square(g.astype(jnp.float32)), state["v"], grads)
            mh = jax.tree.map(lambda m: m / (1 - b1 ** t), m)
            vh = jax.tree.map(lambda v: v / (1 - b2 ** t), v)
            def upd(p, mh, vh):
                step_ = cfg.lr * mh / (jnp.sqrt(vh) + cfg.eps)
                if cfg.weight_decay:
                    step_ = step_ + cfg.lr * cfg.weight_decay * p.astype(jnp.float32)
                return (p.astype(jnp.float32) - step_).astype(p.dtype)
            return jax.tree.map(upd, params, mh, vh), {"m": m, "v": v}

        return Optimizer(init=init, update=update)

    raise ValueError(cfg.kind)
