"""repro: Communication-Efficient Distributed Online Learning with Kernels

Paper-faithful protocol core + multi-pod JAX training/serving framework.
See README.md / DESIGN.md / EXPERIMENTS.md."""

__version__ = "0.2.0"
