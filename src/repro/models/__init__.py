from .config import ModelConfig, param_count, round_up
from .model import ModelAPI, build, count_params

__all__ = ["ModelConfig", "param_count", "round_up", "ModelAPI", "build",
           "count_params"]
