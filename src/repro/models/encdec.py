"""Encoder-decoder model (Whisper-style backbone).

The modality frontend (mel-spectrogram + conv downsampling) is a STUB:
``input_specs`` provides precomputed frame embeddings (B, n_frames, d)
— the sanctioned carve-out.  Everything downstream is real: sinusoidal
encoder positions, non-causal encoder self-attention, causal decoder
self-attention with KV cache, cross-attention with precomputed
encoder K/V, learned decoder positions, LayerNorm + GELU MLPs.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import attention as attn
from .config import ModelConfig
from .layers import (dense, dense_init, embed, embed_init, mlp, mlp_init,
                     norm_apply, norm_init, sinusoidal_pos)

Array = jnp.ndarray
Params = Dict[str, Any]

MAX_DEC_POS = 8192  # learned decoder position table size


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _enc_block_init(key, cfg: ModelConfig, dt) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": norm_init(cfg.norm_kind, cfg.d_model, dt),
        "attn": attn.gqa_init(k1, cfg, dt),
        "norm2": norm_init(cfg.norm_kind, cfg.d_model, dt),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, dt, cfg.act),
    }


def _dec_block_init(key, cfg: ModelConfig, dt) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": norm_init(cfg.norm_kind, cfg.d_model, dt),
        "self_attn": attn.gqa_init(k1, cfg, dt),
        "norm2": norm_init(cfg.norm_kind, cfg.d_model, dt),
        "cross_attn": attn.cross_init(k2, cfg, dt),
        "norm3": norm_init(cfg.norm_kind, cfg.d_model, dt),
        "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, dt, cfg.act),
    }


def init_encdec(key, cfg: ModelConfig) -> Params:
    dt = _dtype(cfg)
    ke, kd, kh, kp = jax.random.split(key, 4)

    enc_keys = jax.random.split(ke, cfg.encoder_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    params: Params = {
        "embed": embed_init(jax.random.fold_in(key, 1), cfg.padded_vocab,
                            cfg.d_model, dt),
        "dec_pos": {"table": (jax.random.normal(kp, (MAX_DEC_POS, cfg.d_model))
                              * 0.01).astype(dt)},
        "enc_blocks": jax.vmap(lambda k: _enc_block_init(k, cfg, dt))(enc_keys),
        "enc_norm": norm_init(cfg.norm_kind, cfg.d_model, dt),
        "dec_blocks": jax.vmap(lambda k: _dec_block_init(k, cfg, dt))(dec_keys),
        "dec_norm": norm_init(cfg.norm_kind, cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(kh, cfg.d_model, cfg.padded_vocab, dt)
    return params


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------


def encode(params: Params, cfg: ModelConfig, frames: Array) -> Array:
    """frames: (B, F, d) stub frontend embeddings -> encoder states."""
    B, F, d = frames.shape
    x = frames.astype(_dtype(cfg)) + sinusoidal_pos(F, d, _dtype(cfg))[None]

    def body(x, bp):
        h = norm_apply(cfg.norm_kind, bp["norm1"], x, cfg.norm_eps)
        x = x + attn.gqa_forward(cfg, bp["attn"], h, causal=False)
        h = norm_apply(cfg.norm_kind, bp["norm2"], x, cfg.norm_eps)
        return x + mlp(bp["mlp"], h, cfg.act), None

    x, _ = lax.scan(body, x, params["enc_blocks"],
                    unroll=cfg.encoder_layers if cfg.unroll_scan else 1)
    return norm_apply(cfg.norm_kind, params["enc_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Decoder (train / prefill / decode)
# ---------------------------------------------------------------------------


def _dec_embed(params, cfg, tokens, offset=0):
    x = embed(params["embed"], tokens)
    S = tokens.shape[1]
    pos_tab = params["dec_pos"]["table"]
    idx = jnp.clip(jnp.arange(S) + offset, 0, MAX_DEC_POS - 1)
    return x + jnp.take(pos_tab, idx, axis=0)[None]


def decode_train(params: Params, cfg: ModelConfig, tokens: Array,
                 enc_out: Array) -> Array:
    """Teacher-forced decoder forward -> logits."""
    x = _dec_embed(params, cfg, tokens)

    def body(x, bp):
        h = norm_apply(cfg.norm_kind, bp["norm1"], x, cfg.norm_eps)
        x = x + attn.gqa_forward(cfg, bp["self_attn"], h, causal=True,
                                 window=cfg.window)
        h = norm_apply(cfg.norm_kind, bp["norm2"], x, cfg.norm_eps)
        ek, ev = attn.cross_precompute(cfg, bp["cross_attn"], enc_out)
        x = x + attn.cross_forward(cfg, bp["cross_attn"], h, ek, ev)
        h = norm_apply(cfg.norm_kind, bp["norm3"], x, cfg.norm_eps)
        return x + mlp(bp["mlp"], h, cfg.act), None

    x, _ = lax.scan(body, x, params["dec_blocks"],
                    unroll=cfg.n_layers if cfg.unroll_scan else 1)
    x = norm_apply(cfg.norm_kind, params["dec_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        return x @ params["embed"]["table"].T
    return dense(params["lm_head"], x)


def encdec_loss(params: Params, cfg: ModelConfig, frames: Array,
                tokens: Array, labels: Array) -> Array:
    enc_out = encode(params, cfg, frames)
    pad_bias = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab, 0.0, -1e30)
    logits = (decode_train(params, cfg, tokens, enc_out).astype(jnp.float32)
              + pad_bias[None, None, :])
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def init_dec_caches(cfg: ModelConfig, B: int, length: int, dtype=None):
    """Per-decoder-layer: self-attn KV cache + cross-attn K/V store."""
    dt = dtype or _dtype(cfg)
    L = min(length, cfg.window) if cfg.window > 0 else length
    one = {
        "self": attn.init_kv_cache(cfg, B, L, dt),
        "cross_k": jnp.zeros((B, cfg.n_audio_frames, cfg.n_kv_heads, cfg.hd), dt),
        "cross_v": jnp.zeros((B, cfg.n_audio_frames, cfg.n_kv_heads, cfg.hd), dt),
    }
    return jax.tree.map(
        lambda l: jnp.broadcast_to(l, (cfg.n_layers,) + l.shape).copy(), one)


def prefill_decoder(params: Params, cfg: ModelConfig, frames: Array,
                    tokens: Array, caches):
    """Encode + teacher-forced prefill of decoder caches."""
    enc_out = encode(params, cfg, frames)
    x = _dec_embed(params, cfg, tokens)
    S = tokens.shape[1]

    def body(x, scanned):
        bp, c = scanned
        h = norm_apply(cfg.norm_kind, bp["norm1"], x, cfg.norm_eps)
        a, kv = attn.gqa_forward(cfg, bp["self_attn"], h, causal=True,
                                 window=cfg.window, return_kv=True)
        x = x + a
        from .transformer import _fill_kv_cache
        new_self = _fill_kv_cache(cfg, c["self"], kv, S)
        ek, ev = attn.cross_precompute(cfg, bp["cross_attn"], enc_out)
        h = norm_apply(cfg.norm_kind, bp["norm2"], x, cfg.norm_eps)
        x = x + attn.cross_forward(cfg, bp["cross_attn"], h, ek, ev)
        h = norm_apply(cfg.norm_kind, bp["norm3"], x, cfg.norm_eps)
        x = x + mlp(bp["mlp"], h, cfg.act)
        return x, {"self": new_self, "cross_k": ek.astype(c["cross_k"].dtype),
                   "cross_v": ev.astype(c["cross_v"].dtype)}

    x, new_caches = lax.scan(body, x, (params["dec_blocks"], caches),
                             unroll=cfg.n_layers if cfg.unroll_scan else 1)
    x = norm_apply(cfg.norm_kind, params["dec_norm"], x[:, -1:, :], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T
    else:
        logits = dense(params["lm_head"], x)
    return logits, new_caches


def decode_step_encdec(params: Params, cfg: ModelConfig, caches,
                       token: Array, pos: Array):
    """One decoder token against self+cross caches."""
    x = embed(params["embed"], token)
    pidx = jnp.clip(pos, 0, MAX_DEC_POS - 1)
    x = x + jnp.take(params["dec_pos"]["table"], pidx[None], axis=0)[None]

    def body(x, scanned):
        bp, c = scanned
        h = norm_apply(cfg.norm_kind, bp["norm1"], x, cfg.norm_eps)
        a, new_self = attn.gqa_decode(cfg, bp["self_attn"], h, pos, c["self"],
                                      window=cfg.window)
        x = x + a
        h = norm_apply(cfg.norm_kind, bp["norm2"], x, cfg.norm_eps)
        x = x + attn.cross_forward(cfg, bp["cross_attn"], h,
                                   c["cross_k"], c["cross_v"])
        h = norm_apply(cfg.norm_kind, bp["norm3"], x, cfg.norm_eps)
        x = x + mlp(bp["mlp"], h, cfg.act)
        return x, {"self": new_self, "cross_k": c["cross_k"],
                   "cross_v": c["cross_v"]}

    x, new_caches = lax.scan(body, x, (params["dec_blocks"], caches),
                             unroll=cfg.n_layers if cfg.unroll_scan else 1)
    x = norm_apply(cfg.norm_kind, params["dec_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T
    else:
        logits = dense(params["lm_head"], x)
    return logits, new_caches
