"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block: two branches from the residual stream —
  gate branch:      y = gelu(W_y x)
  recurrent branch: u = W_x x -> causal conv1d(4) -> RG-LRU -> h
output: W_o (h * y).

RG-LRU recurrence (per channel):
  r_t = sigmoid(W_a u_t + b_a)              recurrence gate
  i_t = sigmoid(W_i u_t + b_i)              input gate
  log_a_t = -c * softplus(Lambda) * r_t     (c = 8)
  a_t = exp(log_a_t)
  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Training/prefill evaluate the linear recurrence with
``jax.lax.associative_scan`` — O(log S) depth, maps well onto the TPU
vector units (this is the TPU-native replacement for the paper-family's
custom CUDA linear-scan kernel).  Decode is the O(1) step; the "cache"
for long_500k is the fixed-size hidden state + conv buffer.
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (causal_conv1d, conv1d_init, conv1d_step, dense,
                     dense_init, expand_left)

Array = jnp.ndarray
Params = Dict[str, Array]

_C = 8.0


class LRUState(NamedTuple):
    h: Array          # (B, W) hidden state
    conv_buf: Array   # (B, conv_width-1, W)


def rglru_init(key, cfg: ModelConfig, dtype) -> Params:
    d, W = cfg.d_model, cfg.lru_dim
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    # Lambda init so a ~ U[0.9, 0.999] at r=1 (griffin init)
    u = jax.random.uniform(k6, (W,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))      # softplus^-1(-log(u)/c)
    return {
        "w_y": dense_init(k1, d, W, dtype),
        "w_x": dense_init(k2, d, W, dtype),
        "conv": conv1d_init(k3, cfg.conv_width, W, dtype),
        "w_a": dense_init(k4, W, W, dtype, bias=True),
        "w_i": dense_init(k5, W, W, dtype, bias=True),
        "Lambda": lam.astype(jnp.float32),
        "w_o": dense_init(jax.random.fold_in(key, 7), W, d, dtype),
    }


def _gates(p: Params, u: Array):
    r = jax.nn.sigmoid(dense(p["w_a"], u).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(p["w_i"], u).astype(jnp.float32))
    log_a = -_C * expand_left(jax.nn.softplus(p["Lambda"]), r.ndim) * r
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * u.astype(jnp.float32))
    return a, gated_in


def rglru_forward(cfg: ModelConfig, p: Params, x: Array,
                  state: "LRUState | None" = None) -> Tuple[Array, "LRUState"]:
    """x: (B, S, d) -> (out, new_state)."""
    B, S, d = x.shape
    y = jax.nn.gelu(dense(p["w_y"], x))
    ux = dense(p["w_x"], x)
    if state is None:
        state = init_lru_state(cfg, B, x.dtype)
    u = causal_conv1d(p["conv"], ux, left_context=state.conv_buf)
    tail_src = jnp.concatenate([state.conv_buf, ux], axis=1)
    new_buf = tail_src[:, -(cfg.conv_width - 1):, :]

    a, b = _gates(p, u)                        # (B, S, W) fp32
    # fold the initial state into the first step: b_1 += a_1 * h0
    b = b.at[:, 0, :].add(a[:, 0, :] * state.h)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h_final = h[:, -1, :]
    out = dense(p["w_o"], (h * y.astype(jnp.float32)).astype(x.dtype))
    return out, LRUState(h=h_final, conv_buf=new_buf)


def init_lru_state(cfg: ModelConfig, B: int, dtype) -> LRUState:
    return LRUState(
        h=jnp.zeros((B, cfg.lru_dim), jnp.float32),
        conv_buf=jnp.zeros((B, cfg.conv_width - 1, cfg.lru_dim), dtype),
    )


def rglru_decode(cfg: ModelConfig, p: Params, x_t: Array,
                 state: LRUState) -> Tuple[Array, LRUState]:
    """x_t: (B, 1, d) single-token step."""
    B = x_t.shape[0]
    y = jax.nn.gelu(dense(p["w_y"], x_t[:, 0, :]))
    ux = dense(p["w_x"], x_t[:, 0, :])
    buf, u = conv1d_step(p["conv"], state.conv_buf, ux)

    a, b = _gates(p, u)                        # (B, W)
    h = a * state.h + b
    out = dense(p["w_o"], (h * y.astype(jnp.float32)).astype(x_t.dtype))
    return out[:, None, :], LRUState(h=h, conv_buf=buf)
