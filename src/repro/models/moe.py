"""Mixture-of-Experts block (top-k routing, expert-parallel friendly).

Mesh-TensorFlow-style dense dispatch: tokens are routed to experts via
one-hot dispatch/combine einsums with a fixed per-expert capacity, so
all shapes are static and the expert dimension shards cleanly over the
"model" mesh axis (64/16 = 4 or 32/16 = 2 experts per shard).  Under
GSPMD the dispatch einsum lowers to an all-to-all over the expert axis
— exactly the communication pattern expert parallelism requires.

Aux losses: Switch-style load-balance loss + router z-loss.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import constrain, dense, dense_init

Array = jnp.ndarray
Params = Dict[str, Array]


def moe_init(key, cfg: ModelConfig, dtype) -> Params:
    d, dff, E = cfg.d_model, cfg.expert_ff, cfg.n_experts
    kr, ki, kg, ko = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(dff)
    return {
        "router": dense_init(kr, d, E, dtype),
        # stacked expert weights: (E, d, dff) / (E, dff, d)
        "wi": (jax.random.normal(ki, (E, d, dff)) * s_in).astype(dtype),
        "wg": (jax.random.normal(kg, (E, d, dff)) * s_in).astype(dtype),
        "wo": (jax.random.normal(ko, (E, dff, d)) * s_out).astype(dtype),
    }


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    cap = int(math.ceil(tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(cap, cfg.top_k)


def moe_forward_einsum(cfg: ModelConfig, p: Params, x: Array) -> Tuple[Array, Array]:
    """Mesh-TF-style one-hot dispatch (REFERENCE implementation).

    Cost of the dispatch/combine einsums is O(T * E * C * d), which at
    32k-token prefill dwarfs the expert FLOPs by >100x (measured in
    EXPERIMENTS.md §Perf, olmoe x prefill_32k baseline).  Kept as the
    semantic oracle; production path is the scatter-based
    ``moe_forward`` below.
    x: (B, S, d) -> (out, aux_loss)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, d)

    logits = dense(p["router"], xt).astype(jnp.float32)        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)

    gate_vals, expert_idx = jax.lax.top_k(probs, K)             # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)       # renormalize top-k

    C = _capacity(T, cfg)
    # position of each (token, k) assignment within its expert's capacity
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)     # (T, K, E)
    flat = onehot.reshape(T * K, E)
    pos_in_expert = jnp.cumsum(flat, axis=0) - flat             # (T*K, E)
    pos = jnp.sum(pos_in_expert * flat, axis=-1).reshape(T, K)  # (T, K)
    keep = pos < C

    # dispatch tensor: (T, E, C)
    disp = (
        jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)[..., None]
        * jax.nn.one_hot(jnp.where(keep, pos, 0), C, dtype=jnp.float32)[:, :, None, :]
        * keep[..., None, None]
    ).sum(axis=1)                                               # (T, E, C)
    comb = (
        jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)[..., None]
        * jax.nn.one_hot(jnp.where(keep, pos, 0), C, dtype=jnp.float32)[:, :, None, :]
        * (gate_vals * keep)[..., None, None]
    ).sum(axis=1)                                               # (T, E, C)

    xin = jnp.einsum("td,tec->ecd", xt.astype(jnp.float32), disp).astype(x.dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, p["wg"])) * jnp.einsum(
        "ecd,edf->ecf", xin, p["wi"])
    eout = jnp.einsum("ecf,efd->ecd", h, p["wo"])               # (E, C, d)
    out = jnp.einsum("ecd,tec->td", eout.astype(jnp.float32), comb)

    # Switch load-balance loss: E * sum_e f_e * P_e  (see below)
    assign_frac = jnp.mean(
        (jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32)), axis=0)
    router_prob = jnp.mean(probs, axis=0)
    lb_loss = E * jnp.sum(assign_frac * router_prob)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = cfg.router_aux_coef * (lb_loss + 1e-3 * z_loss)

    return out.reshape(B, S, d).astype(x.dtype), aux


def moe_forward_dense(cfg: ModelConfig, p: Params, x: Array) -> Tuple[Array, Array]:
    """Capacity-free MoE: every expert runs on every token; outputs are
    combined with the (sparse) top-k gates.  Exact (no token dropping),
    used for decode where T is small and train/decode numerical parity
    matters.  FLOP cost is E/K times the routed path — a documented
    hillclimb target (gather-based top-k decode) in EXPERIMENTS.md §Perf.
    """
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, d)

    logits = dense(p["router"], xt).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    gates = jax.vmap(lambda i, v: jnp.zeros((E,), jnp.float32).at[i].set(v))(
        expert_idx, gate_vals)                              # (T, E) sparse

    h = jax.nn.silu(jnp.einsum("td,edf->etf", xt, p["wg"])) * jnp.einsum(
        "td,edf->etf", xt, p["wi"])
    eout = jnp.einsum("etf,efd->etd", h, p["wo"])           # (E, T, d)
    out = jnp.einsum("etd,te->td", eout.astype(jnp.float32), gates)

    aux = jnp.zeros((), jnp.float32)
    return out.reshape(B, S, d).astype(x.dtype), aux


def _router(cfg: ModelConfig, p: Params, xt: Array):
    logits = dense(p["router"], xt).astype(jnp.float32)        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, cfg.top_k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    return logits, probs, gate_vals, expert_idx


def _aux_loss(cfg: ModelConfig, logits, probs, expert_idx):
    E = cfg.n_experts
    assign_frac = jnp.mean(
        jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32), axis=0)
    router_prob = jnp.mean(probs, axis=0)
    lb_loss = E * jnp.sum(assign_frac * router_prob)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return cfg.router_aux_coef * (lb_loss + 1e-3 * z_loss)


def moe_forward_scatter(cfg: ModelConfig, p: Params, x: Array) -> Tuple[Array, Array]:
    """Scatter/gather (sort-free) MoE dispatch (§Perf iterations 1-3).

    §Perf hillclimb change (EXPERIMENTS.md, olmoe x prefill_32k):
    replaces the O(T*E*C*d) one-hot dispatch/combine einsums of the
    Mesh-TF formulation with O(T*K*d) scatter into per-expert capacity
    buffers and gather back.  Identical routing semantics (same top-k,
    same renormalized gates, same position-in-expert capacity dropping)
    — tests assert exact parity with ``moe_forward_einsum``.

    Under GSPMD the scatter into the (E, C, d) expert-sharded buffer
    lowers to the expert-parallel all-to-all.
    """
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, d)

    logits, probs, gate_vals, expert_idx = _router(cfg, p, xt)

    C = _capacity(T, cfg)
    flat_expert = expert_idx.reshape(T * K)
    # position of each (token, k) assignment within its expert, in
    # flattened (t, k)-major order — identical semantics to the einsum
    # reference's cumsum, but via a stable argsort: §Perf iteration 2
    # found XLA lowers an (T*K, E) cumsum to a quadratic reduce-window
    # (2.8e14 flops per block at 32k-token prefill).  Sort-based rank
    # is O(n log n).
    order = jnp.argsort(flat_expert, stable=True)               # (T*K,)
    sorted_e = flat_expert[order]
    group_start = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    pos_sorted = jnp.arange(T * K) - group_start[sorted_e]
    pos = jnp.zeros((T * K,), jnp.int32).at[order].set(
        pos_sorted.astype(jnp.int32))
    keep = pos < C
    dest = jnp.where(keep, flat_expert * C + pos, E * C)        # sentinel row

    # dispatch: scatter token activations into expert buffers.
    # (§Perf iteration 3 tried pinning buf/eout to expert-sharded specs;
    # REFUTED: GSPMD replicates data-dependent scatters and added a
    # 1.2 TB all-reduce.  The GSPMD-friendly layout is left to the
    # shard_map expert-parallel path; see EXPERIMENTS.md §Perf.)
    tok_of = jnp.repeat(jnp.arange(T), K)                       # (T*K,)
    buf = jnp.zeros((E * C + 1, d), x.dtype)
    buf = buf.at[dest].set(xt[tok_of])
    xin = buf[: E * C].reshape(E, C, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, p["wg"])) * jnp.einsum(
        "ecd,edf->ecf", xin, p["wi"])
    eout = jnp.einsum("ecf,efd->ecd", h, p["wo"])               # (E, C, d)

    # combine: gather expert outputs back to (T*K, d), weight, sum over K
    flat_out = jnp.concatenate(
        [eout.reshape(E * C, d), jnp.zeros((1, d), eout.dtype)], axis=0)
    per_assign = flat_out[dest]                                 # (T*K, d)
    w = (gate_vals.reshape(T * K) * keep).astype(jnp.float32)
    out = jnp.sum(
        (per_assign.astype(jnp.float32) * w[:, None]).reshape(T, K, d), axis=1)

    aux = _aux_loss(cfg, logits, probs, expert_idx)
    return out.reshape(B, S, d).astype(x.dtype), aux


def _positions_by_argsort(flat_expert: Array, E: int) -> Array:
    """Rank of each assignment within its expert (stable, flat order)."""
    n = flat_expert.shape[0]
    order = jnp.argsort(flat_expert, stable=True)
    sorted_e = flat_expert[order]
    group_start = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    pos_sorted = jnp.arange(n) - group_start[sorted_e]
    return jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))


def moe_forward(cfg: ModelConfig, p: Params, x: Array) -> Tuple[Array, Array]:
    """Grouped einsum dispatch — the production path (§Perf iteration 4).

    Tokens are split into groups of ``moe_group_size`` with a per-group
    capacity C_g = ceil(G*K/E * capacity_factor).  Dispatch/combine are
    one-hot einsums like the Mesh-TF reference, but the cost
    T*E*C_g*d is ~4000x smaller than the global-capacity version
    (C_g = 40 vs C = 164k at 32k-token prefill), and — unlike the
    scatter formulation of iterations 1-3 — GSPMD reshards einsum
    outputs with a clean expert-parallel all-to-all instead of
    replicating buffers.  Positions use the argsort rank (iteration 2).

    Capacity is enforced PER GROUP (standard practice; groups align
    with the data sharding so dropping decisions are shard-local).
    With moe_group_size >= T this is exactly the einsum reference.
    """
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    G = min(cfg.moe_group_size, T)
    if T % G != 0:           # pad tokens to a group multiple
        pad = G - T % G
        xt = jnp.concatenate(
            [x.reshape(T, d), jnp.zeros((pad, d), x.dtype)], axis=0)
    else:
        pad = 0
        xt = x.reshape(T, d)
    Tp = T + pad
    g = Tp // G

    logits, probs, gate_vals, expert_idx = _router(cfg, p, xt)
    if pad:
        # padded tokens get zero gates (their expert choice is irrelevant)
        gate_vals = gate_vals * (jnp.arange(Tp) < T)[:, None]

    Cg = max(int(math.ceil(G * K / E * cfg.capacity_factor)), K)
    ei_g = expert_idx.reshape(g, G * K)                          # per group
    pos = jax.vmap(lambda fe: _positions_by_argsort(fe, E))(ei_g)
    pos = pos.reshape(g, G, K)
    keep = pos < Cg
    ei = expert_idx.reshape(g, G, K)
    gv = gate_vals.reshape(g, G, K)

    # (g, G, E, Cg) one-hot dispatch / combine
    e_oh = jax.nn.one_hot(ei, E, dtype=x.dtype)                  # (g,G,K,E)
    c_oh = jax.nn.one_hot(jnp.where(keep, pos, 0), Cg, dtype=x.dtype)
    disp = jnp.einsum("gtke,gtkc->gtec",
                      e_oh * keep[..., None], c_oh)              # (g,G,E,Cg)
    comb = jnp.einsum("gtke,gtkc->gtec",
                      e_oh * (gv * keep).astype(x.dtype)[..., None], c_oh)

    xg = xt.reshape(g, G, d)
    xin = jnp.einsum("gtd,gtec->gecd", xg, disp)                 # (g,E,Cg,d)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xin, p["wg"])) * jnp.einsum(
        "gecd,edf->gecf", xin, p["wi"])
    eout = jnp.einsum("gecf,efd->gecd", h, p["wo"])              # (g,E,Cg,d)
    out = jnp.einsum("gecd,gtec->gtd", eout, comb)

    out = out.reshape(Tp, d)[:T]
    aux = _aux_loss(cfg, logits, probs, expert_idx)
    return out.reshape(B, S, d).astype(x.dtype), aux
