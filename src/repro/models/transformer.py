"""Decoder-only LM assembly covering dense / MoE / SSM / hybrid / VLM.

Layers are organized into **stages**: each stage is a ``lax.scan`` over
``repeats`` copies of a pattern *unit* (one block for uniform archs;
("rglru","rglru","attn") for recurrentgemma).  Scanning keeps the HLO
size O(unit) instead of O(depth) — essential for the 40-combination
dry-run compile matrix.

Three entry points per model:
  forward_lm   — full-sequence logits (+ MoE aux loss)    [train]
  prefill      — full-sequence forward that also fills per-layer caches
  decode_step  — one token against the caches             [serve]
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import attention as attn
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import ssm as ssm_mod
from .config import ModelConfig
from .layers import dense, dense_init, embed, embed_init, mlp, mlp_init, norm_apply, norm_init

Array = jnp.ndarray
Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Block init / forward / decode
# ---------------------------------------------------------------------------


def block_init(key, cfg: ModelConfig, kind: str) -> Params:
    dt = _dtype(cfg)
    d = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if kind == "attn":
        return {
            "norm1": norm_init(cfg.norm_kind, d, dt),
            "attn": attn.attn_init(k1, cfg, dt),
            "norm2": norm_init(cfg.norm_kind, d, dt),
            "mlp": mlp_init(k2, d, cfg.d_ff, dt, cfg.act),
        }
    if kind == "moe":
        return {
            "norm1": norm_init(cfg.norm_kind, d, dt),
            "attn": attn.attn_init(k1, cfg, dt),
            "norm2": norm_init(cfg.norm_kind, d, dt),
            "moe": moe_mod.moe_init(k2, cfg, dt),
        }
    if kind == "ssm":
        return {
            "norm1": norm_init(cfg.norm_kind, d, dt),
            "ssm": ssm_mod.ssm_init(k1, cfg, dt),
        }
    if kind == "rglru":
        return {
            "norm1": norm_init(cfg.norm_kind, d, dt),
            "rglru": rglru_mod.rglru_init(k1, cfg, dt),
            "norm2": norm_init(cfg.norm_kind, d, dt),
            "mlp": mlp_init(k2, d, cfg.d_ff, dt, cfg.act),
        }
    raise ValueError(kind)


def _attn_window(cfg: ModelConfig) -> int:
    return cfg.window


def block_forward(cfg: ModelConfig, kind: str, p: Params, x: Array,
                  positions: Optional[Array]) -> Tuple[Array, Array]:
    """Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    eps = cfg.norm_eps
    if kind in ("attn", "moe"):
        h = norm_apply(cfg.norm_kind, p["norm1"], x, eps)
        if cfg.attn_kind == "mla":
            a = attn.mla_forward(cfg, p["attn"], h, positions,
                                 window=_attn_window(cfg))
        else:
            a = attn.gqa_forward(cfg, p["attn"], h, positions,
                                 window=_attn_window(cfg))
        x = x + a
        h = norm_apply(cfg.norm_kind, p["norm2"], x, eps)
        if kind == "attn":
            x = x + mlp(p["mlp"], h, cfg.act)
        else:
            mo, aux = moe_mod.moe_forward(cfg, p["moe"], h)
            x = x + mo
        return x, aux
    if kind == "ssm":
        h = norm_apply(cfg.norm_kind, p["norm1"], x, eps)
        y, _ = ssm_mod.ssm_forward(cfg, p["ssm"], h)
        return x + y, aux
    if kind == "rglru":
        h = norm_apply(cfg.norm_kind, p["norm1"], x, eps)
        y, _ = rglru_mod.rglru_forward(cfg, p["rglru"], h)
        x = x + y
        h = norm_apply(cfg.norm_kind, p["norm2"], x, eps)
        return x + mlp(p["mlp"], h, cfg.act), aux
    raise ValueError(kind)


def block_cache_init(cfg: ModelConfig, kind: str, B: int, length: int, dtype):
    if kind in ("attn", "moe"):
        L = min(length, cfg.window) if cfg.window > 0 else length
        if cfg.attn_kind == "mla":
            return attn.init_mla_cache(cfg, B, L, dtype)
        return attn.init_kv_cache(cfg, B, L, dtype)
    if kind == "ssm":
        return ssm_mod.init_ssm_state(cfg, B, dtype)
    if kind == "rglru":
        return rglru_mod.init_lru_state(cfg, B, dtype)
    raise ValueError(kind)


def block_prefill(cfg: ModelConfig, kind: str, p: Params, cache, x: Array,
                  positions: Optional[Array]) -> Tuple[Array, Any, Array]:
    """Full-sequence forward that also fills this block's cache.
    Returns (x, cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    eps = cfg.norm_eps
    B, S, _ = x.shape
    if kind in ("attn", "moe"):
        h = norm_apply(cfg.norm_kind, p["norm1"], x, eps)
        if cfg.attn_kind == "mla":
            a, new_cache = _mla_prefill(cfg, p["attn"], h, positions, cache)
        else:
            a, kv = attn.gqa_forward(cfg, p["attn"], h, positions,
                                     window=_attn_window(cfg), return_kv=True)
            new_cache = _fill_kv_cache(cfg, cache, kv, S)
        x = x + a
        h = norm_apply(cfg.norm_kind, p["norm2"], x, eps)
        if kind == "attn":
            x = x + mlp(p["mlp"], h, cfg.act)
        else:
            mo, aux = moe_mod.moe_forward(cfg, p["moe"], h)
            x = x + mo
        return x, new_cache, aux
    if kind == "ssm":
        h = norm_apply(cfg.norm_kind, p["norm1"], x, eps)
        y, new_state = ssm_mod.ssm_forward(cfg, p["ssm"], h, cache)
        return x + y, new_state, aux
    if kind == "rglru":
        h = norm_apply(cfg.norm_kind, p["norm1"], x, eps)
        y, new_state = rglru_mod.rglru_forward(cfg, p["rglru"], h, cache)
        x = x + y
        h = norm_apply(cfg.norm_kind, p["norm2"], x, eps)
        return x + mlp(p["mlp"], h, cfg.act), new_state, aux
    raise ValueError(kind)


def _fill_kv_cache(cfg: ModelConfig, cache: attn.KVCache, kv, S: int) -> attn.KVCache:
    k, v = kv                                  # (B, S, K, hd)
    L = cache.length
    if cfg.window > 0 and S > L:
        # ring layout: token position p lives at slot p % L
        take = k[:, S - L:], v[:, S - L:]
        pos = jnp.arange(S - L, S, dtype=jnp.int32)
        slots = pos % L
        order = jnp.argsort(slots)
        ck = cache.k.at[:, slots[order]].set(take[0][:, order])
        cv = cache.v.at[:, slots[order]].set(take[1][:, order])
        spos = cache.slot_pos.at[slots[order]].set(pos[order])
        return attn.KVCache(k=ck, v=cv, slot_pos=spos)
    ck = lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype), (0, 0, 0, 0))
    cv = lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype), (0, 0, 0, 0))
    pos = jnp.arange(L, dtype=jnp.int32)
    spos = jnp.where(pos < S, pos, -1)
    return attn.KVCache(k=ck, v=cv, slot_pos=spos)


def _mla_prefill(cfg: ModelConfig, p: Params, h: Array, positions, cache):
    B, S, _ = h.shape
    pos = positions if positions is not None else attn._positions_default(B, S)
    a = attn.mla_forward(cfg, p, h, pos, window=_attn_window(cfg))
    # recompute the latent stream for the cache (cheap projections)
    from .layers import rmsnorm
    c = rmsnorm(p["kv_norm"], dense(p["w_dkv"], h), cfg.norm_eps)
    k_rope = dense(p["w_kr"], h).reshape(B, S, 1, cfg.mla_rope_dim)
    from .layers import apply_rope
    k_rope = apply_rope(k_rope, pos, cfg.rope_theta).reshape(B, S, cfg.mla_rope_dim)
    L = cache.c.shape[1]
    cc = lax.dynamic_update_slice(cache.c, c.astype(cache.c.dtype), (0, 0, 0))
    ckr = lax.dynamic_update_slice(cache.k_rope, k_rope.astype(cache.k_rope.dtype), (0, 0, 0))
    posL = jnp.arange(L, dtype=jnp.int32)
    spos = jnp.where(posL < S, posL, -1)
    return a, attn.MLACache(c=cc, k_rope=ckr, slot_pos=spos)


def block_decode(cfg: ModelConfig, kind: str, p: Params, cache, x_t: Array,
                 pos: Array) -> Tuple[Array, Any]:
    eps = cfg.norm_eps
    if kind in ("attn", "moe"):
        h = norm_apply(cfg.norm_kind, p["norm1"], x_t, eps)
        if cfg.attn_kind == "mla":
            a, new_cache = attn.mla_decode(cfg, p["attn"], h, pos, cache,
                                           window=_attn_window(cfg))
        else:
            a, new_cache = attn.gqa_decode(cfg, p["attn"], h, pos, cache,
                                           window=_attn_window(cfg))
        x_t = x_t + a
        h = norm_apply(cfg.norm_kind, p["norm2"], x_t, eps)
        if kind == "attn":
            x_t = x_t + mlp(p["mlp"], h, cfg.act)
        else:
            mo, _ = moe_mod.moe_forward_dense(cfg, p["moe"], h)
            x_t = x_t + mo
        return x_t, new_cache
    if kind == "ssm":
        h = norm_apply(cfg.norm_kind, p["norm1"], x_t, eps)
        y, new_state = ssm_mod.ssm_decode(cfg, p["ssm"], h, cache)
        return x_t + y, new_state
    if kind == "rglru":
        h = norm_apply(cfg.norm_kind, p["norm1"], x_t, eps)
        y, new_state = rglru_mod.rglru_decode(cfg, p["rglru"], h, cache)
        x_t = x_t + y
        h = norm_apply(cfg.norm_kind, p["norm2"], x_t, eps)
        return x_t + mlp(p["mlp"], h, cfg.act), new_state
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------


def init_lm(key, cfg: ModelConfig) -> Params:
    dt = _dtype(cfg)
    ke, kh, ks = jax.random.split(key, 3)
    params: Params = {
        "embed": embed_init(ke, cfg.padded_vocab, cfg.d_model, dt),
        "final_norm": norm_init(cfg.norm_kind, cfg.d_model, dt),
        "stages": [],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(kh, cfg.d_model, cfg.padded_vocab, dt)

    for si, (unit, repeats) in enumerate(cfg.stages):
        def init_unit(k):
            ks = jax.random.split(k, len(unit))
            return {f"b{j}": block_init(ks[j], cfg, kind)
                    for j, kind in enumerate(unit)}
        keys = jax.random.split(jax.random.fold_in(ks, si), repeats)
        params["stages"].append(jax.vmap(init_unit)(keys))
    return params


# ---------------------------------------------------------------------------
# Forward (train)
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg: ModelConfig, tokens: Optional[Array],
                  embeds: Optional[Array]) -> Array:
    parts = []
    if embeds is not None:
        parts.append(embeds.astype(_dtype(cfg)))
    if tokens is not None:
        parts.append(embed(params["embed"], tokens))
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)


def forward_lm(params: Params, cfg: ModelConfig, tokens: Optional[Array],
               embeds: Optional[Array] = None,
               positions: Optional[Array] = None) -> Tuple[Array, Array]:
    """Returns (logits over padded_vocab, aux_loss)."""
    x = _embed_inputs(params, cfg, tokens, embeds)
    aux_total = jnp.zeros((), jnp.float32)

    for (unit, repeats), stage_p in zip(cfg.stages, params["stages"]):
        def body(carry, unit_p):
            x, aux = carry
            for j, kind in enumerate(unit):
                x, a = block_forward(cfg, kind, unit_p[f"b{j}"], x, positions)
                aux = aux + a
            return (x, aux), None
        if cfg.remat:
            if cfg.remat_policy == "dots":
                body = jax.checkpoint(
                    body,
                    policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
            else:
                body = jax.checkpoint(body)
        (x, aux_total), _ = lax.scan(body, (x, aux_total), stage_p,
                                     unroll=repeats if cfg.unroll_scan else 1)

    x = norm_apply(cfg.norm_kind, params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T
    else:
        logits = dense(params["lm_head"], x)
    return logits, aux_total


def lm_loss(params: Params, cfg: ModelConfig, tokens: Array, labels: Array,
            embeds: Optional[Array] = None) -> Array:
    """Cross-entropy over the true vocab (padded columns masked), mean
    per token; MoE aux added.  With embeds (VLM/audio prefix), loss is
    computed only on the trailing token positions."""
    logits, aux = forward_lm(params, cfg, tokens, embeds)
    if embeds is not None:
        logits = logits[:, -labels.shape[1]:, :]
    # §Perf: mask padded vocab columns with an ADDITIVE bias fused into
    # the fp32 upcast (one full-size intermediate instead of two).
    pad_bias = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab, 0.0, -1e30)
    logits = logits.astype(jnp.float32) + pad_bias[None, None, :]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold) + aux


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------


def init_caches(cfg: ModelConfig, B: int, length: int, dtype=None):
    """Stacked per-stage caches matching params['stages'] structure."""
    dt = dtype or _dtype(cfg)
    caches = []
    for unit, repeats in cfg.stages:
        one = {f"b{j}": block_cache_init(cfg, kind, B, length, dt)
               for j, kind in enumerate(unit)}
        caches.append(jax.tree.map(
            lambda l: jnp.broadcast_to(l, (repeats,) + l.shape).copy() if hasattr(l, "shape") else l,
            one))
    return caches


def prefill(params: Params, cfg: ModelConfig, tokens: Optional[Array],
            caches, embeds: Optional[Array] = None,
            positions: Optional[Array] = None):
    """Full-sequence forward filling the caches.  Returns
    (last-token logits, new_caches)."""
    x = _embed_inputs(params, cfg, tokens, embeds)

    new_caches = []
    for (unit, repeats), stage_p, stage_c in zip(cfg.stages, params["stages"], caches):
        def body(x, scanned):
            unit_p, unit_c = scanned
            new_c = {}
            for j, kind in enumerate(unit):
                x, nc, _ = block_prefill(cfg, kind, unit_p[f"b{j}"],
                                         unit_c[f"b{j}"], x, positions)
                new_c[f"b{j}"] = nc
            return x, new_c
        x, nc = lax.scan(body, x, (stage_p, stage_c),
                         unroll=repeats if cfg.unroll_scan else 1)
        new_caches.append(nc)

    x = norm_apply(cfg.norm_kind, params["final_norm"], x[:, -1:, :], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T
    else:
        logits = dense(params["lm_head"], x)
    return logits, new_caches


def decode_step(params: Params, cfg: ModelConfig, caches, token: Array,
                pos: Array):
    """token: (B, 1) int32; pos: scalar int32 absolute position.
    Returns (logits (B, 1, V), new_caches)."""
    x = embed(params["embed"], token)

    new_caches = []
    for (unit, repeats), stage_p, stage_c in zip(cfg.stages, params["stages"], caches):
        def body(x, scanned):
            unit_p, unit_c = scanned
            new_c = {}
            for j, kind in enumerate(unit):
                x, nc = block_decode(cfg, kind, unit_p[f"b{j}"],
                                     unit_c[f"b{j}"], x, pos)
                new_c[f"b{j}"] = nc
            return x, new_c
        x, nc = lax.scan(body, x, (stage_p, stage_c),
                         unroll=repeats if cfg.unroll_scan else 1)
        new_caches.append(nc)

    x = norm_apply(cfg.norm_kind, params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T
    else:
        logits = dense(params["lm_head"], x)
    return logits, new_caches
