"""Shared neural building blocks (pure functional JAX)."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray
Params = Dict[str, Array]


def expand_left(v: Array, ndim: int) -> Array:
    """1-d parameter -> rank ``ndim`` with leading size-1 axes, so the
    broadcast is explicit (jax_numpy_rank_promotion='raise' bans the
    implicit ``(B, S, d) op (d,)`` form)."""
    return jnp.expand_dims(v, tuple(range(ndim - 1)))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def constrain(x: Array, spec) -> Array:
    """Apply a sharding constraint if tracing under a mesh; no-op on a
    bare single device (smoke tests / CPU examples)."""
    try:
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: Array, eps: float = 1e-6) -> Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    scale = expand_left(p["scale"].astype(jnp.float32), out.ndim)
    return (out * scale).astype(x.dtype)


def layernorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: Array, eps: float = 1e-6) -> Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    out = (out * expand_left(p["scale"].astype(jnp.float32), out.ndim)
           + expand_left(p["bias"].astype(jnp.float32), out.ndim))
    return out.astype(x.dtype)


def norm_init(kind: str, d: int, dtype) -> Params:
    return rmsnorm_init(d, dtype) if kind == "rmsnorm" else layernorm_init(d, dtype)


def norm_apply(kind: str, p: Params, x: Array, eps: float) -> Array:
    return rmsnorm(p, x, eps) if kind == "rmsnorm" else layernorm(p, x, eps)


# ---------------------------------------------------------------------------
# Dense / embeddings
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype, bias: bool = False,
               scale: Optional[float] = None) -> Params:
    s = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * s).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: Array) -> Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + expand_left(p["b"], y.ndim)
    return y


def embed_init(key, vocab: int, d: int, dtype) -> Params:
    return {"table": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def embed(p: Params, tokens: Array) -> Array:
    return jnp.take(p["table"], tokens, axis=0)


# ---------------------------------------------------------------------------
# Positional encodings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = (positions[..., None].astype(jnp.float32)
              * expand_left(freqs, positions.ndim + 1))  # (..., S, hd/2)
    angles = angles[..., None, :]                       # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, positions3: Array, theta: float,
                sections: Tuple[int, ...]) -> Array:
    """Multimodal RoPE (Qwen2-VL): the rotary half-dims are split into
    (t, h, w) sections, each rotated by its own position stream.

    x: (B, S, H, hd); positions3: (3, B, S); sum(sections) == hd // 2.
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    # select which of the 3 position streams drives each frequency slot
    sel = jnp.repeat(
        jnp.arange(len(sections)), jnp.asarray(sections), total_repeat_length=hd // 2
    )                                                   # (hd/2,) in {0,1,2}
    pos = jnp.take(positions3, sel, axis=0)             # (hd/2, B, S) -> via take on axis 0
    pos = jnp.moveaxis(pos, 0, -1)                      # (B, S, hd/2)
    angles = pos.astype(jnp.float32) * freqs[None, None, :]  # (B, S, hd/2)
    angles = angles[..., None, :]                       # (B, S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(seq_len: int, d: int, dtype) -> Array:
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    i = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    angles = pos / (10_000.0 ** (2 * i / d))
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_init(key, d: int, d_ff: int, dtype, act: str = "silu_glu") -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    if act == "silu_glu":
        return {
            "wi": dense_init(k1, d, d_ff, dtype),
            "wg": dense_init(k2, d, d_ff, dtype),
            "wo": dense_init(k3, d_ff, d, dtype),
        }
    return {
        "wi": dense_init(k1, d, d_ff, dtype, bias=True),
        "wo": dense_init(k2, d_ff, d, dtype, bias=True),
    }


def mlp(p: Params, x: Array, act: str = "silu_glu") -> Array:
    if act == "silu_glu":
        h = jax.nn.silu(dense(p["wg"], x)) * dense(p["wi"], x)
    else:
        h = jax.nn.gelu(dense(p["wi"], x))
    return dense(p["wo"], h)


# ---------------------------------------------------------------------------
# Conv1d (causal, depthwise) — mamba2 / rglru frontends
# ---------------------------------------------------------------------------


def conv1d_init(key, width: int, channels: int, dtype) -> Params:
    return {
        "w": (jax.random.normal(key, (width, channels)) / jnp.sqrt(width)).astype(dtype),
        "b": jnp.zeros((channels,), dtype),
    }


def causal_conv1d(p: Params, x: Array, left_context: Optional[Array] = None) -> Array:
    """x: (B, S, C) depthwise causal conv.  ``left_context``: (B, width-1, C)
    preceding inputs (zeros if None) — enables exact chunked prefill."""
    width = p["w"].shape[0]
    if left_context is None:
        pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([left_context.astype(x.dtype), x], axis=1)
    out = sum(
        pad[:, i : i + x.shape[1], :] * p["w"][i][None, None, :]
        for i in range(width)
    )
    return out + p["b"][None, None, :]


def conv1d_step(p: Params, buf: Array, x_t: Array) -> Tuple[Array, Array]:
    """Single decode step.  buf: (B, width-1, C) past inputs."""
    width = p["w"].shape[0]
    window = jnp.concatenate([buf, x_t[:, None, :]], axis=1)   # (B, width, C)
    out = jnp.einsum("bwc,wc->bc", window, p["w"]) + p["b"][None, :]
    return window[:, 1:, :], out
