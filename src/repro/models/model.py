"""Unified model API over decoder-only and encoder-decoder families.

    api = build(cfg)
    params = api.init(key)
    loss   = api.loss(params, batch)            # train
    logits, caches = api.prefill(params, batch) # inference prefill
    logits, caches = api.decode(params, caches, token, pos)

``batch`` is a dict; which keys exist depends on the arch family:
  text LM:   tokens (B,S), labels (B,S)
  vlm:       embeds (B,S_img,d) + tokens (B,S_txt) + labels (B,S_txt)
  audio:     frames (B,F,d) + tokens (B,S) + labels (B,S)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from . import encdec, transformer
from .config import ModelConfig

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init: Callable[..., Any]
    loss: Callable[..., Array]
    forward: Callable[..., Any]
    init_caches: Callable[..., Any]
    prefill: Callable[..., Any]
    decode: Callable[..., Any]


def build(cfg: ModelConfig) -> ModelAPI:
    if cfg.is_encdec:
        return _build_encdec(cfg)
    return _build_decoder_only(cfg)


def _build_decoder_only(cfg: ModelConfig) -> ModelAPI:
    def init(key):
        return transformer.init_lm(key, cfg)

    def loss(params, batch):
        return transformer.lm_loss(params, cfg, batch["tokens"],
                                   batch["labels"], batch.get("embeds"))

    def forward(params, batch):
        return transformer.forward_lm(params, cfg, batch.get("tokens"),
                                      batch.get("embeds"))

    def init_caches(B, length, dtype=None):
        return transformer.init_caches(cfg, B, length, dtype)

    def prefill(params, batch, caches):
        return transformer.prefill(params, cfg, batch.get("tokens"), caches,
                                   batch.get("embeds"))

    def decode(params, caches, token, pos):
        return transformer.decode_step(params, cfg, caches, token, pos)

    return ModelAPI(cfg=cfg, init=init, loss=loss, forward=forward,
                    init_caches=init_caches, prefill=prefill, decode=decode)


def _build_encdec(cfg: ModelConfig) -> ModelAPI:
    def init(key):
        return encdec.init_encdec(key, cfg)

    def loss(params, batch):
        return encdec.encdec_loss(params, cfg, batch["frames"],
                                  batch["tokens"], batch["labels"])

    def forward(params, batch):
        enc = encdec.encode(params, cfg, batch["frames"])
        return encdec.decode_train(params, cfg, batch["tokens"], enc), jnp.zeros((), jnp.float32)

    def init_caches(B, length, dtype=None):
        return encdec.init_dec_caches(cfg, B, length, dtype)

    def prefill(params, batch, caches):
        return encdec.prefill_decoder(params, cfg, batch["frames"],
                                      batch["tokens"], caches)

    def decode(params, caches, token, pos):
        return encdec.decode_step_encdec(params, cfg, caches, token, pos)

    return ModelAPI(cfg=cfg, init=init, loss=loss, forward=forward,
                    init_caches=init_caches, prefill=prefill, decode=decode)


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))
