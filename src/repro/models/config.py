"""Architecture configuration for the model zoo.

One ``ModelConfig`` covers all six assigned architecture families:
dense / MoE / SSM / hybrid / VLM / audio.  Every field is static so the
config hashes into jit caches.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    arch_type: str = "dense"          # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab: int = 1000

    head_dim: int = 0                 # 0 -> d_model // n_heads
    norm_kind: str = "rmsnorm"        # rmsnorm | layernorm
    norm_eps: float = 1e-6
    act: str = "silu_glu"             # silu_glu | gelu (whisper)
    tie_embeddings: bool = False
    dtype: str = "float32"            # compute/param dtype
    vocab_pad: int = 256              # pad vocab to a multiple (sharding)

    # --- attention flavour -------------------------------------------------
    attn_kind: str = "gqa"            # gqa | mla | none
    qk_norm: bool = False             # qwen3
    qkv_bias: bool = False            # qwen2/2.5
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (t, h, w) halves
    window: int = 0                   # >0: sliding-window attention
    pos_kind: str = "rope"            # rope | sinusoidal | learned | none

    # --- MLA (minicpm3 / deepseek-style) -----------------------------------
    mla_q_lora: int = 0
    mla_kv_lora: int = 0
    mla_rope_dim: int = 0
    mla_nope_dim: int = 0
    mla_v_dim: int = 0

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    expert_ff: int = 0                # per-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_group_size: int = 256         # grouped-dispatch token group (§Perf)

    # --- SSM (mamba2 / SSD) --------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128
    ssm_groups: int = 1

    # --- hybrid (recurrentgemma / griffin) ----------------------------------
    # pattern of block kinds repeated through depth, e.g. ("rglru","rglru","attn")
    layer_pattern: Tuple[str, ...] = ()
    lru_width: int = 0                # 0 -> d_model
    conv_width: int = 4

    # --- encoder-decoder (whisper) -------------------------------------------
    encoder_layers: int = 0
    n_audio_frames: int = 1500

    # --- modality frontend (stub) --------------------------------------------
    frontend: str = "none"            # none | audio_stub | vision_stub
    vision_tokens: int = 0            # VLM: patch-embedding positions per sample

    # --- long-context variant -------------------------------------------------
    long_context_window: int = 4096   # window used when a dense arch runs 500k

    # --- training ---------------------------------------------------------------
    remat: bool = False               # activation checkpointing around each unit
    remat_policy: str = "full"        # full | dots  (dots: save matmul
                                      # outputs, recompute elementwise only)
    use_flash: bool = False           # fused Pallas flash-attention path
                                      # (TPU; interpret-mode on CPU tests)
    shard_activations: bool = False   # head-parallel attention constraints
                                      # (production mesh; no-op on 1 device)
    act_batch_axes: Tuple[str, ...] = ()  # mesh axes pinning the activation
                                      # batch dim (serve paths; empty under
                                      # the vmapped learner train path)
    unroll_scan: bool = False         # fully unroll layer scans (dry-run only:
                                      # XLA cost analysis counts while-loop
                                      # bodies once, so the roofline needs the
                                      # unrolled HLO for exact flops/collectives)

    # -------------------------------------------------------------------------
    def __post_init__(self):
        if self.arch_type not in ("dense", "moe", "ssm", "hybrid", "vlm", "audio"):
            raise ValueError(self.arch_type)
        if self.attn_kind not in ("gqa", "mla", "none"):
            raise ValueError(self.attn_kind)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        return round_up(self.vocab, self.vocab_pad)

    @property
    def pattern(self) -> Tuple[str, ...]:
        """Block-kind sequence of length n_layers."""
        if self.layer_pattern:
            unit = self.layer_pattern
            reps = (self.n_layers + len(unit) - 1) // len(unit)
            return tuple((unit * reps)[: self.n_layers])
        kind = {"moe": "moe", "ssm": "ssm"}.get(self.arch_type, "attn")
        return (kind,) * self.n_layers

    @property
    def stages(self) -> Tuple[Tuple[Tuple[str, ...], int], ...]:
        """Decompose the pattern into (unit, repeats) stages so each
        stage is a lax.scan over identically-structured units.  Uniform
        archs give one stage; recurrentgemma-9b (38 layers, unit of 3)
        gives [(unit, 12), (('rglru','rglru'), 1)]."""
        pat = self.pattern
        if not self.layer_pattern:
            return (((pat[0],), self.n_layers),)
        unit = self.layer_pattern
        full = len(pat) // len(unit)
        out = []
        if full:
            out.append((unit, full))
        rem = pat[full * len(unit):]
        if rem:
            out.append((tuple(rem), 1))
        return tuple(out)

    @property
    def d_inner(self) -> int:         # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def lru_dim(self) -> int:
        return self.lru_width or self.d_model

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def smoke(self) -> "ModelConfig":
        """Reduced variant of the same family: <=2 layers (plus pattern
        coverage), d_model<=256, <=4 experts — for CPU smoke tests."""
        n_layers = len(self.layer_pattern) or 2
        kw = dict(
            n_layers=max(n_layers, 2),
            d_model=256,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) or 4,
            head_dim=64,
            d_ff=512,
            vocab=512,
            dtype="float32",
            window=min(self.window, 32) if self.window else 0,
        )
        if self.mrope_sections:
            kw.update(mrope_sections=(8, 12, 12))   # sums to 64/2
        if self.n_experts:
            # capacity_factor high enough that the routed path drops no
            # tokens at smoke scale -> routed == dense numerics.
            kw.update(n_experts=4, top_k=2, expert_ff=128, capacity_factor=8.0)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=16)
        if self.mla_kv_lora:
            kw.update(mla_q_lora=64, mla_kv_lora=32, mla_rope_dim=16,
                      mla_nope_dim=32, mla_v_dim=32)
        if self.lru_width:
            kw.update(lru_width=256)
        if self.encoder_layers:
            kw.update(encoder_layers=2, n_audio_frames=16)
        if self.vision_tokens:
            kw.update(vision_tokens=8)
        return self.with_(**kw)


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (used for 6ND model-FLOPs and memory
    sanity; exact counts come from the initialized pytree)."""
    d, hd = cfg.d_model, cfg.hd
    emb = cfg.padded_vocab * d * (1 if cfg.tie_embeddings else 2)
    per_layer = 0
    for kind in cfg.pattern:
        if kind == "attn":
            if cfg.attn_kind == "mla":
                q = d * cfg.mla_q_lora + cfg.mla_q_lora * cfg.n_heads * (
                    cfg.mla_nope_dim + cfg.mla_rope_dim)
                kv = d * (cfg.mla_kv_lora + cfg.mla_rope_dim) + cfg.mla_kv_lora * (
                    cfg.n_heads * (cfg.mla_nope_dim + cfg.mla_v_dim))
                o = cfg.n_heads * cfg.mla_v_dim * d
                per_layer += q + kv + o
            else:
                per_layer += d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * d
            per_layer += 3 * d * cfg.d_ff
        elif kind == "moe":
            per_layer += d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * d
            per_layer += d * cfg.n_experts + cfg.n_experts * 3 * d * cfg.expert_ff
        elif kind == "ssm":
            din = cfg.d_inner
            proj_in = d * (2 * din + 2 * cfg.ssm_groups * cfg.ssm_state + cfg.ssm_heads)
            per_layer += proj_in + din * d + cfg.ssm_conv * (din + 2 * cfg.ssm_groups * cfg.ssm_state)
        elif kind == "rglru":
            w = cfg.lru_dim
            per_layer += d * w * 2 + w * d + 2 * w * w // 1 + cfg.conv_width * w  # proj + gates + conv
        per_layer += 2 * d  # norms
    total = emb + per_layer  # pattern already spans all layers
    if cfg.is_encdec:
        enc_layer = d * hd * 2 * (cfg.n_heads + cfg.n_kv_heads) + 2 * d * cfg.d_ff
        total += cfg.encoder_layers * enc_layer
    return total
