"""Attention variants: GQA (+qk_norm, bias, sliding window, M-RoPE),
MLA (multi-head latent attention), and cross-attention.

Projections use **merged head dims** (n_heads * head_dim) so tensor-
parallel sharding works even when the head count does not divide the
model-axis size (the merged dim is always a multiple of 128).

KV caches:
- full cache:   k/v (B, S_max, K, hd) + scalar position counter.
- ring cache:   sliding-window archs keep (B, window, K, hd) plus a
  per-slot position array; slots are overwritten mod window, masking is
  by stored position.  long_500k decode therefore allocates O(window),
  not O(524288), for windowed archs.
- MLA cache:    the compressed per-token latent (B, S, kv_lora) plus
  the shared rope key (B, S, rope_dim) — the cache-size reduction that
  motivates MLA.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import apply_mrope, apply_rope, dense, dense_init, rmsnorm

Array = jnp.ndarray
Params = Dict[str, Array]

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: Array            # (B, L, K, hd)  L = S_max or window
    v: Array            # (B, L, K, hd)
    slot_pos: Array     # (L,) int32 position stored in each slot (-1 empty)

    @property
    def length(self) -> int:
        return self.k.shape[1]


class MLACache(NamedTuple):
    c: Array            # (B, L, kv_lora)
    k_rope: Array       # (B, L, rope_dim)
    slot_pos: Array     # (L,)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def gqa_init(key, cfg: ModelConfig, dtype) -> Params:
    d, hd = cfg.d_model, cfg.hd
    kq, kk, kv, ko, kn = jax.random.split(key, 5)
    p = {
        "wq": dense_init(kq, d, cfg.n_heads * hd, dtype, bias=cfg.qkv_bias),
        "wk": dense_init(kk, d, cfg.n_kv_heads * hd, dtype, bias=cfg.qkv_bias),
        "wv": dense_init(kv, d, cfg.n_kv_heads * hd, dtype, bias=cfg.qkv_bias),
        "wo": dense_init(ko, cfg.n_heads * hd, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.ones((hd,), dtype)}
        p["k_norm"] = {"scale": jnp.ones((hd,), dtype)}
    return p


def mla_init(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    qk_dim = cfg.mla_nope_dim + cfg.mla_rope_dim
    keys = jax.random.split(key, 8)
    p = {
        "w_dq": dense_init(keys[0], d, cfg.mla_q_lora, dtype),
        "q_norm": {"scale": jnp.ones((cfg.mla_q_lora,), dtype)},
        "w_uq": dense_init(keys[1], cfg.mla_q_lora, cfg.n_heads * qk_dim, dtype),
        "w_dkv": dense_init(keys[2], d, cfg.mla_kv_lora, dtype),
        "kv_norm": {"scale": jnp.ones((cfg.mla_kv_lora,), dtype)},
        "w_kr": dense_init(keys[3], d, cfg.mla_rope_dim, dtype),
        "w_uk": dense_init(keys[4], cfg.mla_kv_lora, cfg.n_heads * cfg.mla_nope_dim, dtype),
        "w_uv": dense_init(keys[5], cfg.mla_kv_lora, cfg.n_heads * cfg.mla_v_dim, dtype),
        "wo": dense_init(keys[6], cfg.n_heads * cfg.mla_v_dim, d, dtype),
    }
    return p


def cross_init(key, cfg: ModelConfig, dtype) -> Params:
    d, hd = cfg.d_model, cfg.hd
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, d, cfg.n_heads * hd, dtype),
        "wk": dense_init(kk, d, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(kv, d, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ko, cfg.n_heads * hd, d, dtype),
    }


def attn_init(key, cfg: ModelConfig, dtype) -> Params:
    if cfg.attn_kind == "mla":
        return mla_init(key, cfg, dtype)
    return gqa_init(key, cfg, dtype)


# ---------------------------------------------------------------------------
# Core scaled-dot-product with GQA grouping
# ---------------------------------------------------------------------------


from .layers import constrain as _constrain


def _flash_sdpa(cfg: ModelConfig, q: Array, k: Array, v: Array,
                causal: bool) -> Array:
    """Fused flash-attention path (kernels/flash.py).  Repeats GQA kv
    heads, folds (B, H) into the kernel grid, pads S to the block size.
    Used on TPU for full-attention prefill/train; interpret mode makes
    it testable on CPU."""
    from repro.kernels.flash import flash_attention
    B, S, H, hd = q.shape
    K = k.shape[2]
    if K != H:
        k = jnp.repeat(k, H // K, axis=2)
        v = jnp.repeat(v, H // K, axis=2)
    bq = bk = min(128, S)
    Sp = ((S + bq - 1) // bq) * bq
    def fold(t):
        t = jnp.moveaxis(t, 2, 1).reshape(B * H, S, hd)
        if Sp != S:
            t = jnp.pad(t, ((0, 0), (0, Sp - S), (0, 0)))
        return t
    qf, kf, vf = fold(q), fold(k), fold(v)
    interp = jax.default_backend() != "tpu"
    o = flash_attention(qf, kf, vf, causal=causal, block_q=bq, block_k=bk,
                        interpret=interp)
    # NOTE on padding: with causal=True padded queries attend only to
    # padded keys (rows are dropped below); padded keys sit at positions
    # > any real query, so real rows are unaffected.  For non-causal,
    # padded keys would leak -> only reached when S % 128 == 0 or causal.
    if Sp != S:
        assert causal, "non-causal flash path requires S % block == 0"
        o = o[:, :S]
    return jnp.moveaxis(o.reshape(B, H, S, hd), 1, 2)


def _sdpa_grouped(q: Array, k: Array, v: Array, mask: Optional[Array],
                  scale: float) -> Array:
    """GQA-grouped SDPA (no kv repeat) — used for DECODE, where
    repeating kv heads would multiply the O(B*L) cache reads by the
    group size G (measured: 19 GB of all-gathers on qwen2.5-3b
    decode_32k with the flat-H path)."""
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, hd)
    logits = jnp.einsum("bskgh,blkh->bkgsl", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if mask is not None:
        if mask.ndim == 2:
            m = mask[None, None, None]
        else:
            m = mask.reshape((1, 1, 1) + mask.shape[-2:])
        logits = jnp.where(m, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgsl,blkh->bskgh", w, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


def _act_specs(cfg: ModelConfig):
    """(qkv_spec, logits_spec) for §Perf head-parallel attention, or
    (None, None) when activation sharding is off."""
    if not cfg.shard_activations:
        return None, None
    b = tuple(cfg.act_batch_axes) if cfg.act_batch_axes else None
    if b is not None and len(b) == 1:
        b = b[0]
    return (b, None, "model", None), (b, "model", None, None)


def _sdpa(q: Array, k: Array, v: Array, mask: Optional[Array], scale: float,
          specs=(None, None)) -> Array:
    """q: (B, S, H, hd); k/v: (B, L, K, hd); H = K * G.

    Flat-H formulation: kv heads are repeated to H before the einsums
    so every tensor carries the full head axis.  With
    ``shard_heads=True`` (§Perf hillclimb) the head axis is constrained
    to the "model" mesh axis — head-parallel attention.  Without it,
    GSPMD facing a merged-dim-sharded q must split the *contraction*
    (head_dim) and all-reduce the fp32 (S, L) logits — measured at
    1.7 TB per device for qwen3-14b prefill_32k (EXPERIMENTS.md §Perf).
    GQA head counts that do not divide the axis are padded by GSPMD.
    """
    qkv_spec, logits_spec = specs
    B, S, H, hd = q.shape
    K = k.shape[2]
    if K != H:
        k = jnp.repeat(k, H // K, axis=2)
        v = jnp.repeat(v, H // K, axis=2)
    if qkv_spec is not None:
        q = _constrain(q, qkv_spec)
        k = _constrain(k, qkv_spec)
        v = _constrain(v, qkv_spec)
    logits = jnp.einsum("bshd,blhd->bhsl", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if mask is not None:
        # mask comes in as (S, L) or (1,1,1,1,L)-style; normalize to
        # broadcast over (B, H, S, L)
        if mask.ndim == 2:
            m = mask[None, None]
        else:
            m = mask.reshape((1, 1) + mask.shape[-2:])
        logits = jnp.where(m, logits, NEG_INF)
    if logits_spec is not None:
        logits = _constrain(logits, logits_spec)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhsl,blhd->bshd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def causal_mask(S: int, L: int, q_offset: int = 0, window: int = 0) -> Array:
    """(S, L) boolean: query i (absolute pos q_offset+i) may see key j."""
    qpos = jnp.arange(S)[:, None] + q_offset
    kpos = jnp.arange(L)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m


# ---------------------------------------------------------------------------
# GQA forward (full sequence) and decode step
# ---------------------------------------------------------------------------


def _positions_default(B: int, S: int, offset: int = 0) -> Array:
    return jnp.broadcast_to(jnp.arange(S) + offset, (B, S))


def gqa_forward(
    cfg: ModelConfig,
    p: Params,
    x: Array,
    positions: Optional[Array] = None,
    *,
    causal: bool = True,
    window: int = 0,
    return_kv: bool = False,
):
    B, S, d = x.shape
    hd = cfg.hd
    q = dense(p["wq"], x).reshape(B, S, cfg.n_heads, hd)
    k = dense(p["wk"], x).reshape(B, S, cfg.n_kv_heads, hd)
    v = dense(p["wv"], x).reshape(B, S, cfg.n_kv_heads, hd)

    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)

    if cfg.pos_kind == "rope":
        if cfg.mrope_sections:
            if positions is None or positions.ndim == 2:
                base = positions if positions is not None else _positions_default(B, S)
                positions3 = jnp.broadcast_to(base[None], (3,) + base.shape)
            else:
                positions3 = positions
            q = apply_mrope(q, positions3, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions3, cfg.rope_theta, cfg.mrope_sections)
        else:
            pos = positions if positions is not None else _positions_default(B, S)
            q = apply_rope(q, pos, cfg.rope_theta)
            k = apply_rope(k, pos, cfg.rope_theta)

    if cfg.use_flash and window == 0:
        y = _flash_sdpa(cfg, q, k, v, causal)
    else:
        mask = causal_mask(S, S, 0, window) if causal else None
        y = _sdpa(q, k, v, mask, 1.0 / jnp.sqrt(hd).astype(jnp.float32),
                  specs=_act_specs(cfg))
    out = dense(p["wo"], y.reshape(B, S, cfg.n_heads * hd))
    if return_kv:
        return out, (k, v)
    return out


def init_kv_cache(cfg: ModelConfig, B: int, length: int, dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((B, length, cfg.n_kv_heads, cfg.hd), dtype),
        v=jnp.zeros((B, length, cfg.n_kv_heads, cfg.hd), dtype),
        slot_pos=-jnp.ones((length,), jnp.int32),
    )


def gqa_decode(
    cfg: ModelConfig,
    p: Params,
    x_t: Array,            # (B, 1, d)
    pos: Array,            # scalar int32 — absolute position of the new token
    cache: KVCache,
    *,
    window: int = 0,
) -> Tuple[Array, KVCache]:
    B, _, d = x_t.shape
    hd = cfg.hd
    q = dense(p["wq"], x_t).reshape(B, 1, cfg.n_heads, hd)
    k = dense(p["wk"], x_t).reshape(B, 1, cfg.n_kv_heads, hd)
    v = dense(p["wv"], x_t).reshape(B, 1, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)

    posb = jnp.broadcast_to(pos[None], (B, 1)) if pos.ndim == 0 else pos
    if cfg.pos_kind == "rope":
        if cfg.mrope_sections:
            pos3 = jnp.broadcast_to(posb[None], (3, B, 1))
            q = apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, posb, cfg.rope_theta)
            k = apply_rope(k, posb, cfg.rope_theta)

    L = cache.length
    slot = (pos % L).astype(jnp.int32) if window > 0 else pos.astype(jnp.int32)
    ck = jax.lax.dynamic_update_slice(cache.k, k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache.v, v, (0, slot, 0, 0))
    spos = cache.slot_pos.at[slot].set(pos.astype(jnp.int32))

    valid = (spos >= 0) & (spos <= pos)
    if window > 0:
        valid &= spos > pos - window
    mask = valid[None, None, None, :].reshape(1, 1, 1, -1)   # -> (1,1,1,L)

    # decode is one token: keep the GQA-grouped form (no kv repeat) and
    # no activation constraints — flat-H/head constraints only help
    # long-sequence scores and regress single-token decode (measured).
    y = _sdpa_grouped(q, ck, cv, mask, 1.0 / jnp.sqrt(hd).astype(jnp.float32))
    out = dense(p["wo"], y.reshape(B, 1, cfg.n_heads * hd))
    return out, KVCache(k=ck, v=cv, slot_pos=spos)


# ---------------------------------------------------------------------------
# MLA forward / decode
# ---------------------------------------------------------------------------


def _mla_qkv_full(cfg: ModelConfig, p: Params, x: Array, positions: Array):
    B, S, d = x.shape
    H = cfg.n_heads
    nope, rope_d, vd = cfg.mla_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim

    q_lat = rmsnorm(p["q_norm"], dense(p["w_dq"], x), cfg.norm_eps)
    q = dense(p["w_uq"], q_lat).reshape(B, S, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c = rmsnorm(p["kv_norm"], dense(p["w_dkv"], x), cfg.norm_eps)   # (B,S,kv_lora)
    k_rope = dense(p["w_kr"], x).reshape(B, S, 1, rope_d)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)

    k_nope = dense(p["w_uk"], c).reshape(B, S, H, nope)
    v = dense(p["w_uv"], c).reshape(B, S, H, vd)
    return q_nope, q_rope, k_nope, k_rope, v, c


def mla_forward(cfg: ModelConfig, p: Params, x: Array,
                positions: Optional[Array] = None, *, causal: bool = True,
                window: int = 0) -> Array:
    B, S, _ = x.shape
    pos = positions if positions is not None else _positions_default(B, S)
    q_nope, q_rope, k_nope, k_rope, v, _ = _mla_qkv_full(cfg, p, x, pos)
    scale = 1.0 / jnp.sqrt(float(cfg.mla_nope_dim + cfg.mla_rope_dim))
    logits = (
        jnp.einsum("bshd,blhd->bhsl", q_nope.astype(jnp.float32), k_nope.astype(jnp.float32))
        + jnp.einsum("bshd,blxd->bhsl", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32))
    ) * scale
    if causal:
        m = causal_mask(S, S, 0, window)
        logits = jnp.where(m[None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    y = jnp.einsum("bhsl,blhd->bshd", w, v.astype(jnp.float32)).astype(x.dtype)
    return dense(p["wo"], y.reshape(B, S, cfg.n_heads * cfg.mla_v_dim))


def init_mla_cache(cfg: ModelConfig, B: int, length: int, dtype) -> MLACache:
    return MLACache(
        c=jnp.zeros((B, length, cfg.mla_kv_lora), dtype),
        k_rope=jnp.zeros((B, length, cfg.mla_rope_dim), dtype),
        slot_pos=-jnp.ones((length,), jnp.int32),
    )


def mla_decode(cfg: ModelConfig, p: Params, x_t: Array, pos: Array,
               cache: MLACache, *, window: int = 0,
               absorbed: bool = True) -> Tuple[Array, MLACache]:
    """One-token MLA decode against the compressed latent cache.

    absorbed=True uses the matrix-absorption trick: scores are computed
    directly in latent space via q_nope' = q_nope @ W_uk (per head),
    and values are combined in latent space before a single W_uv
    up-projection — O(L * kv_lora) per token instead of
    O(L * H * (nope + v_dim)) for naive per-token reconstruction.
    The naive path is kept for oracle testing (absorbed=False).
    """
    B, _, d = x_t.shape
    H = cfg.n_heads
    nope, rope_d, vd, lora = (cfg.mla_nope_dim, cfg.mla_rope_dim,
                              cfg.mla_v_dim, cfg.mla_kv_lora)
    posb = jnp.broadcast_to(pos[None], (B, 1)) if pos.ndim == 0 else pos

    q_lat = rmsnorm(p["q_norm"], dense(p["w_dq"], x_t), cfg.norm_eps)
    q = dense(p["w_uq"], q_lat).reshape(B, 1, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, posb, cfg.rope_theta)

    c_t = rmsnorm(p["kv_norm"], dense(p["w_dkv"], x_t), cfg.norm_eps)
    k_rope_t = dense(p["w_kr"], x_t).reshape(B, 1, 1, rope_d)
    k_rope_t = apply_rope(k_rope_t, posb, cfg.rope_theta).reshape(B, 1, rope_d)

    L = cache.c.shape[1]
    slot = (pos % L).astype(jnp.int32) if window > 0 else pos.astype(jnp.int32)
    cc = jax.lax.dynamic_update_slice(cache.c, c_t, (0, slot, 0))
    ckr = jax.lax.dynamic_update_slice(cache.k_rope, k_rope_t, (0, slot, 0))
    spos = cache.slot_pos.at[slot].set(pos.astype(jnp.int32))

    valid = (spos >= 0) & (spos <= pos)
    if window > 0:
        valid &= spos > pos - window

    scale = 1.0 / jnp.sqrt(float(nope + rope_d))
    w_uk = p["w_uk"]["w"].reshape(lora, H, nope)
    if absorbed:
        # fold W_uk into the query: (B,1,H,nope) x (lora,H,nope) -> (B,H,lora)
        q_lat_scores = jnp.einsum("bshd,lhd->bhl", q_nope.astype(jnp.float32),
                                  w_uk.astype(jnp.float32))
        s_nope = jnp.einsum("bhl,bLl->bhL", q_lat_scores, cc.astype(jnp.float32))
    else:
        k_nope_all = jnp.einsum("bLl,lhd->bLhd", cc.astype(jnp.float32),
                                w_uk.astype(jnp.float32))
        s_nope = jnp.einsum("bshd,bLhd->bhL", q_nope.astype(jnp.float32), k_nope_all)

    s_rope = jnp.einsum("bshd,bLd->bhL", q_rope.astype(jnp.float32),
                        ckr.astype(jnp.float32))
    logits = (s_nope + s_rope) * scale
    logits = jnp.where(valid[None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)                      # (B,H,L)

    w_uv = p["w_uv"]["w"].reshape(lora, H, vd)
    if absorbed:
        ctx_lat = jnp.einsum("bhL,bLl->bhl", w, cc.astype(jnp.float32))   # (B,H,lora)
        y = jnp.einsum("bhl,lhd->bhd", ctx_lat, w_uv.astype(jnp.float32))
    else:
        v_all = jnp.einsum("bLl,lhd->bLhd", cc.astype(jnp.float32),
                           w_uv.astype(jnp.float32))
        y = jnp.einsum("bhL,bLhd->bhd", w, v_all)
    y = y.reshape(B, 1, H * vd).astype(x_t.dtype)
    return dense(p["wo"], y), MLACache(c=cc, k_rope=ckr, slot_pos=spos)


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------


def cross_forward(cfg: ModelConfig, p: Params, x: Array,
                  enc_k: Array, enc_v: Array) -> Array:
    B, S, d = x.shape
    hd = cfg.hd
    q = dense(p["wq"], x).reshape(B, S, cfg.n_heads, hd)
    if S == 1:   # decode: no constraints / no repeat churn (see gqa_decode)
        y = _sdpa_grouped(q, enc_k, enc_v, None,
                          1.0 / jnp.sqrt(hd).astype(jnp.float32))
    else:
        y = _sdpa(q, enc_k, enc_v, None,
                  1.0 / jnp.sqrt(hd).astype(jnp.float32), specs=_act_specs(cfg))
    return dense(p["wo"], y.reshape(B, S, cfg.n_heads * hd))


def cross_precompute(cfg: ModelConfig, p: Params, enc_out: Array):
    B, L, _ = enc_out.shape
    hd = cfg.hd
    k = dense(p["wk"], enc_out).reshape(B, L, cfg.n_kv_heads, hd)
    v = dense(p["wv"], enc_out).reshape(B, L, cfg.n_kv_heads, hd)
    return k, v
