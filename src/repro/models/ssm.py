"""Mamba-2 block with the SSD (state-space duality) algorithm.

Training/prefill use the **chunked SSD** form (arXiv:2405.21060): the
sequence is split into chunks of length Q; within a chunk the output is
a small attention-like matmul (MXU-friendly), across chunks a recurrent
state of shape (heads, head_dim, d_state) is carried by a short
lax.scan.  This is the TPU-native adaptation: the original CUDA kernel
fuses the intra-chunk quadratic part per SM; here each chunk's
(Q x Q) masked-decay matmul and its (Q x N) state projections map onto
the MXU, and the cross-chunk scan has length S/Q.

Decoding is the O(1) recurrent step — the reason long_500k is natural
for SSMs: the "cache" is the fixed-size state, independent of context
length.
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import causal_conv1d, conv1d_init, conv1d_step, dense, dense_init, rmsnorm

Array = jnp.ndarray
Params = Dict[str, Array]


class SSMState(NamedTuple):
    h: Array          # (B, H, hd, N) recurrent state
    conv_buf: Array   # (B, conv_width-1, din + 2*G*N)


def ssm_init(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    din = cfg.d_inner
    H = cfg.ssm_heads
    G, N = cfg.ssm_groups, cfg.ssm_state
    conv_ch = din + 2 * G * N
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(k1, d, 2 * din + 2 * G * N + H, dtype),
        "conv": conv1d_init(k2, cfg.ssm_conv, conv_ch, dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "out_norm": {"scale": jnp.ones((din,), dtype)},
        "out_proj": dense_init(k3, din, d, dtype),
    }


def _split_proj(cfg: ModelConfig, proj: Array):
    din = cfg.d_inner
    G, N, H = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z, xc, Bm, Cm, dt = jnp.split(
        proj, [din, 2 * din, 2 * din + G * N, 2 * din + 2 * G * N], axis=-1
    )
    return z, xc, Bm, Cm, dt


def _ssd_chunked(cfg: ModelConfig, x: Array, Bm: Array, Cm: Array,
                 dt: Array, A_log: Array, h0: Array):
    """Chunked SSD scan.

    x:  (B, S, H, P)   per-head inputs (P = head_dim)
    Bm: (B, S, G, N)   input projections (G groups broadcast over heads)
    Cm: (B, S, G, N)   output projections
    dt: (B, S, H)      positive step sizes
    h0: (B, H, P, N)   initial state
    Returns (y: (B, S, H, P), h_final).
    """
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(cfg.ssm_chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    rep = H // G

    a = -jnp.exp(A_log)                                   # (H,) negative
    # reshape to chunks
    xq = x.reshape(Bsz, nc, Q, H, P)
    Bq = jnp.repeat(Bm.reshape(Bsz, nc, Q, G, N), rep, axis=3)   # (B,nc,Q,H,N)
    Cq = jnp.repeat(Cm.reshape(Bsz, nc, Q, G, N), rep, axis=3)
    dtq = dt.reshape(Bsz, nc, Q, H)
    l = dtq * a[None, None, None, :]                      # (B,nc,Q,H) log-decays
    cum = jnp.cumsum(l, axis=2)                           # inclusive cumsum

    # intra-chunk: M[t,s] = (C_t . B_s) * exp(cum_t - cum_s) * dt_s, s <= t
    # (B,nc,H,Q,Q)
    CB = jnp.einsum("bnqhx,bnshx->bnhqs", Cq, Bq)
    diff = (cum[:, :, :, None, :].transpose(0, 1, 4, 2, 3)
            - cum[:, :, :, None, :].transpose(0, 1, 4, 3, 2))
    # diff[b,n,h,t,s] = cum_t - cum_s; for masked s > t this is >= 0 and
    # exp() can overflow -> masking AFTER exp leaks NaN through the
    # gradient.  Mask the exponent itself instead (exp(-inf) = 0).
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.exp(jnp.where(tri[None, None, None], diff, -jnp.inf))
    M = CB * decay
    y_intra = jnp.einsum("bnhqs,bnshp,bnsh->bnqhp", M, xq, dtq)

    # chunk summaries
    # state injected by chunk n: sum_s exp(cum_Q - cum_s) dt_s B_s (x) x_s
    end_decay = jnp.exp(cum[:, :, -1:, :] - cum)          # (B,nc,Q,H)
    chunk_state = jnp.einsum("bnqh,bnqh,bnqhx,bnqhp->bnhpx",
                             end_decay, dtq, Bq, xq)      # (B,nc,H,P,N)
    chunk_decay = jnp.exp(cum[:, :, -1, :])               # (B,nc,H) total decay

    # inter-chunk recurrence over nc chunks
    def scan_fn(h, inp):
        cs, cd = inp                                      # (B,H,P,N), (B,H)
        h_out = h                                         # state BEFORE this chunk
        h_next = cd[:, :, None, None] * h + cs
        return h_next, h_out

    cs_seq = jnp.moveaxis(chunk_state, 1, 0)              # (nc,B,H,P,N)
    cd_seq = jnp.moveaxis(chunk_decay, 1, 0)              # (nc,B,H)
    h_final, h_prevs = jax.lax.scan(scan_fn, h0, (cs_seq, cd_seq))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                 # (B,nc,H,P,N)

    # inter-chunk contribution: y_t += C_t . (exp(cum_t) * h_prev)
    in_decay = jnp.exp(cum)                               # (B,nc,Q,H)
    y_inter = jnp.einsum("bnqhx,bnhpx,bnqh->bnqhp", Cq, h_prevs, in_decay)

    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y, h_final


def ssm_forward(cfg: ModelConfig, p: Params, x: Array,
                state: "SSMState | None" = None) -> Tuple[Array, "SSMState"]:
    """Full-sequence Mamba-2 block.  x: (B, S, d) -> (y, new_state).

    ``state`` carries the recurrent state and the causal-conv left
    context, so chunked prefill / prefill->decode handoff is exact.
    """
    Bsz, S, d = x.shape
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_groups, cfg.ssm_state

    proj = dense(p["in_proj"], x)
    z, xc, Bm, Cm, dt = _split_proj(cfg, proj)

    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)
    if state is None:
        state = init_ssm_state(cfg, Bsz, x.dtype)
    conv_out = jax.nn.silu(
        causal_conv1d(p["conv"], conv_in, left_context=state.conv_buf))
    conv_tail_src = jnp.concatenate([state.conv_buf, conv_in], axis=1)
    new_conv_buf = conv_tail_src[:, -(cfg.ssm_conv - 1):, :]
    xc, Bm, Cm = jnp.split(conv_out, [cfg.d_inner, cfg.d_inner + G * N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    xh = xc.reshape(Bsz, S, H, P).astype(jnp.float32)
    Bm = Bm.reshape(Bsz, S, G, N).astype(jnp.float32)
    Cm = Cm.reshape(Bsz, S, G, N).astype(jnp.float32)

    # pad the time axis to a chunk multiple: padded steps carry dt=0,
    # i.e. decay exp(0)=1 and zero state contribution — exact.
    Q = min(cfg.ssm_chunk, S) if S % min(cfg.ssm_chunk, S) == 0 else cfg.ssm_chunk
    Sp = ((S + Q - 1) // Q) * Q
    if Sp != S:
        padt = ((0, 0), (0, Sp - S))
        xh_p = jnp.pad(xh, padt + ((0, 0), (0, 0)))
        Bm_p = jnp.pad(Bm, padt + ((0, 0), (0, 0)))
        Cm_p = jnp.pad(Cm, padt + ((0, 0), (0, 0)))
        dt_p = jnp.pad(dt, padt + ((0, 0),))
    else:
        xh_p, Bm_p, Cm_p, dt_p = xh, Bm, Cm, dt
    y, h_final = _ssd_chunked(cfg, xh_p, Bm_p, Cm_p, dt_p, p["A_log"], state.h)
    y = y[:, :S]
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(Bsz, S, cfg.d_inner).astype(x.dtype)

    y = y * jax.nn.silu(z)
    y = rmsnorm(p["out_norm"], y, cfg.norm_eps)
    return dense(p["out_proj"], y), SSMState(h=h_final, conv_buf=new_conv_buf)


def init_ssm_state(cfg: ModelConfig, B: int, dtype) -> SSMState:
    conv_ch = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return SSMState(
        h=jnp.zeros((B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
        conv_buf=jnp.zeros((B, cfg.ssm_conv - 1, conv_ch), dtype),
    )


def ssm_decode(cfg: ModelConfig, p: Params, x_t: Array,
               state: SSMState) -> Tuple[Array, SSMState]:
    """O(1) recurrent decode step.  x_t: (B, 1, d)."""
    Bsz = x_t.shape[0]
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_groups, cfg.ssm_state

    proj = dense(p["in_proj"], x_t[:, 0, :])
    z, xc, Bm, Cm, dt = _split_proj(cfg, proj)

    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)
    buf, conv_out = conv1d_step(p["conv"], state.conv_buf, conv_in)
    conv_out = jax.nn.silu(conv_out)
    xc, Bm, Cm = jnp.split(conv_out, [cfg.d_inner, cfg.d_inner + G * N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])  # (B,H)
    a = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * a[None, :])                                 # (B,H)

    xh = xc.reshape(Bsz, H, P).astype(jnp.float32)
    Bmh = jnp.repeat(Bm.reshape(Bsz, G, N), H // G, axis=1)          # (B,H,N)
    Cmh = jnp.repeat(Cm.reshape(Bsz, G, N), H // G, axis=1)

    h = decay[:, :, None, None] * state.h + jnp.einsum(
        "bh,bhn,bhp->bhpn", dt, Bmh, xh)
    y = jnp.einsum("bhn,bhpn->bhp", Cmh, h) + p["D"][None, :, None] * xh
    y = y.reshape(Bsz, 1, cfg.d_inner).astype(x_t.dtype)

    y = y * jax.nn.silu(z[:, None, :])
    y = rmsnorm(p["out_norm"], y, cfg.norm_eps)
    return dense(p["out_proj"], y), SSMState(h=h, conv_buf=buf)
