"""Population layer: 10^5-10^6 simulated learners with churn and
partial participation (DESIGN.md Sec. 15)."""
from .availability import (ALWAYS_ON, DEFAULT_MIX, PHONE, SLOW,
                           AvailabilityClass, PopulationSpec,
                           class_assignment, participation_masks,
                           rejoin_counts)
from .sim import PopulationResult, run_population, trace_population

__all__ = [
    "AvailabilityClass", "PopulationSpec",
    "ALWAYS_ON", "PHONE", "SLOW", "DEFAULT_MIX",
    "class_assignment", "participation_masks", "rejoin_counts",
    "PopulationResult", "run_population", "trace_population",
]
