"""Availability classes and seeded participation masks (DESIGN.md Sec. 15).

A population of ``m_total`` simulated learners is partitioned into
heterogeneous availability classes.  Each class is a two-state Markov
chain over (on, off) — churn — composed with per-round client sampling
and a device-speed tier:

- ``p_drop``: P(on -> off) per round — a device that churns out
  mid-stream keeps its (now stale) model and stops participating;
- ``p_return``: P(off -> on) per round — recovery.  The engine treats
  the False -> True mask edge as a REJOIN: the device re-``adopt``s the
  coordinator's current reference and the ledger is charged the Sec. 3
  download (``Substrate.rejoin_payload_bytes``);
- ``speed``: the fraction of sampled rounds a device of this tier
  actually completes within the round deadline (slow phones miss
  deadlines; the server drops their contribution, exactly a smaller
  effective cohort);
- the population-level ``sample_rate`` is the coordinator's per-round
  client sampling among currently-available devices.

Everything is derived from ``np.random.default_rng`` seeded with
``np.random.SeedSequence([seed, TAG])`` where the TAGs are fixed module
constants — never string hashes — so masks are byte-identical across
processes and ``PYTHONHASHSEED`` values (tests/test_population.py runs
the subprocess check).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import numpy as np

# fixed integer stream tags (never derived from strings: str hashes vary
# under PYTHONHASHSEED, SeedSequence ints do not)
_TAG_ASSIGN = 101   # class assignment permutation
_TAG_INIT = 102     # initial on/off state
_TAG_CHURN = 103    # per-round drop / return draws
_TAG_SAMPLE = 104   # per-round client sampling
_TAG_SPEED = 105    # per-round deadline (speed-tier) draws


@dataclasses.dataclass(frozen=True)
class AvailabilityClass:
    """One device class of the population."""

    name: str
    p_drop: float = 0.0      # P(on -> off) per round
    p_return: float = 1.0    # P(off -> on) per round
    speed: float = 1.0       # P(completes the round | sampled)

    def __post_init__(self):
        for field in ("p_drop", "p_return", "speed"):
            v = getattr(self, field)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{field}={v} outside [0, 1]")

    @property
    def stationary_on(self) -> float:
        """Stationary P(on) of the churn chain (1.0 when it never
        drops)."""
        if self.p_drop == 0.0:
            return 1.0
        return self.p_return / (self.p_drop + self.p_return)


# The three canonical tiers of the population experiments
# (EXPERIMENTS.md §Population): datacenter nodes that never churn,
# phone-like devices with duty cycles, and a slow tier that misses
# round deadlines half the time.
ALWAYS_ON = AvailabilityClass("always_on")
PHONE = AvailabilityClass("phone", p_drop=0.15, p_return=0.35)
SLOW = AvailabilityClass("slow", p_drop=0.05, p_return=0.25, speed=0.5)

DEFAULT_MIX: Tuple[Tuple[AvailabilityClass, float], ...] = (
    (ALWAYS_ON, 0.2), (PHONE, 0.5), (SLOW, 0.3))


@dataclasses.dataclass(frozen=True)
class PopulationSpec:
    """A population: size, class mix, coordinator sampling, seed."""

    m_total: int
    classes: Tuple[Tuple[AvailabilityClass, float], ...] = DEFAULT_MIX
    sample_rate: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if self.m_total < 1:
            raise ValueError(f"need m_total >= 1, got {self.m_total}")
        if not 0.0 < self.sample_rate <= 1.0:
            raise ValueError(
                f"sample_rate {self.sample_rate} outside (0, 1]")
        if not self.classes:
            raise ValueError("need at least one availability class")
        total = sum(frac for _, frac in self.classes)
        if not math.isclose(total, 1.0, rel_tol=0, abs_tol=1e-9):
            raise ValueError(f"class fractions sum to {total}, not 1")


def _rng(spec: PopulationSpec, tag: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([spec.seed, tag]))


def class_assignment(spec: PopulationSpec) -> np.ndarray:
    """(m_total,) int class index per learner.

    Counts are deterministic (largest-remainder apportionment of the
    fractions), the assignment is a seeded permutation — so the class
    histogram is exact, not sampled.
    """
    m = spec.m_total
    fracs = np.asarray([f for _, f in spec.classes], np.float64)
    base = np.floor(fracs * m).astype(np.int64)
    rem = m - int(base.sum())
    # distribute the remainder to the largest fractional parts;
    # np.argsort is stable ("stable" kind), ties break by class order
    order = np.argsort(-(fracs * m - base), kind="stable")
    for k in range(rem):
        base[order[k]] += 1
    ids = np.repeat(np.arange(len(spec.classes)), base)
    return ids[_rng(spec, _TAG_ASSIGN).permutation(m)]


def participation_masks(spec: PopulationSpec, T: int) -> np.ndarray:
    """(T, m_total) bool participation mask of the population.

    Row t is the cohort of round t: available (per-class churn chain)
    AND sampled (coordinator ``sample_rate``) AND completed (speed
    tier).  Same spec + same T => byte-identical array, in-process and
    across interpreters.
    """
    if T < 1:
        raise ValueError(f"need T >= 1, got {T}")
    m = spec.m_total
    cls = class_assignment(spec)
    p_drop = np.asarray([c.p_drop for c, _ in spec.classes])[cls]
    p_return = np.asarray([c.p_return for c, _ in spec.classes])[cls]
    speed = np.asarray([c.speed for c, _ in spec.classes])[cls]
    stat = np.asarray([c.stationary_on for c, _ in spec.classes])[cls]

    on = _rng(spec, _TAG_INIT).random(m) < stat
    churn = _rng(spec, _TAG_CHURN)
    sample = _rng(spec, _TAG_SAMPLE)
    pace = _rng(spec, _TAG_SPEED)
    mask = np.zeros((T, m), bool)
    for t in range(T):
        u = churn.random(m)
        on = np.where(on, u >= p_drop, u < p_return)
        row = on & (sample.random(m) < spec.sample_rate)
        row &= pace.random(m) < speed
        mask[t] = row
    return mask


def rejoin_counts(mask: np.ndarray) -> np.ndarray:
    """(T,) int rejoins per round under the engine's convention: round
    0 has none (the initial reference reached everyone for free), and a
    learner rejoins at t > 0 iff its mask flips False -> True."""
    T = mask.shape[0]
    out = np.zeros(T, np.int64)
    out[1:] = np.sum(mask[1:] & ~mask[:-1], axis=1)
    return out
