"""Population simulation driver (DESIGN.md Sec. 15).

``run_population`` drives the masked scan engine over a
:class:`~repro.population.availability.PopulationSpec`: the population
is the engine's stacked learner axis (vmapped cohorts; shard it over a
mesh with ``mesh=`` exactly as ``engine.run`` documents), the per-round
cohort is the seeded participation mask, and the result couples the
engine's :class:`~repro.core.simulation.SimResult` — losses bitwise,
Sec. 3 bytes integer-exact over only the participating cohort — with
the population-level observables (cohort sizes, rejoin counts, class
assignment).

Scale: a 10^5-learner population on 8 forced host devices is the CI
quick-sweep (benchmarks/bench_population.py); 10^6 works with short
streams and primal substrates.  The SV substrate's device ledger
refuses populations whose worst-case sync bytes overflow int32
(``accounting.device_sync_bytes_kernel``), so population-scale runs use
RFF / linear — the paper's own Sec. 4 proposal for communication at
scale — where per-sync bytes are the fixed ``2 c |theta| B`` of the
cohort.

Determinism: masks come from ``availability.participation_masks``
(integer-tagged SeedSequences), the engine is the deterministic scan
core, and the trace emitted by :func:`trace_population` is
byte-identical across runs and ``PYTHONHASHSEED`` values
(tests/test_population.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core import engine
from ..core.protocol import ProtocolConfig
from ..core.simulation import SimResult
from ..telemetry.trace import PID_RUNTIME, Tracer
from .availability import PopulationSpec, participation_masks, \
    class_assignment, rejoin_counts


@dataclasses.dataclass
class PopulationResult:
    """A population run: the engine result plus cohort observables."""

    sim: SimResult
    participation: np.ndarray    # (T, m) bool, the mask that ran
    cohort_sizes: np.ndarray     # (T,) int64 participants per round
    rejoins: np.ndarray          # (T,) int64 rejoin events per round
    class_ids: np.ndarray        # (m,) int class index per learner

    @property
    def mean_cohort(self) -> float:
        return float(self.cohort_sizes.mean())

    @property
    def total_rejoins(self) -> int:
        return int(self.rejoins.sum())


def run_population(
    spec: PopulationSpec,
    learner,
    pcfg: ProtocolConfig,
    X: np.ndarray,          # (T, m_total, d)
    Y: np.ndarray,          # (T, m_total)
    *,
    mesh=None,
    topology: str = "coordinator",
    record_divergence: bool = False,
    participation: Optional[np.ndarray] = None,
) -> PopulationResult:
    """Run the population over a labeled stream.

    ``X`` / ``Y`` carry the full population's stream (the engine's
    shapes); learners outside the round's cohort never touch their
    row.  ``participation`` overrides the spec-derived mask (same
    (T, m) shape) — the degenerate all-True override reproduces
    ``engine.run`` bit-for-bit, which is the contract the whole layer
    is proven against.
    """
    T, m, _ = np.asarray(X).shape if not hasattr(X, "shape") else X.shape
    if m != spec.m_total:
        raise ValueError(
            f"stream learner axis {m} != spec.m_total {spec.m_total}")
    if participation is None:
        mask = participation_masks(spec, T)
    else:
        mask = np.asarray(participation, bool)
        if mask.shape != (T, m):
            raise ValueError(
                f"participation shape {mask.shape} != {(T, m)}")
    sim = engine.run(
        learner, pcfg, X, Y,
        mesh=mesh, topology=topology,
        record_divergence=record_divergence,
        participation=mask)
    return PopulationResult(
        sim=sim,
        participation=mask,
        cohort_sizes=mask.sum(axis=1).astype(np.int64),
        rejoins=rejoin_counts(mask),
        class_ids=class_assignment(spec),
    )


def trace_population(result: PopulationResult, tracer: Tracer, *,
                     name: str = "population") -> None:
    """Write the population observables into a Chrome trace: cohort
    size and cumulative rejoins as counter tracks on round-index time,
    plus an instant per sync round carrying the round's cohort.  All
    values are ints from deterministic arrays, so the emitted trace is
    byte-identical for byte-identical results."""
    cum_rejoins = 0
    sync_set = {int(t) for t in np.asarray(result.sim.sync_rounds)}
    for t in range(len(result.cohort_sizes)):
        cum_rejoins += int(result.rejoins[t])
        tracer.counter(f"{name}/cohort", float(t),
                       {"participants": int(result.cohort_sizes[t]),
                        "rejoins": cum_rejoins},
                       pid=PID_RUNTIME)
        if t in sync_set:
            tracer.instant(f"{name}/sync", float(t), pid=PID_RUNTIME,
                           args={"round": t,
                                 "cohort": int(result.cohort_sizes[t])})
