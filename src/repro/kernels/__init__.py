"""Pallas TPU kernels for the paper compute hot-spots.

``ops`` is the public face (padding, autotuned blocks, interpret-mode
selection, tiny-shape fallback); ``autotune`` owns block-size choice;
``fused`` holds the per-round fused kernels; ``ref`` the jnp oracles.
"""
from . import autotune, fused, ops, ref

__all__ = ["autotune", "fused", "ops", "ref"]
