"""Pallas TPU kernels for the paper compute hot-spots."""
from . import ops, ref

__all__ = ["ops", "ref"]
