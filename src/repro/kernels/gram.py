"""Blocked Gram-matrix Pallas kernel (TPU target).

The compute hot-spot of the paper's kernel learners is Gram algebra:
predictions K(X, S) @ alpha, RKHS norms alpha^T K alpha, and the
divergence/local-condition distances — all dominated by pairwise kernel
evaluations.  A GPU implementation would assign one row per thread; on
TPU we instead block for the MXU:

  K[i, j] = exp(-gamma * (||x_i||^2 + ||y_j||^2 - 2 x_i . y_j))

- the cross term -2 X Y^T is a (bm x d) @ (d x bn) matmul on the MXU,
  accumulated in fp32 via preferred_element_type;
- the row/col squared norms are computed in-block on the VPU and fused
  with the exponential, so the intermediate squared-distance matrix
  never leaves VMEM;
- block sizes default to 128/256 — MXU-aligned (multiples of 128 on the
  contracted and output dims; inputs are zero-padded to alignment by
  ops.py, which is exact for the cross term and masked for the norms).

Grid: (ceil(M/bm), ceil(N/bn)); each program writes one (bm, bn) output
tile.  The feature dim d is kept whole inside the block (kernel-method
d is small — tens to a few hundred — so a (bm, d) slab fits VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 128
DEFAULT_BN = 128


def _gram_kernel(x_ref, y_ref, o_ref, *, kind: str, gamma: float,
                 degree: int, coef0: float):
    """One (bm, bn) tile of the Gram matrix."""
    x = x_ref[...].astype(jnp.float32)           # (bm, d)
    y = y_ref[...].astype(jnp.float32)           # (bn, d)
    cross = jax.lax.dot_general(
        x, y,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                            # (bm, bn) on the MXU
    if kind == "linear":
        o_ref[...] = cross
    elif kind == "poly":
        o_ref[...] = (cross + coef0) ** degree
    else:  # gaussian
        xx = jnp.sum(x * x, axis=1, keepdims=True)       # (bm, 1)
        yy = jnp.sum(y * y, axis=1, keepdims=True).T     # (1, bn)
        sq = jnp.maximum(xx + yy - 2.0 * cross, 0.0)
        o_ref[...] = jnp.exp(-gamma * sq)


def gram_pallas(
    X: jnp.ndarray,
    Y: jnp.ndarray,
    *,
    kind: str = "gaussian",
    gamma: float = 1.0,
    degree: int = 3,
    coef0: float = 1.0,
    block_m: int = DEFAULT_BM,
    block_n: int = DEFAULT_BN,
    interpret: bool = False,
) -> jnp.ndarray:
    """K(X, Y) with X: (M, d), Y: (N, d).  M, N, d must already be
    padded to block multiples (ops.py handles padding + masking)."""
    M, d = X.shape
    N, _ = Y.shape
    assert M % block_m == 0 and N % block_n == 0, (M, N, block_m, block_n)

    kernel = functools.partial(
        _gram_kernel, kind=kind, gamma=gamma, degree=degree, coef0=coef0
    )
    return pl.pallas_call(
        kernel,
        grid=(M // block_m, N // block_n),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(X, Y)
