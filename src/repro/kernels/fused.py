"""Fused per-round Pallas kernels (TPU target; DESIGN.md Sec. 12).

The per-round hot path of every substrate family, as ONE kernel each:

- :func:`sv_predict_pallas` — the SV family's round is dominated by
  evaluating the support-vector expansion f_i(x_i) = sum_j k(x_i,
  s_ij) a_ij for each of the B stacked learners.  Composing the
  seed-era ``gram`` + a contraction materializes a (B, N) kernel-row
  matrix in HBM only to immediately reduce it; this kernel fuses the
  Gram tile, the masked-coefficient product, and the reduction so only
  the (B,) predictions leave VMEM.  Masking rides in the coefficients:
  ops.py zeroes the alpha entries of padded sorted-id slots, so padded
  support vectors contribute exactly 0 no matter what k(x, 0) is.

- :func:`primal_step_pallas` — the RFF/linear families' ENTIRE round
  (featurize + predict-dot + loss/grad + SGD update) in one launch:
  z = sqrt(2/D) cos(W x + b) on the MXU+VPU, yhat = <w, z> + b, the
  hinge (or squared) loss and its grad, and the NORMA-decayed weight
  update — the pre-activation matrix, the feature matrix, and the
  gradient never round-trip to HBM.  With ``featurize=False`` the
  identity feature map makes it the linear learner's fused round.

Both kernels block only axes whose accumulation stays row-local, so a
row's floats never depend on how many rows share the launch — the
predict_batch bit-exactness contract (core/substrate.py) extends to
the fused path by construction.

Inputs arrive pre-padded to block multiples (ops.py pads and crops,
exactly like the seed-era kernels); block sizes come from
kernels/autotune.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BN = 128
DEFAULT_BM = 128


def _kernel_row(x, sv, *, kind: str, gamma: float, degree: int,
                coef0: float) -> jnp.ndarray:
    """k(x, sv): x (1, d), sv (bn, d) -> (1, bn), fp32 on the MXU."""
    cross = jax.lax.dot_general(
        x, sv, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                              # (1, bn)
    if kind == "linear":
        return cross
    if kind == "poly":
        return (cross + coef0) ** degree
    xx = jnp.sum(x * x, axis=1, keepdims=True)     # (1, 1)
    yy = jnp.sum(sv * sv, axis=1, keepdims=True).T  # (1, bn)
    return jnp.exp(-gamma * jnp.maximum(xx + yy - 2.0 * cross, 0.0))


def _sv_predict_kernel(x_ref, sv_ref, a_ref, o_ref, *, kind: str,
                       gamma: float, degree: int, coef0: float):
    j = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)             # (1, d)
    sv = sv_ref[...][0].astype(jnp.float32)        # (bn, d)
    a = a_ref[...].astype(jnp.float32)             # (1, bn)
    k = _kernel_row(x, sv, kind=kind, gamma=gamma, degree=degree,
                    coef0=coef0)
    partial_val = jnp.sum(k * a)

    @pl.when(j == 0)
    def _init():
        o_ref[0, 0] = 0.0

    o_ref[0, 0] += partial_val


def sv_predict_pallas(
    X: jnp.ndarray,       # (B, d)    one query per stacked learner
    SV: jnp.ndarray,      # (B, N, d) stacked support sets (padded)
    A: jnp.ndarray,       # (B, N)    coefficients, padded slots zeroed
    *,
    kind: str = "gaussian",
    gamma: float = 1.0,
    degree: int = 3,
    coef0: float = 1.0,
    block_n: int = DEFAULT_BN,
    interpret: bool = False,
) -> jnp.ndarray:
    """(B, 1) fused masked predictions; N, d pre-padded (N % block_n
    == 0).  Grid (B, N/bn): the budget axis streams through VMEM and
    accumulates into one scalar per learner — rows are independent
    grid cells, so per-row floats don't depend on B."""
    B, N, d = SV.shape
    assert X.shape == (B, d) and A.shape == (B, N), (X.shape, SV.shape,
                                                     A.shape)
    assert N % block_n == 0, (N, block_n)
    kernel = functools.partial(
        _sv_predict_kernel, kind=kind, gamma=gamma, degree=degree,
        coef0=coef0)
    return pl.pallas_call(
        kernel,
        grid=(B, N // block_n),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_n, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.float32),
        interpret=interpret,
    )(X, SV, A)


def _loss_grad(loss: str, yhat, y):
    """(ell, dell/dyhat) — the same formulas as core.learners
    .loss_and_grad, restated here so kernels stay core-independent."""
    if loss == "hinge":
        ell = jnp.maximum(0.0, 1.0 - y * yhat)
        return ell, jnp.where(ell > 0.0, -y, 0.0)
    r = yhat - y
    return 0.5 * r * r, r


def _primal_step_math(z, w, b_row, y_row, *, loss: str, eta: float,
                      lam: float):
    """The shared round math on a (bm, D) feature block: returns
    (w_new, b_new_row, ell_row, yhat_row) with the *_row values shaped
    (1, bm)."""
    yhat = jnp.sum(w * z, axis=1)[None, :] + b_row  # (1, bm)
    ell, g = _loss_grad(loss, yhat, y_row)
    w_new = (1.0 - eta * lam) * w - eta * g.T * z   # g.T: (bm, 1)
    b_new = b_row - eta * g
    return w_new, b_new, ell, yhat


def _rff_step_kernel(x_ref, y_ref, w_ref, b_ref, wf_ref, bias_ref,
                     ow_ref, ob_ref, oell_ref, oyh_ref, *, scale: float,
                     loss: str, eta: float, lam: float):
    x = x_ref[...].astype(jnp.float32)              # (bm, d)
    wf = wf_ref[...].astype(jnp.float32)            # (D, d)
    bias = bias_ref[...].astype(jnp.float32)        # (1, D)
    proj = jax.lax.dot_general(
        x, wf, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                               # (bm, D) on the MXU
    z = scale * jnp.cos(proj + bias)
    w_new, b_new, ell, yhat = _primal_step_math(
        z, w_ref[...].astype(jnp.float32), b_ref[...].astype(jnp.float32),
        y_ref[...].astype(jnp.float32), loss=loss, eta=eta, lam=lam)
    ow_ref[...] = w_new
    ob_ref[...] = b_new
    oell_ref[...] = ell
    oyh_ref[...] = yhat


def _linear_step_kernel(x_ref, y_ref, w_ref, b_ref, ow_ref, ob_ref,
                        oell_ref, oyh_ref, *, loss: str, eta: float,
                        lam: float):
    w_new, b_new, ell, yhat = _primal_step_math(
        x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        b_ref[...].astype(jnp.float32), y_ref[...].astype(jnp.float32),
        loss=loss, eta=eta, lam=lam)
    ow_ref[...] = w_new
    ob_ref[...] = b_new
    oell_ref[...] = ell
    oyh_ref[...] = yhat


def primal_step_pallas(
    X: jnp.ndarray,        # (B, d)  one example per stacked learner
    Yl: jnp.ndarray,       # (B,)    labels
    w: jnp.ndarray,        # (B, D)  stacked weights
    b: jnp.ndarray,        # (B,)    stacked biases
    *,
    W: jnp.ndarray | None = None,      # (D, d) RFF projection, or None
    bias: jnp.ndarray | None = None,   # (D,)   RFF phases
    scale: float = 1.0,                # sqrt(2 / num_features)
    loss: str = "hinge",
    eta: float = 0.5,
    lam: float = 0.01,
    block_m: int = DEFAULT_BM,
    interpret: bool = False,
):
    """One fused online round for B stacked primal learners: returns
    (w_new (B, D), b_new (B,), ell (B,), yhat (B,)).

    The learner axis B is the only blocked axis (B % block_m == 0,
    pre-padded); the feature axis D stays whole per program so the
    predict reduction and the update see the full feature row in VMEM
    — which bounds D by VMEM (a (bm, D) fp32 slab; ~2k features at
    bm = 128 uses ~1 MB) and is exactly the regime the paper's RFF
    models live in.  With ``W``/``bias`` set the feature map runs
    in-kernel; otherwise z = x (linear family).
    """
    B, d = X.shape
    D = w.shape[1]
    assert B % block_m == 0, (B, block_m)
    assert w.shape == (B, D) and Yl.shape == (B,) and b.shape == (B,)
    featurize = W is not None
    y_row = Yl.reshape(1, B)
    b_row = b.reshape(1, B)
    row = lambda i: (0, i)                 # (1, bm) blocks over the B axis
    slab = lambda i: (i, 0)                # (bm, ·) blocks over the B axis
    row_specs = pl.BlockSpec((1, block_m), row)
    out_shapes = (
        jax.ShapeDtypeStruct((B, D), jnp.float32),   # w_new
        jax.ShapeDtypeStruct((1, B), jnp.float32),   # b_new
        jax.ShapeDtypeStruct((1, B), jnp.float32),   # ell
        jax.ShapeDtypeStruct((1, B), jnp.float32),   # yhat
    )
    out_specs = (pl.BlockSpec((block_m, D), slab), row_specs, row_specs,
                 row_specs)
    if featurize:
        assert W.shape == (D, d) and bias is not None and bias.shape == (D,)
        kernel = functools.partial(
            _rff_step_kernel, scale=scale, loss=loss, eta=eta, lam=lam)
        in_specs = [
            pl.BlockSpec((block_m, d), slab),        # X
            row_specs,                               # labels
            pl.BlockSpec((block_m, D), slab),        # w
            row_specs,                               # b
            pl.BlockSpec((D, d), lambda i: (0, 0)),  # W (whole)
            pl.BlockSpec((1, D), lambda i: (0, 0)),  # bias (whole)
        ]
        args = (X, y_row, w, b_row, W, bias.reshape(1, D))
    else:
        assert D == d, (D, d)
        kernel = functools.partial(
            _linear_step_kernel, loss=loss, eta=eta, lam=lam)
        in_specs = [
            pl.BlockSpec((block_m, d), slab),
            row_specs,
            pl.BlockSpec((block_m, D), slab),
            row_specs,
        ]
        args = (X, y_row, w, b_row)
    w_new, b_new, ell, yhat = pl.pallas_call(
        kernel,
        grid=(B // block_m,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(*args)
    return w_new, b_new[0], ell[0], yhat[0]
