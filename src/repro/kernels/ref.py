"""Pure-jnp oracles for every Pallas kernel in this package.

These define the exact semantics the kernels must reproduce; the test
suite sweeps shapes/dtypes and asserts allclose against them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gram_ref(X, Y, *, kind="gaussian", gamma=1.0, degree=3, coef0=1.0):
    X = X.astype(jnp.float32)
    Y = Y.astype(jnp.float32)
    cross = X @ Y.T  # reprolint: allow[DET01] bulk oracle, compared under PARITY_RTOL
    if kind == "linear":
        return cross
    if kind == "poly":
        return (cross + coef0) ** degree
    xx = jnp.sum(X * X, axis=-1)[:, None]
    yy = jnp.sum(Y * Y, axis=-1)[None, :]
    sq = jnp.maximum(xx + yy - 2.0 * cross, 0.0)
    return jnp.exp(-gamma * sq)


def rff_ref(X, W, b, *, num_features=None):
    X = X.astype(jnp.float32)
    W = W.astype(jnp.float32)
    D = num_features or W.shape[0]
    # reprolint: allow[DET01] bulk oracle, compared under PARITY_RTOL
    return jnp.sqrt(2.0 / D) * jnp.cos(X @ W.T + b.astype(jnp.float32)[None, :])


def quadform_ref(X, Y, alpha, beta, *, kind="gaussian", gamma=1.0,
                 degree=3, coef0=1.0):
    K = gram_ref(X, Y, kind=kind, gamma=gamma, degree=degree, coef0=coef0)
    # reprolint: allow[DET01] bulk oracle, compared under PARITY_RTOL
    return alpha.astype(jnp.float32) @ K @ beta.astype(jnp.float32)


def sv_predict_ref(X, SV, A, *, kind="gaussian", gamma=1.0, degree=3,
                   coef0=1.0):
    """Masked batched SV predictions: yhat_i = sum_j k(X_i, SV_ij) A_ij.

    X (B, d), SV (B, N, d), A (B, N); padded support slots must carry
    zero coefficients (that is the masking contract — k(x, 0) is
    multiplied by 0, never looked at)."""

    def one(x, S, a):
        # multiply + sum, not `@`: the serving predict path is under the
        # bitwise contract, so the oracle pins the same reduction order
        # as rkhs.predict (DESIGN.md Sec. 9).
        k = gram_ref(x[None, :], S, kind=kind, gamma=gamma,
                     degree=degree, coef0=coef0)[0]
        return jnp.sum(k * a.astype(jnp.float32))

    return jax.vmap(one)(X, SV, A)


def _loss_grad_ref(loss, yhat, y):
    if loss == "hinge":
        ell = jnp.maximum(0.0, 1.0 - y * yhat)
        return ell, jnp.where(ell > 0.0, -y, 0.0)
    r = yhat - y
    return 0.5 * r * r, r


def primal_step_ref(X, Yl, w, b, *, W=None, bias=None, scale=1.0,
                    loss="hinge", eta=0.5, lam=0.01):
    """Oracle for fused.primal_step_pallas: one online round for B
    stacked primal learners -> (w_new, b_new, ell, yhat)."""
    X = X.astype(jnp.float32)
    Yl = Yl.astype(jnp.float32)
    w = w.astype(jnp.float32)
    b = b.astype(jnp.float32)
    if W is not None:
        # reprolint: allow[DET01] bulk oracle, compared under PARITY_RTOL
        z = scale * jnp.cos(X @ W.T.astype(jnp.float32)
                            + bias.astype(jnp.float32)[None, :])
    else:
        z = X
    yhat = jnp.sum(w * z, axis=-1) + b
    ell, g = _loss_grad_ref(loss, yhat, Yl)
    w_new = (1.0 - eta * lam) * w - eta * g[:, None] * z
    b_new = b - eta * g
    return w_new, b_new, ell, yhat
