"""Pure-jnp oracles for every Pallas kernel in this package.

These define the exact semantics the kernels must reproduce; the test
suite sweeps shapes/dtypes and asserts allclose against them.
"""
from __future__ import annotations

import jax.numpy as jnp


def gram_ref(X, Y, *, kind="gaussian", gamma=1.0, degree=3, coef0=1.0):
    X = X.astype(jnp.float32)
    Y = Y.astype(jnp.float32)
    cross = X @ Y.T
    if kind == "linear":
        return cross
    if kind == "poly":
        return (cross + coef0) ** degree
    xx = jnp.sum(X * X, axis=-1)[:, None]
    yy = jnp.sum(Y * Y, axis=-1)[None, :]
    sq = jnp.maximum(xx + yy - 2.0 * cross, 0.0)
    return jnp.exp(-gamma * sq)


def rff_ref(X, W, b, *, num_features=None):
    X = X.astype(jnp.float32)
    W = W.astype(jnp.float32)
    D = num_features or W.shape[0]
    return jnp.sqrt(2.0 / D) * jnp.cos(X @ W.T + b.astype(jnp.float32))


def quadform_ref(X, Y, alpha, beta, *, kind="gaussian", gamma=1.0,
                 degree=3, coef0=1.0):
    K = gram_ref(X, Y, kind=kind, gamma=gamma, degree=degree, coef0=coef0)
    return alpha.astype(jnp.float32) @ K @ beta.astype(jnp.float32)
