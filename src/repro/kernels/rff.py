"""Fused Random-Fourier-Features Pallas kernel (TPU target).

Z = sqrt(2/D) * cos(X W^T + b)

The projection X W^T is an MXU matmul; the bias add, cosine and scale
are fused on the VPU so the pre-activation matrix never round-trips to
HBM.  Grid: (ceil(M/bm), ceil(D/bd)); each program computes one
(bm, bd) feature tile from a (bm, d) input slab and a (bd, d) weight
slab resident in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 128
DEFAULT_BD = 128


def _rff_kernel(x_ref, w_ref, b_ref, o_ref, *, scale: float):
    x = x_ref[...].astype(jnp.float32)            # (bm, d)
    w = w_ref[...].astype(jnp.float32)            # (bd, d)
    b = b_ref[...].astype(jnp.float32)            # (1, bd)
    proj = jax.lax.dot_general(
        x, w, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                             # (bm, bd)
    o_ref[...] = scale * jnp.cos(proj + b)


def rff_pallas(
    X: jnp.ndarray,      # (M, d)
    W: jnp.ndarray,      # (D, d)
    b: jnp.ndarray,      # (D,)
    *,
    num_features: int | None = None,
    block_m: int = DEFAULT_BM,
    block_d: int = DEFAULT_BD,
    interpret: bool = False,
) -> jnp.ndarray:
    M, d = X.shape
    D, _ = W.shape
    assert M % block_m == 0 and D % block_d == 0, (M, D, block_m, block_d)
    import math
    scale = math.sqrt(2.0 / (num_features or D))
    kernel = functools.partial(_rff_kernel, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(M // block_m, D // block_d),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_d, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, block_d), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_d), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, D), jnp.float32),
        interpret=interpret,
    )(X, W, b.reshape(1, D))
