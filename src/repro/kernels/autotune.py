"""Block-size autotuner for the Pallas kernels (DESIGN.md Sec. 12).

The seed-era wrappers hardcoded 128x128 tiles.  That is the *floor*
the MXU imposes, not the optimum: for large operands bigger tiles
amortize grid overhead and raise MXU occupancy, while for small
operands a 512-wide tile only pads.  This module owns the choice:

``tuned_blocks(op, dims, ...)`` returns the tile sizes ops.py launches
with, resolved in three tiers:

1. **cache hit** — a per-process table keyed on
   ``(op, dims, dtype, kind)`` (the issue's per-(shape, dtype, kind)
   contract).  Tile choice is deterministic per key, which is what
   keeps jit caches warm: the same substrate shapes always resolve to
   the same static block arguments, so a value-equal substrate
   re-trace hits the existing executable (the recompile-regression
   test pins this with ``telemetry.probe.CompileCounter``).
2. **interpret-safe defaults (CPU)** — off-TPU the kernels run in
   interpret mode, where "timing" tiles measures the Python
   interpreter, so no search runs: the default is the alignment floor
   (128) clipped to the padded operand, recorded in the cache with
   ``source="default"``.
3. **measured search (TPU)** — on a real TPU backend, and only when
   not called mid-trace (a search launches kernels; doing that while
   tracing an outer jit would nest tracers), each aligned candidate
   tile is timed via the caller-provided ``measure`` thunk and the
   fastest wins (``source="search"``).

The cache is process-local on purpose.  Persisting tunings across
processes would couple benchmark runs to stale machine profiles; a
process re-tunes once per distinct shape, which for this workload
(a handful of static substrate shapes per run) is noise.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax

_ALIGN = 128                      # MXU/VPU alignment floor
_CANDIDATES = (128, 256, 512)     # aligned tile candidates per axis
_SEARCH_ITERS = 3                 # timing iterations per candidate


@dataclasses.dataclass(frozen=True)
class TileKey:
    """Cache key: one tile choice per (op, operand dims, dtype, kind)."""

    op: str
    dims: Tuple[int, ...]
    dtype: str
    kind: str


@dataclasses.dataclass(frozen=True)
class TileChoice:
    """A resolved tile assignment and where it came from."""

    blocks: Tuple[int, ...]
    source: str                   # "default" | "search" | "pinned"


_CACHE: Dict[TileKey, TileChoice] = {}


def _round_up(n: int, mult: int = _ALIGN) -> int:
    return ((n + mult - 1) // mult) * mult


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _tracing() -> bool:
    """True when called under an active jax trace — searching would
    launch kernels inside the outer tracer, so the resolver must not."""
    try:
        return not jax.core.trace_state_clean()
    except AttributeError:   # future jax: assume eager only when provable
        return True


def candidates_for(size: int) -> Tuple[int, ...]:
    """Aligned tile sizes worth trying for one axis of extent ``size``:
    every candidate <= the padded extent (a tile larger than the padded
    operand only adds zero rows), always including the 128 floor."""
    padded = _round_up(max(int(size), 1))
    return tuple(c for c in _CANDIDATES if c <= padded) or (_ALIGN,)


def default_blocks(dims: Sequence[int]) -> Tuple[int, ...]:
    """The no-search choice: the alignment floor per axis (clipped via
    candidates_for so a 40-row operand gets a 128 tile, not 512)."""
    return tuple(candidates_for(s)[0] for s in dims)


def _time_thunk(thunk: Callable[[], object]) -> float:
    jax.block_until_ready(thunk())          # compile + warm
    t0 = time.perf_counter()
    for _ in range(_SEARCH_ITERS):
        jax.block_until_ready(thunk())
    return time.perf_counter() - t0


def tuned_blocks(
    op: str,
    dims: Sequence[int],
    *,
    dtype: str = "float32",
    kind: str = "",
    measure: Optional[Callable[[Tuple[int, ...]], object]] = None,
) -> Tuple[int, ...]:
    """The tile sizes to launch ``op`` with for operand extents ``dims``
    (one entry per blocked axis).

    ``measure(blocks)`` — when provided and the backend is a real TPU —
    must run the kernel once with the given tile assignment and return
    a value to block on; the resolver times each candidate assignment
    and caches the winner.  Off-TPU (interpret mode) or mid-trace the
    defaults are cached without any launch.
    """
    key = TileKey(op=op, dims=tuple(int(s) for s in dims),
                  dtype=str(dtype), kind=str(kind))
    hit = _CACHE.get(key)
    if hit is not None:
        return hit.blocks

    if measure is None or not _on_tpu() or _tracing():
        choice = TileChoice(default_blocks(key.dims), "default")
        _CACHE[key] = choice
        return choice.blocks

    best, best_t = None, float("inf")
    for blocks in _grid(tuple(candidates_for(s) for s in key.dims)):
        try:
            t = _time_thunk(lambda: measure(blocks))
        except Exception:        # a candidate may exceed VMEM — skip it
            continue
        if t < best_t:
            best, best_t = blocks, t
    if best is None:             # every candidate failed: fall back
        choice = TileChoice(default_blocks(key.dims), "default")
    else:
        choice = TileChoice(best, "search")
    _CACHE[key] = choice
    return choice.blocks


def _grid(axes: Tuple[Tuple[int, ...], ...]) -> Tuple[Tuple[int, ...], ...]:
    """Cartesian product of per-axis candidate tuples."""
    out: Tuple[Tuple[int, ...], ...] = ((),)
    for ax in axes:
        out = tuple(prefix + (c,) for prefix in out for c in ax)
    return out


# -- introspection / test hooks ---------------------------------------------


def pin(op: str, dims: Sequence[int], blocks: Sequence[int], *,
        dtype: str = "float32", kind: str = "") -> None:
    """Force a tile choice for one key (benchmarking what-ifs)."""
    key = TileKey(op, tuple(int(s) for s in dims), str(dtype), str(kind))
    _CACHE[key] = TileChoice(tuple(int(b) for b in blocks), "pinned")


def cache_info() -> Dict[TileKey, TileChoice]:
    """A snapshot of the resolution table (copy — mutations don't leak)."""
    return dict(_CACHE)


def clear_cache() -> None:
    _CACHE.clear()
