"""Public wrappers around the Pallas kernels.

Responsibilities:
- pad inputs to MXU-aligned block multiples (zero padding is exact for
  the feature dim of every kernel kind; padded rows/cols are cropped
  from outputs, and padded alpha/beta entries are zero so quadform is
  exact);
- choose interpret mode automatically off-TPU (this container is
  CPU-only: interpret=True executes the kernel bodies in Python so the
  TPU kernels are validated for correctness here and compiled for real
  on TPU);
- resolve block sizes through kernels/autotune.py (the seed-era
  hardcoded 128s are now the *defaults* the tuner falls back to; pass
  explicit ``block_*`` ints to bypass it);
- fall back to the pure-jnp reference for tiny shapes where a Pallas
  launch is not worth it (``engages`` is the one shared threshold).

Structure: each public op is an *eager* resolver (fallback branch,
tuned-block lookup, launch counting) around a module-level jitted
launcher whose static arguments are exactly the kernel-shape-relevant
knobs.  Calling an op eagerly pays one dict lookup + one jit-cache hit
per call; calling it inside an outer jit (the substrate under the scan
engine) resolves everything at trace time and inlines the launcher.

``LAUNCH_COUNTS`` ticks once per *traced* Pallas launch (per call when
eager) — the path-proof used by the backend-parity tests and the
serving ``bucket_predict_hits_pallas`` claim: parity says the numbers
match, the counter says the fused kernel actually produced them.
"""
from __future__ import annotations

import collections
import functools

import jax
import jax.numpy as jnp

from . import autotune, ref
from .fused import primal_step_pallas, sv_predict_pallas
from .gram import gram_pallas
from .quadform import quadform_pallas
from .rff import rff_pallas

_LANE = 128          # TPU lane width: last-dim alignment
_MIN_PALLAS = 128    # below this, use the jnp reference

LAUNCH_COUNTS: collections.Counter = collections.Counter()


def engages(*dims) -> bool:
    """True when these operand extents take the Pallas branch.

    The single fallback threshold every op shares: a launch engages
    when any blocked extent reaches ``_MIN_PALLAS``.  The substrate
    layer keys its own backend dispatch on this, so "pallas backend,
    tiny model" runs the reference expressions bit-for-bit.
    """
    return max(int(d) for d in dims) >= _MIN_PALLAS


def reset_launch_counts() -> None:
    LAUNCH_COUNTS.clear()


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not _on_tpu()


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    n = x.shape[axis]
    target = ((n + mult - 1) // mult) * mult
    if target == n:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - n)
    return jnp.pad(x, pads)


# ---------------------------------------------------------------------------
# Jitted launchers (pad -> pallas_call -> crop, all inside one trace)
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("kind", "gamma", "degree", "coef0", "block_m",
                     "block_n", "interpret"),
)
def _gram_call(X, Y, *, kind, gamma, degree, coef0, block_m, block_n,
               interpret):
    M, N = X.shape[0], Y.shape[0]
    Xp = _pad_to(_pad_to(X, 0, block_m), 1, _LANE)
    Yp = _pad_to(_pad_to(Y, 0, block_n), 1, _LANE)
    K = gram_pallas(
        Xp, Yp, kind=kind, gamma=gamma, degree=degree, coef0=coef0,
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    return K[:M, :N]


@functools.partial(
    jax.jit,
    static_argnames=("num_features", "block_m", "block_d", "interpret"),
)
def _rff_call(X, W, b, *, num_features, block_m, block_d, interpret):
    M, D = X.shape[0], W.shape[0]
    Xp = _pad_to(_pad_to(X, 0, block_m), 1, _LANE)
    Wp = _pad_to(_pad_to(W, 0, block_d), 1, _LANE)
    bp = _pad_to(b, 0, block_d)
    Z = rff_pallas(
        Xp, Wp, bp, num_features=num_features, block_m=block_m,
        block_d=block_d, interpret=interpret,
    )
    return Z[:M, :D]


@functools.partial(
    jax.jit,
    static_argnames=("kind", "gamma", "degree", "coef0", "block_m",
                     "block_n", "interpret"),
)
def _quadform_call(X, Y, alpha, beta, *, kind, gamma, degree, coef0,
                   block_m, block_n, interpret):
    Xp = _pad_to(_pad_to(X, 0, block_m), 1, _LANE)
    Yp = _pad_to(_pad_to(Y, 0, block_n), 1, _LANE)
    ap = _pad_to(alpha, 0, block_m)
    bp = _pad_to(beta, 0, block_n)
    return quadform_pallas(
        Xp, Yp, ap, bp, kind=kind, gamma=gamma, degree=degree, coef0=coef0,
        block_m=block_m, block_n=block_n, interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=("kind", "gamma", "degree", "coef0", "block_n",
                     "interpret"),
)
def _sv_predict_call(X, SV, A, *, kind, gamma, degree, coef0, block_n,
                     interpret):
    Xp = _pad_to(X, 1, _LANE)
    SVp = _pad_to(_pad_to(SV, 1, block_n), 2, _LANE)
    Ap = _pad_to(A, 1, block_n)
    out = sv_predict_pallas(
        Xp, SVp, Ap, kind=kind, gamma=gamma, degree=degree, coef0=coef0,
        block_n=block_n, interpret=interpret,
    )
    return out[:, 0]


@functools.partial(
    jax.jit,
    static_argnames=("scale", "loss", "eta", "lam", "block_m",
                     "featurize", "interpret"),
)
def _primal_step_call(X, Yl, w, b, W, bias, *, scale, loss, eta, lam,
                      block_m, featurize, interpret):
    B, D = w.shape
    Xp = _pad_to(_pad_to(X, 0, block_m), 1, _LANE)
    wp = _pad_to(_pad_to(w, 0, block_m), 1, _LANE)
    yp = _pad_to(Yl, 0, block_m)
    bp = _pad_to(b, 0, block_m)
    if featurize:
        # Padding the feature axis D makes the extra z columns
        # cos(0 + 0) = 1 (not 0) — harmless: the matching w columns are
        # zero-padded, so yhat is exact, and the garbage w_new columns
        # are cropped right here.
        Wp = _pad_to(_pad_to(W, 0, _LANE), 1, _LANE)
        biasp = _pad_to(bias, 0, _LANE)
    else:
        Wp, biasp = None, None
    w_new, b_new, ell, yhat = primal_step_pallas(
        Xp, yp, wp, bp, W=Wp, bias=biasp, scale=scale, loss=loss,
        eta=eta, lam=lam, block_m=block_m, interpret=interpret,
    )
    return w_new[:B, :D], b_new[:B], ell[:B], yhat[:B]


# ---------------------------------------------------------------------------
# Public ops
# ---------------------------------------------------------------------------


def gram(X, Y, *, kind="gaussian", gamma=1.0, degree=3, coef0=1.0,
         block_m=None, block_n=None, force_pallas=False):
    """K(X, Y): (M, d), (N, d) -> (M, N) fp32."""
    M, N = X.shape[0], Y.shape[0]
    if not force_pallas and not engages(M, N):
        return ref.gram_ref(X, Y, kind=kind, gamma=gamma, degree=degree,
                            coef0=coef0)

    def launch(blocks):
        return _gram_call(X, Y, kind=kind, gamma=gamma, degree=degree,
                          coef0=coef0, block_m=blocks[0], block_n=blocks[1],
                          interpret=_interpret())

    if block_m is None or block_n is None:
        block_m, block_n = autotune.tuned_blocks(
            "gram", (M, N), dtype=str(X.dtype),
            kind=f"{kind}:d={X.shape[1]}", measure=launch)
    LAUNCH_COUNTS["gram"] += 1
    return launch((block_m, block_n))


def rff_features(X, W, b, *, num_features=None, block_m=None, block_d=None,
                 force_pallas=False):
    """phi(X): (M, d) with W (D, d), b (D,) -> (M, D) fp32."""
    M, D = X.shape[0], W.shape[0]
    nf = num_features or D
    if not force_pallas and not engages(M, D):
        return ref.rff_ref(X, W, b, num_features=nf)

    def launch(blocks):
        return _rff_call(X, W, b, num_features=nf, block_m=blocks[0],
                         block_d=blocks[1], interpret=_interpret())

    if block_m is None or block_d is None:
        block_m, block_d = autotune.tuned_blocks(
            "rff", (M, D), dtype=str(X.dtype), kind=f"d={X.shape[1]}",
            measure=launch)
    LAUNCH_COUNTS["rff"] += 1
    return launch((block_m, block_d))


def quadform(X, Y, alpha, beta, *, kind="gaussian", gamma=1.0, degree=3,
             coef0=1.0, block_m=None, block_n=None, force_pallas=False):
    """alpha^T K(X, Y) beta -> scalar fp32, without materializing K in HBM."""
    M, N = X.shape[0], Y.shape[0]
    if not force_pallas and not engages(M, N):
        return ref.quadform_ref(X, Y, alpha, beta, kind=kind, gamma=gamma,
                                degree=degree, coef0=coef0)

    def launch(blocks):
        return _quadform_call(X, Y, alpha, beta, kind=kind, gamma=gamma,
                              degree=degree, coef0=coef0, block_m=blocks[0],
                              block_n=blocks[1], interpret=_interpret())

    if block_m is None or block_n is None:
        block_m, block_n = autotune.tuned_blocks(
            "quadform", (M, N), dtype=str(X.dtype),
            kind=f"{kind}:d={X.shape[1]}", measure=launch)
    LAUNCH_COUNTS["quadform"] += 1
    return launch((block_m, block_n))


def rkhs_dist_sq(X, Y, alpha, beta, *, kind="gaussian", gamma=1.0,
                 degree=3, coef0=1.0):
    """||f - g||_H^2 via three fused quadratic forms (never materializes
    any Gram matrix in HBM) — the divergence-monitoring hot path."""
    kw = dict(kind=kind, gamma=gamma, degree=degree, coef0=coef0)
    return (
        quadform(X, X, alpha, alpha, **kw)
        + quadform(Y, Y, beta, beta, **kw)
        - 2.0 * quadform(X, Y, alpha, beta, **kw)
    )


def sv_predict(X, SV, A, *, kind="gaussian", gamma=1.0, degree=3,
               coef0=1.0, block_n=None, force_pallas=False):
    """Fused batched SV predictions: yhat_i = sum_j k(X_i, SV_ij) A_ij.

    X (B, d), SV (B, N, d), A (B, N) -> (B,) fp32.  One launch replaces
    B gram+contract pairs; padded support slots must carry zero alphas
    (the sorted-id masking contract — substrate.py zeroes them).

    Engagement and the tuned block depend on the budget axis N (and d
    via the tune key) but never on B, so a row's floats — and its
    branch — are identical whether it runs alone (``predict_one``) or
    inside a serving bucket (``predict_batch``): the row-bit-exactness
    contract extends to the fused path.
    """
    B, N, d = SV.shape
    if not force_pallas and not engages(N):
        return ref.sv_predict_ref(X, SV, A, kind=kind, gamma=gamma,
                                  degree=degree, coef0=coef0)

    def launch(blocks):
        return _sv_predict_call(X, SV, A, kind=kind, gamma=gamma,
                                degree=degree, coef0=coef0,
                                block_n=blocks[0], interpret=_interpret())

    if block_n is None:
        (block_n,) = autotune.tuned_blocks(
            "sv_predict", (N,), dtype=str(SV.dtype),
            kind=f"{kind}:d={d}", measure=launch)
    LAUNCH_COUNTS["sv_predict"] += 1
    return launch((block_n,))


def fused_primal_step(X, Yl, w, b, *, W=None, bias=None, scale=1.0,
                      loss="hinge", eta=0.5, lam=0.01, block_m=None,
                      force_pallas=False):
    """One fused online round for B stacked primal learners.

    (X (B, d), labels (B,), w (B, D), b (B,)) -> (w_new, b_new, ell,
    yhat).  With ``W``/``bias``/``scale`` set, the RFF feature map runs
    inside the kernel (featurize + predict + loss/grad + NORMA update,
    one launch); without them z = x and it is the linear family's
    round.
    """
    B = X.shape[0]
    D = w.shape[1]
    featurize = W is not None
    op = "rff_step" if featurize else "linear_step"
    if not force_pallas and not engages(B, D):
        return ref.primal_step_ref(X, Yl, w, b, W=W, bias=bias, scale=scale,
                                   loss=loss, eta=eta, lam=lam)

    def launch(blocks):
        return _primal_step_call(X, Yl, w, b, W, bias, scale=scale,
                                 loss=loss, eta=eta, lam=lam,
                                 block_m=blocks[0], featurize=featurize,
                                 interpret=_interpret())

    if block_m is None:
        (block_m,) = autotune.tuned_blocks(
            op, (B,), dtype=str(X.dtype),
            kind=f"d={X.shape[1]}:D={D}:{loss}", measure=launch)
    LAUNCH_COUNTS[op] += 1
    return launch((block_m,))


# ---------------------------------------------------------------------------
# KernelSpec-driven entry points (the substrate layer's pallas backend)
# ---------------------------------------------------------------------------
#
# ``spec`` is duck-typed against core.rkhs.KernelSpec (kind / gamma /
# degree / coef0) so this package stays import-independent of core.
# These are what core.substrate dispatches to under backend="pallas"
# (DESIGN.md Sec. 8 and 12).


def gram_spec(spec, X, Y, **kw):
    """K(X, Y) for a core.rkhs.KernelSpec."""
    return gram(X, Y, kind=spec.kind, gamma=spec.gamma, degree=spec.degree,
                coef0=spec.coef0, **kw)


def quadform_spec(spec, X, Y, alpha, beta, **kw):
    """alpha^T K(X, Y) beta for a core.rkhs.KernelSpec."""
    return quadform(X, Y, alpha, beta, kind=spec.kind, gamma=spec.gamma,
                    degree=spec.degree, coef0=spec.coef0, **kw)


def rkhs_dist_sq_spec(spec, X, Y, alpha, beta):
    """||f - g||_H^2 for a core.rkhs.KernelSpec (three fused quadforms)."""
    return rkhs_dist_sq(X, Y, alpha, beta, kind=spec.kind, gamma=spec.gamma,
                        degree=spec.degree, coef0=spec.coef0)


def sv_predict_spec(spec, X, SV, A, **kw):
    """Fused batched SV predictions for a core.rkhs.KernelSpec."""
    return sv_predict(X, SV, A, kind=spec.kind, gamma=spec.gamma,
                      degree=spec.degree, coef0=spec.coef0, **kw)
