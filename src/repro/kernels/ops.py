"""Jit'd public wrappers around the Pallas kernels.

Responsibilities:
- pad inputs to MXU-aligned block multiples (zero padding is exact for
  the feature dim of every kernel kind; padded rows/cols are cropped
  from outputs, and padded alpha/beta entries are zero so quadform is
  exact);
- choose interpret mode automatically off-TPU (this container is
  CPU-only: interpret=True executes the kernel bodies in Python so the
  TPU kernels are validated for correctness here and compiled for real
  on TPU);
- fall back to the pure-jnp reference for tiny shapes where a Pallas
  launch is not worth it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .gram import gram_pallas
from .quadform import quadform_pallas
from .rff import rff_pallas

_LANE = 128          # TPU lane width: last-dim alignment
_MIN_PALLAS = 128    # below this, use the jnp reference


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not _on_tpu()


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    n = x.shape[axis]
    target = ((n + mult - 1) // mult) * mult
    if target == n:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - n)
    return jnp.pad(x, pads)


@functools.partial(
    jax.jit,
    static_argnames=("kind", "gamma", "degree", "coef0", "block_m", "block_n", "force_pallas"),
)
def gram(X, Y, *, kind="gaussian", gamma=1.0, degree=3, coef0=1.0,
         block_m=128, block_n=128, force_pallas=False):
    """K(X, Y): (M, d), (N, d) -> (M, N) fp32."""
    M, N = X.shape[0], Y.shape[0]
    if not force_pallas and max(M, N) < _MIN_PALLAS:
        return ref.gram_ref(X, Y, kind=kind, gamma=gamma, degree=degree, coef0=coef0)
    Xp = _pad_to(_pad_to(X, 0, block_m), 1, _LANE)
    Yp = _pad_to(_pad_to(Y, 0, block_n), 1, _LANE)
    K = gram_pallas(
        Xp, Yp, kind=kind, gamma=gamma, degree=degree, coef0=coef0,
        block_m=block_m, block_n=block_n, interpret=_interpret(),
    )
    return K[:M, :N]


@functools.partial(
    jax.jit, static_argnames=("num_features", "block_m", "block_d", "force_pallas")
)
def rff_features(X, W, b, *, num_features=None, block_m=128, block_d=128,
                 force_pallas=False):
    """phi(X): (M, d) with W (D, d), b (D,) -> (M, D) fp32."""
    M, D = X.shape[0], W.shape[0]
    nf = num_features or D
    if not force_pallas and max(M, D) < _MIN_PALLAS:
        return ref.rff_ref(X, W, b, num_features=nf)
    Xp = _pad_to(_pad_to(X, 0, block_m), 1, _LANE)
    Wp = _pad_to(_pad_to(W, 0, block_d), 1, _LANE)
    bp = _pad_to(b, 0, block_d)
    Z = rff_pallas(
        Xp, Wp, bp, num_features=nf, block_m=block_m, block_d=block_d,
        interpret=_interpret(),
    )
    return Z[:M, :D]


@functools.partial(
    jax.jit,
    static_argnames=("kind", "gamma", "degree", "coef0", "block_m", "block_n", "force_pallas"),
)
def quadform(X, Y, alpha, beta, *, kind="gaussian", gamma=1.0, degree=3,
             coef0=1.0, block_m=128, block_n=128, force_pallas=False):
    """alpha^T K(X, Y) beta -> scalar fp32, without materializing K in HBM."""
    M, N = X.shape[0], Y.shape[0]
    if not force_pallas and max(M, N) < _MIN_PALLAS:
        return ref.quadform_ref(X, Y, alpha, beta, kind=kind, gamma=gamma,
                                degree=degree, coef0=coef0)
    Xp = _pad_to(_pad_to(X, 0, block_m), 1, _LANE)
    Yp = _pad_to(_pad_to(Y, 0, block_n), 1, _LANE)
    ap = _pad_to(alpha, 0, block_m)
    bp = _pad_to(beta, 0, block_n)
    return quadform_pallas(
        Xp, Yp, ap, bp, kind=kind, gamma=gamma, degree=degree, coef0=coef0,
        block_m=block_m, block_n=block_n, interpret=_interpret(),
    )


def rkhs_dist_sq(X, Y, alpha, beta, *, kind="gaussian", gamma=1.0,
                 degree=3, coef0=1.0):
    """||f - g||_H^2 via three fused quadratic forms (never materializes
    any Gram matrix in HBM) — the divergence-monitoring hot path."""
    kw = dict(kind=kind, gamma=gamma, degree=degree, coef0=coef0)
    return (
        quadform(X, X, alpha, alpha, **kw)
        + quadform(Y, Y, beta, beta, **kw)
        - 2.0 * quadform(X, Y, alpha, beta, **kw)
    )


# ---------------------------------------------------------------------------
# KernelSpec-driven entry points (the substrate layer's pallas backend)
# ---------------------------------------------------------------------------
#
# ``spec`` is duck-typed against core.rkhs.KernelSpec (kind / gamma /
# degree / coef0) so this package stays import-independent of core.
# These are what core.substrate dispatches to under backend="pallas"
# (DESIGN.md Sec. 8).


def gram_spec(spec, X, Y, **kw):
    """K(X, Y) for a core.rkhs.KernelSpec."""
    return gram(X, Y, kind=spec.kind, gamma=spec.gamma, degree=spec.degree,
                coef0=spec.coef0, **kw)


def quadform_spec(spec, X, Y, alpha, beta, **kw):
    """alpha^T K(X, Y) beta for a core.rkhs.KernelSpec."""
    return quadform(X, Y, alpha, beta, kind=spec.kind, gamma=spec.gamma,
                    degree=spec.degree, coef0=spec.coef0, **kw)


def rkhs_dist_sq_spec(spec, X, Y, alpha, beta):
    """||f - g||_H^2 for a core.rkhs.KernelSpec (three fused quadforms)."""
    return rkhs_dist_sq(X, Y, alpha, beta, kind=spec.kind, gamma=spec.gamma,
                        degree=spec.degree, coef0=spec.coef0)
