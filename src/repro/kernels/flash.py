"""Blocked causal flash attention (Pallas, TPU target).

Targets the dominant *memory* roofline term of dense prefill
(EXPERIMENTS.md §Perf, qwen3-14b x prefill_32k): the XLA path
materializes the fp32 (S, S) score matrix in HBM per head
(S=32768 -> 4.3 GB/head); this kernel streams (bq, bk) tiles through
VMEM with the online-softmax recurrence, so HBM traffic drops from
O(S^2) to O(S * d) per head:

  traffic_xla   ~ S*S*4 * 2      (write + read scores)    = 8.6 GB/head
  traffic_flash ~ S*d*2 * 3      (q, k, v reads) + S*d*2  = 0.03 GB/head

Layout: q/k/v (BH, S, hd).  Grid (BH, S/bq, S/bk); the kv-block axis is
the innermost (sequential on TPU), carrying the running max m, the
normalizer l and the unnormalized accumulator acc in VMEM scratch.
Causal masking is applied on the diagonal tiles; fully-masked tiles
above the diagonal are skipped via ``pl.when``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                  *, scale: float, causal: bool, bq: int, bk: int,
                  window: int = 0):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * bq
    k_start = ki * bk

    # skip tiles strictly above the causal diagonal, and (with a
    # sliding window) tiles strictly below the band
    run = (k_start <= q_start + bq - 1) if causal else (ki >= 0)
    if window > 0:
        run = run & (k_start + bk - 1 > q_start - window)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)              # (bq, hd)
        k = k_ref[0].astype(jnp.float32)              # (bk, hd)
        v = v_ref[0].astype(jnp.float32)              # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        if causal or window > 0:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            mask = kpos <= qpos if causal else (kpos == kpos)
            if window > 0:
                mask = mask & (kpos > qpos - window)
            s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                            # (bq, 1)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                         # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                # (bq, 1)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,       # (BH, S, hd)
    k: jnp.ndarray,       # (BH, L, hd)
    v: jnp.ndarray,       # (BH, L, hd)
    *,
    scale: float | None = None,
    causal: bool = True,
    window: int = 0,
    block_q: int = DEFAULT_BQ,
    block_k: int = DEFAULT_BK,
    interpret: bool = False,
) -> jnp.ndarray:
    """window > 0: sliding-window (local) attention — off-band tiles
    are skipped entirely, so HBM traffic AND compute drop to
    O(S * window) (recurrentgemma's 2048-window local attention; the
    long_500k dense variant)."""
    BH, S, hd = q.shape
    L = k.shape[1]
    assert S % block_q == 0 and L % block_k == 0, (S, L, block_q, block_k)
    scale = scale if scale is not None else hd ** -0.5
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, bq=block_q, bk=block_k,
        window=window)
    return pl.pallas_call(
        kernel,
        grid=(BH, S // block_q, L // block_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=_scratch(block_q, hd),
        interpret=interpret,
    )(q, k, v)


def _scratch(bq: int, hd: int):
    """VMEM scratch for the online-softmax carry (acc, m, l)."""
    from jax.experimental.pallas import tpu as pltpu
    return [
        pltpu.VMEM((bq, hd), jnp.float32),
        pltpu.VMEM((bq, 1), jnp.float32),
        pltpu.VMEM((bq, 1), jnp.float32),
    ]
