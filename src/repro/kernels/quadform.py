"""Fused RKHS quadratic-form Pallas kernel (TPU target).

q = alpha^T K(X, Y) beta  —  the building block of RKHS norms,
distances, and the divergence/local-condition monitoring (Sec. 2).

A naive implementation materializes the (M, N) Gram matrix in HBM only
to immediately contract it on both sides.  This kernel streams (bm, bn)
Gram tiles through VMEM and accumulates the scalar

    q = sum_ij alpha_i K_ij beta_j

in an fp32 accumulator, so HBM traffic is O(M d + N d) instead of
O(M N) — on a v5e (819 GB/s HBM) this turns the divergence check from
memory-bound to compute-bound for typical budgets.

TPU grid iterations execute sequentially, so cross-step accumulation
into the output ref is safe; the first step initializes it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 128
DEFAULT_BN = 128


def _quadform_kernel(x_ref, y_ref, a_ref, b_ref, o_ref, *, kind: str,
                     gamma: float, degree: int, coef0: float):
    i = pl.program_id(0)
    j = pl.program_id(1)

    x = x_ref[...].astype(jnp.float32)            # (bm, d)
    y = y_ref[...].astype(jnp.float32)            # (bn, d)
    a = a_ref[...].astype(jnp.float32)            # (1, bm)
    b = b_ref[...].astype(jnp.float32)            # (1, bn)

    cross = jax.lax.dot_general(
        x, y, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    if kind == "linear":
        k = cross
    elif kind == "poly":
        k = (cross + coef0) ** degree
    else:
        xx = jnp.sum(x * x, axis=1, keepdims=True)
        yy = jnp.sum(y * y, axis=1, keepdims=True).T
        k = jnp.exp(-gamma * jnp.maximum(xx + yy - 2.0 * cross, 0.0))

    partial_val = jnp.sum((a.T * k) * b)          # alpha_i K_ij beta_j over tile

    @pl.when((i == 0) & (j == 0))
    def _init():
        o_ref[0, 0] = 0.0

    o_ref[0, 0] += partial_val


def quadform_pallas(
    X: jnp.ndarray,      # (M, d)
    Y: jnp.ndarray,      # (N, d)
    alpha: jnp.ndarray,  # (M,)
    beta: jnp.ndarray,   # (N,)
    *,
    kind: str = "gaussian",
    gamma: float = 1.0,
    degree: int = 3,
    coef0: float = 1.0,
    block_m: int = DEFAULT_BM,
    block_n: int = DEFAULT_BN,
    interpret: bool = False,
) -> jnp.ndarray:
    M, d = X.shape
    N, _ = Y.shape
    assert M % block_m == 0 and N % block_n == 0, (M, N, block_m, block_n)
    kernel = functools.partial(
        _quadform_kernel, kind=kind, gamma=gamma, degree=degree, coef0=coef0
    )
    out = pl.pallas_call(
        kernel,
        grid=(M // block_m, N // block_n),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, block_m), lambda i, j: (0, i)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(X, Y, alpha.reshape(1, M), beta.reshape(1, N))
    return out[0, 0]
