"""Slot scheduler, batch policies, admission control (DESIGN.md Sec. 13).

The serving engine's predict hot path, factored out of
`serving/engine.py` so scheduling is a *policy*, not a property of the
engine: the engine owns models and protocol rounds; a
:class:`SlotScheduler` owns WHEN predict batches launch, HOW BIG they
are, and WHAT happens when more requests arrive than the simulated
compute can carry.  The engine's parity contract — losses bitwise,
Sec. 3 bytes integer-exact vs ``engine.run`` — is therefore structural:
no scheduler decision can reach the protocol state, so batching
aggressiveness is a pure latency/throughput knob
(tests/test_serving.py proves it per policy x arrival model x
overload level).

Three pieces:

- :class:`SlotPool` — a fixed pool of in-flight *slots* (simulated
  predict lanes) per shard.  A launch occupies the earliest-free lane
  for ``predict_cost``; lanes model the device's concurrent predict
  streams, so ``slots=1`` is the single predict server of the PR 5
  engine and ``slots=k`` is k-way in-flight batching.
- **batch policies** — :class:`TickScheduler` (the legacy grid:
  requests wait for the next ``tick_interval`` point, then drain
  through the static bucket ladder; kept as the baseline the max-QPS
  benchmark measures against) and :class:`ContinuousScheduler`
  (continuous batching: a request is admitted into a free slot *on
  arrival*; the next launch size is ``min(queue_depth, buckets[-1])``
  — queue depth picks the size, the static bucket set only pads the
  shape so the compile cache stays bounded — and an optional
  latency-budget hold timer coalesces under light load: a launch may
  wait until ``oldest.arrival + max_wait``, with ``max_wait`` derived
  from the latency SLO, never past it).
- **admission control** — a bounded pending queue (``max_queue``).
  Over capacity, the scheduler either **sheds** (the request is
  refused: ``req.shed = True``, never served, traced as a ``shed``
  instant) or **defers** (the arrival is re-priced onto the event
  clock ``defer_interval`` later and retries admission; its latency
  keeps accruing from the ORIGINAL arrival).  Feedback is never
  admission-controlled — dropping labeled examples would change the
  protocol view; only predict traffic sheds.

Everything here runs on the engine's seeded event clock, so every
decision — launch times, sheds, deferrals — is deterministic under
seed, and the Chrome trace of a serving run is byte-identical across
repeats (tests/test_arrivals.py).
"""
from __future__ import annotations

import math
from collections import Counter
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..runtime.clock import Clock, Event
from ..telemetry.trace import PID_SERVING

__all__ = ["SlotPool", "SlotScheduler", "TickScheduler",
           "ContinuousScheduler", "make_scheduler", "POLICIES"]

POLICIES = ("tick", "continuous")


class SlotPool:
    """Fixed pool of simulated in-flight predict lanes for one shard.

    Purely bookkeeping on the simulated timeline: ``busy_until[i]`` is
    when lane i's current batch completes.  ``acquire`` picks the
    earliest-free lane and returns its start time (``max(now, free)``),
    so with one lane sequential launches reproduce the PR 5 engine's
    single ``_busy_until`` predict server exactly.
    """

    def __init__(self, slots: int):
        if slots < 1:
            raise ValueError(f"need at least one slot, got {slots}")
        self.slots = int(slots)
        self.busy_until = [0.0] * self.slots

    def idle_lane(self, now: float) -> Optional[int]:
        """A lane free at ``now`` (the earliest-free one), else None."""
        i = min(range(self.slots), key=lambda j: self.busy_until[j])
        return i if self.busy_until[i] <= now else None

    def acquire(self, now: float) -> Tuple[int, float]:
        """(lane, start): earliest-free lane, start no earlier than its
        current booking — the no-double-booking rule."""
        i = min(range(self.slots), key=lambda j: self.busy_until[j])
        return i, max(now, self.busy_until[i])

    def occupy(self, lane: int, until: float) -> None:
        self.busy_until[lane] = until

    def in_flight(self, now: float) -> int:
        return sum(1 for b in self.busy_until if b > now)


class SlotScheduler:
    """Shared machinery of both batch policies.

    The engine hands the scheduler its clock, tracer, shard router and
    a ``predict_fn(chunk, bucket) -> yhat`` callable (one jitted
    padded-batch predict; the chunk is always one (tenant, shard)
    group, so the model gather stays tenant- and shard-local).  The
    scheduler owns the pending queue, the per-shard slot pools, the
    admission counters and every serving-side statistic; it never sees
    protocol state.
    """

    POLICY = "base"

    def __init__(
        self,
        *,
        clock: Clock,
        predict_fn: Callable,
        shard_of: Callable[[int], int],
        n_shards: int,
        buckets: Sequence[int],
        predict_cost: float,
        slots: int = 1,
        max_queue: Optional[int] = None,
        overload: str = "shed",
        defer_interval: Optional[float] = None,
        tick_interval: float = 1.0,
        slo: Optional[float] = None,
        max_wait: Optional[float] = None,
        tracer=None,
    ):
        if overload not in ("shed", "defer"):
            raise ValueError(f"overload must be 'shed' or 'defer', "
                             f"got {overload!r}")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        if slo is not None and slo <= 0:
            raise ValueError(f"slo must be > 0, got {slo}")
        self.clock = clock
        self.tracer = tracer
        self._predict_fn = predict_fn
        self._shard_of = shard_of
        self.buckets = tuple(buckets)
        self.predict_cost = float(predict_cost)
        self.tick_interval = float(tick_interval)
        self.slo = slo
        self.max_queue = max_queue
        self.overload = overload
        # defer retries at half a tick by default: cheaper than a full
        # grid wait, still a real simulated-time price per retry
        self.defer_interval = (float(defer_interval) if defer_interval
                               is not None else 0.5 * self.tick_interval)
        if self.defer_interval <= 0:
            raise ValueError("defer_interval must be > 0")
        # latency-budget hold: how long a launch may wait for fill.
        # Derived from the SLO when not given: the whole budget minus
        # two predict costs of slack (one for the batch itself, one
        # for lane contention).  0 = launch as soon as a lane frees.
        if max_wait is not None:
            self.max_wait = float(max_wait)
        elif slo is not None:
            self.max_wait = max(0.0, float(slo) - 2.0 * self.predict_cost)
        else:
            self.max_wait = 0.0
        self.pools = [SlotPool(slots) for _ in range(n_shards)]
        self.slots = int(slots)

        self.pending: List = []          # admitted, not yet launched
        self.launches = 0
        self.ticks = 0
        self.num_admitted = 0
        self.num_shed = 0
        self.num_deferred = 0
        self.bucket_counts: Counter = Counter()
        self.queue_depth: List[int] = []

    # -- admission -----------------------------------------------------------

    def submit(self, req) -> str:
        """Admission decision for a predict request at ``clock.now``:
        'admit' (queued for a launch), 'shed' (refused, never served)
        or 'defer' (retries ``defer_interval`` later)."""
        if self.max_queue is not None and len(self.pending) >= self.max_queue:
            if self.overload == "shed":
                self.num_shed += 1
                req.shed = True
                if self.tracer is not None:
                    self.tracer.instant(
                        "shed", self.clock.now, pid=PID_SERVING,
                        tid=self.tracer.tid(PID_SERVING, "admission"),
                        args={"uid": req.uid, "queue": len(self.pending)})
                return "shed"
            self.num_deferred += 1
            req.deferrals += 1
            if self.tracer is not None:
                self.tracer.instant(
                    "defer", self.clock.now, pid=PID_SERVING,
                    tid=self.tracer.tid(PID_SERVING, "admission"),
                    args={"uid": req.uid, "retry": req.deferrals,
                          "queue": len(self.pending)})
            self.clock.schedule(self.defer_interval,
                                lambda: self.submit(req))
            return "defer"
        self.pending.append(req)
        self.num_admitted += 1
        self._on_admit(req)
        return "admit"

    # -- shared launch machinery --------------------------------------------

    def _group_key(self, req) -> Tuple[int, int]:
        return (req.tenant, self._shard_of(req.learner))

    def bucket_of(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        raise AssertionError(
            f"chunk of {n} exceeds the largest bucket {self.buckets[-1]}")

    def _launch(self, chunk: List, start: float, lane: int) -> float:
        """Run one padded-batch predict for a (tenant, shard) chunk,
        booking [start, start + predict_cost) on ``lane`` of the
        chunk's shard pool; returns the completion time."""
        shard = self._shard_of(chunk[0].learner)
        bucket = self.bucket_of(len(chunk))
        done = start + self.predict_cost
        self.pools[shard].occupy(lane, done)
        yh = self._predict_fn(chunk, bucket)
        for i, r in enumerate(chunk):
            r.yhat = float(yh[i])
            r.done_time = done
        self.launches += 1
        self.bucket_counts[bucket] += 1
        tracer = self.tracer
        if tracer is not None:
            tid = tracer.tid(PID_SERVING, "predict")
            tracer.complete(
                f"predict/bucket{bucket}", start, self.predict_cost,
                pid=PID_SERVING, tid=tid,
                args={"bucket": bucket, "filled": len(chunk),
                      "shard": shard, "tenant": chunk[0].tenant,
                      "lane": lane})
            tracer.counter(
                "serve/bucket_occupancy", start,
                {"filled": len(chunk), "bucket": bucket}, pid=PID_SERVING)
            rtid = tracer.tid(PID_SERVING, "requests")
            for r in chunk:
                tracer.complete(
                    "request", r.arrival, r.done_time - r.arrival,
                    pid=PID_SERVING, tid=rtid,
                    args={"uid": r.uid, "learner": r.learner,
                          "tenant": r.tenant, "bucket": bucket,
                          "deferrals": r.deferrals})
        # the completion lands on the timeline (wall_clock and
        # done_time can never disagree) and wakes the policy
        self.clock.schedule_at(done, self._on_complete)
        return done

    def in_flight(self) -> int:
        now = self.clock.now
        return sum(p.in_flight(now) for p in self.pools)

    def _sample_queue(self) -> None:
        self.queue_depth.append(len(self.pending))
        if self.tracer is not None:
            self.tracer.counter("serve/queue_depth", self.clock.now,
                                {"pending": len(self.pending)},
                                pid=PID_SERVING)

    # -- policy hooks --------------------------------------------------------

    def _on_admit(self, req) -> None:
        raise NotImplementedError

    def _on_complete(self) -> None:
        """A lane freed; the tick policy needs nothing, the continuous
        policy re-checks the queue."""


class TickScheduler(SlotScheduler):
    """The PR 5 grid, now on an integer tick counter.

    Requests wait for the next ``k * tick_interval`` point strictly
    after their arrival; the tick drains the whole pending queue
    through the bucket ladder, chunks booked onto the shard's slot
    pool in sequence.  The grid index k is an INTEGER: each tick time
    is one multiply ``k * tick_interval`` (never an accumulated sum,
    never `floor(now / interval + eps)` float probing), so horizons of
    any length stay exactly on grid — the float-drift regression of
    large ``now`` / tiny ``tick_interval`` cannot occur
    (tests/test_serving.py::test_tick_grid_integer_exact_at_large_times).
    """

    POLICY = "tick"

    def __init__(self, **kw):
        super().__init__(**kw)
        self._tick_scheduled = False

    def _next_grid_k(self, now: float) -> int:
        """Smallest integer k with k * tick_interval > now, by integer
        stepping from the float-division estimate (the estimate may be
        off by an ulp in either direction; the while loops make the
        answer exact regardless)."""
        q = now / self.tick_interval
        if not math.isfinite(q):
            raise OverflowError(
                f"tick grid index overflow: now={now}, "
                f"tick_interval={self.tick_interval}")
        k = int(q) + 1
        while (k - 1) * self.tick_interval > now:
            k -= 1
        while k * self.tick_interval <= now:
            k += 1
        return k

    def _on_admit(self, req) -> None:
        if self._tick_scheduled:
            return
        self._tick_scheduled = True
        k = self._next_grid_k(self.clock.now)
        self.clock.schedule_at(k * self.tick_interval, self._tick)

    def _tick(self) -> None:
        self._tick_scheduled = False
        self.ticks += 1
        self._sample_queue()
        if not self.pending:
            return
        now = self.clock.now
        groups: Dict[Tuple[int, int], List] = {}
        for r in self.pending:
            groups.setdefault(self._group_key(r), []).append(r)
        max_b = self.buckets[-1]
        for key in sorted(groups):
            shard = key[1]
            group = groups[key]
            for lo in range(0, len(group), max_b):
                chunk = group[lo:lo + max_b]
                lane, start = self.pools[shard].acquire(now)
                self._launch(chunk, start, lane)
        self.pending.clear()


class ContinuousScheduler(SlotScheduler):
    """Continuous batching: admit into free slots on arrival.

    Launch rule, re-evaluated at every admission, completion and hold-
    timer expiry: take the oldest pending request whose shard has an
    idle lane; its (tenant, shard) group launches *now* with size
    ``min(group, buckets[-1])`` — unless the group is under-full AND
    still inside its latency budget (``oldest.arrival + max_wait``),
    in which case a hold timer is armed at exactly that deadline and
    the launch waits for more arrivals.  Under load the hold never
    binds (queues fill a bucket before the deadline) and batches grow
    to the ladder top; when idle a lone request pays at most
    ``max_wait + predict_cost``, never a grid wait — which is exactly
    why continuous batching beats the tick grid at equal p99
    (benchmarks/bench_serve.py, EXPERIMENTS.md §Serving).
    """

    POLICY = "continuous"

    def __init__(self, **kw):
        super().__init__(**kw)
        self._hold: Optional[Event] = None

    def _on_admit(self, req) -> None:
        self._maybe_launch()

    def _on_complete(self) -> None:
        self._maybe_launch()

    def _arm_hold(self, deadline: float) -> None:
        if self._hold is not None and not self._hold.cancelled:
            if self._hold.time <= deadline:
                return                      # an earlier deadline is armed
            self.clock.cancel(self._hold)
        self._hold = self.clock.schedule_at(deadline, self._hold_fired)

    def _hold_fired(self) -> None:
        self._hold = None
        self._maybe_launch()

    def _maybe_launch(self) -> None:
        now = self.clock.now
        while self.pending:
            launched = False
            seen = set()
            for req in self.pending:        # arrival order
                key = self._group_key(req)
                if key in seen:
                    continue
                seen.add(key)
                pool = self.pools[key[1]]
                lane = pool.idle_lane(now)
                if lane is None:
                    continue                # completion will wake us
                group = [r for r in self.pending
                         if self._group_key(r) == key][:self.buckets[-1]]
                if (len(group) < self.buckets[-1] and self.max_wait > 0
                        and now < group[0].arrival + self.max_wait):
                    # inside the latency budget: wait for fill
                    self._arm_hold(group[0].arrival + self.max_wait)
                    continue
                self._sample_queue()
                chunk_ids = {id(r) for r in group}
                self.pending = [r for r in self.pending
                                if id(r) not in chunk_ids]
                self._launch(group, now, lane)
                if self.tracer is not None:
                    self.tracer.counter(
                        "serve/slots_in_flight", now,
                        {"in_flight": self.in_flight()}, pid=PID_SERVING)
                launched = True
                break                       # pending changed: rescan
            if not launched:
                return


def make_scheduler(policy: str, **kw) -> SlotScheduler:
    """Factory over :data:`POLICIES`; keywords are the
    :class:`SlotScheduler` constructor's."""
    if policy == "tick":
        return TickScheduler(**kw)
    if policy == "continuous":
        return ContinuousScheduler(**kw)
    raise ValueError(f"unknown policy {policy!r}; "
                     f"expected one of {POLICIES}")
