"""Online serving layer (DESIGN.md Sec. 10).

Front door: the substrate-native :class:`KernelServingEngine` —
micro-batched predict requests + in-flight online updates + background
adaptive synchronization for the paper's m-learner systems, all on one
seeded event timeline.  ``serve_stream`` replays a (T, m, d) protocol
stream through it; the protocol view is bit-identical to
``core.engine.run`` (tests/test_serving.py).

``repro.serving.lm`` holds the separate LM token-serving engine
(continuous-batching prefill/decode over ``repro.models``); it is not
imported here so the kernel-serving path never pays for the LM model
stack — ``import repro.serving.lm`` explicitly to use it.
"""
from .engine import (DEFAULT_BUCKETS, KernelServingEngine, PredictRequest,
                     ServeResult, serve_stream)

__all__ = [
    "DEFAULT_BUCKETS", "KernelServingEngine", "PredictRequest",
    "ServeResult", "serve_stream",
]
