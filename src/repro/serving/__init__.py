"""Online serving layer (DESIGN.md Secs. 10, 13).

Front door: the substrate-native :class:`KernelServingEngine` —
predict requests scheduled by a pluggable batch policy (continuous
slotted batching or the legacy tick grid, `serving/scheduler.py`),
in-flight online updates, admission control with backpressure, and
background adaptive synchronization for the paper's m-learner
systems, all on one seeded event timeline.  Several protocol tenants
can share one engine and slot pool.  ``serve_stream`` replays a
(T, m, d) protocol stream through it — with query traffic from the
seeded arrival processes of `serving/arrivals.py` riding along — and
the protocol view is bit-identical to ``core.engine.run`` under every
scheduling policy, arrival model and overload level
(tests/test_serving.py).

``repro.serving.lm`` holds the separate LM token-serving engine
(continuous-batching prefill/decode over ``repro.models``); it is not
imported here so the kernel-serving path never pays for the LM model
stack — ``import repro.serving.lm`` explicitly to use it.
"""
from .arrivals import (ARRIVAL_KINDS, ArrivalProcess, BurstyArrivals,
                       DiurnalArrivals, PoissonArrivals, make_arrivals)
from .engine import (DEFAULT_BUCKETS, KernelServingEngine, PredictRequest,
                     ServeResult, serve_stream)
from .scheduler import (POLICIES, ContinuousScheduler, SlotPool,
                        SlotScheduler, TickScheduler, make_scheduler)

__all__ = [
    "ARRIVAL_KINDS", "ArrivalProcess", "BurstyArrivals", "DiurnalArrivals",
    "PoissonArrivals", "make_arrivals",
    "DEFAULT_BUCKETS", "KernelServingEngine", "PredictRequest",
    "ServeResult", "serve_stream",
    "POLICIES", "ContinuousScheduler", "SlotPool", "SlotScheduler",
    "TickScheduler", "make_scheduler",
]
