"""LM token serving: request queue + prefill + decode loop.

A deliberately small but real continuous-batching engine for the
LM-scale models of repro/models: requests arrive with prompts, are
grouped into fixed-size batches, prefilled, then decoded step-by-step;
finished sequences are replaced eagerly from the queue (slot
recycling).  The decode step is the same jitted ``serve_step`` the
dry-run lowers for the production mesh (launch/serve.py, DESIGN.md
Sec. 4).

This is the *token* half of the serving story.  The front door of
``repro.serving`` is the substrate-native :class:`KernelServingEngine`
(serving/engine.py, DESIGN.md Sec. 10), which serves the paper's
online kernel learners; this module serves autoregressive LM decode
and is kept for the LM-protocol workloads (benchmarks/bench_lm_protocol
territory), deliberately independent of the substrate layer.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 16
    eos_token: Optional[int] = None
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    latency_s: float = 0.0   # batch start -> THIS request's completion


class LMServingEngine:
    """Fixed-batch LM decode engine; sequences in a batch share a
    prefill length (left-padded to the max prompt in the batch)."""

    def __init__(self, cfg: ModelConfig, params, batch_size: int = 4,
                 max_len: int = 256):
        self.cfg = cfg
        self.api = build(cfg)
        self.params = params
        self.B = batch_size
        self.max_len = max_len

        self._decode = jax.jit(self.api.decode)
        self._prefill = jax.jit(
            lambda params, batch, caches: self.api.prefill(params, batch, caches))

    def _make_batch(self, reqs: List[Request]):
        S = max(len(r.prompt) for r in reqs)
        toks = np.zeros((self.B, S), np.int32)
        for i, r in enumerate(reqs):
            toks[i, S - len(r.prompt):] = r.prompt   # left pad with 0
        return {"tokens": jnp.asarray(toks)}, S

    def run(self, requests: List[Request]) -> List[Request]:
        queue = list(requests)
        finished: List[Request] = []

        while queue:
            batch_reqs = queue[: self.B]
            queue = queue[self.B:]
            while len(batch_reqs) < self.B:   # pad batch with a dummy
                batch_reqs.append(Request(uid=-1, prompt=np.zeros(1, np.int32),
                                          max_new_tokens=0))
            t0 = time.perf_counter()
            batch, S = self._make_batch(batch_reqs)
            caches = self.api.init_caches(self.B, self.max_len)
            logits, caches = self._prefill(self.params, batch, caches)
            next_tok = jnp.argmax(logits[..., : self.cfg.vocab], axis=-1)
            next_tok = next_tok.astype(jnp.int32)          # (B, 1)

            max_new = max(r.max_new_tokens for r in batch_reqs)
            for step in range(max_new):
                for i, r in enumerate(batch_reqs):
                    if r.uid >= 0 and not r.done and step < r.max_new_tokens:
                        t = int(next_tok[i, 0])
                        r.output.append(t)
                        if ((r.eos_token is not None and t == r.eos_token)
                                or len(r.output) >= r.max_new_tokens):
                            r.done = True
                            r.latency_s = time.perf_counter() - t0
                # early exit: once every live sequence has finished
                # (eos or its own token budget), stop decoding instead
                # of burning steps to the batch-wide max.
                if all(r.done or r.uid < 0 for r in batch_reqs):
                    break
                pos = jnp.asarray(S + step, jnp.int32)
                logits, caches = self._decode(self.params, caches, next_tok, pos)
                next_tok = jnp.argmax(
                    logits[..., : self.cfg.vocab], axis=-1).astype(jnp.int32)

            dt = time.perf_counter() - t0
            for r in batch_reqs:
                if r.uid >= 0:
                    if not r.done:            # max_new_tokens == 0 edge
                        r.done = True
                        r.latency_s = dt
                    finished.append(r)
        return finished
