"""Seeded arrival-process generators on the simulated clock
(DESIGN.md Sec. 13).

Serving load is a *process*, not a number: the same mean rate arriving
Poisson-smooth, in on/off bursts, or on a diurnal swell stresses a
scheduler completely differently.  This module generates request
arrival times for the serving engine (`serving/engine.py`) as pure
functions of their configuration and seed — ``times(horizon)`` is
byte-identical across calls and processes (tests/test_arrivals.py),
the same determinism contract every ``repro.runtime`` quantity obeys —
so a latency percentile or a max-QPS search is reproducible down to
the individual request.

Three processes, all parameterized by a mean ``rate`` (requests per
simulated time unit) so they are comparable at equal offered load:

- :class:`PoissonArrivals` — homogeneous Poisson: i.i.d. exponential
  gaps, the memoryless baseline every queueing result is stated for.
- :class:`BurstyArrivals` — an on/off Markov-modulated Poisson
  process: exponential on/off dwell times, arrivals only while "on" at
  a rate inflated so the long-run mean is ``rate``.  Models flash
  crowds; its bursts are what admission control exists for.
- :class:`DiurnalArrivals` — inhomogeneous Poisson with a raised-
  cosine rate profile between ``trough_rate`` and ``peak_rate``
  (period ``period``), sampled by Lewis-Shedler thinning against the
  peak envelope.  Models the daily swell: capacity questions are
  asked at the peak, byte budgets at the mean.

``make_arrivals`` builds any of them by name (the ``bench_serve``
arrival-model axis and ``serve_stream(arrivals=...)`` both go through
it).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List

import numpy as np

__all__ = ["ArrivalProcess", "PoissonArrivals", "BurstyArrivals",
           "DiurnalArrivals", "make_arrivals", "ARRIVAL_KINDS"]


@dataclasses.dataclass(frozen=True)
class ArrivalProcess:
    """Base: a seeded point process on [0, horizon).

    Subclasses implement :meth:`times`; frozen dataclasses so a
    process value-hashes like the substrates do and can key caches /
    parametrize tests directly.
    """

    rate: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if self.rate <= 0:
            raise ValueError(f"rate must be > 0, got {self.rate}")

    @property
    def mean_rate(self) -> float:
        """Long-run arrivals per time unit (the offered load)."""
        return self.rate

    #: per-class stream tag: two processes with the same seed but
    #: different kinds never share draws.  A class constant (NOT
    #: ``hash(classname)``, which PYTHONHASHSEED randomizes per
    #: process) so ``times`` is byte-identical across processes.
    _KIND_TAG = 0

    def _rng(self) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([int(self.seed), 0xAA11, self._KIND_TAG]))

    def times(self, horizon: float) -> np.ndarray:
        """Sorted float64 arrival times in [0, horizon); pure function
        of (config, seed, horizon)."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class PoissonArrivals(ArrivalProcess):
    """Homogeneous Poisson process at ``rate``."""

    _KIND_TAG = 1

    def times(self, horizon: float) -> np.ndarray:
        rng = self._rng()
        out: List[np.ndarray] = []
        t, chunk = 0.0, max(16, int(self.rate * horizon * 1.1) + 8)
        while t < horizon:
            gaps = rng.exponential(1.0 / self.rate, size=chunk)
            ts = t + np.cumsum(gaps)
            out.append(ts)
            t = float(ts[-1])
        ts = np.concatenate(out)
        return ts[ts < horizon]


@dataclasses.dataclass(frozen=True)
class BurstyArrivals(ArrivalProcess):
    """On/off Markov-modulated Poisson process with long-run mean
    ``rate``.

    Dwell times are exponential with means ``mean_on`` / ``mean_off``;
    while on, arrivals are Poisson at ``rate / duty`` where
    ``duty = mean_on / (mean_on + mean_off)`` — so the *burst* rate
    exceeds the mean by 1/duty (4x at the default 25% duty cycle),
    which is exactly the overload a tick-grid scheduler hides and a
    bounded queue must answer with defer-or-shed.
    """

    mean_on: float = 1.0
    mean_off: float = 3.0

    _KIND_TAG = 2

    def __post_init__(self):
        super().__post_init__()
        if self.mean_on <= 0 or self.mean_off < 0:
            raise ValueError("mean_on must be > 0 and mean_off >= 0")

    @property
    def duty(self) -> float:
        return self.mean_on / (self.mean_on + self.mean_off)

    @property
    def burst_rate(self) -> float:
        """Arrival rate while a burst is on (= mean_rate / duty)."""
        return self.rate / self.duty

    def times(self, horizon: float) -> np.ndarray:
        rng = self._rng()
        lam = self.burst_rate
        out: List[float] = []
        t = 0.0
        on = bool(rng.random() < self.duty)   # stationary start
        while t < horizon:
            dwell = rng.exponential(self.mean_on if on else self.mean_off)
            end = min(t + dwell, horizon)
            if on:
                u = t + rng.exponential(1.0 / lam)
                while u < end:
                    out.append(u)
                    u += rng.exponential(1.0 / lam)
            t = t + dwell
            on = not on
        return np.asarray(out, np.float64)


@dataclasses.dataclass(frozen=True)
class DiurnalArrivals(ArrivalProcess):
    """Inhomogeneous Poisson with a raised-cosine daily profile.

    ``rate(t) = trough + (peak - trough) * (1 - cos(2 pi t / period)) / 2``
    — starts at the trough, crests at ``period / 2``.  ``rate`` (the
    dataclass field) is interpreted as the PEAK rate: SLO questions
    are peak questions.  Sampled by thinning against the peak
    envelope, so determinism needs no closed-form inverse.
    """

    trough_frac: float = 0.2      # trough_rate = trough_frac * peak
    period: float = 20.0

    _KIND_TAG = 3

    def __post_init__(self):
        super().__post_init__()
        if not (0.0 <= self.trough_frac <= 1.0):
            raise ValueError("trough_frac in [0, 1]")
        if self.period <= 0:
            raise ValueError("period must be > 0")

    @property
    def peak_rate(self) -> float:
        return self.rate

    @property
    def trough_rate(self) -> float:
        return self.trough_frac * self.rate

    @property
    def mean_rate(self) -> float:
        # mean of the raised cosine: midway between trough and peak
        return 0.5 * (self.trough_rate + self.peak_rate)

    def rate_at(self, t: float) -> float:
        swell = 0.5 * (1.0 - math.cos(2.0 * math.pi * t / self.period))
        return self.trough_rate + (self.peak_rate - self.trough_rate) * swell

    def times(self, horizon: float) -> np.ndarray:
        rng = self._rng()
        out: List[float] = []
        t = 0.0
        while True:
            t += rng.exponential(1.0 / self.peak_rate)
            if t >= horizon:
                break
            # thin: keep with prob rate(t)/peak (one uniform per
            # candidate, drawn unconditionally => deterministic order)
            if rng.random() < self.rate_at(t) / self.peak_rate:
                out.append(t)
        return np.asarray(out, np.float64)


ARRIVAL_KINDS = ("poisson", "bursty", "diurnal")


def make_arrivals(kind: str, rate: float, seed: int = 0,
                  **kw) -> ArrivalProcess:
    """Factory over :data:`ARRIVAL_KINDS`; extra keywords go to the
    process (``mean_on``/``mean_off``, ``trough_frac``/``period``)."""
    if kind == "poisson":
        return PoissonArrivals(rate=rate, seed=seed, **kw)
    if kind == "bursty":
        return BurstyArrivals(rate=rate, seed=seed, **kw)
    if kind == "diurnal":
        return DiurnalArrivals(rate=rate, seed=seed, **kw)
    raise ValueError(f"unknown arrival kind {kind!r}; "
                     f"expected one of {ARRIVAL_KINDS}")
