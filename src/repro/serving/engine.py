"""Substrate-native online serving engine (DESIGN.md Sec. 10).

The paper motivates the whole protocol as infrastructure for
"low-latency real-time services": m distributed learners answer
predict requests *while* they learn online and synchronize adaptively.
This module is that request path.  A :class:`KernelServingEngine`
fronts the m learners of any ``core.substrate.Substrate`` — SV
expansion, random Fourier features, linear; ``backend="reference"`` or
``"pallas"`` — and runs three things on ONE seeded discrete-event
timeline (the ``repro.runtime`` clock):

- **predict requests**, micro-batched per tick into padded batches of
  *static bucket sizes* and answered by one jitted
  ``Substrate.predict_batch`` call per bucket (each bucket size keys
  its own compile-cache entry, the same static-shape discipline as
  ``engine.sweep``'s grouped compiles).  Under an engaged
  ``backend="pallas"`` SV substrate the whole bucket is ONE fused
  ``kernels.ops.sv_predict`` launch — the serving hot path and the
  measured kernel are the same code (the ``bucket_predict_hits_pallas``
  claim in benchmarks/bench_kernels.py counts the launch to prove it);
- **labeled feedback**, queued per learner and applied as online
  updates: the moment every learner has its next example, the engine
  runs one protocol round through the scan engine's OWN step function
  (``engine.make_protocol_step``), so losses, sync decisions, and the
  Sec. 3 byte ledger are bit-identical to ``engine.run`` on the same
  stream *by construction* (tests/test_serving.py);
- **background synchronization**: when the dynamic/periodic protocol
  fires, the sync's Sec. 3 bytes are priced into simulated network
  time by the same seeded ``SystemModel`` the async runtime uses, and
  the transfer completes as a clock event — off the serving critical
  path, but on the same timeline the latency percentiles are measured
  on.

What is and isn't bit-identical: the *protocol view* (losses, errors,
sync rounds, bytes, eps) matches ``engine.run`` exactly, because both
compile the identical step over the identical carry
(``engine.init_protocol_carry``).  The *serving metrics* (latency
percentiles, queue depths, sync delays) have no scan-engine
counterpart — they exist only on the event timeline — and are
deterministic under the ``SystemConfig`` seed, like every
``repro.runtime`` quantity.

Mesh-awareness: pass ``mesh=`` (``launch.mesh.make_learner_mesh``) and
the engine routes each request to its *home shard* — per-tick batches
never mix learners from different shards, so the ``models[lids]``
gather inside ``predict_batch`` stays shard-local — and places the
stacked models with a learner-axis ``NamedSharding`` before the
predict calls.  ``launch.serve.make_kernel_serving_engine`` wraps the
mesh construction.  The protocol rounds themselves stay on the
single-device path: serving ticks are latency-bound, not
throughput-bound (the mesh-sharded *scan* engine of DESIGN.md Sec. 9
owns bulk simulation).

Benchmarked in benchmarks/bench_serve.py (EXPERIMENTS.md §Serving).
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import math
from collections import Counter, deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core import substrate as substrate_mod
from ..core.engine import (assemble_sim_result, init_protocol_carry,
                           learner_axes_of, make_protocol_step, params_of)
from ..core.protocol import ProtocolConfig
from ..core.simulation import SimResult
from ..core.substrate import Substrate
from ..runtime.clock import Clock, SystemConfig, SystemModel
from ..telemetry.trace import PID_SERVING, Tracer

Array = jnp.ndarray

#: Default padded-batch sizes.  Ascending; a tick's pending requests
#: are chunked to the largest bucket and each chunk padded up to the
#: smallest bucket that fits, so at most len(DEFAULT_BUCKETS) predict
#: executables ever compile per substrate.
DEFAULT_BUCKETS: Tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64)


# ---------------------------------------------------------------------------
# Requests and results
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PredictRequest:
    """One predict request: answer ``x`` with learner ``learner``'s
    current model.  ``arrival`` / ``done_time`` are simulated times on
    the engine's event clock; ``latency`` is their difference (queue
    wait until the next tick, plus any backlog of the single simulated
    predict server, plus this batch's ``predict_cost``)."""

    uid: int
    learner: int
    x: np.ndarray                    # (d,)
    arrival: float
    yhat: float = math.nan
    done_time: float = math.nan

    @property
    def done(self) -> bool:
        return not math.isnan(self.done_time)

    @property
    def latency(self) -> float:
        return self.done_time - self.arrival


@dataclasses.dataclass
class ServeResult:
    """What one serving run produced, on both of its faces.

    The protocol face is ``sim`` — a regular :class:`SimResult` whose
    losses/errors/bytes/sync decisions are bit-identical to
    ``engine.run`` on the same feedback stream (the serving parity
    contract).  The serving face is everything a latency SLO cares
    about: per-request latencies, per-tick queue depth, how big the
    served batches were, and how long each background sync spent on
    the simulated network.
    """

    sim: SimResult
    latencies: np.ndarray            # per served request, completion order
    queue_depth: np.ndarray          # pending predicts at each tick start
    bucket_counts: Dict[int, int]    # bucket size -> batches served
    sync_delays: np.ndarray          # simulated network time per sync
    rounds: int                      # protocol rounds applied
    ticks: int
    wall_clock: float                # simulated time at quiescence

    @property
    def num_requests(self) -> int:
        return int(len(self.latencies))

    @property
    def num_syncs(self) -> int:
        return self.sim.num_syncs

    @property
    def total_bytes(self) -> int:
        return self.sim.total_bytes

    @property
    def total_loss(self) -> float:
        return self.sim.total_loss

    def latency_percentiles(
            self, qs: Sequence[float] = (50.0, 90.0, 99.0),
    ) -> Dict[str, float]:
        """{"p50": ..., "p90": ..., "p99": ...} over served requests."""
        if not len(self.latencies):
            return {f"p{q:g}": math.nan for q in qs}
        return {f"p{q:g}": float(np.percentile(self.latencies, q))
                for q in qs}


# ---------------------------------------------------------------------------
# Jitted-op caches (one entry per substrate / static config, like
# engine._jitted: frozen substrates hash, so they key directly)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _round_op(sub: Substrate, kind: str, record_divergence: bool,
              topology: str):
    return jax.jit(make_protocol_step(
        sub, kind, record_divergence=record_divergence, topology=topology))


@functools.lru_cache(maxsize=None)
def _predict_op(sub: Substrate):
    # one jitted callable per substrate; each static bucket shape the
    # engine feeds it adds one executable to jit's own compile cache
    return jax.jit(sub.predict_batch)


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


class KernelServingEngine:
    """Online serving front for m distributed substrate learners.

    Usage (see also :func:`serve_stream` and
    examples/serve_quickstart.py)::

        eng = KernelServingEngine(sub, pcfg, m=4)
        eng.submit(x, learner=2, at=0.7)          # predict request
        eng.feedback(x, y, learner=2, at=1.1)     # labeled example
        res = eng.serve()                         # run clock to drain
        res.latency_percentiles(), res.sim.total_bytes

    ``submit`` / ``feedback`` schedule *arrivals* on the event clock;
    nothing computes until :meth:`serve` runs the clock.  Ticks fire on
    a fixed ``tick_interval`` grid, but only while there is work — the
    clock drains to quiescence exactly like the async runtime's.

    Constructor keywords mirror ``engine.run``'s resolver semantics
    (``substrate_of``): ``sync_budget`` / ``compress_method`` /
    ``backend`` are ``None`` sentinels meaning "keep the substrate's
    own configuration".

    ``tracer`` (a ``repro.telemetry.Tracer``, DESIGN.md Sec. 11)
    records the request lifecycle on the engine's simulated clock:
    an ``enqueue`` instant at arrival, a ``request`` span
    arrival -> reply, per-batch ``predict/bucket<B>`` spans, queue-depth
    and bucket-occupancy counter tracks, per-round protocol instants
    and ``sync/transfer`` spans carrying their Sec. 3 bytes.  No
    tracer, no cost — and never any change to the jitted step.
    """

    def __init__(
        self,
        learner,
        pcfg: ProtocolConfig,
        m: int,
        *,
        sync_budget: Optional[int] = None,
        compress_method: Optional[str] = None,   # None -> substrate's own
        backend: Optional[str] = None,           # None -> substrate's own
        topology: str = "coordinator",
        mesh: Optional[Mesh] = None,
        sys_cfg: Optional[SystemConfig] = None,
        tick_interval: float = 1.0,
        predict_cost: float = 0.0,
        buckets: Sequence[int] = DEFAULT_BUCKETS,
        record_divergence: bool = False,
        tracer: Optional[Tracer] = None,
    ):
        if m < 1:
            raise ValueError(f"need at least one learner, got m={m}")
        if tick_interval <= 0:
            raise ValueError(f"tick_interval must be > 0, got {tick_interval}")
        if predict_cost < 0:
            raise ValueError(f"predict_cost must be >= 0, got {predict_cost}")
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        if not self.buckets or self.buckets[0] < 1:
            raise ValueError(f"buckets must be positive ints, got {buckets}")

        self.sub = substrate_mod.substrate_of(
            learner, sync_budget=sync_budget,
            compress_method=compress_method, backend=backend)
        self.pcfg = pcfg
        self.m = int(m)
        self.d = int(self.sub.input_dim)
        self.tick_interval = float(tick_interval)
        self.predict_cost = float(predict_cost)
        self.record_divergence = bool(record_divergence)

        # protocol round: the scan engine's own step, jitted standalone
        self._params = params_of(pcfg)
        self._round = _round_op(self.sub, pcfg.kind,
                                self.record_divergence, topology)
        self._predict = _predict_op(self.sub)
        self._carry = init_protocol_carry(self.sub, self.m)
        self._t = 0

        # home-shard routing (mesh mode)
        if mesh is not None:
            axes = learner_axes_of(mesh)
            n_shards = math.prod(mesh.shape[a] for a in axes)
            if self.m % n_shards:
                raise ValueError(
                    f"{self.m} learners cannot shard evenly over "
                    f"{n_shards} devices (mesh axes {axes})")
            self._per_shard = self.m // n_shards
            lead = axes if len(axes) > 1 else axes[0]
            self._model_sharding = NamedSharding(mesh, P(lead))
        else:
            self._per_shard = None
            self._model_sharding = None

        # the seeded timeline (shared clock model with repro.runtime);
        # the tracer rides on it so every span below is simulated time
        # (telemetry/trace.py: byte-identical export under seed)
        self.tracer = tracer
        self.clock = Clock(tracer=tracer)
        self.system = SystemModel(sys_cfg or SystemConfig(), self.m)

        self._uid = itertools.count()
        self._pending: List[PredictRequest] = []
        self._fb: List[Deque[Tuple[np.ndarray, float]]] = [
            deque() for _ in range(self.m)]
        self._served: List[PredictRequest] = []
        self._tick_scheduled = False
        self._ticks = 0
        # the predict server is ONE simulated compute resource: a
        # tick's batches start no earlier than the previous tick's
        # batches finished, so predict_cost is never double-booked
        self._busy_until = 0.0
        # stacked models placed for predict, rebuilt only after a
        # protocol round mutates the carry
        self._placed_models = None

        # per-round protocol series (stacked at result() time exactly
        # like engine.run's host-side post-processing)
        self._loss_rows: List[np.ndarray] = []
        self._err_rows: List[np.ndarray] = []
        self._byte_rows: List[int] = []
        self._div_rows: List[np.floating] = []
        self._flag_rows: List[bool] = []
        self._eps_rows: List[np.floating] = []
        self._queue_depth: List[int] = []
        self._sync_delays: List[float] = []
        self._bucket_counts: Counter = Counter()

    # -- request ingress -----------------------------------------------------

    def home_shard(self, learner: int) -> int:
        """The mesh shard holding this learner's model slice (0 when
        unmeshed): contiguous blocks of m / n_shards learners, the
        layout ``NamedSharding(mesh, P('learners'))`` places."""
        if self._per_shard is None:
            return 0
        return int(learner) // self._per_shard

    def _check_ingress(self, x, learner: int, at: float) -> np.ndarray:
        x = np.asarray(x, np.float32)
        if x.shape != (self.d,):
            raise ValueError(f"x shape {x.shape} != ({self.d},)")
        if not (0 <= learner < self.m):
            raise ValueError(f"learner {learner} not in [0, {self.m})")
        if at < self.clock.now:
            raise ValueError(
                f"arrival {at} is in the past (clock at {self.clock.now})")
        return x

    def submit(self, x, *, learner: int = 0, at: float = 0.0,
               ) -> PredictRequest:
        """Schedule a predict request arriving at simulated time ``at``;
        it is answered (``yhat`` / ``done_time`` filled) by the next
        tick after arrival."""
        x = self._check_ingress(x, learner, at)
        req = PredictRequest(uid=next(self._uid), learner=int(learner),
                             x=x, arrival=float(at))
        self.clock.schedule(at - self.clock.now,
                            lambda: self._arrive_predict(req))
        return req

    def feedback(self, x, y, *, learner: int, at: float = 0.0) -> None:
        """Schedule a labeled example arriving at simulated time ``at``.
        Examples queue per learner FIFO; each time every learner has
        one queued, the next tick applies one full protocol round (the
        lockstep round structure the parity contract needs)."""
        x = self._check_ingress(x, learner, at)
        item = (x, float(y))
        self.clock.schedule(
            at - self.clock.now,
            lambda: self._arrive_feedback(int(learner), item))

    # -- event handlers ------------------------------------------------------

    def _arrive_predict(self, req: PredictRequest) -> None:
        self._pending.append(req)
        if self.tracer is not None:
            self.tracer.instant(
                "enqueue", self.clock.now, pid=PID_SERVING,
                tid=self.tracer.tid(PID_SERVING, "requests"),
                args={"uid": req.uid, "learner": req.learner})
        self._ensure_tick()

    def _arrive_feedback(self, learner: int,
                         item: Tuple[np.ndarray, float]) -> None:
        self._fb[learner].append(item)
        if all(self._fb):          # a full round is ready
            self._ensure_tick()

    def _ensure_tick(self) -> None:
        if self._tick_scheduled:
            return
        self._tick_scheduled = True
        # next grid point strictly after now
        k = math.floor(self.clock.now / self.tick_interval + 1e-9) + 1
        self.clock.schedule(k * self.tick_interval - self.clock.now,
                            self._tick)

    # -- the tick ------------------------------------------------------------

    def _route(self) -> List[List[PredictRequest]]:
        """Pending requests grouped by home shard (arrival order kept
        within each group); one group when unmeshed."""
        if self._per_shard is None:
            return [self._pending] if self._pending else []
        groups: Dict[int, List[PredictRequest]] = {}
        for r in self._pending:
            groups.setdefault(self.home_shard(r.learner), []).append(r)
        return [groups[s] for s in sorted(groups)]

    def _bucket_of(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        raise AssertionError(      # _tick chunks by buckets[-1] first
            f"chunk of {n} exceeds the largest bucket {self.buckets[-1]}")

    def _models_for_predict(self):
        if self._placed_models is None:
            models = self.sub.models_of(self._carry[0])
            if self._model_sharding is not None:
                models = jax.device_put(models, self._model_sharding)
            self._placed_models = models
        return self._placed_models

    def _tick(self) -> None:
        self._tick_scheduled = False
        self._ticks += 1
        self._queue_depth.append(len(self._pending))
        tracer = self.tracer
        if tracer is not None:
            # queue-depth counter track, sampled at every tick start
            tracer.counter("serve/queue_depth", self.clock.now,
                           {"pending": len(self._pending)},
                           pid=PID_SERVING)
        cursor = max(self.clock.now, self._busy_until)

        if self._pending:
            models = self._models_for_predict()
            max_b = self.buckets[-1]
            for group in self._route():
                for lo in range(0, len(group), max_b):
                    chunk = group[lo:lo + max_b]
                    bucket = self._bucket_of(len(chunk))
                    # padding rows reuse the chunk's first learner id so
                    # the gather never reaches outside the home shard
                    lids = np.full((bucket,), chunk[0].learner, np.int32)
                    Xb = np.zeros((bucket, self.d), np.float32)
                    for i, r in enumerate(chunk):
                        lids[i] = r.learner
                        Xb[i] = r.x
                    yh = np.asarray(self._predict(
                        models, jnp.asarray(lids), jnp.asarray(Xb)))
                    batch_start = cursor
                    cursor += self.predict_cost
                    self._bucket_counts[bucket] += 1
                    for i, r in enumerate(chunk):
                        r.yhat = float(yh[i])
                        r.done_time = cursor
                    self._served.extend(chunk)
                    if tracer is not None:
                        tid = tracer.tid(PID_SERVING, "predict")
                        tracer.complete(
                            f"predict/bucket{bucket}", batch_start,
                            self.predict_cost, pid=PID_SERVING, tid=tid,
                            args={"bucket": bucket, "filled": len(chunk),
                                  "shard": self.home_shard(
                                      chunk[0].learner)})
                        tracer.counter(
                            "serve/bucket_occupancy", batch_start,
                            {"filled": len(chunk), "bucket": bucket},
                            pid=PID_SERVING)
                        # request lifecycle: enqueue instant at arrival
                        # (recorded then) -> this span closes the loop
                        rtid = tracer.tid(PID_SERVING, "requests")
                        for r in chunk:
                            tracer.complete(
                                "request", r.arrival,
                                r.done_time - r.arrival,
                                pid=PID_SERVING, tid=rtid,
                                args={"uid": r.uid, "learner": r.learner,
                                      "bucket": bucket})
            self._pending.clear()
            self._busy_until = cursor
            if cursor > self.clock.now:
                # completion lands on the timeline so wall_clock and
                # done_time can never disagree
                self.clock.schedule(cursor - self.clock.now, lambda: None)

        while all(self._fb):
            xs = np.stack([self._fb[i][0][0] for i in range(self.m)])
            ys = np.asarray([self._fb[i][0][1] for i in range(self.m)],
                            np.float32)
            for q in self._fb:
                q.popleft()
            self._apply_round(xs, ys)

    def _apply_round(self, x_row: np.ndarray, y_row: np.ndarray) -> None:
        """One protocol round through the scan engine's step (the
        parity-critical path — see the module docstring)."""
        self.sub.validate(self._t + 1, self.m, self.d)   # sv_id capacity
        xs = (jnp.asarray(x_row), jnp.asarray(y_row),
              jnp.asarray(self._t, jnp.int32))
        self._carry, outs = self._round(self._params, self._carry, xs)
        self._placed_models = None      # next tick re-places the models
        loss, err, nbytes, div, flag, eps = outs
        self._loss_rows.append(np.asarray(loss))
        self._err_rows.append(np.asarray(err))
        self._byte_rows.append(int(nbytes))
        self._div_rows.append(np.asarray(div))
        self._eps_rows.append(np.asarray(eps))
        fired = bool(flag)
        self._flag_rows.append(fired)
        self._t += 1
        if self.tracer is not None:
            self.tracer.instant(
                "round", self.clock.now, pid=PID_SERVING,
                tid=self.tracer.tid(PID_SERVING, "protocol"),
                args={"t": self._t - 1, "nbytes": int(nbytes),
                      "sync": fired})
        if fired:
            # background sync: price the Sec. 3 bytes into simulated
            # network time (same seeded draw order as the runtime's
            # transport) and let it complete as a clock event — it
            # never blocks the tick loop, but wall_clock sees it.
            delay = self.system.draw_latency(int(nbytes))
            self._sync_delays.append(delay)
            if self.tracer is not None:
                # the sync transfer span, carrying its Sec. 3 bytes
                self.tracer.complete(
                    "sync/transfer", self.clock.now, delay,
                    pid=PID_SERVING,
                    tid=self.tracer.tid(PID_SERVING, "protocol"),
                    args={"t": self._t - 1, "nbytes": int(nbytes)})
            if delay > 0:
                self.clock.schedule(delay, lambda: None)

    # -- running and results -------------------------------------------------

    @property
    def rounds_applied(self) -> int:
        return self._t

    def serve(self) -> ServeResult:
        """Run the event clock to quiescence and package the results."""
        self.clock.run()
        return self.result()

    def result(self) -> ServeResult:
        """Snapshot of everything served/learned so far.  The ``sim``
        field is assembled by ``engine.assemble_sim_result`` — the SAME
        host-side post-processing ``engine.run`` uses (per-learner
        stacking, fixed-order numpy sums, float64/int64 accumulation) —
        which is the second half of the bit-for-bit parity contract."""
        if self._t:
            loss = np.stack(self._loss_rows)          # (T, m) float32
            err = np.stack(self._err_rows)
            div = np.stack(self._div_rows)
            eps = np.stack(self._eps_rows)
        else:
            loss = np.zeros((0, self.m), np.float32)
            err = np.zeros((0, self.m), np.float32)
            div = np.zeros((0,), np.float32)
            eps = np.zeros((0,), np.float32)
        sim = assemble_sim_result(
            self.sub, self.record_divergence, loss, err,
            np.asarray(self._byte_rows, np.int64), div,
            np.asarray(self._flag_rows, bool), eps)
        return ServeResult(
            sim=sim,
            latencies=np.asarray([r.latency for r in self._served]),
            queue_depth=np.asarray(self._queue_depth, np.int64),
            bucket_counts=dict(self._bucket_counts),
            sync_delays=np.asarray(self._sync_delays),
            rounds=self._t,
            ticks=self._ticks,
            wall_clock=self.clock.now,
        )


# ---------------------------------------------------------------------------
# Stream replay
# ---------------------------------------------------------------------------


def serve_stream(
    learner,
    pcfg: ProtocolConfig,
    X: np.ndarray,          # (T, m, d)
    Y: np.ndarray,          # (T, m)
    *,
    queries_per_round: float = 0.0,
    query_seed: int = 0,
    **engine_kw,
) -> ServeResult:
    """Replay a (T, m, d) protocol stream through the serving engine.

    Learner i's round-t labeled example arrives when that learner
    finishes computing round t on the seeded timeline — the cumulative
    sum of the SAME ``SystemModel.draw_compute`` table the async
    runtime prices barriers with, so serving and async experiments
    share one clock model.  Per-learner arrival order is monotone
    (compute times are positive), which preserves the stream order the
    parity contract needs.

    ``queries_per_round * T`` predict-only requests (seeded uniform
    arrivals over the feedback horizon, home learner uniform, inputs
    resampled from the stream) exercise the micro-batching path; they
    read model state and never touch it, so the protocol view stays
    bit-identical to ``engine.run(learner, pcfg, X, Y)`` at any query
    rate.  ``engine_kw`` forwards to :class:`KernelServingEngine`.
    """
    X = np.asarray(X, np.float32)
    Y = np.asarray(Y, np.float32)
    T, m, d = X.shape
    eng = KernelServingEngine(learner, pcfg, m, **engine_kw)
    eng.sub.validate(T, m, d)
    arrive = np.cumsum(eng.system.draw_compute(T), axis=0)   # (T, m)
    for t in range(T):
        for i in range(m):
            eng.feedback(X[t, i], Y[t, i], learner=i,
                         at=float(arrive[t, i]))
    n_q = int(round(queries_per_round * T))
    if n_q:
        rng = np.random.default_rng(query_seed)
        horizon = float(arrive.max())
        times = np.sort(rng.uniform(0.0, horizon, size=n_q))
        for tq in times:
            lid = int(rng.integers(m))
            x = X[int(rng.integers(T)), lid]
            eng.submit(x, learner=lid, at=float(tq))
    return eng.serve()
