"""Substrate-native online serving engine (DESIGN.md Secs. 10, 13).

The paper motivates the whole protocol as infrastructure for
"low-latency real-time services": m distributed learners answer
predict requests *while* they learn online and synchronize adaptively.
This module is that request path.  A :class:`KernelServingEngine`
fronts the m learners of any ``core.substrate.Substrate`` — SV
expansion, random Fourier features, linear; ``backend="reference"`` or
``"pallas"`` — and runs three things on ONE seeded discrete-event
timeline (the ``repro.runtime`` clock):

- **predict requests**, scheduled by a pluggable batch policy
  (``serving/scheduler.py``): ``policy="continuous"`` admits requests
  into a fixed pool of in-flight slots per shard the moment they
  arrive (continuous batching — launch size picked from queue depth
  and the remaining latency budget); ``policy="tick"`` is the legacy
  grid (wait for the next ``tick_interval`` point, drain through the
  static bucket ladder).  Either way a launch is ONE jitted
  ``Substrate.predict_batch`` call on a statically-shaped padded
  bucket — under an engaged ``backend="pallas"`` SV substrate that is
  one fused ``kernels.ops.sv_predict`` launch — and admission control
  (bounded queue, defer-or-shed under overload) prices every decision
  on the event clock;
- **labeled feedback**, queued per learner and applied as online
  updates: the moment every learner has its next example, the engine
  runs one protocol round through the scan engine's OWN step function
  (``engine.make_protocol_step``), so losses, sync decisions, and the
  Sec. 3 byte ledger are bit-identical to ``engine.run`` on the same
  stream *by construction* (tests/test_serving.py).  Rounds apply at
  feedback-completion time, independent of any predict scheduling —
  which is what makes the parity contract hold under EVERY batch
  policy, arrival process and overload level: no scheduler decision
  can reach the protocol state;
- **background synchronization**: when the dynamic/periodic protocol
  fires, the sync's Sec. 3 bytes are priced into simulated network
  time by the same seeded ``SystemModel`` the async runtime uses, and
  the transfer completes as a clock event — off the serving critical
  path, but on the same timeline the latency percentiles are measured
  on.

**Multi-tenancy.** Several protocol instances can share one engine,
one slot pool and one admission queue: ``add_tenant(learner, pcfg)``
registers another (substrate, protocol) pair over the same m learners
and returns its tenant id; ``submit``/``feedback`` take ``tenant=``.
Launches never mix tenants (each chunk is one (tenant, shard) group,
so the model gather stays tenant-local), and each tenant's protocol
view is independently bit-identical to its own ``engine.run`` — the
sharing is purely of simulated compute and queue capacity.

What is and isn't bit-identical: the *protocol view* (losses, errors,
sync rounds, bytes, eps) matches ``engine.run`` exactly, because both
compile the identical step over the identical carry
(``engine.init_protocol_carry``).  The *serving metrics* (latency
percentiles, queue depths, shed/defer counts, sync delays) have no
scan-engine counterpart — they exist only on the event timeline — and
are deterministic under the ``SystemConfig`` seed, like every
``repro.runtime`` quantity.

Mesh-awareness: pass ``mesh=`` (``launch.mesh.make_learner_mesh``) and
the engine routes each request to its *home shard* — batches never mix
learners from different shards, so the ``models[lids]`` gather inside
``predict_batch`` stays shard-local — each shard gets its own slot
pool, and the stacked models are placed with a learner-axis
``NamedSharding`` before the predict calls.
``launch.serve.make_kernel_serving_engine`` wraps the mesh
construction.

Benchmarked in benchmarks/bench_serve.py (EXPERIMENTS.md §Serving),
including the max-sustainable-QPS-at-p99 search of continuous vs
static batching.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import math
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core import substrate as substrate_mod
from ..core.engine import (assemble_sim_result, init_protocol_carry,
                           learner_axes_of, make_protocol_step, params_of)
from ..core.protocol import ProtocolConfig
from ..core.simulation import SimResult
from ..core.substrate import Substrate
from ..runtime.clock import Clock, SystemConfig, SystemModel
from ..telemetry.trace import PID_SERVING, Tracer
from .arrivals import ArrivalProcess
from .scheduler import POLICIES, SlotScheduler, make_scheduler

Array = jnp.ndarray

#: Default padded-batch sizes.  Ascending; a launch's requests are
#: chunked to the largest bucket and each chunk padded up to the
#: smallest bucket that fits, so at most len(DEFAULT_BUCKETS) predict
#: executables ever compile per substrate.
DEFAULT_BUCKETS: Tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64)


# ---------------------------------------------------------------------------
# Requests and results
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PredictRequest:
    """One predict request: answer ``x`` with learner ``learner``'s
    current model in tenant ``tenant``.  ``arrival`` / ``done_time``
    are simulated times on the engine's event clock; ``latency`` is
    their difference and includes every scheduling decision along the
    way (queue wait, slot contention, deferral retries).  A ``shed``
    request was refused by admission control and never answered."""

    uid: int
    learner: int
    x: np.ndarray                    # (d,)
    arrival: float
    tenant: int = 0
    yhat: float = math.nan
    done_time: float = math.nan
    shed: bool = False
    deferrals: int = 0

    @property
    def done(self) -> bool:
        return not math.isnan(self.done_time)

    @property
    def latency(self) -> float:
        return self.done_time - self.arrival


@dataclasses.dataclass
class ServeResult:
    """What one serving run produced, on both of its faces.

    The protocol face is ``sim`` — a regular :class:`SimResult` whose
    losses/errors/bytes/sync decisions are bit-identical to
    ``engine.run`` on the same feedback stream (the serving parity
    contract), per tenant.  The serving face is everything a latency
    SLO cares about: per-request latencies, queue-depth samples, how
    big the served batches were, admission outcomes (shed/deferred),
    and how long each background sync spent on the simulated network.

    ``latencies``, ``sync_delays`` and ``rounds`` are the tenant's
    own; ``queue_depth``, ``bucket_counts``, ``launches`` and the
    admission counters are engine-wide (the queue and slot pool are
    shared across tenants).  All summary statistics are NaN-free by
    construction, including on empty and single-request runs
    (tests/test_serving.py::test_serve_result_empty_and_single_stats).
    """

    sim: SimResult
    latencies: np.ndarray            # per served request, completion order
    queue_depth: np.ndarray          # pending predicts at each sample
    bucket_counts: Dict[int, int]    # bucket size -> batches served
    sync_delays: np.ndarray          # simulated network time per sync
    rounds: int                      # protocol rounds applied
    ticks: int                       # tick events (0 under continuous)
    wall_clock: float                # simulated time at quiescence
    launches: int = 0                # predict batches launched
    num_shed: int = 0                # requests refused by admission
    num_deferred: int = 0            # deferral retries priced on the clock
    policy: str = "tick"
    slots: int = 1

    @property
    def num_requests(self) -> int:
        return int(len(self.latencies))

    @property
    def num_syncs(self) -> int:
        return self.sim.num_syncs

    @property
    def total_bytes(self) -> int:
        return self.sim.total_bytes

    @property
    def total_loss(self) -> float:
        return self.sim.total_loss

    @property
    def mean_latency(self) -> float:
        return float(self.latencies.mean()) if len(self.latencies) else 0.0

    @property
    def max_latency(self) -> float:
        return float(self.latencies.max()) if len(self.latencies) else 0.0

    @property
    def mean_queue_depth(self) -> float:
        return (float(self.queue_depth.mean())
                if len(self.queue_depth) else 0.0)

    @property
    def max_queue_depth(self) -> int:
        return int(self.queue_depth.max()) if len(self.queue_depth) else 0

    def latency_percentiles(
            self, qs: Sequence[float] = (50.0, 90.0, 99.0),
    ) -> Dict[str, float]:
        """{"p50": ..., "p90": ..., "p99": ...} over served requests.
        Well-defined on degenerate runs: zero served requests gives
        0.0 everywhere (nothing waited), one request gives its own
        latency at every percentile — never NaN."""
        if not len(self.latencies):
            return {f"p{q:g}": 0.0 for q in qs}
        return {f"p{q:g}": float(np.percentile(self.latencies, q))
                for q in qs}

    def summary(self) -> Dict[str, float]:
        """Flat NaN-free scalar summary of the serving face (bench
        rows and reports are built from this)."""
        out = {"requests": float(self.num_requests),
               "rounds": float(self.rounds),
               "launches": float(self.launches),
               "shed": float(self.num_shed),
               "deferred": float(self.num_deferred),
               "mean_latency": self.mean_latency,
               "max_latency": self.max_latency,
               "mean_queue_depth": self.mean_queue_depth,
               "wall_clock": float(self.wall_clock)}
        out.update(self.latency_percentiles())
        return out


# ---------------------------------------------------------------------------
# Jitted-op caches (one entry per substrate / static config, like
# engine._jitted: frozen substrates hash, so they key directly)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _round_op(sub: Substrate, kind: str, record_divergence: bool,
              topology: str):
    return jax.jit(make_protocol_step(
        sub, kind, record_divergence=record_divergence, topology=topology))


@functools.lru_cache(maxsize=None)
def _predict_op(sub: Substrate):
    # one jitted callable per substrate; each static bucket shape the
    # engine feeds it adds one executable to jit's own compile cache
    return jax.jit(sub.predict_batch)


# ---------------------------------------------------------------------------
# Per-tenant protocol state
# ---------------------------------------------------------------------------


class _Tenant:
    """One (substrate, protocol) instance behind the shared engine:
    its own carry, feedback queues, per-round series and placed-model
    cache.  Never touches the scheduler."""

    def __init__(self, tid: int, sub: Substrate, pcfg: ProtocolConfig,
                 m: int, topology: str, record_divergence: bool,
                 name: Optional[str] = None):
        self.tid = tid
        self.name = name or f"tenant{tid}"
        self.sub = sub
        self.pcfg = pcfg
        self.record_divergence = bool(record_divergence)
        self.params = params_of(pcfg)
        self.round_op = _round_op(sub, pcfg.kind, self.record_divergence,
                                  topology)
        self.predict_op = _predict_op(sub)
        self.carry = init_protocol_carry(sub, m)
        self.t = 0
        self.fb: List[Deque[Tuple[np.ndarray, float]]] = [
            deque() for _ in range(m)]
        self.served: List[PredictRequest] = []
        self.placed_models = None
        self.loss_rows: List[np.ndarray] = []
        self.err_rows: List[np.ndarray] = []
        self.byte_rows: List[int] = []
        self.div_rows: List[np.floating] = []
        self.flag_rows: List[bool] = []
        self.eps_rows: List[np.floating] = []
        self.sync_delays: List[float] = []


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


class KernelServingEngine:
    """Online serving front for m distributed substrate learners.

    Usage (see also :func:`serve_stream` and
    examples/serve_quickstart.py)::

        eng = KernelServingEngine(sub, pcfg, m=4, policy="continuous",
                                  slots=2, slo=0.25, max_queue=256)
        eng.submit(x, learner=2, at=0.7)          # predict request
        eng.feedback(x, y, learner=2, at=1.1)     # labeled example
        res = eng.serve()                         # run clock to drain
        res.latency_percentiles(), res.sim.total_bytes

    ``submit`` / ``feedback`` schedule *arrivals* on the event clock;
    nothing computes until :meth:`serve` runs the clock.  The batch
    policy decides when admitted requests launch (``policy=``
    "continuous" or "tick"); the clock drains to quiescence exactly
    like the async runtime's.

    Constructor keywords mirror ``engine.run``'s resolver semantics
    (``substrate_of``): ``sync_budget`` / ``compress_method`` /
    ``backend`` are ``None`` sentinels meaning "keep the substrate's
    own configuration".  Scheduling keywords (`serving/scheduler.py`):

    - ``policy``: "tick" (grid micro-batching, the PR 5 baseline) or
      "continuous" (slotted continuous batching);
    - ``slots``: in-flight predict lanes per shard;
    - ``max_queue`` / ``overload`` / ``defer_interval``: admission
      control — bounded pending queue, "shed" or "defer" over it;
    - ``slo`` / ``max_wait``: the latency target; continuous batching
      spends at most the budget's slack waiting for batches to fill.

    ``tracer`` (a ``repro.telemetry.Tracer``, DESIGN.md Sec. 11)
    records the request lifecycle on the engine's simulated clock:
    an ``enqueue`` instant at arrival, ``shed``/``defer`` admission
    instants, a ``request`` span arrival -> reply, per-batch
    ``predict/bucket<B>`` spans, queue-depth / bucket-occupancy /
    in-flight counter tracks, per-round protocol instants and
    ``sync/transfer`` spans carrying their Sec. 3 bytes.  No tracer,
    no cost — and never any change to the jitted step.
    """

    def __init__(
        self,
        learner,
        pcfg: ProtocolConfig,
        m: int,
        *,
        sync_budget: Optional[int] = None,
        compress_method: Optional[str] = None,   # None -> substrate's own
        backend: Optional[str] = None,           # None -> substrate's own
        topology: str = "coordinator",
        mesh: Optional[Mesh] = None,
        sys_cfg: Optional[SystemConfig] = None,
        tick_interval: float = 1.0,
        predict_cost: float = 0.0,
        buckets: Sequence[int] = DEFAULT_BUCKETS,
        record_divergence: bool = False,
        tracer: Optional[Tracer] = None,
        policy: str = "tick",
        slots: int = 1,
        max_queue: Optional[int] = None,
        overload: str = "shed",
        defer_interval: Optional[float] = None,
        slo: Optional[float] = None,
        max_wait: Optional[float] = None,
    ):
        if m < 1:
            raise ValueError(f"need at least one learner, got m={m}")
        if tick_interval <= 0:
            raise ValueError(f"tick_interval must be > 0, got {tick_interval}")
        if predict_cost < 0:
            raise ValueError(f"predict_cost must be >= 0, got {predict_cost}")
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, "
                             f"got {policy!r}")
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        if not self.buckets or self.buckets[0] < 1:
            raise ValueError(f"buckets must be positive ints, got {buckets}")

        self.m = int(m)
        self.topology = topology
        self.tick_interval = float(tick_interval)
        self.predict_cost = float(predict_cost)
        self.record_divergence = bool(record_divergence)

        # home-shard routing (mesh mode)
        if mesh is not None:
            axes = learner_axes_of(mesh)
            n_shards = math.prod(mesh.shape[a] for a in axes)
            if self.m % n_shards:
                raise ValueError(
                    f"{self.m} learners cannot shard evenly over "
                    f"{n_shards} devices (mesh axes {axes})")
            self._per_shard = self.m // n_shards
            self._n_shards = n_shards
            lead = axes if len(axes) > 1 else axes[0]
            self._model_sharding = NamedSharding(mesh, P(lead))
        else:
            self._per_shard = None
            self._n_shards = 1
            self._model_sharding = None

        # the seeded timeline (shared clock model with repro.runtime);
        # the tracer rides on it so every span below is simulated time
        # (telemetry/trace.py: byte-identical export under seed)
        self.tracer = tracer
        self.clock = Clock(tracer=tracer)
        self.system = SystemModel(sys_cfg or SystemConfig(), self.m)

        # tenant 0 is the constructor's (learner, pcfg)
        self._tenants: List[_Tenant] = []
        self.add_tenant(learner, pcfg, sync_budget=sync_budget,
                        compress_method=compress_method, backend=backend,
                        record_divergence=record_divergence)

        # the predict path: slot pools + batch policy + admission
        self.scheduler: SlotScheduler = make_scheduler(
            policy,
            clock=self.clock,
            predict_fn=self._predict_chunk,
            shard_of=self.home_shard,
            n_shards=self._n_shards,
            buckets=self.buckets,
            predict_cost=self.predict_cost,
            slots=slots,
            max_queue=max_queue,
            overload=overload,
            defer_interval=defer_interval,
            tick_interval=self.tick_interval,
            slo=slo,
            max_wait=max_wait,
            tracer=tracer,
        )
        self.policy = policy
        self._uid = itertools.count()

    # -- tenants -------------------------------------------------------------

    @property
    def sub(self) -> Substrate:
        """Tenant 0's substrate (the single-tenant engine's face)."""
        return self._tenants[0].sub

    @property
    def pcfg(self) -> ProtocolConfig:
        return self._tenants[0].pcfg

    @property
    def d(self) -> int:
        return int(self._tenants[0].sub.input_dim)

    @property
    def num_tenants(self) -> int:
        return len(self._tenants)

    def add_tenant(
        self,
        learner,
        pcfg: ProtocolConfig,
        *,
        sync_budget: Optional[int] = None,
        compress_method: Optional[str] = None,
        backend: Optional[str] = None,
        record_divergence: Optional[bool] = None,
        name: Optional[str] = None,
    ) -> int:
        """Register another protocol instance over the same m learners
        behind the shared slot pool; returns its tenant id.  All
        tenants must share the input dimension (requests are routed by
        (tenant, learner) and carry one ``x`` shape)."""
        sub = substrate_mod.substrate_of(
            learner, sync_budget=sync_budget,
            compress_method=compress_method, backend=backend)
        if self._tenants and int(sub.input_dim) != self.d:
            raise ValueError(
                f"tenant input_dim {sub.input_dim} != engine d {self.d}")
        rec = (self.record_divergence if record_divergence is None
               else bool(record_divergence))
        ten = _Tenant(len(self._tenants), sub, pcfg, self.m, self.topology,
                      rec, name=name)
        self._tenants.append(ten)
        return ten.tid

    def _tenant(self, tenant: int) -> _Tenant:
        if not (0 <= tenant < len(self._tenants)):
            raise ValueError(f"tenant {tenant} not in "
                             f"[0, {len(self._tenants)})")
        return self._tenants[tenant]

    # -- request ingress -----------------------------------------------------

    def home_shard(self, learner: int) -> int:
        """The mesh shard holding this learner's model slice (0 when
        unmeshed): contiguous blocks of m / n_shards learners, the
        layout ``NamedSharding(mesh, P('learners'))`` places."""
        if self._per_shard is None:
            return 0
        return int(learner) // self._per_shard

    def _check_ingress(self, x, learner: int, at: float) -> np.ndarray:
        x = np.asarray(x, np.float32)
        if x.shape != (self.d,):
            raise ValueError(f"x shape {x.shape} != ({self.d},)")
        if not (0 <= learner < self.m):
            raise ValueError(f"learner {learner} not in [0, {self.m})")
        if at < self.clock.now:
            raise ValueError(
                f"arrival {at} is in the past (clock at {self.clock.now})")
        return x

    def submit(self, x, *, learner: int = 0, at: float = 0.0,
               tenant: int = 0) -> PredictRequest:
        """Schedule a predict request arriving at simulated time ``at``;
        the batch policy answers it (``yhat`` / ``done_time`` filled)
        — or admission control sheds it (``shed`` set, never served)."""
        x = self._check_ingress(x, learner, at)
        self._tenant(tenant)
        req = PredictRequest(uid=next(self._uid), learner=int(learner),
                             x=x, arrival=float(at), tenant=int(tenant))
        self.clock.schedule(at - self.clock.now,
                            lambda: self._arrive_predict(req))
        return req

    def feedback(self, x, y, *, learner: int, at: float = 0.0,
                 tenant: int = 0) -> None:
        """Schedule a labeled example arriving at simulated time ``at``.
        Examples queue per learner FIFO; each time every learner has
        one queued, one full protocol round applies immediately (the
        lockstep round structure the parity contract needs).  Feedback
        is never admission-controlled: the learning stream cannot be
        shed without changing the protocol view."""
        x = self._check_ingress(x, learner, at)
        self._tenant(tenant)
        item = (x, float(y))
        self.clock.schedule(
            at - self.clock.now,
            lambda: self._arrive_feedback(int(learner), item, int(tenant)))

    # -- event handlers ------------------------------------------------------

    def _arrive_predict(self, req: PredictRequest) -> None:
        if self.tracer is not None:
            self.tracer.instant(
                "enqueue", self.clock.now, pid=PID_SERVING,
                tid=self.tracer.tid(PID_SERVING, "requests"),
                args={"uid": req.uid, "learner": req.learner,
                      "tenant": req.tenant})
        self.scheduler.submit(req)

    def _arrive_feedback(self, learner: int,
                         item: Tuple[np.ndarray, float],
                         tenant: int) -> None:
        ten = self._tenants[tenant]
        ten.fb[learner].append(item)
        while all(ten.fb):          # full rounds apply immediately
            xs = np.stack([ten.fb[i][0][0] for i in range(self.m)])
            ys = np.asarray([ten.fb[i][0][1] for i in range(self.m)],
                            np.float32)
            for q in ten.fb:
                q.popleft()
            self._apply_round(ten, xs, ys)

    # -- the predict path (called by the scheduler) --------------------------

    def _models_for_predict(self, ten: _Tenant):
        if ten.placed_models is None:
            models = ten.sub.models_of(ten.carry[0])
            if self._model_sharding is not None:
                models = jax.device_put(models, self._model_sharding)
            ten.placed_models = models
        return ten.placed_models

    def _predict_chunk(self, chunk: List[PredictRequest],
                       bucket: int) -> np.ndarray:
        """One padded-batch predict for a (tenant, shard) chunk — the
        scheduler's ``predict_fn``.  Padding rows reuse the chunk's
        first learner id so the gather never reaches outside the home
        shard."""
        ten = self._tenants[chunk[0].tenant]
        models = self._models_for_predict(ten)
        d = int(ten.sub.input_dim)
        lids = np.full((bucket,), chunk[0].learner, np.int32)
        Xb = np.zeros((bucket, d), np.float32)
        for i, r in enumerate(chunk):
            lids[i] = r.learner
            Xb[i] = r.x
        yh = np.asarray(ten.predict_op(
            models, jnp.asarray(lids), jnp.asarray(Xb)))
        ten.served.extend(chunk)
        return yh

    # -- protocol rounds -----------------------------------------------------

    def _apply_round(self, ten: _Tenant, x_row: np.ndarray,
                     y_row: np.ndarray) -> None:
        """One protocol round through the scan engine's step (the
        parity-critical path — see the module docstring)."""
        ten.sub.validate(ten.t + 1, self.m, self.d)   # sv_id capacity
        xs = (jnp.asarray(x_row), jnp.asarray(y_row),
              jnp.asarray(ten.t, jnp.int32))
        ten.carry, outs = ten.round_op(ten.params, ten.carry, xs)
        ten.placed_models = None      # next launch re-places the models
        loss, err, nbytes, div, flag, eps = outs
        ten.loss_rows.append(np.asarray(loss))
        ten.err_rows.append(np.asarray(err))
        ten.byte_rows.append(int(nbytes))
        ten.div_rows.append(np.asarray(div))
        ten.eps_rows.append(np.asarray(eps))
        fired = bool(flag)
        ten.flag_rows.append(fired)
        ten.t += 1
        if self.tracer is not None:
            self.tracer.instant(
                "round", self.clock.now, pid=PID_SERVING,
                tid=self.tracer.tid(PID_SERVING, "protocol"),
                args={"t": ten.t - 1, "tenant": ten.tid,
                      "nbytes": int(nbytes), "sync": fired})
        if fired:
            # background sync: price the Sec. 3 bytes into simulated
            # network time (same seeded draw order as the runtime's
            # transport) and let it complete as a clock event — it
            # never blocks serving, but wall_clock sees it.
            delay = self.system.draw_latency(int(nbytes))
            ten.sync_delays.append(delay)
            if self.tracer is not None:
                # the sync transfer span, carrying its Sec. 3 bytes
                self.tracer.complete(
                    "sync/transfer", self.clock.now, delay,
                    pid=PID_SERVING,
                    tid=self.tracer.tid(PID_SERVING, "protocol"),
                    args={"t": ten.t - 1, "tenant": ten.tid,
                          "nbytes": int(nbytes)})
            if delay > 0:
                self.clock.schedule(delay, lambda: None)

    # -- running and results -------------------------------------------------

    @property
    def rounds_applied(self) -> int:
        return self._tenants[0].t

    def serve(self, tenant: int = 0) -> ServeResult:
        """Run the event clock to quiescence and package the results
        (of ``tenant``; see :meth:`results` for all tenants)."""
        self.clock.run()
        return self.result(tenant)

    def results(self) -> List[ServeResult]:
        """Per-tenant snapshots, tenant order."""
        return [self.result(t) for t in range(len(self._tenants))]

    def result(self, tenant: int = 0) -> ServeResult:
        """Snapshot of everything served/learned so far.  The ``sim``
        field is assembled by ``engine.assemble_sim_result`` — the SAME
        host-side post-processing ``engine.run`` uses (per-learner
        stacking, fixed-order numpy sums, float64/int64 accumulation) —
        which is the second half of the bit-for-bit parity contract."""
        ten = self._tenant(tenant)
        if ten.t:
            loss = np.stack(ten.loss_rows)            # (T, m) float32
            err = np.stack(ten.err_rows)
            div = np.stack(ten.div_rows)
            eps = np.stack(ten.eps_rows)
        else:
            loss = np.zeros((0, self.m), np.float32)
            err = np.zeros((0, self.m), np.float32)
            div = np.zeros((0,), np.float32)
            eps = np.zeros((0,), np.float32)
        sim = assemble_sim_result(
            ten.sub, ten.record_divergence, loss, err,
            np.asarray(ten.byte_rows, np.int64), div,
            np.asarray(ten.flag_rows, bool), eps)
        sched = self.scheduler
        return ServeResult(
            sim=sim,
            latencies=np.asarray([r.latency for r in ten.served]),
            queue_depth=np.asarray(sched.queue_depth, np.int64),
            bucket_counts=dict(sched.bucket_counts),
            sync_delays=np.asarray(ten.sync_delays),
            rounds=ten.t,
            ticks=sched.ticks,
            wall_clock=self.clock.now,
            launches=sched.launches,
            num_shed=sched.num_shed,
            num_deferred=sched.num_deferred,
            policy=sched.POLICY,
            slots=sched.slots,
        )


# ---------------------------------------------------------------------------
# Stream replay
# ---------------------------------------------------------------------------


def serve_stream(
    learner,
    pcfg: ProtocolConfig,
    X: np.ndarray,          # (T, m, d)
    Y: np.ndarray,          # (T, m)
    *,
    queries_per_round: float = 0.0,
    query_seed: int = 0,
    arrivals: Optional[ArrivalProcess] = None,
    **engine_kw,
) -> ServeResult:
    """Replay a (T, m, d) protocol stream through the serving engine.

    Learner i's round-t labeled example arrives when that learner
    finishes computing round t on the seeded timeline — the cumulative
    sum of the SAME ``SystemModel.draw_compute`` table the async
    runtime prices barriers with, so serving and async experiments
    share one clock model.  Per-learner arrival order is monotone
    (compute times are positive), which preserves the stream order the
    parity contract needs.

    Query traffic rides along to exercise the predict path; it reads
    model state and never touches it, so the protocol view stays
    bit-identical to ``engine.run(learner, pcfg, X, Y)`` at any query
    rate, under any batch policy and any admission outcome.  Two ways
    to generate it:

    - ``queries_per_round * T`` requests at seeded *uniform* arrival
      times over the feedback horizon (the PR 5 default, kept for
      comparability);
    - ``arrivals=`` an :class:`repro.serving.arrivals.ArrivalProcess`
      (Poisson / bursty / diurnal), whose seeded ``times(horizon)``
      replace the uniform draws; ``queries_per_round`` is ignored.

    Home learners and inputs are resampled from the stream under
    ``query_seed`` either way.  ``engine_kw`` forwards to
    :class:`KernelServingEngine` (policy, slots, admission, SLO, ...).
    """
    X = np.asarray(X, np.float32)
    Y = np.asarray(Y, np.float32)
    T, m, d = X.shape
    eng = KernelServingEngine(learner, pcfg, m, **engine_kw)
    eng.sub.validate(T, m, d)
    arrive = np.cumsum(eng.system.draw_compute(T), axis=0)   # (T, m)
    for t in range(T):
        for i in range(m):
            eng.feedback(X[t, i], Y[t, i], learner=i,
                         at=float(arrive[t, i]))
    horizon = float(arrive.max())
    rng = np.random.default_rng(query_seed)
    if arrivals is not None:
        times = arrivals.times(horizon)
    else:
        n_q = int(round(queries_per_round * T))
        times = (np.sort(rng.uniform(0.0, horizon, size=n_q))
                 if n_q else np.zeros((0,)))
    for tq in times:
        lid = int(rng.integers(m))
        x = X[int(rng.integers(T)), lid]
        eng.submit(x, learner=lid, at=float(tq))
    return eng.serve()
