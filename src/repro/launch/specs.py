"""Assigned input shapes and ShapeDtypeStruct stand-ins for the dry-run.

Shapes (from the assignment):
  train_4k     seq=4096    global_batch=256   -> protocol train_step
  prefill_32k  seq=32768   global_batch=32    -> prefill_step
  decode_32k   seq=32768   global_batch=128   -> serve_step (1 token)
  long_500k    seq=524288  global_batch=1     -> serve_step (1 token)

``input_specs`` returns weak-type-correct, shardable ShapeDtypeStructs
for every model input — no device allocation ever happens here (the
model/cache shapes come from ``jax.eval_shape`` over the real init
functions, so the dry run exercises exactly the production pytrees).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import build
from repro.models.config import ModelConfig

SHAPES: Dict[str, Dict[str, Any]] = {
    "train_4k":    dict(kind="train",   seq=4_096,   batch=256),
    "prefill_32k": dict(kind="prefill", seq=32_768,  batch=32),
    "decode_32k":  dict(kind="decode",  seq=32_768,  batch=128),
    "long_500k":   dict(kind="decode",  seq=524_288, batch=1),
}

CACHE_MARGIN = 128   # decode caches hold seq_len context + margin slots


def variant_for(cfg: ModelConfig, shape_name: str) -> ModelConfig:
    """long_500k requires sub-quadratic attention: dense/VLM/audio archs
    switch to the sliding-window variant (DESIGN.md long_500k policy).
    SSM/hybrid archs run natively."""
    if shape_name == "long_500k" and cfg.attn_kind != "none" and cfg.window == 0:
        return cfg.with_(window=cfg.long_context_window)
    return cfg


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def train_batch_specs(cfg: ModelConfig, m: int, shape: Dict[str, Any]):
    """Stacked-learner batch: leading dim m (one slice per learner)."""
    B, S = shape["batch"], shape["seq"]
    assert B % m == 0, (B, m)
    b = B // m
    dt = jnp.dtype(cfg.dtype)
    if cfg.arch_type == "vlm":
        sv = cfg.vision_tokens
        return {
            "embeds": _sds((m, b, sv, cfg.d_model), dt),
            "tokens": _sds((m, b, S - sv), jnp.int32),
            "labels": _sds((m, b, S - sv), jnp.int32),
        }
    if cfg.arch_type == "audio":
        return {
            "frames": _sds((m, b, cfg.n_audio_frames, cfg.d_model), dt),
            "tokens": _sds((m, b, S), jnp.int32),
            "labels": _sds((m, b, S), jnp.int32),
        }
    return {
        "tokens": _sds((m, b, S), jnp.int32),
        "labels": _sds((m, b, S), jnp.int32),
    }


def prefill_batch_specs(cfg: ModelConfig, shape: Dict[str, Any]):
    B, S = shape["batch"], shape["seq"]
    dt = jnp.dtype(cfg.dtype)
    if cfg.arch_type == "vlm":
        sv = cfg.vision_tokens
        return {
            "embeds": _sds((B, sv, cfg.d_model), dt),
            "tokens": _sds((B, S - sv), jnp.int32),
        }
    if cfg.arch_type == "audio":
        return {
            "frames": _sds((B, cfg.n_audio_frames, cfg.d_model), dt),
            "tokens": _sds((B, S), jnp.int32),
        }
    return {"tokens": _sds((B, S), jnp.int32)}


def cache_specs(cfg: ModelConfig, B: int, length: int):
    api = build(cfg)
    return jax.eval_shape(lambda: api.init_caches(B, length))


def param_specs(cfg: ModelConfig):
    api = build(cfg)
    return jax.eval_shape(api.init, jax.random.PRNGKey(0))


def stacked_param_specs(cfg: ModelConfig, m: int):
    base = param_specs(cfg)
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((m,) + tuple(l.shape), l.dtype), base)


def input_specs(cfg: ModelConfig, shape_name: str, m: int = 1):
    """The batch-side ShapeDtypeStructs for one (arch, shape) combo."""
    shape = SHAPES[shape_name]
    cfg = variant_for(cfg, shape_name)
    if shape["kind"] == "train":
        return train_batch_specs(cfg, m, shape)
    if shape["kind"] == "prefill":
        return prefill_batch_specs(cfg, shape)
    # decode: one new token + caches of seq_len context
    B = shape["batch"]
    return {
        "token": _sds((B, 1), jnp.int32),
        "pos": _sds((), jnp.int32),
        "caches": cache_specs(cfg, B, shape["seq"] + CACHE_MARGIN),
    }
