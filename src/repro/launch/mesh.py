"""Production mesh construction.

Single pod:  (data=16, model=16)          = 256 chips (v5e pod)
Multi-pod:   (pod=2, data=16, model=16)   = 512 chips

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; tests see the
default single CPU device).
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host/CPU) devices exist — used by
    the distributed-protocol integration tests."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"))


def make_learner_mesh(n: int = 0):
    """1-D mesh with the ``learners`` axis over n devices (default:
    all available) — the axis the mesh-sharded scan engine shards the
    m-learner dim over (``engine.run(..., mesh=...)``, DESIGN.md
    Sec. 9).  The learner count m must divide evenly over n."""
    if n == 0:
        n = len(jax.devices())
    return jax.make_mesh((n,), ("learners",))


def data_axes(mesh) -> Tuple[str, ...]:
    """The learner/batch axes of a mesh (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def num_learners(mesh) -> int:
    import math
    return math.prod(mesh.shape[a] for a in data_axes(mesh))


# Hardware constants for the roofline analysis (TPU v5e).
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
