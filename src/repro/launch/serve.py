"""Serving launch surface: the kernel serving engine on a learner
mesh, plus the LM prefill/decode steps the dry-run lowers.

Kernel serving (DESIGN.md Secs. 10, 13)
---------------------------------------
``make_kernel_serving_engine`` is the mesh-aware constructor for
``repro.serving.KernelServingEngine``: it builds the 1-D learner mesh
(``launch.mesh.make_learner_mesh``) over the visible devices, places
the stacked learner models with a learner-axis ``NamedSharding``, and
the engine then routes every predict request to its *home shard* —
launched micro-batches never mix learners from different shards, so
the model gather inside ``Substrate.predict_batch`` stays shard-local.
Each shard gets its own slot pool, so ``slots`` is per shard: a
``devices=4, slots=2`` engine can have 8 predict batches in flight.
All scheduler knobs forward through ``engine_kw`` — ``policy``
("tick" grid or "continuous" slotted batching), ``slots``, the
admission controls ``max_queue`` / ``overload`` ("shed" or "defer") /
``defer_interval``, and the latency budget ``slo`` / ``max_wait`` the
continuous policy coalesces under.  None of them can change the
protocol view: the scheduling policy is a pure latency/throughput
knob, bit-identical losses and integer-exact bytes under all of them
(tests/test_serving.py runs the routed path on forced host devices).

LM serving (DESIGN.md Sec. 4)
-----------------------------
``make_prefill_step`` / ``make_decode_step`` build the jitted steps of
the LM token path: decode_32k / long_500k lower ``serve_step`` — ONE
new token against a context-length KV cache (or SSM/LRU state).  The
continuous-batching LM engine lives in ``repro.serving.lm``.
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp

from repro.models import build
from repro.models.config import ModelConfig

PyTree = Any


# ---------------------------------------------------------------------------
# Kernel serving on a learner mesh
# ---------------------------------------------------------------------------


def make_kernel_serving_engine(
    learner,
    pcfg,
    m: int,
    *,
    devices: int = 0,
    **engine_kw,
):
    """Build a :class:`repro.serving.KernelServingEngine` with its
    learner axis sharded over a device mesh.

    ``devices``: how many devices the ``learners`` mesh axis spans
    (default 0 = all visible; m must divide evenly).  Every other
    keyword forwards to the engine constructor — protocol, system
    model, batch policy (``policy="tick" | "continuous"``), slot pool
    size (``slots``, per shard), admission control (``max_queue``,
    ``overload``, ``defer_interval``), latency budget (``slo``,
    ``max_wait``), tick cadence, buckets.  With one visible device
    this degrades gracefully to the unmeshed engine (the mesh exists,
    the routing is the identity), so the same launch code serves a
    laptop and a pod.
    """
    from repro.launch.mesh import make_learner_mesh

    if "mesh" in engine_kw:
        raise ValueError(
            "pass devices=..., not mesh=; make_kernel_serving_engine "
            "owns the mesh construction")
    from repro.serving import KernelServingEngine

    mesh = make_learner_mesh(devices)
    return KernelServingEngine(learner, pcfg, m, mesh=mesh, **engine_kw)


# ---------------------------------------------------------------------------
# LM serving steps (prefill / decode), used by the dry-run
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig):
    api = build(cfg)

    def prefill_step(params, batch, caches):
        return api.prefill(params, batch, caches)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    api = build(cfg)

    def serve_step(params, caches, token, pos):
        logits, new_caches = api.decode(params, caches, token, pos)
        next_token = jnp.argmax(logits[..., : cfg.vocab], axis=-1).astype(jnp.int32)
        return next_token, new_caches

    return serve_step
