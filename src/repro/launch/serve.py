"""Serving steps (prefill / decode) used by the dry-run and the
serving engine.

decode_32k / long_500k lower ``serve_step``: ONE new token against a
context-length KV cache (or SSM/LRU state), per the assignment.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import build
from repro.models.config import ModelConfig

PyTree = Any


def make_prefill_step(cfg: ModelConfig):
    api = build(cfg)

    def prefill_step(params, batch, caches):
        return api.prefill(params, batch, caches)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    api = build(cfg)

    def serve_step(params, caches, token, pos):
        logits, new_caches = api.decode(params, caches, token, pos)
        next_token = jnp.argmax(logits[..., : cfg.vocab], axis=-1).astype(jnp.int32)
        return next_token, new_caches

    return serve_step
