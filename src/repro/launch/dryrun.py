import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

The two lines above MUST precede any other import (jax locks the device
count at first init): the dry-run — and only the dry-run — sees 512
placeholder host devices so ``jax.make_mesh`` can build the production
meshes (16x16 single-pod, 2x16x16 multi-pod).

For each combination this script:
  1. builds the jitted step (protocol train / prefill / serve) with
     explicit in/out shardings,
  2. ``.lower(**input_specs(...)).compile()`` — proving the sharding
     config is coherent (no mismatched collectives, no OOM at compile),
  3. prints ``compiled.memory_analysis()`` and ``cost_analysis()``,
  4. parses the post-SPMD HLO for collective bytes (all-gather /
     all-reduce / reduce-scatter / all-to-all / collective-permute),
  5. writes a JSON record consumed by the roofline analysis.

Usage:
  python -m repro.launch.dryrun --arch qwen3_14b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import all_arch_ids, get
from repro.launch import sharding as shd
from repro.launch import specs as specs_mod
from repro.launch.mesh import data_axes, make_production_mesh, num_learners
from repro.launch.serve import make_decode_step, make_prefill_step
from repro.launch.specs import SHAPES, input_specs, variant_for
from repro.launch.train import make_train_step, train_state_specs
from repro.core.protocol import ProtocolConfig
from repro.optim import OptimizerConfig

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", re.M)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes of every collective op in post-SPMD HLO,
    per collective kind.  These are per-device tensor sizes (the HLO is
    the per-partition program)."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        out[kind] = out.get(kind, 0) + _shape_bytes(shape_str)
    return out


def _apply_baseline_emulation():
    """REPRO_BASELINE=1: reproduce the pre-optimization implementation
    (einsum MoE dispatch, grouped SDPA everywhere, no activation
    constraints) so stale baseline records can be regenerated and the
    emulation validated against untouched baseline records."""
    from repro.models import attention as attn_mod
    from repro.models import moe as moe_mod

    def sdpa_orig(q, k, v, mask, scale, specs=(None, None)):
        return attn_mod._sdpa_grouped(q, k, v, mask, scale)

    attn_mod._sdpa = sdpa_orig
    moe_mod.moe_forward = moe_mod.moe_forward_einsum


def build_combo(arch: str, shape_name: str, mesh):
    """Returns (fn, in_shardings, arg_specs) for jax.jit."""
    baseline = os.environ.get("REPRO_BASELINE") == "1"
    if baseline:
        _apply_baseline_emulation()
    cfg0 = get(arch)
    cfg = variant_for(cfg0, shape_name).with_(remat=True, unroll_scan=True,
                                              shard_activations=not baseline,
                                              remat_policy=os.environ.get("REPRO_REMAT", "full"))
    shape = SHAPES[shape_name]
    model_size = mesh.shape["model"]
    daxes = data_axes(mesh)
    m = num_learners(mesh)
    nd = num_learners(mesh)

    if shape["kind"] == "train":
        pcfg = ProtocolConfig(kind="dynamic", delta=1e-3)
        opt_cfg = OptimizerConfig(kind="sgd", lr=1e-2, momentum=0.9)
        fn = make_train_step(cfg, pcfg, opt_cfg)

        state_specs = train_state_specs(cfg, m, opt_cfg)
        batch_specs = specs_mod.train_batch_specs(cfg, m, shape)

        stacked_pspec = shd.param_pspec(
            state_specs.params, model_size, learner_axes=daxes)
        opt_pspec = shd.param_pspec(
            state_specs.opt, model_size, learner_axes=daxes)
        ref_pspec = shd.param_pspec(
            state_specs.pstate.reference, model_size, learner_axes=daxes)
        from repro.core.protocol import ProtocolState
        pstate_pspec = ProtocolState(
            reference=ref_pspec, step=P(), syncs=P(), bytes_sent=P(),
            last_divergence=P(), delta_scale=P())
        from repro.launch.train import TrainState
        state_pspec = TrainState(params=stacked_pspec, opt=opt_pspec,
                                 pstate=pstate_pspec, step=P())
        batch_pspec = shd.batch_pspec(batch_specs, daxes)

        in_shardings = (shd.to_shardings(mesh, state_pspec),
                        shd.to_shardings(mesh, batch_pspec))
        out_shardings = (shd.to_shardings(mesh, state_pspec),
                         NamedSharding(mesh, P()))
        return fn, in_shardings, out_shardings, (state_specs, batch_specs), cfg

    shardable_b = shape["batch"] % nd == 0
    cfg = cfg.with_(act_batch_axes=daxes if shardable_b else ())
    params_specs = specs_mod.param_specs(cfg)
    params_pspec = shd.param_pspec(params_specs, model_size, learner_axes=None)
    B = shape["batch"]

    if shape["kind"] == "prefill":
        fn = make_prefill_step(cfg)
        batch_specs = specs_mod.prefill_batch_specs(cfg, shape)
        cache_specs = specs_mod.cache_specs(cfg, B, shape["seq"])
        batch_pspec = jax.tree.map(
            lambda l: P(*(((daxes if len(daxes) > 1 else daxes[0]),)
                          + (None,) * (len(l.shape) - 1))), batch_specs)
        cache_pspec = shd.cache_pspec(cache_specs, daxes, B, nd, model_size)
        in_shardings = (shd.to_shardings(mesh, params_pspec),
                        shd.to_shardings(mesh, batch_pspec),
                        shd.to_shardings(mesh, cache_pspec))
        out_shardings = (NamedSharding(mesh, P()),
                         shd.to_shardings(mesh, cache_pspec))
        return fn, in_shardings, out_shardings, (params_specs, batch_specs,
                                                 cache_specs), cfg

    # decode
    fn = make_decode_step(cfg)
    dspecs = input_specs(cfg0, shape_name)
    tok_spec, pos_spec, cache_specs = (dspecs["token"], dspecs["pos"],
                                       dspecs["caches"])
    shardable_batch = B % nd == 0
    tok_pspec = P(*(((daxes if len(daxes) > 1 else daxes[0]) if shardable_batch
                     else None), None))
    cache_pspec = shd.cache_pspec(cache_specs, daxes, B, nd, model_size)
    in_shardings = (shd.to_shardings(mesh, params_pspec),
                    shd.to_shardings(mesh, cache_pspec),
                    NamedSharding(mesh, tok_pspec),
                    NamedSharding(mesh, P()))
    out_shardings = (NamedSharding(mesh, tok_pspec),
                     shd.to_shardings(mesh, cache_pspec))
    return fn, in_shardings, out_shardings, (params_specs, cache_specs,
                                             tok_spec, pos_spec), cfg


def run_one(arch: str, shape_name: str, multi_pod: bool, outdir: str):
    mesh_tag = "multi" if multi_pod else "single"
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    fn, in_sh, out_sh, arg_specs, cfg = build_combo(arch, shape_name, mesh)

    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*arg_specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    def _get(obj, *names):
        for name in names:
            v = None
            if isinstance(obj, dict):
                v = obj.get(name)
            if v is None:
                v = getattr(obj, name, None)
            if v is not None:
                try:
                    return float(v)
                except Exception:
                    pass
        return None

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_tag,
        "devices": int(mesh.size),
        "kind": SHAPES[shape_name]["kind"],
        "flops": _get(cost, "flops"),
        "bytes_accessed": _get(cost, "bytes accessed", "bytes_accessed"),
        "transcendentals": _get(cost, "transcendentals"),
        "argument_size": _get(mem, "argument_size_in_bytes"),
        "output_size": _get(mem, "output_size_in_bytes"),
        "temp_size": _get(mem, "temp_size_in_bytes"),
        "generated_code_size": _get(mem, "generated_code_size_in_bytes"),
        "collective_bytes": coll,
        "collective_total": float(sum(coll.values())),
        "lower_s": t_lower,
        "compile_s": t_compile,
        "n_collective_ops": len(_COLL_RE.findall(hlo)),
    }

    print(f"== {arch} x {shape_name} x {mesh_tag} ({mesh.size} devices) ==")
    print("memory_analysis:", {k: record[k] for k in
                               ("argument_size", "output_size", "temp_size")})
    print("cost_analysis: flops=%.3e bytes=%.3e" % (record["flops"] or -1,
                                                    record["bytes_accessed"] or -1))
    print("collectives:", coll)
    print(f"lower={t_lower:.1f}s compile={t_compile:.1f}s")

    os.makedirs(outdir, exist_ok=True)
    out_path = os.path.join(outdir, f"{arch}__{shape_name}__{mesh_tag}.json")
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--outdir", default="experiments/dryrun")
    args = ap.parse_args()

    combos = []
    if args.all:
        for a in all_arch_ids():
            for s in SHAPES:
                combos.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    failures = []
    for arch, shape_name in combos:
        try:
            run_one(arch, shape_name, args.multi_pod, args.outdir)
        except Exception as e:
            traceback.print_exc()
            failures.append((arch, shape_name, repr(e)))
    if failures:
        print("FAILURES:")
        for f in failures:
            print(" ", f)
        sys.exit(1)
    print(f"all {len(combos)} combos lowered+compiled OK")


if __name__ == "__main__":
    main()
