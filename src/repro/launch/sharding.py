"""Sharding rules: param/cache pytrees -> PartitionSpec pytrees.

Baseline scheme (see DESIGN.md Sec. 5, iterated in EXPERIMENTS.md
Sec. Perf):

- tensor-parallel over the ``model`` axis on *merged* head dims, FFN
  hidden dims, expert dims, and the padded vocab;
- the protocol's learner axis (leading dim of stacked training state)
  over the data axes ``("pod", "data")``;
- replication for any dim not divisible by the model-axis size
  (checked per-leaf at spec-build time, never an invalid spec);
- caches: batch dim over the data axes when divisible.

Rules are matched on the path of each leaf, on the LAST ``ndim`` dims
of the leaf; leading dims (scan-stacked layers, learner stacking) get
``None`` / the learner axes.
"""
from __future__ import annotations

import math
import re
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

PyTree = Any

# (path regex, spec for trailing dims).  "M" marks the model axis; the
# number of entries fixes how many trailing dims the rule governs.
_PARAM_RULES = [
    (r"embed/table$",        ("M", None)),
    (r"dec_pos/table$",      (None, None)),
    (r"lm_head/w$",          (None, "M")),
    (r"lm_head/b$",          ("M",)),
    (r"(wq|wk|wv)/w$",       (None, "M")),
    (r"(wq|wk|wv)/b$",       ("M",)),
    (r"wo/w$",               ("M", None)),
    (r"wo/b$",               (None,)),
    (r"mlp/(wi|wg)/w$",      (None, "M")),
    (r"mlp/(wi|wg)/b$",      ("M",)),
    (r"mlp/wo/w$",           ("M", None)),
    (r"mlp/wo/b$",           (None,)),
    (r"moe/router/w$",       (None, None)),
    (r"moe/(wi|wg)$",        ("M", None, None)),   # expert-parallel
    (r"moe/wo$",             ("M", None, None)),
    (r"ssm/in_proj/w$",      (None, None)),        # mixed concat out-dim
    (r"ssm/out_proj/w$",     ("M", None)),
    (r"rglru/(w_y|w_x)/w$",  (None, "M")),
    (r"rglru/(w_a|w_i)/w$",  ("M", "M_diag")),     # see note below
    (r"rglru/(w_a|w_i)/b$",  ("M",)),
    (r"rglru/w_o/w$",        ("M", None)),
    (r"rglru/Lambda$",       ("M",)),
    (r"mla_?.*w_dq/w$",      (None, None)),
    (r"w_dq/w$",             (None, None)),
    (r"w_uq/w$",             (None, "M")),
    (r"w_dkv/w$",            (None, None)),
    (r"w_kr/w$",             (None, None)),
    (r"(w_uk|w_uv)/w$",      (None, "M")),
]

# rglru gate matrices are (W, W); sharding both dims over the same axis
# is invalid — shard rows only.
def _fix_special(spec):
    return tuple(None if s == "M_diag" else s for s in spec)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _apply_rule(spec_tail, shape, model_size: int):
    """Validate divisibility; replicate dims that don't divide."""
    out = []
    for dim_spec, dim in zip(spec_tail, shape):
        if dim_spec == "M" and dim % model_size == 0 and dim >= model_size:
            out.append("model")
        else:
            out.append(None)
    return tuple(out)


def param_pspec(params: PyTree, model_size: int,
                learner_axes: Optional[Tuple[str, ...]] = None) -> PyTree:
    """PartitionSpec pytree for a (possibly learner-stacked) param tree.

    learner_axes: if given, leaves are assumed to carry a leading
    learner dim sharded over these mesh axes.
    """

    def spec_for(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        n_lead = 1 if learner_axes else 0
        body_shape = shape[n_lead:]
        tail_spec = None
        for pat, spec in _PARAM_RULES:
            if re.search(pat, ps):
                spec = _fix_special(spec)
                if len(spec) <= len(body_shape):
                    tail = _apply_rule(spec, body_shape[len(body_shape) - len(spec):],
                                       model_size)
                    tail_spec = (None,) * (len(body_shape) - len(spec)) + tail
                break
        if tail_spec is None:
            tail_spec = (None,) * len(body_shape)
        lead = ((learner_axes if len(learner_axes) > 1 else learner_axes[0]),) \
            if learner_axes else ()
        return P(*(lead + tail_spec))

    return jax.tree_util.tree_map_with_path(spec_for, params)


def cache_pspec(caches: PyTree, batch_axes: Tuple[str, ...], batch: int,
                n_batch_axes_size: int, model_size: int = 0,
                seq_min: int = 4096) -> PyTree:
    """Shard cache batch dims over the data axes, and long context
    dims over the model axis (flash-decoding style: attention keys are
    partitioned; GSPMD turns the softmax/contraction reductions into
    small all-reduces while the O(B*L) cache reads stay local).

    Cache leaves are stacked (repeats, B, L, ...) by the stage
    machinery; dim 1 is treated as batch when its size equals ``batch``,
    dim 2 as context length when >= seq_min and divisible.
    """
    ax = batch_axes if len(batch_axes) > 1 else batch_axes[0]

    def spec_for(leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        if (len(shape) >= 2 and shape[1] == batch
                and batch % n_batch_axes_size == 0):
            spec[1] = ax
        if (model_size and len(shape) >= 3 and shape[2] >= seq_min
                and shape[2] % model_size == 0):
            spec[2] = "model"
        return P(*spec)

    return jax.tree.map(spec_for, caches)


def batch_pspec(batch: PyTree, learner_axes: Tuple[str, ...]) -> PyTree:
    """Training batches are (m, b, ...) — learner dim over data axes."""
    ax = learner_axes if len(learner_axes) > 1 else learner_axes[0]

    def spec_for(leaf):
        return P(*((ax,) + (None,) * (leaf.ndim - 1)))

    return jax.tree.map(spec_for, batch)


def stream_pspec(learner_axes: Tuple[str, ...]) -> P:
    """Protocol streams are (T, m, ...) — round dim replicated, learner
    dim (axis 1) over the learner axes, feature dims local.  Used to
    pre-place X/Y for the mesh-sharded engine (DESIGN.md Sec. 9) so
    the stream never bounces through one device:

        sh = NamedSharding(mesh, stream_pspec(("learners",)))
        engine.run(sub, pcfg, jax.device_put(X, sh),
                   jax.device_put(Y, sh), mesh=mesh)
    """
    ax = learner_axes if len(learner_axes) > 1 else learner_axes[0]
    return P(None, ax)


def to_shardings(mesh, pspecs: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P))
