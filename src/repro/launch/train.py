"""Protocol training step + runnable trainer.

The paper's technique as a first-class feature at LM scale: every
data-parallel group is a *learner* with its own model replica (stacked
leading axis m); each step every learner takes a local optimizer step
on its own batch shard, then the dynamic synchronization operator
checks the local conditions ||theta_i - r||^2 <= Delta and triggers a
parameter average ONLY on violation.  Under GSPMD the violation check
is an all-reduce of one scalar; the parameter all-reduce — the
expensive collective of standard data-parallel training — happens only
when the models have actually diverged.

Run (CPU demo):  PYTHONPATH=src python -m repro.launch.train --arch mamba2_130m --steps 20
"""
from __future__ import annotations

import argparse
import time
from functools import partial
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import protocol
from repro.core.protocol import ProtocolConfig, ProtocolState
from repro.models import build
from repro.models.config import ModelConfig
from repro.optim import OptimizerConfig, make as make_optimizer

PyTree = Any


class TrainState(NamedTuple):
    params: PyTree          # stacked (m, ...)
    opt: PyTree             # stacked optimizer state
    pstate: ProtocolState   # reference model (un-stacked) + counters
    step: jnp.ndarray


def init_train_state(key, cfg: ModelConfig, m: int,
                     opt_cfg: OptimizerConfig) -> TrainState:
    api = build(cfg)
    opt = make_optimizer(opt_cfg)
    params0 = api.init(key)
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (m,) + x.shape).copy(), params0)
    opt_state = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (m,) + x.shape).copy(),
        opt.init(params0))
    return TrainState(
        params=stacked,
        opt=opt_state,
        pstate=protocol.init_state(params0, m),
        step=jnp.zeros((), jnp.int32),
    )


def train_state_specs(cfg: ModelConfig, m: int, opt_cfg: OptimizerConfig):
    """ShapeDtypeStructs of the train state (for the dry-run: never
    allocates)."""
    return jax.eval_shape(
        partial(init_train_state, cfg=cfg, m=m, opt_cfg=opt_cfg),
        jax.random.PRNGKey(0))


def make_train_step(cfg: ModelConfig, pcfg: ProtocolConfig,
                    opt_cfg: OptimizerConfig):
    api = build(cfg)
    opt = make_optimizer(opt_cfg)

    def local_update(params, opt_state, step, batch):
        loss, grads = jax.value_and_grad(api.loss)(params, batch)
        new_params, new_opt = opt.update(grads, opt_state, params, step)
        return new_params, new_opt, loss

    def train_step(state: TrainState, batch) -> Tuple[TrainState, jnp.ndarray]:
        vupd = jax.vmap(local_update, in_axes=(0, 0, None, 0))
        new_params, new_opt, losses = vupd(
            state.params, state.opt, state.step, batch)
        synced, new_pstate = protocol.apply_protocol(
            pcfg, new_params, state.pstate)
        return (
            TrainState(params=synced, opt=new_opt, pstate=new_pstate,
                       step=state.step + 1),
            jnp.mean(losses),
        )

    return train_step


# ---------------------------------------------------------------------------
# Runnable CPU-scale trainer (example-grade; the dry-run exercises the
# production mesh shapes)
# ---------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2_130m")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--learners", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2, help="per-learner batch")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--protocol", default="dynamic",
                    choices=["none", "continuous", "periodic", "dynamic"])
    ap.add_argument("--delta", type=float, default=1e-4)
    ap.add_argument("--period", type=int, default=4)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--smoke", action="store_true", default=True)
    args = ap.parse_args()

    from repro.configs import get
    import numpy as np

    cfg = get(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    m = args.learners
    pcfg = ProtocolConfig(kind=args.protocol, delta=args.delta,
                          period=args.period)
    opt_cfg = OptimizerConfig(kind="sgd", lr=args.lr, momentum=0.0)

    state = init_train_state(jax.random.PRNGKey(0), cfg, m, opt_cfg)
    step_fn = jax.jit(make_train_step(cfg, pcfg, opt_cfg))

    rng = np.random.default_rng(0)
    t0 = time.time()
    for t in range(args.steps):
        toks = rng.integers(0, cfg.vocab, (m, args.batch, args.seq + 1))
        batch = {
            "tokens": jnp.asarray(toks[..., :-1], jnp.int32),
            "labels": jnp.asarray(toks[..., 1:], jnp.int32),
        }
        if cfg.arch_type == "vlm":
            batch["embeds"] = jnp.asarray(
                rng.normal(size=(m, args.batch, cfg.vision_tokens, cfg.d_model)),
                jnp.float32)
        if cfg.arch_type == "audio":
            batch["frames"] = jnp.asarray(
                rng.normal(size=(m, args.batch, cfg.n_audio_frames, cfg.d_model)),
                jnp.float32)
        state, loss = step_fn(state, batch)
        print(f"step {t:4d} loss={float(loss):8.4f} "
              f"syncs={int(state.pstate.syncs):3d} "
              f"divergence={float(state.pstate.last_divergence):10.3e} "
              f"bytes={int(state.pstate.bytes_sent):d}")
    print(f"done in {time.time() - t0:.1f}s; "
          f"{int(state.pstate.syncs)}/{args.steps} rounds synchronized")


if __name__ == "__main__":
    main()
