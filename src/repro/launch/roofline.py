"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) record produced by dryrun.py:

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s          [s]
  memory term     = HLO_bytes_per_device / HBM_bw               [s]
  collective term = collective_bytes_per_device * f / link_bw   [s]

``cost_analysis()`` of the compiled partitioned module reports
PER-DEVICE flops/bytes (calibrated: a 1024^3 matmul sharded over 256
devices reports 2.15e9/256 flops).  Collective bytes come from the
post-SPMD HLO text; an all-reduce of X bytes moves ~2X over the ring
(reduce-scatter + all-gather), other collectives ~X — the factor is
applied per kind.

MODEL_FLOPS uses the 6*N*D convention (2*N*D for inference-forward,
N = active non-embedding params for MoE); the ratio
MODEL_FLOPS / (HLO_FLOPs * devices) exposes remat/redundancy waste.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.launch.specs import SHAPES

_COLL_FACTOR = {
    "all-reduce": 2.0,          # reduce-scatter + all-gather round trip
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def active_params(cfg) -> int:
    """Non-embedding (active, for MoE) parameter count for 6ND."""
    from repro.models.config import param_count
    total = param_count(cfg)
    emb = cfg.padded_vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    body = total - emb
    if cfg.n_experts:
        # scale expert tensors by top_k / n_experts
        expert = len([k for k in cfg.pattern if k == "moe"]) * \
            cfg.n_experts * 3 * cfg.d_model * cfg.expert_ff
        body = body - expert + expert * cfg.top_k / cfg.n_experts
    return int(body)


def model_flops(cfg, shape_name: str) -> float:
    sh = SHAPES[shape_name]
    n = active_params(cfg)
    if sh["kind"] == "train":
        tokens = sh["batch"] * sh["seq"]
        return 6.0 * n * tokens
    if sh["kind"] == "prefill":
        tokens = sh["batch"] * sh["seq"]
        return 2.0 * n * tokens
    tokens = sh["batch"] * 1
    return 2.0 * n * tokens


def analyze_record(rec: Dict) -> Dict:
    from repro.configs import get
    from repro.launch.specs import variant_for
    cfg = variant_for(get(rec["arch"]), rec["shape"])

    devices = rec["devices"]
    compute_s = (rec["flops"] or 0.0) / PEAK_FLOPS_BF16
    memory_s = (rec["bytes_accessed"] or 0.0) / HBM_BW
    # reprolint: allow[ACC01] roofline seconds model: bytes scale into time terms, not the ledger
    coll_bytes = sum(
        _COLL_FACTOR.get(k, 1.0) * v
        for k, v in (rec.get("collective_bytes") or {}).items())
    # reprolint: allow[ACC01] roofline seconds model: bytes scale into time terms, not the ledger
    collective_s = coll_bytes / ICI_BW

    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, rec["shape"])
    hlo_global = (rec["flops"] or 0.0) * devices
    ratio = mf / hlo_global if hlo_global else float("nan")

    bound_s = max(terms.values())
    mfu_bound = (mf / devices / PEAK_FLOPS_BF16) / bound_s if bound_s else 0.0

    suggestion = _suggest(rec, cfg, dominant, ratio)
    return {
        **rec,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": ratio,
        "mfu_upper_bound": mfu_bound,
        "suggestion": suggestion,
    }


def _suggest(rec, cfg, dominant, ratio) -> str:
    if dominant == "collective":
        kinds = rec.get("collective_bytes") or {}
        top = max(kinds, key=kinds.get) if kinds else "?"
        return (f"dominated by {top}: overlap it with compute or reshard to "
                f"remove the largest resharding (likely the logits/vocab or "
                f"expert all-to-all path)")
    if dominant == "memory":
        return ("HBM-bound: fuse/keep activations in bf16, increase "
                "arithmetic intensity (bigger per-device batch), or shard "
                "the largest resident tensor (KV cache / logits)")
    if ratio is not None and ratio < 0.5:
        return ("compute-bound but <50% useful flops: remove recompute/"
                "redundant ops (remat policy, duplicate projections, "
                "dense-MoE decode)")
    return "compute-bound near useful-flops roofline: good placement"


def load_records(outdir: str = "experiments/dryrun") -> List[Dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(outdir, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def markdown_table(analyzed: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | useful ratio | MFU bound |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for a in analyzed:
        rows.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} "
            f"| {a['compute_s']:.3e} | {a['memory_s']:.3e} "
            f"| {a['collective_s']:.3e} | **{a['dominant']}** "
            f"| {a['useful_ratio']:.2f} | {a['mfu_upper_bound']*100:.0f}% |")
    return hdr + "\n".join(rows) + "\n"


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="experiments/dryrun")
    ap.add_argument("--json-out", default="experiments/roofline.json")
    args = ap.parse_args()
    recs = [analyze_record(r) for r in load_records(args.outdir)]
    recs.sort(key=lambda r: (r["shape"], r["arch"], r["mesh"]))
    print(markdown_table(recs))
    os.makedirs(os.path.dirname(args.json_out), exist_ok=True)
    with open(args.json_out, "w") as f:
        json.dump(recs, f, indent=2)
    for r in recs:
        print(f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:6s} -> "
              f"{r['dominant']:10s} | {r['suggestion']}")


if __name__ == "__main__":
    main()
