"""Serial simulation of the m-learner + coordinator system.

This is the paper-faithful experiment driver: m local learners process
individual streams; the chosen protocol (none / continuous / periodic /
dynamic) decides when to synchronize; the ledger accounts bytes exactly
as in Sec. 3.  It produces the quantities plotted in Figs. 1 and 2:
cumulative loss/error, cumulative communication (over time), number of
synchronizations, and quiescence behaviour.

The per-round compute (m learner updates + local-condition checks) is
one jitted function; the byte accounting (set algebra over sv_ids) runs
in numpy outside jit, mirroring a real deployment where the
coordinator's bookkeeping is host-side.  That host round-trip per
round makes this driver the *oracle*, not the fast path: the
device-resident ``lax.scan`` engine (core/engine.py, DESIGN.md Sec. 7)
reproduces this driver's byte ledger exactly while touching the host
once per run, and is what the figure benchmarks use.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import accounting, compression, learners, rkhs
from .learners import LearnerConfig
from .protocol import ProtocolConfig
from .rkhs import KernelSpec, SVModel


@dataclasses.dataclass
class SimResult:
    """Everything the figure benchmarks need."""

    cumulative_loss: np.ndarray        # (T,) summed over learners
    cumulative_bytes: np.ndarray       # (T,)
    cumulative_errors: np.ndarray      # (T,) 0/1 prediction mistakes
    sync_rounds: np.ndarray            # indices where a sync happened
    divergences: np.ndarray            # (T,) measured delta(f_t)
    eps_history: np.ndarray            # compression errors at syncs
    num_syncs: int
    total_bytes: int
    total_loss: float

    @property
    def quiescence_round(self) -> Optional[int]:
        """First round index q from which the run is synchronization-
        free through the end — the boundary convention shared with
        ``criterion.quiescent`` (which is defined in terms of this
        property): ``0`` when the run never synchronized, ``s + 1``
        when the last sync landed at round ``s < T - 1``, and ``None``
        when a sync landed on the final round (quiescence was never
        observed within the run)."""
        if len(self.sync_rounds) == 0:
            return 0
        last = int(self.sync_rounds[-1])
        T = len(self.cumulative_loss)
        return last + 1 if last + 1 <= T - 1 else None

    @classmethod
    def from_round_series(
        cls,
        losses: np.ndarray,       # (T,) per-round summed loss
        errors: np.ndarray,       # (T,) per-round summed errors
        round_bytes: np.ndarray,  # (T,) bytes charged per round
        divergences: np.ndarray,  # (T,) or (0,) measured delta(f_t)
        sync_flags: np.ndarray,   # (T,) bool, True where a sync happened
        eps: np.ndarray,          # (T,) or (0,) compression error per round
    ) -> "SimResult":
        """Build a SimResult from per-round series (the scan engine's
        output format).  Accumulation happens here in float64/int64,
        matching the legacy drivers' host-side accumulators."""
        losses = np.asarray(losses, np.float64)
        errors = np.asarray(errors, np.float64)
        sync_flags = np.asarray(sync_flags, bool)
        cum_bytes = np.cumsum(np.asarray(round_bytes, np.int64))
        cum_loss = np.cumsum(losses)
        return cls(
            cumulative_loss=cum_loss,
            cumulative_bytes=cum_bytes,
            cumulative_errors=np.cumsum(errors),
            sync_rounds=np.nonzero(sync_flags)[0].astype(np.int64),
            divergences=np.asarray(divergences, np.float64),
            eps_history=(np.asarray(eps, np.float64)[sync_flags]
                         if len(eps) else np.zeros((0,))),
            num_syncs=int(sync_flags.sum()),
            total_bytes=int(cum_bytes[-1]) if len(cum_bytes) else 0,
            total_loss=float(cum_loss[-1]) if len(cum_loss) else 0.0,
        )


# ---------------------------------------------------------------------------
# Kernel-learner simulation
# ---------------------------------------------------------------------------


def run_kernel_simulation(
    lcfg: LearnerConfig,
    pcfg: ProtocolConfig,
    X: np.ndarray,          # (T, m, d) per-round per-learner inputs
    Y: np.ndarray,          # (T, m)
    sync_budget: Optional[int] = None,
    compress_method: str = compression.DEFAULT_METHOD,
) -> SimResult:
    """Run T rounds of m kernel learners under the given protocol.

    sync_budget: budget of the synchronized (averaged) model that is
    shipped back to the learners.  Defaults to the learner budget tau —
    i.e. the average (union, budget m*tau) is compressed back to tau
    before redistribution; the measured compression error feeds the
    epsilon term of Thm. 4.
    """
    T, m, d = X.shape
    assert d == lcfg.dim
    learners.check_id_capacity(T)
    tau = lcfg.budget
    sync_budget = sync_budget or tau
    spec = lcfg.kernel

    states = [learners.init_state(lcfg, i) for i in range(m)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *states)

    vupdate = jax.jit(jax.vmap(partial(learners.update, lcfg)))

    @jax.jit
    def local_distances(models: SVModel, ref: SVModel):
        return rkhs.stacked_dist_to(spec, models, ref)

    @jax.jit
    def divergence(models: SVModel):
        return rkhs.divergence_stacked(spec, models)

    @jax.jit
    def make_sync(models: SVModel):
        fbar = rkhs.average_stacked(models)          # budget m*tau
        fsync, eps = compression.compress(spec, fbar, sync_budget, compress_method)
        return fsync, eps

    def set_all(models: SVModel, fsync: SVModel) -> SVModel:
        # learners adopt the (compressed) average; pad/truncate to tau.
        one = rkhs.pad_to_budget(fsync, tau)
        return SVModel(
            sv=jnp.broadcast_to(one.sv[None], (m,) + one.sv.shape),
            alpha=jnp.broadcast_to(one.alpha[None], (m,) + one.alpha.shape),
            sv_id=jnp.broadcast_to(one.sv_id[None], (m,) + one.sv_id.shape),
        )

    # reference model starts as the (empty) average
    reference, _ = make_sync(stacked.model)

    ledger = accounting.CommunicationLedger(accounting.ByteModel(dim=d))
    cum_loss, cum_bytes, cum_err, divs, eps_hist = [], [], [], [], []
    total_loss = 0.0
    total_err = 0.0

    vpredict = jax.jit(
        jax.vmap(lambda f, x: rkhs.predict(spec, f, x[None])[0])
    )

    for t in range(T):
        xb = jnp.asarray(X[t]); yb = jnp.asarray(Y[t])
        # service quality before update (prediction errors); the hinge
        # decision rule is deterministic at a zero margin (yhat >= 0
        # predicts +1), identically in every driver — see
        # engine._err_terms
        yhat = vpredict(stacked.model, xb)
        if lcfg.loss == "hinge":
            pred = jnp.where(yhat >= 0, 1.0, -1.0)
            total_err += float(jnp.sum(pred != yb))
        else:
            total_err += float(jnp.sum((yhat - yb) ** 2))

        stacked, losses = vupdate(stacked, (xb, yb))
        total_loss += float(jnp.sum(losses))

        models = stacked.model
        do_sync = False
        if pcfg.kind == "continuous":
            do_sync = True
        elif pcfg.kind == "periodic":
            do_sync = ((t + 1) % pcfg.period) == 0
        elif pcfg.kind == "dynamic":
            if ((t + 1) % pcfg.mini_batch) == 0:
                dists = np.asarray(local_distances(models, reference))
                do_sync = bool((dists > pcfg.delta).any())

        if do_sync:
            ids = np.asarray(models.sv_id)
            fsync, eps = make_sync(models)
            eps_hist.append(float(eps))
            new_models = set_all(models, fsync)
            stacked = stacked._replace(model=new_models)
            reference = jax.tree.map(lambda x: x, fsync)
            ledger.record_kernel_sync([ids[i] for i in range(m)], t)
        else:
            ledger.record_no_sync()

        divs.append(float(divergence(stacked.model)))
        cum_loss.append(total_loss)
        cum_err.append(total_err)
        cum_bytes.append(ledger.total)

    return SimResult(
        cumulative_loss=np.asarray(cum_loss),
        cumulative_bytes=np.asarray(cum_bytes, dtype=np.int64),
        cumulative_errors=np.asarray(cum_err),
        sync_rounds=np.asarray(ledger.sync_rounds, dtype=np.int64),
        divergences=np.asarray(divs),
        eps_history=np.asarray(eps_hist),
        num_syncs=len(ledger.sync_rounds),
        total_bytes=int(ledger.total),
        total_loss=float(total_loss),
    )


# ---------------------------------------------------------------------------
# Linear-learner simulation (the paper's baseline hypothesis class)
# ---------------------------------------------------------------------------


def run_linear_simulation(
    lcfg: LearnerConfig,
    pcfg: ProtocolConfig,
    X: np.ndarray,
    Y: np.ndarray,
) -> SimResult:
    T, m, d = X.shape
    states = [learners.init_state(lcfg, i) for i in range(m)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *states)

    vupdate = jax.jit(jax.vmap(partial(learners.update, lcfg)))

    @jax.jit
    def dists_to(st, ref):
        return jax.vmap(
            lambda s: jnp.sum((s.w - ref.w) ** 2) + (s.b - ref.b) ** 2
        )(st)

    @jax.jit
    def diverg(st):
        wbar = jnp.mean(st.w, axis=0); bbar = jnp.mean(st.b)
        return jnp.mean(jnp.sum((st.w - wbar[None, :]) ** 2, -1)
                        + (st.b - bbar) ** 2)

    @jax.jit
    def avg(st):
        return learners.LinearLearnerState(
            w=jnp.mean(st.w, axis=0), b=jnp.mean(st.b)
        )

    reference = avg(stacked)
    ledger = accounting.CommunicationLedger(accounting.ByteModel(dim=d))
    cum_loss, cum_bytes, cum_err, divs = [], [], [], []
    total_loss = 0.0; total_err = 0.0
    nparams = d + 1

    # multiply + reduce, matching the substrate layer's layout-
    # independent prediction floats (rkhs.predict rationale)
    vpredict = jax.jit(jax.vmap(lambda s, x: jnp.sum(s.w * x) + s.b))

    for t in range(T):
        xb = jnp.asarray(X[t]); yb = jnp.asarray(Y[t])
        yhat = vpredict(stacked, xb)
        if lcfg.loss == "hinge":
            pred = jnp.where(yhat >= 0, 1.0, -1.0)   # zero margin -> +1
            total_err += float(jnp.sum(pred != yb))
        else:
            total_err += float(jnp.sum((yhat - yb) ** 2))

        stacked, losses = vupdate(stacked, (xb, yb))
        total_loss += float(jnp.sum(losses))

        do_sync = False
        if pcfg.kind == "continuous":
            do_sync = True
        elif pcfg.kind == "periodic":
            do_sync = ((t + 1) % pcfg.period) == 0
        elif pcfg.kind == "dynamic":
            if ((t + 1) % pcfg.mini_batch) == 0:
                dists = np.asarray(dists_to(stacked, reference))
                do_sync = bool((dists > pcfg.delta).any())

        if do_sync:
            mean = avg(stacked)
            stacked = learners.LinearLearnerState(
                w=jnp.broadcast_to(mean.w[None], stacked.w.shape),
                b=jnp.broadcast_to(mean.b[None], stacked.b.shape),
            )
            reference = mean
            ledger.record_linear_sync(nparams, m, t)
        else:
            ledger.record_no_sync()

        divs.append(float(diverg(stacked)))
        cum_loss.append(total_loss)
        cum_err.append(total_err)
        cum_bytes.append(ledger.total)

    return SimResult(
        cumulative_loss=np.asarray(cum_loss),
        cumulative_bytes=np.asarray(cum_bytes, dtype=np.int64),
        cumulative_errors=np.asarray(cum_err),
        sync_rounds=np.asarray(ledger.sync_rounds, dtype=np.int64),
        divergences=np.asarray(divs),
        eps_history=np.zeros((0,)),
        num_syncs=len(ledger.sync_rounds),
        total_bytes=int(ledger.total),
        total_loss=float(total_loss),
    )
