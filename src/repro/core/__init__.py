"""Core library: the paper's contribution as composable JAX modules.

- protocol:    sync operators (none/continuous/periodic/dynamic) over
               stacked-learner pytrees — mesh-agnostic.
- rkhs:        support-vector expansions, Prop. 2 averaging, divergence.
- learners:    (approximately) loss-proportional online learners.
- compression: truncation / projection with exact epsilon.
- accounting:  byte-exact communication model of Sec. 3.
- criterion:   Def. 1 efficiency audit + theorem-level bound checks.
- simulation:  serial m-learner + coordinator experiment driver (oracle).
- substrate:   the learner-substrate layer (SV / RFF / linear behind one
               protocol-facing interface, reference or Pallas backend).
- engine:      device-resident lax.scan driver + protocol-grid sweep,
               one generic scan core over any substrate.
- rff:         Random Fourier Features map + learner state (Sec. 4
               future work; protocol integration via RFFSubstrate).
"""
from . import (accounting, compression, criterion, engine, learners, protocol,
               rff, rkhs, simulation, substrate)
from .learners import LearnerConfig
from .protocol import ProtocolConfig, ProtocolState
from .rff import RFFSpec
from .rkhs import KernelSpec, SVModel
from .substrate import (LinearSubstrate, RFFSubstrate, Substrate, SVSubstrate,
                        substrate_of)

__all__ = [
    "accounting", "compression", "criterion", "engine", "learners", "protocol",
    "rff", "rkhs", "simulation", "substrate",
    "LearnerConfig", "ProtocolConfig", "ProtocolState", "KernelSpec",
    "SVModel", "RFFSpec",
    "Substrate", "SVSubstrate", "RFFSubstrate", "LinearSubstrate",
    "substrate_of",
]
