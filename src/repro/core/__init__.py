"""Core library: the paper's contribution as composable JAX modules.

- protocol:    sync operators (none/continuous/periodic/dynamic) over
               stacked-learner pytrees — mesh-agnostic.
- rkhs:        support-vector expansions, Prop. 2 averaging, divergence.
- learners:    (approximately) loss-proportional online learners.
- compression: truncation / projection with exact epsilon.
- accounting:  byte-exact communication model of Sec. 3.
- criterion:   Def. 1 efficiency audit + theorem-level bound checks.
- simulation:  serial m-learner + coordinator experiment driver (oracle).
- engine:      device-resident lax.scan driver + protocol-grid sweep.
- rff:         Random Fourier Features learner (Sec. 4 future work).
"""
from . import (accounting, compression, criterion, engine, learners, protocol,
               rff, rkhs, simulation)
from .learners import LearnerConfig
from .protocol import ProtocolConfig, ProtocolState
from .rkhs import KernelSpec, SVModel

__all__ = [
    "accounting", "compression", "criterion", "engine", "learners", "protocol",
    "rff", "rkhs", "simulation",
    "LearnerConfig", "ProtocolConfig", "ProtocolState", "KernelSpec", "SVModel",
]
