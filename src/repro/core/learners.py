"""Online learning algorithms A = (H, phi, ell) used by the protocols.

All learners expose a functional update

    update(state, (x, y)) -> (new_state, loss)

and are members of the (approximately) loss-proportional convex update
family the paper's analysis requires:

- drift bound:      ||f - phi(f, x, y)||  <=  eta * ell(f, x, y)
- convex target:    the update moves toward the minimizer set of ell
- gamma-proportional: ||phi(f) - phi(g)||^2 <= ||f-g||^2
                      - gamma^2 (ell(f) - ell(g))^2

Implemented:
- ``KernelSGD``  — NORMA (Kivinen, Smola, Williamson 2004): regularized
  SGD in an RKHS; coefficient decay (1 - eta*lam) plus one new SV per
  lossy round.  With a fixed budget the slot eviction is the truncation
  compression, making the update *approximately* loss-proportional
  (Lemma 3) with the epsilon of compression.py.
- ``KernelPA``   — kernel Passive-Aggressive (Crammer et al. 2006):
  exactly loss-proportional convex update, tau_pa = min(C, ell/k(x,x)).
- ``LinearSGD`` / ``LinearPA`` — the Euclidean originals from [10],
  used as the paper's linear baselines (Figs. 1 and 2).

Losses: ``hinge`` (classification, y in {-1,+1}) and ``squared``
(regression).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .rkhs import (
    KernelSpec,
    SVModel,
    empty_model,
    insert_sv,
    predict,
    scale_model,
)

Array = jnp.ndarray

# A global cap on the number of learners used only to mint unique
# support-vector ids (id = counter * MAX_LEARNERS + learner_id).
MAX_LEARNERS = 4096

# sv_ids are minted in int32 (the dtype of rkhs.SVModel.sv_id and of the
# whole sorted-id set algebra behind the byte ledger: rkhs.sorted_unique
# pads with ID_SENTINEL = int32 max, accounting.DeviceLedger stores
# int32 arrays).  With id = counter * MAX_LEARNERS + learner_id the
# counter may not exceed this bound or the id wraps negative and the
# slot silently reads as *empty*, corrupting the Sec. 3 accounting.
# The counter increments at most once per processed example, so any
# driver can enforce the bound up front from its round count T via
# ``check_id_capacity`` (engine.run/sweep, the serial oracle, and the
# async harness all do).  Minting in int64 instead would need
# jax_enable_x64, which the launchers keep off — so the bound is
# guarded, not widened: ~524k insertions per learner.
MAX_INSERTIONS_PER_LEARNER = (2**31 - 1) // MAX_LEARNERS


def check_id_capacity(num_rounds: int) -> None:
    """Refuse runs long enough to wrap the int32 sv_id space.

    ``num_rounds`` is an upper bound on any learner's insertion counter
    (one insertion per lossy round).  Raises ValueError beyond
    ``MAX_INSERTIONS_PER_LEARNER``.
    """
    if num_rounds > MAX_INSERTIONS_PER_LEARNER:
        raise ValueError(
            f"{num_rounds} rounds can mint sv_ids past int32 "
            f"(counter * MAX_LEARNERS + learner_id wraps after "
            f"{MAX_INSERTIONS_PER_LEARNER} insertions per learner); "
            "shard the stream into shorter runs")


@dataclasses.dataclass(frozen=True)
class LearnerConfig:
    """Configuration of an online learner.

    algo: kernel_sgd | kernel_pa | linear_sgd | linear_pa
    loss: hinge | squared
    eta: learning rate (SGD); also the drift constant of Prop. 6.
    lam: regularization (NORMA decay (1 - eta*lam)).
    C: PA aggressiveness cap.
    budget: SV budget tau (kernel learners).
    evict: smallest | oldest  (inline truncation policy).
    kernel: KernelSpec for the RKHS.
    dim: input dimensionality d.
    """

    algo: str = "kernel_sgd"
    loss: str = "hinge"
    eta: float = 0.5
    lam: float = 0.01
    C: float = 1.0
    budget: int = 64
    evict: str = "smallest"
    kernel: KernelSpec = dataclasses.field(default_factory=KernelSpec)
    dim: int = 8

    def __post_init__(self):
        if self.algo not in ("kernel_sgd", "kernel_pa", "linear_sgd", "linear_pa"):
            raise ValueError(f"unknown algo {self.algo!r}")
        if self.loss not in ("hinge", "squared"):
            raise ValueError(f"unknown loss {self.loss!r}")

    @property
    def is_kernel(self) -> bool:
        return self.algo.startswith("kernel")


class KernelLearnerState(NamedTuple):
    model: SVModel
    counter: Array      # int32 — per-learner insertion counter
    learner_id: Array   # int32 — index of this learner in [m]


class LinearLearnerState(NamedTuple):
    w: Array            # (d,)
    b: Array            # ()


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def loss_and_grad(loss: str, yhat: Array, y: Array) -> Tuple[Array, Array]:
    """Returns (ell, dell/dyhat).  Shared by the learners here and the
    primal substrates (core/substrate.py)."""
    if loss == "hinge":
        ell = jnp.maximum(0.0, 1.0 - y * yhat)
        g = jnp.where(ell > 0.0, -y, 0.0)
        return ell, g
    # squared
    r = yhat - y
    return 0.5 * r * r, r


_loss_and_grad = loss_and_grad


# ---------------------------------------------------------------------------
# Kernel learners
# ---------------------------------------------------------------------------


def init_kernel_state(cfg: LearnerConfig, learner_id: int) -> KernelLearnerState:
    return KernelLearnerState(
        model=empty_model(cfg.budget, cfg.dim),
        counter=jnp.zeros((), jnp.int32),
        learner_id=jnp.asarray(learner_id, jnp.int32),
    )


def kernel_update(
    cfg: LearnerConfig, state: KernelLearnerState, example: Tuple[Array, Array]
) -> Tuple[KernelLearnerState, Array]:
    x, y = example
    yhat = predict(cfg.kernel, state.model, x[None])[0]
    return kernel_update_from_yhat(cfg, state, example, yhat)


def kernel_update_from_yhat(
    cfg: LearnerConfig,
    state: KernelLearnerState,
    example: Tuple[Array, Array],
    yhat: Array,
) -> Tuple[KernelLearnerState, Array]:
    """``kernel_update`` with the prediction supplied by the caller.

    The fused scan round (core/substrate.py) computes yhat once per
    round and feeds it both to the loss record and here, halving the
    Gram work per round; passing exactly ``predict(...)``'s value makes
    this bit-identical to ``kernel_update``.
    """
    x, y = example
    f = state.model
    ell, g = _loss_and_grad(cfg.loss, yhat, y)

    kxx = {
        "gaussian": jnp.asarray(1.0, jnp.float32),
        "linear": jnp.sum(x * x),
        "poly": (jnp.sum(x * x) + cfg.kernel.coef0) ** cfg.kernel.degree,
    }[cfg.kernel.kind]

    if cfg.algo == "kernel_sgd":
        f = scale_model(f, 1.0 - cfg.eta * cfg.lam)
        alpha_new = -cfg.eta * g
    else:  # kernel_pa
        tau_pa = jnp.minimum(cfg.C, ell / jnp.maximum(kxx, 1e-12))
        direction = y if cfg.loss == "hinge" else -jnp.sign(yhat - y)
        alpha_new = tau_pa * direction

    new_id = state.counter * MAX_LEARNERS + state.learner_id
    do_insert = jnp.abs(alpha_new) > 0.0

    f_ins = insert_sv(f, x, alpha_new, new_id, evict=cfg.evict)
    f2 = SVModel(
        sv=jnp.where(do_insert, f_ins.sv, f.sv),
        alpha=jnp.where(do_insert, f_ins.alpha, f.alpha),
        sv_id=jnp.where(do_insert, f_ins.sv_id, f.sv_id),
    )
    new_state = KernelLearnerState(
        model=f2,
        counter=state.counter + do_insert.astype(jnp.int32),
        learner_id=state.learner_id,
    )
    return new_state, ell


# ---------------------------------------------------------------------------
# Linear learners (the paper's baselines)
# ---------------------------------------------------------------------------


def init_linear_state(cfg: LearnerConfig) -> LinearLearnerState:
    return LinearLearnerState(w=jnp.zeros((cfg.dim,), jnp.float32), b=jnp.zeros((), jnp.float32))


def linear_update(
    cfg: LearnerConfig, state: LinearLearnerState, example: Tuple[Array, Array]
) -> Tuple[LinearLearnerState, Array]:
    x, y = example
    # multiply + reduce, not a dot: keeps the float result independent
    # of the learner-axis layout (see rkhs.predict / DESIGN.md Sec. 9)
    yhat = jnp.sum(state.w * x) + state.b
    ell, g = _loss_and_grad(cfg.loss, yhat, y)

    if cfg.algo == "linear_sgd":
        w = (1.0 - cfg.eta * cfg.lam) * state.w - cfg.eta * g * x
        b = state.b - cfg.eta * g
    else:  # linear_pa
        tau_pa = jnp.minimum(cfg.C, ell / jnp.maximum(jnp.sum(x * x) + 1.0, 1e-12))
        direction = y if cfg.loss == "hinge" else -jnp.sign(yhat - y)
        w = state.w + tau_pa * direction * x
        b = state.b + tau_pa * direction
    return LinearLearnerState(w=w, b=b), ell


# ---------------------------------------------------------------------------
# Uniform entry points
# ---------------------------------------------------------------------------


def init_state(cfg: LearnerConfig, learner_id: int = 0):
    if cfg.is_kernel:
        return init_kernel_state(cfg, learner_id)
    return init_linear_state(cfg)


def update(cfg: LearnerConfig, state, example):
    if cfg.is_kernel:
        return kernel_update(cfg, state, example)
    return linear_update(cfg, state, example)


def gamma_of(cfg: LearnerConfig) -> float:
    """The loss-proportionality constant used in Thm. 4's bound."""
    return cfg.eta if cfg.algo.endswith("sgd") else min(cfg.C, 1.0)
