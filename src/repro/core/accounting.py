"""Byte-exact communication accounting (paper, Sec. 3).

The paper measures cumulative communication C(T, m) = sum_t c(f_t) in
bytes, under a designated-coordinator topology with the *trivial
communication-reduction strategy*:

  upload  (learner i -> coordinator):  |S_t^i| B_alpha  +  |S_t^i \\ Sbar_{t'}| B_x
  download(coordinator -> learner i):  |Sbar_t| B_alpha +  |Sbar_t \\ S_t^i| B_x

where t' is the last synchronization time, B_x in O(d) bytes per
support vector and B_alpha in O(1) bytes per coefficient.  Support
vectors already known to the receiving side are never re-sent; identity
is tracked through the unique ``sv_id`` tags of rkhs.SVModel.

For linear models a synchronization costs m uploads + m downloads of a
fixed-size weight vector.

Beyond the paper (DESIGN.md Sec. 3 hardware-adaptation): on a TPU mesh
there is no coordinator; averaging is a ring all-reduce in which each
of m participants moves 2 (m-1)/m |theta| bytes, i.e. a ring TOTAL of
2 (m-1) |theta| bytes.  ``allreduce_bytes`` and ``allgather_bytes``
price that topology — both return ring *totals*, the same semantics as
``sync_bytes_linear`` / ``sync_bytes_kernel`` on the coordinator side —
so every experiment can report the two topologies side by side
(``engine.run(..., topology="allreduce")``, DESIGN.md Sec. 9).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class ByteModel:
    """B_x = bytes per support vector (O(d)); B_alpha per coefficient."""

    dim: int
    dtype_bytes: int = 4
    id_bytes: int = 4

    @property
    def B_x(self) -> int:
        # vector payload + its id tag
        return self.dim * self.dtype_bytes + self.id_bytes

    @property
    def B_alpha(self) -> int:
        # coefficient + the id it belongs to
        return self.dtype_bytes + self.id_bytes


def idset(ids: np.ndarray) -> set:
    """Active sv_id set of an id array (negative = empty slot)."""
    ids = np.asarray(ids).reshape(-1)
    return set(int(i) for i in ids if i >= 0)


_idset = idset


def sync_bytes_kernel(
    bm: ByteModel,
    local_ids: Sequence[np.ndarray],
    coordinator_known: set,
) -> tuple[int, set]:
    """Bytes for one synchronization of kernel models.

    local_ids: per-learner arrays of active sv_ids at sync time.
    coordinator_known: ids of Sbar_{t'} cached at the coordinator.

    Returns (bytes, new_coordinator_known = Sbar_t ids).
    """
    sets = [_idset(a) for a in local_ids]
    union = set().union(*sets) if sets else set()
    total = 0
    for s in sets:
        # upload: all coefficients, only new support vectors
        total += len(s) * bm.B_alpha + len(s - coordinator_known) * bm.B_x
        # download: all average coefficients, only unknown-to-i vectors
        total += len(union) * bm.B_alpha + len(union - s) * bm.B_x
    return total, union


def sync_bytes_linear(num_params: int, m: int, dtype_bytes: int = 4) -> int:
    """m uploads + m downloads of a fixed-size weight vector.

    This is also the RFF substrate's cost with num_params = D + 1: a
    random-feature model is a fixed-size primal vector, so per-sync
    bytes are independent of the rounds seen (Cor. 8 strict
    adaptivity — the paper's Sec. 4 'future work' case).
    """
    return 2 * m * num_params * dtype_bytes


# -- per-message payload sizing (used by the async transport and the
#    substrate layer's upload/download accounting) --------------------------


def kernel_payload_bytes(bm: ByteModel, send_ids: set,
                         receiver_known: set) -> int:
    """Bytes to ship an expansion over ``send_ids`` to a receiver that
    already caches ``receiver_known``: every coefficient, only novel
    support vectors (the Sec. 3 delta encoding per link)."""
    return (len(send_ids) * bm.B_alpha
            + len(send_ids - receiver_known) * bm.B_x)


def linear_payload_bytes(num_params: int, dtype_bytes: int = 4) -> int:
    """Dense weight vectors have no identity structure: full re-send."""
    return num_params * dtype_bytes


def allreduce_bytes(num_params: int, m: int, dtype_bytes: int = 4) -> int:
    """TOTAL ring bytes of one all-reduce of a |theta|-parameter vector:
    ``2 (m-1) |theta| B`` (reduce-scatter + all-gather; each of the m
    participants moves ``2 (m-1)/m |theta| B`` of that total).

    The total semantics match the coordinator-side accounting
    (``sync_bytes_linear`` = ``2 m |theta| B`` total), so the two
    topologies compare directly: per direction the ring moves a
    ``(m-1)/m`` fraction of the coordinator's bytes
    (tests/test_accounting.py pins the ratio)."""
    if m <= 1:
        return 0
    return int(2 * (m - 1) * num_params * dtype_bytes)


def allgather_bytes(shard_bytes: int, m: int) -> int:
    """TOTAL ring bytes of one all-gather where each of m participants
    contributes a ``shard_bytes``-sized shard: every participant
    receives the other m-1 shards, so the ring moves
    ``m (m-1) shard_bytes`` in total.

    This prices the SV substrate's mesh synchronization
    (``topology="allreduce"``, DESIGN.md Sec. 9): support-vector
    expansions have no slot alignment across learners, so the mesh
    average is an all-gather of the m budget-tau expansions rather
    than a reduce-scatter."""
    if m <= 1:
        return 0
    return int(m * (m - 1) * shard_bytes)


# ---------------------------------------------------------------------------
# Device-resident ledger (DESIGN.md Sec. 7)
# ---------------------------------------------------------------------------
#
# ``CommunicationLedger`` below runs the Sec. 3 set algebra in numpy on
# the host — one Python call per round, which is what caps the serial
# simulation driver at host speed.  ``DeviceLedger`` is the same
# accounting expressed over fixed-shape sorted id arrays so it can live
# inside a jitted ``lax.scan`` (core/engine.py): sets become
# ID_SENTINEL-padded sorted arrays, distinctness a neighbour
# comparison, membership a searchsorted probe (rkhs.sorted_unique /
# rkhs.count_members).  tests/test_engine.py proves the two ledgers
# agree byte-for-byte on randomized sync sequences.


class DeviceLedger(NamedTuple):
    """Jit-compatible coordinator cache: ``known`` is the sorted-unique
    id array of Sbar_{t'} (the support set shipped at the last sync),
    padded with rkhs.ID_SENTINEL.  Capacity is fixed at m * tau — the
    union of m budget-tau expansions can never exceed it."""

    known: "jnp.ndarray"


def device_ledger_init(capacity: int) -> DeviceLedger:
    """Fresh coordinator cache (nothing known — first sync ships all)."""
    import jax.numpy as jnp

    from .rkhs import ID_SENTINEL

    return DeviceLedger(known=jnp.full((capacity,), ID_SENTINEL, jnp.int32))


def device_sync_bytes_kernel(
    bm: ByteModel, stacked_ids: "jnp.ndarray", ledger: DeviceLedger,
    mask: "jnp.ndarray | None" = None,
) -> "tuple[jnp.ndarray, DeviceLedger]":
    """``sync_bytes_kernel`` under jit: bytes for one kernel-model sync.

    stacked_ids: (m, tau) int32 active sv_ids at sync time (-1 = empty
    slot; duplicated ids — support vectors shared after an earlier sync
    — are transmitted / stored once, exactly as the host ledger's set
    semantics).  Returns (bytes, ledger with known = Sbar_t).

    Per learner i with distinct active set s_i, known cache K and union
    U = ∪_i s_i (note s_i ⊆ U, so |U \\ s_i| = |U| - |s_i|):

      upload   |s_i| B_alpha + |s_i \\ K| B_x
      download |U| B_alpha + (|U| - |s_i|) B_x

    ``mask`` (m,) bool restricts the synchronization to a participating
    cohort (DESIGN.md Sec. 15): non-participating learners neither
    upload nor download, contribute nothing to the union, and the new
    coordinator cache ``known`` is the cohort union only — exactly the
    Sec. 3 formulas evaluated over the sampled learner subset (the
    host-side oracle is ``sync_bytes_kernel`` over the filtered id
    lists, pinned by tests/test_population.py).  ``mask=None`` is the
    full-participation case with ``m`` a static constant, unchanged.
    """
    import jax
    import jax.numpy as jnp

    from . import rkhs

    m, tau = stacked_ids.shape
    # The arithmetic below runs in int32 (x64 is disabled by default).
    # Worst case per sync: every learner ships tau distinct vectors and
    # downloads a full m*tau union — refuse shapes that could wrap.
    # A mask only shrinks the cohort, so the full-m worst case covers it.
    worst = m * tau * (bm.B_alpha + bm.B_x) * (m + 1)
    if worst >= 2**31:
        raise ValueError(
            f"per-sync bytes can reach {worst} for m={m}, tau={tau}, "
            f"d={bm.dim}, which overflows the device ledger's int32; "
            "use the host CommunicationLedger at this scale")
    if mask is not None:
        # a non-participating learner's id row becomes the empty set:
        # n_i = 0, in_known_i = 0, and it adds nothing to the union
        stacked_ids = jnp.where(mask[:, None], stacked_ids, -1)
    uniq, n = jax.vmap(rkhs.sorted_unique)(stacked_ids)    # (m, tau), (m,)
    union, u = rkhs.sorted_unique(uniq)                    # (m*tau,), ()
    in_known = jax.vmap(
        lambda q: rkhs.count_members(q, ledger.known))(uniq)  # (m,)
    n_total = jnp.sum(n)
    downloaders = (jnp.sum(
        # reprolint: allow[ACC01] int32 cohort count; the worst >= 2**31 guard above covers it
        mask.astype(jnp.int32)) if mask is not None
        else m)
    total = (
        n_total * bm.B_alpha
        + jnp.sum(n - in_known) * bm.B_x
        + downloaders * u * bm.B_alpha
        + (downloaders * u - n_total) * bm.B_x
    )
    cap = ledger.known.shape[0]
    if union.shape[0] != cap:
        raise ValueError(
            f"union capacity {union.shape[0]} != ledger capacity {cap}")
    # reprolint: allow[ACC01] int32 is safe here: the worst >= 2**31 guard above rejects overflow
    return total.astype(jnp.int32), DeviceLedger(known=union)


def device_rejoin_bytes_kernel(
    bm: ByteModel, ref_ids: "jnp.ndarray", stacked_ids: "jnp.ndarray",
    rejoin: "jnp.ndarray",
) -> "jnp.ndarray":
    """Sec. 3 download bytes of re-``adopt``-ing rejoining learners
    (DESIGN.md Sec. 15): a learner that recovers from churn downloads
    the coordinator's current reference model before its first round
    back.  Per rejoining learner i with current id set s_i and the
    reference's distinct id set R, the link is the standard per-message
    delta encoding (``kernel_payload_bytes`` on the host):

        |R| B_alpha + |R \\ s_i| B_x

    ``ref_ids``: the reference model's sv_id array; ``stacked_ids``:
    (m, tau) learner ids; ``rejoin``: (m,) bool.  Returns int32 total.
    """
    import jax
    import jax.numpy as jnp

    from . import rkhs

    m, tau = stacked_ids.shape
    # same static worst-case envelope as device_sync_bytes_kernel: at
    # most m learners each download a full budget of novel vectors
    worst = m * max(int(ref_ids.reshape(-1).shape[0]), tau) \
        * (bm.B_alpha + bm.B_x)
    if worst >= 2**31:
        raise ValueError(
            f"per-round rejoin bytes can reach {worst} for m={m}, "
            "which overflows the int32 byte column; use the host "
            "accounting at this scale")
    ref_uniq, ref_n = rkhs.sorted_unique(ref_ids)
    sorted_rows, _ = jax.vmap(rkhs.sorted_unique)(stacked_ids)
    overlap = jax.vmap(
        lambda row: rkhs.count_members(ref_uniq, row))(sorted_rows)  # (m,)
    per = ref_n * bm.B_alpha + (ref_n - overlap) * bm.B_x
    # reprolint: allow[ACC01] int32 is safe here: the worst >= 2**31 guard above rejects overflow
    return jnp.sum(jnp.where(rejoin, per, 0)).astype(jnp.int32)


class CommunicationLedger:
    """Running C(T, m) with per-round records, used by the simulation
    driver and the figure benchmarks."""

    def __init__(self, bm: ByteModel):
        self.bm = bm
        self.coordinator_known: set = set()
        self.total = 0
        self.rounds: list[int] = []          # bytes per round
        self.sync_rounds: list[int] = []     # round indices of syncs

    def record_no_sync(self) -> None:
        self.rounds.append(0)

    def record_kernel_sync(self, local_ids: Sequence[np.ndarray], t: int) -> int:
        b, known = sync_bytes_kernel(self.bm, local_ids, self.coordinator_known)
        self.coordinator_known = known
        self.total += b
        self.rounds.append(b)
        self.sync_rounds.append(t)
        return b

    def record_linear_sync(self, num_params: int, m: int, t: int) -> int:
        b = sync_bytes_linear(num_params, m, self.bm.dtype_bytes)
        self.total += b
        self.rounds.append(b)
        self.sync_rounds.append(t)
        return b

    @property
    def cumulative(self) -> np.ndarray:
        return np.cumsum(np.asarray(self.rounds, dtype=np.int64))
