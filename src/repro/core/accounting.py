"""Byte-exact communication accounting (paper, Sec. 3).

The paper measures cumulative communication C(T, m) = sum_t c(f_t) in
bytes, under a designated-coordinator topology with the *trivial
communication-reduction strategy*:

  upload  (learner i -> coordinator):  |S_t^i| B_alpha  +  |S_t^i \\ Sbar_{t'}| B_x
  download(coordinator -> learner i):  |Sbar_t| B_alpha +  |Sbar_t \\ S_t^i| B_x

where t' is the last synchronization time, B_x in O(d) bytes per
support vector and B_alpha in O(1) bytes per coefficient.  Support
vectors already known to the receiving side are never re-sent; identity
is tracked through the unique ``sv_id`` tags of rkhs.SVModel.

For linear models a synchronization costs m uploads + m downloads of a
fixed-size weight vector.

Beyond the paper (DESIGN.md Sec. 3 hardware-adaptation): on a TPU mesh
there is no coordinator; averaging is a ring all-reduce moving
2 (m-1)/m |theta| bytes per participant.  ``allreduce_bytes`` reports
that cost so EXPERIMENTS.md can compare both topologies.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class ByteModel:
    """B_x = bytes per support vector (O(d)); B_alpha per coefficient."""

    dim: int
    dtype_bytes: int = 4
    id_bytes: int = 4

    @property
    def B_x(self) -> int:
        # vector payload + its id tag
        return self.dim * self.dtype_bytes + self.id_bytes

    @property
    def B_alpha(self) -> int:
        # coefficient + the id it belongs to
        return self.dtype_bytes + self.id_bytes


def idset(ids: np.ndarray) -> set:
    """Active sv_id set of an id array (negative = empty slot)."""
    ids = np.asarray(ids).reshape(-1)
    return set(int(i) for i in ids if i >= 0)


_idset = idset


def sync_bytes_kernel(
    bm: ByteModel,
    local_ids: Sequence[np.ndarray],
    coordinator_known: set,
) -> tuple[int, set]:
    """Bytes for one synchronization of kernel models.

    local_ids: per-learner arrays of active sv_ids at sync time.
    coordinator_known: ids of Sbar_{t'} cached at the coordinator.

    Returns (bytes, new_coordinator_known = Sbar_t ids).
    """
    sets = [_idset(a) for a in local_ids]
    union = set().union(*sets) if sets else set()
    total = 0
    for s in sets:
        # upload: all coefficients, only new support vectors
        total += len(s) * bm.B_alpha + len(s - coordinator_known) * bm.B_x
        # download: all average coefficients, only unknown-to-i vectors
        total += len(union) * bm.B_alpha + len(union - s) * bm.B_x
    return total, union


def sync_bytes_linear(num_params: int, m: int, dtype_bytes: int = 4) -> int:
    """m uploads + m downloads of a fixed-size weight vector."""
    return 2 * m * num_params * dtype_bytes


def allreduce_bytes(num_params: int, m: int, dtype_bytes: int = 4) -> int:
    """Ring all-reduce cost: each of m participants moves
    2 (m-1)/m * |theta| bytes (reduce-scatter + all-gather)."""
    if m <= 1:
        return 0
    return int(2 * (m - 1) * num_params * dtype_bytes)


class CommunicationLedger:
    """Running C(T, m) with per-round records, used by the simulation
    driver and the figure benchmarks."""

    def __init__(self, bm: ByteModel):
        self.bm = bm
        self.coordinator_known: set = set()
        self.total = 0
        self.rounds: list[int] = []          # bytes per round
        self.sync_rounds: list[int] = []     # round indices of syncs

    def record_no_sync(self) -> None:
        self.rounds.append(0)

    def record_kernel_sync(self, local_ids: Sequence[np.ndarray], t: int) -> int:
        b, known = sync_bytes_kernel(self.bm, local_ids, self.coordinator_known)
        self.coordinator_known = known
        self.total += b
        self.rounds.append(b)
        self.sync_rounds.append(t)
        return b

    def record_linear_sync(self, num_params: int, m: int, t: int) -> int:
        b = sync_bytes_linear(num_params, m, self.bm.dtype_bytes)
        self.total += b
        self.rounds.append(b)
        self.sync_rounds.append(t)
        return b

    @property
    def cumulative(self) -> np.ndarray:
        return np.cumsum(np.asarray(self.rounds, dtype=np.int64))
