"""RKHS models in support-vector expansion, with Prop. 2 averaging.

The paper generalizes the synchronization protocols from Euclidean
weight vectors to a reproducing kernel Hilbert space H where models are
represented by their dual (support vector) expansion

    f(.) = sum_{x in S} alpha_x k(x, .)

JAX/XLA require static shapes, so an expansion is stored with a fixed
**budget** of slots; inactive slots carry ``alpha = 0`` and ``sv_id =
-1``.  This matches the paper's own conclusion that streaming kernel
learners must bound their model size (truncation / projection — see
compression.py), and makes the budget a first-class config knob tau.

Every support vector carries a globally unique integer id (assigned by
the learner at insertion time).  Ids make the *union* of support sets
(Prop. 2) well defined under the fixed-budget representation and drive
the byte-exact communication accounting of Sec. 3 (a vector already
known to the coordinator is never re-transmitted).  Ids are int32
everywhere — the expansions here, the sorted-id set algebra below, and
``accounting.DeviceLedger`` — and the minting scheme in core/learners
bounds runs to ``learners.MAX_INSERTIONS_PER_LEARNER`` insertions per
learner so an id can never wrap negative (which would silently read as
an empty slot).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


Array = jnp.ndarray


# ---------------------------------------------------------------------------
# Kernel functions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """k : X x X -> R.  ``kind`` in {gaussian, linear, poly}."""

    kind: str = "gaussian"
    gamma: float = 1.0          # gaussian: exp(-gamma ||x-y||^2)
    degree: int = 3             # poly: (x.y + coef0)^degree
    coef0: float = 1.0

    def __post_init__(self):
        if self.kind not in ("gaussian", "linear", "poly"):
            raise ValueError(f"unknown kernel {self.kind!r}")


def gram(spec: KernelSpec, X: Array, Y: Array) -> Array:
    """Dense Gram matrix K[i, j] = k(X[i], Y[j]).  Pure-jnp reference.

    The Pallas-accelerated path lives in repro.kernels.ops.gram; this
    function is the semantic definition used by tests as the oracle and
    by small CPU simulations directly.
    """
    X = X.astype(jnp.float32)
    Y = Y.astype(jnp.float32)
    if spec.kind == "linear":
        return X @ Y.T  # reprolint: allow[DET01] bulk-Gram oracle; the bitwise path is _gram_rows
    if spec.kind == "poly":
        return (X @ Y.T + spec.coef0) ** spec.degree  # reprolint: allow[DET01] bulk-Gram oracle
    # gaussian
    xx = jnp.sum(X * X, axis=-1)[:, None]
    yy = jnp.sum(Y * Y, axis=-1)[None, :]
    sq = jnp.maximum(xx + yy - 2.0 * (X @ Y.T), 0.0)  # reprolint: allow[DET01] bulk-Gram oracle
    return jnp.exp(-spec.gamma * sq)


def kernel_diag(spec: KernelSpec, X: Array) -> Array:
    """k(x, x) for each row (cheap; avoids materializing the diagonal)."""
    if spec.kind == "linear":
        return jnp.sum(X * X, axis=-1)
    if spec.kind == "poly":
        return (jnp.sum(X * X, axis=-1) + spec.coef0) ** spec.degree
    return jnp.ones(X.shape[0], jnp.float32)


# ---------------------------------------------------------------------------
# Support-vector expansion with a fixed budget
# ---------------------------------------------------------------------------


class SVModel(NamedTuple):
    """A budgeted support-vector expansion.

    sv:     (budget, d)  support vector inputs (zeros when inactive)
    alpha:  (budget,)    coefficients (0 when inactive)
    sv_id:  (budget,)    unique int32 id, -1 when the slot is empty
    """

    sv: Array
    alpha: Array
    sv_id: Array

    @property
    def budget(self) -> int:
        return self.sv.shape[0]

    @property
    def dim(self) -> int:
        return self.sv.shape[1]


def empty_model(budget: int, dim: int, dtype=jnp.float32) -> SVModel:
    return SVModel(
        sv=jnp.zeros((budget, dim), dtype),
        alpha=jnp.zeros((budget,), dtype),
        sv_id=-jnp.ones((budget,), jnp.int32),
    )


def active_mask(f: SVModel) -> Array:
    return f.sv_id >= 0


def num_active(f: SVModel) -> Array:
    return jnp.sum(active_mask(f).astype(jnp.int32))


def _gram_rows(spec: KernelSpec, X: Array, Y: Array) -> Array:
    """``gram`` with the cross term as an explicit multiply + last-axis
    reduce instead of ``X @ Y.T``.  Same formula (gaussian still uses
    xx + yy - 2<x,y>), but a row's floats no longer depend on how many
    rows share the call: XLA's gemm/gemv kernels pick row-count-
    dependent accumulation orders, and the prediction path must be
    bit-identical between the single-device engine (m learners in one
    vmap) and the mesh-sharded engine (m/n per device) — DESIGN.md
    Sec. 9.  The (n, budget, d) intermediate is fine at prediction
    shapes (n is 1 in every driver); bulk Gram algebra keeps ``gram``.
    """
    X = X.astype(jnp.float32)
    Y = Y.astype(jnp.float32)
    cross = jnp.sum(X[:, None, :] * Y[None, :, :], axis=-1)
    if spec.kind == "linear":
        return cross
    if spec.kind == "poly":
        return (cross + spec.coef0) ** spec.degree
    xx = jnp.sum(X * X, axis=-1)[:, None]
    yy = jnp.sum(Y * Y, axis=-1)[None, :]
    sq = jnp.maximum(xx + yy - 2.0 * cross, 0.0)
    return jnp.exp(-spec.gamma * sq)


def predict(spec: KernelSpec, f: SVModel, X: Array) -> Array:
    """f(X) = K(X, S) alpha, masking inactive slots.

    Evaluated shape-independently (``_gram_rows`` + multiply-reduce):
    this is the value every driver's losses and service errors are
    measured from, so it must not change with the learner-axis layout.
    """
    a = jnp.where(active_mask(f), f.alpha, 0.0)
    return jnp.sum(_gram_rows(spec, X, f.sv) * a[None, :], axis=-1)


def quadform(K: Array, a: Array, b: Array) -> Array:
    """a^T K b with a layout-independent reduction order.

    Row-wise multiply + last-axis sum, then one outer sum — the same
    accumulation order whether the caller is batched, vmapped or
    sharded.  ``a @ K @ b`` would lower to gemv pairs whose reduction
    order depends on operand layout (DESIGN.md Sec. 9); every quadform
    feeding divergence / epsilon / norm values must come through here.
    """
    return jnp.sum(a * jnp.sum(K * b[None, :], axis=-1))


def norm_sq(spec: KernelSpec, f: SVModel) -> Array:
    """||f||_H^2 = alpha^T K(S, S) alpha."""
    a = jnp.where(active_mask(f), f.alpha, 0.0)
    return quadform(gram(spec, f.sv, f.sv), a, a)


def dist_sq(spec: KernelSpec, f: SVModel, g: SVModel) -> Array:
    """||f - g||_H^2 = <f,f> + <g,g> - 2<f,g>  (paper, Sec. 2)."""
    af = jnp.where(active_mask(f), f.alpha, 0.0)
    ag = jnp.where(active_mask(g), g.alpha, 0.0)
    return (
        quadform(gram(spec, f.sv, f.sv), af, af)
        + quadform(gram(spec, g.sv, g.sv), ag, ag)
        - 2.0 * quadform(gram(spec, f.sv, g.sv), af, ag)
    )


# ---------------------------------------------------------------------------
# Prop. 2: averaging a model configuration
# ---------------------------------------------------------------------------


def average_stacked(stacked: SVModel) -> SVModel:
    """Average of a stacked configuration (leading axis m) — Prop. 2.

    The average is the expansion over the union of support sets
    Sbar = U_i S^i with coefficients alphabar_s = 1/m sum_i alphabar_s^i
    (zero-padded).  Under the budgeted representation the union is the
    concatenation of all slots with coefficients divided by m; slots
    that share an sv_id are *semantically* merged (they represent the
    same point mass in H, and downstream Gram algebra treats duplicated
    rows exactly as a merged coefficient would).  The result has budget
    m * tau.
    """
    m, tau, d = stacked.sv.shape
    return SVModel(
        sv=stacked.sv.reshape(m * tau, d),
        alpha=jnp.where(
            (stacked.sv_id >= 0), stacked.alpha / m, 0.0
        ).reshape(m * tau),
        sv_id=stacked.sv_id.reshape(m * tau),
    )


# Fixed-shape set algebra over sv_id arrays: a set of ids is represented
# as a sorted int32 array whose inactive tail is padded with ID_SENTINEL.
# This is what lets the byte accounting of Sec. 3 run under jit
# (DESIGN.md Sec. 7): sorted arrays make distinctness a neighbour
# comparison and membership a searchsorted probe, both static-shape.
ID_SENTINEL = jnp.iinfo(jnp.int32).max


def sorted_unique(ids: Array) -> Tuple[Array, Array]:
    """Sorted-distinct representation of an active id set.

    ``ids`` is any int32 array where a slot is *active* iff
    ``0 <= id < ID_SENTINEL`` (empty slots are -1, sentinel padding is
    ID_SENTINEL — so the output of this function is a valid input,
    making it composable for unions).  Returns ``(uniq, count)``:
    ``uniq`` has the same (flattened) length with the distinct active
    ids sorted ascending followed by ID_SENTINEL padding, and ``count``
    is the number of distinct active ids.
    """
    flat = ids.reshape(-1)
    active = (flat >= 0) & (flat < ID_SENTINEL)
    s = jnp.sort(jnp.where(active, flat, ID_SENTINEL))
    first = jnp.concatenate(
        [s[:1] < ID_SENTINEL,
         (s[1:] != s[:-1]) & (s[1:] < ID_SENTINEL)]
    )
    uniq = jnp.sort(jnp.where(first, s, ID_SENTINEL))
    return uniq, jnp.sum(first.astype(jnp.int32))


def count_members(queries: Array, sorted_ids: Array) -> Array:
    """|Q ∩ A| for a sorted-unique query array Q and sorted id array A.

    Both arrays use the ID_SENTINEL padding convention of
    ``sorted_unique``; sentinel slots never count as members.
    """
    idx = jnp.clip(jnp.searchsorted(sorted_ids, queries), 0,
                   sorted_ids.shape[0] - 1)
    hit = (sorted_ids[idx] == queries) & (queries < ID_SENTINEL)
    return jnp.sum(hit.astype(jnp.int32))


def union_unique_count(stacked_or_avg_sv_id: Array) -> Array:
    """|Sbar| — the number of *distinct* active support vector ids.

    Used by the communication accounting: duplicated ids (support
    vectors shared among learners after an earlier synchronization) are
    transmitted / stored once.
    """
    return sorted_unique(stacked_or_avg_sv_id)[1]


def stacked_dist_to(spec: KernelSpec, stacked: SVModel, ref: SVModel) -> Array:
    """Per-learner ||f_i - r||^2, shape (m,).  Local-condition values."""

    def one(f: SVModel) -> Array:
        return dist_sq(spec, f, ref)

    return jax.vmap(one)(stacked)


def divergence_stacked(spec: KernelSpec, stacked: SVModel) -> Array:
    """delta(f) = 1/m sum_i ||f_i - fbar||^2 over RKHS models (Eq. 1)."""
    fbar = average_stacked(stacked)
    return jnp.mean(stacked_dist_to(spec, stacked, fbar))


# ---------------------------------------------------------------------------
# Slot insertion (shared by the online learners)
# ---------------------------------------------------------------------------


def insert_sv(
    f: SVModel,
    x: Array,
    alpha_new: Array,
    new_id: Array,
    evict: str = "smallest",
) -> SVModel:
    """Insert a support vector into a budgeted expansion.

    If a free slot exists it is used; otherwise the slot chosen by the
    eviction policy is overwritten (``smallest`` |alpha| — the
    truncation rule of Kivinen et al. [12]; ``oldest`` — FIFO).  The
    eviction IS the paper's model-compression step: dropping a slot
    perturbs the exact loss-proportional update by at most
    epsilon = |alpha_evicted| * sqrt(k(x_e, x_e)), which is what makes
    the update *approximately* loss-proportional (Lemma 3).
    """
    act = active_mask(f)
    # score: free slots first (score -inf), then per-policy.
    if evict == "smallest":
        score = jnp.where(act, jnp.abs(f.alpha), -jnp.inf)
    elif evict == "oldest":
        score = jnp.where(act, f.sv_id.astype(jnp.float32), -jnp.inf)
    else:
        raise ValueError(f"unknown eviction policy {evict!r}")
    slot = jnp.argmin(score)
    return SVModel(
        sv=f.sv.at[slot].set(x.astype(f.sv.dtype)),
        alpha=f.alpha.at[slot].set(alpha_new.astype(f.alpha.dtype)),
        sv_id=f.sv_id.at[slot].set(new_id.astype(jnp.int32)),
    )


def scale_model(f: SVModel, c: Array) -> SVModel:
    """c * f  (coefficient scaling — e.g. the (1 - eta*lambda) decay)."""
    return f._replace(alpha=f.alpha * c)


def pad_to_budget(f: SVModel, tau: int) -> SVModel:
    """Pad (inactive fill) or truncate an expansion to budget tau.

    Both drivers use this when learners adopt a synchronized model, so
    the serial and async adopt paths stay bit-identical.
    """

    def pad(v, fill):
        if v.shape[0] < tau:
            width = [(0, tau - v.shape[0])] + [(0, 0)] * (v.ndim - 1)
            v = jnp.pad(v, width, constant_values=fill)
        return v[:tau]

    return SVModel(sv=pad(f.sv, 0.0), alpha=pad(f.alpha, 0.0),
                   sv_id=pad(f.sv_id, -1))
