"""Model compression for support-vector expansions (Sec. 3/4).

Two families from the paper:

- **Truncation** (Kivinen et al. [12]): drop support vectors with small
  coefficients.  For SGD with learning rate lambda the compression
  error is bounded by epsilon in O((1/lambda)(1-lambda)^tau) for budget
  tau, which makes the compressed update approximately
  loss-proportional and the dynamic protocol *adaptive* (and with
  consistency, *efficient*).
- **Projection** (Orabona et al. [15], Wang & Vucetic [20]): project
  the dropped support vectors onto the span of the kept ones, i.e.
  solve  K_kk c = K_kd beta  and fold c into the kept coefficients.
  Strictly smaller epsilon than truncation for the same budget, at
  O(tau^3) compression cost; no formal bound on |S| in the paper.

Both return the new model *and* the exact compression error
epsilon = ||f - f~||_H, so the caller can verify Lemma 3 / Theorem 4
empirically (tests/test_bounds.py) and drive the epsilon-dependent
terms of the loss bound.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .rkhs import KernelSpec, SVModel, active_mask, gram, quadform

Array = jnp.ndarray

#: The repo-wide default compression method.  Every entry point that
#: compresses a synchronized model — ``SVSubstrate.compress_method``,
#: ``substrate_of``'s LearnerConfig resolution, the legacy simulation
#: drivers — defaults to this one name, so "what does None mean"
#: resolves to a single constant instead of per-call-site comments.
DEFAULT_METHOD = "truncate"


def _top_tau_mask(f: SVModel, tau: int) -> Array:
    """Boolean mask of the tau active slots with the largest |alpha|."""
    act = active_mask(f)
    score = jnp.where(act, jnp.abs(f.alpha), -jnp.inf)
    order = jnp.argsort(-score)  # descending; inactive (-inf) sink to the end
    keep_idx = order[:tau]
    mask = jnp.zeros(f.budget, bool).at[keep_idx].set(True)
    return mask & act


def _masked_model(f: SVModel, keep: Array) -> SVModel:
    return SVModel(
        sv=jnp.where(keep[:, None], f.sv, 0.0),
        alpha=jnp.where(keep, f.alpha, 0.0),
        sv_id=jnp.where(keep, f.sv_id, -1),
    )


def _pack_to_budget(f: SVModel, keep: Array, tau: int) -> SVModel:
    """Gather the kept slots into a tau-slot model (static shapes)."""
    # indices of kept slots first (stable), padded with dropped slots
    order = jnp.argsort(~keep)  # kept (False<True inverted) first, stable
    idx = order[:tau]
    valid = keep[idx]
    return SVModel(
        sv=jnp.where(valid[:, None], f.sv[idx], 0.0),
        alpha=jnp.where(valid, f.alpha[idx], 0.0),
        sv_id=jnp.where(valid, f.sv_id[idx], -1),
    )


def truncate(
    spec: KernelSpec, f: SVModel, tau: int
) -> Tuple[SVModel, Array]:
    """Truncate f to at most tau support vectors (smallest-|alpha| rule).

    Returns (f_trunc with budget tau, epsilon) where
    epsilon^2 = beta^T K_dd beta over the dropped part — the exact RKHS
    norm of the removed component.
    """
    keep = _top_tau_mask(f, tau)
    act = active_mask(f)
    dropped = act & ~keep
    beta = jnp.where(dropped, f.alpha, 0.0)
    K = gram(spec, f.sv, f.sv)
    eps_sq = jnp.maximum(quadform(K, beta, beta), 0.0)
    return _pack_to_budget(f, keep, tau), jnp.sqrt(eps_sq)


def project(
    spec: KernelSpec, f: SVModel, tau: int, ridge: float = 1e-6
) -> Tuple[SVModel, Array]:
    """Compress f to tau SVs by projecting dropped SVs on the kept span.

    Solves (K_kk + ridge I) c = K_kd beta and adds c to the kept
    coefficients.  epsilon^2 = beta^T K_dd beta - beta^T K_dk c  (the
    residual of the orthogonal projection; clipped at 0 for numerical
    safety).
    """
    keep = _top_tau_mask(f, tau)
    act = active_mask(f)
    dropped = act & ~keep
    beta = jnp.where(dropped, f.alpha, 0.0)

    K = gram(spec, f.sv, f.sv)
    keep_f = keep.astype(K.dtype)
    # Restrict to kept rows/cols by masking; ridge keeps the masked-out
    # diagonal invertible without affecting the kept block's solution.
    K_kk = K * keep_f[:, None] * keep_f[None, :]
    K_kk = K_kk + (ridge + (1.0 - keep_f))[:, None] * jnp.eye(f.budget,
                                                              dtype=K.dtype)
    rhs = jnp.sum(K * beta[None, :], axis=-1) * keep_f
    c = jnp.linalg.solve(K_kk, rhs)
    c = c * keep_f

    eps_sq = quadform(K, beta, beta) - quadform(K, beta, c)
    eps_sq = jnp.maximum(eps_sq, 0.0)

    merged = f._replace(alpha=jnp.where(keep, f.alpha + c, f.alpha))
    return _pack_to_budget(merged, keep, tau), jnp.sqrt(eps_sq)


def compress(
    spec: KernelSpec, f: SVModel, tau: int, method: str = DEFAULT_METHOD
) -> Tuple[SVModel, Array]:
    if method == "truncate":
        return truncate(spec, f, tau)
    if method == "project":
        return project(spec, f, tau)
    raise ValueError(f"unknown compression method {method!r}")


def truncation_error_bound(lam: float, tau: int) -> float:
    """The [12] bound:  epsilon in O((1/lam) (1-lam)^tau)  for SGD with
    learning rate lam and budget tau.  Used by tests to check the
    measured epsilon stays within a constant of the bound."""
    return (1.0 / lam) * (1.0 - lam) ** tau
