"""Learner substrates: one protocol-facing model interface (DESIGN.md Sec. 8).

The paper's protocols are agnostic to how a learner represents its
model: they only ever (1) run the local update, (2) average the m
models (Prop. 2), (3) measure distance to the reference model for the
local conditions, and (4) pay Sec. 3 bytes when a synchronization ships
models around.  A :class:`Substrate` packages exactly those operations,
so the scan engine (core/engine.py) and the asynchronous runtime
(repro/runtime/) each have ONE code path serving every representation:

- :class:`SVSubstrate`      — dual support-vector expansion in the RKHS
  (``rkhs.SVModel``); sync payloads use the delta-encoded id accounting
  (``accounting.DeviceLedger`` under jit, id sets on the host).
- :class:`RFFSubstrate`     — primal weights over D random Fourier
  features (paper Sec. 4 "future work", cf. Bouboulis et al.): kernel-
  quality models at *linear-model* communication cost — every sync
  costs O(m D) bytes independent of the rounds seen, so Cor. 8's strict
  adaptivity applies verbatim.
- :class:`LinearSubstrate`  — the paper's Euclidean baselines.

Substrates are frozen (hashable) dataclasses: the engine's compiled-
function cache and the runtime's jitted node-op cache key on them
directly.

Backend dispatch: ``backend="reference"`` evaluates kernel algebra with
the pure-jnp definitions in core/rkhs.py and core/rff.py (the semantic
oracles); ``backend="pallas"`` routes ``predict`` / ``predict_batch`` /
``dist_to_ref`` / ``divergence`` and the fused scan round through the
fused TPU kernels ``kernels.ops.sv_predict`` / ``fused_primal_step`` /
``quadform`` / ``rff_features`` (interpret mode validates them on
CPU).  The dispatch is *engage-aware* (``kernels.ops.engages``): below
the Pallas launch threshold the pallas backend runs the exact
reference expressions, so small-model pallas runs are bit-identical to
``backend="reference"`` — which is what makes the Def. 1 byte ledger
backend-independent by construction (tools/substrate_matrix.py pins
it across the full substrate x protocol x driver matrix).

Two faces, one contract
-----------------------
Scan face (jit-side, stacked over the learner axis m):
``init / predict / update / average_stacked / adopt / dist_to_ref /
divergence / ledger_init / sync_payload``.  ``sync_payload`` implements
the Sec. 3 byte accounting *for this representation*: delta-encoded
support-vector sets for SV, a fixed ``2 m (D+1) B`` for RFF, a fixed
``2 m (d+1) B`` for linear.

Node face (host-side, one model per node, used by repro/runtime):
``init_node / node_model / update_one / predict_one / dist_one /
init_reference / upload_payload / download_payload_bytes / aggregate /
adopt_node`` plus the snapshot hooks the async harness uses to record
round-indexed divergences.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, NamedTuple, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import accounting, compression, learners, rff, rkhs
from .learners import LearnerConfig, LinearLearnerState
from .rff import RFFLearnerState, RFFSpec
from .rkhs import SVModel

Array = jnp.ndarray

_BACKENDS = ("reference", "pallas")


def _kops():
    """Lazy import of the Pallas op wrappers (kernels.ops)."""
    from ..kernels import ops
    return ops


# ---------------------------------------------------------------------------
# Interface
# ---------------------------------------------------------------------------


class Substrate:
    """Protocol-facing model representation (see module docstring).

    Class attributes every implementation sets:

    - ``loss``: the surrogate loss name ("hinge" | "squared") — the
      engine uses it to measure service errors.
    - ``input_dim``: expected feature dimension d of the stream.
    - ``has_eps``: syncs produce a compression-error series epsilon
      (Thm. 4's epsilon term); False for exact (primal) substrates.
    - ``free_divergence``: recording delta(f_t) is O(m d)-cheap, so the
      engine records it every round (matching the legacy linear
      driver); False makes recording opt-in (SV: a full union Gram).
    - ``guarded_dist_check``: wrap the dynamic local-condition check in
      ``lax.cond`` so the (expensive) distance computation only runs on
      check rounds; False evaluates it unconditionally (cheap).
    """

    loss: str = "hinge"
    has_eps: bool = False
    free_divergence: bool = True
    guarded_dist_check: bool = False

    # -- scan face ----------------------------------------------------------

    def init(self, m: int):
        raise NotImplementedError

    def models_of(self, state):
        return state

    def with_models(self, state, models):
        return models

    def predict(self, models, x: Array) -> Array:
        raise NotImplementedError

    def predict_batch(self, models, lids: Array, Xb: Array) -> Array:
        """Serve a padded batch of predict requests from the stacked
        models: request ``i`` is answered by learner ``lids[i]``'s
        current model on input ``Xb[i]`` -> (n,) predictions.

        This is the serving engine's hot path (DESIGN.md Sec. 10):
        ``lids`` (n,) int32 home-learner ids, ``Xb`` (n, d) inputs, n a
        *static bucket size* so each bucket keys one compile-cache
        entry.  Padding rows repeat a learner id already present in
        the batch (the serving engine uses the chunk's first, keeping
        the gather shard-local under mesh routing) with zero inputs,
        and are discarded by the caller.

        Bit-exactness contract: row ``i``'s floats equal
        ``predict_one(models[lids[i]], Xb[i])`` regardless of how many
        rows share the call — guaranteed because every loss-feeding
        contraction in this repo is an explicit multiply + last-axis
        reduce (DESIGN.md Sec. 9), so a row's accumulation order never
        depends on the batch around it (tests/test_serving.py pins it).
        """
        picked = jax.tree.map(lambda v: v[lids], models)
        return jax.vmap(self.predict_one)(picked, Xb)

    def update(self, state, example):
        raise NotImplementedError

    # A substrate whose stacked predict and update share expensive work
    # (the RFF feature map, the SV Gram rows) can set fused_scan_round
    # and override round_stacked as ONE fused computation; the scan
    # engine (core/engine.py) then replaces its separate predict +
    # update calls with it.  The default composition is the engine's
    # legacy order, so overriding is purely an optimization — the
    # returned floats must not change (tests/test_backend_parity.py).
    fused_scan_round: bool = False

    def round_stacked(self, state, example):
        """One stacked round -> (new_state, losses, yhat_pre_update)."""
        yhat = self.predict(self.models_of(state), example[0])
        new_state, losses = self.update(state, example)
        return new_state, losses, yhat

    def average_stacked(self, models):
        """(f_sync, eps): the Prop. 2 average prepared for
        redistribution — compressed to the sync budget for SV, exact
        (eps = 0) for primal substrates."""
        raise NotImplementedError

    # -- participation face (DESIGN.md Sec. 15) -----------------------------
    #
    # The population layer synchronizes a sampled cohort: the Prop. 2
    # average, the Sec. 3 payload, and the ring pricing all restrict to
    # the participating learners, and a learner rejoining after churn
    # re-adopts the reference at a Sec. 3 download price.  Contract:
    # with ``mask`` all-True every masked op returns the SAME floats /
    # integers as its unmasked twin (tests/test_population.py pins it
    # bitwise) — that degenerate case is what makes the population
    # engine path provable against ``engine.run``.

    def average_stacked_masked(self, models, mask):
        """(f_sync, eps) over the participating cohort only: the
        Prop. 2 average of the masked learners.  ``mask`` (m,) bool;
        an empty cohort must not divide by zero (the engine never
        syncs one, but ``lax.cond`` lowers to a select under some
        transforms, so the untaken branch still executes)."""
        raise NotImplementedError

    def sync_payload_masked(self, models, mask, ledger):
        """Sec. 3 bytes of one cohort synchronization
        -> (int32 bytes, ledger): non-participants neither upload nor
        download and are excluded from the shipped union."""
        raise NotImplementedError

    def rejoin_payload_bytes(self, models, ref, rejoin):
        """int32 Sec. 3 download bytes of re-``adopt``-ing the
        reference on the ``rejoin`` (m,) bool learners — the recovery
        half of churn (DESIGN.md Sec. 15)."""
        raise NotImplementedError

    def allreduce_sync_bytes_masked(self, count):
        """Traced-int32 ring bytes of one cohort synchronization under
        ``topology="allreduce"`` — ``allreduce_sync_bytes`` with the
        static m replaced by the traced cohort size ``count``."""
        raise NotImplementedError

    def adopt(self, models, fsync):
        raise NotImplementedError

    def dist_to_ref(self, models, ref) -> Array:
        raise NotImplementedError

    def dist_to_ref_each(self, models, ref_stacked) -> Array:
        """Per-learner distance to a PER-LEARNER reference slice.

        The mesh-sharded engine (DESIGN.md Sec. 9) keeps the Sec. 3
        stacked reference sliced next to each learner, so the dynamic
        local condition is a purely device-local reduction:
        ``ref_stacked`` carries the same leading learner axis as
        ``models`` (every slice holds the same synchronized model).
        """
        return jax.vmap(self.dist_one)(models, ref_stacked)

    def divergence(self, models) -> Array:
        raise NotImplementedError

    def ledger_init(self, m: int):
        return ()

    def sync_payload(self, models, ledger):
        """Sec. 3 bytes of one synchronization -> (int32 bytes, ledger)."""
        raise NotImplementedError

    def allreduce_sync_bytes(self, m: int) -> int:
        """TOTAL ring bytes of one mesh synchronization
        (``topology="allreduce"``, DESIGN.md Sec. 9): the cost of the
        collective that replaces the coordinator's up/downloads when
        the learner axis is sharded.  A host-side constant — unlike
        ``sync_payload`` it never depends on the rounds seen."""
        raise NotImplementedError

    def validate(self, T: int, m: int, d: int) -> None:
        if d != self.input_dim:
            raise ValueError(
                f"stream dim {d} != substrate dim {self.input_dim}")

    # -- node face ----------------------------------------------------------

    def init_node(self, idx: int):
        raise NotImplementedError

    def node_model(self, state):
        return state

    def update_one(self, state, example):
        raise NotImplementedError

    def predict_one(self, model, x: Array) -> Array:
        raise NotImplementedError

    def dist_one(self, model, ref) -> Array:
        raise NotImplementedError

    # A substrate whose predict and update share expensive work (e.g.
    # the RFF feature map) can set fused_node_round and implement
    # round_one(state, example) -> (new_state, loss, yhat_pre_update)
    # as ONE jitted computation; otherwise the runtime composes the
    # separately-jitted predict_one / update_one, which keeps node
    # numerics identical to the legacy per-op dispatch.
    fused_node_round: bool = False

    def round_one(self, state, example):
        raise NotImplementedError

    def init_reference(self):
        raise NotImplementedError

    def upload_payload(self, bm: accounting.ByteModel, state,
                       known: Set[int]):
        """(model, ids, nbytes) for a learner->coordinator upload."""
        raise NotImplementedError

    def download_payload_bytes(self, bm: accounting.ByteModel,
                               union: Set[int], receiver_ids: Set[int]) -> int:
        raise NotImplementedError

    def aggregate(self, reference, models: Sequence, weights: Sequence[float]):
        """Staleness-weighted aggregation -> (fsync, eps | None, union)."""
        raise NotImplementedError

    def adopt_node(self, state, fsync):
        raise NotImplementedError

    # -- async-harness snapshot hooks ---------------------------------------

    def snapshot_buffers(self, T: int, m: int) -> Dict[str, np.ndarray]:
        raise NotImplementedError

    def write_snapshot(self, bufs, t: int, i: int, model) -> None:
        raise NotImplementedError

    def divergence_series(self, bufs) -> np.ndarray:
        raise NotImplementedError


class NodeOps(NamedTuple):
    """Jitted per-node compute, shared across nodes (one compile).

    ``round`` performs one full learner round: it returns
    (new_state, loss, yhat) with yhat the pre-update prediction the
    harness measures service errors with.
    """

    update: Any
    predict: Any
    dist: Any
    round: Any


@functools.lru_cache(maxsize=None)
def node_ops(sub: Substrate) -> NodeOps:
    update = jax.jit(sub.update_one)
    predict = jax.jit(sub.predict_one)
    if sub.fused_node_round:
        rnd = jax.jit(sub.round_one)
    else:
        def rnd(state, example):
            yhat = predict(sub.node_model(state), example[0])
            new_state, loss = update(state, example)
            return new_state, loss, yhat
    return NodeOps(
        update=update,
        predict=predict,
        dist=jax.jit(sub.dist_one),
        round=rnd,
    )


# ---------------------------------------------------------------------------
# SV substrate (dual RKHS expansion)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SVSubstrate(Substrate):
    """Budgeted support-vector expansion + DeviceLedger delta accounting."""

    lcfg: LearnerConfig = dataclasses.field(default_factory=LearnerConfig)
    sync_budget: int = 0          # 0 -> lcfg.budget
    compress_method: str = compression.DEFAULT_METHOD
    backend: str = "reference"

    has_eps = True
    free_divergence = False
    guarded_dist_check = True

    def __post_init__(self):
        if not self.lcfg.is_kernel:
            raise ValueError("SVSubstrate needs a kernel LearnerConfig")
        if self.backend not in _BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.sync_budget == 0:
            object.__setattr__(self, "sync_budget", int(self.lcfg.budget))

    @property
    def loss(self) -> str:
        return self.lcfg.loss

    @property
    def input_dim(self) -> int:
        return self.lcfg.dim

    def validate(self, T: int, m: int, d: int) -> None:
        super().validate(T, m, d)
        learners.check_id_capacity(T)

    # -- scan face ----------------------------------------------------------

    def init(self, m: int):
        states = [learners.init_state(self.lcfg, i) for i in range(m)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *states)

    def models_of(self, state):
        return state.model

    def with_models(self, state, models):
        return state._replace(model=models)

    def _engaged(self) -> bool:
        """Pallas backend AND the SV budget reaches the launch
        threshold.  Below it the reference expressions run verbatim —
        bit-identical to backend="reference" (module docstring)."""
        return self.backend == "pallas" and _kops().engages(self.lcfg.budget)

    def predict(self, models: SVModel, x: Array) -> Array:
        if self._engaged():
            a = jnp.where(rkhs.active_mask(models), models.alpha, 0.0)
            return _kops().sv_predict_spec(self.lcfg.kernel, x, models.sv, a)
        return jax.vmap(lambda f, xi: self.predict_one(f, xi))(models, x)

    def predict_batch(self, models: SVModel, lids: Array, Xb: Array) -> Array:
        # the serving bucket path: one fused sv_predict launch answers
        # the whole bucket.  Row floats still match predict_one —
        # ops.sv_predict's blocks and engagement never depend on the
        # batch size (kernels/ops.py), and each row is its own grid
        # cell — so the serving bit-exactness contract holds on the
        # fused path too (tests/test_backend_parity.py pins it).
        if self._engaged():
            picked = jax.tree.map(lambda v: v[lids], models)
            a = jnp.where(rkhs.active_mask(picked), picked.alpha, 0.0)
            return _kops().sv_predict_spec(self.lcfg.kernel, Xb,
                                           picked.sv, a)
        return super().predict_batch(models, lids, Xb)

    def update(self, state, example):
        return jax.vmap(functools.partial(learners.update, self.lcfg))(
            state, example)

    # one shared predict feeds both the service-error record and the
    # learner update — half the per-round Gram work of the composed
    # path, same floats (kernel_update_from_yhat is kernel_update with
    # the prediction supplied)
    fused_scan_round = True

    def round_stacked(self, state, example):
        x, y = example
        yhat = self.predict(state.model, x)
        upd = functools.partial(learners.kernel_update_from_yhat, self.lcfg)
        new_state, losses = jax.vmap(
            lambda st, xi, yi, yh: upd(st, (xi, yi), yh))(state, x, y, yhat)
        return new_state, losses, yhat

    def average_stacked(self, models: SVModel):
        fbar = rkhs.average_stacked(models)           # budget m*tau
        return compression.compress(self.lcfg.kernel, fbar,
                                    self.sync_budget, self.compress_method)

    def average_stacked_masked(self, models: SVModel, mask):
        # the Prop. 2 average over the cohort: non-participants' slots
        # enter with alpha = 0 / id = -1 (inactive), and the divisor is
        # the cohort size.  With mask all-True this is exactly
        # rkhs.average_stacked — same slot multiset, same order, same
        # float32 division by m — so the compressed result is bitwise
        # identical to average_stacked's (tests/test_population.py).
        m, tau, d = models.sv.shape
        cnt = jnp.sum(mask.astype(jnp.int32))
        # XLA lowers division by the COMPILE-TIME constant m differently
        # from division by a traced scalar (strength reduction), so the
        # full-cohort branch must literally be ``alpha / m`` for the
        # bitwise contract to hold; the where picks it when cnt == m.
        cnt_f = jnp.maximum(cnt, 1).astype(jnp.float32)
        scaled = jnp.where(cnt == m, models.alpha / m, models.alpha / cnt_f)
        alpha = jnp.where(mask[:, None] & (models.sv_id >= 0), scaled, 0.0)
        sv_id = jnp.where(mask[:, None], models.sv_id, -1)
        fbar = SVModel(sv=models.sv.reshape(m * tau, d),
                       alpha=alpha.reshape(m * tau),
                       sv_id=sv_id.reshape(m * tau))
        return compression.compress(self.lcfg.kernel, fbar,
                                    self.sync_budget, self.compress_method)

    def sync_payload_masked(self, models: SVModel, mask, ledger):
        bm = accounting.ByteModel(dim=self.lcfg.dim)
        return accounting.device_sync_bytes_kernel(
            bm, models.sv_id, ledger, mask=mask)

    def rejoin_payload_bytes(self, models: SVModel, ref: SVModel, rejoin):
        bm = accounting.ByteModel(dim=self.lcfg.dim)
        return accounting.device_rejoin_bytes_kernel(
            bm, ref.sv_id, models.sv_id, rejoin)

    def allreduce_sync_bytes_masked(self, count):
        bm = accounting.ByteModel(dim=self.lcfg.dim)
        slot = bm.B_x + bm.dtype_bytes
        # allgather_bytes with traced cohort size: c (c-1) shard_bytes
        # reprolint: allow[ACC01] int32 mirrors allgather_bytes; engine guards the worst case at full m
        return (count * jnp.maximum(count - 1, 0)
                # reprolint: allow[ACC01] int32 mirrors allgather_bytes; engine guards the worst case at full m
                * jnp.asarray(self.lcfg.budget * slot, jnp.int32))

    def adopt(self, models: SVModel, fsync: SVModel) -> SVModel:
        one = rkhs.pad_to_budget(fsync, self.lcfg.budget)
        return SVModel(
            sv=jnp.broadcast_to(one.sv[None], models.sv.shape),
            alpha=jnp.broadcast_to(one.alpha[None], models.alpha.shape),
            sv_id=jnp.broadcast_to(one.sv_id[None], models.sv_id.shape),
        )

    def dist_to_ref(self, models: SVModel, ref: SVModel) -> Array:
        # engage-gated like every pallas branch: the dynamic protocol's
        # sync decisions feed the byte ledger, so the non-engaged
        # pallas path must be the reference expression verbatim
        if self.backend == "pallas" and _kops().engages(
                self.lcfg.budget, self.sync_budget):
            return jax.vmap(lambda f: self.dist_one(f, ref))(models)
        return rkhs.stacked_dist_to(self.lcfg.kernel, models, ref)

    def divergence(self, models: SVModel) -> Array:
        if self._engaged():
            fbar = rkhs.average_stacked(models)
            return jnp.mean(self.dist_to_ref(models, fbar))
        return rkhs.divergence_stacked(self.lcfg.kernel, models)

    def ledger_init(self, m: int):
        return accounting.device_ledger_init(m * self.lcfg.budget)

    def sync_payload(self, models: SVModel, ledger):
        bm = accounting.ByteModel(dim=self.lcfg.dim)
        return accounting.device_sync_bytes_kernel(bm, models.sv_id, ledger)

    def allreduce_sync_bytes(self, m: int) -> int:
        # SV expansions have no slot alignment across learners, so the
        # mesh sync is a ring all-gather of the m budget-tau stacks;
        # each slot ships its vector + id (B_x) and its coefficient.
        bm = accounting.ByteModel(dim=self.lcfg.dim)
        slot = bm.B_x + bm.dtype_bytes
        return accounting.allgather_bytes(self.lcfg.budget * slot, m)

    # -- node face ----------------------------------------------------------

    def init_node(self, idx: int):
        return learners.init_state(self.lcfg, idx)

    def node_model(self, state):
        return state.model

    def update_one(self, state, example):
        return learners.update(self.lcfg, state, example)

    def predict_one(self, model: SVModel, x: Array) -> Array:
        spec = self.lcfg.kernel
        if self._engaged():
            a = jnp.where(rkhs.active_mask(model), model.alpha, 0.0)
            return _kops().sv_predict_spec(
                spec, x[None], model.sv[None], a[None])[0]
        return rkhs.predict(spec, model, x[None])[0]

    def dist_one(self, model: SVModel, ref: SVModel) -> Array:
        spec = self.lcfg.kernel
        if self.backend == "pallas" and _kops().engages(
                model.sv.shape[0], ref.sv.shape[0]):
            af = jnp.where(rkhs.active_mask(model), model.alpha, 0.0)
            ag = jnp.where(rkhs.active_mask(ref), ref.alpha, 0.0)
            return _kops().rkhs_dist_sq_spec(spec, model.sv, ref.sv, af, ag)
        return rkhs.dist_sq(spec, model, ref)

    def init_reference(self):
        ref, _ = compression.compress(
            self.lcfg.kernel, rkhs.empty_model(self.lcfg.budget, self.lcfg.dim),
            self.sync_budget, self.compress_method)
        return ref

    def upload_payload(self, bm, state, known):
        ids = accounting.idset(np.asarray(state.model.sv_id))
        return (state.model, ids,
                accounting.kernel_payload_bytes(bm, ids, known))

    def download_payload_bytes(self, bm, union, receiver_ids):
        return accounting.kernel_payload_bytes(bm, union, receiver_ids)

    def aggregate(self, reference, models, weights):
        """Staleness-weighted RKHS aggregation (FedAsync-style).

        candidate_k = (1 - w_k) r + w_k f_k; the new reference is the
        mean of the candidates compressed to the sync budget.  In an
        RKHS the convex combination is the concatenation of the
        coefficient-scaled expansions; exact-zero coefficients are
        pruned so the degenerate alpha = 1 case produces the identical
        slot multiset as the serial ``rkhs.average_stacked`` — which is
        why the zero-latency async run reproduces the serial ledger
        byte-for-byte (tests/test_runtime.py).
        """
        n = len(models)
        assert n == len(weights) and n > 0
        parts: List[Tuple[SVModel, float]] = []
        for f, w in zip(models, weights):
            parts.append((reference, (1.0 - w)))
            parts.append((f, w))
        mix = _concat_sv(parts)
        # mean over candidates: divide (not multiply by reciprocal) so
        # the n == m full-weight case reproduces average_stacked's floats.
        mix = mix._replace(alpha=mix.alpha / n)
        union = set(int(i) for i in np.asarray(mix.sv_id) if i >= 0)
        fsync, eps = compression.compress(
            self.lcfg.kernel, mix, self.sync_budget, self.compress_method)
        return fsync, float(eps), union

    def adopt_node(self, state, fsync: SVModel):
        return state._replace(model=rkhs.pad_to_budget(fsync, self.lcfg.budget))

    # -- snapshots ----------------------------------------------------------

    def snapshot_buffers(self, T, m):
        tau, d = self.lcfg.budget, self.lcfg.dim
        return {"sv": np.zeros((T, m, tau, d), np.float32),
                "alpha": np.zeros((T, m, tau), np.float32),
                "sv_id": -np.ones((T, m, tau), np.int32)}

    def write_snapshot(self, bufs, t, i, model: SVModel):
        bufs["sv"][t, i] = np.asarray(model.sv)
        bufs["alpha"][t, i] = np.asarray(model.alpha)
        bufs["sv_id"][t, i] = np.asarray(model.sv_id)

    def divergence_series(self, bufs):
        div_t = jax.jit(lambda f: self.divergence(f))
        out = [float(div_t(SVModel(sv=jnp.asarray(bufs["sv"][t]),
                                   alpha=jnp.asarray(bufs["alpha"][t]),
                                   sv_id=jnp.asarray(bufs["sv_id"][t]))))
               for t in range(bufs["sv"].shape[0])]
        return np.asarray(out)


def _concat_sv(parts: Sequence[Tuple[SVModel, float]]) -> SVModel:
    """Concatenate coefficient-scaled expansions; prune exact zeros.

    Pruning (alpha == 0 -> slot inactive) keeps the degenerate
    full-weight case bit-identical to ``rkhs.average_stacked``: the
    reference's slots enter with weight exactly 0 and vanish, leaving
    the same active-slot multiset in the same order.
    """
    svs, alphas, ids = [], [], []
    for model, w in parts:
        svs.append(np.asarray(model.sv))
        alphas.append(np.asarray(model.alpha) * np.float32(w))
        ids.append(np.asarray(model.sv_id))
    sv = np.concatenate(svs, axis=0)
    alpha = np.concatenate(alphas, axis=0).astype(np.float32)
    sv_id = np.concatenate(ids, axis=0)
    dead = (alpha == 0.0) | (sv_id < 0)
    sv_id = np.where(dead, -1, sv_id)
    sv = np.where(dead[:, None], 0.0, sv).astype(np.float32)
    alpha = np.where(dead, 0.0, alpha)
    return SVModel(sv=jnp.asarray(sv), alpha=jnp.asarray(alpha),
                   sv_id=jnp.asarray(sv_id, jnp.int32))


# ---------------------------------------------------------------------------
# Primal substrates share the (w, b) aggregation and snapshot logic
# ---------------------------------------------------------------------------


class _PrimalSubstrate(Substrate):
    """Shared logic for fixed-size (w, b) models (linear and RFF).

    The representation is a weight vector: Prop. 2 averaging is the
    plain mean, distance is Euclidean, and a synchronization costs a
    fixed ``2 m (num_params) B`` bytes — independent of rounds seen, so
    Cor. 8's strictly-adaptive communication bound applies verbatim
    (the RFF case is exactly the paper's Sec. 4 proposal).
    """

    has_eps = False
    free_divergence = True
    guarded_dist_check = False

    # num_params of one model (w and b), for the Sec. 3 linear accounting
    @property
    def num_params(self) -> int:
        raise NotImplementedError

    def _state_cls(self):
        raise NotImplementedError

    def average_stacked(self, models):
        cls = self._state_cls()
        mean = cls(w=jnp.mean(models.w, axis=0), b=jnp.mean(models.b))
        return mean, jnp.zeros((), jnp.float32)

    def adopt(self, models, fsync):
        cls = self._state_cls()
        return cls(w=jnp.broadcast_to(fsync.w[None], models.w.shape),
                   b=jnp.broadcast_to(fsync.b[None], models.b.shape))

    def dist_to_ref(self, models, ref) -> Array:
        return jax.vmap(
            lambda s: jnp.sum((s.w - ref.w) ** 2) + (s.b - ref.b) ** 2
        )(models)

    def divergence(self, models) -> Array:
        wbar = jnp.mean(models.w, axis=0)
        bbar = jnp.mean(models.b)
        return jnp.mean(jnp.sum((models.w - wbar[None, :]) ** 2, -1)
                        + (models.b - bbar) ** 2)

    def sync_payload(self, models, ledger):
        m = models.w.shape[0]
        nbytes = accounting.sync_bytes_linear(self.num_params, m)
        return jnp.asarray(nbytes, jnp.int32), ledger

    def allreduce_sync_bytes(self, m: int) -> int:
        # fixed-size primal vectors reduce-scatter + all-gather
        return accounting.allreduce_bytes(self.num_params, m)

    # -- participation face (DESIGN.md Sec. 15) -----------------------------

    def average_stacked_masked(self, models, mask):
        # masked Prop. 2 mean: sum the cohort's weights in stacked
        # order, divide by the cohort size.  With mask all-True this is
        # sum/m in the same reduction order as jnp.mean — bitwise
        # identical to average_stacked (tests/test_population.py).
        cls = self._state_cls()
        m = models.w.shape[0]
        cnt = jnp.sum(mask.astype(jnp.int32))
        # division by the compile-time constant m is strength-reduced
        # by XLA; division by a traced scalar is not — the full-cohort
        # branch must literally divide by m for bitwise parity with
        # jnp.mean in average_stacked (see SVSubstrate's masked twin)
        cnt_f = jnp.maximum(cnt, 1).astype(jnp.float32)
        sum_w = jnp.sum(jnp.where(mask[:, None], models.w, 0.0), axis=0)
        sum_b = jnp.sum(jnp.where(mask, models.b, 0.0))
        w = jnp.where(cnt == m, jnp.mean(models.w, axis=0), sum_w / cnt_f)
        b = jnp.where(cnt == m, jnp.mean(models.b), sum_b / cnt_f)
        return cls(w=w, b=b), jnp.zeros((), jnp.float32)

    def sync_payload_masked(self, models, mask, ledger):
        # sync_bytes_linear with the traced cohort size: 2 c |theta| B
        count = jnp.sum(mask.astype(jnp.int32))
        # reprolint: allow[ACC01] int32 mirrors sync_bytes_linear; bounded by the full-m value
        return (count * jnp.asarray(2 * self.num_params * 4, jnp.int32),
                ledger)

    def rejoin_payload_bytes(self, models, ref, rejoin):
        # dense vectors have no identity structure: a rejoin is one
        # full download per recovering learner (linear_payload_bytes)
        # reprolint: allow[ACC01] int32 rejoin count; bounded by m
        count = jnp.sum(rejoin.astype(jnp.int32))
        # reprolint: allow[ACC01] int32 mirrors linear_payload_bytes; bounded by m |theta| B
        return count * jnp.asarray(
            # reprolint: allow[ACC01] int32 mirrors linear_payload_bytes; bounded by m |theta| B
            accounting.linear_payload_bytes(self.num_params), jnp.int32)

    def allreduce_sync_bytes_masked(self, count):
        # allreduce_bytes with traced cohort size: 2 (c-1) |theta| B
        # reprolint: allow[ACC01] int32 mirrors allreduce_bytes; bounded by the full-m value
        return (2 * jnp.maximum(count - 1, 0)
                # reprolint: allow[ACC01] int32 mirrors allreduce_bytes; bounded by the full-m value
                * jnp.asarray(self.num_params * 4, jnp.int32))

    def dist_one(self, model, ref) -> Array:
        return jnp.sum((model.w - ref.w) ** 2) + (model.b - ref.b) ** 2

    def upload_payload(self, bm, state, known):
        return (state, set(),
                accounting.linear_payload_bytes(self.num_params,
                                                bm.dtype_bytes))

    def download_payload_bytes(self, bm, union, receiver_ids):
        return accounting.linear_payload_bytes(self.num_params,
                                               bm.dtype_bytes)

    def aggregate(self, reference, models, weights):
        """Mean over candidates (1 - w_k) r + w_k f_k in weight space."""
        n = len(models)
        assert n == len(weights) and n > 0
        cls = self._state_cls()
        w_acc = np.zeros_like(np.asarray(reference.w, np.float64))
        b_acc = 0.0
        rw = np.asarray(reference.w, np.float64)
        rb = float(reference.b)
        for st, wt in zip(models, weights):
            w_acc += (1.0 - wt) * rw + wt * np.asarray(st.w, np.float64)
            b_acc += (1.0 - wt) * rb + wt * float(st.b)
        return cls(
            w=jnp.asarray((w_acc / n).astype(np.float32)),
            b=jnp.asarray(np.float32(b_acc / n)),
        ), None, set()

    def adopt_node(self, state, fsync):
        cls = self._state_cls()
        return cls(w=fsync.w, b=fsync.b)

    def snapshot_buffers(self, T, m):
        D = int(np.prod(self.init_node(0).w.shape))
        return {"w": np.zeros((T, m, D), np.float32),
                "b": np.zeros((T, m), np.float32)}

    def write_snapshot(self, bufs, t, i, st):
        bufs["w"][t, i] = np.asarray(st.w)
        bufs["b"][t, i] = float(st.b)

    def divergence_series(self, bufs):
        snap_w, snap_b = bufs["w"], bufs["b"]
        wbar = snap_w.mean(axis=1, keepdims=True)      # (T, 1, D)
        bbar = snap_b.mean(axis=1, keepdims=True)      # (T, 1)
        return (((snap_w - wbar) ** 2).sum(-1)
                + (snap_b - bbar) ** 2).mean(axis=1)


# ---------------------------------------------------------------------------
# Linear substrate (the paper's baseline hypothesis class)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LinearSubstrate(_PrimalSubstrate):
    """Euclidean weight vectors with fixed-size sync payloads."""

    lcfg: LearnerConfig = dataclasses.field(
        default_factory=lambda: LearnerConfig(algo="linear_sgd"))
    backend: str = "reference"    # accepted for uniformity; no kernel algebra

    def __post_init__(self):
        if self.lcfg.is_kernel:
            raise ValueError("LinearSubstrate needs a linear LearnerConfig")
        if self.backend not in _BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}")

    @property
    def loss(self) -> str:
        return self.lcfg.loss

    @property
    def input_dim(self) -> int:
        return self.lcfg.dim

    @property
    def num_params(self) -> int:
        return self.lcfg.dim + 1

    def _state_cls(self):
        return LinearLearnerState

    def init(self, m: int):
        states = [learners.init_state(self.lcfg, i) for i in range(m)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *states)

    def predict(self, models, x: Array) -> Array:
        # multiply + reduce, not a dot — layout-independent floats
        # (rkhs.predict has the full rationale; DESIGN.md Sec. 9)
        return jnp.sum(models.w * x, axis=-1) + models.b

    def update(self, state, example):
        return jax.vmap(functools.partial(learners.update, self.lcfg))(
            state, example)

    # linear_sgd's round is exactly the fused primal step with the
    # identity feature map; linear_pa (and the non-engaged / reference
    # cases) keep the composed expressions
    fused_scan_round = True

    def round_stacked(self, state, example):
        x, y = example
        if (self.backend == "pallas" and self.lcfg.algo == "linear_sgd"
                and _kops().engages(x.shape[0], self.lcfg.dim)):
            w_new, b_new, ell, yhat = _kops().fused_primal_step(
                x, y, state.w, state.b, loss=self.loss,
                eta=self.lcfg.eta, lam=self.lcfg.lam)
            return LinearLearnerState(w=w_new, b=b_new), ell, yhat
        yhat = self.predict(state, x)
        new_state, ell = self.update(state, example)
        return new_state, ell, yhat

    def init_node(self, idx: int):
        return learners.init_state(self.lcfg, idx)

    def update_one(self, state, example):
        return learners.update(self.lcfg, state, example)

    def predict_one(self, model, x: Array) -> Array:
        return jnp.sum(model.w * x) + model.b

    def init_reference(self):
        return learners.init_linear_state(self.lcfg)


# ---------------------------------------------------------------------------
# RFF substrate (paper Sec. 4 future work, made first-class)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _rff_consts(spec: RFFSpec) -> Tuple[np.ndarray, np.ndarray]:
    """Host copies of (W, b) so jitted substrate methods embed them as
    constants (hoisted out of the scan body) instead of re-deriving the
    random projection every step.  ``ensure_compile_time_eval`` keeps
    the draw eager even when the first call happens inside a trace."""
    with jax.ensure_compile_time_eval():
        W, b = rff.rff_params(spec)
    return np.asarray(W), np.asarray(b)


@dataclasses.dataclass(frozen=True)
class RFFSubstrate(_PrimalSubstrate):
    """Primal SGD over D random Fourier features.

    The model is a fixed-size weight vector over phi(x) = sqrt(2/D)
    cos(W x + b), so every synchronization ships O(m D) bytes no matter
    how many examples have been seen — the strict adaptivity of Cor. 8
    at near-kernel accuracy (benchmarks/bench_rff.py measures both).
    """

    spec: RFFSpec = dataclasses.field(
        default_factory=lambda: RFFSpec(dim=8, num_features=256))
    eta: float = 0.5
    lam: float = 0.01
    loss: str = "hinge"
    backend: str = "reference"

    def __post_init__(self):
        if self.loss not in ("hinge", "squared"):
            raise ValueError(f"unknown loss {self.loss!r}")
        if self.backend not in _BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}")

    @property
    def input_dim(self) -> int:
        return self.spec.dim

    @property
    def num_params(self) -> int:
        return self.spec.num_features + 1

    def _state_cls(self):
        return RFFLearnerState

    def _phi(self, X2d: Array) -> Array:
        """phi over a batch of rows: (n, d) -> (n, D).  Engage-aware:
        below the Pallas threshold the pallas backend featurizes with
        the reference map, bit-identical to backend="reference"."""
        W, b = _rff_consts(self.spec)
        if self.backend == "pallas" and _kops().engages(
                X2d.shape[0], self.spec.num_features):
            return _kops().rff_features(X2d, jnp.asarray(W), jnp.asarray(b))
        return rff.featurize(self.spec, jnp.asarray(W), jnp.asarray(b), X2d)

    def init(self, m: int):
        states = [rff.init_state(self.spec) for _ in range(m)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *states)

    def predict(self, models, x: Array) -> Array:
        Z = self._phi(x)                               # (m, D)
        return jnp.sum(models.w * Z, axis=-1) + models.b

    def predict_batch(self, models, lids: Array, Xb: Array) -> Array:
        # featurize the whole bucket in one _phi call (the feature map
        # dominates an RFF predict), then gather each request's home
        # weights; per-row floats match predict_one because featurize
        # and the dot are row-independent multiply+reduce ops.
        Z = self._phi(Xb)                              # (n, D)
        return jnp.sum(models.w[lids] * Z, axis=-1) + models.b[lids]

    def _round_with_features(self, st, z, y):
        yhat = jnp.sum(st.w * z) + st.b   # layout-independent floats
        ell, g = learners.loss_and_grad(self.loss, yhat, y)
        w = (1.0 - self.eta * self.lam) * st.w - self.eta * g * z
        b = st.b - self.eta * g
        return RFFLearnerState(w=w, b=b), ell, yhat

    def _update_with_features(self, st, z, y):
        new_state, ell, _ = self._round_with_features(st, z, y)
        return new_state, ell

    def update(self, state, example):
        x, y = example
        Z = self._phi(x)                               # (m, D)
        return jax.vmap(self._update_with_features)(state, Z, y)

    # the whole stacked round — featurize + predict + loss/grad +
    # NORMA update — as one computation; under an engaged pallas
    # backend it is ONE kernel launch (kernels.ops.fused_primal_step)
    fused_scan_round = True

    def round_stacked(self, state, example):
        x, y = example
        if self.backend == "pallas" and _kops().engages(
                x.shape[0], self.spec.num_features):
            W, b = _rff_consts(self.spec)
            w_new, b_new, ell, yhat = _kops().fused_primal_step(
                x, y, state.w, state.b,
                W=jnp.asarray(W), bias=jnp.asarray(b),
                scale=float(np.sqrt(2.0 / self.spec.num_features)),
                loss=self.loss, eta=self.eta, lam=self.lam)
            return RFFLearnerState(w=w_new, b=b_new), ell, yhat
        # unfused: one shared featurize (instead of the composed
        # path's two), the exact predict expression, the exact update
        Z = self._phi(x)                               # (m, D)
        yhat = jnp.sum(state.w * Z, axis=-1) + state.b
        new_state, ell = jax.vmap(self._update_with_features)(state, Z, y)
        return new_state, ell, yhat

    def init_node(self, idx: int):
        return rff.init_state(self.spec)

    def update_one(self, state, example):
        x, y = example
        z = self._phi(x[None])[0]
        return self._update_with_features(state, z, y)

    def predict_one(self, model, x: Array) -> Array:
        z = self._phi(x[None])[0]
        return jnp.sum(model.w * z) + model.b

    # the feature map dominates a node round: featurize once, share it
    # between the service-error prediction and the update
    fused_node_round = True

    def round_one(self, state, example):
        x, y = example
        z = self._phi(x[None])[0]
        return self._round_with_features(state, z, y)

    def init_reference(self):
        return rff.init_state(self.spec)


# ---------------------------------------------------------------------------
# Construction
# ---------------------------------------------------------------------------


def substrate_of(
    learner,
    *,
    sync_budget: Optional[int] = None,
    compress_method: Optional[str] = None,
    backend: Optional[str] = None,
) -> Substrate:
    """Resolve a learner description to a Substrate.

    Accepts a :class:`Substrate` (returned as-is, except that keyword
    arguments explicitly passed — the defaults are ``None`` sentinels,
    so every explicit value counts, including "reference"/"truncate" —
    are applied via ``dataclasses.replace``; ``engine.run(sub, ...,
    backend="pallas")`` does what it says), a :class:`LearnerConfig`
    (kernel algos -> :class:`SVSubstrate`, linear algos ->
    :class:`LinearSubstrate`; representation-inapplicable keywords are
    resolved away exactly as the legacy drivers did), or an
    :class:`RFFSpec` (-> :class:`RFFSubstrate` with the default SGD
    hyperparameters).  An override the resolved substrate type has no
    field for raises ValueError rather than being dropped.

    ``None`` semantics of the keyword sentinels: ``None`` means "keep
    the substrate's own configuration" — for a passed :class:`Substrate`
    that is whatever it was built with; for a :class:`LearnerConfig` /
    :class:`RFFSpec` it is the dataclass default, i.e.
    ``compress_method=None`` resolves to
    ``compression.DEFAULT_METHOD`` ("truncate"), ``backend=None`` to
    "reference", and ``sync_budget=None`` to the learner budget tau.
    """
    overrides = {}
    if sync_budget is not None:
        overrides["sync_budget"] = int(sync_budget)
    if compress_method is not None:
        overrides["compress_method"] = compress_method
    if backend is not None:
        overrides["backend"] = backend

    if isinstance(learner, Substrate):
        if not overrides:
            return learner
        sub = learner
    elif isinstance(learner, LearnerConfig):
        if learner.is_kernel:
            return SVSubstrate(
                lcfg=learner,
                sync_budget=int(sync_budget or learner.budget),
                compress_method=compress_method or compression.DEFAULT_METHOD,
                backend=backend or "reference")
        # linear models have no sync budget / compression: the legacy
        # drivers accepted and ignored these, so the resolver does too
        return LinearSubstrate(lcfg=learner, backend=backend or "reference")
    elif isinstance(learner, RFFSpec):
        sub = RFFSubstrate(spec=learner)
        if not overrides:
            return sub
    else:
        raise TypeError(
            f"cannot build a substrate from {type(learner).__name__}; pass a "
            "Substrate, LearnerConfig, or RFFSpec")

    fields = {f.name for f in dataclasses.fields(sub)}
    unknown = sorted(set(overrides) - fields)
    if unknown:
        raise ValueError(
            f"{unknown} cannot be applied to {type(sub).__name__}; "
            "configure the substrate directly")
    return dataclasses.replace(sub, **overrides)
