"""Device-resident scan simulation engine (DESIGN.md Sec. 7).

``simulation.run_kernel_simulation`` drives the m-learner system with a
Python loop: every round costs several jitted dispatches plus a host
round-trip (``float()`` on losses / divergence) and a numpy set-algebra
pass per sync.  This module compiles the ENTIRE T-round experiment into
one ``jax.lax.scan``: the carry holds (stacked learner states,
reference model, device byte ledger), every per-round observable
(loss, errors, bytes, divergence, sync flag, compression eps) comes
back as a T-length output array, and the host touches data exactly once
at the end.  The Sec. 3 byte accounting runs inside the scan through
``accounting.DeviceLedger`` (sorted-id set algebra over fixed-budget
``sv_id`` arrays) and reproduces the host ``CommunicationLedger``
byte-for-byte (tests/test_engine.py).

``sweep`` vmaps the whole simulation across a grid of ProtocolConfigs
(delta / period / mini_batch) and optionally per-config data streams
(seeds), one compilation per protocol kind — the grid-evaluation
workload of Kamp et al.'s adaptive-bounds protocol family.

Static vs. traced configuration: the protocol ``kind`` changes the
structure of the scan body (what is computed each round), so it is a
compile-time specialization; ``delta``, ``period`` and ``mini_batch``
are traced scalars, so one compiled executable serves a whole grid.

Exactness contract against the legacy serial driver:

- ``cumulative_bytes``, ``sync_rounds``, ``num_syncs`` are
  integer-exact;
- per-round losses / errors are the same float32 values, accumulated on
  the host in float64 exactly like the legacy driver's accumulators;
- the RKHS divergence series delta(f_t) is the one observable whose
  *recording* costs a full union Gram every round, and nothing in the
  protocol consumes it — so it is opt-in (``record_divergence=True``;
  linear simulations always record it, the cost there is O(m d)).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import accounting, compression, learners, rkhs
from .learners import LearnerConfig
from .protocol import PROTOCOL_KIND_CODES, ProtocolConfig
from .rkhs import SVModel
from .simulation import SimResult

Array = jnp.ndarray


class ScanParams(NamedTuple):
    """The traced protocol parameters of one simulation (scalars), or of
    a sweep (vectors of length n_configs)."""

    delta: Array
    period: Array
    mini_batch: Array


def _params_of(pcfg: ProtocolConfig) -> ScanParams:
    return ScanParams(
        delta=jnp.asarray(pcfg.delta, jnp.float32),
        period=jnp.asarray(pcfg.period, jnp.int32),
        mini_batch=jnp.asarray(pcfg.mini_batch, jnp.int32),
    )


def _stack_params(pcfgs: Sequence[ProtocolConfig]) -> ScanParams:
    return ScanParams(
        delta=jnp.asarray([p.delta for p in pcfgs], jnp.float32),
        period=jnp.asarray([p.period for p in pcfgs], jnp.int32),
        mini_batch=jnp.asarray([p.mini_batch for p in pcfgs], jnp.int32),
    )


def _err_of(loss: str, yhat: Array, y: Array) -> Array:
    """Per-round summed service error, as the legacy driver measures it
    (prediction mistakes for hinge, squared error otherwise)."""
    if loss == "hinge":
        return jnp.sum((jnp.sign(yhat) != y).astype(jnp.float32))
    return jnp.sum((yhat - y) ** 2)


# ---------------------------------------------------------------------------
# Kernel-learner scan core
# ---------------------------------------------------------------------------


def _kernel_core(lcfg: LearnerConfig, kind: str, sync_budget: int,
                 compress_method: str, record_divergence: bool):
    spec = lcfg.kernel
    tau = lcfg.budget

    def simulate(params: ScanParams, X: Array, Y: Array):
        T, m, d = X.shape
        bm = accounting.ByteModel(dim=d)
        states = [learners.init_state(lcfg, i) for i in range(m)]
        stacked0 = jax.tree.map(lambda *xs: jnp.stack(xs), *states)

        def make_sync(models: SVModel):
            fbar = rkhs.average_stacked(models)          # budget m*tau
            return compression.compress(spec, fbar, sync_budget,
                                        compress_method)

        ref0, _ = make_sync(stacked0.model)
        ledger0 = accounting.device_ledger_init(m * tau)

        vupdate = jax.vmap(functools.partial(learners.update, lcfg))
        vpredict = jax.vmap(lambda f, x: rkhs.predict(spec, f, x[None])[0])

        def adopt(models: SVModel, fsync: SVModel) -> SVModel:
            one = rkhs.pad_to_budget(fsync, tau)
            return SVModel(
                sv=jnp.broadcast_to(one.sv[None], models.sv.shape),
                alpha=jnp.broadcast_to(one.alpha[None], models.alpha.shape),
                sv_id=jnp.broadcast_to(one.sv_id[None], models.sv_id.shape),
            )

        def step(carry, xs):
            state, reference, ledger = carry
            x, y, t = xs

            yhat = vpredict(state.model, x)
            err = _err_of(lcfg.loss, yhat, y)
            state, losses = vupdate(state, (x, y))
            loss = jnp.sum(losses)
            models = state.model

            if kind == "none":
                do_sync = jnp.zeros((), bool)
            elif kind == "continuous":
                do_sync = jnp.ones((), bool)
            elif kind == "periodic":
                do_sync = ((t + 1) % params.period) == 0
            else:  # dynamic: check local conditions every mini_batch rounds
                check_now = ((t + 1) % params.mini_batch) == 0

                def check(_):
                    dists = rkhs.stacked_dist_to(spec, models, reference)
                    return jnp.any(dists > params.delta)

                do_sync = lax.cond(check_now, check,
                                   lambda _: jnp.zeros((), bool), None)

            if kind == "none":
                new_models, new_ref, new_ledger = models, reference, ledger
                nbytes = jnp.zeros((), jnp.int32)
                eps = jnp.zeros((), jnp.float32)
            else:

                def sync_branch(args):
                    models, reference, ledger = args
                    fsync, eps = make_sync(models)
                    nbytes, new_ledger = accounting.device_sync_bytes_kernel(
                        bm, models.sv_id, ledger)
                    return adopt(models, fsync), fsync, new_ledger, nbytes, eps

                def keep_branch(args):
                    models, reference, ledger = args
                    return (models, reference, ledger,
                            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float32))

                new_models, new_ref, new_ledger, nbytes, eps = lax.cond(
                    do_sync, sync_branch, keep_branch,
                    (models, reference, ledger))

            state = state._replace(model=new_models)
            if record_divergence:
                div = rkhs.divergence_stacked(spec, state.model)
            else:
                div = jnp.zeros((), jnp.float32)
            out = (loss, err, nbytes, div, do_sync, eps)
            return (state, new_ref, new_ledger), out

        ts = jnp.arange(T, dtype=jnp.int32)
        _, outs = lax.scan(step, (stacked0, ref0, ledger0), (X, Y, ts))
        return outs

    return simulate


# ---------------------------------------------------------------------------
# Linear-learner scan core
# ---------------------------------------------------------------------------


def _linear_core(lcfg: LearnerConfig, kind: str):
    def simulate(params: ScanParams, X: Array, Y: Array):
        T, m, d = X.shape
        bytes_per_sync = accounting.sync_bytes_linear(d + 1, m)
        states = [learners.init_state(lcfg, i) for i in range(m)]
        stacked0 = jax.tree.map(lambda *xs: jnp.stack(xs), *states)

        def avg(st):
            return learners.LinearLearnerState(
                w=jnp.mean(st.w, axis=0), b=jnp.mean(st.b))

        ref0 = avg(stacked0)
        vupdate = jax.vmap(functools.partial(learners.update, lcfg))
        vpredict = jax.vmap(lambda s, x: s.w @ x + s.b)

        def step(carry, xs):
            state, reference = carry
            x, y, t = xs

            yhat = vpredict(state, x)
            err = _err_of(lcfg.loss, yhat, y)
            state, losses = vupdate(state, (x, y))
            loss = jnp.sum(losses)

            if kind == "none":
                do_sync = jnp.zeros((), bool)
            elif kind == "continuous":
                do_sync = jnp.ones((), bool)
            elif kind == "periodic":
                do_sync = ((t + 1) % params.period) == 0
            else:
                check_now = ((t + 1) % params.mini_batch) == 0
                dists = jax.vmap(
                    lambda s: jnp.sum((s.w - reference.w) ** 2)
                    + (s.b - reference.b) ** 2)(state)
                do_sync = check_now & jnp.any(dists > params.delta)

            if kind == "none":
                new_state, new_ref = state, reference
                nbytes = jnp.zeros((), jnp.int32)
            else:

                def sync_branch(args):
                    state, reference = args
                    mean = avg(state)
                    synced = learners.LinearLearnerState(
                        w=jnp.broadcast_to(mean.w[None], state.w.shape),
                        b=jnp.broadcast_to(mean.b[None], state.b.shape))
                    return synced, mean

                def keep_branch(args):
                    return args

                new_state, new_ref = lax.cond(
                    do_sync, sync_branch, keep_branch, (state, reference))
                nbytes = jnp.where(do_sync, bytes_per_sync, 0).astype(jnp.int32)

            state = new_state
            wbar = jnp.mean(state.w, axis=0)
            bbar = jnp.mean(state.b)
            div = jnp.mean(jnp.sum((state.w - wbar) ** 2, -1)
                           + (state.b - bbar) ** 2)
            out = (loss, err, nbytes, div, do_sync,
                   jnp.zeros((), jnp.float32))
            return (state, new_ref), out

        ts = jnp.arange(T, dtype=jnp.int32)
        _, outs = lax.scan(step, (stacked0, ref0), (X, Y, ts))
        return outs

    return simulate


# ---------------------------------------------------------------------------
# Compiled-function cache and public API
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _jitted(lcfg: LearnerConfig, kind: str, sync_budget: int,
            compress_method: str, record_divergence: bool,
            vmapped: bool, data_batched: bool):
    """One jitted (optionally vmapped) simulate fn per static config.

    The cache is what lets benchmarks call ``run`` in a timing loop
    without re-tracing: jax.jit caches on function identity, so the
    closure must be built once per static configuration.
    """
    if lcfg.is_kernel:
        core = _kernel_core(lcfg, kind, sync_budget, compress_method,
                            record_divergence)
    else:
        core = _linear_core(lcfg, kind)
    if vmapped:
        dax = 0 if data_batched else None
        core = jax.vmap(core, in_axes=(ScanParams(0, 0, 0), dax, dax))
    return jax.jit(core)


def run(
    lcfg: LearnerConfig,
    pcfg: ProtocolConfig,
    X: np.ndarray,          # (T, m, d)
    Y: np.ndarray,          # (T, m)
    *,
    sync_budget: Optional[int] = None,
    compress_method: str = "truncate",
    record_divergence: bool = False,
) -> SimResult:
    """Run T rounds of m learners under pcfg, fully on device.

    Drop-in replacement for ``simulation.run_kernel_simulation`` /
    ``run_linear_simulation`` (dispatches on ``lcfg.is_kernel``) with
    the exactness contract in the module docstring.
    """
    sb = int(sync_budget or lcfg.budget)
    fn = _jitted(lcfg, pcfg.kind, sb, compress_method,
                 bool(record_divergence), False, False)
    outs = fn(_params_of(pcfg), jnp.asarray(X), jnp.asarray(Y))
    loss, err, nbytes, div, flags, eps = (np.asarray(o) for o in outs)
    keep_div = record_divergence or not lcfg.is_kernel
    return SimResult.from_round_series(
        loss, err, nbytes,
        div if keep_div else np.zeros((0,)),
        flags,
        eps if lcfg.is_kernel else np.zeros((0,)))


@dataclasses.dataclass
class SweepResult:
    """Stacked per-round series of a protocol-grid sweep.

    Every array carries a leading axis of size n = len(configs);
    ``sweep_result[i]`` materializes the i-th configuration as a
    regular ``SimResult``.
    """

    configs: List[ProtocolConfig]
    losses: np.ndarray        # (n, T)
    errors: np.ndarray        # (n, T)
    round_bytes: np.ndarray   # (n, T)
    sync_flags: np.ndarray    # (n, T) bool
    divergences: Optional[np.ndarray]  # (n, T) or None (not recorded)
    eps: Optional[np.ndarray]          # (n, T) or None (linear learners)

    def __len__(self) -> int:
        return len(self.configs)

    def __getitem__(self, i: int) -> SimResult:
        return SimResult.from_round_series(
            self.losses[i], self.errors[i], self.round_bytes[i],
            self.divergences[i] if self.divergences is not None
            else np.zeros((0,)),
            self.sync_flags[i],
            self.eps[i] if self.eps is not None else np.zeros((0,)))

    @property
    def results(self) -> List[SimResult]:
        return [self[i] for i in range(len(self))]


def sweep(
    lcfg: LearnerConfig,
    pcfgs: Sequence[ProtocolConfig],
    X: np.ndarray,          # (T, m, d) shared, or (n, T, m, d) per config
    Y: np.ndarray,          # (T, m) shared, or (n, T, m)
    *,
    sync_budget: Optional[int] = None,
    compress_method: str = "truncate",
    record_divergence: bool = False,
) -> SweepResult:
    """Simulate a grid of protocol configurations in one compilation.

    The whole simulation (scan over T rounds, ledger included) is
    vmapped across the config axis; configs are grouped by ``kind`` so
    each group shares one compiled executable regardless of its delta /
    period / mini_batch values.  Pass X with a leading config axis to
    sweep seeds (per-config data streams) at the same time.
    """
    pcfgs = list(pcfgs)
    n = len(pcfgs)
    if n == 0:
        raise ValueError("sweep needs at least one ProtocolConfig")
    X = np.asarray(X)
    Y = np.asarray(Y)
    data_batched = X.ndim == 4
    if data_batched and X.shape[0] != n:
        raise ValueError(
            f"per-config data axis {X.shape[0]} != n_configs {n}")
    T = X.shape[1] if data_batched else X.shape[0]
    sb = int(sync_budget or lcfg.budget)
    is_kernel = lcfg.is_kernel

    losses = np.zeros((n, T), np.float32)
    errors = np.zeros((n, T), np.float32)
    round_bytes = np.zeros((n, T), np.int64)
    flags = np.zeros((n, T), bool)
    divs = np.zeros((n, T), np.float32)
    eps = np.zeros((n, T), np.float32)

    by_kind: dict = {}
    for i, p in enumerate(pcfgs):
        by_kind.setdefault(p.kind, []).append(i)

    for kind, idx in sorted(by_kind.items(),
                            key=lambda kv: PROTOCOL_KIND_CODES[kv[0]]):
        fn = _jitted(lcfg, kind, sb, compress_method,
                     bool(record_divergence), True, data_batched)
        params = _stack_params([pcfgs[i] for i in idx])
        Xg = jnp.asarray(X[idx]) if data_batched else jnp.asarray(X)
        Yg = jnp.asarray(Y[idx]) if data_batched else jnp.asarray(Y)
        outs = fn(params, Xg, Yg)
        lo, er, nb, dv, fl, ep = (np.asarray(o) for o in outs)
        losses[idx], errors[idx], flags[idx] = lo, er, fl
        round_bytes[idx], divs[idx], eps[idx] = nb, dv, ep

    keep_div = record_divergence or not is_kernel
    return SweepResult(
        configs=pcfgs,
        losses=losses,
        errors=errors,
        round_bytes=round_bytes,
        sync_flags=flags,
        divergences=divs if keep_div else None,
        eps=eps if is_kernel else None,
    )
