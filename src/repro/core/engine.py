"""Device-resident scan simulation engine (DESIGN.md Sec. 7).

``simulation.run_kernel_simulation`` drives the m-learner system with a
Python loop: every round costs several jitted dispatches plus a host
round-trip (``float()`` on losses / divergence) and a numpy set-algebra
pass per sync.  This module compiles the ENTIRE T-round experiment into
one ``jax.lax.scan``: the carry holds (stacked learner states,
reference model, device byte ledger), every per-round observable
(loss, errors, bytes, divergence, sync flag, compression eps) comes
back as a T-length output array, and the host touches data exactly once
at the end.

There is ONE scan core.  Everything representation-specific — how a
model predicts, updates, averages, measures distance to the reference,
and what a synchronization costs in Sec. 3 bytes — lives behind the
``core.substrate.Substrate`` interface (DESIGN.md Sec. 8), so the same
compiled step serves support-vector expansions (``SVSubstrate`` with
the jit-resident ``accounting.DeviceLedger`` set algebra), random
Fourier feature models (``RFFSubstrate``: fixed O(m D) bytes per sync),
and the paper's linear baselines (``LinearSubstrate``).  ``run`` /
``sweep`` accept a ``LearnerConfig`` (resolved via
``substrate.substrate_of``), an ``RFFSpec``, or a ``Substrate``.

``sweep`` vmaps the whole simulation across a grid of ProtocolConfigs
(delta / period / mini_batch) and optionally per-config data streams
(seeds), one compilation per (substrate, protocol kind) — the
grid-evaluation workload of Kamp et al.'s adaptive-bounds protocol
family, including mixed-substrate grids (e.g. SV vs RFF vs linear on
the same stream).

Mesh-sharded execution (DESIGN.md Sec. 9): ``run(..., mesh=...)`` /
``sweep(..., mesh=...)`` execute the SAME scan core with the learner
axis sharded across a real ``jax.sharding.Mesh`` via ``shard_map``.
Learner state, streams, and the Sec. 3 stacked reference live sliced
per device; ``predict`` / ``update`` / the dynamic local-condition
distance are purely device-local, the protocol's only unconditional
cross-device traffic is the one-bit violation all-reduce, and a
synchronization lowers to an ``all_gather`` of the stacked models (the
sorted-id arrays feeding ``DeviceLedger`` ride along) followed by a
replicated average + local adopt.  The sharded engine reproduces the
single-device engine bit-for-bit on losses and integer-exactly on the
byte ledger (tests/test_engine_mesh.py, on 8 forced host devices).

Topology accounting: ``topology="coordinator"`` (default) charges the
paper's Sec. 3 designated-coordinator bytes; ``topology="allreduce"``
charges the mesh collective instead (``accounting.allreduce_bytes`` /
``allgather_bytes`` ring totals via ``Substrate.allreduce_sync_bytes``)
— same sync decisions, same models, different price — so every
experiment can report both topologies side by side.  The switch works
with and without a mesh.

Static vs. traced configuration: the protocol ``kind`` and the
substrate change the structure of the scan body (what is computed each
round), so they are compile-time specializations; ``delta``, ``period``
and ``mini_batch`` are traced scalars, so one compiled executable
serves a whole grid.

Exactness contract against the legacy serial driver:

- ``cumulative_bytes``, ``sync_rounds``, ``num_syncs`` are
  integer-exact;
- per-learner per-round losses / errors are the same float32 values;
  the cross-learner sum runs on the host (numpy, one fixed reduction
  order for every execution mode — the legacy driver sums on device,
  so per-round sums agree to float32 rounding and error counts agree
  exactly), then accumulates in float64 exactly like the legacy
  driver's accumulators;
- the RKHS divergence series delta(f_t) is the one observable whose
  *recording* costs a full union Gram every round, and nothing in the
  protocol consumes it — so it is opt-in (``record_divergence=True``;
  substrates with ``free_divergence`` — linear, RFF — always record it,
  the cost there is O(m d)).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import List, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from . import substrate as substrate_mod
from .learners import LearnerConfig
from .protocol import PROTOCOL_KIND_CODES, ProtocolConfig
from .simulation import SimResult
from .substrate import Substrate

Array = jnp.ndarray

LearnerLike = Union[Substrate, LearnerConfig, "substrate_mod.RFFSpec"]

TOPOLOGIES = ("coordinator", "allreduce")


class ScanParams(NamedTuple):
    """The traced protocol parameters of one simulation (scalars), or of
    a sweep (vectors of length n_configs)."""

    delta: Array
    period: Array
    mini_batch: Array


def params_of(pcfg: ProtocolConfig) -> ScanParams:
    """The traced-scalar view of one ProtocolConfig — the companion of
    :func:`make_protocol_step`, so external step drivers (the serving
    engine) share the scan engine's exact dtype conversion."""
    return ScanParams(
        delta=jnp.asarray(pcfg.delta, jnp.float32),
        period=jnp.asarray(pcfg.period, jnp.int32),
        mini_batch=jnp.asarray(pcfg.mini_batch, jnp.int32),
    )


_params_of = params_of


def _stack_params(pcfgs: Sequence[ProtocolConfig]) -> ScanParams:
    return ScanParams(
        delta=jnp.asarray([p.delta for p in pcfgs], jnp.float32),
        period=jnp.asarray([p.period for p in pcfgs], jnp.int32),
        mini_batch=jnp.asarray([p.mini_batch for p in pcfgs], jnp.int32),
    )


def _err_terms(loss: str, yhat: Array, y: Array) -> Array:
    """Per-learner service-error terms (prediction mistakes for hinge,
    squared error otherwise).  The hinge decision rule is deterministic
    at a zero margin — ``yhat >= 0`` predicts +1 — so an untrained
    all-zero model is scored against one label, not both; the serial
    oracle (core/simulation.py) and the async runtime nodes apply the
    identical rule."""
    if loss == "hinge":
        return (jnp.where(yhat >= 0, 1.0, -1.0) != y).astype(jnp.float32)
    return (yhat - y) ** 2


# ---------------------------------------------------------------------------
# The one generic scan core, parameterized by substrate
# ---------------------------------------------------------------------------


def _allreduce_cost(sub: Substrate, m: int) -> Array:
    """Trace-time constant ring bytes of one sync, int32-guarded like
    the device ledger (accounting.device_sync_bytes_kernel)."""
    cost = int(sub.allreduce_sync_bytes(m))
    if cost >= 2**31:
        raise ValueError(
            f"per-sync ring bytes {cost} for m={m} overflow the byte "
            "ledger's int32; use the host accounting at this scale")
    return jnp.asarray(cost, jnp.int32)


def _tree_select(mask: Array, new, old):
    """Per-learner select over a stacked state tree: leaf shapes are
    (m, ...), ``mask`` is (m,) bool — broadcast against the trailing
    dims.  ``jnp.where`` on identical operands is the identity, so an
    all-True mask keeps the masked engine bitwise on the unmasked path."""
    def sel(n, o):
        return jnp.where(mask.reshape(mask.shape + (1,) * (n.ndim - 1)),
                         n, o)
    return jax.tree.map(sel, new, old)


def _make_step(sub: Substrate, kind: str, record_divergence: bool,
               topology: str, axis, masked: bool = False):
    """One scan step over (state, reference, ledger).

    ``axis=None`` is the single-device engine: ``reference`` is ONE
    synchronized model and every reduction sees all m learners.

    ``axis`` set means the step runs inside ``shard_map`` with the
    learner dim sharded over the named mesh axes (DESIGN.md Sec. 9):
    state / streams / reference are per-device slices, ``reference``
    carries a leading (local) learner axis — the Sec. 3 stacked
    reference — and the cross-device protocol is exactly (a) the
    one-bit violation all-reduce of the dynamic check and (b) an
    ``all_gather`` of the stacked models when a sync fires.  The
    loss/err observables stay PER-LEARNER (sharded outputs, summed on
    the host identically in both modes): a device-side cross-learner
    sum would make the recorded floats depend on the reduction order
    the compiler picks for that program, which is exactly the
    bit-for-bit leak the parity contract forbids.

    ``masked`` (DESIGN.md Sec. 15) threads a per-round participation
    mask: ``xs`` gains a (m,) bool row ``p`` and the carry gains the
    previous round's mask.  Inactive learners keep their state bitwise
    (no predict/update), report zero loss/err, contribute nothing to
    the violation check or the sync average, and pay no bytes.
    Learners with ``p & ~prev`` are RE-JOINING after churn: before
    their first round back they re-``adopt`` the current reference and
    the ledger is charged the Sec. 3 download
    (``Substrate.rejoin_payload_bytes``).  A round with an empty cohort
    syncs nothing and moves zero bytes.  With an all-True mask every
    ``jnp.where`` selects the unmasked operand, so this path reproduces
    the unmasked step bit-for-bit (tests/test_population.py).
    """
    sharded = axis is not None

    def gather_tree(t):
        if not sharded:
            return t
        return jax.tree.map(
            lambda v: lax.all_gather(v, axis, axis=0, tiled=True), t)

    def step(params: ScanParams, carry, xs):
        if masked:
            state, reference, ledger, prev = carry
            x, y, t, p = xs
            cohort = jnp.sum(p.astype(jnp.int32))
            n_rejoin = jnp.sum((p & jnp.logical_not(prev)).astype(jnp.int32))
            if sharded:
                cohort = lax.psum(cohort, axis)
                n_rejoin = lax.psum(n_rejoin, axis)
                m_total = lax.psum(jnp.asarray(p.shape[0], jnp.int32), axis)
            else:
                m_total = p.shape[0]
            any_active = cohort > 0
            all_active = cohort == m_total
            # churn recovery: a rejoining learner (p & ~prev) downloads
            # the current reference before its first round back.  The
            # whole phase lives behind a lax.cond so that rejoin-free
            # rounds — every round of a full-participation run — take
            # an identity branch: inlining the rejoin selects into the
            # scan body changes how XLA fuses the predict/update
            # cluster and drifts full-participation floats by ulps
            # (the cond compiles branches as separate computations).
            rejoin = p & jnp.logical_not(prev)
            ref_one = (jax.tree.map(lambda v: v[0], reference)
                       if sharded else reference)

            def do_rejoin(models):
                rjb = sub.rejoin_payload_bytes(models, ref_one, rejoin)
                if sharded:
                    rjb = lax.psum(rjb, axis)
                new = _tree_select(
                    rejoin, sub.adopt(models, ref_one), models)
                return new, jnp.asarray(rjb, jnp.int32)

            def no_rejoin(models):
                return models, jnp.zeros((), jnp.int32)

            models, rejoin_bytes = lax.cond(
                n_rejoin > 0, do_rejoin, no_rejoin, sub.models_of(state))
            state = sub.with_models(state, models)
        else:
            state, reference, ledger = carry
            x, y, t = xs
        pre_state = state

        if sub.fused_scan_round:
            # one fused round: predict + update share their featurize/
            # Gram work (and under an engaged pallas backend run as a
            # single kernel launch) — core/substrate.py round_stacked
            state, losses, yhat = sub.round_stacked(state, (x, y))
        else:
            yhat = sub.predict(sub.models_of(state), x)
            state, losses = sub.update(state, (x, y))
        err = _err_terms(sub.loss, yhat, y)         # per-learner
        if masked:
            # inactive learners: no round happened — state stays as the
            # (possibly rejoin-adopted) pre-round state, observables
            # zero.  Same cond discipline as the rejoin phase: a
            # full-cohort round takes the identity branch, keeping the
            # masking selects out of the round's HLO cluster.
            def apply_mask(args):
                state, losses, err = args
                return (_tree_select(p, state, pre_state),
                        jnp.where(p, losses, 0.0),
                        jnp.where(p, err, 0.0))

            state, losses, err = lax.cond(
                all_active, lambda args: args, apply_mask,
                (state, losses, err))
        models = sub.models_of(state)

        if kind == "none":
            do_sync = jnp.zeros((), bool)
        elif kind == "continuous":
            do_sync = any_active if masked else jnp.ones((), bool)
        elif kind == "periodic":
            do_sync = ((t + 1) % params.period) == 0
            if masked:
                do_sync = do_sync & any_active
        else:  # dynamic: check local conditions every mini_batch rounds
            check_now = ((t + 1) % params.mini_batch) == 0

            def check(_):
                if sharded:
                    dists = sub.dist_to_ref_each(models, reference)
                else:
                    dists = sub.dist_to_ref(models, reference)
                violations = dists > params.delta
                if masked:
                    # only the participating cohort is polled; stale
                    # detached models cannot trigger a sync
                    violations = p & violations
                return jnp.any(violations)

            if sub.guarded_dist_check:
                # the distance costs a Gram — only pay it on check
                # rounds (lax.cond skips the untaken branch)
                violated = lax.cond(check_now, check,
                                    lambda _: jnp.zeros((), bool), None)
            else:
                violated = check_now & check(None)
            if sharded:
                # the one-bit violation all-reduce: the only
                # unconditional cross-device traffic of the protocol
                do_sync = lax.psum(violated.astype(jnp.int32), axis) > 0
            else:
                do_sync = violated

        if kind == "none":
            new_models, new_ref, new_ledger = models, reference, ledger
            nbytes = jnp.zeros((), jnp.int32)
            eps = jnp.zeros((), jnp.float32)
        else:

            def sync_branch(args):
                models, reference, ledger = args
                full = gather_tree(models)
                if masked:
                    full_mask = gather_tree(p)
                    fsync, eps = sub.average_stacked_masked(full, full_mask)
                    if topology == "coordinator":
                        nbytes, new_ledger = sub.sync_payload_masked(
                            full, full_mask, ledger)
                    else:
                        # static full-m guard, traced cohort-sized cost
                        _allreduce_cost(
                            sub, jax.tree.leaves(full)[0].shape[0])
                        nbytes = sub.allreduce_sync_bytes_masked(cohort)
                        new_ledger = ledger
                    # only the cohort adopts; detached learners stay on
                    # their stale model until they rejoin
                    new_models = _tree_select(
                        p, sub.adopt(models, fsync), models)
                else:
                    fsync, eps = sub.average_stacked(full)
                    if topology == "coordinator":
                        nbytes, new_ledger = sub.sync_payload(full, ledger)
                    else:
                        m = jax.tree.leaves(full)[0].shape[0]
                        nbytes, new_ledger = _allreduce_cost(sub, m), ledger
                    new_models = sub.adopt(models, fsync)
                if sharded:
                    m_local = jax.tree.leaves(models)[0].shape[0]
                    new_ref = _stack_ref(fsync, m_local)
                else:
                    new_ref = fsync
                return (new_models, new_ref, new_ledger,
                        jnp.asarray(nbytes, jnp.int32),
                        jnp.asarray(eps, jnp.float32))

            def keep_branch(args):
                models, reference, ledger = args
                return (models, reference, ledger,
                        jnp.zeros((), jnp.int32),
                        jnp.zeros((), jnp.float32))

            new_models, new_ref, new_ledger, nbytes, eps = lax.cond(
                do_sync, sync_branch, keep_branch,
                (models, reference, ledger))

        state = sub.with_models(state, new_models)
        if record_divergence or sub.free_divergence:
            div = sub.divergence(gather_tree(sub.models_of(state)))
        else:
            div = jnp.zeros((), jnp.float32)
        if masked:
            nbytes = nbytes + rejoin_bytes
            out = (losses, err, nbytes, div, do_sync, eps)
            return (state, new_ref, new_ledger, p), out
        out = (losses, err, nbytes, div, do_sync, eps)
        return (state, new_ref, new_ledger), out

    return step


def _stack_ref(ref, m: int):
    """Broadcast one synchronized model to a leading learner axis — the
    Sec. 3 stacked reference, one slice per learner."""
    return jax.tree.map(
        lambda v: jnp.broadcast_to(v[None], (m,) + v.shape), ref)


def make_protocol_step(sub: Substrate, kind: str, *,
                       record_divergence: bool = False,
                       topology: str = "coordinator"):
    """One protocol round as a standalone function — EXACTLY the scan
    body ``run`` / ``sweep`` iterate.

    Returns ``step(params, carry, xs) -> (carry, outs)`` with
    ``carry = (stacked learner state, reference, ledger)``,
    ``xs = (x (m, d), y (m,), t int32)`` and
    ``outs = (loss (m,), err (m,), bytes, divergence, sync_flag, eps)``.

    The online serving engine (repro/serving, DESIGN.md Sec. 10) jits
    this step and drives it one labeled round at a time between predict
    micro-batches: because it is the same function object the scan
    engine compiles, the serving path's losses, sync decisions, and
    Sec. 3 bytes are bit-identical to ``run`` by construction — the
    same already-proven discipline by which the serial loop driver
    (core/simulation.py) matches the scan engine while composing
    separately-jitted per-round ops.
    """
    if kind not in PROTOCOL_KIND_CODES:
        raise ValueError(f"unknown protocol kind {kind!r}")
    if topology not in TOPOLOGIES:
        raise ValueError(f"unknown topology {topology!r}")
    return _make_step(sub, kind, record_divergence, topology, axis=None)


def init_protocol_carry(sub: Substrate, m: int):
    """The round-0 scan carry of an m-learner system: freshly
    initialized stacked learner states, the compressed average of those
    blank models as the first reference, and an empty byte ledger —
    shared by the scan core and the serving engine so both start from
    the identical state."""
    state0 = sub.init(m)
    ref0, _ = sub.average_stacked(sub.models_of(state0))
    return state0, ref0, sub.ledger_init(m)


def assemble_sim_result(sub: Substrate, record_divergence: bool,
                        loss: np.ndarray, err: np.ndarray,
                        round_bytes: np.ndarray, div: np.ndarray,
                        flags: np.ndarray, eps: np.ndarray) -> SimResult:
    """Host-side post-processing of per-round step outputs — ONE code
    path for :func:`run` and the serving engine's ``result()``.

    ``loss`` / ``err`` arrive PER-LEARNER as (T, m) float32; the
    cross-learner sum happens HERE, identically for every execution
    mode — numpy's pairwise float32 sum over identical per-learner
    values — which is what makes the mesh-sharded engine and the
    serving path bit-for-bit with the single-device scan.  Divergence
    and eps series are dropped when not recorded / not produced,
    matching the substrate's ``free_divergence`` / ``has_eps`` flags.
    """
    keep_div = record_divergence or sub.free_divergence
    return SimResult.from_round_series(
        loss.sum(axis=1), err.sum(axis=1), round_bytes,
        div if keep_div else np.zeros((0,)),
        flags,
        eps if sub.has_eps else np.zeros((0,)))


def _scan_core(sub: Substrate, kind: str, record_divergence: bool,
               topology: str = "coordinator", masked: bool = False):
    step = _make_step(sub, kind, record_divergence, topology, axis=None,
                      masked=masked)

    if masked:
        def simulate(params: ScanParams, X: Array, Y: Array, part: Array):
            T, m, d = X.shape
            state0, ref0, ledger0 = init_protocol_carry(sub, m)
            # prev-mask carry starts as round 0's mask: nobody is
            # "rejoining" into the freshly distributed blank reference
            carry0 = (state0, ref0, ledger0, part[0])
            ts = jnp.arange(T, dtype=jnp.int32)
            _, outs = lax.scan(functools.partial(step, params),
                               carry0, (X, Y, ts, part))
            return outs

        return simulate

    def simulate(params: ScanParams, X: Array, Y: Array):
        T, m, d = X.shape
        carry0 = init_protocol_carry(sub, m)
        ts = jnp.arange(T, dtype=jnp.int32)
        _, outs = lax.scan(functools.partial(step, params),
                           carry0, (X, Y, ts))
        return outs

    return simulate


# ---------------------------------------------------------------------------
# Mesh-sharded core (DESIGN.md Sec. 9)
# ---------------------------------------------------------------------------


def learner_axes_of(mesh: Mesh) -> Tuple[str, ...]:
    """The mesh axes the learner dim is sharded over: the ``learners``
    axis when the mesh has one (``launch.mesh.make_learner_mesh``),
    otherwise every axis except ``model`` (the convention of
    DESIGN.md Sec. 5)."""
    if "learners" in mesh.axis_names:
        return ("learners",)
    axes = tuple(a for a in mesh.axis_names if a != "model")
    if not axes:
        raise ValueError(
            f"mesh {mesh.axis_names} has no learner axis; name one "
            "'learners' or include a non-'model' axis")
    return axes


def _num_shards(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    return math.prod(mesh.shape[a] for a in axes)


def _sharded_core(sub: Substrate, kind: str, record_divergence: bool,
                  topology: str, mesh: Mesh, axes: Tuple[str, ...],
                  vmapped: bool, data_batched: bool,
                  masked: bool = False):
    """The scan core under ``shard_map``: learner axis sharded over
    ``axes``, config axis (when ``vmapped``) vmapped INSIDE the shard
    so one mesh program serves the whole grid.

    Layout (in_specs): learner state, streams and the stacked
    reference are sharded on their learner dim; protocol params and
    the DeviceLedger are replicated (the ledger is the coordinator's
    cache — every device maintains the identical copy from the
    gathered union, so the coordinator-topology accounting needs no
    host).  Outputs: the per-learner loss/err series come back sharded
    like the streams; bytes / divergence / sync flags / eps are
    replicated per-round scalars.
    """
    if masked and vmapped:
        raise NotImplementedError(
            "participation masks are per-run (engine.run); sweep grids "
            "do not take a participation= argument")
    step = _make_step(sub, kind, record_divergence, topology, axis=axes,
                      masked=masked)

    if masked:
        def local_run(params: ScanParams, state0, ref0, ledger0, X, Y,
                      part):
            T = X.shape[0]
            ts = jnp.arange(T, dtype=jnp.int32)
            _, outs = lax.scan(functools.partial(step, params),
                               (state0, ref0, ledger0, part[0]),
                               (X, Y, ts, part))
            return outs
    else:
        def local_run(params: ScanParams, state0, ref0, ledger0, X, Y):
            T = X.shape[0]
            ts = jnp.arange(T, dtype=jnp.int32)
            _, outs = lax.scan(functools.partial(step, params),
                               (state0, ref0, ledger0), (X, Y, ts))
            return outs

    body = local_run
    if vmapped:
        dax = 0 if data_batched else None
        body = jax.vmap(local_run,
                        in_axes=(ScanParams(0, 0, 0), None, None, None,
                                 dax, dax))

    lead = axes if len(axes) > 1 else axes[0]
    data_spec = P(None, None, lead) if (vmapped and data_batched) \
        else P(None, lead)
    # per-learner loss/err series come back sharded like the streams;
    # bytes / divergence / flags / eps are replicated per-round scalars
    series_spec = P(None, None, lead) if vmapped else P(None, lead)
    scalar_spec = P()
    in_specs = (P(), P(lead), P(lead), P(), data_spec, data_spec)
    if masked:
        in_specs = in_specs + (P(None, lead),)   # participation (T, m)
    smapped = shard_map(
        body, mesh=mesh,
        in_specs=in_specs,
        out_specs=(series_spec, series_spec, scalar_spec, scalar_spec,
                   scalar_spec, scalar_spec),
        check_rep=False)

    if masked:
        def simulate(params: ScanParams, X: Array, Y: Array, part: Array):
            m = X.shape[1]
            state0 = sub.init(m)
            ref0, _ = sub.average_stacked(sub.models_of(state0))
            ledger0 = sub.ledger_init(m)
            return smapped(params, state0, _stack_ref(ref0, m), ledger0,
                           X, Y, part)

        return simulate

    def simulate(params: ScanParams, X: Array, Y: Array):
        m = X.shape[2] if (vmapped and data_batched) else X.shape[1]
        state0 = sub.init(m)
        ref0, _ = sub.average_stacked(sub.models_of(state0))
        ledger0 = sub.ledger_init(m)
        return smapped(params, state0, _stack_ref(ref0, m), ledger0, X, Y)

    return simulate


# ---------------------------------------------------------------------------
# Compiled-function cache and public API
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _jitted(sub: Substrate, kind: str, record_divergence: bool,
            vmapped: bool, data_batched: bool,
            topology: str = "coordinator",
            mesh: Optional[Mesh] = None,
            axes: Optional[Tuple[str, ...]] = None,
            masked: bool = False):
    """One jitted (optionally vmapped / mesh-sharded) simulate fn per
    static config.

    The cache is what lets benchmarks call ``run`` in a timing loop
    without re-tracing: jax.jit caches on function identity, so the
    closure must be built once per static configuration.  Substrates
    are frozen dataclasses (and Meshes are hashable), so they key the
    cache directly.
    """
    if mesh is not None:
        return jax.jit(_sharded_core(
            sub, kind, record_divergence, topology, mesh, axes,
            vmapped, data_batched, masked))
    core = _scan_core(sub, kind, record_divergence, topology, masked)
    if vmapped:
        dax = 0 if data_batched else None
        core = jax.vmap(core, in_axes=(ScanParams(0, 0, 0), dax, dax))
    return jax.jit(core)


def _resolve_mesh(mesh: Optional[Mesh], topology: str, m: int):
    """Validate (mesh, topology) for a run over m learners; returns
    the learner axes (None without a mesh)."""
    if topology not in TOPOLOGIES:
        raise ValueError(
            f"unknown topology {topology!r}; expected one of {TOPOLOGIES}")
    if mesh is None:
        return None
    axes = learner_axes_of(mesh)
    n = _num_shards(mesh, axes)
    if m % n:
        raise ValueError(
            f"{m} learners cannot shard evenly over {n} devices "
            f"(mesh axes {axes})")
    return axes


def run(
    learner: LearnerLike,
    pcfg: ProtocolConfig,
    X: np.ndarray,          # (T, m, d)
    Y: np.ndarray,          # (T, m)
    *,
    sync_budget: Optional[int] = None,
    compress_method: Optional[str] = None,   # None -> substrate's own
    record_divergence: bool = False,
    backend: Optional[str] = None,           # None -> substrate's own
    mesh: Optional[Mesh] = None,
    topology: str = "coordinator",
    participation: Optional[np.ndarray] = None,   # (T, m) bool
) -> SimResult:
    """Run T rounds of m learners under pcfg, fully on device.

    ``learner`` is a Substrate, a LearnerConfig, or an RFFSpec (see
    ``substrate.substrate_of`` — explicitly passed keywords override a
    Substrate's own configuration).  Drop-in replacement for
    ``simulation.run_kernel_simulation`` / ``run_linear_simulation``
    with the exactness contract in the module docstring.

    ``compress_method=None`` (like ``backend=None`` / the other
    keyword sentinels) means "keep the substrate's own configuration":
    for a passed Substrate, whatever it was built with; for a
    LearnerConfig, the dataclass default
    ``SVSubstrate.compress_method == compression.DEFAULT_METHOD``
    ("truncate").  Pass an explicit string ("truncate" | "project") to
    override either way.

    ``mesh``: a ``jax.sharding.Mesh`` to shard the learner axis over
    (``launch.mesh.make_learner_mesh``; m must divide evenly) — same
    losses and ledger as the single-device engine, bit-for-bit.
    ``topology``: "coordinator" charges the paper's Sec. 3 bytes,
    "allreduce" the mesh collective's ring total (DESIGN.md Sec. 9);
    decisions and models are identical either way.

    ``participation``: a (T, m) bool mask selecting the per-round
    cohort (DESIGN.md Sec. 15).  Inactive learners skip predict/update,
    contribute nothing to the violation check or the sync average, and
    pay no Sec. 3 bytes; a learner whose mask flips False→True is
    re-joining after churn and re-``adopt``s the current reference,
    paying the download.  ``participation=None`` (default) and an
    all-True mask both produce the exact unmasked result — losses
    bitwise, bytes integer-exact (tests/test_population.py).
    """
    sub = substrate_mod.substrate_of(
        learner, sync_budget=sync_budget, compress_method=compress_method,
        backend=backend)
    if not isinstance(X, jax.Array):   # keep pre-sharded streams on device
        X = np.asarray(X)
    T, m, d = X.shape
    sub.validate(T, m, d)
    axes = _resolve_mesh(mesh, topology, m)
    masked = participation is not None
    if masked:
        part = np.asarray(participation)
        if part.shape != (T, m):
            raise ValueError(
                f"participation shape {part.shape} != (T, m) = {(T, m)}")
        part = jnp.asarray(part.astype(bool))
    fn = _jitted(sub, pcfg.kind, bool(record_divergence), False, False,
                 topology, mesh, axes, masked)
    if masked:
        outs = fn(_params_of(pcfg), jnp.asarray(X), jnp.asarray(Y), part)
    else:
        outs = fn(_params_of(pcfg), jnp.asarray(X), jnp.asarray(Y))
    loss, err, nbytes, div, flags, eps = (np.asarray(o) for o in outs)
    return assemble_sim_result(sub, bool(record_divergence),
                               loss, err, nbytes, div, flags, eps)


@dataclasses.dataclass
class SweepResult:
    """Stacked per-round series of a protocol-grid sweep.

    Every array carries a leading axis of size n = len(configs);
    ``sweep_result[i]`` materializes the i-th configuration as a
    regular ``SimResult``.
    """

    configs: List[ProtocolConfig]
    losses: np.ndarray        # (n, T)
    errors: np.ndarray        # (n, T)
    round_bytes: np.ndarray   # (n, T)
    sync_flags: np.ndarray    # (n, T) bool
    divergences: Optional[np.ndarray]  # (n, T) or None (not recorded)
    eps: Optional[np.ndarray]          # (n, T) or None (eps-free substrates)

    def __len__(self) -> int:
        return len(self.configs)

    def __getitem__(self, i: int) -> SimResult:
        return SimResult.from_round_series(
            self.losses[i], self.errors[i], self.round_bytes[i],
            self.divergences[i] if self.divergences is not None
            else np.zeros((0,)),
            self.sync_flags[i],
            self.eps[i] if self.eps is not None else np.zeros((0,)))

    @property
    def results(self) -> List[SimResult]:
        return [self[i] for i in range(len(self))]


def sweep(
    learner: Union[LearnerLike, Sequence[LearnerLike]],
    pcfgs: Sequence[ProtocolConfig],
    X: np.ndarray,          # (T, m, d) shared, or (n, T, m, d) per config
    Y: np.ndarray,          # (T, m) shared, or (n, T, m)
    *,
    sync_budget: Optional[int] = None,
    compress_method: Optional[str] = None,   # None -> substrate's own
    record_divergence: bool = False,
    backend: Optional[str] = None,           # None -> substrate's own
    mesh: Optional[Mesh] = None,
    topology: str = "coordinator",
) -> SweepResult:
    """Simulate a grid of protocol configurations in one compilation.

    The whole simulation (scan over T rounds, ledger included) is
    vmapped across the config axis; configs are grouped by
    (substrate, kind) so each group shares one compiled executable
    regardless of its delta / period / mini_batch values.  ``learner``
    may also be a sequence of per-config substrates (same length as
    ``pcfgs``) for mixed-substrate grids — e.g. SV vs RFF vs linear on
    the same stream.  Pass X with a leading config axis to sweep seeds
    (per-config data streams) at the same time.

    With ``mesh`` the config axis stays vmapped while the learner axis
    is sharded (the vmap runs inside the ``shard_map``, so the whole
    grid is still one mesh program per (substrate, kind) group);
    ``topology`` selects the byte accounting as in :func:`run`, and
    ``compress_method=None`` / ``backend=None`` keep each substrate's
    own configuration exactly as :func:`run` documents.
    """
    pcfgs = list(pcfgs)
    n = len(pcfgs)
    if n == 0:
        raise ValueError("sweep needs at least one ProtocolConfig")
    if isinstance(learner, (list, tuple)):
        if len(learner) != n:
            raise ValueError(
                f"{len(learner)} substrates != {n} protocol configs")
        subs = [substrate_mod.substrate_of(
            s, sync_budget=sync_budget, compress_method=compress_method,
            backend=backend) for s in learner]
    else:
        one = substrate_mod.substrate_of(
            learner, sync_budget=sync_budget, compress_method=compress_method,
            backend=backend)
        subs = [one] * n
    X = np.asarray(X)
    Y = np.asarray(Y)
    data_batched = X.ndim == 4
    if data_batched and X.shape[0] != n:
        raise ValueError(
            f"per-config data axis {X.shape[0]} != n_configs {n}")
    T = X.shape[1] if data_batched else X.shape[0]
    m = X.shape[2] if data_batched else X.shape[1]
    d = X.shape[3] if data_batched else X.shape[2]
    for sub in set(subs):
        sub.validate(T, m, d)
    axes = _resolve_mesh(mesh, topology, m)

    losses = np.zeros((n, T), np.float32)
    errors = np.zeros((n, T), np.float32)
    round_bytes = np.zeros((n, T), np.int64)
    flags = np.zeros((n, T), bool)
    divs = np.zeros((n, T), np.float32)
    eps = np.zeros((n, T), np.float32)

    by_group: dict = {}
    for i, (s, p) in enumerate(zip(subs, pcfgs)):
        by_group.setdefault((s, p.kind), []).append(i)

    for (sub, kind), idx in sorted(
            by_group.items(),
            key=lambda kv: (PROTOCOL_KIND_CODES[kv[0][1]], repr(kv[0][0]))):
        fn = _jitted(sub, kind, bool(record_divergence), True, data_batched,
                     topology, mesh, axes)
        params = _stack_params([pcfgs[i] for i in idx])
        Xg = jnp.asarray(X[idx]) if data_batched else jnp.asarray(X)
        Yg = jnp.asarray(Y[idx]) if data_batched else jnp.asarray(Y)
        outs = fn(params, Xg, Yg)
        lo, er, nb, dv, fl, ep = (np.asarray(o) for o in outs)
        # (n, T, m) per-learner series -> (n, T), summed exactly as in run
        losses[idx], errors[idx], flags[idx] = lo.sum(-1), er.sum(-1), fl
        round_bytes[idx], divs[idx], eps[idx] = nb, dv, ep

    keep_div = record_divergence or all(s.free_divergence for s in subs)
    keep_eps = any(s.has_eps for s in subs)
    return SweepResult(
        configs=pcfgs,
        losses=losses,
        errors=errors,
        round_bytes=round_bytes,
        sync_flags=flags,
        divergences=divs if keep_div else None,
        eps=eps if keep_eps else None,
    )
