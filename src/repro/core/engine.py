"""Device-resident scan simulation engine (DESIGN.md Sec. 7).

``simulation.run_kernel_simulation`` drives the m-learner system with a
Python loop: every round costs several jitted dispatches plus a host
round-trip (``float()`` on losses / divergence) and a numpy set-algebra
pass per sync.  This module compiles the ENTIRE T-round experiment into
one ``jax.lax.scan``: the carry holds (stacked learner states,
reference model, device byte ledger), every per-round observable
(loss, errors, bytes, divergence, sync flag, compression eps) comes
back as a T-length output array, and the host touches data exactly once
at the end.

There is ONE scan core.  Everything representation-specific — how a
model predicts, updates, averages, measures distance to the reference,
and what a synchronization costs in Sec. 3 bytes — lives behind the
``core.substrate.Substrate`` interface (DESIGN.md Sec. 8), so the same
compiled step serves support-vector expansions (``SVSubstrate`` with
the jit-resident ``accounting.DeviceLedger`` set algebra), random
Fourier feature models (``RFFSubstrate``: fixed O(m D) bytes per sync),
and the paper's linear baselines (``LinearSubstrate``).  ``run`` /
``sweep`` accept a ``LearnerConfig`` (resolved via
``substrate.substrate_of``), an ``RFFSpec``, or a ``Substrate``.

``sweep`` vmaps the whole simulation across a grid of ProtocolConfigs
(delta / period / mini_batch) and optionally per-config data streams
(seeds), one compilation per (substrate, protocol kind) — the
grid-evaluation workload of Kamp et al.'s adaptive-bounds protocol
family, including mixed-substrate grids (e.g. SV vs RFF vs linear on
the same stream).

Static vs. traced configuration: the protocol ``kind`` and the
substrate change the structure of the scan body (what is computed each
round), so they are compile-time specializations; ``delta``, ``period``
and ``mini_batch`` are traced scalars, so one compiled executable
serves a whole grid.

Exactness contract against the legacy serial driver:

- ``cumulative_bytes``, ``sync_rounds``, ``num_syncs`` are
  integer-exact;
- per-round losses / errors are the same float32 values, accumulated on
  the host in float64 exactly like the legacy driver's accumulators;
- the RKHS divergence series delta(f_t) is the one observable whose
  *recording* costs a full union Gram every round, and nothing in the
  protocol consumes it — so it is opt-in (``record_divergence=True``;
  substrates with ``free_divergence`` — linear, RFF — always record it,
  the cost there is O(m d)).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import substrate as substrate_mod
from .learners import LearnerConfig
from .protocol import PROTOCOL_KIND_CODES, ProtocolConfig
from .simulation import SimResult
from .substrate import Substrate

Array = jnp.ndarray

LearnerLike = Union[Substrate, LearnerConfig, "substrate_mod.RFFSpec"]


class ScanParams(NamedTuple):
    """The traced protocol parameters of one simulation (scalars), or of
    a sweep (vectors of length n_configs)."""

    delta: Array
    period: Array
    mini_batch: Array


def _params_of(pcfg: ProtocolConfig) -> ScanParams:
    return ScanParams(
        delta=jnp.asarray(pcfg.delta, jnp.float32),
        period=jnp.asarray(pcfg.period, jnp.int32),
        mini_batch=jnp.asarray(pcfg.mini_batch, jnp.int32),
    )


def _stack_params(pcfgs: Sequence[ProtocolConfig]) -> ScanParams:
    return ScanParams(
        delta=jnp.asarray([p.delta for p in pcfgs], jnp.float32),
        period=jnp.asarray([p.period for p in pcfgs], jnp.int32),
        mini_batch=jnp.asarray([p.mini_batch for p in pcfgs], jnp.int32),
    )


def _err_of(loss: str, yhat: Array, y: Array) -> Array:
    """Per-round summed service error, as the legacy driver measures it
    (prediction mistakes for hinge, squared error otherwise)."""
    if loss == "hinge":
        return jnp.sum((jnp.sign(yhat) != y).astype(jnp.float32))
    return jnp.sum((yhat - y) ** 2)


# ---------------------------------------------------------------------------
# The one generic scan core, parameterized by substrate
# ---------------------------------------------------------------------------


def _scan_core(sub: Substrate, kind: str, record_divergence: bool):
    def simulate(params: ScanParams, X: Array, Y: Array):
        T, m, d = X.shape
        state0 = sub.init(m)
        ref0, _ = sub.average_stacked(sub.models_of(state0))
        ledger0 = sub.ledger_init(m)

        def step(carry, xs):
            state, reference, ledger = carry
            x, y, t = xs

            yhat = sub.predict(sub.models_of(state), x)
            err = _err_of(sub.loss, yhat, y)
            state, losses = sub.update(state, (x, y))
            loss = jnp.sum(losses)
            models = sub.models_of(state)

            if kind == "none":
                do_sync = jnp.zeros((), bool)
            elif kind == "continuous":
                do_sync = jnp.ones((), bool)
            elif kind == "periodic":
                do_sync = ((t + 1) % params.period) == 0
            else:  # dynamic: check local conditions every mini_batch rounds
                check_now = ((t + 1) % params.mini_batch) == 0
                if sub.guarded_dist_check:
                    # the distance costs a Gram — only pay it on check
                    # rounds (lax.cond skips the untaken branch)
                    def check(_):
                        dists = sub.dist_to_ref(models, reference)
                        return jnp.any(dists > params.delta)

                    do_sync = lax.cond(check_now, check,
                                       lambda _: jnp.zeros((), bool), None)
                else:
                    dists = sub.dist_to_ref(models, reference)
                    do_sync = check_now & jnp.any(dists > params.delta)

            if kind == "none":
                new_models, new_ref, new_ledger = models, reference, ledger
                nbytes = jnp.zeros((), jnp.int32)
                eps = jnp.zeros((), jnp.float32)
            else:

                def sync_branch(args):
                    models, reference, ledger = args
                    fsync, eps = sub.average_stacked(models)
                    nbytes, new_ledger = sub.sync_payload(models, ledger)
                    return (sub.adopt(models, fsync), fsync, new_ledger,
                            jnp.asarray(nbytes, jnp.int32),
                            jnp.asarray(eps, jnp.float32))

                def keep_branch(args):
                    models, reference, ledger = args
                    return (models, reference, ledger,
                            jnp.zeros((), jnp.int32),
                            jnp.zeros((), jnp.float32))

                new_models, new_ref, new_ledger, nbytes, eps = lax.cond(
                    do_sync, sync_branch, keep_branch,
                    (models, reference, ledger))

            state = sub.with_models(state, new_models)
            if record_divergence or sub.free_divergence:
                div = sub.divergence(sub.models_of(state))
            else:
                div = jnp.zeros((), jnp.float32)
            out = (loss, err, nbytes, div, do_sync, eps)
            return (state, new_ref, new_ledger), out

        ts = jnp.arange(T, dtype=jnp.int32)
        _, outs = lax.scan(step, (state0, ref0, ledger0), (X, Y, ts))
        return outs

    return simulate


# ---------------------------------------------------------------------------
# Compiled-function cache and public API
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _jitted(sub: Substrate, kind: str, record_divergence: bool,
            vmapped: bool, data_batched: bool):
    """One jitted (optionally vmapped) simulate fn per static config.

    The cache is what lets benchmarks call ``run`` in a timing loop
    without re-tracing: jax.jit caches on function identity, so the
    closure must be built once per static configuration.  Substrates
    are frozen dataclasses, so they key the cache directly.
    """
    core = _scan_core(sub, kind, record_divergence)
    if vmapped:
        dax = 0 if data_batched else None
        core = jax.vmap(core, in_axes=(ScanParams(0, 0, 0), dax, dax))
    return jax.jit(core)


def run(
    learner: LearnerLike,
    pcfg: ProtocolConfig,
    X: np.ndarray,          # (T, m, d)
    Y: np.ndarray,          # (T, m)
    *,
    sync_budget: Optional[int] = None,
    compress_method: Optional[str] = None,   # default "truncate"
    record_divergence: bool = False,
    backend: Optional[str] = None,           # default "reference"
) -> SimResult:
    """Run T rounds of m learners under pcfg, fully on device.

    ``learner`` is a Substrate, a LearnerConfig, or an RFFSpec (see
    ``substrate.substrate_of`` — explicitly passed keywords override a
    Substrate's own configuration).  Drop-in replacement for
    ``simulation.run_kernel_simulation`` / ``run_linear_simulation``
    with the exactness contract in the module docstring.
    """
    sub = substrate_mod.substrate_of(
        learner, sync_budget=sync_budget, compress_method=compress_method,
        backend=backend)
    X = np.asarray(X)
    T, m, d = X.shape
    sub.validate(T, m, d)
    fn = _jitted(sub, pcfg.kind, bool(record_divergence), False, False)
    outs = fn(_params_of(pcfg), jnp.asarray(X), jnp.asarray(Y))
    loss, err, nbytes, div, flags, eps = (np.asarray(o) for o in outs)
    keep_div = record_divergence or sub.free_divergence
    return SimResult.from_round_series(
        loss, err, nbytes,
        div if keep_div else np.zeros((0,)),
        flags,
        eps if sub.has_eps else np.zeros((0,)))


@dataclasses.dataclass
class SweepResult:
    """Stacked per-round series of a protocol-grid sweep.

    Every array carries a leading axis of size n = len(configs);
    ``sweep_result[i]`` materializes the i-th configuration as a
    regular ``SimResult``.
    """

    configs: List[ProtocolConfig]
    losses: np.ndarray        # (n, T)
    errors: np.ndarray        # (n, T)
    round_bytes: np.ndarray   # (n, T)
    sync_flags: np.ndarray    # (n, T) bool
    divergences: Optional[np.ndarray]  # (n, T) or None (not recorded)
    eps: Optional[np.ndarray]          # (n, T) or None (eps-free substrates)

    def __len__(self) -> int:
        return len(self.configs)

    def __getitem__(self, i: int) -> SimResult:
        return SimResult.from_round_series(
            self.losses[i], self.errors[i], self.round_bytes[i],
            self.divergences[i] if self.divergences is not None
            else np.zeros((0,)),
            self.sync_flags[i],
            self.eps[i] if self.eps is not None else np.zeros((0,)))

    @property
    def results(self) -> List[SimResult]:
        return [self[i] for i in range(len(self))]


def sweep(
    learner: Union[LearnerLike, Sequence[LearnerLike]],
    pcfgs: Sequence[ProtocolConfig],
    X: np.ndarray,          # (T, m, d) shared, or (n, T, m, d) per config
    Y: np.ndarray,          # (T, m) shared, or (n, T, m)
    *,
    sync_budget: Optional[int] = None,
    compress_method: Optional[str] = None,   # default "truncate"
    record_divergence: bool = False,
    backend: Optional[str] = None,           # default "reference"
) -> SweepResult:
    """Simulate a grid of protocol configurations in one compilation.

    The whole simulation (scan over T rounds, ledger included) is
    vmapped across the config axis; configs are grouped by
    (substrate, kind) so each group shares one compiled executable
    regardless of its delta / period / mini_batch values.  ``learner``
    may also be a sequence of per-config substrates (same length as
    ``pcfgs``) for mixed-substrate grids — e.g. SV vs RFF vs linear on
    the same stream.  Pass X with a leading config axis to sweep seeds
    (per-config data streams) at the same time.
    """
    pcfgs = list(pcfgs)
    n = len(pcfgs)
    if n == 0:
        raise ValueError("sweep needs at least one ProtocolConfig")
    if isinstance(learner, (list, tuple)):
        if len(learner) != n:
            raise ValueError(
                f"{len(learner)} substrates != {n} protocol configs")
        subs = [substrate_mod.substrate_of(
            s, sync_budget=sync_budget, compress_method=compress_method,
            backend=backend) for s in learner]
    else:
        one = substrate_mod.substrate_of(
            learner, sync_budget=sync_budget, compress_method=compress_method,
            backend=backend)
        subs = [one] * n
    X = np.asarray(X)
    Y = np.asarray(Y)
    data_batched = X.ndim == 4
    if data_batched and X.shape[0] != n:
        raise ValueError(
            f"per-config data axis {X.shape[0]} != n_configs {n}")
    T = X.shape[1] if data_batched else X.shape[0]
    m = X.shape[2] if data_batched else X.shape[1]
    d = X.shape[3] if data_batched else X.shape[2]
    for sub in set(subs):
        sub.validate(T, m, d)

    losses = np.zeros((n, T), np.float32)
    errors = np.zeros((n, T), np.float32)
    round_bytes = np.zeros((n, T), np.int64)
    flags = np.zeros((n, T), bool)
    divs = np.zeros((n, T), np.float32)
    eps = np.zeros((n, T), np.float32)

    by_group: dict = {}
    for i, (s, p) in enumerate(zip(subs, pcfgs)):
        by_group.setdefault((s, p.kind), []).append(i)

    for (sub, kind), idx in sorted(
            by_group.items(),
            key=lambda kv: (PROTOCOL_KIND_CODES[kv[0][1]], repr(kv[0][0]))):
        fn = _jitted(sub, kind, bool(record_divergence), True, data_batched)
        params = _stack_params([pcfgs[i] for i in idx])
        Xg = jnp.asarray(X[idx]) if data_batched else jnp.asarray(X)
        Yg = jnp.asarray(Y[idx]) if data_batched else jnp.asarray(Y)
        outs = fn(params, Xg, Yg)
        lo, er, nb, dv, fl, ep = (np.asarray(o) for o in outs)
        losses[idx], errors[idx], flags[idx] = lo, er, fl
        round_bytes[idx], divs[idx], eps[idx] = nb, dv, ep

    keep_div = record_divergence or all(s.free_divergence for s in subs)
    keep_eps = any(s.has_eps for s in subs)
    return SweepResult(
        configs=pcfgs,
        losses=losses,
        errors=errors,
        round_bytes=round_bytes,
        sync_flags=flags,
        divergences=divs if keep_div else None,
        eps=eps if keep_eps else None,
    )
