"""Random Fourier Features learner (paper Sec. 4, 'future work').

The paper notes that a finite-dimensional approximation of the feature
map (Rahimi & Recht 2007) would give kernel-quality models with
*linear-model communication*: the model is a fixed-size primal weight
vector over D random features, so a synchronization transmits O(m D)
bytes regardless of how many examples have been seen — the strict
adaptivity of Cor. 8 applies verbatim.

phi(x) = sqrt(2/D) * cos(W x + b),   W ~ N(0, 2*gamma I),  b ~ U[0, 2pi]

approximates the Gaussian kernel k(x, y) = exp(-gamma ||x-y||^2) via
E[phi(x).phi(y)] = k(x, y).

This module provides the feature map (the Pallas-fused path lives in
repro.kernels.ops.rff_features) and the RFF learner state.  Protocol
integration — the scan engine, the async runtime, sweeps, and the
Sec. 3 byte accounting — goes through ``substrate.RFFSubstrate``
(DESIGN.md Sec. 8), which closes the paper's open question empirically
(benchmarks/bench_rff.py).  ``make_update`` stays as the standalone
reference update the substrate is tested against.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class RFFSpec:
    dim: int            # input dim d
    num_features: int   # D
    gamma: float = 1.0
    seed: int = 0


def rff_params(spec: RFFSpec) -> Tuple[Array, Array]:
    kw, kb = jax.random.split(jax.random.PRNGKey(spec.seed))
    W = jax.random.normal(kw, (spec.num_features, spec.dim)) * jnp.sqrt(2.0 * spec.gamma)
    b = jax.random.uniform(kb, (spec.num_features,), maxval=2.0 * jnp.pi)
    return W, b


def featurize(spec: RFFSpec, W: Array, b: Array, X: Array) -> Array:
    """phi(X): (..., d) -> (..., D).  Pure-jnp reference; see
    repro.kernels.ops.rff_features for the Pallas path.

    The projection is an explicit multiply + last-axis reduce rather
    than ``X @ W.T``: a row's result is then independent of how many
    rows share the call, which is what lets the mesh-sharded engine
    (one learner slice per device) reproduce the single-device engine
    bit-for-bit (DESIGN.md Sec. 9 — XLA's gemm kernels pick
    row-count-dependent accumulation orders, gemv vs gemm).  The
    materialized (..., D, d) intermediate is small at simulation scale;
    the Pallas path owns the large-D regime.
    """
    lead = tuple(range(X.ndim - 1))     # explicit broadcast of the
    Wx = jnp.expand_dims(W, lead)       # (D, d) params over X's batch
    bx = jnp.expand_dims(b, lead)       # axes (rank promotion is off)
    proj = jnp.sum(X[..., None, :] * Wx, axis=-1) + bx
    return jnp.sqrt(2.0 / spec.num_features) * jnp.cos(proj)


class RFFLearnerState(NamedTuple):
    w: Array   # (D,) primal weights
    b: Array   # ()


def init_state(spec: RFFSpec) -> RFFLearnerState:
    return RFFLearnerState(
        w=jnp.zeros((spec.num_features,), jnp.float32), b=jnp.zeros((), jnp.float32)
    )


def make_update(spec: RFFSpec, W: Array, bias: Array, *, eta: float = 0.5,
                lam: float = 0.01, loss: str = "hinge"):
    """SGD in the RFF primal space — an exactly loss-proportional convex
    update on a fixed-size model."""

    def update(state: RFFLearnerState, example):
        x, y = example
        z = featurize(spec, W, bias, x[None])[0]
        yhat = jnp.sum(state.w * z) + state.b
        if loss == "hinge":
            ell = jnp.maximum(0.0, 1.0 - y * yhat)
            g = jnp.where(ell > 0, -y, 0.0)
        else:
            r = yhat - y
            ell, g = 0.5 * r * r, r
        w = (1.0 - eta * lam) * state.w - eta * g * z
        b = state.b - eta * g
        return RFFLearnerState(w=w, b=b), ell

    return update
