"""Distributed online learning protocols (Kamp et al.).

A *protocol* Pi = (A, sigma) runs an online learning algorithm A on m
local learners and synchronizes their models with a synchronization
operator sigma.  This module implements the operators of the paper over
**stacked-learner pytrees**: every leaf of the model pytree carries a
leading axis of size ``m`` (one slice per learner).  All operators are
pure ``jnp`` + ``lax`` and therefore mesh-agnostic — the identical code
runs in a CPU simulation (m=4) and on a 512-chip mesh where the learner
axis is sharded over ``("pod", "data")`` and GSPMD lowers the means to
all-reduces.

Operators
---------
- ``sigma_none``       : no synchronization (isolated learners).
- ``sigma_continuous`` : average every round (sigma_1).
- ``sigma_periodic``   : average every b rounds (sigma_b).
- ``sigma_dynamic``    : average only when the divergence
  ``delta(f) = 1/m sum_i ||f_i - fbar||**2`` exceeds the threshold
  ``Delta``, monitored through the local conditions
  ``||f_i - r||**2 <= Delta`` against the shared reference model r.

The dynamic operator returns the updated reference model and the number
of bytes communicated this round, so callers can account communication
exactly as in Sec. 3 of the paper.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

PyTree = Any


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------

# Stable integer codes for the protocol kinds.  The scan engine
# (core/engine.py, DESIGN.md Sec. 7) specializes its compiled step on
# the kind and uses the code to group a sweep's configs into one
# compilation per kind.
PROTOCOL_KIND_CODES = {"none": 0, "continuous": 1, "periodic": 2, "dynamic": 3}


@dataclasses.dataclass(frozen=True)
class ProtocolConfig:
    """Configuration of a distributed online learning protocol.

    Attributes:
      kind: one of ``none | continuous | periodic | dynamic``.
      period: synchronization period b (periodic protocol only).
      delta: divergence threshold Delta (dynamic protocol only).
      mini_batch: check local conditions only every ``mini_batch`` steps
        (Sec. 4: bounds peak communication like a periodic protocol
        while keeping the dynamic total-communication advantage).
      per_group: if True, maintain a separate reference/threshold per
        top-level parameter group (beyond-paper refinement, useful for
        MoE router vs. expert tensors).
    """

    kind: str = "dynamic"
    period: int = 1
    delta: float = 0.1
    mini_batch: int = 1
    per_group: bool = False
    # --- adaptive divergence threshold (paper Sec. 4 future work) ---------
    # "const":   Delta_t = delta
    # "sqrt":    Delta_t = delta / sqrt(t)   (the paper's consistency
    #            schedule for static targets: Delta_t = t^-1/2)
    # "adaptive": multiplicative feedback controller steering the sync
    #            RATE to target_sync_rate: raise Delta on every sync,
    #            lower it geometrically while quiet.  Equilibrium at
    #            sync-rate == target independent of the initial Delta —
    #            answers the paper's open problem of selecting the
    #            communication/quality trade-off directly.
    delta_schedule: str = "const"
    target_sync_rate: float = 0.05
    adapt_up: float = 1.25

    def __post_init__(self) -> None:
        if self.kind not in ("none", "continuous", "periodic", "dynamic"):
            raise ValueError(f"unknown protocol kind: {self.kind!r}")
        if self.period < 1:
            raise ValueError("period must be >= 1")
        if self.delta < 0:
            raise ValueError("delta must be >= 0")
        if self.delta_schedule not in ("const", "sqrt", "adaptive"):
            raise ValueError(self.delta_schedule)
        if not (0.0 < self.target_sync_rate < 1.0):
            raise ValueError("target_sync_rate in (0, 1)")

    @property
    def kind_code(self) -> int:
        """Integer code of ``kind`` (see PROTOCOL_KIND_CODES)."""
        return PROTOCOL_KIND_CODES[self.kind]


class ProtocolState(NamedTuple):
    """Carry of a protocol between rounds.

    reference: the common reference model r_t (un-stacked pytree).
    step: round counter t.
    syncs: cumulative number of synchronizations V(t).
    bytes_sent: cumulative communication C(t) in bytes
      (coordinator-topology accounting; see accounting.py for the
      all-reduce model).
    last_divergence: divergence measured in the most recent round.
    """

    reference: PyTree
    step: jnp.ndarray
    syncs: jnp.ndarray
    bytes_sent: jnp.ndarray
    last_divergence: jnp.ndarray
    # adaptive-threshold multiplier; the neutral scale 1 makes a state
    # built without it behave identically under every schedule.  A
    # weak-typed Python scalar, not a jnp array: a class-level array
    # default would initialize the JAX backend at import time and lock
    # the device count before launchers can set XLA_FLAGS.
    delta_scale: jnp.ndarray = 1.0


# ---------------------------------------------------------------------------
# Stacked-pytree helpers
# ---------------------------------------------------------------------------


def average_model(stacked: PyTree) -> PyTree:
    """fbar = 1/m sum_i f_i  (mean over the leading learner axis)."""
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), stacked)


def broadcast_model(model: PyTree, m: int) -> PyTree:
    """Replicate an un-stacked model to a stacked configuration."""
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (m,) + x.shape), model)


def _sq_dist_to(stacked: PyTree, ref: PyTree) -> jnp.ndarray:
    """Per-learner squared distances ||f_i - r||^2, shape (m,).

    ``ref`` may be un-stacked (broadcast against the learner axis) or
    stacked to the same shape as ``stacked`` — the latter is how the
    LM-scale trainer stores it, so each device's slice of the reference
    lives with its learner's params and the local-condition check needs
    NO communication (DESIGN.md Sec. 3)."""

    def per_leaf(x, r):
        r32 = r.astype(jnp.float32)
        if r.ndim != x.ndim:
            r32 = r32[None]
        return jnp.sum(
            jnp.square(x.astype(jnp.float32) - r32),
            axis=tuple(range(1, x.ndim)),
        )

    leaves = jax.tree.leaves(jax.tree.map(per_leaf, stacked, ref))
    return sum(leaves)


def divergence(stacked: PyTree) -> jnp.ndarray:
    """delta(f) = 1/m sum_i ||f_i - fbar||^2  (Eq. 1)."""
    fbar = average_model(stacked)
    return jnp.mean(_sq_dist_to(stacked, fbar))


def group_local_conditions(stacked: PyTree, reference: PyTree,
                           delta) -> jnp.ndarray:
    """Per-GROUP local conditions (beyond-paper, ``per_group=True``).

    The total threshold Delta is split across the top-level parameter
    groups proportionally to their parameter counts, and each group's
    distance is monitored separately; a violation in ANY group triggers
    synchronization.  Since sum_g Delta_g = Delta, "no group violates"
    still implies ||f_i - r||^2 <= Delta — soundness of the divergence
    bound is preserved — while drift concentrated in a small group
    (e.g. a MoE router) is caught much earlier than by the global norm.
    Returns per-learner violation flags, shape (m,).
    """
    if isinstance(stacked, dict):
        groups = [(k, stacked[k], reference[k]) for k in stacked]
    else:
        leaves_s = jax.tree.leaves(stacked)
        leaves_r = jax.tree.leaves(reference)
        groups = [(str(i), l, r) for i, (l, r) in enumerate(zip(leaves_s, leaves_r))]
    total = sum(
        sum(int(x.size) for x in jax.tree.leaves(g)) for _, g, _ in groups)
    violated = None
    for _, g_s, g_r in groups:
        n = sum(int(x.size) for x in jax.tree.leaves(g_s))
        delta_g = delta * (n / total)
        v = _sq_dist_to(g_s, g_r) > delta_g
        violated = v if violated is None else (violated | v)
    return violated


def local_conditions(stacked: PyTree, reference: PyTree, delta: float) -> jnp.ndarray:
    """Boolean per-learner violation flags of ||f_i - r||^2 <= Delta.

    If no condition is violated then the divergence provably does not
    exceed Delta (the reference-sphere argument of the geometric
    monitoring literature) — this is the O(1)-communication check that
    replaces computing delta(f) globally each round.
    """
    return _sq_dist_to(stacked, reference) > delta


def model_num_params(model: PyTree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(model))


def model_bytes(model: PyTree) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(model))


# ---------------------------------------------------------------------------
# Synchronization operators
# ---------------------------------------------------------------------------


def sigma_continuous(stacked: PyTree) -> PyTree:
    """sigma_1: replace every local model by the average."""
    m = jax.tree.leaves(stacked)[0].shape[0]
    return broadcast_model(average_model(stacked), m)


def sigma_periodic(stacked: PyTree, step: jnp.ndarray, period: int) -> PyTree:
    """sigma_b: average iff b | t, else identity."""
    do_sync = (step % period) == 0
    return lax.cond(do_sync, sigma_continuous, lambda f: f, stacked)


def sigma_dynamic(
    stacked: PyTree,
    reference: PyTree,
    delta: float,
) -> Tuple[PyTree, PyTree, jnp.ndarray]:
    """sigma_Delta with local-condition monitoring.

    Returns (new_stacked, new_reference, synced_flag).

    The decision uses the *local conditions* (distance of each learner
    to the reference model), exactly as the protocol prescribes: a
    global synchronization is triggered iff at least one local
    condition is violated.  The violation flags are per-learner scalars,
    so under GSPMD the only unconditional cross-learner communication
    is an all-reduce of one bit per round.
    """
    violated = local_conditions(stacked, reference, delta)
    any_violation = jnp.any(violated)

    def sync(_):
        fbar = average_model(stacked)
        m = jax.tree.leaves(stacked)[0].shape[0]
        return broadcast_model(fbar, m), fbar

    def keep(_):
        return stacked, reference

    new_stacked, new_reference = lax.cond(any_violation, sync, keep, None)
    return new_stacked, new_reference, any_violation


# ---------------------------------------------------------------------------
# Full protocol step
# ---------------------------------------------------------------------------


def init_state(model0: PyTree, m: int, *, stacked_reference: bool = True) -> ProtocolState:
    """Initial protocol state: all learners start at model0, r_1 = fbar_1.

    stacked_reference=True stores the reference with a learner axis so
    its sharding matches the stacked params (each device keeps only its
    slice — no replicated full model)."""
    ref = broadcast_model(model0, m) if stacked_reference else \
        jax.tree.map(lambda x: jnp.asarray(x), model0)
    return ProtocolState(
        reference=ref,
        step=jnp.zeros((), jnp.int32),
        syncs=jnp.zeros((), jnp.int32),
        bytes_sent=jnp.zeros((), jnp.float64 if jax.config.jax_enable_x64 else jnp.float32),
        last_divergence=jnp.zeros((), jnp.float32),
        delta_scale=jnp.ones((), jnp.float32),
    )


def apply_protocol(
    cfg: ProtocolConfig,
    stacked: PyTree,
    state: ProtocolState,
    *,
    bytes_per_sync: Optional[float] = None,
) -> Tuple[PyTree, ProtocolState]:
    """Apply one round of the protocol's synchronization operator.

    ``bytes_per_sync`` is the cost c(f) charged when a synchronization
    happens; by default it is the coordinator-topology cost for dense
    models: every learner uploads its model and downloads the average
    (2 * m * |model| bytes).  RKHS callers pass the support-vector
    accounting cost instead (see accounting.py).
    """
    m = jax.tree.leaves(stacked)[0].shape[0]
    step = state.step + 1
    ref_is_stacked = (
        jax.tree.leaves(state.reference)[0].ndim
        == jax.tree.leaves(stacked)[0].ndim
    )

    def _as_ref(fbar):
        return broadcast_model(fbar, m) if ref_is_stacked else fbar

    if bytes_per_sync is None:
        one = jax.tree.map(lambda x: x[0], stacked)
        # python-int cost: exact until it meets the (float32) carry
        bytes_per_sync = 2 * m * model_bytes(one)

    if cfg.kind == "none":
        div = divergence(stacked)
        new_state = state._replace(step=step, last_divergence=div)
        return stacked, new_state

    if cfg.kind == "continuous":
        div = divergence(stacked)
        out = sigma_continuous(stacked)
        new_state = ProtocolState(
            reference=_as_ref(average_model(stacked)),
            step=step,
            syncs=state.syncs + 1,
            bytes_sent=state.bytes_sent + bytes_per_sync,
            last_divergence=div,
            delta_scale=state.delta_scale,
        )
        return out, new_state

    if cfg.kind == "periodic":
        div = divergence(stacked)
        do_sync = (step % cfg.period) == 0
        out = lax.cond(do_sync, sigma_continuous, lambda f: f, stacked)
        new_state = ProtocolState(
            reference=lax.cond(
                do_sync, lambda _: _as_ref(average_model(stacked)),
                lambda _: state.reference, None
            ),
            step=step,
            syncs=state.syncs + do_sync.astype(jnp.int32),
            bytes_sent=state.bytes_sent + do_sync * bytes_per_sync,
            last_divergence=div,
            delta_scale=state.delta_scale,
        )
        return out, new_state

    # dynamic
    check_now = (step % cfg.mini_batch) == 0
    delta_eff = jnp.asarray(cfg.delta, jnp.float32)
    if cfg.delta_schedule == "sqrt":
        delta_eff = delta_eff / jnp.sqrt(step.astype(jnp.float32))
    scale = state.delta_scale
    if cfg.delta_schedule == "adaptive":
        delta_eff = delta_eff * scale
    if cfg.per_group:
        violated = group_local_conditions(stacked, state.reference, delta_eff)
    else:
        violated = local_conditions(stacked, state.reference, delta_eff)
    any_violation = jnp.logical_and(jnp.any(violated), check_now)

    def sync(_):
        fbar = average_model(stacked)
        return broadcast_model(fbar, m), _as_ref(fbar)

    def keep(_):
        return stacked, state.reference

    out, new_ref = lax.cond(any_violation, sync, keep, None)
    div = divergence(stacked)
    if cfg.delta_schedule == "adaptive":
        # multiplicative-increase on sync; geometric decay while quiet,
        # balanced so the equilibrium sync rate equals target_sync_rate.
        r = cfg.target_sync_rate
        down = cfg.adapt_up ** (-r / (1.0 - r))
        new_scale = jnp.where(any_violation, scale * cfg.adapt_up,
                              scale * down)
        new_scale = jnp.clip(new_scale, 1e-9, 1e12)
    else:
        new_scale = scale
    new_state = ProtocolState(
        reference=new_ref,
        step=step,
        syncs=state.syncs + any_violation.astype(jnp.int32),
        bytes_sent=state.bytes_sent + any_violation * bytes_per_sync,
        last_divergence=div,
        delta_scale=new_scale,
    )
    return out, new_state


def make_protocol_step(
    cfg: ProtocolConfig,
    local_update: Callable[[PyTree, Any], Tuple[PyTree, jnp.ndarray]],
) -> Callable[[PyTree, ProtocolState, Any], Tuple[PyTree, ProtocolState, jnp.ndarray]]:
    """Build a jittable full protocol round.

    ``local_update(model_i, example_i) -> (new_model_i, loss_i)`` is the
    online learning algorithm A run at each learner; it is vmapped over
    the learner axis.  The returned step function performs

        f_{t+1} = sigma(phi(f_t))

    exactly as in the paper, and also returns the per-round mean loss.
    """

    vupdate = jax.vmap(local_update)

    def step(stacked, state, batch):
        new_stacked, losses = vupdate(stacked, batch)
        out, new_state = apply_protocol(cfg, new_stacked, state)
        return out, new_state, jnp.sum(losses)

    return step
