"""The paper's efficiency criterion (Definition 1) as measurable checks.

Def. 1: a protocol Pi = (A, sigma) processing mT inputs is

  consistent  iff  L_Pi(T, m) in O(L_A(mT))          (serial loss kept)
  adaptive    iff  C_Pi(T, m) in O(m * L_A(mT))      (comm tied to loss)
  efficient   iff  consistent and adaptive.

Asymptotic statements cannot be *proved* from finite runs, but they can
be *audited*: we measure the ratios L_Pi / L_serial and
C_Pi / (m * L_serial * unit) on growing prefixes and check they stay
bounded (no upward trend).  We also verify the theorem-level inequalities
that imply the criterion:

  Thm. 4  :  L_D(T,m)  <=  L_P(T,m) + T (Delta + 2 eps^2) / gamma^2
  Prop. 6 :  V_D(T)    <=  (eta / sqrt(Delta)) * L_D(T, m)
  Thm. 7  :  C_D(T,m)  <=  V_D(T) * 2 m |Sbar_T| B_alpha + m |Sbar_T| B_x
  Prop. 5 :  C_C(T,m)  <=  2 T m |Sbar_T| B_alpha + m |Sbar_T| B_x

and the qualitative signature of efficiency: communication VANISHES
whenever the loss approaches zero (quiescence).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .accounting import ByteModel
from .simulation import SimResult


@dataclasses.dataclass
class CriterionReport:
    consistent_ratio: float        # L_Pi / L_serial   (bounded => consistent)
    adaptive_ratio: float          # C_Pi / (m L_Pi c_unit)
    sync_bound_ok: bool            # Prop. 6 inequality holds
    sync_bound_slack: float        # bound / measured (>= 1 when ok)
    comm_bound_ok: bool            # Thm. 7 inequality holds
    comm_bound_slack: float
    quiescent: bool                # no syncs in the final window
    ratios_trend: np.ndarray       # consistency ratio on growing prefixes


def check_sync_bound(
    res: SimResult, eta: float, delta: float
) -> tuple[bool, float]:
    """Prop. 6:  V_D(T) <= (eta / sqrt(Delta)) L_D(T, m)."""
    bound = (eta / np.sqrt(delta)) * res.total_loss
    v = max(res.num_syncs, 1e-12)
    return res.num_syncs <= bound + 1e-9, float(bound / v)


def check_comm_bound(
    res: SimResult,
    bm: ByteModel,
    m: int,
    union_size: int,
    eta: float,
    delta: float,
) -> tuple[bool, float]:
    """Thm. 7:  C_D <= (eta/sqrt(Delta)) L_D (2 m |Sbar_T| B_alpha)
                      + m |Sbar_T| B_x."""
    v_bound = (eta / np.sqrt(delta)) * res.total_loss
    bound = v_bound * 2 * m * union_size * bm.B_alpha + m * union_size * bm.B_x
    c = max(res.total_bytes, 1e-12)
    # integer bytes vs the (float) Thm. 7 bound, no epsilon slop: the
    # bound has orders-of-magnitude slack, a boundary tie is not real.
    return res.total_bytes <= bound, float(bound / c)


def check_continuous_comm_bound(
    total_bytes: int, bm: ByteModel, m: int, T: int, union_size: int
) -> bool:
    """Prop. 5:  C_C(T,m) <= 2 T m |Sbar_T| B_alpha + m |Sbar_T| B_x."""
    bound = 2 * T * m * union_size * bm.B_alpha + m * union_size * bm.B_x
    return total_bytes <= bound   # both sides int: exact, no slop


def quiescent(res: SimResult, window_frac: float = 0.2) -> bool:
    """True iff the run reached quiescence before the trailing window:
    no synchronization in rounds ``{w, ..., T-1}`` with
    ``w = ceil((1 - window_frac) * T)``.

    Defined through ``SimResult.quiescence_round`` so the two share
    one boundary convention: quiescent iff quiescence was observed
    (``quiescence_round is not None`` — a sync on the final round
    means it never was) and it arrived no later than the window start
    (``quiescence_round <= w``; a run with no syncs has
    ``quiescence_round == 0`` and is always quiescent).  Edge cases
    are pinned in tests/test_criterion.py."""
    T = len(res.cumulative_loss)
    w = int(np.ceil((1.0 - window_frac) * T))
    q = res.quiescence_round
    return q is not None and q <= w


def consistency_trend(res: SimResult, serial_cum_loss: np.ndarray) -> np.ndarray:
    """L_Pi(t) / L_serial(t') on growing prefixes.

    serial_cum_loss is the cumulative loss of the serial algorithm on
    the centralized stream of the same mT examples; prefix t of the
    distributed run corresponds to prefix m*t of the serial run.
    """
    T = len(res.cumulative_loss)
    m_ratio = len(serial_cum_loss) // T
    checkpoints = np.unique(np.linspace(max(T // 10, 1), T, 10).astype(int)) - 1
    out = []
    for t in checkpoints:
        s = serial_cum_loss[min((t + 1) * m_ratio - 1, len(serial_cum_loss) - 1)]
        out.append(res.cumulative_loss[t] / max(s, 1e-9))
    return np.asarray(out)


def audit(
    res: SimResult,
    serial_cum_loss: np.ndarray,
    bm: ByteModel,
    m: int,
    union_size: int,
    eta: float,
    delta: float,
) -> CriterionReport:
    trend = consistency_trend(res, serial_cum_loss)
    s_ok, s_slack = check_sync_bound(res, eta, delta)
    c_ok, c_slack = check_comm_bound(res, bm, m, union_size, eta, delta)
    c_unit = 2 * m * max(union_size, 1) * bm.B_alpha  # bytes per sync
    return CriterionReport(
        consistent_ratio=float(trend[-1]),
        # reprolint: allow[ACC01] Def. 1 ratio is a float diagnostic; the ledger itself stays int
        adaptive_ratio=float(res.total_bytes / max(m * res.total_loss * c_unit, 1e-9)),
        sync_bound_ok=s_ok,
        sync_bound_slack=s_slack,
        comm_bound_ok=c_ok,
        comm_bound_slack=c_slack,
        quiescent=quiescent(res),
        ratios_trend=trend,
    )
