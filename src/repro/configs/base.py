"""Config registry.  One module per assigned architecture; each module
defines ``CONFIG`` (exact assigned sizes, source cited) and registers it.

``get(name)`` returns the full config; ``get_smoke(name)`` the reduced
same-family variant used by CPU smoke tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS: List[str] = [
    "qwen2_vl_2b",
    "recurrentgemma_9b",
    "mamba2_130m",
    "olmoe_1b_7b",
    "whisper_large_v3",
    "granite_moe_1b_a400m",
    "qwen2_5_3b",
    "granite_8b",
    "qwen3_14b",
    "minicpm3_4b",
    "paper_kernel",   # the paper's own kernel-learner "architecture"
]

# CLI aliases (dashes as given in the assignment)
ALIASES = {
    "qwen2-vl-2b": "qwen2_vl_2b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "mamba2-130m": "mamba2_130m",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "whisper-large-v3": "whisper_large_v3",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "qwen2.5-3b": "qwen2_5_3b",
    "granite-8b": "granite_8b",
    "qwen3-14b": "qwen3_14b",
    "minicpm3-4b": "minicpm3_4b",
}

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get(name: str) -> ModelConfig:
    name = ALIASES.get(name, name)
    if name not in _REGISTRY:
        importlib.import_module(f"repro.configs.{name}")
    return _REGISTRY[name]


def get_smoke(name: str) -> ModelConfig:
    return get(name).smoke()


def all_arch_ids(include_paper: bool = False) -> List[str]:
    ids = [a for a in ARCH_IDS if a != "paper_kernel"]
    return ids + (["paper_kernel"] if include_paper else [])
