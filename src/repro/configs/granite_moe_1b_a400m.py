"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) expert d_ff=512, MoE 32 experts top-8,
vocab=49155.
"""
from repro.configs.base import register
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="granite_moe_1b_a400m",
    arch_type="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab=49155,
    n_experts=32,
    top_k=8,
    expert_ff=512,
    rope_theta=10_000.0,
    tie_embeddings=True,
    dtype="bfloat16",
))
