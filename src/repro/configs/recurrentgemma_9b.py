"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427].

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000.
Temporal mixing pattern 1:2 — (rglru, rglru, attn) repeated; local
(sliding-window 2048) attention; RG-LRU recurrence width = d_model.
"""
from repro.configs.base import register
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="recurrentgemma_9b",
    arch_type="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    layer_pattern=("rglru", "rglru", "attn"),
    window=2048,
    lru_width=4096,
    conv_width=4,
    tie_embeddings=True,
    dtype="bfloat16",
))
