"""Qwen3-14B [hf:Qwen/Qwen3-8B family] — qk_norm, GQA.

40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936.
"""
from repro.configs.base import register
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="qwen3_14b",
    arch_type="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    dtype="bfloat16",
))
