"""Whisper-large-v3 [arXiv:2212.04356] — encoder-decoder audio backbone.

32L (enc) + 32L (dec), d_model=1280 20H (kv=20) d_ff=5120 vocab=51866.
Mel+conv frontend is a STUB: input_specs provides 1500 frame embeddings.
LayerNorm + GELU (not RMS/GLU); learned decoder positions.
"""
from repro.configs.base import register
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="whisper_large_v3",
    arch_type="audio",
    n_layers=32,                  # decoder layers
    encoder_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab=51866,
    norm_kind="layernorm",
    act="gelu",
    pos_kind="learned",
    attn_kind="gqa",
    n_audio_frames=1500,
    frontend="audio_stub",
    tie_embeddings=True,          # whisper ties emb/unemb
    dtype="bfloat16",
))
