"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B] — MLA (multi-head latent attn).

62L d_model=2560 40H d_ff=6400 vocab=73448.
MLA: q_lora=768, kv_lora=256, qk_nope=64, qk_rope=32, v_dim=64 —
decode cache stores only the 256-d latent + 32-d rope key per token.
"""
from repro.configs.base import register
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="minicpm3_4b",
    arch_type="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab=73448,
    attn_kind="mla",
    mla_q_lora=768,
    mla_kv_lora=256,
    mla_rope_dim=32,
    mla_nope_dim=64,
    mla_v_dim=64,
    rope_theta=10_000.0,
    tie_embeddings=True,
    dtype="bfloat16",
))
