"""OLMoE-1B-7B [arXiv:2409.02060] — 64 experts, top-8.

16L d_model=2048 16H (kv=16) expert d_ff=1024 vocab=50304.
"""
from repro.configs.base import register
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="olmoe_1b_7b",
    arch_type="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab=50304,
    qk_norm=True,                 # OLMoE uses QK-norm
    n_experts=64,
    top_k=8,
    expert_ff=1024,
    rope_theta=10_000.0,
    dtype="bfloat16",
))
