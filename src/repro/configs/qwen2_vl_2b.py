"""Qwen2-VL-2B language backbone [arXiv:2409.12191].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.  M-RoPE with
(t, h, w) sections; dynamic-resolution ViT is a STUB — input_specs
provides patch embeddings (B, vision_tokens, d_model).
"""
from repro.configs.base import register
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="qwen2_vl_2b",
    arch_type="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),   # sums to head_dim/2 = 64
    frontend="vision_stub",
    vision_tokens=1024,
    tie_embeddings=True,           # qwen2-vl-2b ties embeddings
    dtype="bfloat16",
))
