"""Granite-8B-Code [arXiv:2405.04324] — llama-architecture, code model.

36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152.
"""
from repro.configs.base import register
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="granite_8b",
    arch_type="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=49152,
    rope_theta=10_000_000.0,
    dtype="bfloat16",
))
