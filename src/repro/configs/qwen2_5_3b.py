"""Qwen2.5-3B [hf:Qwen/Qwen2.5-0.5B family].

36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936; QKV bias.
"""
from repro.configs.base import register
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="qwen2_5_3b",
    arch_type="dense",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    head_dim=128,
    d_ff=11008,
    vocab=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    dtype="bfloat16",
))
