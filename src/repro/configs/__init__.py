from .base import ALIASES, ARCH_IDS, all_arch_ids, get, get_smoke, register

__all__ = ["ALIASES", "ARCH_IDS", "all_arch_ids", "get", "get_smoke", "register"]
