"""The paper's own 'architecture': distributed kernel online learners.

Not a transformer — this config names the RKHS learner setup used by
the paper-faithful experiments (SUSY-like classification, Fig. 1; stock
regression, Fig. 2) so it is selectable via --arch paper_kernel.
"""
import dataclasses

from repro.configs.base import _REGISTRY
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.core.rkhs import KernelSpec


@dataclasses.dataclass(frozen=True)
class PaperKernelConfig:
    name: str = "paper_kernel"
    arch_type: str = "kernel"
    learner: LearnerConfig = dataclasses.field(default_factory=lambda: LearnerConfig(
        algo="kernel_sgd", loss="hinge", eta=0.5, lam=0.01, budget=64,
        kernel=KernelSpec(kind="gaussian", gamma=0.5), dim=8,
    ))
    protocol: ProtocolConfig = dataclasses.field(default_factory=lambda: ProtocolConfig(
        kind="dynamic", delta=1.0,
    ))
    m: int = 4

    def smoke(self):
        return dataclasses.replace(self, learner=dataclasses.replace(
            self.learner, budget=16), m=2)


CONFIG = PaperKernelConfig()
_REGISTRY["paper_kernel"] = CONFIG
