"""Mamba2-130M [arXiv:2405.21060] — SSD (state-space duality).

24L d_model=768, attention-free, ssm_state=128, vocab=50280.
d_inner = 2*768 = 1536, head_dim 64 -> 24 SSD heads.
"""
from repro.configs.base import register
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="mamba2_130m",
    arch_type="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    attn_kind="none",
    pos_kind="none",
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=128,
    tie_embeddings=True,
    dtype="bfloat16",
))
