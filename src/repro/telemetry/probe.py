"""Compile counters and honest wall-clock probes (DESIGN.md Sec. 11).

Two measurement hazards this module exists to close:

- **Phantom speed.** JAX dispatch is asynchronous: timing ``fn(x)``
  without blocking measures how fast Python can *enqueue* work, not
  how fast the device computes it.  Every timing path here calls
  ``jax.block_until_ready`` on the produced values inside both the
  warmup and the timed region (``benchmarks/common.timeit`` delegates
  to the same discipline).

- **Silent recompiles.** The repo's compile-cache contracts (frozen
  hashable substrates keying ``engine._jitted``, one executable per
  (substrate, kind) sweep group — DESIGN.md Secs. 7-8) are easy to
  break invisibly: a recompile costs seconds and shows up in no test.
  :class:`CompileCounter` counts backend compiles via
  ``jax.monitoring``'s ``/jax/core/compile/backend_compile_duration``
  event — fired exactly once per XLA compilation, cache hits fire
  nothing — making "this call must not compile anything new" an
  assertable property (tests/test_telemetry.py pins the engine's
  cache-keying contract with it).

The jax.monitoring API registers listeners for the life of the
process; this module installs ONE module-level listener lazily and
dispatches to whatever counters are currently active, so counters nest
and never leak.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, Optional

import jax

#: The monitoring event jax fires once per actual XLA backend compile.
_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

_active_counters: List["CompileCounter"] = []
_listener_installed = False


def _on_event_duration(event: str, duration_secs: float, **_kw) -> None:
    if event != _COMPILE_EVENT:
        return
    for c in _active_counters:
        c.compiles += 1
        c.compile_secs += duration_secs


def _install_listener() -> None:
    global _listener_installed
    if not _listener_installed:
        jax.monitoring.register_event_duration_secs_listener(
            _on_event_duration)
        _listener_installed = True


class CompileCounter:
    """Context manager counting XLA backend compiles in its scope.

    ::

        with CompileCounter() as c:
            engine.run(cfg, pcfg, X, Y)      # may compile
            n = c.compiles
            engine.run(cfg, pcfg, X, Y)      # cache hit
        assert c.compiles == n               # no recompile

    ``compiles`` counts every executable XLA built — the jitted scan
    plus any small eager ops not yet in the process-wide cache — so
    regression tests assert *deltas* ("the second call adds zero"),
    which is exactly the cache-contract shape.  Counters may nest;
    each sees all compiles while it is active.
    """

    def __init__(self) -> None:
        self.compiles = 0
        self.compile_secs = 0.0

    def __enter__(self) -> "CompileCounter":
        _install_listener()
        _active_counters.append(self)
        return self

    def __exit__(self, *exc) -> None:
        _active_counters.remove(self)


@dataclasses.dataclass
class TimedStats:
    """What :func:`time_fn` measured."""

    us_per_call: float       # mean wall time per timed call, blocked
    iters: int
    compiles: int            # backend compiles during the TIMED loop
    warmup_compiles: int     # backend compiles during warmup
    compile_secs: float      # seconds spent compiling during warmup


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5,
            ) -> TimedStats:
    """Time ``fn(*args)``, blocking on its outputs every iteration.

    Warmup runs absorb compilation (and report it:
    ``warmup_compiles`` / ``compile_secs``); the timed loop then
    measures steady state — if anything compiles *inside* the timed
    loop, ``compiles`` is nonzero and the number is not a steady-state
    number, which callers can assert against.
    """
    with CompileCounter() as cw:
        for _ in range(max(warmup, 0)):
            jax.block_until_ready(fn(*args))
    with CompileCounter() as ct:
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(fn(*args))
        wall = time.perf_counter() - t0
    return TimedStats(
        us_per_call=wall / iters * 1e6,
        iters=iters,
        compiles=ct.compiles,
        warmup_compiles=cw.compiles,
        compile_secs=cw.compile_secs,
    )


class Wallclock:
    """Handle yielded by :func:`wallclock`; ``track`` registers device
    values the elapsed time must wait for."""

    def __init__(self) -> None:
        self.seconds: float = 0.0
        self.compiles: int = 0
        self._tracked: List[Any] = []

    def track(self, value):
        """Register a (pytree of) device value(s); returns it."""
        self._tracked.append(value)
        return value


class wallclock:
    """Timing context that always blocks on tracked device values::

        with wallclock() as w:
            out = w.track(jitted_step(carry, xs))
        w.seconds, w.compiles

    On exit the context blocks on everything ``track``ed (async
    dispatch cannot leak out of the measurement) and records backend
    compiles observed inside the region.
    """

    def __init__(self) -> None:
        self._w = Wallclock()
        self._counter = CompileCounter()

    def __enter__(self) -> Wallclock:
        self._counter.__enter__()
        self._t0 = time.perf_counter()
        return self._w

    def __exit__(self, *exc) -> Optional[bool]:
        try:
            if exc == (None, None, None):
                jax.block_until_ready(self._w._tracked)
        finally:
            self._w.seconds = time.perf_counter() - self._t0
            self._counter.__exit__(*exc)
            self._w.compiles = self._counter.compiles
        return None
