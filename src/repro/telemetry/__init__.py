"""Observability subsystem (DESIGN.md Sec. 11).

- trace:   structured span/counter/instant recorder exporting
           Chrome-trace-event JSON (Perfetto-viewable) on the
           *simulated* event clock — byte-identical under seed.
- monitor: the paper's loss-proportionality criterion as a live
           per-round check (CriterionMonitor), integer-exact against
           the Sec. 3 DeviceLedger for every driver and substrate.
- probe:   backend-compile counters on jit cache misses
           (CompileCounter) and wall-clock timers that always
           ``block_until_ready`` (time_fn / wallclock).

Everything here is host-side and opt-in: no tracer, no cost — the
jitted scan core is never touched (no traced values enter the carry).
"""
from . import monitor, probe, trace
from .monitor import (CriterionMonitor, MonitorSeries, monitor_population,
                      monitor_result, monitor_sweep, unit_bytes_of)
from .probe import CompileCounter, TimedStats, time_fn, wallclock
from .trace import (PID_MONITOR, PID_NETWORK, PID_RUNTIME, PID_SERVING,
                    TICKS_PER_UNIT, Tracer)

__all__ = [
    "monitor", "probe", "trace",
    "CriterionMonitor", "MonitorSeries", "monitor_population",
    "monitor_result", "monitor_sweep", "unit_bytes_of",
    "CompileCounter", "TimedStats", "time_fn", "wallclock",
    "PID_MONITOR", "PID_NETWORK", "PID_RUNTIME", "PID_SERVING",
    "TICKS_PER_UNIT", "Tracer",
]
