"""Live loss-proportionality monitor (DESIGN.md Sec. 11).

The paper's central quality claim (Def. 1, criterion.py) is that the
dynamic protocol keeps communication *loss-proportional*:

    adaptive  iff  C_Pi(T, m) in O(m * L_A(mT)).

``core.criterion.audit`` checks that post-hoc, once, at the end of a
run.  This module makes the criterion a *running* check: a
:class:`CriterionMonitor` consumes per-round (summed loss, bytes)
increments — from ``engine.run`` / ``engine.sweep`` outputs, the async
harness, or the serving engine, for any substrate and either topology
— and tracks the cumulative series

    bound(t) = slack * m * unit_bytes * max(L(t), loss_floor)

flagging ``violation_round``, the first round where cumulative bytes
outgrow the bound.  ``unit_bytes`` is the worst-case Sec. 3 cost of
ONE synchronization (:func:`unit_bytes_of` derives it from any
substrate for either topology), so the bound is the finite-run face of
the Thm. 7 inequality: a protocol that only syncs when loss justifies
it cannot spend more than O(1) syncs per unit of loss.

Exactness contract: the monitor's cumulative byte series is built from
the same per-round byte column the ``DeviceLedger`` produced, so it is
integer-exact against ``SimResult.cumulative_bytes`` — and therefore
against the serial oracle and the mesh-sharded engine, which all share
that ledger (tests/test_telemetry.py pins {SV, RFF, linear} x
{engine, async harness, serving}).  Losses are carried bitwise from
the source series; the monitor never recomputes them.

The monitor lives entirely on the host, post-scan: it adds ZERO
overhead to the jitted scan core (no traced values enter the carry).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from ..core import accounting
from ..core.simulation import SimResult
from ..core.substrate import Substrate, SVSubstrate, substrate_of
from .trace import PID_MONITOR, Tracer


def unit_bytes_of(learner, m: int, topology: str = "coordinator") -> int:
    """Worst-case Sec. 3 bytes of ONE synchronization of ``m`` learners
    — the per-sync unit the adaptivity bound prices loss in.

    ``learner`` is anything ``substrate_of`` resolves.  For
    ``topology="allreduce"`` this is the substrate's own host-side
    constant (``Substrate.allreduce_sync_bytes``).  For the coordinator
    topology: primal substrates (RFF / linear) have the fixed
    ``2 m |theta| B`` cost of ``accounting.sync_bytes_linear``; the SV
    substrate's cost is data-dependent, so the unit is its worst case —
    every learner uploads a full budget-tau expansion of ids novel to
    the coordinator (union m*tau), and downloads the whole union:

        m * (tau B_alpha + tau B_x)                  uploads
      + m * (m tau B_alpha) + m (m-1) tau B_x        downloads
    """
    sub = substrate_of(learner)
    if topology == "allreduce":
        return int(sub.allreduce_sync_bytes(m))
    if topology != "coordinator":
        raise ValueError(f"unknown topology {topology!r}")
    if isinstance(sub, SVSubstrate):
        bm = accounting.ByteModel(dim=sub.input_dim)
        tau = int(sub.lcfg.budget)
        up = m * tau * (bm.B_alpha + bm.B_x)
        down = m * m * tau * bm.B_alpha + m * (m - 1) * tau * bm.B_x
        return up + down
    return int(accounting.sync_bytes_linear(sub.num_params, m))


@dataclasses.dataclass
class MonitorSeries:
    """The monitor's cumulative tracks, one entry per observed round."""

    cumulative_loss: np.ndarray    # (T,) float64, bitwise from source
    cumulative_bytes: np.ndarray   # (T,) int64, integer-exact vs ledger
    bound: np.ndarray              # (T,) float64 allowed bytes
    ratio: np.ndarray              # (T,) bytes / bound
    violation_round: Optional[int]

    @property
    def ok(self) -> bool:
        return self.violation_round is None

    def __len__(self) -> int:
        return len(self.cumulative_loss)


class CriterionMonitor:
    """Running check of loss-proportional communication.

    Feed per-round increments with :meth:`observe` (the async harness
    and serving engine do this as rounds complete) or whole result
    series with :meth:`observe_result`.  ``slack`` absorbs the
    constant of the O(.) statement; ``loss_floor`` keeps the bound
    positive through the first rounds, where an immediate sync (one
    unit) must not count as a violation of a still-zero loss.
    """

    def __init__(self, m: int, unit_bytes: int, *,
                 slack: float = 2.0, loss_floor: float = 1.0):
        if m < 1:
            raise ValueError(f"need m >= 1, got {m}")
        if unit_bytes <= 0:
            raise ValueError(f"unit_bytes must be > 0, got {unit_bytes}")
        if slack <= 0 or loss_floor <= 0:
            raise ValueError("slack and loss_floor must be > 0")
        self.m = int(m)
        self.unit_bytes = int(unit_bytes)
        self.slack = float(slack)
        self.loss_floor = float(loss_floor)
        self._cum_loss = 0.0
        self._cum_bytes = 0
        self._loss: List[float] = []
        self._bytes: List[int] = []
        self._bound: List[float] = []
        self.violation_round: Optional[int] = None

    @classmethod
    def for_substrate(cls, learner, m: int, *,
                      topology: str = "coordinator",
                      **kw) -> "CriterionMonitor":
        """Monitor with the per-sync unit derived from the substrate
        (works for SV / RFF / linear and both topologies)."""
        return cls(m, unit_bytes_of(learner, m, topology), **kw)

    # -- feeding -------------------------------------------------------------

    def observe(self, loss_sum: float, nbytes: int) -> bool:
        """One protocol round: summed-over-learners loss + the round's
        Sec. 3 bytes.  Returns True while the bound holds; records the
        first violating round in ``violation_round``."""
        t = len(self._loss)
        self._cum_loss += float(loss_sum)
        self._cum_bytes += int(nbytes)
        bound = (self.slack * self.m * self.unit_bytes
                 * max(self._cum_loss, self.loss_floor))
        self._loss.append(self._cum_loss)
        self._bytes.append(self._cum_bytes)
        self._bound.append(bound)
        ok = self._cum_bytes <= bound
        if not ok and self.violation_round is None:
            self.violation_round = t
        return ok

    def observe_result(self, res: SimResult) -> "CriterionMonitor":
        """Feed a whole result's per-round series (any driver: the
        scan engine, the async harness, or ``ServeResult.sim``).

        The cumulative series are adopted from the source bitwise /
        integer-exactly — never re-accumulated from increments, which
        would reintroduce float re-summation drift on the loss track.
        """
        if self.rounds:
            raise ValueError("observe_result needs a fresh monitor")
        self._loss = [float(v) for v in res.cumulative_loss]
        self._bytes = [int(v) for v in res.cumulative_bytes]
        self._cum_loss = self._loss[-1] if self._loss else 0.0
        self._cum_bytes = self._bytes[-1] if self._bytes else 0
        self._refresh_bounds()
        return self

    def _refresh_bounds(self) -> None:
        self._bound = [
            self.slack * self.m * self.unit_bytes
            * max(lo, self.loss_floor) for lo in self._loss]
        self.violation_round = None
        for t, (b, bd) in enumerate(zip(self._bytes, self._bound)):
            if b > bd:
                self.violation_round = t
                break

    # -- reading -------------------------------------------------------------

    @property
    def rounds(self) -> int:
        return len(self._loss)

    @property
    def ok(self) -> bool:
        return self.violation_round is None

    def series(self) -> MonitorSeries:
        bound = np.asarray(self._bound, np.float64)
        nbytes = np.asarray(self._bytes, np.int64)
        return MonitorSeries(
            cumulative_loss=np.asarray(self._loss, np.float64),
            cumulative_bytes=nbytes,
            bound=bound,
            # reprolint: allow[ACC01] Def. 1 ratio track is a float diagnostic; observe() compares exact ints
            ratio=nbytes / np.maximum(bound, 1e-12),
            violation_round=self.violation_round,
        )

    def emit(self, tracer: Tracer, *, name: str = "criterion") -> None:
        """Write the monitor's tracks into a trace: two counter tracks
        (bytes vs bound, cumulative loss) on round-index time, plus an
        instant at the violation round if there is one."""
        for t in range(self.rounds):
            tracer.counter(f"{name}/bytes", float(t),
                           {"cumulative": int(self._bytes[t]),
                            "bound": float(self._bound[t])},
                           pid=PID_MONITOR)
            tracer.counter(f"{name}/loss", float(t),
                           {"cumulative": float(self._loss[t])},
                           pid=PID_MONITOR)
        if self.violation_round is not None:
            t = self.violation_round
            tracer.instant(f"{name}/violation", float(t), pid=PID_MONITOR,
                           args={"round": t,
                                 "bytes": int(self._bytes[t]),
                                 "bound": float(self._bound[t])})


def monitor_result(res: SimResult, learner, m: int, *,
                   topology: str = "coordinator",
                   **kw) -> CriterionMonitor:
    """One-call monitor over a finished run (``engine.run``, the async
    harness's ``AsyncSimResult``, or ``ServeResult.sim``)."""
    mon = CriterionMonitor.for_substrate(learner, m, topology=topology, **kw)
    return mon.observe_result(res)


def monitor_sweep(sweep_result, learner, m: int, *,
                  topology: str = "coordinator",
                  **kw) -> Sequence[CriterionMonitor]:
    """Per-config monitors over an ``engine.sweep`` result (uses its
    ``__getitem__`` materialization, so the byte series are the same
    int64 ledger columns the SimResult view exposes)."""
    return [monitor_result(sweep_result[i], learner, m,
                           topology=topology, **kw)
            for i in range(len(sweep_result))]


def monitor_population(pres, learner, *,
                       topology: str = "coordinator",
                       **kw) -> CriterionMonitor:
    """Def. 1 monitor over a population run (DESIGN.md Sec. 15).

    ``pres`` is a ``population.sim.PopulationResult``.  Under partial
    participation only the sampled cohort communicates, so the bound is
    priced at the LARGEST cohort the run ever synchronized — ``m`` and
    ``unit_bytes`` both evaluate at ``max_t |cohort_t|``, not at
    ``m_total`` — and the byte series fed to the monitor is the device
    ledger's cohort-only column, integer-exact (the engine charges
    nothing for detached learners; tests/test_population.py pins the
    column against the set-algebra oracle).  An idle population (every
    round empty) monitors trivially at cohort 1.
    """
    m_eff = max(1, int(np.max(pres.cohort_sizes)))
    # a 1-learner allreduce ring moves 0 bytes; the monitor needs a
    # positive unit, and such a run cannot communicate anyway
    unit = max(1, unit_bytes_of(learner, m_eff, topology))
    mon = CriterionMonitor(m_eff, unit, **kw)
    return mon.observe_result(pres.sim)
