"""Structured event-clock tracing (DESIGN.md Sec. 11).

A :class:`Tracer` records spans, counters and instants and exports
them in the Chrome trace-event JSON format, so any run of the async
runtime or the serving engine can be dropped into Perfetto
(https://ui.perfetto.dev) and read like a real system trace — learner
rounds as thread slices, messages as network spans carrying their
Sec. 3 byte annotations, synchronization episodes as coordinator
spans, queue depths and bucket occupancy as counter tracks.

Two properties the rest of the repo relies on:

- **Simulated time only.**  Every timestamp is a value of the
  discrete-event clock (``runtime.clock.Clock.now``) or a round index
  — never the host's wall clock — so a trace is a pure function of the
  run's seeds: identical configuration => byte-identical trace JSON
  (tests/test_telemetry.py extends
  tests/test_runtime.py::test_determinism_under_seed to the trace
  layer).  One simulated time unit maps to ``TICKS_PER_UNIT``
  microseconds of trace time.

- **Zero cost when absent.**  Nothing constructs a Tracer unless the
  caller passes one; every instrumentation site is guarded by
  ``if tracer is not None`` on the host, and the jitted scan core
  (core/engine.py) is not touched at all — telemetry never adds
  traced values to the scan carry (the live monitor,
  telemetry/monitor.py, consumes the scan's *outputs*).

Export format: ``{"traceEvents": [...], "displayTimeUnit": "ms"}``
with the standard phases — ``X`` (complete span with ``dur``), ``C``
(counter), ``i`` (instant), ``M`` (process/thread name metadata).
``pid`` groups events into named tracks (:data:`PID_RUNTIME`,
:data:`PID_NETWORK`, :data:`PID_SERVING`); ``tid`` lanes within a pid
are handed out by :meth:`Tracer.tid` in first-use order (deterministic
because event order is).

The serving engine (DESIGN.md Secs. 10, 13) uses four PID_SERVING
lanes: ``requests`` (enqueue instants + per-request spans),
``predict`` (padded-batch launch spans, one per ``predict/bucketN``),
``protocol`` (round instants + sync/transfer spans), and
``admission`` (shed/defer instants from the bounded-queue admission
controller) — plus the ``serve/queue_depth``, ``serve/bucket_occupancy``
and ``serve/slots_in_flight`` counter tracks.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Tuple

#: One simulated time unit (`Clock.now == 1.0`) = 1e6 trace
#: microseconds, so `base_compute = 1.0` rounds render as 1 s slices.
TICKS_PER_UNIT = 1_000_000.0

# Process-track ids.  Keep these stable: bench tooling and tests match
# on them, and a renumbering would silently re-lane existing traces.
PID_RUNTIME = 1    # learner rounds + coordinator episodes (nodes.py)
PID_NETWORK = 2    # message spans with Sec. 3 byte args (transport.py)
PID_SERVING = 3    # request/bucket/round spans (serving/engine.py)
PID_MONITOR = 4    # loss-proportionality counter tracks (monitor.py)

_PID_NAMES = {
    PID_RUNTIME: "runtime",
    PID_NETWORK: "network",
    PID_SERVING: "serving",
    PID_MONITOR: "monitor",
}


class Tracer:
    """Append-only recorder of Chrome trace events on simulated time.

    All ``ts`` / ``dur`` arguments are in simulated clock units (or
    round indices, for clockless sources like ``engine.run`` series);
    the tracer scales them by :data:`TICKS_PER_UNIT` at record time.
    ``args`` values must be JSON-serializable scalars — keep them to
    ints, floats, bools and short strings, they are what Perfetto
    shows in the selection panel.
    """

    def __init__(self) -> None:
        self._events: List[Dict[str, Any]] = []
        self._tids: Dict[Tuple[int, str], int] = {}
        self._named_pids: set = set()

    # -- track naming --------------------------------------------------------

    def _ensure_pid(self, pid: int) -> None:
        if pid in self._named_pids:
            return
        self._named_pids.add(pid)
        name = _PID_NAMES.get(pid, f"pid{pid}")
        self._events.append({"ph": "M", "name": "process_name",
                             "pid": pid, "tid": 0,
                             "args": {"name": name}})

    def tid(self, pid: int, lane: str) -> int:
        """Stable integer lane id for a named lane within ``pid``;
        assigns ids in first-use order and emits the thread-name
        metadata event on first use."""
        key = (pid, lane)
        if key not in self._tids:
            self._ensure_pid(pid)
            t = len([1 for (p, _) in self._tids if p == pid])
            self._tids[key] = t
            self._events.append({"ph": "M", "name": "thread_name",
                                 "pid": pid, "tid": t,
                                 "args": {"name": lane}})
        return self._tids[key]

    # -- recording -----------------------------------------------------------

    def complete(self, name: str, ts: float, dur: float, *,
                 pid: int = PID_RUNTIME, tid: int = 0,
                 args: Optional[Dict[str, Any]] = None) -> None:
        """A span [ts, ts + dur) in simulated time (phase ``X``)."""
        self._ensure_pid(pid)
        ev: Dict[str, Any] = {
            "ph": "X", "name": name, "pid": pid, "tid": tid,
            "ts": ts * TICKS_PER_UNIT, "dur": dur * TICKS_PER_UNIT}
        if args:
            ev["args"] = args
        self._events.append(ev)

    def instant(self, name: str, ts: float, *,
                pid: int = PID_RUNTIME, tid: int = 0,
                args: Optional[Dict[str, Any]] = None) -> None:
        """A point event (phase ``i``, thread scope)."""
        self._ensure_pid(pid)
        ev: Dict[str, Any] = {
            "ph": "i", "name": name, "pid": pid, "tid": tid,
            "ts": ts * TICKS_PER_UNIT, "s": "t"}
        if args:
            ev["args"] = args
        self._events.append(ev)

    def counter(self, name: str, ts: float, values: Dict[str, float], *,
                pid: int = PID_RUNTIME, tid: int = 0) -> None:
        """One sample on a counter track (phase ``C``); ``values`` maps
        series name -> numeric sample, all plotted on one track.
        ``tid`` places the track on a named lane (``Tracer.tid``) so
        per-lane counters — e.g. the serving scheduler's per-shard
        slot occupancy — group under their lane instead of lane 0."""
        self._ensure_pid(pid)
        self._events.append({
            "ph": "C", "name": name, "pid": pid, "tid": tid,
            "ts": ts * TICKS_PER_UNIT, "args": dict(values)})

    # -- export --------------------------------------------------------------

    @property
    def events(self) -> List[Dict[str, Any]]:
        return self._events

    def __len__(self) -> int:
        return len(self._events)

    def to_dict(self) -> Dict[str, Any]:
        return {"traceEvents": self._events, "displayTimeUnit": "ms"}

    def to_json(self) -> str:
        """Canonical serialization: sorted keys, fixed separators — the
        byte-identical-under-seed contract depends on this being a pure
        function of the recorded event sequence."""
        return json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"))

    def save(self, path) -> None:
        """Write Perfetto-loadable JSON to ``path``."""
        with open(path, "w", encoding="utf-8") as f:
            f.write(self.to_json())
