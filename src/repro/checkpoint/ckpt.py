"""Pytree checkpointing (msgpack + numpy, no external deps).

Saves/restores arbitrary pytrees of arrays (model params, optimizer
state, protocol state incl. the reference model) with dtype/shape
preservation.  Layout: one ``.ckpt`` msgpack file per step +
``latest`` pointer, atomic rename on write.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

PyTree = Any


def _np_dtype(name: str):
    """Resolve a dtype name, including ml_dtypes extras (bfloat16)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _encode_leaf(x):
    arr = np.asarray(x)
    return {
        b"__nd__": True,
        b"dtype": arr.dtype.name.encode(),
        b"shape": list(arr.shape),
        b"data": arr.tobytes(),
    }


def _is_encoded(obj) -> bool:
    return isinstance(obj, dict) and obj.get(b"__nd__") is True


def _decode_leaf(obj):
    name = obj[b"dtype"]
    if isinstance(name, bytes):
        name = name.decode()
    arr = np.frombuffer(obj[b"data"], dtype=_np_dtype(name))
    return jnp.asarray(arr.reshape(obj[b"shape"]))


def save(path: str, tree: PyTree) -> None:
    leaves, treedef = jax.tree.flatten(tree)
    payload = {
        b"treedef": str(treedef).encode(),
        b"leaves": [_encode_leaf(l) for l in leaves],
        b"structure": _structure_of(tree),
    }
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def _structure_of(tree: PyTree):
    """Serializable mirror of the pytree with leaves replaced by 0."""
    if isinstance(tree, dict):
        return {k: _structure_of(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        kind = "tuple" if isinstance(tree, tuple) else "list"
        named = type(tree).__name__ if hasattr(tree, "_fields") else kind
        return {"__seq__": named, "items": [_structure_of(v) for v in tree]}
    return 0


def restore(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (shape/dtype checked)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=True)
    leaves = [_decode_leaf(l) for l in payload[b"leaves"]]
    like_leaves, treedef = jax.tree.flatten(like)
    if len(leaves) != len(like_leaves):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, expected {len(like_leaves)}")
    for got, want in zip(leaves, like_leaves):
        if tuple(got.shape) != tuple(jnp.shape(want)):
            raise ValueError(f"shape mismatch: {got.shape} vs {jnp.shape(want)}")
    return jax.tree.unflatten(treedef, leaves)


def save_step(ckpt_dir: str, step: int, tree: PyTree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    path = os.path.join(ckpt_dir, f"step_{step:08d}.ckpt")
    save(path, tree)
    with open(os.path.join(ckpt_dir, "latest.tmp"), "w") as f:
        f.write(os.path.basename(path))
    os.replace(os.path.join(ckpt_dir, "latest.tmp"),
               os.path.join(ckpt_dir, "latest"))
    return path


def latest_step(ckpt_dir: str):
    p = os.path.join(ckpt_dir, "latest")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return os.path.join(ckpt_dir, f.read().strip())
