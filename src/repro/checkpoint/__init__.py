from .ckpt import latest_step, restore, save, save_step

__all__ = ["latest_step", "restore", "save", "save_step"]
