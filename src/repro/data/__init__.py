from . import streams
from .streams import (
    drifting_stream,
    separable_stream,
    stock_stream,
    susy_stream,
    token_stream,
)

__all__ = [
    "streams", "susy_stream", "separable_stream", "drifting_stream",
    "stock_stream", "token_stream",
]
