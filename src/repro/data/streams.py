"""Synthetic data streams for the paper's experiments.

The paper evaluates on (a) the UCI SUSY classification task and (b) a
stock-price nowcasting task [9].  Neither dataset ships offline, so we
generate distribution-matched synthetics:

- ``susy_stream``: binary classification with a non-linear
  (radial/XOR-ish) Bayes boundary in d=8 'low-level' features — linear
  models plateau at high error while Gaussian-kernel learners can
  approach zero loss, reproducing the qualitative gap of Fig. 1.
- ``stock_stream``: auto-regressive multi-asset price process with a
  shared market factor and a *non-linear* response of the target stock
  to its correlated features — reproducing the Fig. 2 setting where
  kernel models beat linear by an order of magnitude.
- ``drifting_stream``: concept drift (rotating boundary) to exercise
  re-synchronization after quiescence.
- ``token_stream``: integer token batches for the LM-scale protocol.

All generators return (X, Y) shaped (T, m, d) / (T, m): T rounds for m
learners, drawn i.i.d. from the same time-variant distribution P_t as
the paper assumes.
"""
from __future__ import annotations

import numpy as np


def susy_stream(T: int, m: int, d: int = 8, seed: int = 0, noise: float = 0.05):
    """Non-linearly separable binary stream (SUSY-like)."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(T, m, d)).astype(np.float32)
    # radial boundary in the first 4 dims + XOR term: non-linear Bayes rule
    r = np.sum(X[..., :4] ** 2, axis=-1)
    xor = X[..., 4] * X[..., 5]
    score = (r - 4.0) + 2.0 * xor
    flip = rng.random((T, m)) < noise
    Y = np.where((score > 0) ^ flip, 1.0, -1.0).astype(np.float32)
    return X, Y


def separable_stream(T: int, m: int, d: int = 8, seed: int = 0, margin: float = 0.5):
    """Linearly separable stream — lets linear learners reach zero loss,
    used to demonstrate quiescence of the dynamic protocol."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(d,)); w /= np.linalg.norm(w)
    X = rng.normal(size=(T, m, d)).astype(np.float32)
    s = X @ w
    # enforce a margin by pushing points away from the boundary
    X += (np.sign(s) * margin)[..., None] * w
    Y = np.sign(X @ w).astype(np.float32)
    return X, Y


def drifting_stream(T: int, m: int, d: int = 8, seed: int = 0,
                    drift_every: int = 500, angle: float = 0.5):
    """Rotating linear boundary: concept drift forces re-synchronization."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(T, m, d)).astype(np.float32)
    Y = np.zeros((T, m), np.float32)
    w = rng.normal(size=(d,)); w /= np.linalg.norm(w)
    for t in range(T):
        if t > 0 and t % drift_every == 0:
            # rotate w in a random plane
            v = rng.normal(size=(d,)); v -= (v @ w) * w; v /= np.linalg.norm(v)
            w = np.cos(angle) * w + np.sin(angle) * v
        Y[t] = np.sign(X[t] @ w)
    return X, Y


def stock_stream(T: int, m: int, d: int = 10, seed: int = 0):
    """Multi-asset AR(1) market with a non-linear target response.

    Features: d correlated asset returns (shared market factor).
    Target:   next-step return of the target stock =
              sin(2 f0) * f1 + 0.3 tanh(2 * factor) + noise —
              non-linear in the features, so linear regression suffers
              persistent loss while a Gaussian-kernel learner fits it.
    """
    rng = np.random.default_rng(seed)
    X = np.zeros((T, m, d), np.float32)
    Y = np.zeros((T, m), np.float32)
    market = np.zeros((m,), np.float32)
    prev = rng.normal(size=(m, d)).astype(np.float32) * 0.1
    for t in range(T):
        market = 0.9 * market + 0.1 * rng.normal(size=(m,)).astype(np.float32)
        eps = rng.normal(size=(m, d)).astype(np.float32) * 0.3
        feats = 0.5 * prev + market[:, None] + eps
        X[t] = feats
        Y[t] = (
            np.sin(2.0 * feats[:, 0]) * feats[:, 1]
            + 0.3 * np.tanh(2.0 * market)
            + 0.05 * rng.normal(size=(m,)).astype(np.float32)
        )
        prev = feats
    return X, Y


def token_stream(T: int, batch: int, seq_len: int, vocab: int, seed: int = 0):
    """Integer token batches for LM-scale protocol training (synthetic
    Zipfian unigram text with local repetition structure)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1)
    p = 1.0 / ranks
    p /= p.sum()
    for _ in range(T):
        toks = rng.choice(vocab, size=(batch, seq_len + 1), p=p).astype(np.int32)
        # inject copy structure so there is something to learn
        half = seq_len // 2
        toks[:, half + 1 : 2 * half + 1] = toks[:, 1 : half + 1]
        yield toks[:, :-1], toks[:, 1:]
