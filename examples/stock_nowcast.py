"""Fig. 2 reproduction: 32 learners nowcast a stock's next-step return.

Linear vs Gaussian-kernel learners (budget 50, truncation — the paper's
setup), periodic vs dynamic synchronization.

    PYTHONPATH=src python examples/stock_nowcast.py
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import simulation
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.core.rkhs import KernelSpec
from repro.data import stock_stream


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=1200)
    ap.add_argument("--learners", type=int, default=32)
    args = ap.parse_args()

    T, m, d = args.rounds, args.learners, 10
    X, Y = stock_stream(T=T, m=m, d=d, seed=0)

    linear = LearnerConfig(algo="linear_sgd", loss="squared", eta=0.05,
                           lam=1e-4, dim=d)
    kernel = LearnerConfig(algo="kernel_sgd", loss="squared", eta=0.5,
                           lam=1e-3, budget=100,
                           kernel=KernelSpec("gaussian", gamma=0.2), dim=d)

    print(f"stock stream: {m} learners x {T} rounds")
    print(f"{'system':24s} {'cum.sq.err':>11s} {'cum.KB':>10s} {'syncs':>6s}")
    res = {}
    for name, fam, lcfg, pcfg in [
        ("linear  x periodic(10)", "lin", linear, ProtocolConfig(kind="periodic", period=10)),
        ("kernel  x periodic(10)", "ker", kernel, ProtocolConfig(kind="periodic", period=10)),
        ("kernel  x dynamic     ", "ker", kernel, ProtocolConfig(kind="dynamic", delta=2.0)),
    ]:
        run = (simulation.run_linear_simulation if fam == "lin"
               else simulation.run_kernel_simulation)
        r = run(lcfg, pcfg, X, Y)
        res[name] = r
        print(f"{name:24s} {r.cumulative_errors[-1]:11.1f} "
              f"{r.total_bytes/1024:10.1f} {r.num_syncs:6d}")

    err_red = (res["linear  x periodic(10)"].cumulative_errors[-1]
               / res["kernel  x dynamic     "].cumulative_errors[-1])
    comm_red = (res["kernel  x periodic(10)"].total_bytes
                / max(res["kernel  x dynamic     "].total_bytes, 1))
    print(f"\nkernel+dynamic vs linear: error reduced {err_red:.1f}x "
          f"(paper: ~18x on real data)")
    print(f"dynamic vs periodic kernel: communication reduced {comm_red:.1f}x "
          f"(paper: ~2433x on real data)")


if __name__ == "__main__":
    main()
