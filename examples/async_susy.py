"""Sync vs. async on the SUSY-like stream: what latency and stragglers
do to the dynamic protocol, and what staleness weighting buys back.

    PYTHONPATH=src python examples/async_susy.py [--rounds 600]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import simulation
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.core.rkhs import KernelSpec
from repro.data import susy_stream
from repro.runtime import (AsyncProtocolConfig, SystemConfig,
                           run_async_simulation)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=600)
    ap.add_argument("--learners", type=int, default=4)
    args = ap.parse_args()

    T, m, d = args.rounds, args.learners, 8
    X, Y = susy_stream(T=T, m=m, d=d, seed=0)
    lcfg = LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.5, lam=0.01,
                         budget=64, kernel=KernelSpec("gaussian", gamma=0.3),
                         dim=d)
    delta = 2.0

    print(f"SUSY-like stream: {m} kernel learners x {T} rounds "
          f"(dynamic protocol, Delta={delta})\n")
    hdr = (f"{'system':34s} {'cum.err':>8s} {'KB':>8s} {'syncs':>6s} "
           f"{'sim-wall':>9s} {'barrier':>8s} {'speedup':>8s}")
    print(hdr)
    print("-" * len(hdr))

    res = simulation.run_kernel_simulation(
        lcfg, ProtocolConfig(kind="dynamic", delta=delta), X, Y)
    print(f"{'serial lockstep (paper driver)':34s} "
          f"{int(res.cumulative_errors[-1]):8d} {res.total_bytes/1024:8.1f} "
          f"{res.num_syncs:6d} {'-':>9s} {'-':>8s} {'-':>8s}")

    wan = dict(base_latency=0.5, latency_jitter=0.5, bandwidth=1e5)
    systems = [
        ("async / ideal network (= serial)",
         AsyncProtocolConfig(kind="dynamic", delta=delta),
         SystemConfig(seed=0)),
        ("async / WAN, constant weights",
         AsyncProtocolConfig(kind="dynamic", delta=delta, alpha=0.6,
                             staleness="constant", agg_window=1.0),
         SystemConfig(seed=0, compute_jitter=0.3, straggler_frac=0.25,
                      straggler_mult=4.0, straggler_prob=0.3, **wan)),
        ("async / WAN, poly staleness",
         AsyncProtocolConfig(kind="dynamic", delta=delta, alpha=0.6,
                             staleness="poly", stale_a=0.5, agg_window=1.0),
         SystemConfig(seed=0, compute_jitter=0.3, straggler_frac=0.25,
                      straggler_mult=4.0, straggler_prob=0.3, **wan)),
        ("async / WAN + 5% message loss",
         AsyncProtocolConfig(kind="dynamic", delta=delta, alpha=0.6,
                             staleness="poly", stale_a=0.5, agg_window=1.0),
         SystemConfig(seed=0, compute_jitter=0.3, straggler_frac=0.25,
                      straggler_mult=4.0, straggler_prob=0.3,
                      drop_prob=0.05, **wan)),
    ]
    for name, acfg, sc in systems:
        r = run_async_simulation(lcfg, acfg, X, Y, sys_cfg=sc,
                                 record_divergence=False)
        print(f"{name:34s} {int(r.cumulative_errors[-1]):8d} "
              f"{r.total_bytes/1024:8.1f} {r.num_syncs:6d} "
              f"{r.wall_clock:9.1f} {r.barrier_wall_clock:8.1f} "
              f"{r.speedup_vs_barrier:8.2f}")

    print("\nThe ideal-network async run reproduces the serial ledger "
          "byte-for-byte; under WAN latency + intermittent stragglers the "
          "event-driven runtime finishes the same streams faster than any "
          "lockstep schedule on the identical compute draws, and staleness "
          "weighting keeps stale straggler models from dragging the "
          "reference around.")


if __name__ == "__main__":
    main()
