"""Quickstart: distributed online learning with kernels in ~40 lines.

Four learners classify a non-linear stream; the dynamic protocol keeps
them in sync only when their models drift apart.  Each experiment runs
as one compiled lax.scan (core/engine.py, DESIGN.md Sec. 7).

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import engine
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.core.rkhs import KernelSpec
from repro.data import susy_stream

# one data stream per learner: 4 learners x 500 rounds
X, Y = susy_stream(T=500, m=4, d=8, seed=0)

learner = LearnerConfig(
    algo="kernel_sgd", loss="hinge", eta=0.5, lam=0.01,
    budget=128, kernel=KernelSpec(kind="gaussian", gamma=0.3), dim=8,
)

print(f"{'protocol':14s} {'errors':>7s} {'syncs':>6s} {'kilobytes':>10s}")
for kind, kwargs in [("none", {}), ("continuous", {}),
                     ("periodic", {"period": 10}),
                     ("dynamic", {"delta": 2.0})]:
    res = engine.run(learner, ProtocolConfig(kind=kind, **kwargs), X, Y)
    print(f"{kind:14s} {int(res.cumulative_errors[-1]):7d} "
          f"{res.num_syncs:6d} {res.total_bytes / 1024:10.1f}")

print("\nThe dynamic protocol approaches the continuous protocol's "
      "accuracy at a fraction of the communication (paper, Fig. 1).")
