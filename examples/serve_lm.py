"""Serve a small model with batched requests through the serving engine
(deliverable (b), serving flavour).

    PYTHONPATH=src python examples/serve_lm.py --requests 6
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get
from repro.models import build, count_params
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_5_3b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = get(args.arch).smoke()
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    print(f"serving {args.arch} (smoke variant, "
          f"{count_params(params)/1e6:.1f}M params), batch={args.batch}")

    engine = ServingEngine(cfg, params, batch_size=args.batch, max_len=128)
    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=i,
                prompt=rng.integers(0, cfg.vocab, (4 + 2 * i,)).astype(np.int32),
                max_new_tokens=args.new_tokens)
        for i in range(args.requests)
    ]
    t0 = time.time()
    done = engine.run(reqs)
    dt = time.time() - t0
    total_tokens = sum(len(r.output) for r in done)
    for r in sorted(done, key=lambda r: r.uid):
        print(f"req {r.uid}: prompt_len={len(r.prompt):2d} -> "
              f"{r.output[:8]}{'...' if len(r.output) > 8 else ''} "
              f"(batch latency {r.latency_s:.2f}s)")
    print(f"\n{len(done)} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s on CPU)")


if __name__ == "__main__":
    main()
