"""Fig. 1 reproduction: error/communication trade-off and
communication-over-time, with and without model compression.

    PYTHONPATH=src python examples/susy_distributed.py [--rounds 1000]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import simulation
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.core.rkhs import KernelSpec
from repro.data import susy_stream


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=1000)
    ap.add_argument("--learners", type=int, default=4)
    args = ap.parse_args()

    T, m, d = args.rounds, args.learners, 8
    X, Y = susy_stream(T=T, m=m, d=d, seed=0)

    linear = LearnerConfig(algo="linear_sgd", loss="hinge", eta=0.1,
                           lam=0.001, dim=d)
    kernel = LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.5,
                           lam=0.01, budget=256,
                           kernel=KernelSpec("gaussian", gamma=0.3), dim=d)
    kernel_small = kernel.__class__(**{**kernel.__dict__, "budget": 48})

    systems = [
        ("linear  x continuous", "linear", linear, ProtocolConfig(kind="continuous")),
        ("linear  x dynamic   ", "linear", linear, ProtocolConfig(kind="dynamic", delta=0.1)),
        ("kernel  x continuous", "kernel", kernel, ProtocolConfig(kind="continuous")),
        ("kernel  x dynamic   ", "kernel", kernel, ProtocolConfig(kind="dynamic", delta=2.0)),
        ("kernel+compress dyn ", "kernel", kernel_small, ProtocolConfig(kind="dynamic", delta=2.0)),
    ]

    print(f"SUSY-like stream: {m} learners x {T} rounds")
    print(f"{'system':22s} {'cum.error':>9s} {'cum.KB':>10s} {'syncs':>6s} "
          f"{'quiescent@':>10s}")
    curves = {}
    for name, fam, lcfg, pcfg in systems:
        run = (simulation.run_linear_simulation if fam == "linear"
               else simulation.run_kernel_simulation)
        res = run(lcfg, pcfg, X, Y)
        curves[name] = res
        q = res.quiescence_round
        print(f"{name:22s} {int(res.cumulative_errors[-1]):9d} "
              f"{res.total_bytes / 1024:10.1f} {res.num_syncs:6d} "
              f"{str(q) if q is not None else '-':>10s}")

    # ASCII communication-over-time plot (Fig. 1b)
    print("\ncumulative communication over time (KB):")
    width = 60
    for name in ("kernel  x continuous", "kernel  x dynamic   ",
                 "kernel+compress dyn "):
        c = curves[name].cumulative_bytes / 1024
        pts = c[np.linspace(0, len(c) - 1, width).astype(int)]
        peak = max(1.0, curves["kernel  x continuous"].cumulative_bytes[-1] / 1024)
        bar = "".join("#" if p > peak * (i + 1) / width else "."
                      for i, p in enumerate(pts))
        print(f"{name:22s} |{bar}| {c[-1]:.0f}")


if __name__ == "__main__":
    main()
