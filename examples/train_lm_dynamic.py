"""End-to-end driver: train a ~100M-parameter LM with the dynamic
protocol for a few hundred steps (deliverable (b)).

The model is the assigned mamba2-130m architecture (full width, reduced
depth by default so a CPU run finishes in minutes; pass --full-depth
for all 24 layers).  Four learners run local SGD on their own token
streams; the dynamic operator synchronizes them only on local-condition
violations.  Checkpoints + protocol state are saved periodically.

    PYTHONPATH=src python examples/train_lm_dynamic.py --steps 300
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import get
from repro.core.protocol import ProtocolConfig
from repro.launch.train import init_train_state, make_train_step
from repro.models import count_params
from repro.optim import OptimizerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--learners", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--delta", type=float, default=5e-3)
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--full-depth", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = get("mamba2_130m")
    if not args.full_depth:
        cfg = cfg.with_(n_layers=4)           # ~35M params, CPU-friendly
    m = args.learners

    pcfg = ProtocolConfig(kind="dynamic", delta=args.delta)
    opt_cfg = OptimizerConfig(kind="sgd", lr=args.lr, momentum=0.9,
                              grad_clip=1.0)
    state = init_train_state(jax.random.PRNGKey(0), cfg, m, opt_cfg)
    n = count_params(jax.tree.map(lambda x: x[0], state.params))
    print(f"arch=mamba2_130m layers={cfg.n_layers} params={n/1e6:.1f}M "
          f"learners={m} protocol=dynamic(delta={args.delta})")

    step_fn = jax.jit(make_train_step(cfg, pcfg, opt_cfg))
    rng = np.random.default_rng(0)

    t0 = time.time()
    model_bytes = n * 4
    for t in range(args.steps):
        toks = rng.integers(0, cfg.vocab, (m, args.batch, args.seq + 1))
        half = args.seq // 2
        toks[..., half + 1: 2 * half + 1] = toks[..., 1: half + 1]  # copy task
        batch = {"tokens": jnp.asarray(toks[..., :-1], jnp.int32),
                 "labels": jnp.asarray(toks[..., 1:], jnp.int32)}
        state, loss = step_fn(state, batch)
        if t % 20 == 0 or t == args.steps - 1:
            syncs = int(state.pstate.syncs)
            comm_gb = 2 * m * model_bytes * syncs / 1e9
            print(f"step {t:4d} loss={float(loss):7.4f} syncs={syncs:4d} "
                  f"divergence={float(state.pstate.last_divergence):9.2e} "
                  f"comm={comm_gb:7.3f}GB "
                  f"({(time.time()-t0)/(t+1):.2f}s/step)")
        if args.ckpt_every and (t + 1) % args.ckpt_every == 0:
            path = ckpt.save_step(args.ckpt_dir, t + 1, state)
            print(f"  checkpoint -> {path}")

    syncs = int(state.pstate.syncs)
    saved = 1.0 - syncs / args.steps
    print(f"\ndone: {syncs}/{args.steps} rounds communicated "
          f"({saved*100:.0f}% of parameter all-reduces eliminated by the "
          f"dynamic protocol)")


if __name__ == "__main__":
    main()
