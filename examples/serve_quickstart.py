"""Serve online kernel learners while they learn (DESIGN.md Secs. 10, 13).

Four distributed learners answer predict requests from a shared
request queue, apply labeled feedback as online updates the moment it
arrives, and run the paper's dynamic synchronization protocol in the
background — latency percentiles and Sec. 3 sync bytes metered on one
seeded timeline.  The protocol view is bit-identical to the scan
engine (``engine.run``) on the same stream; swap the substrate
(SV / RFF / linear) and the same serving path serves it.

The second half shows continuous batching (Sec. 13): Poisson arrivals
served by the ``"continuous"`` policy launch on arrival instead of at
tick-grid points — lower p99 at the same load — and a bounded queue
sheds (or defers) when offered load exceeds simulated capacity,
without the protocol view moving a bit.

  python examples/serve_quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import engine
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.core.rff import RFFSpec
from repro.core.rkhs import KernelSpec
from repro.core.substrate import RFFSubstrate
from repro.data import susy_stream
from repro.runtime import SystemConfig
from repro.serving import make_arrivals, serve_stream

T, M, D = 400, 4, 8


def main():
    X, Y = susy_stream(T=T, m=M, d=D, seed=0)
    pcfg = ProtocolConfig(kind="dynamic", delta=2.0)
    sys_cfg = SystemConfig(seed=0, compute_jitter=0.3, base_latency=0.05,
                           bandwidth=1e7)

    sv = LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.5, lam=0.01,
                       budget=64, kernel=KernelSpec("gaussian", gamma=0.3),
                       dim=D)
    rff = RFFSubstrate(spec=RFFSpec(dim=D, num_features=256, gamma=0.3,
                                    seed=0))

    for name, learner in (("sv-64", sv), ("rff-256", rff)):
        res = serve_stream(learner, pcfg, X, Y, queries_per_round=4.0,
                           sys_cfg=sys_cfg)
        pct = res.latency_percentiles()
        print(f"{name:8s} served {res.num_requests} requests over "
              f"{res.rounds} online rounds: "
              f"p50={pct['p50']:.2f} p99={pct['p99']:.2f} (sim time units), "
              f"syncs={res.num_syncs} bytes={res.total_bytes}")

        # the serving path IS the scan engine, protocol-wise
        ref = engine.run(learner, pcfg, X, Y)
        assert np.array_equal(ref.cumulative_loss, res.sim.cumulative_loss)
        assert np.array_equal(ref.cumulative_bytes, res.sim.cumulative_bytes)
        print(f"{'':8s} ... protocol view bit-identical to engine.run "
              f"(loss={res.total_loss:.1f})")

    # batches pay: the engine answered from padded static-size buckets
    res = serve_stream(sv, pcfg, X, Y, queries_per_round=8.0,
                       sys_cfg=sys_cfg)
    print("bucket histogram (size -> batches):",
          dict(sorted(res.bucket_counts.items())))

    # --- continuous batching under a latency SLO (Sec. 13) -------------
    lin = LearnerConfig(algo="linear_sgd", loss="hinge", eta=0.1,
                        lam=0.001, dim=D)
    kw = dict(sys_cfg=sys_cfg, predict_cost=0.05, tick_interval=0.25,
              slots=2)
    ref = engine.run(lin, pcfg, X, Y)
    print()
    for policy in ("tick", "continuous"):
        res = serve_stream(lin, pcfg, X, Y,
                           arrivals=make_arrivals("poisson", rate=6.0,
                                                  seed=0),
                           policy=policy, slo=0.3, **kw)
        pct = res.latency_percentiles()
        assert np.array_equal(ref.cumulative_loss, res.sim.cumulative_loss)
        print(f"{policy:10s} p50={pct['p50']:.3f} p99={pct['p99']:.3f} "
              f"launches={res.launches} (protocol view unchanged)")

    # overload: bursty arrivals past simulated capacity — a bounded
    # queue sheds, served requests keep their SLO, the models don't move
    res = serve_stream(lin, pcfg, X, Y,
                       arrivals=make_arrivals("bursty", rate=30.0, seed=0),
                       policy="continuous", slo=0.3, max_queue=8,
                       overload="shed", **kw)
    assert np.array_equal(ref.cumulative_loss, res.sim.cumulative_loss)
    print(f"overloaded  served={res.num_requests} shed={res.num_shed} "
          f"p99={res.latency_percentiles()['p99']:.3f} "
          f"(feedback never shed -> parity holds)")


if __name__ == "__main__":
    main()
