"""Kernel-quality learning at linear-model communication cost.

The paper's Sec. 4 'future work': replace the support-vector expansion
with random Fourier features so the model is a fixed-size primal
vector and every synchronization ships O(m D) bytes — no matter how
long the stream runs.  The substrate layer (DESIGN.md Sec. 8) makes
this a one-line swap: the same ``engine.run`` / async harness serve
SV, RFF, and linear models.

  python examples/rff_quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import engine
from repro.core.accounting import sync_bytes_linear
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.core.rff import RFFSpec
from repro.core.rkhs import KernelSpec
from repro.core.substrate import RFFSubstrate, substrate_of
from repro.data import susy_stream
from repro.runtime import AsyncProtocolConfig, SystemConfig, run_async_simulation

T, M, D_IN, D_FEAT = 400, 4, 8, 256


def main():
    X, Y = susy_stream(T=T, m=M, d=D_IN, seed=0)
    pcfg = ProtocolConfig(kind="dynamic", delta=2.0)

    sv = LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.5, lam=0.01,
                       budget=128, kernel=KernelSpec("gaussian", gamma=0.3),
                       dim=D_IN)
    rff = RFFSubstrate(spec=RFFSpec(dim=D_IN, num_features=D_FEAT,
                                    gamma=0.3, seed=0))

    # one sweep call, two model representations, same stream
    sweep = engine.sweep([substrate_of(sv), rff], [pcfg, pcfg], X, Y)
    for name, res in zip(("sv-128", f"rff-{D_FEAT}"), sweep.results):
        print(f"{name:9s} errors={int(res.cumulative_errors[-1]):4d} "
              f"syncs={res.num_syncs:3d} bytes={res.total_bytes}")

    # the RFF payload is a constant — Cor. 8 strict adaptivity
    res = sweep[1]
    per_sync = sync_bytes_linear(D_FEAT + 1, M)
    rb = np.diff(np.concatenate([[0], res.cumulative_bytes]))
    assert set(rb[rb > 0].tolist()) == {per_sync}
    print(f"every RFF sync costs exactly {per_sync} bytes")

    # identical substrate, event-driven with stragglers
    res_a = run_async_simulation(
        rff, AsyncProtocolConfig(kind="dynamic", delta=2.0), X, Y,
        sys_cfg=SystemConfig(seed=0, compute_jitter=0.3, straggler_frac=0.25,
                             straggler_mult=4.0, straggler_prob=0.3),
        record_divergence=False)
    print(f"async: syncs={res_a.num_syncs} bytes={res_a.total_bytes} "
          f"speedup_vs_barrier={res_a.speedup_vs_barrier:.2f}x")


if __name__ == "__main__":
    main()
