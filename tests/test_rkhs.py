"""RKHS machinery tests: Prop. 2 averaging, distances, divergence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rkhs
from repro.core.rkhs import KernelSpec, SVModel


def _model(budget, d, n_active, seed, id_offset=0):
    rng = np.random.default_rng(seed)
    sv = np.zeros((budget, d), np.float32)
    alpha = np.zeros((budget,), np.float32)
    ids = -np.ones((budget,), np.int32)
    sv[:n_active] = rng.normal(size=(n_active, d))
    alpha[:n_active] = rng.normal(size=(n_active,))
    ids[:n_active] = np.arange(n_active) + id_offset
    return SVModel(sv=jnp.asarray(sv), alpha=jnp.asarray(alpha),
                   sv_id=jnp.asarray(ids))


def test_predict_linear_kernel_equals_primal():
    """For the linear kernel, f(x) = (sum_i alpha_i x_i) . x — check the
    dual prediction against the explicit primal weight vector."""
    spec = KernelSpec(kind="linear")
    f = _model(8, 5, 6, seed=0)
    w = np.sum(np.asarray(f.alpha)[:, None] * np.asarray(f.sv), axis=0)
    X = np.random.default_rng(1).normal(size=(7, 5)).astype(np.float32)
    got = rkhs.predict(spec, f, jnp.asarray(X))
    np.testing.assert_allclose(got, X @ w, rtol=1e-4, atol=1e-4)


def test_norm_and_dist_linear_kernel():
    spec = KernelSpec(kind="linear")
    f = _model(8, 5, 6, seed=0)
    g = _model(8, 5, 4, seed=1, id_offset=100)
    wf = np.sum(np.asarray(f.alpha)[:, None] * np.asarray(f.sv), axis=0)
    wg = np.sum(np.asarray(g.alpha)[:, None] * np.asarray(g.sv), axis=0)
    np.testing.assert_allclose(float(rkhs.norm_sq(spec, f)), wf @ wf,
                               rtol=1e-4)
    np.testing.assert_allclose(float(rkhs.dist_sq(spec, f, g)),
                               (wf - wg) @ (wf - wg), rtol=1e-4, atol=1e-4)


def test_prop2_average_matches_function_average():
    """Prop. 2: the averaged expansion evaluates to the average of the
    individual functions at every point, for any kernel."""
    spec = KernelSpec(kind="gaussian", gamma=0.7)
    models = [_model(6, 4, 5, seed=s, id_offset=100 * s) for s in range(3)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *models)
    fbar = rkhs.average_stacked(stacked)
    X = np.random.default_rng(9).normal(size=(11, 4)).astype(np.float32)
    avg_pred = np.mean(
        [np.asarray(rkhs.predict(spec, m, jnp.asarray(X))) for m in models],
        axis=0)
    got = rkhs.predict(spec, fbar, jnp.asarray(X))
    np.testing.assert_allclose(got, avg_pred, rtol=1e-4, atol=1e-5)


def test_union_unique_count():
    m1 = _model(6, 4, 5, seed=0, id_offset=0)
    m2 = _model(6, 4, 3, seed=1, id_offset=3)  # ids 3,4,5 overlap 0..4
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), m1, m2)
    n = int(rkhs.union_unique_count(stacked.sv_id))
    assert n == len({0, 1, 2, 3, 4} | {3, 4, 5})


def test_divergence_zero_for_identical_models():
    spec = KernelSpec(kind="gaussian", gamma=1.0)
    m = _model(6, 4, 5, seed=0)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), m, m, m)
    assert abs(float(rkhs.divergence_stacked(spec, stacked))) < 1e-6


def test_divergence_positive_for_distinct_models():
    spec = KernelSpec(kind="gaussian", gamma=1.0)
    models = [_model(6, 4, 5, seed=s, id_offset=10 * s) for s in range(3)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *models)
    assert float(rkhs.divergence_stacked(spec, stacked)) > 0.0


def test_insert_sv_free_slot_then_eviction():
    f = rkhs.empty_model(3, 2)
    for i in range(3):
        f = rkhs.insert_sv(f, jnp.asarray([float(i), 0.0]),
                           jnp.asarray(0.1 * (i + 1)), jnp.asarray(i))
    assert int(rkhs.num_active(f)) == 3
    # budget full: smallest-|alpha| slot (alpha=0.1, id=0) is evicted
    f2 = rkhs.insert_sv(f, jnp.asarray([9.0, 9.0]), jnp.asarray(1.0),
                        jnp.asarray(99), evict="smallest")
    ids = set(np.asarray(f2.sv_id).tolist())
    assert 99 in ids and 0 not in ids
    # oldest eviction: id=1 is now oldest
    f3 = rkhs.insert_sv(f2, jnp.asarray([8.0, 8.0]), jnp.asarray(0.01),
                        jnp.asarray(100), evict="oldest")
    ids3 = set(np.asarray(f3.sv_id).tolist())
    assert 100 in ids3 and 1 not in ids3


def test_scale_model():
    f = _model(6, 4, 5, seed=0)
    g = rkhs.scale_model(f, 0.5)
    np.testing.assert_allclose(np.asarray(g.alpha), 0.5 * np.asarray(f.alpha))
