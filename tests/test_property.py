"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import compression, protocol, rkhs
from repro.core.rkhs import KernelSpec, SVModel

_fin = st.floats(-3.0, 3.0, allow_nan=False, allow_infinity=False,
                 width=32)


def _arrays(m, d):
    return st.lists(
        st.lists(_fin, min_size=d, max_size=d), min_size=m, max_size=m)


@settings(max_examples=25, deadline=None)
@given(data=_arrays(4, 5))
def test_sync_preserves_mean(data):
    """Invariant: sigma (averaging) preserves the mean of the model
    configuration — no mass is created or destroyed."""
    st_ = {"w": jnp.asarray(np.asarray(data, np.float32))}
    out = protocol.sigma_continuous(st_)
    np.testing.assert_allclose(
        np.asarray(protocol.average_model(out)["w"]),
        np.asarray(protocol.average_model(st_)["w"]), rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(data=_arrays(4, 5))
def test_divergence_nonnegative_and_zero_after_sync(data):
    st_ = {"w": jnp.asarray(np.asarray(data, np.float32))}
    assert float(protocol.divergence(st_)) >= -1e-6
    out = protocol.sigma_continuous(st_)
    assert float(protocol.divergence(out)) < 1e-8


@settings(max_examples=25, deadline=None)
@given(data=_arrays(5, 4), delta=st.floats(0.01, 100.0))
def test_no_violation_implies_divergence_below_delta(data, delta):
    """The local-condition soundness invariant (geometric monitoring):
    all ||f_i - r|| <= sqrt(Delta) implies delta(f) <= Delta."""
    st_ = {"w": jnp.asarray(np.asarray(data, np.float32))}
    ref = protocol.average_model(st_)
    violated = protocol.local_conditions(st_, ref, delta)
    if not bool(jnp.any(violated)):
        assert float(protocol.divergence(st_)) <= delta * (1 + 1e-5) + 1e-6


@settings(max_examples=20, deadline=None)
@given(alphas=st.lists(_fin, min_size=6, max_size=6),
       gamma=st.floats(0.05, 2.0))
def test_rkhs_norm_nonnegative(alphas, gamma):
    """||f||^2 = a^T K a >= 0 for any PSD kernel."""
    rng = np.random.default_rng(0)
    sv = rng.normal(size=(6, 3)).astype(np.float32)
    f = SVModel(sv=jnp.asarray(sv),
                alpha=jnp.asarray(np.asarray(alphas, np.float32)),
                sv_id=jnp.arange(6, dtype=jnp.int32))
    spec = KernelSpec(kind="gaussian", gamma=gamma)
    assert float(rkhs.norm_sq(spec, f)) >= -1e-4


@settings(max_examples=20, deadline=None)
@given(alphas=st.lists(_fin, min_size=8, max_size=8),
       tau=st.integers(2, 7))
def test_compression_epsilon_consistency(alphas, tau):
    """compress returns (f~, eps) with eps^2 ~= ||f - f~||^2 >= 0 and
    fewer active slots than tau."""
    rng = np.random.default_rng(1)
    sv = rng.normal(size=(8, 3)).astype(np.float32)
    f = SVModel(sv=jnp.asarray(sv),
                alpha=jnp.asarray(np.asarray(alphas, np.float32)),
                sv_id=jnp.arange(8, dtype=jnp.int32))
    spec = KernelSpec(kind="gaussian", gamma=0.5)
    fc, eps = compression.truncate(spec, f, tau)
    assert int(rkhs.num_active(fc)) <= tau
    assert float(eps) >= 0.0
    d2 = float(rkhs.dist_sq(spec, f, fc))
    np.testing.assert_allclose(float(eps) ** 2, max(d2, 0.0), rtol=5e-2,
                               atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(2, 6))
def test_prop2_average_prediction_property(m):
    """Prop. 2 as a property over random configurations."""
    rng = np.random.default_rng(m)
    models = []
    for i in range(m):
        models.append(SVModel(
            sv=jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32)),
            alpha=jnp.asarray(rng.normal(size=(4,)).astype(np.float32)),
            sv_id=jnp.arange(4, dtype=jnp.int32) + 10 * i))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *models)
    spec = KernelSpec(kind="gaussian", gamma=0.8)
    fbar = rkhs.average_stacked(stacked)
    X = jnp.asarray(rng.normal(size=(5, 3)).astype(np.float32))
    want = np.mean([np.asarray(rkhs.predict(spec, f, X)) for f in models], 0)
    got = np.asarray(rkhs.predict(spec, fbar, X))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_gram_psd(seed):
    """Gaussian Gram matrices are PSD (up to numerical tolerance)."""
    from repro.kernels import ref
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(12, 4)).astype(np.float32))
    K = np.asarray(ref.gram_ref(X, X, kind="gaussian", gamma=0.5))
    w = np.linalg.eigvalsh((K + K.T) / 2)
    assert w.min() > -1e-4
