"""Substrate tests: optimizers, checkpointing, data streams, serving."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.configs import get
from repro.data import (drifting_stream, separable_stream, stock_stream,
                        susy_stream, token_stream)
from repro.models import build
from repro.optim import OptimizerConfig, make as make_optimizer
from repro.serving.lm import LMServingEngine, Request


# --- optimizers -----------------------------------------------------------

def _quadratic_problem():
    w_true = jnp.asarray([1.0, -2.0, 0.5])

    def loss(p):
        return jnp.sum((p["w"] - w_true) ** 2)

    return w_true, loss


@pytest.mark.parametrize("kind,lr", [("sgd", 0.1), ("adamw", 0.1)])
def test_optimizer_converges(kind, lr):
    w_true, loss = _quadratic_problem()
    cfg = OptimizerConfig(kind=kind, lr=lr, momentum=0.9 if kind == "sgd" else 0.0)
    opt = make_optimizer(cfg)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    for t in range(200):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, jnp.asarray(t))
    assert float(loss(params)) < 1e-2


def test_grad_clip():
    cfg = OptimizerConfig(kind="sgd", lr=1.0, grad_clip=1.0)
    opt = make_optimizer(cfg)
    params = {"w": jnp.zeros(4)}
    g = {"w": jnp.full((4,), 100.0)}
    new_params, _ = opt.update(g, opt.init(params), params,
                               jnp.asarray(0))
    assert float(jnp.linalg.norm(new_params["w"])) <= 1.0 + 1e-5


# --- checkpointing ----------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray([1, 2], jnp.int32),
                  "d": jnp.asarray(3.5, jnp.bfloat16)}}
    path = os.path.join(tmp_path, "t.ckpt")
    ckpt.save(path, tree)
    out = ckpt.restore(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_checkpoint_latest_pointer(tmp_path):
    d = str(tmp_path)
    tree = {"w": jnp.ones(3)}
    ckpt.save_step(d, 1, tree)
    p2 = ckpt.save_step(d, 2, tree)
    assert ckpt.latest_step(d) == p2


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = os.path.join(tmp_path, "t.ckpt")
    ckpt.save(path, {"w": jnp.ones(3)})
    with pytest.raises(ValueError):
        ckpt.restore(path, {"w": jnp.ones(4)})


# --- data streams ------------------------------------------------------------

def test_susy_stream_nonlinear_labels():
    X, Y = susy_stream(200, 2, d=8, seed=0)
    assert X.shape == (200, 2, 8) and Y.shape == (200, 2)
    assert set(np.unique(Y)) <= {-1.0, 1.0}
    # both classes present
    assert 0.1 < (Y > 0).mean() < 0.9


def test_separable_stream_is_separable():
    X, Y = separable_stream(300, 1, d=6, seed=1)
    # a linear SVM-ish check: the generating w achieves zero errors; use
    # logistic regression via least squares as a proxy
    Xf = X[:, 0]
    w, *_ = np.linalg.lstsq(Xf, Y[:, 0], rcond=None)
    acc = (np.sign(Xf @ w) == Y[:, 0]).mean()
    assert acc > 0.97


def test_drifting_stream_changes_boundary():
    X, Y = drifting_stream(1000, 1, d=6, seed=2, drift_every=250)
    Xf, Yf = X[:, 0], Y[:, 0]
    w1, *_ = np.linalg.lstsq(Xf[:250], Yf[:250], rcond=None)
    acc_late = (np.sign(Xf[750:] @ w1) == Yf[750:]).mean()
    assert acc_late < 0.95   # old boundary degrades after drift


def test_stock_stream_nonlinear_target():
    X, Y = stock_stream(500, 2, d=10, seed=3)
    assert np.isfinite(X).all() and np.isfinite(Y).all()
    # linear fit leaves substantial residual (the non-linear term)
    Xf = X[:, 0]
    w, *_ = np.linalg.lstsq(Xf, Y[:, 0], rcond=None)
    resid = Y[:, 0] - Xf @ w
    assert np.var(resid) > 0.05 * np.var(Y[:, 0])


def test_token_stream_shapes():
    it = token_stream(3, batch=4, seq_len=16, vocab=100)
    x, y = next(it)
    assert x.shape == (4, 16) and y.shape == (4, 16)
    assert x.max() < 100


# --- serving engine ----------------------------------------------------------

def test_serving_engine_end_to_end():
    cfg = get("qwen2_5_3b").smoke()
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    engine = LMServingEngine(cfg, params, batch_size=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab, (5 + i,),
                                               ).astype(np.int32),
                    max_new_tokens=4) for i in range(3)]
    done = engine.run(reqs)
    assert len(done) == 3
    for r in done:
        assert len(r.output) == 4
        assert all(0 <= t < cfg.vocab for t in r.output)


def test_serving_deterministic():
    cfg = get("mamba2_130m").smoke()
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(1))
    engine = LMServingEngine(cfg, params, batch_size=2, max_len=32)
    prompt = np.arange(1, 8, dtype=np.int32)
    r1 = engine.run([Request(uid=0, prompt=prompt, max_new_tokens=5)])[0]
    r2 = engine.run([Request(uid=0, prompt=prompt, max_new_tokens=5)])[0]
    assert r1.output == r2.output
