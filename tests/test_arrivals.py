"""Arrival-process determinism suite (DESIGN.md Sec. 13).

The serving load generators (`repro.serving.arrivals`) obey the same
determinism contract as every other seeded quantity in the repo: a
process's ``times(horizon)`` is a pure function of (config, seed,
horizon) — byte-identical across calls AND across Python processes
(the per-class RNG stream tags are fixed integers, never
PYTHONHASHSEED-randomized string hashes) — and a serving run fed by
one produces a byte-identical Chrome trace under seed, extending the
PR 6 trace-determinism test to the continuous-batching path.
"""
import hashlib
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.data import susy_stream
from repro.serving import (ARRIVAL_KINDS, BurstyArrivals, DiurnalArrivals,
                           PoissonArrivals, make_arrivals, serve_stream)
from repro.telemetry.trace import Tracer

HORIZON = 50.0
RATE = 4.0


def _times(kind, seed=3, rate=RATE, horizon=HORIZON):
    return make_arrivals(kind, rate, seed=seed).times(horizon)


# ---------------------------------------------------------------------------
# Generator determinism
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ARRIVAL_KINDS)
def test_arrivals_byte_identical_under_seed(kind):
    a, b = _times(kind), _times(kind)
    assert a.dtype == np.float64
    assert a.tobytes() == b.tobytes()        # byte-identical, not approx
    assert _times(kind, seed=4).tobytes() != a.tobytes()


@pytest.mark.parametrize("kind", ARRIVAL_KINDS)
def test_arrivals_sorted_and_in_range(kind):
    ts = _times(kind)
    assert len(ts) > 0
    assert (np.diff(ts) >= 0).all()
    assert ts[0] >= 0.0 and ts[-1] < HORIZON


def test_kinds_draw_from_distinct_streams():
    """Same (rate, seed), different kind => different draws: the
    per-class stream tag actually separates the generators."""
    blobs = {kind: _times(kind).tobytes() for kind in ARRIVAL_KINDS}
    assert len(set(blobs.values())) == len(ARRIVAL_KINDS)


def test_arrivals_byte_identical_across_processes():
    """The regression the fixed _KIND_TAG constants prevent: a
    hash(classname)-based stream tag varies with PYTHONHASHSEED, which
    Python randomizes per process.  A fresh interpreter must reproduce
    the parent's draws exactly."""
    digests = {kind: hashlib.sha256(_times(kind).tobytes()).hexdigest()
               for kind in ARRIVAL_KINDS}
    script = textwrap.dedent(f"""
        import hashlib
        from repro.serving import make_arrivals
        for kind in {list(ARRIVAL_KINDS)!r}:
            ts = make_arrivals(kind, {RATE}, seed=3).times({HORIZON})
            print(kind, hashlib.sha256(ts.tobytes()).hexdigest())
    """)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True,
                         env={"PYTHONPATH": "src", "PYTHONHASHSEED": "99"})
    assert out.returncode == 0, out.stderr
    for line in out.stdout.strip().splitlines():
        kind, digest = line.split()
        assert digests[kind] == digest, kind


# ---------------------------------------------------------------------------
# Statistical sanity (deterministic seeds => plain asserts, no flake)
# ---------------------------------------------------------------------------


def test_poisson_count_near_mean():
    ts = PoissonArrivals(rate=RATE, seed=0).times(500.0)
    mean = RATE * 500.0
    assert abs(len(ts) - mean) < 5 * np.sqrt(mean)


def test_bursty_long_run_rate_and_duty():
    p = BurstyArrivals(rate=RATE, seed=0, mean_on=1.0, mean_off=3.0)
    assert p.duty == pytest.approx(0.25)
    assert p.burst_rate == pytest.approx(4 * RATE)   # 1/duty inflation
    ts = p.times(2000.0)
    assert len(ts) / 2000.0 == pytest.approx(p.mean_rate, rel=0.15)
    # bursty really is burstier than Poisson: higher variance of
    # per-unit-interval counts at the same mean rate
    pois = PoissonArrivals(rate=RATE, seed=0).times(2000.0)
    var_b = np.var(np.histogram(ts, bins=2000, range=(0, 2000))[0])
    var_p = np.var(np.histogram(pois, bins=2000, range=(0, 2000))[0])
    assert var_b > 2 * var_p


def test_diurnal_profile_and_mean():
    p = DiurnalArrivals(rate=RATE, seed=0, trough_frac=0.2, period=20.0)
    assert p.peak_rate == RATE
    assert p.trough_rate == pytest.approx(0.2 * RATE)
    assert p.mean_rate == pytest.approx(0.5 * (0.2 * RATE + RATE))
    assert p.rate_at(0.0) == pytest.approx(p.trough_rate)
    assert p.rate_at(10.0) == pytest.approx(p.peak_rate)
    ts = p.times(2000.0)
    assert len(ts) / 2000.0 == pytest.approx(p.mean_rate, rel=0.15)
    # more arrivals near the crest than near the trough
    phase = np.mod(ts, 20.0)
    crest = ((phase > 5.0) & (phase < 15.0)).sum()
    trough = len(ts) - crest
    assert crest > 1.5 * trough


def test_arrivals_validation():
    with pytest.raises(ValueError):
        PoissonArrivals(rate=0.0)
    with pytest.raises(ValueError):
        BurstyArrivals(rate=1.0, mean_on=0.0)
    with pytest.raises(ValueError):
        DiurnalArrivals(rate=1.0, trough_frac=1.5)
    with pytest.raises(ValueError):
        DiurnalArrivals(rate=1.0, period=0.0)
    with pytest.raises(ValueError):
        make_arrivals("uniform", 1.0)


# ---------------------------------------------------------------------------
# Trace byte-identity through the serving engine (extends PR 6)
# ---------------------------------------------------------------------------

T, M, D = 30, 4, 6


def _traced_run(kind, policy, seed=3):
    X, Y = susy_stream(T=T, m=M, d=D, seed=1)
    lcfg = LearnerConfig(algo="linear_sgd", loss="hinge", eta=0.1,
                         lam=0.001, dim=D)
    tr = Tracer()
    res = serve_stream(
        lcfg, ProtocolConfig(kind="dynamic", delta=1.0), X, Y,
        arrivals=make_arrivals(kind, rate=3.0, seed=seed),
        policy=policy, slots=2, predict_cost=0.05, max_queue=8,
        overload="shed", tracer=tr)
    return tr, res


@pytest.mark.parametrize("policy", ["tick", "continuous"])
@pytest.mark.parametrize("kind", ARRIVAL_KINDS)
def test_serving_trace_byte_identical_under_seed(kind, policy):
    """Identical configuration => byte-identical trace JSON, for every
    arrival model under both batch policies — scheduling decisions,
    holds, sheds and all."""
    t1, r1 = _traced_run(kind, policy)
    t2, r2 = _traced_run(kind, policy)
    assert r1.num_requests == r2.num_requests
    assert t1.to_json() == t2.to_json()
    t3, _ = _traced_run(kind, policy, seed=4)
    assert t3.to_json() != t1.to_json()      # the seed actually matters
