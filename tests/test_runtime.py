"""Tests of the asynchronous event-driven runtime (repro.runtime)."""
import numpy as np
import pytest

from repro.core import accounting, simulation
from repro.core.accounting import ByteModel
from repro.core.criterion import check_sync_bound, quiescent
from repro.core.learners import LearnerConfig, gamma_of
from repro.core.protocol import ProtocolConfig
from repro.core.rkhs import KernelSpec
from repro.data.streams import separable_stream, susy_stream
from repro.runtime import (AsyncProtocolConfig, Clock, SystemConfig,
                           SystemModel, run_async_simulation,
                           staleness_weight)
from repro.runtime.transport import kernel_payload_bytes

D = 8
KCFG = LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.5, lam=0.01,
                     budget=32, kernel=KernelSpec("gaussian", gamma=0.3),
                     dim=D)


# ---------------------------------------------------------------------------
# Event queue / system model
# ---------------------------------------------------------------------------


def test_clock_orders_events_and_breaks_ties_by_schedule_order():
    clock = Clock()
    seen = []
    clock.schedule(2.0, lambda: seen.append("late"))
    clock.schedule(1.0, lambda: seen.append("a"))
    clock.schedule(1.0, lambda: seen.append("b"))   # same time, later seq
    clock.run()
    assert seen == ["a", "b", "late"]
    assert clock.now == 2.0


def test_clock_rejects_negative_delay():
    with pytest.raises(ValueError):
        Clock().schedule(-1.0, lambda: None)


def test_clock_schedule_at_preserves_exact_time():
    """schedule_at fires at exactly the float passed in — no
    now + (t - now) round-trip, so grid points like k * interval are
    hit bit-exactly even at large simulated times."""
    clock = Clock()
    target = 1e9 + 3 * 1e-3                 # not reachable via now+(t-now)
    fired = []
    clock.schedule_at(target, lambda: fired.append(clock.now))
    clock.run()
    assert fired == [target]                # bit-exact, not approx
    # times in the past clamp to now (fire as soon as reached)
    clock2 = Clock()
    clock2.schedule(1.0, lambda: clock2.schedule_at(
        0.25, lambda: fired.append(clock2.now)))
    clock2.run()
    assert fired[-1] == 1.0


def test_clock_cancel_skips_event():
    clock = Clock()
    seen = []
    ev = clock.schedule(1.0, lambda: seen.append("cancelled"))
    clock.schedule(2.0, lambda: seen.append("kept"))
    clock.cancel(ev)
    clock.cancel(ev)                        # double-cancel is a no-op
    clock.run()
    assert seen == ["kept"]
    assert clock.events_processed == 1      # skipped events don't count
    clock.cancel(ev)                        # cancel-after-drain: no-op


def test_system_model_deterministic_and_straggler_count():
    cfg = SystemConfig(seed=7, compute_jitter=0.4, straggler_frac=0.5,
                       straggler_mult=3.0, base_latency=0.2,
                       latency_jitter=0.3)
    a, b = SystemModel(cfg, 8), SystemModel(cfg, 8)
    np.testing.assert_array_equal(a.stragglers, b.stragglers)
    assert len(a.stragglers) == 4
    np.testing.assert_array_equal(a.draw_compute(50), b.draw_compute(50))
    assert [a.draw_latency(100) for _ in range(5)] == \
           [b.draw_latency(100) for _ in range(5)]


# ---------------------------------------------------------------------------
# Staleness schedules
# ---------------------------------------------------------------------------


def test_staleness_schedule_math():
    const = AsyncProtocolConfig(staleness="constant")
    hinge = AsyncProtocolConfig(staleness="hinge", stale_a=0.5, stale_b=4)
    poly = AsyncProtocolConfig(staleness="poly", stale_a=0.5)
    for lag in range(10):
        assert staleness_weight(const, lag) == 1.0
    # hinge: 1 up to b, then 1/(a (lag-b)), clipped into (0, 1]
    assert staleness_weight(hinge, 4) == 1.0
    assert staleness_weight(hinge, 6) == pytest.approx(1.0)  # 1/(0.5*2)=1
    assert staleness_weight(hinge, 8) == pytest.approx(1.0 / (0.5 * 4))
    # poly: (lag+1)^-a, monotone decreasing from 1
    assert staleness_weight(poly, 0) == 1.0
    assert staleness_weight(poly, 3) == pytest.approx(4.0 ** -0.5)
    ws = [staleness_weight(poly, k) for k in range(8)]
    assert all(w1 >= w2 for w1, w2 in zip(ws, ws[1:]))
    assert all(0.0 < w <= 1.0 for w in ws)
    with pytest.raises(ValueError):
        AsyncProtocolConfig(staleness="hinge", stale_a=0.0)


# ---------------------------------------------------------------------------
# Delta-encoding byte exactness
# ---------------------------------------------------------------------------


def test_delta_encoding_matches_accounting():
    """Per-message transport costs summed over one full synchronization
    must reproduce accounting.sync_bytes_kernel to the byte."""
    bm = ByteModel(dim=D)
    rng = np.random.default_rng(0)
    known = set(int(i) for i in rng.choice(200, 30, replace=False))
    local_ids = [rng.choice(200, size=rng.integers(5, 40), replace=False)
                 for _ in range(4)]
    expect, union = accounting.sync_bytes_kernel(bm, local_ids, known)

    total = 0
    sets = [set(int(i) for i in ids) for ids in local_ids]
    for s in sets:                                    # uploads
        total += kernel_payload_bytes(bm, s, known)
    for s in sets:                                    # downloads
        total += kernel_payload_bytes(bm, union, s)
    assert total == expect


def test_async_bytes_match_serial_at_zero_latency():
    """Ideal network + alpha=1 + constant staleness: the async dynamic
    protocol reproduces the serial simulator's ledger exactly."""
    T, m = 150, 4
    X, Y = susy_stream(T=T, m=m, d=D, seed=0)
    res_s = simulation.run_kernel_simulation(
        KCFG, ProtocolConfig(kind="dynamic", delta=2.0), X, Y)
    res_a = run_async_simulation(
        KCFG, AsyncProtocolConfig(kind="dynamic", delta=2.0, alpha=1.0,
                                  staleness="constant"),
        X, Y, sys_cfg=SystemConfig())
    np.testing.assert_array_equal(res_s.sync_rounds, res_a.sync_rounds)
    np.testing.assert_array_equal(res_s.cumulative_bytes,
                                  res_a.cumulative_bytes)
    assert res_s.total_bytes == res_a.total_bytes
    np.testing.assert_allclose(res_s.eps_history, res_a.eps_history,
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(res_s.total_loss, res_a.total_loss, rtol=1e-5)


# ---------------------------------------------------------------------------
# Determinism under seed
# ---------------------------------------------------------------------------


def test_determinism_under_seed():
    T, m = 120, 4
    X, Y = susy_stream(T=T, m=m, d=D, seed=1)
    acfg = AsyncProtocolConfig(kind="dynamic", delta=2.0, alpha=0.6,
                               staleness="poly", agg_window=0.5)
    sc = SystemConfig(seed=3, compute_jitter=0.3, straggler_frac=0.25,
                      base_latency=0.4, latency_jitter=0.5,
                      bandwidth=1e5, drop_prob=0.05)
    r1 = run_async_simulation(KCFG, acfg, X, Y, sys_cfg=sc)
    r2 = run_async_simulation(KCFG, acfg, X, Y, sys_cfg=sc)
    assert r1.total_bytes == r2.total_bytes
    assert r1.total_loss == r2.total_loss
    assert r1.wall_clock == r2.wall_clock
    assert r1.num_dropped == r2.num_dropped
    np.testing.assert_array_equal(r1.sync_rounds, r2.sync_rounds)
    np.testing.assert_array_equal(r1.cumulative_bytes, r2.cumulative_bytes)

    r3 = run_async_simulation(
        KCFG, acfg, X, Y, sys_cfg=SystemConfig(
            seed=4, compute_jitter=0.3, straggler_frac=0.25,
            base_latency=0.4, latency_jitter=0.5, bandwidth=1e5,
            drop_prob=0.05))
    assert r3.wall_clock != r1.wall_clock     # the seed actually matters


# ---------------------------------------------------------------------------
# Wall-clock: stragglers hurt the barrier, not the async runtime
# ---------------------------------------------------------------------------


def test_async_beats_barrier_under_stragglers():
    T, m = 100, 4
    X, Y = susy_stream(T=T, m=m, d=D, seed=2)
    sc = SystemConfig(seed=0, compute_jitter=0.4, straggler_frac=0.25,
                      straggler_mult=4.0, straggler_prob=0.3)
    res = run_async_simulation(
        KCFG, AsyncProtocolConfig(kind="dynamic", delta=2.0), X, Y,
        sys_cfg=sc, record_divergence=False)
    assert res.wall_clock < res.barrier_wall_clock
    assert res.speedup_vs_barrier > 1.0


# ---------------------------------------------------------------------------
# Efficiency criterion on async traces
# ---------------------------------------------------------------------------


def test_criterion_on_async_trace():
    """Async traces plug into core.criterion unchanged: on a learnable
    stream the dynamic protocol stays loss-proportional (Prop. 6) and
    reaches quiescence — communication vanishes with the loss."""
    T, m = 300, 4
    X, Y = separable_stream(T=T, m=m, d=D, seed=0, margin=1.0)
    lcfg = LearnerConfig(algo="linear_pa", loss="hinge", C=1.0, dim=D)
    res = run_async_simulation(
        lcfg, AsyncProtocolConfig(kind="dynamic", delta=1.0), X, Y,
        sys_cfg=SystemConfig(), record_divergence=False)
    ok, slack = check_sync_bound(res, gamma_of(lcfg), delta=1.0)
    assert ok and slack >= 1.0
    assert quiescent(res)
    # communication really stops: flat ledger over the last quarter
    assert res.cumulative_bytes[-1] == res.cumulative_bytes[3 * T // 4]


def test_async_periodic_pushes_every_period():
    T, m = 60, 3
    X, Y = susy_stream(T=T, m=m, d=D, seed=3)
    res = run_async_simulation(
        KCFG, AsyncProtocolConfig(kind="periodic", period=10), X, Y,
        sys_cfg=SystemConfig(), record_divergence=False)
    assert res.num_syncs == T // 10
    # every sync merged all m freshly-pushed models
    np.testing.assert_array_equal(res.sync_rounds,
                                  np.arange(9, T, 10, dtype=np.int64))


def test_staleness_discount_under_latency():
    """Slow links force merges of stale models; hinge/poly weights must
    record positive lags and still produce a working system."""
    T, m = 120, 4
    X, Y = susy_stream(T=T, m=m, d=D, seed=4)
    res = run_async_simulation(
        KCFG,
        AsyncProtocolConfig(kind="dynamic", delta=1.0, alpha=0.6,
                            staleness="hinge", agg_window=0.2),
        X, Y,
        sys_cfg=SystemConfig(seed=1, base_latency=1.5, latency_jitter=0.5,
                             compute_jitter=0.3),
        record_divergence=False)
    assert res.num_syncs > 0
    assert res.max_staleness >= 1
    assert np.isfinite(res.total_loss)
