"""Attention variants: masks, M-RoPE, MLA absorbed-vs-naive decode."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn
from repro.models.config import ModelConfig


def test_causal_mask_window():
    m = np.asarray(attn.causal_mask(6, 6, window=3))
    for i in range(6):
        for j in range(6):
            assert m[i, j] == (j <= i and j > i - 3)


def test_sliding_window_equals_full_for_large_window():
    cfg = ModelConfig(d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                      d_ff=64, vocab=32, dtype="float32")
    p = attn.gqa_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(1, 8, 32)),
                    jnp.float32)
    y_full = attn.gqa_forward(cfg, p, x, window=0)
    y_win = attn.gqa_forward(cfg, p, x, window=100)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_win),
                               rtol=1e-5, atol=1e-6)


def test_mrope_reduces_to_rope_for_equal_streams():
    """With identical (t,h,w) position streams, M-RoPE == plain RoPE."""
    from repro.models.layers import apply_mrope, apply_rope
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 5, 3, 16)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(5), (2, 5))
    pos3 = jnp.broadcast_to(pos[None], (3, 2, 5))
    a = apply_rope(x, pos, 10_000.0)
    b = apply_mrope(x, pos3, 10_000.0, (3, 2, 3))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-6)


def test_rope_relative_property():
    """RoPE: <q_m, k_n> depends only on (m - n)."""
    from repro.models.layers import apply_rope
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 8)), jnp.float32)

    def score(m, n):
        qm = apply_rope(q, jnp.asarray([[m]]), 10_000.0)
        kn = apply_rope(k, jnp.asarray([[n]]), 10_000.0)
        return float(jnp.sum(qm * kn))

    assert abs(score(3, 1) - score(7, 5)) < 1e-4
    assert abs(score(2, 2) - score(9, 9)) < 1e-4


def test_mla_absorbed_equals_naive_decode():
    cfg = ModelConfig(d_model=32, n_heads=4, n_kv_heads=4, d_ff=64,
                      vocab=32, attn_kind="mla", mla_q_lora=16,
                      mla_kv_lora=8, mla_rope_dim=4, mla_nope_dim=8,
                      mla_v_dim=8, dtype="float32")
    p = attn.mla_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    rng = np.random.default_rng(0)
    x_t = jnp.asarray(rng.normal(size=(2, 1, 32)), jnp.float32)
    cache = attn.init_mla_cache(cfg, 2, 8, jnp.float32)
    # seed the cache with a few tokens
    for t in range(3):
        xt = jnp.asarray(rng.normal(size=(2, 1, 32)), jnp.float32)
        _, cache = attn.mla_decode(cfg, p, xt, jnp.asarray(t, jnp.int32),
                                   cache)
    y_abs, _ = attn.mla_decode(cfg, p, x_t, jnp.asarray(3, jnp.int32),
                               cache, absorbed=True)
    y_naive, _ = attn.mla_decode(cfg, p, x_t, jnp.asarray(3, jnp.int32),
                                 cache, absorbed=False)
    np.testing.assert_allclose(np.asarray(y_abs), np.asarray(y_naive),
                               rtol=1e-4, atol=1e-5)


def test_mla_forward_matches_decode_chain():
    cfg = ModelConfig(d_model=32, n_heads=4, n_kv_heads=4, d_ff=64,
                      vocab=32, attn_kind="mla", mla_q_lora=16,
                      mla_kv_lora=8, mla_rope_dim=4, mla_nope_dim=8,
                      mla_v_dim=8, dtype="float32")
    p = attn.mla_init(jax.random.PRNGKey(1), cfg, jnp.float32)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(1, 6, 32)), jnp.float32)
    y_full = attn.mla_forward(cfg, p, x)

    cache = attn.init_mla_cache(cfg, 1, 8, jnp.float32)
    for t in range(6):
        y_t, cache = attn.mla_decode(cfg, p, x[:, t:t + 1],
                                     jnp.asarray(t, jnp.int32), cache)
    np.testing.assert_allclose(np.asarray(y_t[:, 0]),
                               np.asarray(y_full[:, -1]), rtol=1e-4,
                               atol=1e-5)


def test_gqa_grouping_correctness():
    """GQA with K kv-heads must equal MHA where kv heads are repeated."""
    rng = np.random.default_rng(3)
    B, S, H, K, hd = 1, 4, 4, 2, 8
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, K, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, K, hd)), jnp.float32)
    mask = attn.causal_mask(S, S)
    y_gqa = attn._sdpa(q, k, v, mask, 1.0)
    k_rep = jnp.repeat(k, H // K, axis=2)
    v_rep = jnp.repeat(v, H // K, axis=2)
    y_mha = attn._sdpa(q, k_rep, v_rep, mask, 1.0)
    np.testing.assert_allclose(np.asarray(y_gqa), np.asarray(y_mha),
                               rtol=1e-5, atol=1e-6)


def test_flash_path_matches_sdpa_in_model():
    """gqa_forward with use_flash=True (interpret mode on CPU) must
    match the XLA SDPA path, including GQA repeat and RoPE."""
    cfg = ModelConfig(d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                      d_ff=128, vocab=64, dtype="float32")
    p = attn.gqa_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 128, 64)),
                    jnp.float32)
    y_ref = attn.gqa_forward(cfg, p, x)
    y_flash = attn.gqa_forward(cfg.with_(use_flash=True), p, x)
    np.testing.assert_allclose(np.asarray(y_flash), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-5)


def test_flash_path_padded_seq():
    cfg = ModelConfig(d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
                      d_ff=128, vocab=64, dtype="float32")
    p = attn.gqa_init(jax.random.PRNGKey(1), cfg, jnp.float32)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(1, 100, 64)),
                    jnp.float32)
    y_ref = attn.gqa_forward(cfg, p, x)
    y_flash = attn.gqa_forward(cfg.with_(use_flash=True), p, x)
    np.testing.assert_allclose(np.asarray(y_flash), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-5)
