"""Distributed integration test: the dynamic protocol on a real
multi-device host mesh (8 CPU devices in a subprocess — jax locks the
device count at first init, so this must run out-of-process)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get
    from repro.core.protocol import ProtocolConfig
    from repro.launch.train import init_train_state, make_train_step
    from repro.launch import sharding as shd
    from repro.optim import OptimizerConfig

    assert len(jax.devices()) == 8
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    m = 4

    cfg = get("qwen2_5_3b").smoke()
    pcfg = ProtocolConfig(kind="dynamic", delta=1e-4)
    opt_cfg = OptimizerConfig(kind="sgd", lr=0.05)
    state = init_train_state(jax.random.PRNGKey(0), cfg, m, opt_cfg)

    pspec = shd.param_pspec(state.params, 2, learner_axes=("data",))
    opt_pspec = shd.param_pspec(state.opt, 2, learner_axes=("data",))
    from repro.core.protocol import ProtocolState
    from repro.launch.train import TrainState
    state_pspec = TrainState(
        params=pspec, opt=opt_pspec,
        pstate=ProtocolState(
            reference=shd.param_pspec(state.pstate.reference, 2,
                                      learner_axes=("data",)),
            step=P(), syncs=P(), bytes_sent=P(), last_divergence=P(),
            delta_scale=P()),
        step=P())

    step_fn = jax.jit(
        make_train_step(cfg, pcfg, opt_cfg),
        in_shardings=(shd.to_shardings(mesh, state_pspec), None),
        out_shardings=(shd.to_shardings(mesh, state_pspec), None),
    )

    rng = np.random.default_rng(0)
    with mesh:
        state = jax.device_put(state, shd.to_shardings(mesh, state_pspec))
        losses = []
        for t in range(6):
            toks = rng.integers(0, cfg.vocab, (m, 2, 17))
            batch = {"tokens": jnp.asarray(toks[..., :-1], jnp.int32),
                     "labels": jnp.asarray(toks[..., 1:], jnp.int32)}
            state, loss = step_fn(state, batch)
            losses.append(float(loss))

    assert all(np.isfinite(losses)), losses
    assert int(state.pstate.syncs) >= 1      # tiny delta forces syncs
    # all learners hold identical models after a sync round
    from repro.core import protocol
    div = float(protocol.divergence(state.params))
    print("OK syncs=", int(state.pstate.syncs), "div=", div)
""")


@pytest.mark.slow
def test_dynamic_protocol_on_host_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK syncs=" in r.stdout
