"""Prefill/decode-path consistency for all architectures: prefill then
single-token decode must reproduce the full-forward logits."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_ids, get
from repro.models import build


@pytest.mark.parametrize("arch", all_arch_ids())
def test_prefill_and_decode_match_full_forward(arch):
    scfg = get(arch).smoke()
    api = build(scfg)
    params = api.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, scfg.vocab, (B, S + 1)), jnp.int32)
    batch = {"tokens": tokens[:, :S]}
    extra = 0
    if scfg.arch_type == "vlm":
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(B, scfg.vision_tokens, scfg.d_model)), jnp.float32)
        extra = scfg.vision_tokens
    if scfg.arch_type == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, scfg.n_audio_frames, scfg.d_model)), jnp.float32)

    logits_full, _ = api.forward(params, batch)
    full_last = np.asarray(logits_full[:, -1, :scfg.vocab], np.float32)

    caches = api.init_caches(B, S + extra + 8)
    logits_pre, caches = api.prefill(params, batch, caches)
    pre_last = np.asarray(logits_pre[:, -1, :scfg.vocab], np.float32)
    scale = np.max(np.abs(full_last)) + 1e-9
    assert np.max(np.abs(full_last - pre_last)) / scale < 2e-2

    tok_next = tokens[:, S:S + 1]
    logits_dec, caches = api.decode(params, caches, tok_next,
                                    jnp.asarray(S + extra, jnp.int32))
    batch2 = dict(batch)
    batch2["tokens"] = tokens[:, :S + 1]
    logits_full2, _ = api.forward(params, batch2)
    dec_ref = np.asarray(logits_full2[:, -1, :scfg.vocab], np.float32)
    dec_got = np.asarray(logits_dec[:, -1, :scfg.vocab], np.float32)
    scale2 = np.max(np.abs(dec_ref)) + 1e-9
    assert np.max(np.abs(dec_ref - dec_got)) / scale2 < 2e-2


@pytest.mark.parametrize("arch", ["recurrentgemma_9b", "qwen2_5_3b"])
def test_sliding_window_decode_ring_buffer(arch):
    """Decode far past the window: ring cache must keep only the last
    `window` positions and still match the windowed full forward."""
    scfg = get(arch).smoke().with_(window=8)
    api = build(scfg)
    params = api.init(jax.random.PRNGKey(0))
    B, S = 1, 24
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, scfg.vocab, (B, S + 1)), jnp.int32)

    caches = api.init_caches(B, S + 8)
    _, caches = api.prefill(params, {"tokens": tokens[:, :S]}, caches)
    logits_dec, _ = api.decode(params, caches, tokens[:, S:S + 1],
                               jnp.asarray(S, jnp.int32))
    logits_full, _ = api.forward(params, {"tokens": tokens})
    a = np.asarray(logits_dec[:, -1, :scfg.vocab], np.float32)
    b = np.asarray(logits_full[:, -1, :scfg.vocab], np.float32)
    assert np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-9) < 2e-2
