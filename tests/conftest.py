import os
import sys

import numpy as np
import pytest

# tests see the default single CPU device (the dry-run, and only the
# dry-run, forces 512 host devices in its own process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402  (after the path insert, before any repro import)

# ---------------------------------------------------------------------------
# Strict numerics for the whole suite (ISSUE 9).
#
# Rank promotion is where silent-broadcast bugs live: an (m,) array
# meeting an (m, 1) array quietly produces (m, m) and every downstream
# reduction still "works".  The bitwise-parity contract makes those
# especially nasty — the numbers stay plausible while the reduction
# geometry changes — so the suite runs with promotion as a hard error.
#
# jax_debug_nans re-runs de-optimized on every NaN producer; it is
# opt-in (REPRO_DEBUG_NANS=1) because it disables the jit caching the
# recompile-guard tests count on.
# ---------------------------------------------------------------------------
jax.config.update("jax_numpy_rank_promotion", "raise")
if os.environ.get("REPRO_DEBUG_NANS") == "1":
    jax.config.update("jax_debug_nans", True)

# ---------------------------------------------------------------------------
# THE backend-parity tolerance (ISSUE 7).
#
# One pinned pair for every pallas-vs-reference comparison in the
# suite — predictions, distances, divergences, fused rounds.  The
# kernels accumulate in fp32 with a tile order that differs from the
# jnp oracles, so values agree to a few ULP-amplified rounding steps;
# rtol covers the large-magnitude RKHS distances, atol the near-zero
# hinge margins.  Tests must not carry private tolerances for parity
# checks: loosening THIS number is a reviewed decision, not a local
# tweak.
# ---------------------------------------------------------------------------
PARITY_RTOL = 1e-3
PARITY_ATOL = 5e-3


def assert_backend_parity(got, want, label: str = ""):
    """Assert a pallas-backend value matches its reference-backend
    counterpart within the pinned parity tolerance."""
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want),
        rtol=PARITY_RTOL, atol=PARITY_ATOL, err_msg=label)


@pytest.fixture
def backend_parity():
    """Fixture handing tests the pinned parity assertion helper."""
    return assert_backend_parity
