"""Population layer: churn, partial participation, and the cohort-only
byte ledger, proven against the full-participation oracle (DESIGN.md
Sec. 15).

Four contracts:

1. ORACLE PARITY — a churn-free population (or an all-True
   ``participation`` override) reproduces ``engine.run`` BIT-FOR-BIT
   (losses, errors, bytes, sync decisions) for
   {dynamic, periodic} x {SV, RFF, linear}.
2. SET-ALGEBRA BYTES — the masked device ledger
   (``device_sync_bytes_kernel(mask=...)``,
   ``device_rejoin_bytes_kernel``) equals the pure-Python set-algebra
   oracle (``sync_bytes_kernel`` / ``kernel_payload_bytes`` over the
   cohort-filtered id lists), hypothesis-driven across masks including
   all-on, all-off and single-learner rounds; end-to-end, a primal
   run's byte column equals the closed-form Sec. 3 oracle priced from
   (mask, sync decisions) alone.
3. EMPTY COHORT — a zero-participant round divides nothing by zero,
   emits zero bytes and zero loss, and never synchronizes.
4. DETERMINISM — same spec => byte-identical masks, results and
   Chrome traces, in-process and across ``PYTHONHASHSEED`` subprocesses
   (fixed integer SeedSequence tags, the tests/test_arrivals.py
   contract).
"""
import hashlib
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import accounting, engine
from repro.core.accounting import ByteModel
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.core.rff import RFFSpec
from repro.core.rkhs import KernelSpec
from repro.core.substrate import substrate_of
from repro.data import separable_stream, susy_stream
from repro.population import (ALWAYS_ON, DEFAULT_MIX, PHONE, SLOW,
                              AvailabilityClass, PopulationSpec,
                              class_assignment, participation_masks,
                              rejoin_counts, run_population,
                              trace_population)
from repro.telemetry.monitor import monitor_population
from repro.telemetry.trace import Tracer

T, M, D = 40, 6, 6


def _sv_cfg(budget=8):
    return LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.5, lam=0.01,
                         budget=budget,
                         kernel=KernelSpec("gaussian", gamma=0.3), dim=D)


LEARNERS = {
    "sv": _sv_cfg(),
    "rff": RFFSpec(dim=D, num_features=16, gamma=0.3, seed=0),
    "linear": LearnerConfig(algo="linear_sgd", loss="hinge", eta=0.1,
                            lam=0.001, dim=D),
}

PROTOS = {
    "dynamic": ProtocolConfig(kind="dynamic", delta=1.0),
    "periodic": ProtocolConfig(kind="periodic", period=7),
}

FULL_SPEC = PopulationSpec(m_total=M, classes=((ALWAYS_ON, 1.0),))


def _stream(seed=3):
    return susy_stream(T=T, m=M, d=D, seed=seed)


def _assert_bit_identical(a, b, tag=""):
    for field in ("cumulative_loss", "cumulative_errors", "cumulative_bytes",
                  "sync_rounds", "divergences", "eps_history"):
        x, y = np.asarray(getattr(a, field)), np.asarray(getattr(b, field))
        assert x.tobytes() == y.tobytes(), (tag, field, x, y)
    assert a.num_syncs == b.num_syncs, tag
    assert a.total_bytes == b.total_bytes, tag


# ---------------------------------------------------------------------------
# 1. full participation == engine.run, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("proto", PROTOS)
@pytest.mark.parametrize("name", LEARNERS)
def test_full_participation_bitwise_identical(name, proto):
    """The acceptance gate: a churn-free population through the masked
    scan core reproduces the unmasked engine bit-for-bit."""
    X, Y = _stream()
    learner, pcfg = LEARNERS[name], PROTOS[proto]
    oracle = engine.run(learner, pcfg, X, Y, record_divergence=True)
    pres = run_population(FULL_SPEC, learner, pcfg, X, Y,
                          record_divergence=True)
    assert oracle.num_syncs > 0, "degenerate run proves nothing"
    assert pres.participation.all()
    assert pres.total_rejoins == 0
    _assert_bit_identical(oracle, pres.sim, f"{name}/{proto}")


def test_all_true_override_bitwise_identical():
    """The override path: an explicit all-True mask through a churny
    spec is still the oracle, bit for bit."""
    X, Y = _stream(seed=5)
    spec = PopulationSpec(m_total=M, seed=11)     # churny DEFAULT_MIX
    pcfg = PROTOS["dynamic"]
    lcfg = LEARNERS["linear"]
    oracle = engine.run(lcfg, pcfg, X, Y)
    pres = run_population(spec, lcfg, pcfg, X, Y,
                          participation=np.ones((T, M), bool))
    _assert_bit_identical(oracle, pres.sim, "override")


def test_partial_mask_actually_changes_the_run():
    X, Y = _stream(seed=5)
    pcfg = PROTOS["dynamic"]
    lcfg = LEARNERS["linear"]
    full = engine.run(lcfg, pcfg, X, Y)
    pres = run_population(PopulationSpec(m_total=M, sample_rate=0.6, seed=2),
                          lcfg, pcfg, X, Y)
    assert pres.mean_cohort < M
    assert not np.array_equal(np.asarray(full.cumulative_loss),
                              np.asarray(pres.sim.cumulative_loss))


# ---------------------------------------------------------------------------
# 2a. masked device ledger vs the pure-Python set-algebra oracle
# ---------------------------------------------------------------------------


def _random_ids(rng, m, tau, pool):
    """Random stacked sv_id array mixing empty slots, shared ids and
    fresh ids (the tests/test_engine.py generator)."""
    ids = np.full((m, tau), -1, np.int32)
    for i in range(m):
        n_active = int(rng.integers(0, tau + 1))
        chosen = []
        for _ in range(n_active):
            if pool and rng.random() < 0.6:
                chosen.append(int(rng.choice(pool)))
            else:
                fresh = int(rng.integers(0, 100_000))
                pool.append(fresh)
                chosen.append(fresh)
        slots = rng.permutation(tau)[:n_active]
        ids[i, slots] = chosen
    return ids


def _round_mask(rng, m, t):
    """Random cohort, with the edge shapes forced early: all-on,
    all-off, then a single-learner round."""
    if t == 0:
        return np.ones(m, bool)
    if t == 1:
        return np.zeros(m, bool)
    if t == 2:
        mask = np.zeros(m, bool)
        mask[int(rng.integers(0, m))] = True
        return mask
    return rng.random(m) < rng.random()


def _assert_masked_ledger_agrees(seed, m=4, tau=5, n_syncs=6):
    from repro.core import rkhs

    rng = np.random.default_rng(seed)
    bm = ByteModel(dim=5)
    dev = accounting.device_ledger_init(m * tau)
    known: set = set()
    pool: list = []
    for t in range(n_syncs):
        ids = _random_ids(rng, m, tau, pool)
        mask = _round_mask(rng, m, t)
        cohort = [ids[i] for i in np.where(mask)[0]]
        b_host, known = accounting.sync_bytes_kernel(bm, cohort, known)
        b_dev, dev = accounting.device_sync_bytes_kernel(
            bm, jnp.asarray(ids), dev, mask=jnp.asarray(mask))
        assert int(b_dev) == b_host, (t, mask, int(b_dev), b_host)
    known_dev = np.asarray(dev.known)
    known_dev = set(known_dev[known_dev < int(rkhs.ID_SENTINEL)].tolist())
    assert known_dev == known


@pytest.mark.parametrize("seed", range(6))
def test_masked_sync_bytes_match_set_oracle(seed):
    _assert_masked_ledger_agrees(seed)


def test_masked_sync_bytes_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def inner(seed):
        _assert_masked_ledger_agrees(seed, m=5, tau=4, n_syncs=4)

    inner()


def _assert_rejoin_bytes_agree(seed, m=5, tau=6):
    rng = np.random.default_rng(seed)
    bm = ByteModel(dim=4)
    pool: list = []
    ref = _random_ids(rng, 1, tau, pool)[0]          # reference id row
    ids = _random_ids(rng, m, tau, pool)
    for t in range(4):
        rejoin = _round_mask(rng, m, t)
        ref_set = set(ref[ref >= 0].tolist())
        want = sum(
            accounting.kernel_payload_bytes(
                bm, ref_set, set(ids[i][ids[i] >= 0].tolist()))
            for i in np.where(rejoin)[0])
        got = accounting.device_rejoin_bytes_kernel(
            bm, jnp.asarray(ref), jnp.asarray(ids), jnp.asarray(rejoin))
        assert int(got) == want, (t, rejoin, int(got), want)


@pytest.mark.parametrize("seed", range(4))
def test_rejoin_bytes_match_payload_oracle(seed):
    _assert_rejoin_bytes_agree(seed)


def test_rejoin_bytes_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def inner(seed):
        _assert_rejoin_bytes_agree(seed)

    inner()


# ---------------------------------------------------------------------------
# 2b. end-to-end primal byte column == closed-form Sec. 3 oracle
# ---------------------------------------------------------------------------


def _primal_oracle_bytes(res, mask, num_params, topology):
    """Per-round Sec. 3 bytes priced from (mask, sync decisions) alone:
    every rejoiner downloads |theta| B; a sync moves 2 c_t |theta| B
    (coordinator) or 2 (c_t - 1) |theta| B (ring total)."""
    Tn, _ = mask.shape
    sync_set = {int(t) for t in np.asarray(res.sync_rounds)}
    r = rejoin_counts(mask)
    c = mask.sum(axis=1).astype(np.int64)
    per = np.zeros(Tn, np.int64)
    for t in range(Tn):
        per[t] = int(r[t]) * num_params * 4
        if t in sync_set:
            if topology == "coordinator":
                per[t] += 2 * int(c[t]) * num_params * 4
            else:
                per[t] += 2 * max(int(c[t]) - 1, 0) * num_params * 4
    return np.cumsum(per)


@pytest.mark.parametrize("topology", ["coordinator", "allreduce"])
@pytest.mark.parametrize("name", ["linear", "rff"])
def test_primal_bytes_match_closed_form_oracle(name, topology):
    X, Y = _stream(seed=7)
    learner = LEARNERS[name]
    spec = PopulationSpec(m_total=M, sample_rate=0.7, seed=4)
    pres = run_population(spec, learner,
                          ProtocolConfig(kind="dynamic", delta=0.3), X, Y,
                          topology=topology)
    assert pres.sim.num_syncs > 0
    assert pres.total_rejoins > 0, "churn-free mask proves nothing"
    want = _primal_oracle_bytes(pres.sim, pres.participation,
                                substrate_of(learner).num_params, topology)
    np.testing.assert_array_equal(
        np.asarray(pres.sim.cumulative_bytes, np.int64), want)


def test_periodic_primal_bytes_match_closed_form_oracle():
    X, Y = _stream(seed=9)
    lcfg = LEARNERS["linear"]
    pres = run_population(PopulationSpec(m_total=M, seed=1), lcfg,
                          PROTOS["periodic"], X, Y)
    want = _primal_oracle_bytes(pres.sim, pres.participation,
                                substrate_of(lcfg).num_params, "coordinator")
    np.testing.assert_array_equal(
        np.asarray(pres.sim.cumulative_bytes, np.int64), want)


# ---------------------------------------------------------------------------
# 3. empty-cohort rounds
# ---------------------------------------------------------------------------


def _mask_with_empty_rounds():
    mask = np.ones((T, M), bool)
    mask[0] = True
    mask[5] = False                    # empty round mid-stream
    mask[6] = False                    # and a consecutive one
    mask[12, 1:] = False               # single-learner round
    mask[20:23, ::2] = False           # staggered churn
    return mask


@pytest.mark.parametrize("name", LEARNERS)
@pytest.mark.parametrize("proto", ["dynamic", "periodic", "continuous"])
def test_empty_cohort_rounds_are_inert(name, proto):
    """A zero-participant round must not divide by zero, emit phantom
    bytes, sync, or accrue loss — for every substrate and protocol."""
    X, Y = _stream(seed=2)
    pcfg = (ProtocolConfig(kind="continuous") if proto == "continuous"
            else PROTOS[proto])
    mask = _mask_with_empty_rounds()
    pres = run_population(PopulationSpec(m_total=M), LEARNERS[name], pcfg,
                          X, Y, participation=mask)
    loss = np.asarray(pres.sim.cumulative_loss, np.float64)
    nbytes = np.asarray(pres.sim.cumulative_bytes, np.int64)
    err = np.asarray(pres.sim.cumulative_errors, np.float64)
    assert np.isfinite(loss).all(), name
    for t in (5, 6):
        assert t not in set(int(s) for s in pres.sim.sync_rounds)
        assert loss[t] == loss[t - 1], (name, proto)
        assert err[t] == err[t - 1], (name, proto)
        # empty round: no sync, no rejoins (mask[6] has none) => 0 bytes
        if t == 6:
            assert nbytes[t] == nbytes[t - 1], (name, proto)


@pytest.mark.parametrize("name", LEARNERS)
def test_fully_idle_population(name):
    """Every round empty: zero loss, zero bytes, zero syncs — and the
    monitor trivially holds at cohort 1."""
    X, Y = _stream(seed=2)
    pres = run_population(PopulationSpec(m_total=M), LEARNERS[name],
                          PROTOS["dynamic"], X, Y,
                          participation=np.zeros((T, M), bool))
    assert pres.sim.total_bytes == 0
    assert pres.sim.num_syncs == 0
    assert float(pres.sim.total_loss) == 0.0
    assert np.isfinite(np.asarray(pres.sim.cumulative_loss)).all()
    mon = monitor_population(pres, LEARNERS[name])
    assert mon.ok and mon.m == 1


@pytest.mark.parametrize("name", LEARNERS)
def test_average_stacked_masked_empty_cohort_is_finite(name):
    """The division guard itself: averaging an empty cohort must not
    produce NaN (cnt is clamped before the divide)."""
    import jax

    sub = substrate_of(LEARNERS[name])
    models = sub.models_of(sub.init(M))
    avg, _ = sub.average_stacked_masked(models, jnp.zeros(M, bool))
    for leaf in jax.tree.leaves(avg):
        leaf = np.asarray(leaf)
        if leaf.dtype.kind == "f":
            assert np.isfinite(leaf).all(), name


# ---------------------------------------------------------------------------
# 4. determinism: masks, results, traces — in- and cross-process
# ---------------------------------------------------------------------------


def test_participation_masks_byte_identical_under_seed():
    spec = PopulationSpec(m_total=64, seed=7)
    a = participation_masks(spec, 20)
    b = participation_masks(spec, 20)
    assert a.tobytes() == b.tobytes()
    other = participation_masks(
        PopulationSpec(m_total=64, seed=8), 20)
    assert other.tobytes() != a.tobytes()


def test_population_run_and_trace_byte_identical_under_seed():
    X, Y = _stream(seed=5)
    spec = PopulationSpec(m_total=M, sample_rate=0.8, seed=3)

    def go():
        pres = run_population(spec, LEARNERS["linear"], PROTOS["dynamic"],
                              X, Y)
        tr = Tracer()
        trace_population(pres, tr)
        mon = monitor_population(pres, LEARNERS["linear"])
        mon.emit(tr)
        return pres, tr.to_json()

    p1, j1 = go()
    p2, j2 = go()
    _assert_bit_identical(p1.sim, p2.sim, "rerun")
    assert p1.participation.tobytes() == p2.participation.tobytes()
    assert j1 == j2
    p3 = run_population(
        PopulationSpec(m_total=M, sample_rate=0.8, seed=4),
        LEARNERS["linear"], PROTOS["dynamic"], X, Y)
    assert p3.participation.tobytes() != p1.participation.tobytes()


def test_population_deterministic_across_processes():
    """PYTHONHASHSEED must not reach any population draw: a fresh
    interpreter reproduces masks AND the full run byte-for-byte."""
    X, Y = _stream(seed=5)
    spec = PopulationSpec(m_total=M, sample_rate=0.8, seed=3)
    pres = run_population(spec, LEARNERS["linear"], PROTOS["dynamic"], X, Y)
    d_mask = hashlib.sha256(pres.participation.tobytes()).hexdigest()
    d_bytes = hashlib.sha256(
        np.asarray(pres.sim.cumulative_bytes, np.int64).tobytes()).hexdigest()
    d_loss = hashlib.sha256(
        np.asarray(pres.sim.cumulative_loss).tobytes()).hexdigest()
    script = textwrap.dedent(f"""
        import hashlib
        import numpy as np
        from repro.core.learners import LearnerConfig
        from repro.core.protocol import ProtocolConfig
        from repro.data import susy_stream
        from repro.population import PopulationSpec, run_population
        X, Y = susy_stream(T={T}, m={M}, d={D}, seed=5)
        spec = PopulationSpec(m_total={M}, sample_rate=0.8, seed=3)
        lcfg = LearnerConfig(algo="linear_sgd", loss="hinge", eta=0.1,
                             lam=0.001, dim={D})
        pres = run_population(spec, lcfg,
                              ProtocolConfig(kind="dynamic", delta=1.0), X, Y)
        print("mask", hashlib.sha256(
            pres.participation.tobytes()).hexdigest())
        print("bytes", hashlib.sha256(np.asarray(
            pres.sim.cumulative_bytes, np.int64).tobytes()).hexdigest())
        print("loss", hashlib.sha256(np.asarray(
            pres.sim.cumulative_loss).tobytes()).hexdigest())
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONHASHSEED"] = "99"
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    got = dict(line.split() for line in out.stdout.strip().splitlines())
    assert got["mask"] == d_mask
    assert got["bytes"] == d_bytes
    assert got["loss"] == d_loss


# ---------------------------------------------------------------------------
# availability-model unit contracts
# ---------------------------------------------------------------------------


def test_class_assignment_exact_histogram():
    spec = PopulationSpec(m_total=103, seed=0)     # fractions don't divide
    ids = class_assignment(spec)
    assert ids.shape == (103,)
    counts = np.bincount(ids, minlength=len(spec.classes))
    assert counts.sum() == 103
    # largest-remainder: every class within 1 of its exact share
    for k, (_, frac) in enumerate(spec.classes):
        assert abs(counts[k] - frac * 103) < 1.0 + 1e-9
    # deterministic
    assert np.array_equal(ids, class_assignment(spec))


def test_stationary_on_and_validation():
    assert ALWAYS_ON.stationary_on == 1.0
    assert PHONE.stationary_on == pytest.approx(0.35 / 0.50)
    assert SLOW.speed == 0.5
    with pytest.raises(ValueError):
        AvailabilityClass("bad", p_drop=1.5)
    with pytest.raises(ValueError):
        PopulationSpec(m_total=0)
    with pytest.raises(ValueError):
        PopulationSpec(m_total=4, sample_rate=0.0)
    with pytest.raises(ValueError):
        PopulationSpec(m_total=4, classes=((ALWAYS_ON, 0.5),))
    with pytest.raises(ValueError):
        participation_masks(PopulationSpec(m_total=4), 0)


def test_rejoin_counts_convention():
    mask = np.asarray([[1, 0, 0],
                       [1, 1, 0],      # learner 1 rejoins
                       [0, 1, 1],      # learner 2 rejoins
                       [1, 1, 1]],     # learner 0 rejoins
                      bool)
    np.testing.assert_array_equal(rejoin_counts(mask), [0, 1, 1, 1])


def test_churn_rates_track_the_class_mix():
    """Statistical sanity on a big deterministic draw: the realized
    on-fraction of each class sits near stationary_on * speed."""
    spec = PopulationSpec(m_total=4000, seed=0)
    mask = participation_masks(spec, 50)
    ids = class_assignment(spec)
    for k, (cls, _) in enumerate(spec.classes):
        realized = mask[10:, ids == k].mean()      # past burn-in
        expect = cls.stationary_on * cls.speed
        assert abs(realized - expect) < 0.05, (cls.name, realized, expect)


def test_run_population_validates_shapes():
    X, Y = separable_stream(T=5, m=3, d=4, seed=0)
    lcfg = LearnerConfig(algo="linear_sgd", loss="hinge", dim=4)
    with pytest.raises(ValueError, match="m_total"):
        run_population(PopulationSpec(m_total=7), lcfg, PROTOS["dynamic"],
                       X, Y)
    with pytest.raises(ValueError, match="participation"):
        run_population(PopulationSpec(m_total=3), lcfg, PROTOS["dynamic"],
                       X, Y, participation=np.ones((4, 3), bool))
    with pytest.raises(ValueError, match="participation"):
        engine.run(lcfg, PROTOS["dynamic"], X, Y,
                   participation=np.ones((5, 2), bool))


# ---------------------------------------------------------------------------
# monitor: Def. 1 priced at the largest cohort, integer-exact bytes
# ---------------------------------------------------------------------------


def test_monitor_population_integer_exact_and_cohort_priced():
    X, Y = _stream(seed=5)
    spec = PopulationSpec(m_total=M, sample_rate=0.8, seed=3)
    pres = run_population(spec, LEARNERS["linear"], PROTOS["dynamic"], X, Y)
    mon = monitor_population(pres, LEARNERS["linear"])
    assert mon.m == int(pres.cohort_sizes.max())
    series = mon.series()
    np.testing.assert_array_equal(
        series.cumulative_bytes,
        np.asarray(pres.sim.cumulative_bytes, np.int64))
    assert series.cumulative_loss.tobytes() == np.asarray(
        pres.sim.cumulative_loss, np.float64).tobytes()
    assert mon.ok


# ---------------------------------------------------------------------------
# mesh: masked runs shard like unmasked ones (subprocess, 8 devices)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import numpy as np

    from repro.core import engine
    from repro.core.learners import LearnerConfig
    from repro.core.protocol import ProtocolConfig
    from repro.core.rff import RFFSpec
    from repro.core.rkhs import KernelSpec
    from repro.data import susy_stream
    from repro.launch.mesh import make_learner_mesh
    from repro.population import PopulationSpec, run_population

    assert len(jax.devices()) == 8
    mesh = make_learner_mesh()
    T, M, D = 30, 8, 6
    X, Y = susy_stream(T=T, m=M, d=D, seed=3)
    spec = PopulationSpec(m_total=M, sample_rate=0.8, seed=5)
    pcfg = ProtocolConfig(kind="dynamic", delta=1.0)

    learners = [
        ("sv", LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.5,
                             lam=0.01, budget=8,
                             kernel=KernelSpec("gaussian", gamma=0.3), dim=D)),
        ("rff", RFFSpec(dim=D, num_features=16, gamma=0.3, seed=0)),
        ("linear", LearnerConfig(algo="linear_sgd", loss="hinge", eta=0.1,
                                 lam=0.001, dim=D)),
    ]
    for name, learner in learners:
        p1 = run_population(spec, learner, pcfg, X, Y)
        p8 = run_population(spec, learner, pcfg, X, Y, mesh=mesh)
        assert p1.total_rejoins > 0, name
        for field in ("cumulative_loss", "cumulative_errors",
                      "cumulative_bytes", "sync_rounds"):
            a = np.asarray(getattr(p1.sim, field))
            b = np.asarray(getattr(p8.sim, field))
            assert a.tobytes() == b.tobytes(), (name, field)
        assert p1.sim.total_bytes == p8.sim.total_bytes, name
    print("OK population mesh parity")
""")


@pytest.mark.slow
def test_masked_engine_matches_single_device_on_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK population mesh parity" in r.stdout
