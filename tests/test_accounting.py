"""Byte-model semantics pins (core/accounting.py).

Three contracts that previously had no direct tests:

- ``allreduce_bytes`` returns the m-participant ring TOTAL
  ``2 (m-1) |theta| B`` (per-participant cost is ``2 (m-1)/m |theta|
  B`` — a caller comparing against coordinator totals must NOT divide
  or multiply by m again), related to ``sync_bytes_linear`` by the
  ratio ``(m-1)/m`` per direction;
- the ``device_sync_bytes_kernel`` int32 guard raises exactly at the
  documented ``m * tau * (B_alpha + B_x) * (m + 1) >= 2**31`` boundary;
- host-side cumulative byte accounting stays int64 end to end
  (``SweepResult`` / ``SimResult``).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import accounting, engine
from repro.core.accounting import ByteModel
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.data import separable_stream


# ---------------------------------------------------------------------------
# allreduce_bytes: total semantics, pinned against sync_bytes_linear
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m", [2, 3, 8, 64])
@pytest.mark.parametrize("num_params", [9, 257])
def test_allreduce_bytes_is_ring_total(m, num_params):
    ring = accounting.allreduce_bytes(num_params, m)
    coord = accounting.sync_bytes_linear(num_params, m)
    # total 2 (m-1) |theta| B, NOT the per-participant 2 (m-1)/m |theta| B
    assert ring == 2 * (m - 1) * num_params * 4
    # per direction the ring moves an (m-1)/m fraction of the
    # coordinator's bytes: ring/coord == (m-1)/m exactly
    assert ring * m == coord * (m - 1)
    assert ring < coord


def test_allreduce_bytes_degenerate():
    assert accounting.allreduce_bytes(100, 1) == 0
    assert accounting.allreduce_bytes(100, 0) == 0
    assert accounting.allgather_bytes(100, 1) == 0


def test_allgather_bytes_total():
    # each of m participants receives the other m-1 shards
    assert accounting.allgather_bytes(10, 4) == 4 * 3 * 10


# ---------------------------------------------------------------------------
# device ledger int32 guard: exact boundary
# ---------------------------------------------------------------------------


def _worst(m, tau, bm):
    return m * tau * (bm.B_alpha + bm.B_x) * (m + 1)


def test_overflow_guard_boundary_exact():
    # B_alpha + B_x = 4*dim + 12; dim=253 makes it exactly 1024, so
    # m=1, tau=2**20 puts the worst case at exactly 2**31 (must raise)
    # and tau=2**20 - 1 one step below it (must run).
    bm = ByteModel(dim=253)
    assert bm.B_alpha + bm.B_x == 1024
    m, tau = 1, 2**20
    assert _worst(m, tau, bm) == 2**31

    ids = np.full((m, tau), -1, np.int32)
    ledger = accounting.device_ledger_init(m * tau)
    with pytest.raises(ValueError, match="int32"):
        accounting.device_sync_bytes_kernel(bm, jnp.asarray(ids), ledger)

    tau_ok = 2**20 - 1
    assert _worst(m, tau_ok, bm) < 2**31
    ledger = accounting.device_ledger_init(m * tau_ok)
    b, ledger = accounting.device_sync_bytes_kernel(
        bm, jnp.asarray(ids[:, :tau_ok]), ledger)
    assert int(b) == 0  # all slots empty: nothing shipped


def test_overflow_guard_boundary_multi_learner():
    # m=2: worst = 6 * tau * (B_alpha + B_x); dim=100000 crosses 2**31
    # between tau=894 and tau=895.
    bm = ByteModel(dim=100_000)
    m = 2
    assert _worst(m, 894, bm) < 2**31 <= _worst(m, 895, bm)

    ids = np.full((m, 895), -1, np.int32)
    with pytest.raises(ValueError, match="int32"):
        accounting.device_sync_bytes_kernel(
            bm, jnp.asarray(ids), accounting.device_ledger_init(m * 895))
    ids = np.arange(m * 894, dtype=np.int32).reshape(m, 894)
    b, _ = accounting.device_sync_bytes_kernel(
        bm, jnp.asarray(ids), accounting.device_ledger_init(m * 894))
    host = accounting.CommunicationLedger(bm)
    assert int(b) == host.record_kernel_sync([ids[i] for i in range(m)], 0)


# ---------------------------------------------------------------------------
# int64 on the host side, end to end
# ---------------------------------------------------------------------------


def test_cumulative_bytes_stay_int64_through_sweep():
    lcfg = LearnerConfig(algo="linear_sgd", loss="hinge", eta=0.1,
                         lam=0.001, dim=6)
    X, Y = separable_stream(T=30, m=3, d=6, seed=0)
    grid = [ProtocolConfig(kind="continuous"),
            ProtocolConfig(kind="periodic", period=5)]
    sw = engine.sweep(lcfg, grid, X, Y)
    assert sw.round_bytes.dtype == np.int64
    for i in range(len(grid)):
        res = sw[i]
        assert res.cumulative_bytes.dtype == np.int64
        assert isinstance(res.total_bytes, int)
        # per-round int32 device values, host cumsum in int64
        assert res.cumulative_bytes[-1] == res.total_bytes
