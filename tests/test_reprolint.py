"""reprolint rule-engine tests (DESIGN.md Sec. 14).

Golden positive/negative snippets per rule, suppression-comment and
baseline round-trips, CLI exit codes, and the self-check that the
committed baseline matches a fresh scan of the working tree.

The snippets are scanned under synthetic repo-relative paths so the
rules' scope predicates engage (e.g. DET01 only fires under
``repro/core/``); path choice is part of each golden case.
"""
from __future__ import annotations

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))

from tools.reprolint import ALL_RULES, RULE_IDS  # noqa: E402
from tools.reprolint.engine import (DEFAULT_BASELINE, Finding,  # noqa: E402
                                    load_baseline, save_baseline,
                                    scan_paths, scan_source)

CORE = "src/repro/core/golden.py"          # in every bitwise scope
RUNTIME = "src/repro/runtime/golden.py"    # clock-owned scope
OUTSIDE = "benchmarks/golden.py"           # outside DET/CLK/JIT scopes


def lint(src: str, path: str = CORE):
    return scan_source(textwrap.dedent(src), path, ALL_RULES)


def rules_of(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# registry sanity
# ---------------------------------------------------------------------------


def test_registry_has_at_least_five_rules():
    assert len(ALL_RULES) >= 5
    assert len(set(RULE_IDS)) == len(RULE_IDS)
    for rule in ALL_RULES:
        assert rule.id and rule.title


# ---------------------------------------------------------------------------
# DET01 — layout-dependent contractions
# ---------------------------------------------------------------------------


DET_POSITIVES = [
    "def f(a, K):\n    return a @ K\n",
    "import jax.numpy as jnp\ndef f(a, b):\n    return jnp.dot(a, b)\n",
    "import jax.numpy as jnp\ndef f(a, b):\n    return jnp.einsum('i,i->', a, b)\n",
    "import numpy as np\ndef f(a, b):\n    return np.matmul(a, b)\n",
]


@pytest.mark.parametrize("src", DET_POSITIVES)
def test_det01_positive(src):
    assert "DET01" in rules_of(lint(src))


def test_det01_negative_multiply_reduce():
    src = """
    import jax.numpy as jnp
    def f(K, a):
        return jnp.sum(a * jnp.sum(K * a[None, :], axis=-1))
    """
    assert "DET01" not in rules_of(lint(src))


def test_det01_out_of_scope_module_not_flagged():
    assert "DET01" not in rules_of(lint("def f(a, K):\n    return a @ K\n",
                                        path=OUTSIDE))


# ---------------------------------------------------------------------------
# CLK01 — wall clock + global randomness
# ---------------------------------------------------------------------------


def test_clk01_positive_wall_clock():
    src = "import time\ndef f():\n    return time.time()\n"
    assert "CLK01" in rules_of(lint(src, path=RUNTIME))


def test_clk01_positive_global_np_random_anywhere():
    src = "import numpy as np\ndef f():\n    return np.random.rand(3)\n"
    assert "CLK01" in rules_of(lint(src, path=OUTSIDE))


def test_clk01_positive_stdlib_random():
    src = "import random\ndef f():\n    return random.randint(0, 9)\n"
    assert "CLK01" in rules_of(lint(src, path=OUTSIDE))


def test_clk01_negative_perf_counter_and_seeded_rng():
    src = """
    import time
    import numpy as np
    def f(seed):
        t0 = time.perf_counter()
        rng = np.random.default_rng(np.random.SeedSequence([seed, 1]))
        return rng.normal(), time.perf_counter() - t0
    """
    assert "CLK01" not in rules_of(lint(src, path=RUNTIME))


def test_clk01_negative_wall_clock_outside_clock_scope():
    src = "import time\ndef f():\n    return time.time()\n"
    assert "CLK01" not in rules_of(lint(src, path=OUTSIDE))


# ---------------------------------------------------------------------------
# JIT01 — host syncs inside jit-traced roots
# ---------------------------------------------------------------------------


def test_jit01_positive_jitted_function():
    src = """
    import jax
    import numpy as np
    @jax.jit
    def f(x):
        return np.asarray(x)
    """
    assert "JIT01" in rules_of(lint(src))


def test_jit01_positive_scan_body():
    src = """
    from jax import lax
    def step(carry, xt):
        print(carry)
        return carry, xt
    def run(xs):
        return lax.scan(step, 0, xs)
    """
    assert "JIT01" in rules_of(lint(src))


def test_jit01_positive_substrate_scan_face():
    src = """
    class MySubstrate:
        def predict(self, models, x):
            return float(x)
    """
    assert "JIT01" in rules_of(lint(src))


def test_jit01_positive_item_sync():
    src = """
    import jax
    @jax.jit
    def f(x):
        return x.item()
    """
    assert "JIT01" in rules_of(lint(src))


def test_jit01_negative_host_side_numpy():
    # not a jit root: free host-side function, numpy is fine
    src = """
    import numpy as np
    def snapshot(bufs, t, model):
        bufs[t] = np.asarray(model)
    """
    assert "JIT01" not in rules_of(lint(src))


def test_jit01_negative_node_face_method():
    # node-face Substrate methods are host-side by design
    src = """
    import numpy as np
    class MySubstrate:
        def upload_payload(self, bm, state, known):
            return np.asarray(state)
    """
    assert "JIT01" not in rules_of(lint(src))


def test_jit01_negative_float_of_static():
    # float() of a non-parameter (static/global) value is a trace-time
    # constant, not a host sync
    src = """
    import jax
    LR = "0.5"
    @jax.jit
    def f(x):
        return x * float(LR)
    """
    assert "JIT01" not in rules_of(lint(src))


# ---------------------------------------------------------------------------
# ACC01 — byte-ledger float contamination
# ---------------------------------------------------------------------------


def test_acc01_positive_epsilon_slop_comparison():
    src = "def check(total_bytes, bound):\n    return total_bytes <= bound + 1e-9\n"
    assert "ACC01" in rules_of(lint(src))


def test_acc01_positive_float_literal_arithmetic():
    src = "def cost(model_bytes, m):\n    return 2.0 * m * model_bytes\n"
    assert "ACC01" in rules_of(lint(src))


def test_acc01_positive_float_cast():
    src = "def report(res):\n    return float(res.total_bytes)\n"
    assert "ACC01" in rules_of(lint(src))


def test_acc01_positive_int32_in_bytes_function():
    src = """
    import jax.numpy as jnp
    def sync_bytes_kernel(total):
        return total.astype(jnp.int32)
    """
    assert "ACC01" in rules_of(lint(src))


def test_acc01_positive_float_astype_on_bytes():
    # the population-layer temptation: cohort-mask the byte column by
    # casting it float before a reduction (DESIGN.md Sec. 15)
    src = """
    import jax.numpy as jnp
    def cohort_cost(round_bytes, mask):
        return jnp.sum(jnp.where(mask, round_bytes.astype(jnp.float32), 0))
    """
    assert "ACC01" in rules_of(lint(src))


def test_acc01_positive_mean_over_bytes():
    src = """
    import jax.numpy as jnp
    def per_learner(cum_bytes):
        return jnp.mean(cum_bytes)
    """
    assert "ACC01" in rules_of(lint(src))


def test_acc01_positive_bytes_mean_method():
    src = "def report(res):\n    return res.cumulative_bytes.mean()\n"
    assert "ACC01" in rules_of(lint(src))


def test_acc01_negative_masked_integer_cohort_bytes():
    # the correct population shape: integer where-select, integer sum,
    # int64 widening — nothing to flag
    src = """
    import jax.numpy as jnp
    def cohort_cost(round_bytes, mask):
        kept = jnp.where(mask, round_bytes, 0)
        return jnp.sum(kept).astype(jnp.int64)
    def mean_loss(losses, mask):
        return jnp.mean(jnp.where(mask, losses, 0.0))
    """
    assert "ACC01" not in rules_of(lint(src))


def test_acc01_negative_integer_exact():
    src = """
    def check(total_bytes, bound):
        return total_bytes <= bound
    def cost(model_bytes, m):
        return 2 * m * model_bytes
    """
    assert "ACC01" not in rules_of(lint(src))


def test_acc01_negative_float_math_without_bytes():
    src = "def ratio(a, b):\n    return a / max(b, 1e-9)\n"
    assert "ACC01" not in rules_of(lint(src))


# ---------------------------------------------------------------------------
# REC01 — recompile hazards
# ---------------------------------------------------------------------------


def test_rec01_positive_mutable_default_factory():
    src = """
    import dataclasses
    @dataclasses.dataclass(frozen=True)
    class Spec:
        tags: list = dataclasses.field(default_factory=list)
    """
    assert "REC01" in rules_of(lint(src))


def test_rec01_positive_unhashable_annotation():
    src = """
    from dataclasses import dataclass
    from typing import Dict
    @dataclass(frozen=True)
    class Spec:
        table: Dict[str, int]
    """
    assert "REC01" in rules_of(lint(src))


def test_rec01_positive_dict_literal_to_jitted_entry():
    src = """
    import jax
    def f(opts, x):
        return x
    step = jax.jit(f)
    def run(x):
        return step({"lr": 0.5}, x)
    """
    assert "REC01" in rules_of(lint(src))


def test_rec01_negative_unfrozen_dataclass_mutable_default():
    # not frozen => not a jit cache key; serving's Request does this
    src = """
    import dataclasses
    @dataclasses.dataclass
    class Request:
        output: list = dataclasses.field(default_factory=list)
    """
    assert "REC01" not in rules_of(lint(src))


def test_rec01_negative_frozen_hashable_fields():
    src = """
    from dataclasses import dataclass
    from typing import Tuple
    @dataclass(frozen=True)
    class Spec:
        dims: Tuple[int, ...] = (1,)
        name: str = "x"
    """
    assert "REC01" not in rules_of(lint(src))


# ---------------------------------------------------------------------------
# suppression comments
# ---------------------------------------------------------------------------


def test_allow_same_line_suppresses():
    src = ("def f(a, K):\n"
           "    return a @ K  # reprolint: allow[DET01] documented oracle\n")
    assert "DET01" not in rules_of(lint(src))


def test_allow_line_above_suppresses():
    src = ("def f(a, K):\n"
           "    # reprolint: allow[DET01] documented oracle\n"
           "    return a @ K\n")
    assert "DET01" not in rules_of(lint(src))


def test_allow_wrong_rule_does_not_suppress():
    src = ("def f(a, K):\n"
           "    return a @ K  # reprolint: allow[CLK01] wrong id\n")
    assert "DET01" in rules_of(lint(src))


def test_allow_without_reason_does_not_suppress_and_is_flagged():
    src = ("def f(a, K):\n"
           "    return a @ K  # reprolint: allow[DET01]\n")
    found = lint(src)
    assert "DET01" in rules_of(found)       # not suppressed
    assert "SUP00" in rules_of(found)       # and the bare allow is loud


def test_allow_multiple_ids_one_comment():
    src = ("import time\n"
           "def f(a, K):\n"
           "    # reprolint: allow[DET01,CLK01] measured oracle timing\n"
           "    return a @ K, time.time()\n")
    assert rules_of(lint(src, path=RUNTIME)) == set()


# ---------------------------------------------------------------------------
# baseline round-trip
# ---------------------------------------------------------------------------


def _findings_for(src: str, path: str = CORE):
    return scan_source(textwrap.dedent(src), path, ALL_RULES)


def test_baseline_round_trip(tmp_path):
    findings = _findings_for("def f(a, K):\n    return a @ K\n")
    assert findings
    bl = tmp_path / "baseline.json"
    save_baseline(bl, findings, {findings[0].fingerprint(): "legacy gemm"})
    entries = load_baseline(bl)
    assert [e.fingerprint() for e in entries] \
        == [f.fingerprint() for f in findings]
    assert entries[0].reason == "legacy gemm"


def test_baseline_fingerprint_survives_line_moves():
    a = _findings_for("def f(a, K):\n    return a @ K\n")
    b = _findings_for("\n\n# moved down\ndef f(a, K):\n    return a @ K\n")
    assert a[0].line != b[0].line
    assert a[0].fingerprint() == b[0].fingerprint()


def test_baseline_detects_stale_entries(tmp_path):
    findings = _findings_for("def f(a, K):\n    return a @ K\n")
    bl = tmp_path / "baseline.json"
    save_baseline(bl, findings)
    # the offending code is gone; the baseline entry is now stale
    fresh = _findings_for("def f(a, K):\n    return a * K\n")
    seen = {f.fingerprint() for f in fresh}
    stale = [e for e in load_baseline(bl) if e.fingerprint() not in seen]
    assert len(stale) == 1


# ---------------------------------------------------------------------------
# CLI exit codes
# ---------------------------------------------------------------------------


def _cli(*args, cwd=REPO):
    return subprocess.run(
        [sys.executable, "-m", "tools.reprolint", *args],
        cwd=cwd, capture_output=True, text=True)


def test_cli_exit_zero_on_clean_tree_with_baseline():
    proc = _cli("src", "tests", "benchmarks", "tools")
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_exit_one_on_new_finding(tmp_path):
    bad = tmp_path / "src" / "repro" / "core" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("def f(a, K):\n    return a @ K\n", encoding="utf-8")
    proc = subprocess.run(
        [sys.executable, "-m", "tools.reprolint",
         str(bad), "--no-baseline"],
        cwd=REPO, capture_output=True, text=True)
    assert proc.returncode == 1
    assert "DET01" in proc.stderr


def test_cli_exit_two_on_usage_error():
    proc = _cli("--no-such-flag")
    assert proc.returncode == 2


def test_cli_list_rules():
    proc = _cli("--list-rules")
    assert proc.returncode == 0
    for rid in RULE_IDS:
        assert rid in proc.stdout


# ---------------------------------------------------------------------------
# committed baseline self-check
# ---------------------------------------------------------------------------


def test_committed_baseline_matches_fresh_scan():
    """Every committed baseline entry must still correspond to a real
    finding (no stale grandfathering), every entry carries a real
    reason, and the scan must produce nothing outside the baseline."""
    findings = scan_paths(["src", "tests", "benchmarks", "tools"],
                          ALL_RULES, root=REPO)
    fresh = {f.fingerprint() for f in findings}
    entries = load_baseline(DEFAULT_BASELINE)
    known = {e.fingerprint() for e in entries}
    assert fresh - known == set(), \
        f"non-baselined findings: {sorted(fresh - known)}"
    assert known - fresh == set(), \
        f"stale baseline entries: {sorted(known - fresh)}"
    for e in entries:
        assert e.reason and "add a real reason" not in e.reason, \
            f"baseline entry without a reason: {e}"
