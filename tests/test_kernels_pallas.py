"""Per-kernel validation: Pallas (interpret mode on CPU) vs pure-jnp
oracle, swept over shapes and dtypes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [(128, 128, 8), (256, 384, 130), (100, 200, 7), (513, 129, 64),
          (64, 64, 3)]
KINDS = ["gaussian", "linear", "poly"]
DTYPES = [jnp.float32, jnp.bfloat16]


def _data(M, N, d, dtype, seed=0):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(M, d)), dtype)
    Y = jnp.asarray(rng.normal(size=(N, d)), dtype)
    a = jnp.asarray(rng.normal(size=(M,)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(N,)), jnp.float32)
    return X, Y, a, b


@pytest.mark.parametrize("M,N,d", SHAPES)
@pytest.mark.parametrize("kind", KINDS)
def test_gram_matches_oracle(M, N, d, kind):
    X, Y, _, _ = _data(M, N, d, jnp.float32)
    got = ops.gram(X, Y, kind=kind, gamma=0.5, force_pallas=True)
    want = ref.gram_ref(X, Y, kind=kind, gamma=0.5)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", DTYPES)
def test_gram_dtypes(dtype):
    X, Y, _, _ = _data(128, 128, 16, dtype)
    got = ops.gram(X, Y, kind="gaussian", gamma=1.0, force_pallas=True)
    want = ref.gram_ref(X, Y, kind="gaussian", gamma=1.0)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@pytest.mark.parametrize("M,N,d", SHAPES)
@pytest.mark.parametrize("kind", KINDS)
def test_quadform_matches_oracle(M, N, d, kind):
    X, Y, a, b = _data(M, N, d, jnp.float32)
    got = ops.quadform(X, Y, a, b, kind=kind, gamma=0.5, force_pallas=True)
    want = ref.quadform_ref(X, Y, a, b, kind=kind, gamma=0.5)
    np.testing.assert_allclose(got, want, rtol=5e-4,
                               atol=5e-3 * max(1.0, abs(float(want))))


@pytest.mark.parametrize("M,N,d", SHAPES)
def test_rff_matches_oracle(M, N, d):
    rng = np.random.default_rng(1)
    X = jnp.asarray(rng.normal(size=(M, d)), jnp.float32)
    W = jnp.asarray(rng.normal(size=(N, d)), jnp.float32)
    b = jnp.asarray(rng.uniform(size=(N,)) * 6.28, jnp.float32)
    got = ops.rff_features(X, W, b, force_pallas=True)
    want = ref.rff_ref(X, W, b)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_rff_approximates_gaussian_kernel():
    """E[phi(x).phi(y)] -> k(x,y): the RFF contract (Rahimi-Recht)."""
    rng = np.random.default_rng(2)
    d, D = 4, 4096
    gamma = 0.7
    X = jnp.asarray(rng.normal(size=(16, d)), jnp.float32)
    W = jnp.asarray(rng.normal(size=(D, d)) * np.sqrt(2 * gamma), jnp.float32)
    b = jnp.asarray(rng.uniform(size=(D,)) * 2 * np.pi, jnp.float32)
    Z = ops.rff_features(X, W, b, force_pallas=True)
    K_hat = np.asarray(Z @ Z.T)
    K = np.asarray(ref.gram_ref(X, X, kind="gaussian", gamma=gamma))
    assert np.max(np.abs(K_hat - K)) < 0.12


def test_rkhs_dist_sq_fused():
    rng = np.random.default_rng(3)
    X = jnp.asarray(rng.normal(size=(130, 9)), jnp.float32)
    Y = jnp.asarray(rng.normal(size=(200, 9)), jnp.float32)
    a = jnp.asarray(rng.normal(size=(130,)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(200,)), jnp.float32)
    got = ops.rkhs_dist_sq(X, Y, a, b, kind="gaussian", gamma=0.5)
    Kxx = ref.gram_ref(X, X, gamma=0.5)
    Kyy = ref.gram_ref(Y, Y, gamma=0.5)
    Kxy = ref.gram_ref(X, Y, gamma=0.5)
    want = a @ Kxx @ a + b @ Kyy @ b - 2 * (a @ Kxy @ b)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-4, atol=1e-3)


# --- substrate backend dispatch (core/substrate.py, DESIGN.md Sec. 8) -------
#
# The substrate layer's backend="pallas" routes predict / dist_to_ref /
# divergence through ops.gram / quadform / rff_features.  These tests
# pin the interpret-mode Pallas kernels against the substrate's
# *reference* paths (the pure-jnp semantics in core/rkhs.py and
# core/rff.py), tolerance-bounded, on shapes large enough that the
# Pallas launch actually engages (>= 128, see ops._MIN_PALLAS).


def _sv_fixture(m=2, budget=130, d=9, seed=5):
    from repro.core.learners import LearnerConfig
    from repro.core.rkhs import KernelSpec, SVModel
    from repro.core.substrate import SVSubstrate
    rng = np.random.default_rng(seed)

    def one():
        active = rng.random(budget) < 0.8
        return SVModel(
            sv=jnp.asarray(rng.normal(size=(budget, d)), jnp.float32),
            alpha=jnp.asarray(rng.normal(size=(budget,)), jnp.float32),
            sv_id=jnp.asarray(np.where(active, np.arange(budget), -1),
                              jnp.int32))

    models = SVModel(*[jnp.stack(parts) for parts in
                       zip(*[tuple(one()) for _ in range(m)])])
    ref_model = one()
    lcfg = LearnerConfig(algo="kernel_sgd", budget=budget,
                         kernel=KernelSpec("gaussian", gamma=0.4), dim=d)
    return (SVSubstrate(lcfg=lcfg),
            SVSubstrate(lcfg=lcfg, backend="pallas"),
            models, ref_model, rng)


def test_substrate_predict_pallas_vs_reference():
    s_ref, s_pal, models, _, rng = _sv_fixture()
    x = jnp.asarray(rng.normal(size=(2, 9)), jnp.float32)
    got = s_pal.predict(models, x)
    want = s_ref.predict(models, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_substrate_dist_to_ref_pallas_vs_reference():
    s_ref, s_pal, models, ref_model, _ = _sv_fixture()
    got = s_pal.dist_to_ref(models, ref_model)
    want = s_ref.dist_to_ref(models, ref_model)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-4, atol=5e-3)


def test_substrate_divergence_pallas_vs_reference():
    s_ref, s_pal, models, _, _ = _sv_fixture()
    got, want = s_pal.divergence(models), s_ref.divergence(models)
    np.testing.assert_allclose(float(got), float(want), rtol=5e-4, atol=5e-3)


def test_substrate_rff_features_pallas_vs_reference():
    from repro.core.rff import RFFSpec
    from repro.core.substrate import RFFSubstrate
    spec = RFFSpec(dim=8, num_features=256, gamma=0.5, seed=1)
    s_ref = RFFSubstrate(spec=spec)
    s_pal = RFFSubstrate(spec=spec, backend="pallas")
    rng = np.random.default_rng(6)
    X = jnp.asarray(rng.normal(size=(140, 8)), jnp.float32)
    np.testing.assert_allclose(np.asarray(s_pal._phi(X)),
                               np.asarray(s_ref._phi(X)),
                               rtol=2e-5, atol=2e-5)


def test_spec_entry_points_force_pallas_vs_substrate_reference():
    """ops.gram_spec / quadform_spec / rkhs_dist_sq_spec with the Pallas
    path forced, against the rkhs.py reference algebra the substrates
    use by default."""
    from repro.core import rkhs
    from repro.core.rkhs import KernelSpec
    spec = KernelSpec("gaussian", gamma=0.7)
    rng = np.random.default_rng(7)
    X = jnp.asarray(rng.normal(size=(130, 6)), jnp.float32)
    Y = jnp.asarray(rng.normal(size=(150, 6)), jnp.float32)
    a = jnp.asarray(rng.normal(size=(130,)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(150,)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ops.gram_spec(spec, X, Y, force_pallas=True)),
        np.asarray(rkhs.gram(spec, X, Y)), rtol=2e-5, atol=2e-5)
    want_qf = float(a @ rkhs.gram(spec, X, Y) @ b)
    got_qf = float(ops.quadform_spec(spec, X, Y, a, b, force_pallas=True))
    np.testing.assert_allclose(got_qf, want_qf, rtol=5e-4,
                               atol=5e-3 * max(1.0, abs(want_qf)))
    fa = rkhs.SVModel(sv=X, alpha=a, sv_id=jnp.arange(130, dtype=jnp.int32))
    fb = rkhs.SVModel(sv=Y, alpha=b,
                      sv_id=jnp.arange(130, 280, dtype=jnp.int32))
    np.testing.assert_allclose(
        float(ops.rkhs_dist_sq_spec(spec, X, Y, a, b)),
        float(rkhs.dist_sq(spec, fa, fb)), rtol=1e-4, atol=1e-3)


# --- flash attention (kernels/flash.py) -------------------------------------

def _flash_ref(q, k, v, causal=True):
    hd = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (hd ** -0.5)
    if causal:
        S, L = s.shape[-2:]
        m = jnp.arange(L)[None, :] <= jnp.arange(S)[:, None]
        s = jnp.where(m[None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32))


import jax  # noqa: E402


@pytest.mark.parametrize("S,hd,bq,bk", [(256, 64, 64, 64), (128, 128, 128, 64),
                                        (384, 64, 128, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_oracle(S, hd, bq, bk, causal):
    from repro.kernels.flash import flash_attention
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(3, S, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(3, S, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(3, S, hd)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          interpret=True)
    want = _flash_ref(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    from repro.kernels.flash import flash_attention
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.bfloat16)
    got = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    want = _flash_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("window", [32, 100, 64])
def test_flash_attention_sliding_window(window):
    from repro.kernels.flash import flash_attention
    rng = np.random.default_rng(2)
    S, hd = 256, 64
    q = jnp.asarray(rng.normal(size=(2, S, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, S, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, S, hd)), jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_k=64, interpret=True)
    # oracle: masked softmax with the band mask
    s = jnp.einsum("bqd,bkd->bqk", q, k) * (hd ** -0.5)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    m = (kpos <= qpos) & (kpos > qpos - window)
    s = jnp.where(m[None], s, -1e30)
    want = jnp.einsum("bqk,bkd->bqd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
