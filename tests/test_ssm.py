"""Mamba-2 SSD: chunked algorithm vs naive sequential recurrence oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models import ssm as ssm_mod


def _naive_ssd(x, Bm, Cm, dt, A_log, h0):
    """Step-by-step recurrence oracle:
    h_t = exp(dt_t a) h_{t-1} + dt_t B_t (x) x_t ;  y_t = C_t . h_t."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    a = -np.exp(np.asarray(A_log))
    h = np.asarray(h0).copy()
    ys = np.zeros((Bsz, S, H, P), np.float32)
    for t in range(S):
        decay = np.exp(np.asarray(dt[:, t]) * a)                 # (B,H)
        h = decay[:, :, None, None] * h + np.einsum(
            "bh,bhn,bhp->bhpn", np.asarray(dt[:, t]), np.asarray(Bm[:, t]),
            np.asarray(x[:, t]))
        ys[:, t] = np.einsum("bhn,bhpn->bhp", np.asarray(Cm[:, t]), h)
    return ys, h


@pytest.mark.parametrize("S,chunk", [(32, 8), (64, 16), (24, 8), (16, 16)])
def test_chunked_ssd_matches_naive(S, chunk):
    cfg = ModelConfig(arch_type="ssm", ssm_state=8, ssm_head_dim=4,
                      ssm_chunk=chunk, d_model=8, vocab=32,
                      attn_kind="none", pos_kind="none")
    rng = np.random.default_rng(0)
    Bsz, H, P, N = 2, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    x = jnp.asarray(rng.normal(size=(Bsz, S, H, P)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(Bsz, S, 1, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(Bsz, S, 1, N)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, size=(Bsz, S, H)), jnp.float32)
    A_log = jnp.asarray(np.log(rng.uniform(0.5, 4.0, size=(H,))), jnp.float32)
    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)

    Bq = jnp.repeat(Bm, H, axis=2)
    Cq = jnp.repeat(Cm, H, axis=2)
    y, hT = ssm_mod._ssd_chunked(cfg, x, Bm, Cm, dt, A_log, h0)
    y_ref, h_ref = _naive_ssd(np.asarray(x), np.asarray(Bq), np.asarray(Cq),
                              np.asarray(dt), A_log, np.asarray(h0))
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hT), h_ref, rtol=1e-4, atol=1e-4)


def test_ssd_with_initial_state():
    cfg = ModelConfig(arch_type="ssm", ssm_state=4, ssm_head_dim=4,
                      ssm_chunk=8, d_model=8, vocab=32, attn_kind="none",
                      pos_kind="none")
    rng = np.random.default_rng(1)
    Bsz, S, H, P, N = 1, 16, cfg.ssm_heads, 4, 4
    x = jnp.asarray(rng.normal(size=(Bsz, S, H, P)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(Bsz, S, 1, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(Bsz, S, 1, N)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.05, 0.3, size=(Bsz, S, H)), jnp.float32)
    A_log = jnp.zeros((H,), jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(Bsz, H, P, N)), jnp.float32)

    y, hT = ssm_mod._ssd_chunked(cfg, x, Bm, Cm, dt, A_log, h0)
    y_ref, h_ref = _naive_ssd(
        np.asarray(x), np.asarray(jnp.repeat(Bm, H, 2)),
        np.asarray(jnp.repeat(Cm, H, 2)), np.asarray(dt), A_log,
        np.asarray(h0))
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hT), h_ref, rtol=1e-4, atol=1e-4)


def test_ssm_forward_then_decode_continuity():
    """ssm_forward state handoff -> ssm_decode equals one longer
    ssm_forward (block-level test, complements test_decode.py)."""
    cfg = ModelConfig(arch_type="ssm", ssm_state=8, ssm_head_dim=4,
                      ssm_chunk=8, d_model=16, vocab=32, attn_kind="none",
                      pos_kind="none", dtype="float32")
    key = jax.random.PRNGKey(0)
    p = ssm_mod.ssm_init(key, cfg, jnp.float32)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(1, 17, 16)), jnp.float32)

    y_full, _ = ssm_mod.ssm_forward(cfg, p, x)
    y_pre, state = ssm_mod.ssm_forward(cfg, p, x[:, :16])
    y_dec, _ = ssm_mod.ssm_decode(cfg, p, x[:, 16:17], state)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]),
                               np.asarray(y_full[:, 16]), rtol=1e-3,
                               atol=1e-4)
