"""Theorem-level empirical bound checks (Sec. 3 of the paper).

These are the paper's own claims validated on simulations:
 - Thm. 4 : L_D <= L_P + T(Delta + 2 eps^2)/gamma^2   (vs continuous, b=1)
 - Prop. 6: V_D <= (eta/sqrt(Delta)) L_D
 - Prop. 5: C_C <= 2Tm|S_T|B_alpha + m|S_T|B_x
 - Thm. 7 : C_D <= V_bound * 2m|S_T|B_alpha + m|S_T|B_x
"""
import numpy as np
import pytest

from repro.core import criterion, simulation
from repro.core.accounting import ByteModel
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.core.rkhs import KernelSpec
from repro.data import susy_stream

T, M, D = 300, 4, 8


@pytest.fixture(scope="module")
def runs():
    X, Y = susy_stream(T=T, m=M, d=D, seed=0)
    lcfg = LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.5, lam=0.01,
                         budget=64, kernel=KernelSpec("gaussian", gamma=0.3),
                         dim=D)
    delta = 2.0
    res_d = simulation.run_kernel_simulation(
        lcfg, ProtocolConfig(kind="dynamic", delta=delta), X, Y)
    res_c = simulation.run_kernel_simulation(
        lcfg, ProtocolConfig(kind="continuous"), X, Y)
    return lcfg, delta, res_d, res_c


def test_thm4_loss_bound(runs):
    lcfg, delta, res_d, res_c = runs
    gamma = lcfg.eta
    eps = float(res_d.eps_history.max()) if len(res_d.eps_history) else 0.0
    bound = res_c.total_loss + T * (delta + 2 * eps ** 2) / gamma ** 2
    assert res_d.total_loss <= bound + 1e-6


def test_prop6_sync_bound(runs):
    lcfg, delta, res_d, _ = runs
    ok, slack = criterion.check_sync_bound(res_d, lcfg.eta, delta)
    assert ok, f"sync bound violated, slack={slack}"


def test_prop5_continuous_comm_bound(runs):
    lcfg, delta, _, res_c = runs
    bm = ByteModel(dim=D)
    union = T * M  # worst case |S_T| <= mT
    assert criterion.check_continuous_comm_bound(
        res_c.total_bytes, bm, M, T, union)


def test_thm7_dynamic_comm_bound(runs):
    lcfg, delta, res_d, _ = runs
    bm = ByteModel(dim=D)
    union = T * M
    ok, slack = criterion.check_comm_bound(
        res_d, bm, M, union, lcfg.eta, delta)
    assert ok, f"comm bound violated, slack={slack}"


def test_dynamic_communicates_less_than_continuous(runs):
    _, _, res_d, res_c = runs
    assert res_d.total_bytes < res_c.total_bytes
    assert res_d.num_syncs < res_c.num_syncs


def test_dynamic_loss_within_factor_of_continuous(runs):
    _, _, res_d, res_c = runs
    # consistency in practice: no blow-up vs the continuous protocol
    assert res_d.total_loss <= 1.5 * res_c.total_loss + 10.0
