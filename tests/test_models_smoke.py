"""Deliverable (f): per-architecture smoke tests.

For each of the 10 assigned architectures, instantiate a REDUCED
variant of the same family (2 layers / pattern unit, d_model<=512,
<=4 experts) and run one forward + one train step on CPU, asserting
output shapes and absence of NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_ids, get
from repro.core.protocol import ProtocolConfig
from repro.launch.train import init_train_state, make_train_step
from repro.models import build
from repro.optim import OptimizerConfig

ARCHS = all_arch_ids()


def _batch(cfg, B=2, S=16, m=None, seed=0):
    rng = np.random.default_rng(seed)
    shape = (m, B) if m else (B,)
    toks = rng.integers(0, cfg.vocab, shape + (S + 1,))
    batch = {"tokens": jnp.asarray(toks[..., :-1], jnp.int32),
             "labels": jnp.asarray(toks[..., 1:], jnp.int32)}
    if cfg.arch_type == "vlm":
        batch["embeds"] = jnp.asarray(
            rng.normal(size=shape + (cfg.vision_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.arch_type == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=shape + (cfg.n_audio_frames, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_and_no_nans(arch):
    cfg = get(arch).smoke()
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    logits, aux = api.forward(params, batch)
    S_total = S + (cfg.vision_tokens if cfg.arch_type == "vlm" else 0)
    assert logits.shape == (B, S_total, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    loss = api.loss(params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss))
    assert float(loss) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_one_protocol_train_step(arch):
    """One full protocol train step (2 learners) decreases nothing but
    must produce finite loss, updated params, and valid protocol state."""
    cfg = get(arch).smoke()
    m = 2
    pcfg = ProtocolConfig(kind="dynamic", delta=1e6)  # no sync expected
    opt_cfg = OptimizerConfig(kind="sgd", lr=0.01)
    state = init_train_state(jax.random.PRNGKey(0), cfg, m, opt_cfg)
    step = jax.jit(make_train_step(cfg, pcfg, opt_cfg))
    batch = _batch(cfg, B=2, S=16, m=m)
    new_state, loss = step(state, batch)
    assert not bool(jnp.isnan(loss))
    assert int(new_state.step) == 1
    assert int(new_state.pstate.syncs) == 0
    # params actually changed
    diff = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                     - b.astype(jnp.float32))))
               for a, b in zip(jax.tree.leaves(new_state.params),
                               jax.tree.leaves(state.params)))
    assert diff > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_loss_decreases(arch):
    """A few steps on a tiny repeated batch must reduce the loss —
    catches dead gradients per architecture family."""
    cfg = get(arch).smoke()
    m = 2
    pcfg = ProtocolConfig(kind="continuous")
    opt_cfg = OptimizerConfig(kind="adamw", lr=3e-3)
    state = init_train_state(jax.random.PRNGKey(0), cfg, m, opt_cfg)
    step = jax.jit(make_train_step(cfg, pcfg, opt_cfg))
    batch = _batch(cfg, B=2, S=16, m=m, seed=1)
    losses = []
    for _ in range(8):
        state, loss = step(state, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses
