"""RG-LRU: associative-scan forward vs sequential decode-step oracle."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models import rglru as rg

CFG = ModelConfig(arch_type="hybrid", d_model=16, lru_width=16,
                  conv_width=4, vocab=32,
                  layer_pattern=("rglru",), n_layers=1, dtype="float32")


def test_forward_matches_step_loop():
    key = jax.random.PRNGKey(0)
    p = rg.rglru_init(key, CFG, jnp.float32)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 12, 16)), jnp.float32)

    y_scan, state_scan = rg.rglru_forward(CFG, p, x)

    state = rg.init_lru_state(CFG, 2, jnp.float32)
    outs = []
    for t in range(12):
        y_t, state = rg.rglru_decode(CFG, p, x[:, t:t + 1], state)
        outs.append(np.asarray(y_t[:, 0]))
    y_loop = np.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan), y_loop, rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(state_scan.h),
                               np.asarray(state.h), rtol=1e-4, atol=1e-5)


def test_forward_state_handoff():
    key = jax.random.PRNGKey(1)
    p = rg.rglru_init(key, CFG, jnp.float32)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(1, 10, 16)), jnp.float32)
    y_full, _ = rg.rglru_forward(CFG, p, x)
    y_a, st = rg.rglru_forward(CFG, p, x[:, :6])
    y_b, _ = rg.rglru_forward(CFG, p, x[:, 6:], st)
    np.testing.assert_allclose(np.asarray(y_b), np.asarray(y_full[:, 6:]),
                               rtol=1e-4, atol=1e-5)


def test_stability_decay_in_unit_interval():
    key = jax.random.PRNGKey(2)
    p = rg.rglru_init(key, CFG, jnp.float32)
    rng = np.random.default_rng(3)
    u = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
    a, _ = rg._gates(p, u)
    assert float(jnp.min(a)) > 0.0
    assert float(jnp.max(a)) < 1.0
