"""Tests of the telemetry layer (repro.telemetry, DESIGN.md Sec. 11).

Four contracts:

- **Trace determinism** — a trace is a pure function of the run's
  seeds: identical configuration gives byte-identical Chrome-trace
  JSON (extends test_runtime.py::test_determinism_under_seed to the
  trace layer), and the per-message byte annotations sum to the run's
  ``total_bytes``.
- **Monitor exactness** — the live loss-proportionality monitor adopts
  the driver's cumulative series bitwise (losses) / integer-exactly
  (bytes) for {SV, RFF, linear} x {scan engine, async harness,
  serving engine}.
- **Compile-cache regression** — using the compile counter, a second
  value-equal configuration adds ZERO backend compiles to ``engine.run``
  and ``engine.sweep`` stays at one compile per (substrate, kind)
  group, pinning the frozen/hashable-substrate cache keying of PR 3.
- **Bench reports** — BENCH_*.json round-trips through the schema
  validator and ``tools/bench_compare.py`` passes a self-diff and
  fails an injected regression.
"""
import importlib.util
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.core.rff import RFFSpec
from repro.core.rkhs import KernelSpec
from repro.data.streams import susy_stream
from repro.runtime import (AsyncProtocolConfig, SystemConfig,
                           run_async_simulation)
from repro.serving import serve_stream
from repro.telemetry import (CompileCounter, CriterionMonitor, Tracer,
                             monitor_result, monitor_sweep, time_fn,
                             unit_bytes_of, wallclock)
from repro.telemetry.trace import PID_NETWORK, PID_SERVING, TICKS_PER_UNIT

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, ROOT)                      # for benchmarks.common

from benchmarks.common import (BenchReport, Row, load_report,  # noqa: E402
                               validate_report)

D = 8
T, M = 150, 4
KCFG = LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.5, lam=0.01,
                     budget=32, kernel=KernelSpec("gaussian", gamma=0.3),
                     dim=D)
RSPEC = RFFSpec(dim=D, num_features=64, gamma=0.3, seed=0)
LCFG = LearnerConfig(algo="linear_sgd", loss="hinge", eta=0.1, lam=0.001,
                     dim=D)
PCFG = ProtocolConfig(kind="dynamic", delta=2.0)
ACFG_IDEAL = AsyncProtocolConfig(kind="dynamic", delta=2.0, alpha=1.0,
                                 staleness="constant")
X, Y = susy_stream(T=T, m=M, d=D, seed=0)

# the noisy-network configuration of test_runtime's determinism test
NOISY = dict(
    acfg=AsyncProtocolConfig(kind="dynamic", delta=2.0, alpha=0.6,
                             staleness="poly", agg_window=0.5),
    sys_cfg=SystemConfig(seed=3, compute_jitter=0.3, straggler_frac=0.25,
                         base_latency=0.4, latency_jitter=0.5,
                         bandwidth=1e5, drop_prob=0.05))


def _noisy_trace(seed: int = 3) -> tuple:
    cfg = NOISY["sys_cfg"]
    sc = SystemConfig(**{**cfg.__dict__, "seed": seed})
    tr = Tracer()
    res = run_async_simulation(KCFG, NOISY["acfg"], X, Y, sys_cfg=sc,
                               tracer=tr)
    return tr, res


def _load_bench_compare():
    path = os.path.join(ROOT, "tools", "bench_compare.py")
    spec = importlib.util.spec_from_file_location("bench_compare", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# Trace format and determinism
# ---------------------------------------------------------------------------


def test_trace_json_is_perfetto_loadable_shape():
    tr, _ = _noisy_trace()
    doc = json.loads(tr.to_json())
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    assert doc["traceEvents"]
    for ev in doc["traceEvents"]:
        assert ev["ph"] in ("X", "C", "i", "M")
        assert isinstance(ev["pid"], int) and isinstance(ev["tid"], int)
        if ev["ph"] == "X":
            assert ev["ts"] >= 0 and ev["dur"] >= 0
        if ev["ph"] == "C":
            assert all(isinstance(v, (int, float))
                       for v in ev["args"].values())
        if ev["ph"] == "i":
            assert ev["s"] == "t"
    # named tracks: process metadata for every pid that has events
    pids_used = {e["pid"] for e in doc["traceEvents"] if e["ph"] != "M"}
    pids_named = {e["pid"] for e in doc["traceEvents"]
                  if e["ph"] == "M" and e["name"] == "process_name"}
    assert pids_used <= pids_named
    # learner rounds land as spans at the simulated-time scale
    rounds = [e for e in doc["traceEvents"]
              if e["ph"] == "X" and e["name"] == "round"]
    assert len(rounds) == T * M
    assert max(e["ts"] for e in rounds) > TICKS_PER_UNIT


def test_trace_byte_annotations_sum_to_total_bytes():
    tr, res = _noisy_trace()
    # bytes leave the sender whether or not the network drops the
    # message, so delivered spans plus drop instants cover the ledger
    msg = [e for e in tr.events
           if e["ph"] == "X" and e["name"].startswith("msg/")]
    drop = [e for e in tr.events
            if e["ph"] == "i" and e["name"].startswith("drop/")]
    assert res.num_dropped > 0 and len(drop) == res.num_dropped
    total = sum(e["args"]["nbytes"] for e in msg + drop)
    assert total == res.total_bytes
    assert all(e["pid"] == PID_NETWORK for e in msg + drop)


def test_trace_byte_identical_under_seed():
    t1, r1 = _noisy_trace()
    t2, r2 = _noisy_trace()
    assert r1.total_bytes == r2.total_bytes
    assert t1.to_json() == t2.to_json()       # byte-identical export
    t3, _ = _noisy_trace(seed=4)
    assert t3.to_json() != t1.to_json()       # the seed actually matters


def test_serving_trace_request_lifecycle():
    tr = Tracer()
    res = serve_stream(KCFG, PCFG, X, Y, queries_per_round=2.0, tracer=tr)
    by = {}
    for e in tr.events:
        by.setdefault((e["ph"], e["name"]), []).append(e)
    enq = by[("i", "enqueue")]
    req = by[("X", "request")]
    assert len(enq) == res.num_requests
    assert len(req) == res.num_requests       # every request answered
    assert {e["args"]["uid"] for e in enq} == {e["args"]["uid"] for e in req}
    assert all(e["dur"] >= 0 and e["pid"] == PID_SERVING for e in req)
    rounds = by[("i", "round")]
    assert len(rounds) == res.rounds
    syncs = by.get(("X", "sync/transfer"), [])
    assert len(syncs) == res.num_syncs > 0
    assert sum(e["args"]["nbytes"] for e in syncs) == res.total_bytes
    buckets = [e for (ph, name), evs in by.items() if ph == "X"
               and name.startswith("predict/bucket") for e in evs]
    assert buckets
    assert all(1 <= e["args"]["filled"] <= e["args"]["bucket"]
               for e in buckets)
    assert ("C", "serve/queue_depth") in by
    assert ("C", "serve/bucket_occupancy") in by


# ---------------------------------------------------------------------------
# Live loss-proportionality monitor
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("learner", [KCFG, RSPEC, LCFG],
                         ids=["sv", "rff", "linear"])
def test_monitor_exact_across_drivers(learner):
    """The monitor's series are the driver's series — bitwise losses,
    integer-exact bytes — for all three substrates and all three
    drivers, and the dynamic protocol satisfies the criterion."""
    res_e = engine.run(learner, PCFG, X, Y)
    res_a = run_async_simulation(learner, ACFG_IDEAL, X, Y,
                                 sys_cfg=SystemConfig())
    res_s = serve_stream(learner, PCFG, X, Y, queries_per_round=1.0).sim
    for res in (res_e, res_a, res_s):
        mon = monitor_result(res, learner, M)
        s = mon.series()
        assert s.cumulative_bytes.dtype == np.int64
        np.testing.assert_array_equal(s.cumulative_bytes,
                                      res.cumulative_bytes)
        np.testing.assert_array_equal(s.cumulative_loss,
                                      res.cumulative_loss)
        assert len(s) == T and s.ok and mon.ok
    # the three drivers' ledgers agree, so the monitors do too
    np.testing.assert_array_equal(res_e.cumulative_bytes,
                                  res_a.cumulative_bytes)
    np.testing.assert_array_equal(res_e.cumulative_bytes,
                                  res_s.cumulative_bytes)
    np.testing.assert_array_equal(res_e.cumulative_loss,
                                  res_s.cumulative_loss)  # bitwise
    np.testing.assert_allclose(res_e.cumulative_loss,
                               res_a.cumulative_loss, rtol=1e-5)


def test_monitor_unit_bytes_topologies():
    # coordinator SV worst case: full-budget novel uploads + union
    # downloads; allreduce: the substrate's fixed ring total
    ub = unit_bytes_of(KCFG, M)
    bx, ba = D * 4 + 4, 4 + 4
    tau = KCFG.budget
    assert ub == (M * tau * (ba + bx)
                  + M * M * tau * ba + M * (M - 1) * tau * bx)
    assert unit_bytes_of(LCFG, M) == 2 * M * (D + 1) * 4   # weights + bias
    assert unit_bytes_of(KCFG, M, "allreduce") > 0
    with pytest.raises(ValueError):
        unit_bytes_of(KCFG, M, "ring")


def test_monitor_flags_disproportionate_communication():
    mon = CriterionMonitor(m=2, unit_bytes=100, slack=1.0, loss_floor=1.0)
    assert mon.observe(0.0, 150)        # 150 <= 1 * 2 * 100 * 1
    assert not mon.observe(0.0, 500)    # 650 > 200: loss never grew
    assert mon.observe(10.0, 0)         # bound catches up with the loss
    assert mon.violation_round == 1 and not mon.ok
    s = mon.series()
    assert s.ratio[1] > 1.0 and s.ratio[0] <= 1.0
    assert not s.ok
    tr = Tracer()
    mon.emit(tr)
    names = [e["name"] for e in tr.events]
    assert names.count("criterion/bytes") == mon.rounds
    assert names.count("criterion/loss") == mon.rounds
    assert names.count("criterion/violation") == 1


def test_monitor_sweep_matches_per_config_ledgers():
    grid = [ProtocolConfig(kind="dynamic", delta=d) for d in (0.5, 2.0)]
    sw = engine.sweep(KCFG, grid, X, Y)
    mons = monitor_sweep(sw, KCFG, M)
    assert len(mons) == len(grid)
    for i, mon in enumerate(mons):
        np.testing.assert_array_equal(mon.series().cumulative_bytes,
                                      sw[i].cumulative_bytes)
        assert mon.ok


# ---------------------------------------------------------------------------
# Compile counters: the engine's cache-keying contract
# ---------------------------------------------------------------------------

# distinctive values so these tests key fresh engine._jitted entries no
# other test warmed (the lru_cache is process-wide)
KCFG_DISTINCT = LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.47,
                              lam=0.013, budget=48,
                              kernel=KernelSpec("gaussian", gamma=0.317),
                              dim=D)
X2, Y2 = susy_stream(T=60, m=M, d=D, seed=2)


def test_engine_run_reuses_compile_across_equal_configs():
    engine.run(KCFG_DISTINCT, ProtocolConfig(kind="dynamic", delta=0.7),
               X2, Y2)                        # warm: compiles the scan
    # a NEW value-equal config and different protocol parameters must
    # be a pure cache hit: frozen substrates key on value, and
    # delta / period are runtime params, not trace constants
    cfg_b = LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.47,
                          lam=0.013, budget=48,
                          kernel=KernelSpec("gaussian", gamma=0.317),
                          dim=D)
    assert cfg_b == KCFG_DISTINCT and cfg_b is not KCFG_DISTINCT
    with CompileCounter() as c:
        engine.run(cfg_b, ProtocolConfig(kind="dynamic", delta=1.9), X2, Y2)
    assert c.compiles == 0


def test_engine_sweep_one_compile_per_substrate_kind_group():
    dyn = [ProtocolConfig(kind="dynamic", delta=d) for d in (0.41, 1.7)]
    engine.sweep(KCFG_DISTINCT, dyn, X2, Y2)  # warm the dynamic@2 group
    with CompileCounter() as c1:
        engine.sweep(KCFG_DISTINCT,
                     [ProtocolConfig(kind="dynamic", delta=d)
                      for d in (0.93, 2.9)], X2, Y2)
    assert c1.compiles == 0                   # same group, new deltas
    # warm the size-1 param-stacking eager ops (shapes are substrate-
    # independent) on a DIFFERENT substrate, so the only thing left to
    # compile below is the new (substrate, kind) group executable
    lcfg_distinct = LearnerConfig(algo="linear_sgd", loss="hinge", eta=0.23,
                                  lam=0.0017, dim=D)
    engine.sweep(lcfg_distinct, [ProtocolConfig(kind="periodic", period=11)],
                 X2, Y2)
    with CompileCounter() as c2:
        engine.sweep(KCFG_DISTINCT,
                     dyn + [ProtocolConfig(kind="periodic", period=7)],
                     X2, Y2)
    assert c2.compiles == 1                   # exactly the new group


def test_time_fn_blocks_and_reports_compiles():
    @jax.jit
    def f(v):
        return v * 2.0 + 1.0

    v = jnp.arange(37, dtype=jnp.float32)
    s1 = time_fn(f, v, warmup=1, iters=3)
    assert s1.warmup_compiles >= 1 and s1.compiles == 0
    assert s1.us_per_call > 0 and s1.iters == 3
    s2 = time_fn(f, v, warmup=1, iters=3)
    assert s2.warmup_compiles == 0            # cache hit on re-measure

    with wallclock() as w:
        w.track(f(v))
    assert w.seconds > 0 and w.compiles == 0


# ---------------------------------------------------------------------------
# Bench reports and the comparator
# ---------------------------------------------------------------------------


def _report(suite="demo", us=100.0, claim=True):
    rows = [
        Row(f"{suite}/hot_loop", us, "rounds_per_sec=10.0"),
        Row(f"{suite}/claims", 0.0,
            f"parity={claim};speedup=3.1x"),
    ]
    return BenchReport(suite, rows, wall_seconds=0.5)


def test_bench_report_schema_roundtrip(tmp_path):
    rep = _report()
    doc = rep.to_dict()
    assert validate_report(doc) == []
    assert doc["claims"] == {"demo/claims/parity": True}
    path = rep.save(str(tmp_path))
    assert os.path.basename(path) == "BENCH_demo.json"
    assert load_report(path)["suite"] == "demo"
    # the validator actually rejects malformed documents
    assert validate_report({"suite": "x"})
    bad = rep.to_dict()
    bad["rows"][0]["us_per_call"] = "fast"
    assert any("us_per_call" in p for p in validate_report(bad))
    bad2 = rep.to_dict()
    bad2["claims"]["demo/claims/parity"] = "yes"
    assert any("claim" in p for p in validate_report(bad2))


def test_bench_compare_self_diff_and_regressions(tmp_path):
    bc = _load_bench_compare()
    base, cand = tmp_path / "base", tmp_path / "cand"
    _report().save(str(base))
    _report().save(str(cand))
    assert bc.main([str(base), str(cand)]) == 0          # self-diff

    bad = tmp_path / "bad"
    _report(us=300.0, claim=False).save(str(bad))        # 3x + claim flip
    assert bc.main([str(base), str(bad)]) == 1
    regs = bc.compare(bc.load_dir(str(base)), bc.load_dir(str(bad)))
    assert any("demo/hot_loop" in r for r in regs)
    assert any("parity" in r for r in regs)
    # a generous per-metric override waives the timing gate
    regs2 = bc.compare(bc.load_dir(str(base)), bc.load_dir(str(bad)),
                       overrides=[("demo/*", 10.0)])
    assert not any(r.startswith("[timing]") for r in regs2)
    # sub-threshold rows are not flagged
    ok = tmp_path / "ok"
    _report(us=120.0).save(str(ok))
    assert bc.main([str(base), str(ok)]) == 0
    # a vanished row is a coverage regression
    missing = tmp_path / "missing"
    rep = _report()
    rep.rows = rep.rows[1:]
    rep.save(str(missing))
    assert bc.main([str(base), str(missing)]) == 1


def _bytes_report(nbytes: int, gram: int):
    rows = [Row("demo/ledger", 500.0,
                f"bytes={nbytes};hbm_gram_bytes={gram};ratio=0.33")]
    return BenchReport("demo", rows, wall_seconds=0.1)


def test_bench_compare_byte_metrics_exact(tmp_path):
    # byte ledgers are integer-exact under seed (DESIGN.md Sec. 7):
    # any drift in a *bytes* derived metric is a regression at exact
    # integer equality, regardless of the timing threshold.
    bc = _load_bench_compare()
    base, same, drift = tmp_path / "b", tmp_path / "s", tmp_path / "d"
    _bytes_report(150336, 262144).save(str(base))
    _bytes_report(150336, 262144).save(str(same))
    _bytes_report(150336, 262148).save(str(drift))     # 4-byte drift

    assert bc.byte_metrics({"derived": "bytes=12;x=1.5"}) == {"bytes": 12}
    assert bc.byte_metrics({"derived": "ratio=0.8"}) == {}

    assert bc.main([str(base), str(same), "--threshold", "25"]) == 0
    assert bc.main([str(base), str(drift), "--threshold", "25"]) == 1
    regs = bc.compare(bc.load_dir(str(base)), bc.load_dir(str(drift)),
                      threshold=25.0)
    assert any(r.startswith("[bytes]") and "hbm_gram_bytes" in r
               for r in regs)
    # cross-version comparisons can downgrade the gate to a warning
    assert bc.main([str(base), str(drift), "--threshold", "25",
                    "--allow-bytes-drift"]) == 0
