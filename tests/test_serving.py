"""Serving parity suite (DESIGN.md Sec. 10).

The contract under test: the same (T, m, d) labeled stream pushed
through :class:`repro.serving.KernelServingEngine` — with predict
query traffic riding along — reproduces ``engine.run`` BIT-FOR-BIT on
losses / errors and integer-exactly on the Sec. 3 byte ledger, for
{dynamic, periodic} x {SV, RFF, linear}; and a padded-batch
``Substrate.predict_batch`` call answers every request with exactly
the floats a per-request ``predict_one`` would have produced
(micro-batching is free, numerically).
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression, engine, simulation
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.core.rff import RFFSpec
from repro.core.rkhs import KernelSpec
from repro.core.substrate import SVSubstrate, substrate_of
from repro.data import susy_stream
from repro.runtime import SystemConfig
from repro.runtime.clock import Clock
from repro.serving import (DEFAULT_BUCKETS, KernelServingEngine,
                           TickScheduler, make_arrivals, serve_stream)

T, M, D = 40, 4, 6


def _kcfg(budget=12):
    return LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.5, lam=0.01,
                         budget=budget,
                         kernel=KernelSpec("gaussian", gamma=0.3), dim=D)


def _lcfg():
    return LearnerConfig(algo="linear_sgd", loss="hinge", eta=0.1, lam=0.001,
                         dim=D)


def _rspec():
    return RFFSpec(dim=D, num_features=32, gamma=0.3, seed=0)


def _stream(seed=1):
    return susy_stream(T=T, m=M, d=D, seed=seed)


def _assert_protocol_identical(res_ref, res_srv, tag):
    for field in ("cumulative_loss", "cumulative_errors",
                  "cumulative_bytes", "sync_rounds", "eps_history"):
        a, b = getattr(res_ref, field), getattr(res_srv, field)
        assert np.array_equal(a, b), (tag, field, a, b)
    assert res_ref.num_syncs == res_srv.num_syncs, tag
    assert res_ref.total_bytes == res_srv.total_bytes, tag


# ---------------------------------------------------------------------------
# Parity: serving path vs scan engine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("learner_name", ["sv", "rff", "linear"])
@pytest.mark.parametrize("pcfg", [ProtocolConfig(kind="dynamic", delta=1.0),
                                  ProtocolConfig(kind="periodic", period=7)],
                         ids=["dynamic", "periodic"])
def test_serving_matches_engine(learner_name, pcfg):
    learner = {"sv": _kcfg(), "rff": _rspec(), "linear": _lcfg()}[learner_name]
    X, Y = _stream()
    res_ref = engine.run(learner, pcfg, X, Y)
    res_srv = serve_stream(learner, pcfg, X, Y, queries_per_round=2.0)
    assert res_ref.num_syncs > 0, "degenerate stream: no syncs to compare"
    _assert_protocol_identical(res_ref, res_srv.sim,
                               f"{learner_name}/{pcfg.kind}")
    # every feedback round was applied; queries were all answered
    assert res_srv.rounds == T
    assert res_srv.num_requests == 2 * T
    assert np.isfinite(res_srv.latencies).all()


def test_serving_query_rate_does_not_perturb_protocol():
    """Predict traffic reads model state and never touches it: any
    query rate leaves the protocol view bit-identical."""
    X, Y = _stream(seed=3)
    pcfg = ProtocolConfig(kind="dynamic", delta=1.0)
    quiet = serve_stream(_kcfg(), pcfg, X, Y, queries_per_round=0.0)
    busy = serve_stream(_kcfg(), pcfg, X, Y, queries_per_round=5.0)
    _assert_protocol_identical(quiet.sim, busy.sim, "query-rate")
    assert quiet.num_requests == 0 and busy.num_requests == 5 * T


def test_serving_matches_engine_under_system_noise():
    """Stragglers and jitter reshuffle arrival *times*, never the
    per-learner stream order — the protocol view is timing-independent
    (the serving analogue of the async zero-latency collapse)."""
    X, Y = _stream(seed=4)
    pcfg = ProtocolConfig(kind="dynamic", delta=1.0)
    res_ref = engine.run(_kcfg(), pcfg, X, Y)
    res_srv = serve_stream(
        _kcfg(), pcfg, X, Y, queries_per_round=1.0,
        sys_cfg=SystemConfig(seed=7, compute_jitter=0.4, straggler_frac=0.25,
                             straggler_mult=4.0, straggler_prob=0.5,
                             base_latency=0.3, latency_jitter=0.2,
                             bandwidth=1e5))
    _assert_protocol_identical(res_ref, res_srv.sim, "noisy-system")
    # metered sync network time exists on the noisy timeline
    assert len(res_srv.sync_delays) == res_srv.num_syncs
    assert (res_srv.sync_delays > 0).all()


def test_serve_stream_deterministic_under_seed():
    X, Y = _stream(seed=5)
    pcfg = ProtocolConfig(kind="dynamic", delta=1.0)
    kw = dict(queries_per_round=3.0, query_seed=11,
              sys_cfg=SystemConfig(seed=2, compute_jitter=0.3,
                                   base_latency=0.1, bandwidth=1e6))
    a = serve_stream(_rspec(), pcfg, X, Y, **kw)
    b = serve_stream(_rspec(), pcfg, X, Y, **kw)
    assert np.array_equal(a.latencies, b.latencies)
    assert np.array_equal(a.queue_depth, b.queue_depth)
    assert np.array_equal(a.sim.cumulative_loss, b.sim.cumulative_loss)
    assert a.wall_clock == b.wall_clock


# ---------------------------------------------------------------------------
# Micro-batching: padded-batch predict == per-request predict
# ---------------------------------------------------------------------------


def _trained_models(sub, X, Y):
    """Push the stream through the protocol step so predict runs
    against non-trivial models."""
    step = jax.jit(engine.make_protocol_step(sub, "dynamic"))
    params = engine.params_of(ProtocolConfig(kind="dynamic", delta=1.0))
    carry = engine.init_protocol_carry(sub, X.shape[1])
    for t in range(X.shape[0]):
        carry, _ = step(params, carry,
                        (jnp.asarray(X[t]), jnp.asarray(Y[t]),
                         jnp.asarray(t, jnp.int32)))
    return sub.models_of(carry[0])


@pytest.mark.parametrize("learner_name", ["sv", "rff", "linear"])
def test_predict_batch_bit_equals_per_request(learner_name):
    learner = {"sv": _kcfg(), "rff": _rspec(), "linear": _lcfg()}[learner_name]
    sub = substrate_of(learner)
    X, Y = _stream(seed=6)
    models = _trained_models(sub, X, Y)
    rng = np.random.default_rng(0)
    n = 13                                   # pads into the 16-bucket
    lids = rng.integers(0, M, n).astype(np.int32)
    Xb = np.asarray(X[rng.integers(0, T, n), rng.integers(0, M, n)],
                    np.float32)
    pad = 16 - n
    batched = np.asarray(sub.predict_batch(
        models,
        jnp.asarray(np.concatenate([lids, np.zeros(pad, np.int32)])),
        jnp.asarray(np.concatenate([Xb, np.zeros((pad, D), np.float32)]))))
    solo = np.asarray([
        np.asarray(sub.predict_one(
            jax.tree.map(lambda v: v[lids[i]], models), jnp.asarray(Xb[i])))
        for i in range(n)])
    assert np.array_equal(batched[:n], solo), (learner_name, batched[:n], solo)


def test_bucket_sizes_key_compile_cache():
    """The engine serves every queue depth from the static bucket set
    (padding up), so the number of predict executables is bounded by
    len(buckets), sweep-style."""
    X, Y = _stream(seed=7)
    pcfg = ProtocolConfig(kind="periodic", period=10)
    res = serve_stream(_lcfg(), pcfg, X, Y, queries_per_round=3.0,
                       buckets=(1, 4, 16))
    assert set(res.bucket_counts) <= {1, 4, 16}
    assert sum(res.bucket_counts.values()) >= 1
    # every request got answered exactly once
    assert res.num_requests == 3 * T
    assert int(res.queue_depth.sum()) == res.num_requests


# ---------------------------------------------------------------------------
# Engine mechanics
# ---------------------------------------------------------------------------


def test_engine_tick_latency_semantics():
    """A request waits for the next tick-grid point after its arrival;
    with predict_cost=0 its latency is exactly that queue wait.  An
    arrival landing exactly ON a grid point is served by that tick
    (arrival events sort before the tick at equal time — the clock's
    (time, seq) order)."""
    eng = KernelServingEngine(_lcfg(), ProtocolConfig(kind="dynamic",
                                                      delta=0.1),
                              M, tick_interval=1.0)
    r1 = eng.submit(np.zeros(D), learner=0, at=0.25)
    r2 = eng.submit(np.ones(D), learner=3, at=1.0)    # lands on the grid
    res = eng.serve()
    assert r1.done and r2.done
    assert r1.done_time == pytest.approx(1.0)
    assert r1.latency == pytest.approx(0.75)
    assert r2.done_time == pytest.approx(1.0)
    assert r2.latency == pytest.approx(0.0)
    assert res.ticks == 1
    # an untrained linear model answers 0 everywhere
    assert r1.yhat == 0.0


def test_engine_predict_cost_shifts_done_time():
    eng = KernelServingEngine(_lcfg(), ProtocolConfig(kind="dynamic",
                                                      delta=0.1),
                              M, tick_interval=1.0, predict_cost=0.5,
                              buckets=(1,))
    ra = eng.submit(np.zeros(D), learner=0, at=0.0)
    rb = eng.submit(np.zeros(D), learner=1, at=0.0)
    eng.serve()
    # two single-slot buckets served back-to-back within the tick
    assert ra.done_time == pytest.approx(1.5)
    assert rb.done_time == pytest.approx(2.0)


def test_predict_compute_is_a_single_resource():
    """The predict server is one simulated resource: a tick's batches
    start no earlier than the previous tick's finished, and every
    completion lands on the timeline (wall_clock >= every done_time)."""
    eng = KernelServingEngine(_lcfg(), ProtocolConfig(kind="dynamic",
                                                      delta=0.1),
                              M, tick_interval=1.0, predict_cost=0.6,
                              buckets=(1,))
    first = [eng.submit(np.zeros(D), learner=0, at=0.1) for _ in range(3)]
    late = eng.submit(np.zeros(D), learner=1, at=1.5)
    res = eng.serve()
    assert [r.done_time for r in first] == pytest.approx([1.6, 2.2, 2.8])
    # the 2.0 tick finds the server busy until 2.8; no double-booking
    assert late.done_time == pytest.approx(3.4)
    assert res.wall_clock == pytest.approx(3.4)
    assert res.wall_clock >= max(r.done_time for r in first + [late])


def test_engine_ingress_validation():
    eng = KernelServingEngine(_lcfg(), ProtocolConfig(kind="dynamic",
                                                      delta=0.1), M)
    with pytest.raises(ValueError):
        eng.submit(np.zeros(D + 1), learner=0)        # wrong dim
    with pytest.raises(ValueError):
        eng.submit(np.zeros(D), learner=M)            # no such learner
    with pytest.raises(ValueError):
        eng.feedback(np.zeros(D), 1.0, learner=0, at=-1.0)  # in the past
    with pytest.raises(ValueError):
        KernelServingEngine(_lcfg(), ProtocolConfig(kind="dynamic",
                                                    delta=0.1), M,
                            tick_interval=0.0)
    with pytest.raises(ValueError):
        KernelServingEngine(_lcfg(), ProtocolConfig(kind="dynamic",
                                                    delta=0.1), M,
                            buckets=())


def test_partial_feedback_rounds_wait():
    """Protocol rounds are lockstep: nothing is applied until every
    learner's next example arrived (the parity-critical queueing)."""
    eng = KernelServingEngine(_lcfg(), ProtocolConfig(kind="continuous"), M)
    for i in range(M - 1):
        eng.feedback(np.ones(D), 1.0, learner=i, at=0.1)
    res_half = eng.serve()
    assert res_half.rounds == 0 and res_half.num_syncs == 0
    eng.feedback(np.ones(D), 1.0, learner=M - 1, at=eng.clock.now + 0.1)
    res = eng.serve()
    assert res.rounds == 1 and res.num_syncs == 1


# ---------------------------------------------------------------------------
# compress_method default unification (the satellite bugfix)
# ---------------------------------------------------------------------------


def test_compress_method_default_is_one_constant():
    assert compression.DEFAULT_METHOD == "truncate"
    assert SVSubstrate().compress_method == compression.DEFAULT_METHOD
    assert (simulation.run_kernel_simulation.__defaults__[-1]
            == compression.DEFAULT_METHOD)
    # None sentinel keeps a substrate's own (non-default) configuration
    sub = SVSubstrate(lcfg=_kcfg(), compress_method="project")
    assert substrate_of(sub, compress_method=None).compress_method == "project"
    assert substrate_of(sub).compress_method == "project"
    # ... while an explicit value overrides it
    assert (substrate_of(sub, compress_method="truncate").compress_method
            == "truncate")


def test_engine_run_none_sentinel_respects_substrate_method():
    """engine.run(sub) must not silently reset a configured
    compress_method back to the default."""
    X, Y = _stream(seed=8)
    pcfg = ProtocolConfig(kind="periodic", period=5)
    sub_p = SVSubstrate(lcfg=_kcfg(), compress_method="project")
    res_none = engine.run(sub_p, pcfg, X, Y)
    res_explicit = engine.run(_kcfg(), pcfg, X, Y,
                              compress_method="project")
    assert np.array_equal(res_none.cumulative_loss,
                          res_explicit.cumulative_loss)
    assert np.array_equal(res_none.eps_history, res_explicit.eps_history)
    # and projection genuinely differs from the truncation default
    res_trunc = engine.run(_kcfg(), pcfg, X, Y)
    assert not np.array_equal(res_none.eps_history, res_trunc.eps_history)


# ---------------------------------------------------------------------------
# Mesh routing (out-of-process: jax locks the device count at init —
# the established pattern of tests/test_engine_mesh.py)
# ---------------------------------------------------------------------------


_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import numpy as np

    from repro.core import engine
    from repro.core.learners import LearnerConfig
    from repro.core.protocol import ProtocolConfig
    from repro.core.rkhs import KernelSpec
    from repro.data import susy_stream
    from repro.launch.serve import make_kernel_serving_engine

    assert len(jax.devices()) == 8
    T, M, D = 30, 8, 6
    X, Y = susy_stream(T=T, m=M, d=D, seed=3)
    kcfg = LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.5, lam=0.01,
                         budget=12, kernel=KernelSpec("gaussian", gamma=0.3),
                         dim=D)
    pcfg = ProtocolConfig(kind="dynamic", delta=1.0)

    eng = make_kernel_serving_engine(kcfg, pcfg, M)
    assert eng.home_shard(0) == 0 and eng.home_shard(M - 1) == 7
    rng = np.random.default_rng(0)
    for t in range(T):
        for i in range(M):
            eng.feedback(X[t, i], Y[t, i], learner=i, at=float(t + 1))
    for k in range(40):
        lid = int(rng.integers(M))
        eng.submit(X[int(rng.integers(T)), lid], learner=lid,
                   at=float(rng.uniform(0, T)))
    res = eng.serve()

    res_ref = engine.run(kcfg, pcfg, X, Y)
    assert np.array_equal(res_ref.cumulative_loss, res.sim.cumulative_loss)
    assert np.array_equal(res_ref.cumulative_bytes, res.sim.cumulative_bytes)
    assert res.num_requests == 40
    assert np.isfinite(res.latencies).all()

    # devices=1 degrades to identity routing, same launch code
    eng1 = make_kernel_serving_engine(kcfg, pcfg, M, devices=1)
    assert eng1.home_shard(M - 1) == 0
    print("MESH_SERVING_OK")
""")


def test_mesh_routed_serving_matches_engine():
    out = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT], capture_output=True, text=True)
    assert out.returncode == 0, out.stderr
    assert "MESH_SERVING_OK" in out.stdout


# ---------------------------------------------------------------------------
# Continuous batching, admission control, multi-tenancy (DESIGN.md Sec. 13)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("overload", ["none", "shed", "defer"])
@pytest.mark.parametrize("kind", ["poisson", "bursty", "diurnal"])
@pytest.mark.parametrize("policy", ["tick", "continuous"])
def test_parity_under_policy_arrival_overload(policy, kind, overload):
    """The acceptance matrix: losses bitwise-identical and Sec. 3
    bytes integer-exact vs engine.run under EVERY scheduling policy,
    arrival model and overload level — scheduling is a pure
    latency/throughput knob, structurally unable to touch the
    protocol view."""
    pcfg = ProtocolConfig(kind="dynamic", delta=1.0)
    X, Y = _stream()
    res_ref = engine.run(_lcfg(), pcfg, X, Y)
    kw = dict(policy=policy, slots=2, predict_cost=0.05,
              arrivals=make_arrivals(kind, rate=6.0, seed=3))
    if overload != "none":
        # cap capacity below the offered load (one lane, batch of two,
        # 0.5 per launch = 4 req/s < rate 6) so admission actually binds
        kw.update(max_queue=2, overload=overload, slots=1,
                  predict_cost=0.5, buckets=(1, 2))
    res = serve_stream(_lcfg(), pcfg, X, Y, **kw)
    _assert_protocol_identical(res_ref, res.sim,
                               (policy, kind, overload))
    if overload == "shed":
        # the bounded queue actually bound something at this rate
        assert res.num_shed > 0
        assert res.num_requests + res.num_shed > 0
    elif overload == "defer":
        assert res.num_shed == 0


@pytest.mark.parametrize("learner_name", ["sv", "rff"])
def test_parity_under_overload_kernel_substrates(learner_name):
    """Substrate spot-check of the same matrix: the kernel substrates
    keep the contract under continuous batching with a shedding
    queue."""
    learner = {"sv": _kcfg(), "rff": _rspec()}[learner_name]
    pcfg = ProtocolConfig(kind="dynamic", delta=1.0)
    X, Y = _stream()
    res_ref = engine.run(learner, pcfg, X, Y)
    res = serve_stream(
        learner, pcfg, X, Y, policy="continuous", slots=1,
        predict_cost=0.2, max_queue=2, overload="shed",
        arrivals=make_arrivals("bursty", rate=8.0, seed=1))
    _assert_protocol_identical(res_ref, res.sim, learner_name)
    assert res.num_shed > 0                   # overload actually hit


def test_tick_grid_integer_exact_at_large_times():
    """The tick grid is an integer index k: each tick time is ONE
    multiply k * tick_interval, so huge horizons with tiny intervals
    stay exactly on grid (the old float probe
    floor(now / interval + 1e-9) + 1 drifts at this scale and can
    even produce a tick in the past)."""
    sch = TickScheduler(clock=Clock(), predict_fn=None,
                        shard_of=lambda l: 0, n_shards=1, buckets=(1,),
                        predict_cost=0.0, tick_interval=1e-3)
    for now in [0.0, 1e-3, 0.9999999999, 123456.789, 1e9, 1e9 + 0.25e-3,
                1e12]:
        k = sch._next_grid_k(now)
        t = k * sch.tick_interval
        assert t > now, (now, k, t)
        assert (k - 1) * sch.tick_interval <= now, (now, k)
    # grid points are exact fixed points: the next tick after k*dt is
    # (k+1)*dt, never a repeat or a skip
    for k in [1, 1_000, 1_000_000_000, 10 ** 12]:
        assert sch._next_grid_k(k * 1e-3) == k + 1
    with pytest.raises(OverflowError):
        sch._next_grid_k(float("inf"))


def test_engine_serves_at_large_now_tiny_tick():
    """End-to-end regression: a request arriving at simulated time 1e9
    on a 1e-3 grid is served within a couple of grid intervals — the
    float-drift failure mode (negative delay / off-grid tick) cannot
    occur."""
    eng = KernelServingEngine(_lcfg(), ProtocolConfig(kind="dynamic",
                                                      delta=0.1),
                              M, tick_interval=1e-3)
    big = 1.0e9
    r = eng.submit(np.zeros(D), learner=0, at=big + 0.4e-3)
    res = eng.serve()
    assert r.done
    assert 0.0 <= r.latency <= 2e-3
    assert res.ticks == 1


def test_serve_result_empty_and_single_stats():
    """Latency summaries are NaN-free and well-defined on degenerate
    runs: zero served requests gives 0.0 everywhere, one request gives
    its own latency at every percentile."""
    pcfg = ProtocolConfig(kind="dynamic", delta=0.1)
    eng = KernelServingEngine(_lcfg(), pcfg, M)
    res = eng.serve()                          # nothing ever submitted
    assert res.num_requests == 0
    pct = res.latency_percentiles()
    assert pct == {"p50": 0.0, "p90": 0.0, "p99": 0.0}
    assert res.mean_latency == 0.0 and res.max_latency == 0.0
    assert res.mean_queue_depth == 0.0
    summary = res.summary()
    assert all(np.isfinite(v) for v in summary.values()), summary

    eng2 = KernelServingEngine(_lcfg(), pcfg, M, tick_interval=1.0)
    r = eng2.submit(np.zeros(D), learner=0, at=0.25)
    res2 = eng2.serve()
    assert res2.num_requests == 1
    pct2 = res2.latency_percentiles()
    assert pct2["p50"] == pct2["p90"] == pct2["p99"] == \
        pytest.approx(r.latency)
    assert res2.mean_latency == res2.max_latency == \
        pytest.approx(r.latency)
    assert all(np.isfinite(v) for v in res2.summary().values())


def test_multi_tenant_parity_shared_engine():
    """Several protocol instances share one engine, clock and slot
    pool; each tenant's protocol view still reproduces its own
    engine.run bit-for-bit, and launched batches never mix tenants."""
    from repro.telemetry.trace import Tracer
    X, Y = _stream()
    pcfg_a = ProtocolConfig(kind="dynamic", delta=1.0)
    pcfg_b = ProtocolConfig(kind="periodic", period=3)
    ref_a = engine.run(_lcfg(), pcfg_a, X, Y)
    ref_b = engine.run(_lcfg(), pcfg_b, X, Y)

    tr = Tracer()
    eng = KernelServingEngine(_lcfg(), pcfg_a, M, policy="continuous",
                              slots=2, predict_cost=0.05, tracer=tr)
    tb = eng.add_tenant(_lcfg(), pcfg_b)
    assert eng.num_tenants == 2
    rng = np.random.default_rng(0)
    for t in range(T):
        at = float(t + 1)
        for i in range(M):
            eng.feedback(X[t, i], Y[t, i], learner=i, at=at, tenant=0)
            eng.feedback(X[t, i], Y[t, i], learner=i, at=at, tenant=tb)
        # interleaved query traffic against both tenants
        eng.submit(X[t, 0], learner=int(rng.integers(M)),
                   at=at + 0.1, tenant=0)
        eng.submit(X[t, 0], learner=int(rng.integers(M)),
                   at=at + 0.2, tenant=tb)
    eng.serve()
    res_a, res_b = eng.results()
    _assert_protocol_identical(ref_a, res_a.sim, "tenant0")
    _assert_protocol_identical(ref_b, res_b.sim, "tenant1")
    assert res_a.num_requests == res_b.num_requests == T
    # every launched batch belongs to exactly one tenant
    batches = [e for e in tr.events if e["ph"] == "X"
               and e["name"].startswith("predict/bucket")]
    assert batches and all(e["args"]["tenant"] in (0, tb)
                           for e in batches)


def test_add_tenant_validates_input_dim():
    eng = KernelServingEngine(_lcfg(), ProtocolConfig(kind="dynamic",
                                                      delta=0.1), M)
    bad = LearnerConfig(algo="linear_sgd", loss="hinge", eta=0.1,
                        lam=0.001, dim=D + 1)
    with pytest.raises(ValueError):
        eng.add_tenant(bad, ProtocolConfig(kind="dynamic", delta=0.1))


def test_continuous_launches_on_arrival_not_grid():
    """The continuous policy's whole point: an idle engine answers a
    lone request in exactly predict_cost — no grid wait."""
    pcfg = ProtocolConfig(kind="dynamic", delta=0.1)
    eng = KernelServingEngine(_lcfg(), pcfg, M, policy="continuous",
                              predict_cost=0.25, tick_interval=1.0)
    r = eng.submit(np.zeros(D), learner=0, at=0.3)
    res = eng.serve()
    assert r.done_time == pytest.approx(0.55)
    assert r.latency == pytest.approx(0.25)
    assert res.ticks == 0                      # no grid involved
    assert res.policy == "continuous"


def test_continuous_hold_coalesces_within_budget():
    """With a latency budget, an under-full launch waits for fill —
    but never past oldest.arrival + max_wait."""
    pcfg = ProtocolConfig(kind="dynamic", delta=0.1)
    # lone request: held the full budget, then served
    eng = KernelServingEngine(_lcfg(), pcfg, M, policy="continuous",
                              predict_cost=0.1, max_wait=0.3,
                              buckets=(4,))
    r = eng.submit(np.zeros(D), learner=0, at=1.0)
    eng.serve()
    assert r.done_time == pytest.approx(1.0 + 0.3 + 0.1)

    # a second arrival inside the hold window rides the same launch
    eng2 = KernelServingEngine(_lcfg(), pcfg, M, policy="continuous",
                               predict_cost=0.1, max_wait=0.3,
                               buckets=(4,))
    ra = eng2.submit(np.zeros(D), learner=0, at=1.0)
    rb = eng2.submit(np.zeros(D), learner=0, at=1.2)
    res2 = eng2.serve()
    assert res2.launches == 1                 # coalesced
    assert ra.done_time == rb.done_time == pytest.approx(1.4)

    # a full bucket launches immediately, budget or not
    eng3 = KernelServingEngine(_lcfg(), pcfg, M, policy="continuous",
                               predict_cost=0.1, max_wait=0.5,
                               buckets=(2,))
    rs = [eng3.submit(np.zeros(D), learner=0, at=1.0) for _ in range(2)]
    eng3.serve()
    assert all(r.done_time == pytest.approx(1.1) for r in rs)


def test_slots_bound_concurrent_launches():
    """slots=k is k-way in-flight batching on one shard: with two
    lanes, two same-shard launches overlap; with one, they serialize
    (the PR 5 single predict server)."""
    pcfg = ProtocolConfig(kind="dynamic", delta=0.1)
    for slots, dones in ((1, [1.0, 2.0]), (2, [1.0, 1.0])):
        eng = KernelServingEngine(_lcfg(), pcfg, M, policy="continuous",
                                  predict_cost=1.0, buckets=(1,),
                                  slots=slots)
        rs = [eng.submit(np.zeros(D), learner=0, at=0.0)
              for _ in range(2)]
        eng.serve()
        assert [r.done_time for r in rs] == pytest.approx(dones), slots


def test_admission_shed_refuses_and_marks():
    """Over the queue bound with overload='shed', a request is refused:
    marked shed, never served, excluded from the latency ledger."""
    pcfg = ProtocolConfig(kind="dynamic", delta=0.1)
    eng = KernelServingEngine(_lcfg(), pcfg, M, policy="continuous",
                              predict_cost=1.0, buckets=(1,),
                              max_queue=1, overload="shed")
    ra = eng.submit(np.zeros(D), learner=0, at=0.0)   # launches at once
    rb = eng.submit(np.zeros(D), learner=0, at=0.0)   # queued
    rc = eng.submit(np.zeros(D), learner=0, at=0.0)   # queue full: shed
    res = eng.serve()
    assert ra.done and rb.done
    assert rc.shed and not rc.done
    assert res.num_shed == 1
    assert res.num_requests == 2              # shed never enters stats


def test_admission_defer_retries_and_accrues_latency():
    """overload='defer' re-prices the arrival onto the event clock:
    the request eventually lands, and its latency counts from the
    ORIGINAL arrival — deferral is never free."""
    pcfg = ProtocolConfig(kind="dynamic", delta=0.1)
    eng = KernelServingEngine(_lcfg(), pcfg, M, policy="continuous",
                              predict_cost=1.0, buckets=(1,),
                              max_queue=1, overload="defer",
                              defer_interval=0.25)
    rs = [eng.submit(np.zeros(D), learner=0, at=0.0) for _ in range(3)]
    res = eng.serve()
    assert all(r.done for r in rs)            # nothing lost
    assert res.num_shed == 0
    assert res.num_deferred >= 1
    last = max(rs, key=lambda r: r.done_time)
    assert last.deferrals >= 1
    assert last.latency >= 2.0                # queued behind two launches
    assert res.num_requests == 3
