"""Backend-parity matrix: backend="pallas" vs backend="reference".

The contract (ISSUE 7, DESIGN.md Sec. 12) has two regimes:

- BELOW the Pallas launch threshold (kernels.ops.engages is False) the
  pallas backend runs the reference expressions verbatim, so every
  observable is BIT-IDENTICAL — asserted with exact equality here, and
  it is what makes the Def. 1 byte ledger backend-independent by
  construction (tools/substrate_matrix.py runs the full protocol
  matrix on it).
- AT OR ABOVE the threshold the fused kernels produce the numbers,
  compared against the reference within the ONE pinned tolerance in
  conftest.py (assert_backend_parity).

The deterministic sweep below runs everywhere; the hypothesis sweep at
the bottom widens the same assertions over random shapes when
hypothesis is installed (CI always has it — pyproject pins nothing
locally, so it import-skips, mirroring tests/test_property.py).
Shapes deliberately include non-multiples of 128, budget-1 SV sets,
and empty/all-padded sorted-id buffers.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_backend_parity

from repro.core import engine
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.core.rff import RFFSpec
from repro.core.rkhs import KernelSpec, SVModel
from repro.core.substrate import RFFSubstrate, SVSubstrate
from repro.kernels import ops

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # container without hypothesis: CI has it
    HAVE_HYPOTHESIS = False


def _sv_sub(budget, d=7, kind="gaussian", backend="reference"):
    lcfg = LearnerConfig(algo="kernel_sgd", budget=budget, dim=d,
                         kernel=KernelSpec(kind=kind, gamma=0.4))
    return SVSubstrate(lcfg=lcfg, backend=backend)


def _stacked_models(seed, m, budget, d, active_frac=0.8):
    """A stacked SVModel with ``active_frac`` of slots active; inactive
    slots follow the repo convention (sv_id=-1, zeroed payload)."""
    rng = np.random.default_rng(seed)
    sv = rng.normal(size=(m, budget, d)).astype(np.float32)
    alpha = rng.normal(size=(m, budget)).astype(np.float32)
    active = rng.random((m, budget)) < active_frac
    ids = np.arange(m * budget, dtype=np.int32).reshape(m, budget)
    ids = np.where(active, ids, -1)
    sv = np.where(active[..., None], sv, 0.0)
    alpha = np.where(active, alpha, 0.0)
    return SVModel(sv=jnp.asarray(sv), alpha=jnp.asarray(alpha),
                   sv_id=jnp.asarray(ids, jnp.int32))


def _one_model(seed, budget, d, active_frac=0.8):
    stacked = _stacked_models(seed, 1, budget, d, active_frac)
    return jax.tree.map(lambda v: v[0], stacked)


def _parity_pair(budget, d, kind):
    return (_sv_sub(budget, d, kind, "reference"),
            _sv_sub(budget, d, kind, "pallas"))


# budgets straddle the 128 threshold and its pad boundaries, plus the
# degenerate budget-1 set
SV_BUDGETS = [1, 31, 127, 128, 129, 200]
ACTIVE_FRACS = [0.0, 0.8, 1.0]      # 0.0 = all-padded sorted-id buffer


class TestSVParity:
    @pytest.mark.parametrize("budget", SV_BUDGETS)
    @pytest.mark.parametrize("kind", ["gaussian", "linear", "poly"])
    def test_predict(self, budget, kind):
        ref_sub, pal_sub = _parity_pair(budget, 7, kind)
        models = _stacked_models(1, 3, budget, 7)
        x = jnp.asarray(
            np.random.default_rng(2).normal(size=(3, 7)), jnp.float32)
        want = ref_sub.predict(models, x)
        got = pal_sub.predict(models, x)
        assert_backend_parity(got, want, f"predict b={budget} {kind}")
        if not ops.engages(budget):
            assert np.array_equal(np.asarray(got), np.asarray(want)), (
                "sub-threshold pallas must be bit-identical")

    @pytest.mark.parametrize("budget", SV_BUDGETS)
    @pytest.mark.parametrize("frac", ACTIVE_FRACS)
    def test_predict_batch_and_rows(self, budget, frac):
        ref_sub, pal_sub = _parity_pair(budget, 7, "gaussian")
        models = _stacked_models(3, 4, budget, 7, active_frac=frac)
        rng = np.random.default_rng(4)
        lids = jnp.asarray(rng.integers(0, 4, size=11), jnp.int32)
        Xb = jnp.asarray(rng.normal(size=(11, 7)), jnp.float32)
        want = ref_sub.predict_batch(models, lids, Xb)
        got = pal_sub.predict_batch(models, lids, Xb)
        assert_backend_parity(got, want, f"predict_batch b={budget}")
        if frac == 0.0:      # empty expansions predict exactly zero
            assert np.array_equal(np.asarray(got), np.zeros(11, np.float32))
        # the serving contract on the fused path: each batch row is
        # bitwise the lone predict_one of its home model
        rows = np.asarray(got)
        for i in [0, 5, 10]:
            one = pal_sub.predict_one(
                jax.tree.map(lambda v: v[lids[i]], models), Xb[i])
            assert rows[i] == float(one), (
                f"row {i} differs from predict_one at b={budget}")

    @pytest.mark.parametrize("budget", [1, 31, 129])
    def test_dist_and_divergence(self, budget):
        ref_sub, pal_sub = _parity_pair(budget, 7, "gaussian")
        models = _stacked_models(5, 3, budget, 7)
        ref_model = _one_model(6, budget, 7)
        want = ref_sub.dist_to_ref(models, ref_model)
        got = pal_sub.dist_to_ref(models, ref_model)
        assert_backend_parity(got, want, f"dist_to_ref b={budget}")
        want_d = ref_sub.divergence(models)
        got_d = pal_sub.divergence(models)
        assert_backend_parity(got_d, want_d, f"divergence b={budget}")
        if not ops.engages(budget):
            assert np.array_equal(np.asarray(got), np.asarray(want))
            assert float(got_d) == float(want_d)

    @pytest.mark.parametrize("budget", [1, 129])
    def test_round_stacked(self, budget):
        ref_sub, pal_sub = _parity_pair(budget, 7, "gaussian")
        m = 3
        state = ref_sub.init(m)
        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.normal(size=(m, 7)), jnp.float32)
        y = jnp.asarray(rng.choice([-1.0, 1.0], size=(m,)), jnp.float32)
        # a few warm rounds so the models are non-trivial
        for _ in range(4):
            state, _, _ = ref_sub.round_stacked(state, (x, y))
        s_ref, l_ref, y_ref = ref_sub.round_stacked(state, (x, y))
        s_pal, l_pal, y_pal = pal_sub.round_stacked(state, (x, y))
        assert_backend_parity(y_pal, y_ref, "round yhat")
        assert_backend_parity(l_pal, l_ref, "round losses")
        assert_backend_parity(s_pal.model.alpha, s_ref.model.alpha,
                              "round alphas")
        # the fused round must also equal the composed predict + update
        yhat_c = ref_sub.predict(state.model, x)
        s_c, l_c = ref_sub.update(state, (x, y))
        assert np.array_equal(np.asarray(yhat_c), np.asarray(y_ref))
        assert np.array_equal(np.asarray(l_c), np.asarray(l_ref))
        assert np.array_equal(np.asarray(s_c.model.alpha),
                              np.asarray(s_ref.model.alpha))


RFF_FEATURES = [32, 127, 128, 129, 256]


class TestRFFParity:
    @pytest.mark.parametrize("D", RFF_FEATURES)
    def test_predict_and_batch(self, D):
        ref_sub = RFFSubstrate(spec=RFFSpec(dim=6, num_features=D, seed=0))
        pal_sub = dataclasses.replace(ref_sub, backend="pallas")
        m = 3
        rng = np.random.default_rng(8)
        models = jax.tree.map(
            jnp.asarray,
            type(ref_sub.init(m))(
                w=jnp.asarray(rng.normal(size=(m, D)), jnp.float32),
                b=jnp.asarray(rng.normal(size=(m,)), jnp.float32)))
        x = jnp.asarray(rng.normal(size=(m, 6)), jnp.float32)
        want = ref_sub.predict(models, x)
        got = pal_sub.predict(models, x)
        assert_backend_parity(got, want, f"rff predict D={D}")
        lids = jnp.asarray(rng.integers(0, m, size=9), jnp.int32)
        Xb = jnp.asarray(rng.normal(size=(9, 6)), jnp.float32)
        assert_backend_parity(pal_sub.predict_batch(models, lids, Xb),
                              ref_sub.predict_batch(models, lids, Xb),
                              f"rff predict_batch D={D}")

    @pytest.mark.parametrize("D", [32, 129, 256])
    @pytest.mark.parametrize("loss", ["hinge", "squared"])
    def test_round_stacked(self, D, loss):
        ref_sub = RFFSubstrate(spec=RFFSpec(dim=6, num_features=D, seed=0),
                               loss=loss)
        pal_sub = dataclasses.replace(ref_sub, backend="pallas")
        m = 3
        state = ref_sub.init(m)
        rng = np.random.default_rng(9)
        for i in range(3):
            x = jnp.asarray(rng.normal(size=(m, 6)), jnp.float32)
            y = jnp.asarray(rng.choice([-1.0, 1.0], size=(m,)), jnp.float32)
            state, _ = ref_sub.update(state, (x, y))
        x = jnp.asarray(rng.normal(size=(m, 6)), jnp.float32)
        y = jnp.asarray(rng.choice([-1.0, 1.0], size=(m,)), jnp.float32)
        s_ref, l_ref, y_ref = ref_sub.round_stacked(state, (x, y))
        s_pal, l_pal, y_pal = pal_sub.round_stacked(state, (x, y))
        assert_backend_parity(y_pal, y_ref, f"rff round yhat D={D}")
        assert_backend_parity(l_pal, l_ref, f"rff round loss D={D}")
        assert_backend_parity(s_pal.w, s_ref.w, f"rff round w D={D}")
        assert_backend_parity(s_pal.b, s_ref.b, f"rff round b D={D}")
        # unfused reference round == composed predict + update, bitwise
        yhat_c = ref_sub.predict(state, x)
        s_c, l_c = ref_sub.update(state, (x, y))
        assert np.array_equal(np.asarray(yhat_c), np.asarray(y_ref))
        assert np.array_equal(np.asarray(l_c), np.asarray(l_ref))
        assert np.array_equal(np.asarray(s_c.w), np.asarray(s_ref.w))


class TestEngineParity:
    """End-to-end: the scan engine's observables across backends."""

    def _stream(self, T=50, m=3, d=8, seed=0):
        rng = np.random.default_rng(seed)
        X = np.asarray(rng.normal(size=(T, m, d)), np.float32)
        Y = np.asarray(rng.choice([-1.0, 1.0], size=(T, m)), np.float32)
        return X, Y

    @pytest.mark.parametrize("kind", ["periodic", "dynamic"])
    def test_small_sv_bitwise(self, kind):
        X, Y = self._stream()
        sub = _sv_sub(32, 8)
        pcfg = ProtocolConfig(kind=kind, period=10, delta=1.0, mini_batch=5)
        r_ref = engine.run(sub, pcfg, X, Y)
        r_pal = engine.run(dataclasses.replace(sub, backend="pallas"),
                           pcfg, X, Y)
        assert np.array_equal(r_ref.cumulative_loss, r_pal.cumulative_loss)
        assert np.array_equal(r_ref.cumulative_errors,
                              r_pal.cumulative_errors)
        assert int(r_ref.total_bytes) == int(r_pal.total_bytes)
        assert r_ref.num_syncs == r_pal.num_syncs

    def test_engaged_sv_parity(self):
        X, Y = self._stream(T=30)
        sub = _sv_sub(130, 8)
        pcfg = ProtocolConfig(kind="periodic", period=10)
        r_ref = engine.run(sub, pcfg, X, Y)
        r_pal = engine.run(dataclasses.replace(sub, backend="pallas"),
                           pcfg, X, Y)
        assert_backend_parity(r_pal.cumulative_loss, r_ref.cumulative_loss,
                              "engaged SV engine losses")
        assert int(r_ref.total_bytes) == int(r_pal.total_bytes)

    def test_engaged_rff_parity(self):
        X, Y = self._stream(T=40)
        sub = RFFSubstrate(spec=RFFSpec(dim=8, num_features=256, seed=0))
        pcfg = ProtocolConfig(kind="dynamic", delta=1.0, mini_batch=5)
        r_ref = engine.run(sub, pcfg, X, Y)
        r_pal = engine.run(dataclasses.replace(sub, backend="pallas"),
                           pcfg, X, Y)
        assert_backend_parity(r_pal.cumulative_loss, r_ref.cumulative_loss,
                              "engaged RFF engine losses")
        assert int(r_ref.total_bytes) == int(r_pal.total_bytes)
        assert r_ref.num_syncs == r_pal.num_syncs


# ---------------------------------------------------------------------------
# Property-based shape sweep (hypothesis; CI always installs it)
# ---------------------------------------------------------------------------


if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(budget=st.integers(1, 160), d=st.integers(1, 16),
           m=st.integers(1, 4), seed=st.integers(0, 2**16),
           frac=st.sampled_from([0.0, 0.5, 1.0]),
           kind=st.sampled_from(["gaussian", "linear", "poly"]))
    def test_sv_parity_sweep(budget, d, m, seed, frac, kind):
        ref_sub, pal_sub = _parity_pair(budget, d, kind)
        models = _stacked_models(seed, m, budget, d, active_frac=frac)
        rng = np.random.default_rng(seed + 1)
        x = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
        want = ref_sub.predict(models, x)
        got = pal_sub.predict(models, x)
        assert_backend_parity(got, want, f"sweep predict b={budget} d={d}")
        if not ops.engages(budget):
            assert np.array_equal(np.asarray(got), np.asarray(want))
        lids = jnp.asarray(rng.integers(0, m, size=6), jnp.int32)
        Xb = jnp.asarray(rng.normal(size=(6, d)), jnp.float32)
        assert_backend_parity(pal_sub.predict_batch(models, lids, Xb),
                              ref_sub.predict_batch(models, lids, Xb),
                              f"sweep batch b={budget} d={d}")
        ref_model = _one_model(seed + 2, budget, d, active_frac=max(frac, 0.5))
        assert_backend_parity(pal_sub.dist_to_ref(models, ref_model),
                              ref_sub.dist_to_ref(models, ref_model),
                              f"sweep dist b={budget} d={d}")

    @settings(max_examples=8, deadline=None)
    @given(D=st.integers(1, 200), d=st.integers(1, 12),
           m=st.integers(1, 4), seed=st.integers(0, 2**16))
    def test_rff_parity_sweep(D, d, m, seed):
        ref_sub = RFFSubstrate(spec=RFFSpec(dim=d, num_features=D, seed=0))
        pal_sub = dataclasses.replace(ref_sub, backend="pallas")
        rng = np.random.default_rng(seed)
        state = type(ref_sub.init(m))(
            w=jnp.asarray(rng.normal(size=(m, D)), jnp.float32),
            b=jnp.asarray(rng.normal(size=(m,)), jnp.float32))
        x = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
        y = jnp.asarray(rng.choice([-1.0, 1.0], size=(m,)), jnp.float32)
        assert_backend_parity(pal_sub.predict(state, x),
                              ref_sub.predict(state, x),
                              f"rff sweep predict D={D}")
        s_ref, l_ref, y_ref = ref_sub.round_stacked(state, (x, y))
        s_pal, l_pal, y_pal = pal_sub.round_stacked(state, (x, y))
        assert_backend_parity(y_pal, y_ref, f"rff sweep yhat D={D}")
        assert_backend_parity(s_pal.w, s_ref.w, f"rff sweep w D={D}")
        if not ops.engages(m, D):
            assert np.array_equal(np.asarray(y_pal), np.asarray(y_ref))
            assert np.array_equal(np.asarray(s_pal.w), np.asarray(s_ref.w))
