"""Fused kernels, fallback boundaries, and the block-size autotuner.

Three contracts from ISSUE 7:

- fallback boundary: shapes below ``ops._MIN_PALLAS`` take the jnp
  reference path bit-for-bit (and never launch); ``force_pallas=True``
  on the same shapes still matches within the pinned parity tolerance;
  ``_pad_to`` cropping is exact at n = mult +/- 1 for every kernel
  kind;
- fused kernels equal their oracles (kernels/ref.py) for all kernel
  kinds and both losses;
- the autotuner resolves deterministically off-TPU and value-equal
  configs reuse tuned blocks with ZERO new XLA compiles
  (telemetry.probe.CompileCounter) — the recompile-regression gate.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_backend_parity

from repro.core import engine
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.core.rkhs import KernelSpec
from repro.core.substrate import SVSubstrate
from repro.kernels import autotune, ops, ref
from repro.telemetry.probe import CompileCounter

KINDS = ["gaussian", "linear", "poly"]


def _rng(seed=0):
    return np.random.default_rng(seed)


def _sv_args(rng, B, N, d):
    X = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)
    SV = jnp.asarray(rng.normal(size=(B, N, d)), jnp.float32)
    A = jnp.asarray(rng.normal(size=(B, N)), jnp.float32)
    mask = jnp.asarray(rng.random((B, N)) < 0.8, jnp.float32)
    return X, SV, A * mask


def _step_args(rng, B, d, D=None):
    X = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)
    Y = jnp.asarray(rng.choice([-1.0, 1.0], size=(B,)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(B, D or d)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(B,)), jnp.float32)
    kw = {}
    if D is not None:
        kw["W"] = jnp.asarray(rng.normal(size=(D, d)), jnp.float32)
        kw["bias"] = jnp.asarray(
            rng.uniform(0, 2 * np.pi, size=(D,)), jnp.float32)
        kw["scale"] = float(np.sqrt(2.0 / D))
    return (X, Y, w, b), kw


# ---------------------------------------------------------------------------
# Fused kernels vs oracles
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("N", [127, 129, 256])
def test_sv_predict_matches_oracle(kind, N):
    X, SV, A = _sv_args(_rng(1), 4, N, 9)
    want = ref.sv_predict_ref(X, SV, A, kind=kind, gamma=0.5)
    got = ops.sv_predict(X, SV, A, kind=kind, gamma=0.5, force_pallas=True)
    assert got.shape == (4,)
    assert_backend_parity(got, want, f"sv_predict {kind} N={N}")


@pytest.mark.parametrize("loss", ["hinge", "squared"])
@pytest.mark.parametrize("B", [127, 129])
def test_fused_rff_step_matches_oracle(loss, B):
    args, kw = _step_args(_rng(2), B, 9, D=140)
    want = ref.primal_step_ref(*args, loss=loss, eta=0.3, lam=0.01, **kw)
    got = ops.fused_primal_step(*args, loss=loss, eta=0.3, lam=0.01,
                                force_pallas=True, **kw)
    for g, w, name in zip(got, want, ["w", "b", "ell", "yhat"]):
        assert_backend_parity(g, w, f"rff_step/{name} {loss} B={B}")


@pytest.mark.parametrize("B", [127, 129])
def test_fused_linear_step_matches_oracle(B):
    args, _ = _step_args(_rng(3), B, 9)
    want = ref.primal_step_ref(*args, loss="hinge", eta=0.3, lam=0.01)
    got = ops.fused_primal_step(*args, loss="hinge", eta=0.3, lam=0.01,
                                force_pallas=True)
    for g, w, name in zip(got, want, ["w", "b", "ell", "yhat"]):
        assert_backend_parity(g, w, f"linear_step/{name} B={B}")


# ---------------------------------------------------------------------------
# Fallback boundary
# ---------------------------------------------------------------------------


def test_engages_threshold():
    assert not ops.engages(1)
    assert not ops.engages(127, 100)
    assert ops.engages(128)
    assert ops.engages(2, 128)


def test_below_min_pallas_is_reference_bitwise():
    """Sub-threshold calls return the jnp oracle's exact floats and
    never count a launch."""
    rng = _rng(4)
    X = jnp.asarray(rng.normal(size=(40, 9)), jnp.float32)
    Y = jnp.asarray(rng.normal(size=(30, 9)), jnp.float32)
    a = jnp.asarray(rng.normal(size=(40,)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(30,)), jnp.float32)
    Xs, SVs, As = _sv_args(rng, 3, 40, 9)
    sargs, skw = _step_args(rng, 5, 9, D=40)
    before = dict(ops.LAUNCH_COUNTS)
    checks = [
        (ops.gram(X, Y, gamma=0.5), ref.gram_ref(X, Y, gamma=0.5)),
        (ops.quadform(X, Y, a, b, gamma=0.5),
         ref.quadform_ref(X, Y, a, b, gamma=0.5)),
        (ops.sv_predict(Xs, SVs, As, gamma=0.5),
         ref.sv_predict_ref(Xs, SVs, As, gamma=0.5)),
    ]
    got_step = ops.fused_primal_step(*sargs, loss="hinge", **skw)
    want_step = ref.primal_step_ref(*sargs, loss="hinge", **skw)
    checks += list(zip(got_step, want_step))
    for got, want in checks:
        assert np.array_equal(np.asarray(got), np.asarray(want))
    assert dict(ops.LAUNCH_COUNTS) == before, "fallback must not launch"


def test_force_pallas_on_small_shapes_is_close():
    rng = _rng(5)
    Xs, SVs, As = _sv_args(rng, 3, 40, 9)
    assert_backend_parity(
        ops.sv_predict(Xs, SVs, As, gamma=0.5, force_pallas=True),
        ref.sv_predict_ref(Xs, SVs, As, gamma=0.5), "forced small sv")
    sargs, skw = _step_args(rng, 5, 9, D=40)
    got = ops.fused_primal_step(*sargs, loss="hinge", force_pallas=True,
                                **skw)
    want = ref.primal_step_ref(*sargs, loss="hinge", **skw)
    for g, w in zip(got, want):
        assert_backend_parity(g, w, "forced small step")


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("n", [127, 129])
def test_pad_crop_exact_every_kind(kind, n):
    """n = mult +/- 1 exercises both pad directions; outputs must crop
    back to exactly the unpadded extents with oracle-close values."""
    rng = _rng(6)
    X = jnp.asarray(rng.normal(size=(n, 9)), jnp.float32)
    Y = jnp.asarray(rng.normal(size=(n, 9)), jnp.float32)
    a = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    K = ops.gram(X, Y, kind=kind, gamma=0.5, force_pallas=True)
    assert K.shape == (n, n)
    np.testing.assert_allclose(
        np.asarray(K),
        np.asarray(ref.gram_ref(X, Y, kind=kind, gamma=0.5)),
        rtol=2e-5, atol=2e-5)
    q = ops.quadform(X, Y, a, b, kind=kind, gamma=0.5, force_pallas=True)
    assert q.shape == ()
    assert_backend_parity(q, ref.quadform_ref(X, Y, a, b, kind=kind,
                                              gamma=0.5), f"qf {kind} {n}")


@pytest.mark.parametrize("n", [127, 129])
def test_pad_crop_exact_rff_and_fused(n):
    rng = _rng(7)
    X = jnp.asarray(rng.normal(size=(n, 9)), jnp.float32)
    W = jnp.asarray(rng.normal(size=(n, 9)), jnp.float32)
    bias = jnp.asarray(rng.uniform(0, 2 * np.pi, size=(n,)), jnp.float32)
    Z = ops.rff_features(X, W, bias, force_pallas=True)
    assert Z.shape == (n, n)
    np.testing.assert_allclose(
        np.asarray(Z), np.asarray(ref.rff_ref(X, W, bias)),
        rtol=2e-5, atol=2e-5)
    Xs, SVs, As = _sv_args(rng, 3, n, 9)
    got = ops.sv_predict(Xs, SVs, As, gamma=0.5, force_pallas=True)
    assert got.shape == (3,)
    assert_backend_parity(got, ref.sv_predict_ref(Xs, SVs, As, gamma=0.5),
                          f"sv crop {n}")
    sargs, skw = _step_args(rng, n, 9, D=n)
    got = ops.fused_primal_step(*sargs, loss="hinge", force_pallas=True,
                                **skw)
    want = ref.primal_step_ref(*sargs, loss="hinge", **skw)
    assert got[0].shape == (n, n) and got[1].shape == (n,)
    for g, w in zip(got, want):
        assert_backend_parity(g, w, f"step crop {n}")


# ---------------------------------------------------------------------------
# Autotuner
# ---------------------------------------------------------------------------


def test_autotune_candidates_and_defaults():
    assert autotune.candidates_for(100) == (128,)
    assert autotune.candidates_for(200) == (128, 256)
    assert autotune.candidates_for(600) == (128, 256, 512)
    assert autotune.default_blocks((100, 600)) == (128, 128)


def test_autotune_cache_deterministic_off_tpu():
    autotune.clear_cache()
    try:
        calls = []
        b1 = autotune.tuned_blocks("op", (300, 40), kind="k",
                                   measure=lambda blk: calls.append(blk))
        b2 = autotune.tuned_blocks("op", (300, 40), kind="k",
                                   measure=lambda blk: calls.append(blk))
        assert b1 == b2 == (128, 128)
        assert calls == [], "no search may run off-TPU"
        key = autotune.TileKey("op", (300, 40), "float32", "k")
        assert autotune.cache_info()[key].source == "default"
    finally:
        autotune.clear_cache()


def test_autotune_pin_overrides():
    autotune.clear_cache()
    try:
        autotune.pin("sv_predict", (256,), (256,), kind="gaussian:d=9")
        blocks = autotune.tuned_blocks("sv_predict", (256,),
                                       kind="gaussian:d=9")
        assert blocks == (256,)
        X, SV, A = _sv_args(_rng(8), 3, 256, 9)
        got = ops.sv_predict(X, SV, A, kind="gaussian", gamma=0.5)
        assert_backend_parity(
            got, ref.sv_predict_ref(X, SV, A, kind="gaussian", gamma=0.5),
            "pinned 256 block")
    finally:
        autotune.clear_cache()


# ---------------------------------------------------------------------------
# Recompile regression (the PR 6 compile counters as the gate)
# ---------------------------------------------------------------------------


def _pallas_sub():
    return SVSubstrate(
        lcfg=LearnerConfig(algo="kernel_sgd", budget=130, dim=8,
                           kernel=KernelSpec(kind="gaussian", gamma=0.3)),
        backend="pallas")


def test_ops_reuse_compiles_across_autotune_resets():
    """Value-equal calls hit the jit cache even after the tuner's table
    is dropped: off-TPU resolution is deterministic, so the launcher's
    static block args — and therefore its executable — are identical."""
    X, SV, A = _sv_args(_rng(9), 3, 200, 9)
    ops.sv_predict(X, SV, A, gamma=0.5)          # warm (may compile)
    with CompileCounter() as c:
        ops.sv_predict(X, SV, A, gamma=0.5)
        autotune.clear_cache()
        ops.sv_predict(X, SV, A, gamma=0.5)
    assert c.compiles == 0


def test_engine_zero_recompiles_for_value_equal_pallas_substrate():
    """Two value-equal pallas substrates are one compile-cache entry:
    the second engine.run traces and compiles NOTHING new."""
    rng = _rng(10)
    X = np.asarray(rng.normal(size=(25, 3, 8)), np.float32)
    Y = np.asarray(rng.choice([-1.0, 1.0], size=(25, 3)), np.float32)
    pcfg = ProtocolConfig(kind="periodic", period=10)
    engine.run(_pallas_sub(), pcfg, X, Y)        # warm (compiles)
    with CompileCounter() as c:
        r = engine.run(dataclasses.replace(_pallas_sub()), pcfg, X, Y)
    assert c.compiles == 0, "value-equal pallas config recompiled"
    assert np.isfinite(r.total_loss)
