"""Quiescence + concept-drift behaviour (the protocol's raison d'etre).

The efficiency criterion's signature: communication vanishes when loss
vanishes — and, crucially, the dynamic protocol WAKES UP again when the
distribution drifts (periodic protocols pay constantly; isolated
learners never re-coordinate).
"""
import numpy as np

from repro.core import accounting, simulation
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.data import drifting_stream, separable_stream


def test_quiescence_then_drift_then_requiescence():
    """Phase 1: separable stream -> protocol must go quiescent.
    Phase 2 (drift): labels flip direction -> syncs must resume.
    Phase 3: drifted-but-stable -> quiescent again."""
    T, m, d = 900, 4, 8
    rng = np.random.default_rng(0)
    w = rng.normal(size=(d,)); w /= np.linalg.norm(w)
    X = rng.normal(size=(T, m, d)).astype(np.float32)
    s = X @ w
    X += (np.sign(s) * 1.0)[..., None] * w          # margin
    Y = np.sign(X @ w).astype(np.float32)
    Y[T // 3: , :] *= -1.0                           # drift at T/3: flip labels

    lcfg = LearnerConfig(algo="linear_pa", loss="hinge", C=1.0, dim=d)
    res = simulation.run_linear_simulation(
        lcfg, ProtocolConfig(kind="dynamic", delta=1.0), X, Y)

    sync_rounds = np.asarray(res.sync_rounds)
    p1 = ((sync_rounds >= 0) & (sync_rounds < T // 3)).sum()
    p1_late = ((sync_rounds >= T // 3 - T // 9) & (sync_rounds < T // 3)).sum()
    p2 = ((sync_rounds >= T // 3) & (sync_rounds < 2 * T // 3)).sum()
    p3_late = (sync_rounds >= T - T // 9).sum()

    assert p1_late == 0, "should be quiescent before the drift"
    assert p2 >= 1, "drift must reawaken synchronization"
    assert p3_late == 0, "should re-quiesce after adapting to the drift"


def test_no_sync_protocol_never_adapts_jointly():
    """Contrast: isolated learners communicate nothing ever."""
    T, m, d = 300, 4, 8
    X, Y = drifting_stream(T, m, d=d, seed=1, drift_every=100)
    lcfg = LearnerConfig(algo="linear_pa", loss="hinge", C=1.0, dim=d)
    res = simulation.run_linear_simulation(
        lcfg, ProtocolConfig(kind="none"), X, Y)
    assert res.total_bytes == 0 and res.num_syncs == 0


def test_allreduce_vs_coordinator_byte_models():
    """DESIGN.md hardware-adaptation: ring all-reduce moves
    2(m-1)/m * |theta| per participant vs 2m|theta| through a
    coordinator — the all-reduce total is smaller for m >= 2 and the
    ratio approaches m/(m-1) ~ 1 of 2|theta| per device."""
    n = 1000
    for m in (2, 4, 32):
        coord = accounting.sync_bytes_linear(n, m)
        ring = accounting.allreduce_bytes(n, m)
        assert ring < coord
        assert ring == 2 * (m - 1) * n * 4
    assert accounting.allreduce_bytes(n, 1) == 0
