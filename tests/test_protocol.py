"""Protocol operator unit tests (Sec. 2 of the paper)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import protocol
from repro.core.protocol import ProtocolConfig


def _stacked(m=4, d=6, seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(m, d)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(m,)), jnp.float32)}


def test_average_model():
    st = _stacked()
    avg = protocol.average_model(st)
    np.testing.assert_allclose(avg["w"], np.mean(np.asarray(st["w"]), 0),
                               rtol=1e-6)


def test_sigma_continuous_sets_all_to_average():
    st = _stacked()
    out = protocol.sigma_continuous(st)
    avg = protocol.average_model(st)
    for i in range(4):
        np.testing.assert_allclose(out["w"][i], avg["w"], rtol=1e-6)
    # averaging preserves the mean (mass conservation)
    np.testing.assert_allclose(protocol.average_model(out)["w"], avg["w"],
                               rtol=1e-6)


def test_divergence_zero_after_sync():
    st = _stacked()
    out = protocol.sigma_continuous(st)
    assert float(protocol.divergence(out)) < 1e-10
    assert float(protocol.divergence(st)) > 0.0


def test_local_conditions_imply_divergence_bound():
    """If no local condition is violated w.r.t. reference r, then
    delta(f) <= Delta (the geometric monitoring guarantee).

    delta(f) = 1/m sum ||f_i - fbar||^2 <= 1/m sum ||f_i - r||^2
    (the mean minimizes the mean squared distance)."""
    rng = np.random.default_rng(1)
    for trial in range(20):
        m, d = 5, 4
        st = {"w": jnp.asarray(rng.normal(size=(m, d)), jnp.float32)}
        ref = {"w": jnp.asarray(rng.normal(size=(d,)), jnp.float32)}
        delta = float(rng.uniform(0.5, 10.0))
        violated = protocol.local_conditions(st, ref, delta)
        if not bool(jnp.any(violated)):
            assert float(protocol.divergence(st)) <= delta + 1e-6


def test_dynamic_no_sync_below_threshold():
    st = _stacked()
    ref = protocol.average_model(st)
    # huge threshold: no violation, models unchanged
    out, new_ref, synced = protocol.sigma_dynamic(st, ref, delta=1e9)
    assert not bool(synced)
    np.testing.assert_allclose(out["w"], st["w"])


def test_dynamic_sync_on_violation():
    st = _stacked()
    ref = protocol.average_model(st)
    out, new_ref, synced = protocol.sigma_dynamic(st, ref, delta=1e-9)
    assert bool(synced)
    avg = protocol.average_model(st)
    for i in range(4):
        np.testing.assert_allclose(out["w"][i], avg["w"], rtol=1e-6)
    np.testing.assert_allclose(new_ref["w"], avg["w"], rtol=1e-6)


@pytest.mark.parametrize("kind,period", [("continuous", 1), ("periodic", 3)])
def test_apply_protocol_schedules(kind, period):
    cfg = ProtocolConfig(kind=kind, period=period)
    st = _stacked()
    state = protocol.init_state(jax.tree.map(lambda x: x[0], st), 4)
    syncs = 0
    for t in range(6):
        st = _stacked(seed=t + 10)
        st, state = protocol.apply_protocol(cfg, st, state)
    expected = 6 if kind == "continuous" else 2
    assert int(state.syncs) == expected


def test_apply_protocol_counts_bytes():
    cfg = ProtocolConfig(kind="continuous")
    st = _stacked(m=4, d=6)
    state = protocol.init_state(jax.tree.map(lambda x: x[0], st), 4)
    _, state = protocol.apply_protocol(cfg, st, state)
    # 2 * m * model_bytes = 2 * 4 * (6+1)*4 bytes
    assert int(state.bytes_sent) == 2 * 4 * (7 * 4)


def test_stacked_reference_mode():
    st = _stacked()
    one = jax.tree.map(lambda x: x[0], st)
    state = protocol.init_state(one, 4, stacked_reference=True)
    assert jax.tree.leaves(state.reference)[0].shape[0] == 4
    cfg = ProtocolConfig(kind="dynamic", delta=1e-9)
    out, new_state = protocol.apply_protocol(cfg, st, state)
    # after sync the (stacked) reference equals the average in every slot
    avg = protocol.average_model(st)
    for i in range(4):
        np.testing.assert_allclose(new_state.reference["w"][i], avg["w"],
                                   rtol=1e-6)


def test_mini_batch_peak_communication_guard():
    """Sec. 4: with mini_batch=b, syncs happen at most every b rounds."""
    cfg = ProtocolConfig(kind="dynamic", delta=1e-12, mini_batch=3)
    st = _stacked()
    state = protocol.init_state(jax.tree.map(lambda x: x[0], st), 4)
    sync_rounds = []
    for t in range(9):
        st = _stacked(seed=t)
        st, state = protocol.apply_protocol(cfg, st, state)
        sync_rounds.append(int(state.syncs))
    # syncs only at steps 3, 6, 9 -> at most 3
    assert sync_rounds[-1] <= 3


def test_make_protocol_step_runs_and_reduces_divergence():
    cfg = ProtocolConfig(kind="dynamic", delta=0.5)

    def local_update(model, ex):
        x, y = ex
        pred = model["w"] @ x
        err = pred - y
        return {"w": model["w"] - 0.1 * err * x}, 0.5 * err * err

    step = jax.jit(protocol.make_protocol_step(cfg, local_update))
    m, d = 4, 3
    st = {"w": jnp.zeros((m, d))}
    state = protocol.init_state({"w": jnp.zeros((d,))}, m)
    rng = np.random.default_rng(0)
    w_true = rng.normal(size=(d,))
    for t in range(100):
        X = rng.normal(size=(m, d)).astype(np.float32)
        Y = (X @ w_true).astype(np.float32)
        st, state, loss = step(st, state, (jnp.asarray(X), jnp.asarray(Y)))
    assert float(loss) < 0.1
    assert float(protocol.divergence(st)) < 0.5 + 1e-5


def test_sqrt_delta_schedule_tightens_over_time():
    """With Delta_t = delta/sqrt(t), a drift that is tolerated early
    triggers a sync late (the paper's consistency schedule)."""
    cfg = ProtocolConfig(kind="dynamic", delta=4.0, delta_schedule="sqrt")
    base = {"w": jnp.zeros((3, 4))}
    state = protocol.init_state({"w": jnp.zeros((4,))}, 3)
    drifted = {"w": jnp.ones((3, 4)) * jnp.asarray([[1.], [0.], [-1.]])}
    # ||f_i - r||^2 = 4 for learners 0/2. At t=1: Delta=4 -> no sync.
    out1, state = protocol.apply_protocol(cfg, drifted, state)
    assert int(state.syncs) == 0
    # advance time; at t>=2, Delta = 4/sqrt(t) < 4 -> sync fires.
    state = state._replace(step=jnp.asarray(15, jnp.int32))
    out2, state = protocol.apply_protocol(cfg, drifted, state)
    assert int(state.syncs) == 1


def test_adaptive_threshold_reaches_target_sync_rate():
    """The Sec.-4 open problem: the adaptive controller should steer
    the sync rate to the target regardless of the initial Delta."""
    rng = np.random.default_rng(0)
    for delta0 in (1e-6, 1e2):
        cfg = ProtocolConfig(kind="dynamic", delta=delta0,
                             delta_schedule="adaptive",
                             target_sync_rate=0.2, adapt_up=1.5)
        m, d = 4, 6
        st = {"w": jnp.zeros((m, d))}
        state = protocol.init_state({"w": jnp.zeros((d,))}, m)
        T = 400
        for t in range(T):
            # persistent random drift
            st = jax.tree.map(
                lambda x: x + jnp.asarray(rng.normal(size=x.shape) * 0.3,
                                          jnp.float32), st)
            st, state = protocol.apply_protocol(cfg, st, state)
        rate = int(state.syncs) / T
        assert 0.08 < rate < 0.45, (delta0, rate)


def test_per_group_conditions_catch_concentrated_drift():
    """Drift concentrated in a small group violates its proportional
    threshold long before the global norm reaches Delta."""
    m = 3
    st = {"big": jnp.zeros((m, 1000)), "small": jnp.zeros((m, 10))}
    ref = {"big": jnp.zeros((m, 1000)), "small": jnp.zeros((m, 10))}
    # drift of norm^2 = 0.9 entirely in the small group
    st = dict(st)
    st["small"] = st["small"].at[0].set(jnp.sqrt(0.09) * jnp.ones(10))
    delta = 1.0
    glob = protocol.local_conditions(st, ref, delta)
    assert not bool(jnp.any(glob))          # global norm 0.9 < 1.0
    per = protocol.group_local_conditions(st, ref, delta)
    assert bool(per[0])                     # small-group share ~= 0.0099
    # soundness: no per-group violation still implies divergence <= Delta
    st2 = {"big": jnp.zeros((m, 1000)), "small": jnp.zeros((m, 10))}
    per2 = protocol.group_local_conditions(st2, ref, delta)
    assert not bool(jnp.any(per2))


def test_per_group_protocol_round():
    cfg = ProtocolConfig(kind="dynamic", delta=1.0, per_group=True)
    m = 3
    st = {"big": jnp.zeros((m, 100)), "small": jnp.ones((m, 4)) * 0.5}
    state = protocol.init_state({"big": jnp.zeros(100), "small": jnp.zeros(4)}, m)
    out, new_state = protocol.apply_protocol(cfg, st, state)
    assert int(new_state.syncs) == 1   # small-group drift triggers
