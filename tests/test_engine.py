"""Scan engine (core/engine.py) vs the serial oracle, and the device
byte ledger vs the host ledger (DESIGN.md Sec. 7).

The contract under test: the device-resident engine reproduces the
legacy Python-loop driver's byte ledger *exactly* (cumulative_bytes
identical, sync decisions identical) and its losses / errors /
divergences to float32 tolerance.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import accounting, engine, rkhs, simulation
from repro.core.accounting import ByteModel, CommunicationLedger
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.core.rkhs import KernelSpec
from repro.data import separable_stream, susy_stream


# ---------------------------------------------------------------------------
# sorted-id set algebra
# ---------------------------------------------------------------------------


def test_sorted_unique_counts_and_composes():
    ids = jnp.asarray([[5, -1, 3, 5], [3, 7, -1, -1]], jnp.int32)
    uniq, n = rkhs.sorted_unique(ids)
    assert int(n) == 3
    np.testing.assert_array_equal(
        np.asarray(uniq)[:3], [3, 5, 7])
    assert (np.asarray(uniq)[3:] == int(rkhs.ID_SENTINEL)).all()
    # output is a valid input (sentinel slots stay inactive)
    uniq2, n2 = rkhs.sorted_unique(uniq)
    assert int(n2) == 3
    np.testing.assert_array_equal(np.asarray(uniq), np.asarray(uniq2))


def test_count_members():
    a, _ = rkhs.sorted_unique(jnp.asarray([2, 4, 6, -1, -1], jnp.int32))
    q, _ = rkhs.sorted_unique(jnp.asarray([4, 5, 6, -1, -1], jnp.int32))
    assert int(rkhs.count_members(q, a)) == 2
    empty, _ = rkhs.sorted_unique(jnp.asarray([-1, -1], jnp.int32))
    assert int(rkhs.count_members(empty, a)) == 0
    assert int(rkhs.count_members(q, jnp.sort(empty))) == 0


# ---------------------------------------------------------------------------
# DeviceLedger vs CommunicationLedger (byte-for-byte)
# ---------------------------------------------------------------------------


def _random_id_config(rng, m, tau, pool):
    """Random stacked sv_id array with empty slots, ids shared across
    learners (post-sync state), duplicated ids within one learner
    (adopted compressed average), and fresh ids (insertions)."""
    ids = np.full((m, tau), -1, np.int32)
    for i in range(m):
        n_active = int(rng.integers(0, tau + 1))
        chosen = []
        for _ in range(n_active):
            if pool and rng.random() < 0.6:
                chosen.append(int(rng.choice(pool)))   # shared / duplicate
            else:
                fresh = int(rng.integers(0, 100_000))
                pool.append(fresh)
                chosen.append(fresh)
        slots = rng.permutation(tau)[:n_active]
        ids[i, slots] = chosen
    return ids


def _assert_ledgers_agree(seed, m=3, tau=7, n_syncs=6):
    rng = np.random.default_rng(seed)
    bm = ByteModel(dim=5)
    host = CommunicationLedger(bm)
    dev = accounting.device_ledger_init(m * tau)
    pool = []
    for t in range(n_syncs):
        ids = _random_id_config(rng, m, tau, pool)
        b_host = host.record_kernel_sync([ids[i] for i in range(m)], t)
        b_dev, dev = accounting.device_sync_bytes_kernel(
            bm, jnp.asarray(ids), dev)
        assert int(b_dev) == b_host, f"sync {t}: {int(b_dev)} != {b_host}"
    known_dev = np.asarray(dev.known)
    known_dev = set(known_dev[known_dev < int(rkhs.ID_SENTINEL)].tolist())
    assert known_dev == host.coordinator_known


@pytest.mark.parametrize("seed", range(8))
def test_device_ledger_matches_host_ledger(seed):
    _assert_ledgers_agree(seed)


def test_device_ledger_empty_and_full():
    bm = ByteModel(dim=3)
    m, tau = 2, 4
    dev = accounting.device_ledger_init(m * tau)
    empty = np.full((m, tau), -1, np.int32)
    b, dev = accounting.device_sync_bytes_kernel(bm, jnp.asarray(empty), dev)
    assert int(b) == 0
    # all slots active, all distinct: first sync ships everything
    ids = np.arange(m * tau, dtype=np.int32).reshape(m, tau)
    b, dev = accounting.device_sync_bytes_kernel(bm, jnp.asarray(ids), dev)
    host = CommunicationLedger(bm)
    b_host = host.record_kernel_sync([ids[i] for i in range(m)], 0)
    assert int(b) == b_host
    # re-syncing the identical configuration re-ships no vectors
    b2, dev = accounting.device_sync_bytes_kernel(bm, jnp.asarray(ids), dev)
    b2_host = host.record_kernel_sync([ids[i] for i in range(m)], 1)
    assert int(b2) == b2_host
    assert int(b2) < int(b)


def test_device_ledger_refuses_int32_overflow_scales():
    bm = ByteModel(dim=1000)
    m, tau = 64, 4096
    dev = accounting.device_ledger_init(m * tau)
    ids = np.full((m, tau), -1, np.int32)
    with pytest.raises(ValueError, match="int32"):
        accounting.device_sync_bytes_kernel(bm, jnp.asarray(ids), dev)


def test_device_ledger_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def inner(seed):
        _assert_ledgers_agree(seed, m=4, tau=5, n_syncs=4)

    inner()


# ---------------------------------------------------------------------------
# engine.run vs the serial oracle
# ---------------------------------------------------------------------------

T, M, D = 70, 3, 6


def _kernel_cfg(budget=12, **kw):
    return LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.5, lam=0.01,
                         budget=budget,
                         kernel=KernelSpec("gaussian", gamma=0.3), dim=D, **kw)


def _assert_matches_oracle(res_loop, res_eng, check_div=True):
    np.testing.assert_array_equal(res_loop.cumulative_bytes,
                                  res_eng.cumulative_bytes)
    np.testing.assert_array_equal(res_loop.sync_rounds, res_eng.sync_rounds)
    assert res_loop.num_syncs == res_eng.num_syncs
    np.testing.assert_allclose(res_loop.cumulative_loss,
                               res_eng.cumulative_loss, rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(res_loop.cumulative_errors,
                                  res_eng.cumulative_errors)
    assert abs(res_loop.total_loss - res_eng.total_loss) <= \
        1e-5 * max(1.0, abs(res_loop.total_loss))
    if check_div and len(res_eng.divergences):
        np.testing.assert_allclose(res_loop.divergences, res_eng.divergences,
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("pcfg", [
    ProtocolConfig(kind="dynamic", delta=2.0),
    ProtocolConfig(kind="dynamic", delta=1.0, mini_batch=4),
    ProtocolConfig(kind="periodic", period=9),
    ProtocolConfig(kind="continuous"),
    ProtocolConfig(kind="none"),
], ids=lambda p: f"{p.kind}-d{p.delta}-b{p.period}-mb{p.mini_batch}")
def test_engine_matches_kernel_oracle(pcfg):
    X, Y = susy_stream(T=T, m=M, d=D, seed=3)
    lcfg = _kernel_cfg()
    res_loop = simulation.run_kernel_simulation(lcfg, pcfg, X, Y)
    res_eng = engine.run(lcfg, pcfg, X, Y, record_divergence=True)
    _assert_matches_oracle(res_loop, res_eng)
    np.testing.assert_allclose(res_loop.eps_history, res_eng.eps_history,
                               rtol=1e-4, atol=1e-5)


def test_engine_matches_kernel_oracle_projection_and_budget():
    X, Y = susy_stream(T=50, m=M, d=D, seed=5)
    lcfg = _kernel_cfg(budget=10)
    pcfg = ProtocolConfig(kind="dynamic", delta=1.0)
    res_loop = simulation.run_kernel_simulation(
        lcfg, pcfg, X, Y, sync_budget=6, compress_method="project")
    res_eng = engine.run(lcfg, pcfg, X, Y, sync_budget=6,
                         compress_method="project", record_divergence=True)
    _assert_matches_oracle(res_loop, res_eng)


@pytest.mark.parametrize("pcfg", [
    ProtocolConfig(kind="dynamic", delta=1.0),
    ProtocolConfig(kind="periodic", period=10),
    ProtocolConfig(kind="continuous"),
], ids=lambda p: p.kind)
def test_engine_matches_linear_oracle(pcfg):
    X, Y = separable_stream(T=T, m=M, d=D, seed=0, margin=1.0)
    lcfg = LearnerConfig(algo="linear_pa", loss="hinge", C=1.0, dim=D)
    res_loop = simulation.run_linear_simulation(lcfg, pcfg, X, Y)
    res_eng = engine.run(lcfg, pcfg, X, Y)
    _assert_matches_oracle(res_loop, res_eng)
    assert len(res_eng.eps_history) == 0


def test_topology_allreduce_same_decisions_ring_pricing():
    """topology="allreduce" swaps the Sec. 3 coordinator pricing for
    the mesh ring total (DESIGN.md Sec. 9) without touching a single
    sync decision — with or without a mesh."""
    from repro.core import accounting
    from repro.core.substrate import substrate_of

    X, Y = susy_stream(T=60, m=M, d=D, seed=2)
    for learner in [_kernel_cfg(),
                    LearnerConfig(algo="linear_sgd", loss="hinge", eta=0.1,
                                  lam=0.001, dim=D)]:
        sub = substrate_of(learner)
        pcfg = ProtocolConfig(kind="dynamic", delta=1.0)
        rc = engine.run(learner, pcfg, X, Y)
        ra = engine.run(learner, pcfg, X, Y, topology="allreduce")
        np.testing.assert_array_equal(rc.sync_rounds, ra.sync_rounds)
        np.testing.assert_array_equal(rc.cumulative_loss, ra.cumulative_loss)
        assert ra.num_syncs > 0
        assert ra.total_bytes == ra.num_syncs * sub.allreduce_sync_bytes(M)
    # the primal ring total IS the fixed accounting.allreduce_bytes
    lin = substrate_of(LearnerConfig(algo="linear_sgd", dim=D))
    assert lin.allreduce_sync_bytes(M) == accounting.allreduce_bytes(D + 1, M)


def test_round0_zero_margin_predicts_positive_in_every_driver():
    """The hinge decision rule is deterministic at a zero margin
    (yhat >= 0 -> +1): an untrained all-zero model errs exactly on the
    negative labels at round 0 — not on every label — identically in
    the engine, the serial oracle, and the async runtime."""
    from repro.runtime import (AsyncProtocolConfig, SystemConfig,
                               run_async_simulation)

    X, Y = susy_stream(T=3, m=M, d=D, seed=11)
    Y[0] = np.asarray([1.0, -1.0, 1.0], np.float32)   # mixed round-0 labels
    expected0 = float((Y[0] == -1).sum())
    lcfg = _kernel_cfg()
    pcfg = ProtocolConfig(kind="none")

    res_eng = engine.run(lcfg, pcfg, X, Y)
    res_loop = simulation.run_kernel_simulation(lcfg, pcfg, X, Y)
    res_async = run_async_simulation(
        lcfg, AsyncProtocolConfig(kind="dynamic", delta=1e9), X, Y,
        sys_cfg=SystemConfig(), record_divergence=False)
    assert res_eng.cumulative_errors[0] == expected0
    assert res_loop.cumulative_errors[0] == expected0
    assert res_async.cumulative_errors[0] == expected0


def test_engine_divergence_recording_is_optional():
    X, Y = susy_stream(T=30, m=M, d=D, seed=7)
    res = engine.run(_kernel_cfg(), ProtocolConfig(kind="dynamic", delta=2.0),
                     X, Y)
    assert len(res.divergences) == 0
    assert len(res.cumulative_loss) == 30


# ---------------------------------------------------------------------------
# engine.sweep
# ---------------------------------------------------------------------------


def test_sweep_matches_solo_runs_mixed_kinds():
    X, Y = susy_stream(T=50, m=M, d=D, seed=1)
    lcfg = _kernel_cfg()
    grid = [
        ProtocolConfig(kind="dynamic", delta=0.5),
        ProtocolConfig(kind="dynamic", delta=2.0, mini_batch=5),
        ProtocolConfig(kind="periodic", period=7),
        ProtocolConfig(kind="continuous"),
    ]
    sw = engine.sweep(lcfg, grid, X, Y, record_divergence=True)
    assert len(sw) == len(grid)
    for i, p in enumerate(grid):
        solo = engine.run(lcfg, p, X, Y, record_divergence=True)
        got = sw[i]
        np.testing.assert_array_equal(solo.cumulative_bytes,
                                      got.cumulative_bytes)
        np.testing.assert_array_equal(solo.sync_rounds, got.sync_rounds)
        np.testing.assert_allclose(solo.cumulative_loss, got.cumulative_loss,
                                   rtol=1e-5, atol=1e-4)
        np.testing.assert_allclose(solo.divergences, got.divergences,
                                   rtol=1e-4, atol=1e-5)


def test_sweep_per_config_data_streams():
    lcfg = LearnerConfig(algo="linear_sgd", loss="hinge", eta=0.1, lam=0.001,
                         dim=D)
    grid = [ProtocolConfig(kind="dynamic", delta=0.1) for _ in range(3)]
    Xs, Ys = zip(*(separable_stream(T=40, m=M, d=D, seed=s) for s in range(3)))
    sw = engine.sweep(lcfg, grid, np.stack(Xs), np.stack(Ys))
    for i in range(3):
        solo = engine.run(lcfg, grid[i], Xs[i], Ys[i])
        np.testing.assert_array_equal(solo.cumulative_bytes,
                                      sw[i].cumulative_bytes)
        np.testing.assert_allclose(solo.cumulative_loss,
                                   sw[i].cumulative_loss,
                                   rtol=1e-5, atol=1e-4)
    # seeds differ, so the runs must actually differ
    assert not np.array_equal(sw[0].cumulative_loss, sw[1].cumulative_loss)


def test_sweep_validates_inputs():
    lcfg = _kernel_cfg()
    with pytest.raises(ValueError):
        engine.sweep(lcfg, [], *susy_stream(T=10, m=M, d=D, seed=0))
    X, Y = susy_stream(T=10, m=M, d=D, seed=0)
    with pytest.raises(ValueError):
        engine.sweep(lcfg, [ProtocolConfig(kind="dynamic")],
                     np.stack([X, X]), np.stack([Y, Y]))
