"""MoE block tests: routed vs dense parity, capacity dropping, aux loss."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models import moe as moe_mod

CFG = ModelConfig(arch_type="moe", d_model=32, n_experts=4, top_k=2,
                  expert_ff=16, capacity_factor=8.0, vocab=64,
                  n_layers=2, dtype="float32")


def _setup(seed=0):
    p = moe_mod.moe_init(jax.random.PRNGKey(seed), CFG, jnp.float32)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, 6, 32)), jnp.float32)
    return p, x


def test_routed_equals_dense_with_ample_capacity():
    p, x = _setup()
    y_routed, _ = moe_mod.moe_forward(CFG, p, x)
    y_dense, _ = moe_mod.moe_forward_dense(CFG, p, x)
    np.testing.assert_allclose(np.asarray(y_routed), np.asarray(y_dense),
                               rtol=1e-4, atol=1e-5)


def test_capacity_dropping_reduces_output_norm():
    p, x = _setup(1)
    tight = CFG.with_(capacity_factor=0.25)
    y_tight, _ = moe_mod.moe_forward(tight, p, x)
    y_full, _ = moe_mod.moe_forward(CFG, p, x)
    # dropped tokens produce zero expert output -> norms differ
    assert float(jnp.linalg.norm(y_tight)) < float(jnp.linalg.norm(y_full))


def test_aux_loss_positive_and_finite():
    p, x = _setup(2)
    _, aux = moe_mod.moe_forward(CFG, p, x)
    assert np.isfinite(float(aux))
    assert float(aux) >= 0.0


def test_balanced_router_minimizes_lb_loss():
    """With perfectly uniform routing probs, lb_loss ~= 1 * coef — the
    theoretical minimum of E * sum f_e P_e under sum P = 1."""
    p, x = _setup(3)
    # zero router weights -> uniform probabilities
    p = dict(p)
    p["router"] = {"w": jnp.zeros_like(p["router"]["w"])}
    _, aux = moe_mod.moe_forward(CFG, p, x)
    # lb part = E * sum_e f_e * (1/E) = 1; z-loss small
    assert float(aux) <= CFG.router_aux_coef * 1.6


def test_gradients_flow_to_all_parts():
    p, x = _setup(4)

    def loss(p):
        y, aux = moe_mod.moe_forward(CFG, p, x)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(p)
    for name in ("router", "wi", "wg", "wo"):
        leaf = g[name]["w"] if isinstance(g[name], dict) else g[name]
        assert float(jnp.sum(jnp.abs(leaf))) > 0.0, name


def test_scatter_dispatch_equals_einsum_reference():
    """The §Perf scatter-based dispatch must reproduce the Mesh-TF
    einsum reference exactly (same routing, capacity and gates), at
    both generous and tight capacity."""
    for cap in (8.0, 0.5):
        cfg = CFG.with_(capacity_factor=cap)
        p, x = _setup(5)
        y_ein, aux_ein = moe_mod.moe_forward_einsum(cfg, p, x)
        y_sc, aux_sc = moe_mod.moe_forward(cfg, p, x)
        np.testing.assert_allclose(np.asarray(y_sc), np.asarray(y_ein),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(float(aux_sc), float(aux_ein), rtol=1e-5)
