"""Mesh-sharded scan engine vs the single-device engine (DESIGN.md
Sec. 9).

The contract under test: with the learner axis sharded over 8 forced
host devices, ``engine.run(..., mesh=...)`` reproduces the
single-device engine BIT-FOR-BIT on losses / errors / divergences and
integer-exactly on the byte ledger, for {dynamic, periodic} x
{SV, RFF, linear}; ``engine.sweep(..., mesh=...)`` does the same for a
mixed-kind grid; and ``topology="allreduce"`` prices every sync at the
fixed ring total of ``Substrate.allreduce_sync_bytes`` without
changing a single decision.

jax locks the device count at first init, so the multi-device half
runs out-of-process (the established pattern of
tests/test_distributed.py); mesh/topology *validation* runs
in-process.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import numpy as np

    from repro.core import accounting, engine
    from repro.core.learners import LearnerConfig
    from repro.core.protocol import ProtocolConfig
    from repro.core.rff import RFFSpec
    from repro.core.rkhs import KernelSpec
    from repro.core.substrate import substrate_of
    from repro.data import susy_stream
    from repro.launch.mesh import make_learner_mesh

    assert len(jax.devices()) == 8
    mesh = make_learner_mesh()
    T, M, D = 40, 8, 6
    X, Y = susy_stream(T=T, m=M, d=D, seed=3)

    kcfg = LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.5,
                         lam=0.01, budget=12,
                         kernel=KernelSpec("gaussian", gamma=0.3), dim=D)
    lcfg = LearnerConfig(algo="linear_sgd", loss="hinge", eta=0.1,
                         lam=0.001, dim=D)
    rspec = RFFSpec(dim=D, num_features=32, gamma=0.3, seed=0)

    def assert_bit_identical(r1, r8, tag):
        for field in ("cumulative_loss", "cumulative_errors",
                      "cumulative_bytes", "sync_rounds", "divergences",
                      "eps_history"):
            a, b = getattr(r1, field), getattr(r8, field)
            assert np.array_equal(a, b), (tag, field, a, b)
        assert r1.num_syncs == r8.num_syncs, tag
        assert r1.total_bytes == r8.total_bytes, tag

    protos = [ProtocolConfig(kind="dynamic", delta=1.0),
              ProtocolConfig(kind="periodic", period=7)]
    for name, learner in [("sv", kcfg), ("rff", rspec), ("linear", lcfg)]:
        for pcfg in protos:
            r1 = engine.run(learner, pcfg, X, Y, record_divergence=True)
            r8 = engine.run(learner, pcfg, X, Y, record_divergence=True,
                            mesh=mesh)
            assert r1.num_syncs > 0, (name, pcfg.kind)
            assert_bit_identical(r1, r8, f"{name}/{pcfg.kind}")

    # sweep: config axis vmapped x learner axis sharded, mixed kinds
    grid = [ProtocolConfig(kind="dynamic", delta=d) for d in (0.5, 1.0, 2.0)]
    grid.append(ProtocolConfig(kind="periodic", period=5))
    sw1 = engine.sweep(kcfg, grid, X, Y)
    sw8 = engine.sweep(kcfg, grid, X, Y, mesh=mesh)
    for i in range(len(grid)):
        assert np.array_equal(sw1[i].cumulative_loss, sw8[i].cumulative_loss)
        assert np.array_equal(sw1[i].cumulative_bytes, sw8[i].cumulative_bytes)
        assert np.array_equal(sw1[i].sync_rounds, sw8[i].sync_rounds)

    # topology="allreduce": identical decisions, ring-total pricing
    for name, learner in [("sv", kcfg), ("rff", rspec), ("linear", lcfg)]:
        sub = substrate_of(learner)
        pcfg = ProtocolConfig(kind="dynamic", delta=1.0)
        rc = engine.run(learner, pcfg, X, Y, mesh=mesh)
        ra = engine.run(learner, pcfg, X, Y, mesh=mesh,
                        topology="allreduce")
        assert np.array_equal(rc.sync_rounds, ra.sync_rounds), name
        assert np.array_equal(rc.cumulative_loss, ra.cumulative_loss), name
        per_sync = sub.allreduce_sync_bytes(M)
        assert ra.total_bytes == ra.num_syncs * per_sync, name
    # the linear/RFF ring totals are the fixed accounting.allreduce_bytes
    assert substrate_of(lcfg).allreduce_sync_bytes(M) == \\
        accounting.allreduce_bytes(D + 1, M)
    assert substrate_of(rspec).allreduce_sync_bytes(M) == \\
        accounting.allreduce_bytes(32 + 1, M)

    print("OK mesh parity")
""")


@pytest.mark.slow
def test_sharded_engine_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK mesh parity" in r.stdout


# ---------------------------------------------------------------------------
# in-process validation (single default device is fine)
# ---------------------------------------------------------------------------


def test_learner_axes_resolution():
    import jax

    from repro.core.engine import learner_axes_of

    mesh = jax.make_mesh((1,), ("learners",))
    assert learner_axes_of(mesh) == ("learners",)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert learner_axes_of(mesh) == ("data",)
    mesh = jax.make_mesh((1,), ("model",))
    with pytest.raises(ValueError, match="learner axis"):
        learner_axes_of(mesh)


def test_run_validates_topology_and_single_shard_mesh():
    from repro.core import engine
    from repro.core.learners import LearnerConfig
    from repro.core.protocol import ProtocolConfig
    from repro.data import separable_stream
    from repro.launch.mesh import make_learner_mesh

    lcfg = LearnerConfig(algo="linear_sgd", loss="hinge", dim=6)
    X, Y = separable_stream(T=5, m=3, d=6, seed=0)
    with pytest.raises(ValueError, match="topology"):
        engine.run(lcfg, ProtocolConfig(kind="dynamic"), X, Y,
                   topology="ring")
    mesh = make_learner_mesh(1)
    # m divides over 1 device: must run (and agree with the meshless run)
    r = engine.run(lcfg, ProtocolConfig(kind="periodic", period=2), X, Y,
                   mesh=mesh)
    r0 = engine.run(lcfg, ProtocolConfig(kind="periodic", period=2), X, Y)
    np.testing.assert_array_equal(r.cumulative_loss, r0.cumulative_loss)
    np.testing.assert_array_equal(r.cumulative_bytes, r0.cumulative_bytes)
