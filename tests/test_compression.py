"""Compression tests: exact epsilon, projection <= truncation error,
and the Kivinen et al. truncation bound shape (Sec. 3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression, rkhs
from repro.core.rkhs import KernelSpec, SVModel


def _model(budget, d, n_active, seed):
    rng = np.random.default_rng(seed)
    sv = np.zeros((budget, d), np.float32)
    alpha = np.zeros((budget,), np.float32)
    ids = -np.ones((budget,), np.int32)
    sv[:n_active] = rng.normal(size=(n_active, d))
    alpha[:n_active] = rng.normal(size=(n_active,)) * 0.5
    ids[:n_active] = np.arange(n_active)
    return SVModel(sv=jnp.asarray(sv), alpha=jnp.asarray(alpha),
                   sv_id=jnp.asarray(ids))


@pytest.mark.parametrize("method", ["truncate", "project"])
def test_epsilon_is_exact_rkhs_distance(method):
    """epsilon returned by compress equals ||f - f~||_H computed
    independently (compressed model compared against the original)."""
    spec = KernelSpec(kind="gaussian", gamma=0.5)
    f = _model(10, 3, 10, seed=0)
    fc, eps = compression.compress(spec, f, tau=6, method=method)
    assert fc.budget == 6
    d2 = float(rkhs.dist_sq(spec, f, fc))
    np.testing.assert_allclose(float(eps) ** 2, max(d2, 0.0), rtol=1e-3,
                               atol=1e-4)


def test_projection_never_worse_than_truncation():
    spec = KernelSpec(kind="gaussian", gamma=0.5)
    for seed in range(5):
        f = _model(12, 4, 12, seed=seed)
        _, e_t = compression.truncate(spec, f, tau=7)
        _, e_p = compression.project(spec, f, tau=7)
        assert float(e_p) <= float(e_t) + 1e-4


def test_truncation_keeps_largest_coefficients():
    spec = KernelSpec(kind="linear")
    f = _model(8, 3, 8, seed=1)
    fc, _ = compression.truncate(spec, f, tau=4)
    kept = set(np.asarray(fc.sv_id)[np.asarray(fc.sv_id) >= 0].tolist())
    order = np.argsort(-np.abs(np.asarray(f.alpha)))[:4]
    want = set(np.asarray(f.sv_id)[order].tolist())
    assert kept == want


def test_compress_noop_when_under_budget():
    spec = KernelSpec(kind="gaussian")
    f = _model(8, 3, 4, seed=2)
    fc, eps = compression.truncate(spec, f, tau=6)
    assert float(eps) < 1e-6
    assert int(rkhs.num_active(fc)) == 4


def test_truncation_error_bound_decreases_in_tau():
    b = [compression.truncation_error_bound(0.1, t) for t in (5, 10, 20, 40)]
    assert all(x > y for x, y in zip(b, b[1:]))


def test_compressed_update_is_approximately_loss_proportional():
    """Lemma 3 precondition: ||phi~(f) - phi(f)|| <= eps, where phi~ is
    the update followed by compression.  We verify the measured eps of
    the compression step bounds the function-space deviation."""
    spec = KernelSpec(kind="gaussian", gamma=0.5)
    f = _model(12, 3, 12, seed=3)
    fc, eps = compression.truncate(spec, f, tau=8)
    # deviation in prediction at arbitrary points is bounded by
    # |f(x) - fc(x)| <= ||f - fc|| * sqrt(k(x,x)) = eps * 1 (gaussian)
    X = np.random.default_rng(4).normal(size=(50, 3)).astype(np.float32)
    gap = np.abs(np.asarray(rkhs.predict(spec, f, jnp.asarray(X)))
                 - np.asarray(rkhs.predict(spec, fc, jnp.asarray(X))))
    assert float(gap.max()) <= float(eps) + 1e-4
