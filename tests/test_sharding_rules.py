"""Sharding-rule unit tests (no multi-device mesh needed: specs only)."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get
from repro.launch import sharding as shd
from repro.launch.specs import param_specs


def _find(pspecs, *path):
    node = pspecs
    for k in path:
        node = node[k]
    return node


def test_dense_param_rules():
    cfg = get("granite_8b")
    specs = param_specs(cfg)
    ps = shd.param_pspec(specs, model_size=16)
    st = ps["stages"][0]
    assert _find(st, "b0", "attn", "wq", "w") == P(None, None, "model")
    assert _find(st, "b0", "attn", "wo", "w") == P(None, "model", None)
    assert _find(st, "b0", "mlp", "wi", "w") == P(None, None, "model")
    assert _find(st, "b0", "mlp", "wo", "w") == P(None, "model", None)
    assert ps["embed"]["table"] == P("model", None)
    assert ps["lm_head"]["w"] == P(None, "model")
    # norm scales replicated
    assert _find(st, "b0", "norm1", "scale") == P(None, None)


def test_moe_expert_parallel_rule():
    cfg = get("olmoe_1b_7b")
    ps = shd.param_pspec(param_specs(cfg), model_size=16)
    st = ps["stages"][0]
    assert _find(st, "b0", "moe", "wi") == P(None, "model", None, None)
    assert _find(st, "b0", "moe", "wo") == P(None, "model", None, None)
    assert _find(st, "b0", "moe", "router", "w") == P(None, None, None)


def test_nondivisible_dims_replicated():
    cfg = get("mamba2_130m")
    ps = shd.param_pspec(param_specs(cfg), model_size=16)
    st = ps["stages"][0]
    # in_proj out-dim (mixed concat 3352) not divisible -> replicated
    assert _find(st, "b0", "ssm", "in_proj", "w") == P(None, None, None)
    # out_proj in-dim 1536 divisible -> sharded
    assert _find(st, "b0", "ssm", "out_proj", "w") == P(None, "model", None)


def test_learner_axis_prepended():
    cfg = get("qwen2_5_3b")
    specs = param_specs(cfg)
    stacked = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((16,) + tuple(l.shape), l.dtype), specs)
    ps = shd.param_pspec(stacked, model_size=16, learner_axes=("data",))
    st = ps["stages"][0]
    assert st["b0"]["attn"]["wq"]["w"] == P("data", None, None, "model")
    assert ps["embed"]["table"] == P("data", "model", None)


def test_multipod_learner_axes():
    cfg = get("qwen2_5_3b")
    specs = param_specs(cfg)
    stacked = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((32,) + tuple(l.shape), l.dtype), specs)
    ps = shd.param_pspec(stacked, model_size=16,
                         learner_axes=("pod", "data"))
    assert ps["embed"]["table"] == P(("pod", "data"), "model", None)


def test_cache_pspec_shards_batch_and_length():
    cfg = get("granite_8b")
    from repro.launch.specs import cache_specs
    cs = cache_specs(cfg, B=128, length=32768)
    ps = shd.cache_pspec(cs, ("data",), batch=128, n_batch_axes_size=16,
                         model_size=16)
    k_spec = ps[0]["b0"].k
    assert k_spec == P(None, "data", "model", None, None)


def test_cache_pspec_small_batch_replicated():
    cfg = get("granite_8b")
    from repro.launch.specs import cache_specs
    cs = cache_specs(cfg.with_(window=4096), B=1, length=4096)
    ps = shd.cache_pspec(cs, ("data",), batch=1, n_batch_axes_size=16,
                         model_size=16)
    k_spec = ps[0]["b0"].k
    assert k_spec == P(None, None, "model", None, None)


def test_stream_pspec_learner_dim():
    assert shd.stream_pspec(("learners",)) == P(None, "learners")
    assert shd.stream_pspec(("pod", "data")) == P(None, ("pod", "data"))
