"""Online learner tests: loss decrease, drift bound (Prop. 6
precondition), PA aggressiveness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import learners, rkhs
from repro.core.learners import LearnerConfig
from repro.core.rkhs import KernelSpec
from repro.data import separable_stream, susy_stream


def _run_learner(cfg, X, Y):
    st = learners.init_state(cfg, 0)
    upd = jax.jit(lambda s, ex: learners.update(cfg, s, ex))
    losses = []
    for t in range(X.shape[0]):
        st, ell = upd(st, (jnp.asarray(X[t]), jnp.asarray(Y[t])))
        losses.append(float(ell))
    return st, np.asarray(losses)


@pytest.mark.parametrize("algo", ["kernel_sgd", "kernel_pa"])
def test_kernel_learner_learns_nonlinear(algo):
    X, Y = susy_stream(T=400, m=1, d=8, seed=0, noise=0.0)
    # PA is maximally aggressive, so it needs a larger budget before the
    # inline truncation stops thrashing its support set.
    budget = 256 if algo == "kernel_pa" else 128
    cfg = LearnerConfig(algo=algo, loss="hinge", eta=0.5, lam=0.01, C=1.0,
                        budget=budget,
                        kernel=KernelSpec("gaussian", gamma=0.3), dim=8)
    st, losses = _run_learner(cfg, X[:, 0], Y[:, 0])
    assert losses[-100:].mean() < losses[:100].mean() * 0.85


@pytest.mark.parametrize("algo", ["linear_sgd", "linear_pa"])
def test_linear_learner_learns_separable(algo):
    X, Y = separable_stream(T=400, m=1, d=8, seed=0)
    cfg = LearnerConfig(algo=algo, loss="hinge", eta=0.2, lam=0.0, C=1.0,
                        dim=8)
    st, losses = _run_learner(cfg, X[:, 0], Y[:, 0])
    assert losses[-100:].mean() < 0.2


def test_drift_bound_kernel_sgd():
    """Prop. 6 precondition: ||f - phi~(f)|| <= eta * ell(f).  For
    NORMA with lam=0 the drift is exactly eta*|g|*sqrt(k(x,x)) <=
    eta*ell for hinge (|g| <= 1, ell >= margin deficit... we check the
    measured drift against eta*ell + eps directly)."""
    spec = KernelSpec("gaussian", gamma=0.5)
    cfg = LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.5, lam=0.0,
                        budget=64, kernel=spec, dim=4)
    st = learners.init_state(cfg, 0)
    rng = np.random.default_rng(0)
    for t in range(60):
        x = jnp.asarray(rng.normal(size=(4,)), jnp.float32)
        y = jnp.asarray(float(rng.choice([-1.0, 1.0])))
        f_before = st.model
        yhat = float(rkhs.predict(spec, f_before, x[None])[0])
        ell = max(0.0, 1.0 - float(y) * yhat)
        st, ell_ret = learners.update(cfg, st, (x, y))
        drift = float(np.sqrt(max(rkhs.dist_sq(spec, st.model, f_before), 0)))
        # with a free budget slot the update is exact:
        # drift = eta*|g|*sqrt(k(x,x)) = eta when ell>0 (hinge, |g|=1)
        if ell > 0 and t < 64:
            assert drift <= cfg.eta * max(ell, 1.0) + 1e-4
        else:
            assert drift <= cfg.eta * max(ell, 1.0) + 1e-4


def test_pa_update_zeroes_loss_on_repeat():
    """PA is maximally aggressive: after updating on (x, y) the new
    model classifies x with margin >= 1 (when tau_pa not capped)."""
    spec = KernelSpec("gaussian", gamma=1.0)
    cfg = LearnerConfig(algo="kernel_pa", loss="hinge", C=100.0, budget=16,
                        kernel=spec, dim=3)
    st = learners.init_state(cfg, 0)
    x = jnp.asarray([1.0, -0.5, 0.2], jnp.float32)
    st, ell0 = learners.update(cfg, st, (x, jnp.asarray(1.0)))
    yhat = float(rkhs.predict(spec, st.model, x[None])[0])
    assert yhat >= 1.0 - 1e-4


def test_unique_ids_monotone():
    cfg = LearnerConfig(algo="kernel_sgd", budget=8, dim=3,
                        kernel=KernelSpec("gaussian"))
    st = learners.init_state(cfg, learner_id=2)
    rng = np.random.default_rng(0)
    seen = set()
    for t in range(12):
        x = jnp.asarray(rng.normal(size=(3,)), jnp.float32)
        st, _ = learners.update(cfg, st, (x, jnp.asarray(1.0)))
    ids = np.asarray(st.model.sv_id)
    ids = ids[ids >= 0]
    assert len(set(ids.tolist())) == len(ids)
    assert all(i % learners.MAX_LEARNERS == 2 for i in ids)
