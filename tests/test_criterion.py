"""Efficiency-criterion (Def. 1) audit tests: quiescence, consistency
trend, adaptivity signature."""
import numpy as np
import pytest

from repro.core import criterion, simulation
from repro.core.accounting import ByteModel
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.core.rkhs import KernelSpec
from repro.data import separable_stream, susy_stream


def test_quiescence_on_separable_data():
    """The paper's headline property: when the loss reaches zero, the
    dynamic protocol stops communicating (communication vanishes)."""
    T, m, d = 400, 4, 8
    X, Y = separable_stream(T, m, d=d, seed=0, margin=1.0)
    lcfg = LearnerConfig(algo="linear_pa", loss="hinge", C=1.0, dim=d)
    res = simulation.run_linear_simulation(
        lcfg, ProtocolConfig(kind="dynamic", delta=1.0), X, Y)
    assert criterion.quiescent(res, window_frac=0.25)
    # and the last-quarter byte increments are all zero
    q = res.cumulative_bytes
    assert q[-1] == q[3 * T // 4]


def test_periodic_never_quiescent():
    T, m, d = 400, 4, 8
    X, Y = separable_stream(T, m, d=d, seed=0, margin=1.0)
    lcfg = LearnerConfig(algo="linear_pa", loss="hinge", C=1.0, dim=d)
    res = simulation.run_linear_simulation(
        lcfg, ProtocolConfig(kind="periodic", period=10), X, Y)
    assert not criterion.quiescent(res, window_frac=0.25)


def _result_with_syncs(T, sync_rounds):
    """SimResult with syncs at exactly the given rounds."""
    flags = np.zeros(T, bool)
    flags[list(sync_rounds)] = True
    nbytes = np.where(flags, 100, 0)
    return simulation.SimResult.from_round_series(
        np.zeros(T), np.zeros(T), nbytes, np.zeros(T), flags, np.zeros(0))


def test_quiescence_round_boundary_convention():
    """One convention, both definitions (ISSUE 4 satellite): q is the
    first round from which the run is sync-free; 0 with no syncs; None
    when the final round syncs (never observed quiescent)."""
    T = 10
    assert _result_with_syncs(T, []).quiescence_round == 0
    assert _result_with_syncs(T, [3]).quiescence_round == 4
    assert _result_with_syncs(T, [0, 8]).quiescence_round == 9
    assert _result_with_syncs(T, [T - 1]).quiescence_round is None
    # degenerate one-round runs
    assert _result_with_syncs(1, []).quiescence_round == 0
    assert _result_with_syncs(1, [0]).quiescence_round is None


def test_quiescent_honors_quiescence_round_convention():
    """quiescent <=> quiescence was observed (q not None) and arrived
    no later than the trailing-window start w = ceil((1-frac)*T)."""
    T, frac = 10, 0.2       # window = rounds {8, 9}
    assert criterion.quiescent(_result_with_syncs(T, []), frac)
    # sync just OUTSIDE the window (round 7): quiescent, q == w == 8
    res = _result_with_syncs(T, [7])
    assert res.quiescence_round == 8
    assert criterion.quiescent(res, frac)
    # sync just INSIDE the window (round 8): not quiescent
    res = _result_with_syncs(T, [8])
    assert res.quiescence_round == 9
    assert not criterion.quiescent(res, frac)
    # sync on the final round: q is None, never quiescent
    assert not criterion.quiescent(_result_with_syncs(T, [T - 1]), frac)


def test_consistency_trend_bounded():
    """L_dynamic(t) / L_serial(mt) stays bounded (consistency audit)."""
    T, m, d = 250, 4, 8
    X, Y = susy_stream(T, m, d=d, seed=1)
    lcfg = LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.5, lam=0.01,
                         budget=64, kernel=KernelSpec("gaussian", gamma=0.3),
                         dim=d)
    res = simulation.run_kernel_simulation(
        lcfg, ProtocolConfig(kind="dynamic", delta=2.0), X, Y)
    # serial run: one learner on the centralized stream (mT rounds)
    Xs = X.reshape(T * m, 1, d)
    Ys = Y.reshape(T * m, 1)
    serial = simulation.run_kernel_simulation(
        lcfg, ProtocolConfig(kind="none"), Xs, Ys)
    trend = criterion.consistency_trend(res, serial.cumulative_loss)
    assert np.isfinite(trend).all()
    assert trend[-1] < 3.0     # no blow-up vs serial
    # the trend must not be increasing without bound
    assert trend[-1] <= trend[0] * 2.0 + 1.0


def test_full_audit_report():
    T, m, d = 200, 4, 8
    X, Y = susy_stream(T, m, d=d, seed=2)
    lcfg = LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.5, lam=0.01,
                         budget=64, kernel=KernelSpec("gaussian", gamma=0.3),
                         dim=d)
    delta = 2.0
    res = simulation.run_kernel_simulation(
        lcfg, ProtocolConfig(kind="dynamic", delta=delta), X, Y)
    Xs = X.reshape(T * m, 1, d)
    Ys = Y.reshape(T * m, 1)
    serial = simulation.run_kernel_simulation(
        lcfg, ProtocolConfig(kind="none"), Xs, Ys)
    rep = criterion.audit(res, serial.cumulative_loss, ByteModel(dim=d),
                          m, union_size=T * m, eta=lcfg.eta, delta=delta)
    assert rep.sync_bound_ok
    assert rep.comm_bound_ok
    assert np.isfinite(rep.consistent_ratio)
