"""Efficiency-criterion (Def. 1) audit tests: quiescence, consistency
trend, adaptivity signature."""
import numpy as np
import pytest

from repro.core import criterion, simulation
from repro.core.accounting import ByteModel
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.core.rkhs import KernelSpec
from repro.data import separable_stream, susy_stream


def test_quiescence_on_separable_data():
    """The paper's headline property: when the loss reaches zero, the
    dynamic protocol stops communicating (communication vanishes)."""
    T, m, d = 400, 4, 8
    X, Y = separable_stream(T, m, d=d, seed=0, margin=1.0)
    lcfg = LearnerConfig(algo="linear_pa", loss="hinge", C=1.0, dim=d)
    res = simulation.run_linear_simulation(
        lcfg, ProtocolConfig(kind="dynamic", delta=1.0), X, Y)
    assert criterion.quiescent(res, window_frac=0.25)
    # and the last-quarter byte increments are all zero
    q = res.cumulative_bytes
    assert q[-1] == q[3 * T // 4]


def test_periodic_never_quiescent():
    T, m, d = 400, 4, 8
    X, Y = separable_stream(T, m, d=d, seed=0, margin=1.0)
    lcfg = LearnerConfig(algo="linear_pa", loss="hinge", C=1.0, dim=d)
    res = simulation.run_linear_simulation(
        lcfg, ProtocolConfig(kind="periodic", period=10), X, Y)
    assert not criterion.quiescent(res, window_frac=0.25)


def test_consistency_trend_bounded():
    """L_dynamic(t) / L_serial(mt) stays bounded (consistency audit)."""
    T, m, d = 250, 4, 8
    X, Y = susy_stream(T, m, d=d, seed=1)
    lcfg = LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.5, lam=0.01,
                         budget=64, kernel=KernelSpec("gaussian", gamma=0.3),
                         dim=d)
    res = simulation.run_kernel_simulation(
        lcfg, ProtocolConfig(kind="dynamic", delta=2.0), X, Y)
    # serial run: one learner on the centralized stream (mT rounds)
    Xs = X.reshape(T * m, 1, d)
    Ys = Y.reshape(T * m, 1)
    serial = simulation.run_kernel_simulation(
        lcfg, ProtocolConfig(kind="none"), Xs, Ys)
    trend = criterion.consistency_trend(res, serial.cumulative_loss)
    assert np.isfinite(trend).all()
    assert trend[-1] < 3.0     # no blow-up vs serial
    # the trend must not be increasing without bound
    assert trend[-1] <= trend[0] * 2.0 + 1.0


def test_full_audit_report():
    T, m, d = 200, 4, 8
    X, Y = susy_stream(T, m, d=d, seed=2)
    lcfg = LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.5, lam=0.01,
                         budget=64, kernel=KernelSpec("gaussian", gamma=0.3),
                         dim=d)
    delta = 2.0
    res = simulation.run_kernel_simulation(
        lcfg, ProtocolConfig(kind="dynamic", delta=delta), X, Y)
    Xs = X.reshape(T * m, 1, d)
    Ys = Y.reshape(T * m, 1)
    serial = simulation.run_kernel_simulation(
        lcfg, ProtocolConfig(kind="none"), Xs, Ys)
    rep = criterion.audit(res, serial.cumulative_loss, ByteModel(dim=d),
                          m, union_size=T * m, eta=lcfg.eta, delta=delta)
    assert rep.sync_bound_ok
    assert rep.comm_bound_ok
    assert np.isfinite(rep.consistent_ratio)
