"""The learner-substrate layer (core/substrate.py, DESIGN.md Sec. 8).

SV / linear parity with the legacy drivers is covered by
tests/test_engine.py (which runs unmodified through the generic scan
core).  This file tests what is NEW with the substrate layer: the RFF
substrate through engine.run / engine.sweep / the async runtime with
its Cor. 8 byte guarantee, mixed-substrate sweeps, the Pallas backend
dispatch, and the sv_id capacity guard.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, learners, rff, substrate
from repro.core.accounting import sync_bytes_linear
from repro.core.learners import LearnerConfig
from repro.core.protocol import ProtocolConfig
from repro.core.rff import RFFSpec
from repro.core.rkhs import KernelSpec, SVModel
from repro.core.substrate import (LinearSubstrate, RFFSubstrate, SVSubstrate,
                                  substrate_of)
from repro.data import susy_stream
from repro.runtime import AsyncProtocolConfig, SystemConfig, run_async_simulation

T, M, D_IN = 90, 3, 8
NUM_FEATURES = 64
RSPEC = RFFSpec(dim=D_IN, num_features=NUM_FEATURES, gamma=0.3, seed=0)


def _kernel_cfg(budget=16):
    return LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.5, lam=0.01,
                         budget=budget,
                         kernel=KernelSpec("gaussian", gamma=0.3), dim=D_IN)


# ---------------------------------------------------------------------------
# substrate_of dispatch
# ---------------------------------------------------------------------------


def test_substrate_of_dispatch():
    assert isinstance(substrate_of(_kernel_cfg()), SVSubstrate)
    assert isinstance(
        substrate_of(LearnerConfig(algo="linear_pa", dim=D_IN)),
        LinearSubstrate)
    assert isinstance(substrate_of(RSPEC), RFFSubstrate)
    sub = RFFSubstrate(spec=RSPEC)
    assert substrate_of(sub) is sub
    with pytest.raises(TypeError):
        substrate_of("nope")
    # explicit overrides are applied to an existing substrate, not
    # silently dropped; impossible overrides raise
    assert substrate_of(sub, backend="pallas").backend == "pallas"
    assert substrate_of(sub).backend == "reference"   # default = no-op
    sv = substrate_of(_kernel_cfg(), compress_method="project")
    assert substrate_of(sv, sync_budget=7).sync_budget == 7
    assert substrate_of(sv, sync_budget=7).compress_method == "project"
    with pytest.raises(ValueError, match="sync_budget"):
        substrate_of(sub, sync_budget=7)
    # substrates are hashable (they key the engine's compile cache)
    assert hash(substrate_of(_kernel_cfg())) == hash(substrate_of(_kernel_cfg()))


def test_substrate_config_validation():
    with pytest.raises(ValueError):
        SVSubstrate(lcfg=LearnerConfig(algo="linear_sgd", dim=D_IN))
    with pytest.raises(ValueError):
        LinearSubstrate(lcfg=_kernel_cfg())
    with pytest.raises(ValueError):
        RFFSubstrate(spec=RSPEC, loss="absolute")
    with pytest.raises(ValueError):
        SVSubstrate(lcfg=_kernel_cfg(), backend="cuda")
    # default sync budget resolves to the learner budget
    assert SVSubstrate(lcfg=_kernel_cfg(budget=24)).sync_budget == 24


def test_substrate_rejects_dim_mismatch():
    X, Y = susy_stream(T=10, m=M, d=D_IN + 1, seed=0)
    with pytest.raises(ValueError, match="dim"):
        engine.run(RFFSubstrate(spec=RSPEC),
                   ProtocolConfig(kind="periodic", period=5), X, Y)


# ---------------------------------------------------------------------------
# RFF substrate: engine.run with Cor. 8 byte guarantee
# ---------------------------------------------------------------------------


PER_SYNC = sync_bytes_linear(NUM_FEATURES + 1, M)


@pytest.mark.parametrize("pcfg", [
    ProtocolConfig(kind="dynamic", delta=2.0),
    ProtocolConfig(kind="periodic", period=9),
    ProtocolConfig(kind="continuous"),
], ids=lambda p: p.kind)
def test_rff_engine_bytes_independent_of_rounds(pcfg):
    X, Y = susy_stream(T=T, m=M, d=D_IN, seed=1)
    res = engine.run(RFFSubstrate(spec=RSPEC), pcfg, X, Y)
    assert res.num_syncs > 0
    round_bytes = np.diff(np.concatenate([[0], res.cumulative_bytes]))
    nz = round_bytes[round_bytes > 0]
    # every sync costs exactly 2 m (D+1) B bytes, no matter how late in
    # the stream it happens — the Cor. 8 strict-adaptivity payload
    assert (nz == PER_SYNC).all()
    assert res.total_bytes == res.num_syncs * PER_SYNC
    # an eps-free substrate reports no compression errors, and records
    # its (cheap) divergence series unconditionally like the linear driver
    assert len(res.eps_history) == 0
    assert len(res.divergences) == len(res.cumulative_loss)


def test_rff_per_sync_bytes_same_for_longer_streams():
    """The per-sync payload must not grow with rounds seen (the SV
    union does): run 60 and 180 rounds, compare the nonzero per-round
    byte values."""
    sub = RFFSubstrate(spec=RSPEC)
    pcfg = ProtocolConfig(kind="periodic", period=7)
    payloads = []
    for t in (60, 180):
        X, Y = susy_stream(T=t, m=M, d=D_IN, seed=2)
        res = engine.run(sub, pcfg, X, Y)
        rb = np.diff(np.concatenate([[0], res.cumulative_bytes]))
        payloads.append(set(rb[rb > 0].tolist()))
    assert payloads[0] == payloads[1] == {PER_SYNC}


def test_rff_substrate_update_matches_make_update():
    """The substrate's vectorized update is the rff.make_update
    reference, learner by learner."""
    sub = RFFSubstrate(spec=RSPEC, eta=0.5, lam=0.01, loss="hinge")
    W, b = substrate._rff_consts(RSPEC)
    upd = rff.make_update(RSPEC, jnp.asarray(W), jnp.asarray(b),
                          eta=0.5, lam=0.01, loss="hinge")
    rng = np.random.default_rng(0)
    xs = rng.normal(size=(5, M, D_IN)).astype(np.float32)
    ys = np.sign(rng.normal(size=(5, M))).astype(np.float32)

    state = sub.init(M)
    for x, y in zip(xs, ys):
        state, _ = sub.update(state, (jnp.asarray(x), jnp.asarray(y)))

    for i in range(M):
        ref_state = rff.init_state(RSPEC)
        for x, y in zip(xs, ys):
            ref_state, _ = upd(ref_state,
                               (jnp.asarray(x[i]), jnp.asarray(y[i])))
        np.testing.assert_allclose(np.asarray(state.w[i]),
                                   np.asarray(ref_state.w),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(float(state.b[i]), float(ref_state.b),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# RFF through engine.sweep and mixed-substrate grids
# ---------------------------------------------------------------------------


def test_rff_sweep_matches_solo_runs():
    X, Y = susy_stream(T=60, m=M, d=D_IN, seed=3)
    sub = RFFSubstrate(spec=RSPEC)
    grid = [
        ProtocolConfig(kind="dynamic", delta=0.5),
        ProtocolConfig(kind="dynamic", delta=2.0, mini_batch=4),
        ProtocolConfig(kind="periodic", period=11),
    ]
    sw = engine.sweep(sub, grid, X, Y)
    assert len(sw) == len(grid)
    assert sw.eps is None
    for i, p in enumerate(grid):
        solo = engine.run(sub, p, X, Y)
        np.testing.assert_array_equal(solo.cumulative_bytes,
                                      sw[i].cumulative_bytes)
        np.testing.assert_array_equal(solo.sync_rounds, sw[i].sync_rounds)
        np.testing.assert_allclose(solo.cumulative_loss,
                                   sw[i].cumulative_loss,
                                   rtol=1e-5, atol=1e-4)


def test_mixed_substrate_sweep():
    """One sweep call serving SV, RFF, and linear configs on the same
    stream reproduces each substrate's solo run."""
    X, Y = susy_stream(T=50, m=M, d=D_IN, seed=4)
    subs = [
        substrate_of(_kernel_cfg()),
        RFFSubstrate(spec=RSPEC),
        substrate_of(LearnerConfig(algo="linear_pa", loss="hinge", C=1.0,
                                   dim=D_IN)),
    ]
    grid = [
        ProtocolConfig(kind="dynamic", delta=1.0),
        ProtocolConfig(kind="dynamic", delta=1.0),
        ProtocolConfig(kind="periodic", period=8),
    ]
    sw = engine.sweep(subs, grid, X, Y)
    assert sw.eps is not None          # the SV member has an eps series
    assert sw.divergences is None      # SV divergence is opt-in
    for i in range(len(grid)):
        solo = engine.run(subs[i], grid[i], X, Y)
        np.testing.assert_array_equal(solo.cumulative_bytes,
                                      sw[i].cumulative_bytes)
        np.testing.assert_array_equal(solo.sync_rounds, sw[i].sync_rounds)
        np.testing.assert_allclose(solo.cumulative_loss,
                                   sw[i].cumulative_loss,
                                   rtol=1e-5, atol=1e-4)


def test_mixed_substrate_sweep_validates_length():
    X, Y = susy_stream(T=10, m=M, d=D_IN, seed=0)
    with pytest.raises(ValueError, match="substrates"):
        engine.sweep([RFFSubstrate(spec=RSPEC)],
                     [ProtocolConfig(kind="dynamic")] * 2, X, Y)


# ---------------------------------------------------------------------------
# RFF through the async event-driven runtime
# ---------------------------------------------------------------------------


def test_rff_async_bytes_independent_of_rounds():
    X, Y = susy_stream(T=120, m=M, d=D_IN, seed=5)
    sub = RFFSubstrate(spec=RSPEC)
    res = run_async_simulation(
        sub, AsyncProtocolConfig(kind="dynamic", delta=2.0), X, Y,
        sys_cfg=SystemConfig())
    assert res.num_syncs > 0
    assert res.total_bytes == res.num_syncs * PER_SYNC
    assert len(res.eps_history) == 0
    assert np.isfinite(res.total_loss)
    # divergence series recorded through the substrate snapshot hooks
    assert len(res.divergences) == 120 and np.isfinite(res.divergences).all()


def test_rff_async_matches_engine_at_zero_latency():
    """Ideal network + alpha=1: the async dynamic RFF run collapses to
    the engine's round structure (fixed-size aggregation is exact)."""
    X, Y = susy_stream(T=100, m=M, d=D_IN, seed=6)
    sub = RFFSubstrate(spec=RSPEC)
    res_e = engine.run(sub, ProtocolConfig(kind="dynamic", delta=2.0), X, Y)
    res_a = run_async_simulation(
        sub, AsyncProtocolConfig(kind="dynamic", delta=2.0), X, Y,
        sys_cfg=SystemConfig(), record_divergence=False)
    assert res_e.num_syncs == res_a.num_syncs
    np.testing.assert_array_equal(res_e.sync_rounds, res_a.sync_rounds)
    assert res_e.total_bytes == res_a.total_bytes
    np.testing.assert_allclose(res_e.total_loss, res_a.total_loss, rtol=1e-5)


def test_rff_async_under_stragglers_stays_fixed_payload():
    X, Y = susy_stream(T=80, m=4, d=D_IN, seed=7)
    res = run_async_simulation(
        RFFSubstrate(spec=RSPEC),
        AsyncProtocolConfig(kind="dynamic", delta=1.0, alpha=0.6,
                            staleness="poly", agg_window=0.3),
        X, Y,
        sys_cfg=SystemConfig(seed=1, compute_jitter=0.3, straggler_frac=0.25,
                             base_latency=0.4, latency_jitter=0.5),
        record_divergence=False)
    assert np.isfinite(res.total_loss)
    assert res.num_syncs > 0
    # windows may fragment (fewer than m uploads per aggregation), but
    # every shipped model — upload or download — is the same fixed-size
    # payload, so the total is an exact multiple of it
    per_message = (NUM_FEATURES + 1) * 4
    assert res.total_bytes % per_message == 0


# ---------------------------------------------------------------------------
# sv_id capacity guard (int32 minting bound)
# ---------------------------------------------------------------------------


def test_check_id_capacity():
    learners.check_id_capacity(learners.MAX_INSERTIONS_PER_LEARNER)
    with pytest.raises(ValueError, match="int32"):
        learners.check_id_capacity(learners.MAX_INSERTIONS_PER_LEARNER + 1)
    # the bound is what the minting scheme can actually represent
    top_id = (learners.MAX_INSERTIONS_PER_LEARNER * learners.MAX_LEARNERS
              + learners.MAX_LEARNERS - 1)
    assert top_id <= np.iinfo(np.int32).max
    assert np.int32(top_id) == top_id    # no wrap at the documented bound


def test_engine_refuses_id_wrapping_runs():
    sub = substrate_of(_kernel_cfg())
    with pytest.raises(ValueError, match="int32"):
        sub.validate(learners.MAX_INSERTIONS_PER_LEARNER + 1, M, D_IN)
    # primal substrates mint no ids: no bound applies
    RFFSubstrate(spec=RSPEC).validate(10**9, M, D_IN)


def test_sv_ids_stay_int32_through_update():
    lcfg = _kernel_cfg(budget=4)
    state = learners.init_state(lcfg, 2)
    x = jnp.ones((D_IN,), jnp.float32)
    state, _ = learners.kernel_update(lcfg, state, (x, jnp.asarray(-1.0)))
    assert state.model.sv_id.dtype == jnp.int32
    assert state.counter.dtype == jnp.int32


# ---------------------------------------------------------------------------
# Pallas backend through the substrate (end-to-end)
# ---------------------------------------------------------------------------


def test_engine_backend_pallas_matches_reference_sv():
    X, Y = susy_stream(T=40, m=M, d=D_IN, seed=8)
    lcfg = _kernel_cfg()
    pcfg = ProtocolConfig(kind="dynamic", delta=1.0)
    r_ref = engine.run(lcfg, pcfg, X, Y, record_divergence=True)
    r_pal = engine.run(lcfg, pcfg, X, Y, record_divergence=True,
                       backend="pallas")
    np.testing.assert_array_equal(r_ref.cumulative_bytes,
                                  r_pal.cumulative_bytes)
    np.testing.assert_array_equal(r_ref.sync_rounds, r_pal.sync_rounds)
    np.testing.assert_allclose(r_ref.cumulative_loss, r_pal.cumulative_loss,
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(r_ref.divergences, r_pal.divergences,
                               rtol=1e-4, atol=1e-4)


def test_engine_backend_pallas_matches_reference_rff():
    X, Y = susy_stream(T=40, m=M, d=D_IN, seed=9)
    pcfg = ProtocolConfig(kind="dynamic", delta=2.0)
    r_ref = engine.run(RFFSubstrate(spec=RSPEC), pcfg, X, Y)
    r_pal = engine.run(RFFSubstrate(spec=RSPEC, backend="pallas"), pcfg, X, Y)
    np.testing.assert_array_equal(r_ref.cumulative_bytes,
                                  r_pal.cumulative_bytes)
    np.testing.assert_allclose(r_ref.cumulative_loss, r_pal.cumulative_loss,
                               rtol=1e-5, atol=1e-4)
