"""reprolint — this repo's own static-analysis suite (DESIGN.md Sec. 14).

Usage::

    python -m tools.reprolint src tests benchmarks tools

Public API: :func:`tools.reprolint.engine.scan_source`,
:func:`tools.reprolint.engine.scan_paths`,
:data:`tools.reprolint.rules.ALL_RULES`.
"""
from .engine import (Finding, load_baseline, main, scan_paths,  # noqa: F401
                     scan_source)
from .rules import ALL_RULES, RULE_IDS  # noqa: F401
