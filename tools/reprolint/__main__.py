"""``python -m tools.reprolint`` entry point."""
import sys

from .engine import main

sys.exit(main())
