"""The reprolint engine: scanning, suppression, baseline, reporting.

reprolint is this repo's own static-analysis pass (DESIGN.md Sec. 14):
a handful of AST rules, each born from a bug that actually shipped and
had to be hand-hunted — layout-dependent contractions (PR 4), int32
byte-ledger overflow (PR 4), wall-clock leaking into the simulated
event clock (PR 8), host syncs inside the jitted scan core, recompile
hazards on the jit cache keys.  Generic linters cannot know these
contracts; this engine makes them mechanical.

Design:

* A **rule** (see rules/) is an object with an ``id``, a one-line
  ``title``, and ``check(ctx) -> iterable[Finding]``.  Rules receive a
  parsed :class:`FileContext` — AST plus source lines plus a parent
  map — and never do their own I/O.

* **Suppression** is per-line and must carry a reason::

      eps = beta @ K @ beta  # reprolint: allow[DET01] oracle quadform

  The comment may sit on the finding's line or alone on the line
  above.  An allow comment WITHOUT a reason does not suppress and is
  itself reported (rule id ``SUP00``), so suppressions stay auditable.

* The **baseline** (``tools/reprolint/baseline.json``) grandfathers
  known findings by fingerprint ``(rule, path, context, snippet)`` —
  deliberately not by line number, so unrelated edits don't churn it.
  Every entry carries a ``reason``.  A fresh finding not in the
  baseline fails the run; a baseline entry no longer found is *stale*
  and also fails (run ``--update-baseline`` after removing dead code).

CLI (``python -m tools.reprolint``) exit codes: 0 clean, 1 new or
stale findings, 2 usage error.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import json
import re
import sys
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

REPO_ROOT = Path(__file__).resolve().parent.parent.parent
DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.json"

#: ``# reprolint: allow[DET01] reason`` / ``allow[DET01,CLK01] reason``
_ALLOW_RE = re.compile(
    r"#\s*reprolint:\s*allow\[([A-Z0-9,\s]+)\]\s*(.*)$")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one site."""

    rule: str       # rule id, e.g. "DET01"
    path: str       # repo-relative posix path
    line: int       # 1-based
    col: int        # 0-based
    context: str    # dotted enclosing scope ("<module>" at top level)
    snippet: str    # stripped source of the finding's line
    message: str

    def fingerprint(self) -> Tuple[str, str, str, str]:
        """Baseline identity: stable across pure line moves."""
        return (self.rule, self.path, self.context, self.snippet)

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"[{self.context}] {self.message}")


class FileContext:
    """Everything a rule needs to know about one source file."""

    def __init__(self, path: str, source: str):
        self.path = path                       # repo-relative posix
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self._parents: Dict[ast.AST, ast.AST] = {}
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self._parents[child] = node
        # line -> (set of allowed rule ids, reason or "")
        self.allows: Dict[int, Tuple[Set[str], str]] = {}
        for i, text in enumerate(self.lines, start=1):
            m = _ALLOW_RE.search(text)
            if m:
                ids = {s.strip() for s in m.group(1).split(",") if s.strip()}
                self.allows[i] = (ids, m.group(2).strip())

    # -- scope helpers -------------------------------------------------------

    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return self._parents.get(node)

    def context_of(self, node: ast.AST) -> str:
        """Dotted qualname of the enclosing defs/classes."""
        parts: List[str] = []
        cur = self._parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.ClassDef)):
                parts.append(cur.name)
            cur = self._parents.get(cur)
        return ".".join(reversed(parts)) or "<module>"

    def enclosing_function(self, node: ast.AST):
        cur = self._parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return cur
            cur = self._parents.get(cur)
        return None

    def snippet_at(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        return Finding(
            rule=rule, path=self.path, line=line,
            col=getattr(node, "col_offset", 0),
            context=self.context_of(node),
            snippet=self.snippet_at(line), message=message)

    # -- suppression ---------------------------------------------------------

    def allowed(self, finding: Finding) -> bool:
        """True iff an allow comment WITH a reason covers the finding's
        line (same line, or a comment-only line directly above)."""
        for line in (finding.line, finding.line - 1):
            entry = self.allows.get(line)
            if entry is None:
                continue
            if line != finding.line and not self.snippet_at(
                    line).startswith("#"):
                continue   # the line above only counts when comment-only
            ids, reason = entry
            if finding.rule in ids and reason:
                return True
        return False

    def unsupported_allows(self) -> Iterable[Finding]:
        """``SUP00`` findings for allow comments with no reason — they
        suppress nothing, which should be loud, not silent."""
        for line, (ids, reason) in sorted(self.allows.items()):
            if not reason:
                yield Finding(
                    rule="SUP00", path=self.path, line=line, col=0,
                    context="<module>", snippet=self.snippet_at(line),
                    message=("allow comment without a reason suppresses "
                             f"nothing (rules {sorted(ids)}); write "
                             "`# reprolint: allow[ID] why`"))


# ---------------------------------------------------------------------------
# Name-resolution helpers shared by rules
# ---------------------------------------------------------------------------


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def names_in(node: ast.AST) -> Set[str]:
    """All bare identifier names referenced inside ``node``."""
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def contains_float_literal(node: ast.AST) -> Optional[ast.Constant]:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Constant) and isinstance(sub.value, float):
            return sub
    return None


def contains_true_division(node: ast.AST) -> Optional[ast.BinOp]:
    for sub in ast.walk(node):
        if isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Div):
            return sub
    return None


# ---------------------------------------------------------------------------
# Baseline
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BaselineEntry:
    rule: str
    path: str
    context: str
    snippet: str
    reason: str

    def fingerprint(self) -> Tuple[str, str, str, str]:
        return (self.rule, self.path, self.context, self.snippet)


def load_baseline(path: Path) -> List[BaselineEntry]:
    if not path.exists():
        return []
    doc = json.loads(path.read_text(encoding="utf-8"))
    entries = []
    for raw in doc.get("findings", []):
        entries.append(BaselineEntry(
            rule=raw["rule"], path=raw["path"], context=raw["context"],
            snippet=raw["snippet"], reason=raw.get("reason", "")))
    return entries


def save_baseline(path: Path, findings: Sequence[Finding],
                  reasons: Optional[Dict[Tuple, str]] = None) -> None:
    """Serialize findings as the new baseline, carrying over reasons
    for fingerprints that already had one."""
    reasons = reasons or {}
    doc = {
        "comment": ("reprolint grandfathered findings — every entry needs "
                    "a reason; regenerate with --update-baseline"),
        "findings": [
            {
                "rule": f.rule, "path": f.path, "context": f.context,
                "snippet": f.snippet,
                "reason": reasons.get(f.fingerprint(),
                                      "grandfathered (add a real reason)"),
            }
            for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule))
        ],
    }
    path.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n",
                    encoding="utf-8")


# ---------------------------------------------------------------------------
# Scanning
# ---------------------------------------------------------------------------


def iter_py_files(paths: Sequence[str], root: Path) -> Iterable[Path]:
    for p in paths:
        full = (root / p) if not Path(p).is_absolute() else Path(p)
        if full.is_file() and full.suffix == ".py":
            yield full
        elif full.is_dir():
            yield from sorted(full.rglob("*.py"))


def scan_source(source: str, path: str, rules: Sequence) -> List[Finding]:
    """Run ``rules`` over one in-memory source file; returns the
    *unsuppressed* findings (allow comments already applied) plus any
    SUP00 reason-less-allow findings."""
    ctx = FileContext(path, source)
    out: List[Finding] = []
    seen: Set[Tuple[str, int, int]] = set()
    for rule in rules:
        if not rule.applies_to(path):
            continue
        for f in rule.check(ctx):
            # nested expressions (`a @ b @ c`) can hit one site twice;
            # one finding per (rule, line, col) is enough to fix it
            key = (f.rule, f.line, f.col)
            if key in seen or ctx.allowed(f):
                continue
            seen.add(key)
            out.append(f)
    out.extend(ctx.unsupported_allows())
    out.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return out


def scan_paths(paths: Sequence[str], rules: Sequence,
               root: Path = REPO_ROOT) -> List[Finding]:
    findings: List[Finding] = []
    for file in iter_py_files(paths, root):
        resolved = file.resolve()
        try:
            rel = resolved.relative_to(root.resolve()).as_posix()
        except ValueError:   # scanning outside the repo (tests, tmp dirs)
            rel = resolved.as_posix()
        try:
            source = file.read_text(encoding="utf-8")
            findings.extend(scan_source(source, rel, rules))
        except SyntaxError as exc:
            findings.append(Finding(
                rule="SUP00", path=rel, line=exc.lineno or 1, col=0,
                context="<module>", snippet="",
                message=f"file does not parse: {exc.msg}"))
    return findings


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv: Optional[Sequence[str]] = None) -> int:
    from .rules import ALL_RULES  # late import: rules import this module

    ap = argparse.ArgumentParser(
        prog="python -m tools.reprolint",
        description="repo-specific static analysis "
                    "(determinism / clock / jit / byte-ledger invariants)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to scan (repo-relative)")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                    help="baseline JSON of grandfathered findings")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignoring the baseline")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from this scan "
                         "(carries over existing reasons)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the active rules and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in ALL_RULES:
            print(f"{rule.id}  {rule.title}")
        return 0
    if not args.paths:
        ap.error("no paths to scan")

    findings = scan_paths(args.paths, ALL_RULES)

    baseline_path = Path(args.baseline)
    entries = [] if args.no_baseline else load_baseline(baseline_path)
    known = {e.fingerprint(): e for e in entries}

    if args.update_baseline:
        save_baseline(baseline_path, findings,
                      {fp: e.reason for fp, e in known.items()})
        print(f"reprolint: baseline rewritten with {len(findings)} "
              f"findings -> {baseline_path}")
        return 0

    seen = {f.fingerprint() for f in findings}
    new = [f for f in findings if f.fingerprint() not in known]
    stale = [e for e in entries if e.fingerprint() not in seen]

    for f in new:
        print(f.render(), file=sys.stderr)
    for e in stale:
        print(f"{e.path}: STALE baseline entry {e.rule} [{e.context}] "
              f"{e.snippet!r} — the code changed; run --update-baseline",
              file=sys.stderr)

    n_files = len(set(f.path for f in findings)) if findings else 0
    print(f"reprolint: {len(findings)} findings "
          f"({len(findings) - len(new)} baselined in {n_files} files), "
          f"{len(new)} new, {len(stale)} stale")
    return 1 if (new or stale) else 0


if __name__ == "__main__":
    sys.exit(main())
