"""DET01 — layout-dependent contractions in bitwise-contract modules.

PR 4's driver-parity hunt (DESIGN.md Sec. 9): XLA lowers ``@`` /
``jnp.dot`` / ``jnp.matmul`` to gemm/gemv whose accumulation order
depends on operand shapes, so the same mathematical contraction
produces different low bits when the row count changes (batched vs
row-at-a-time, sharded vs single-device).  Every contraction on the
loss-feeding path must therefore be written as an explicit
multiply + last-axis ``jnp.sum`` — a fixed reduction order regardless
of layout.  This rule bans the layout-dependent spellings inside the
modules under the bitwise contract; documented pure-jnp oracles carry
inline allows.
"""
from __future__ import annotations

from typing import Iterable, List

import ast

from ..engine import FileContext, Finding, dotted_name
from . import Rule

#: Path fragments under the bitwise-reproducibility contract.
SCOPE = (
    "repro/core/",
    "repro/runtime/",
    "repro/serving/",
    "repro/telemetry/monitor.py",
    "repro/kernels/ref.py",
)

#: Contraction callables whose accumulation order is layout-dependent.
BANNED_FUNCS = frozenset({
    "dot", "matmul", "einsum", "vdot", "inner", "tensordot",
})
BANNED_BASES = frozenset({"jnp", "np", "numpy", "jax.numpy"})
BANNED_DOTTED = frozenset({"lax.dot_general", "jax.lax.dot_general"})


class Det01(Rule):
    id = "DET01"
    title = ("layout-dependent contraction (@ / jnp.dot / matmul / "
             "einsum) in a bitwise-contract module")

    def applies_to(self, path: str) -> bool:
        return any(frag in path for frag in SCOPE)

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        out: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.BinOp) and isinstance(
                    node.op, ast.MatMult):
                out.append(ctx.finding(
                    self.id, node,
                    "`@` lowers to a gemm whose accumulation order is "
                    "layout-dependent; write explicit multiply + "
                    "last-axis reduce (DESIGN.md Sec. 9, PR 4)"))
            elif isinstance(node, ast.Call):
                name = dotted_name(node.func)
                if name is None:
                    continue
                base, _, leaf = name.rpartition(".")
                if (name in BANNED_DOTTED
                        or (leaf in BANNED_FUNCS and base in BANNED_BASES)):
                    out.append(ctx.finding(
                        self.id, node,
                        f"`{name}` is a layout-dependent contraction; "
                        "write explicit multiply + last-axis reduce "
                        "(DESIGN.md Sec. 9, PR 4)"))
        return out
