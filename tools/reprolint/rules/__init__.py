"""reprolint rule registry.

A rule is a tiny object: an ``id`` (stable, referenced by allow
comments, the baseline, and DESIGN.md Sec. 14), a one-line ``title``,
an ``applies_to(path)`` scope predicate, and ``check(ctx)`` yielding
:class:`~tools.reprolint.engine.Finding`s.  Rules never read files —
the engine hands them a parsed :class:`FileContext`.

Writing a new rule (see DESIGN.md Sec. 14 for the how-to):

1. Add ``rules/xyz01.py`` with a ``Rule`` subclass; keep detection
   name-based and syntactic — reprolint has no type information, so
   prefer precise scopes + allow-comments over clever inference.
2. Import and append it to :data:`ALL_RULES` below.
3. Add a golden positive + negative snippet to tests/test_reprolint.py
   and a DESIGN.md Sec. 14 subsection naming the bug that motivated it
   (tools/check_docs.py cross-checks the doc against this registry).
"""
from __future__ import annotations

from typing import Iterable, List


class Rule:
    """Base class; subclasses set ``id``/``title`` and implement
    ``check``.  ``applies_to`` defaults to every scanned file."""

    id: str = "XXX00"
    title: str = ""

    def applies_to(self, path: str) -> bool:
        return True

    def check(self, ctx) -> Iterable:
        raise NotImplementedError


from .det01 import Det01  # noqa: E402
from .clk01 import Clk01  # noqa: E402
from .jit01 import Jit01  # noqa: E402
from .acc01 import Acc01  # noqa: E402
from .rec01 import Rec01  # noqa: E402

#: Active rules, id-sorted.  check_docs.py verifies DESIGN.md Sec. 14
#: documents exactly these ids.
ALL_RULES: List[Rule] = sorted(
    [Acc01(), Clk01(), Det01(), Jit01(), Rec01()], key=lambda r: r.id)

RULE_IDS = [r.id for r in ALL_RULES]
