"""CLK01 — wall-clock time and unseeded randomness where the
simulated clock owns time.

The runtime, the serving engine, and the trace layer all promise that
a run is a pure function of its seeds: the discrete-event
``runtime.clock.Clock`` is the only source of time, and every random
draw comes from a ``np.random.Generator`` seeded through
``SeedSequence([seed, tag])`` (DESIGN.md Sec. 7; PR 8's float-grid
tick drift is what happens when wall-clock sneaks in).  This rule bans:

* wall-clock reads (``time.time``, ``datetime.now``, ...) inside the
  clock-owned modules — ``time.perf_counter`` stays legal because
  measuring *real* latency of a host call is not simulated time;
* global-state randomness (``np.random.rand`` and friends, stdlib
  ``random.*`` module functions) anywhere in the repo — the seeded
  ``default_rng`` / ``SeedSequence`` / ``Generator`` constructors and
  method calls on generator objects are untouched.
"""
from __future__ import annotations

from typing import Iterable, List

import ast

from ..engine import FileContext, Finding, dotted_name
from . import Rule

#: Modules where the simulated Clock owns time.
CLOCK_SCOPE = (
    "repro/runtime/",
    "repro/serving/",
    "repro/telemetry/trace.py",
)

WALL_CLOCK = frozenset({
    "time.time", "time.time_ns", "time.localtime", "time.gmtime",
    "time.ctime", "datetime.now", "datetime.utcnow", "datetime.today",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
})

#: np.random constructors that are fine: they make *seeded* objects.
NP_RANDOM_OK = frozenset({
    "default_rng", "SeedSequence", "Generator", "PCG64", "Philox",
    "BitGenerator",
})

#: stdlib random module-level functions (global Mersenne state).
#: ``random.Random(seed)`` instances are deliberately not banned.
STDLIB_RANDOM = frozenset({
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "sample", "uniform", "gauss", "normalvariate", "seed", "betavariate",
    "expovariate", "getrandbits", "random.random",
})


class Clk01(Rule):
    id = "CLK01"
    title = ("wall-clock read in a simulated-clock module, or "
             "global-state randomness")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        in_clock_scope = any(frag in ctx.path for frag in CLOCK_SCOPE)
        out: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            if name is None:
                continue
            if in_clock_scope and name in WALL_CLOCK:
                out.append(ctx.finding(
                    self.id, node,
                    f"`{name}()` reads the wall clock, but the simulated "
                    "Clock owns time here; use Clock.now for simulated "
                    "time or time.perf_counter for real durations "
                    "(DESIGN.md Sec. 6, PR 8)"))
                continue
            if name.startswith(("np.random.", "numpy.random.")):
                leaf = name.rsplit(".", 1)[1]
                if leaf not in NP_RANDOM_OK:
                    out.append(ctx.finding(
                        self.id, node,
                        f"`{name}()` draws from numpy's global RNG state; "
                        "thread a seeded np.random.default_rng(...) "
                        "Generator instead (DESIGN.md Sec. 6)"))
            elif name.startswith("random."):
                leaf = name.split(".", 1)[1]
                if leaf in STDLIB_RANDOM:
                    out.append(ctx.finding(
                        self.id, node,
                        f"`{name}()` uses the global Mersenne state; use a "
                        "seeded np.random.default_rng(...) Generator "
                        "(DESIGN.md Sec. 6)"))
        return out
