"""ACC01 — float contamination of the integer-exact byte ledger.

The Sec. 3 byte accounting is integer-exact by contract: every
``*_bytes`` quantity is an int (Python int or int64 on device), the
criterion bound comparisons are exact integer comparisons, and the
one deliberate int32 site (``accounting.device_sync_bytes_kernel``)
carries an overflow guard — PR 4 shipped exactly this overflow, and
PR 6's live monitor only works because bytes never pass through
floats.  This rule flags:

* arithmetic mixing a ``*bytes*`` identifier with a float literal;
* comparisons where one side mentions a ``*bytes*`` identifier and
  the other contains a float literal or a true division (``/``) —
  the classic ``total_bytes <= bound + 1e-9`` slop pattern;
* assignments to a ``*bytes*`` name whose value contains a float
  literal or a true division (use ``//`` on the ledger);
* ``float(...)`` applied to a ``*bytes*`` expression;
* ``.astype(<float dtype>)`` applied to a ``*bytes*`` expression —
  the population layer's cohort masks made ``round_bytes.astype
  (jnp.float32)`` a tempting reduction input (DESIGN.md Sec. 15);
* ``mean`` / ``average`` over a ``*bytes*`` expression — averaging
  the ledger over a cohort produces fractional bytes; cohort
  accounting sums integers (divide only on a host report path,
  explicitly allowed);
* ``int32`` dtypes referenced inside functions whose name contains
  ``bytes`` (the PR 4 overflow shape) — guarded sites carry an
  inline allow.
"""
from __future__ import annotations

import re
from typing import Iterable, List

import ast

from ..engine import (FileContext, Finding, contains_float_literal,
                      contains_true_division, dotted_name)
from . import Rule

BYTES_NAME = re.compile(r"(^|_)bytes($|_)|bytes$", re.IGNORECASE)
INT32_NAMES = frozenset({"jnp.int32", "np.int32", "numpy.int32",
                         "jax.numpy.int32"})
FLOAT_DTYPE_NAMES = frozenset(
    f"{mod}.{dt}" for mod in ("jnp", "np", "numpy", "jax.numpy")
    for dt in ("float16", "float32", "float64", "bfloat16"))
FLOAT_DTYPE_STRINGS = frozenset(
    {"float16", "float32", "float64", "bfloat16"})
MEAN_FUNCS = frozenset(
    f"{mod}.{fn}" for mod in ("jnp", "np", "numpy", "jax.numpy")
    for fn in ("mean", "average", "nanmean"))


def _is_float_dtype(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value in FLOAT_DTYPE_STRINGS
    if isinstance(node, ast.Name):
        return node.id == "float"
    return dotted_name(node) in FLOAT_DTYPE_NAMES


def mentions_bytes(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and BYTES_NAME.search(sub.id):
            return True
        if isinstance(sub, ast.Attribute) and BYTES_NAME.search(sub.attr):
            return True
    return False


def _float_taint(node: ast.AST) -> str:
    """Why ``node`` is float-valued, or '' if it isn't (syntactically)."""
    if contains_float_literal(node) is not None:
        return "a float literal"
    if contains_true_division(node) is not None:
        return "a true division (use // on the ledger)"
    return ""


class Acc01(Rule):
    id = "ACC01"
    title = ("float arithmetic / comparison slop / int32 accumulation "
             "on the integer-exact byte ledger")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        out: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.BinOp) and isinstance(
                    node.op, (ast.Add, ast.Sub, ast.Mult, ast.Div)):
                sides = [node.left, node.right]
                if any(mentions_bytes(s) for s in sides):
                    why = _float_taint(node)
                    if why:
                        out.append(ctx.finding(
                            self.id, node,
                            "byte-ledger arithmetic mixes in "
                            f"{why}; the Sec. 3 ledger is integer-exact "
                            "(DESIGN.md Sec. 7, PR 4)"))
            elif isinstance(node, ast.Compare):
                sides = [node.left] + list(node.comparators)
                if any(mentions_bytes(s) for s in sides):
                    tainted = next(
                        (s for s in sides
                         if not mentions_bytes(s) and _float_taint(s)), None)
                    if tainted is not None:
                        out.append(ctx.finding(
                            self.id, node,
                            "byte-ledger comparison against "
                            f"{_float_taint(tainted)}; byte bounds compare "
                            "integer-exact, no epsilon slop "
                            "(DESIGN.md Sec. 7, PR 4)"))
            elif isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                value = node.value
                if value is None:
                    continue
                if any(mentions_bytes(t) for t in targets):
                    why = _float_taint(value)
                    if why:
                        out.append(ctx.finding(
                            self.id, node,
                            f"assignment to a byte-ledger name from {why}; "
                            "keep *_bytes values integral "
                            "(DESIGN.md Sec. 7)"))
            elif isinstance(node, ast.Call):
                fname = dotted_name(node.func)
                if (fname == "float" and node.args
                        and mentions_bytes(node.args[0])):
                    out.append(ctx.finding(
                        self.id, node,
                        "`float()` on a byte-ledger value loses "
                        "integer-exactness above 2**53; keep bytes "
                        "integral (DESIGN.md Sec. 7)"))
                elif (isinstance(node.func, ast.Attribute)
                        and node.func.attr == "astype"
                        and mentions_bytes(node.func.value)
                        and node.args and _is_float_dtype(node.args[0])):
                    out.append(ctx.finding(
                        self.id, node,
                        "float `.astype` on a byte-ledger value; cohort "
                        "byte paths stay integral end to end "
                        "(DESIGN.md Sec. 15)"))
                elif ((fname in MEAN_FUNCS
                        and node.args and mentions_bytes(node.args[0]))
                      or (isinstance(node.func, ast.Attribute)
                          and node.func.attr == "mean"
                          and mentions_bytes(node.func.value))):
                    out.append(ctx.finding(
                        self.id, node,
                        "averaging a byte-ledger value produces "
                        "fractional bytes; cohort accounting sums "
                        "integers — divide only on an explicitly "
                        "allowed host report path (DESIGN.md Sec. 15)"))

        # int32 accumulation inside *bytes* functions (PR 4 overflow)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if "bytes" not in node.name:
                continue
            for sub in ast.walk(node):
                if (isinstance(sub, (ast.Attribute, ast.Name))
                        and dotted_name(sub) in INT32_NAMES):
                    out.append(ctx.finding(
                        self.id, sub,
                        f"int32 in byte-ledger function `{node.name}` — "
                        "the PR 4 overflow shape; use int64 or prove a "
                        "bound and allow with the guard as the reason"))
        return out
