"""REC01 — recompile hazards on jit cache keys.

The engine caches one compiled step per substrate/protocol pair
(``core/engine.py``'s ``_jitted`` lru_cache) and keys it on frozen
dataclasses; serving reuses the same cache across requests.  Two
mechanical ways to break that (both produce silent recompiles, which
the CompileCounter tests then chase for hours):

* a *frozen* dataclass — i.e. one meant to be hashable and used as a
  cache key — with an unhashable field: a ``default_factory`` of
  ``list``/``dict``/``set``, or a field annotated with a mutable
  container type.  ``hash()`` raises at first use, or worse, an
  ``eq=False`` fallback keys the cache on object identity and every
  fresh instance recompiles;
* a dict/list/set literal passed positionally to a jitted entry point
  (a name bound to ``jax.jit(...)`` or ``partial(jax.jit, ...)``):
  each literal is a fresh pytree container whose *structure* is the
  cache key part, but mutating it between calls (the usual reason to
  pass one) changes leaves without changing identity — and a set is
  not a pytree at all.
"""
from __future__ import annotations

from typing import Iterable, List, Set

import ast

from ..engine import FileContext, Finding, dotted_name
from . import Rule
from .jit01 import _is_jit_expr

MUTABLE_FACTORIES = frozenset({"list", "dict", "set", "bytearray"})
MUTABLE_ANNOTATIONS = frozenset({
    "list", "dict", "set", "List", "Dict", "Set", "MutableMapping",
    "DefaultDict", "bytearray",
})


def _dataclass_frozen(node: ast.ClassDef) -> bool:
    """True iff decorated ``@dataclass(frozen=True)`` (any spelling of
    the dataclass decorator)."""
    for dec in node.decorator_list:
        if not isinstance(dec, ast.Call):
            continue
        if dotted_name(dec.func) not in ("dataclass", "dataclasses.dataclass"):
            continue
        for kw in dec.keywords:
            if (kw.arg == "frozen" and isinstance(kw.value, ast.Constant)
                    and kw.value.value is True):
                return True
    return False


def _annotation_names(node: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            out.add(sub.id)
        elif isinstance(sub, ast.Attribute):
            out.add(sub.attr)
        elif isinstance(sub, ast.Constant) and isinstance(sub.value, str):
            # string annotations ("List[int]") — take the head symbol
            out.add(sub.value.split("[", 1)[0].strip())
    return out


class Rec01(Rule):
    id = "REC01"
    title = ("recompile hazard: unhashable field on a frozen (jit-key) "
             "dataclass, or mutable literal passed to a jitted entry")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        out: List[Finding] = []

        # 1. frozen dataclasses with unhashable fields
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.ClassDef)
                    and _dataclass_frozen(node)):
                continue
            for item in node.body:
                if not isinstance(item, ast.AnnAssign):
                    continue
                if (isinstance(item.value, ast.Call)
                        and dotted_name(item.value.func)
                        in ("field", "dataclasses.field")):
                    for kw in item.value.keywords:
                        if (kw.arg == "default_factory"
                                and dotted_name(kw.value)
                                in MUTABLE_FACTORIES):
                            out.append(ctx.finding(
                                self.id, item,
                                f"frozen dataclass `{node.name}` has a "
                                f"mutable default_factory "
                                f"`{dotted_name(kw.value)}`; frozen "
                                "dataclasses key the jit cache and must "
                                "stay hashable (DESIGN.md Sec. 8)"))
                if item.annotation is not None:
                    bad = _annotation_names(item.annotation) \
                        & MUTABLE_ANNOTATIONS
                    if bad:
                        out.append(ctx.finding(
                            self.id, item,
                            f"frozen dataclass `{node.name}` field "
                            f"annotated with unhashable {sorted(bad)}; "
                            "hash() will raise when it keys the jit "
                            "cache — use a tuple/frozen type "
                            "(DESIGN.md Sec. 8)"))

        # 2. mutable literals passed to jitted entry points
        jitted_names: Set[str] = set()
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Assign) and isinstance(
                    node.value, ast.Call) and _is_jit_expr(node.value):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        jitted_names.add(tgt.id)
                    elif isinstance(tgt, ast.Attribute):
                        jitted_names.add(tgt.attr)
        if jitted_names:
            for node in ast.walk(ctx.tree):
                if not isinstance(node, ast.Call):
                    continue
                fname = dotted_name(node.func)
                leaf = fname.rpartition(".")[2] if fname else None
                if leaf not in jitted_names:
                    continue
                for arg in node.args:
                    if isinstance(arg, (ast.Dict, ast.List, ast.Set)):
                        kind = type(arg).__name__.lower()
                        out.append(ctx.finding(
                            self.id, arg,
                            f"{kind} literal passed to jitted entry "
                            f"`{leaf}`; fresh mutable containers defeat "
                            "the jit cache (and sets aren't pytrees) — "
                            "pass arrays/tuples (DESIGN.md Sec. 8)"))
        return out
